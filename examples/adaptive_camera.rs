//! The paper's §3.3.3 deployment scenario: a solar/battery-powered
//! monitoring camera serving detection requests around the clock.
//!
//! A solar-day battery trace drives the switch policy: full-bit INT8 in
//! busy/charged hours, part-bit INT4 when the battery sags. The run
//! reports per-phase accuracy, every switch's byte cost, and what the
//! same trace would have cost under the diverse-bitwidths deployment.
//!
//! ```bash
//! cargo run --release --example adaptive_camera [arch] [steps]
//! ```

use anyhow::Result;
use nestquant::coordinator::{Coordinator, SwitchPolicy};
use nestquant::device::ResourceTrace;

fn main() -> Result<()> {
    let root = nestquant::artifacts_dir();
    let mut args = std::env::args().skip(1);
    let arch = args.next().unwrap_or_else(|| "cnn_m".into());
    let steps: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(48);

    let mut coord = Coordinator::new(&root, &arch, 8, 4)?;
    let (sec_a, sec_b) = coord.manager.section_bytes();

    println!("== adaptive camera: {arch}, INT(8|4), {steps}-step solar day ==");
    let trace = ResourceTrace::solar_day(steps);
    let policy = SwitchPolicy::default();
    let report = coord.run_trace(trace, policy, 32)?;

    println!("\nphase log ({} switches):", report.switches.len());
    for s in &report.switches {
        println!(
            "  t={:>3}  battery {:>4.0}%  → {:?}  (page-in {:>6.1}KB, page-out {:>6.1}KB, {:.1}ms)",
            s.step,
            s.level * 100.0,
            s.to,
            s.cost.page_in_bytes as f64 / 1e3,
            s.cost.page_out_bytes as f64 / 1e3,
            s.cost.micros as f64 / 1e3,
        );
    }

    println!("\nserved: {} full-bit reqs @ {:.3} acc | {} part-bit reqs @ {:.3} acc",
             report.full_served, report.full_acc(), report.part_served, report.part_acc());

    // What would diverse bitwidths have paid on the same switch schedule?
    let spec = coord.manifest.model(&arch)?;
    let int8 = std::fs::metadata(coord.manifest.abs(&spec.mono_containers[&8]))?.len();
    let int4 = std::fs::metadata(coord.manifest.abs(&spec.mono_containers[&4]))?.len();
    let nq_moved: u64 = report
        .switches
        .iter()
        .map(|s| s.cost.page_in_bytes + s.cost.page_out_bytes)
        .sum();
    let diverse_moved = report.switches.len() as u64 * (int8 + int4);
    println!("\nswitching I/O over the day:");
    println!("  NestQuant          : {:>8.1} KB  (w_low only, {} moves)", nq_moved as f64 / 1e3, report.switches.len());
    println!("  diverse bitwidths  : {:>8.1} KB  (whole models swapped)", diverse_moved as f64 / 1e3);
    println!("  reduction          : {:.1}%", (1.0 - nq_moved as f64 / diverse_moved as f64) * 100.0);
    println!("\nresident set: part-bit {:.1}KB / full-bit {:.1}KB (packed accounting)",
             sec_a as f64 / 1e3, (sec_a + sec_b) as f64 / 1e3);
    println!("\n{}", coord.metrics.summary());
    Ok(())
}
