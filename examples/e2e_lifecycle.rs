//! END-TO-END DRIVER — the full system on a real workload, all layers
//! composing (recorded in EXPERIMENTS.md):
//!
//!   1. OTA: the edge server pushes the `.nq` container to the device
//!      over TCP (measured wire bytes).
//!   2. The device launches the part-bit model from the received bytes,
//!      then upgrades to full-bit — the Pallas-kernel HLO graphs execute
//!      under PJRT from Rust.
//!   3. A multi-client inference load runs against the TCP server with
//!      dynamic batching, while a solar-day battery trace drives live
//!      full↔part switches under the hysteresis policy.
//!   4. Report: per-variant accuracy, latency percentiles, switching I/O
//!      vs the diverse-bitwidths baseline, wire traffic.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_lifecycle [arch]
//! ```

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;
use nestquant::coordinator::{server, Coordinator, Decision, PolicyState, SwitchPolicy, Variant};
use nestquant::device::ResourceTrace;
use nestquant::transport::{pull_frames, Frame, FrameKind, Meter, PushServer};

fn main() -> Result<()> {
    let root = nestquant::artifacts_dir();
    let arch = std::env::args().nth(1).unwrap_or_else(|| "cnn_m".into());
    let t_start = Instant::now();
    println!("=== NestQuant end-to-end lifecycle: {arch} INT(8|4) ===\n");

    // ---- 1. OTA transmission (edge server → device) --------------------
    let nq_path = root.join(format!("nq/{arch}_n8h4.nq"));
    let push = PushServer::serve_frames(
        vec![Frame {
            kind: FrameKind::ModelFull,
            name: format!("{arch}_n8h4.nq"),
            payload: std::fs::read(&nq_path)?,
        }],
        1,
    )?;
    let meter = Meter::default();
    let frames = pull_frames(push.addr, 1, &meter)?;
    let (wire_sent, _) = push.join();
    println!("[ota] received {} ({:.2} MB wire)", frames[0].name, wire_sent as f64 / 1e6);

    // Device-side sanity: open what actually arrived as an in-memory
    // archive (header + layout walk; no payload decode).
    let received = nestquant::store::NqArchive::from_bytes(&frames[0].payload)?;
    println!(
        "[ota] container OK: {} tensors, INT({}|{}), sections {:.1}/{:.1} KB",
        received.layout()?.len(),
        received.index().n,
        received.index().h,
        received.section_a_bytes() as f64 / 1e3,
        received.section_b_bytes() as f64 / 1e3
    );

    // ---- 2. Device boots the model ------------------------------------
    let mut coord = Coordinator::new(&root, &arch, 8, 4)?;
    let boot = coord.manager.load_part_bit(&mut coord.ledger)?;
    println!(
        "\n[boot] part-bit model live after paging {:.1} KB ({:.1} ms)",
        boot.page_in_bytes as f64 / 1e3,
        boot.micros as f64 / 1e3
    );
    let up = coord.manager.upgrade(&mut coord.ledger)?;
    println!(
        "[boot] upgraded to full-bit: +{:.1} KB, zero page-out ({:.1} ms)",
        up.page_in_bytes as f64 / 1e3,
        up.micros as f64 / 1e3
    );

    // accuracy checkpoints straight through PJRT
    let full_acc = coord.eval_accuracy(Some(1024))?;
    coord.manager.downgrade(&mut coord.ledger)?;
    let part_acc = coord.eval_accuracy(Some(1024))?;
    coord.manager.upgrade(&mut coord.ledger)?;
    println!("[eval] top-1 @1024: full-bit {full_acc:.3} | part-bit {part_acc:.3}");

    // ---- 3. Serve a live load while the battery cycles ------------------
    let (x, y) = coord.manifest.load_val()?;
    let img_len = coord.manifest.img * coord.manifest.img * coord.manifest.channels;
    let metrics = Arc::clone(&coord.metrics);
    let coord = Arc::new(Mutex::new(coord));
    let handle = server::serve(Arc::clone(&coord), server::ServerConfig::default())?;
    let addr = handle.addr;
    println!("\n[serve] inference server on {addr}; 4 clients + battery trace");

    let stop = Arc::new(AtomicBool::new(false));
    let correct = Arc::new(AtomicU64::new(0));
    let total = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for c in 0..4usize {
        let stop = Arc::clone(&stop);
        let correct = Arc::clone(&correct);
        let total = Arc::clone(&total);
        let x = x.clone();
        let y = y.clone();
        clients.push(std::thread::spawn(move || -> Result<()> {
            let mut cl = server::Client::connect(addr)?;
            let mut i = c * 997; // decorrelate clients
            while !stop.load(Ordering::Relaxed) {
                let j = i % y.len();
                let logits = cl.infer(&x[j * img_len..(j + 1) * img_len])?;
                let pred = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as u32;
                correct.fetch_add((pred == y[j]) as u64, Ordering::Relaxed);
                total.fetch_add(1, Ordering::Relaxed);
                i += 1;
            }
            Ok(())
        }));
    }

    // battery trace driving switches through the shared coordinator
    let mut trace = ResourceTrace::solar_day(24);
    let mut policy = PolicyState::new(SwitchPolicy::default(), Variant::FullBit);
    let mut switch_log = Vec::new();
    while let Some(level) = trace.next_level() {
        std::thread::sleep(Duration::from_millis(120));
        let decision = policy.decide(level);
        if !matches!(decision, Decision::Stay) {
            let mut c = coord.lock().unwrap();
            if let Some(cost) = c.apply(decision)? {
                switch_log.push((level, policy.current(), cost));
            }
        }
    }
    stop.store(true, Ordering::Relaxed);
    for c in clients {
        c.join().unwrap()?;
    }
    handle.stop();

    // ---- 4. Report ------------------------------------------------------
    println!("\n[load] {} requests, {:.3} accuracy under live switching",
             total.load(Ordering::Relaxed),
             correct.load(Ordering::Relaxed) as f64 / total.load(Ordering::Relaxed).max(1) as f64);
    println!("[load] {} live switches during serving:", switch_log.len());
    for (level, to, cost) in &switch_log {
        println!(
            "    battery {:>4.0}% → {to:?}: page-in {:.1}KB page-out {:.1}KB ({:.1}ms)",
            level * 100.0,
            cost.page_in_bytes as f64 / 1e3,
            cost.page_out_bytes as f64 / 1e3,
            cost.micros as f64 / 1e3
        );
    }
    let moved: u64 = switch_log
        .iter()
        .map(|(_, _, c)| c.page_in_bytes + c.page_out_bytes)
        .sum();
    let spec_int8 = {
        let c = coord.lock().unwrap();
        let spec = c.manifest.model(&arch)?.clone();
        let a = std::fs::metadata(c.manifest.abs(&spec.mono_containers[&8]))?.len();
        let b = std::fs::metadata(c.manifest.abs(&spec.mono_containers[&4]))?.len();
        a + b
    };
    let diverse_moved = switch_log.len() as u64 * spec_int8;
    println!(
        "\n[headline] switching I/O: NestQuant {:.1}KB vs diverse {:.1}KB → {:.1}% reduction",
        moved as f64 / 1e3,
        diverse_moved as f64 / 1e3,
        (1.0 - moved as f64 / diverse_moved.max(1) as f64) * 100.0
    );
    println!("[headline] wire traffic for BOTH models in one push: {:.2}MB", wire_sent as f64 / 1e6);
    println!("\n{}", metrics.summary());
    println!("\ntotal wall time: {:.1}s", t_start.elapsed().as_secs_f64());
    Ok(())
}
