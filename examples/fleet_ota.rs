//! Fleet OTA: an edge server pushes models to a fleet of IoT devices over
//! TCP, reproducing the paper's network-traffic experiment (§4.3.1,
//! Figs 13/14) with *measured wire bytes*, plus the staged-provisioning
//! flow NestQuant enables: push section A first (devices come online in
//! part-bit mode immediately), stream section B later as a delta.
//!
//! ```bash
//! cargo run --release --example fleet_ota [arch] [devices]
//! ```

use anyhow::Result;
use nestquant::device::{transmission_seconds, RPI_4B};
use nestquant::transport::{pull_frames, Frame, FrameKind, Meter, PushServer};

fn push(frames: Vec<Frame>, devices: usize) -> Result<u64> {
    let n = frames.len();
    let server = PushServer::serve_frames(frames, devices)?;
    let mut handles = Vec::new();
    for _ in 0..devices {
        let addr = server.addr;
        handles.push(std::thread::spawn(move || {
            let meter = Meter::default();
            pull_frames(addr, n, &meter).map(|_| meter.snapshot().1)
        }));
    }
    let mut received = 0;
    for h in handles {
        received += h.join().unwrap()?;
    }
    let (sent, _) = server.join();
    assert_eq!(sent, received, "wire accounting must balance");
    Ok(sent)
}

fn file_frame(path: &std::path::Path, kind: FrameKind) -> Result<Frame> {
    Ok(Frame {
        kind,
        name: path.file_name().unwrap().to_string_lossy().into_owned(),
        payload: std::fs::read(path)?,
    })
}

fn main() -> Result<()> {
    let root = nestquant::artifacts_dir();
    let mut args = std::env::args().skip(1);
    let arch = args.next().unwrap_or_else(|| "cnn_m".into());
    let devices: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(8);

    println!("== fleet OTA: pushing {arch} to {devices} devices (localhost TCP, measured) ==\n");

    // Deployment A: FP32 model.
    let fp32 = push(
        vec![file_frame(&root.join(format!("nq/{arch}_fp32.nq")), FrameKind::ModelFull)?],
        devices,
    )?;

    // Deployment B: diverse bitwidths (INT8 + INT4 separately).
    let diverse = push(
        vec![
            file_frame(&root.join(format!("nq/{arch}_int8.nq")), FrameKind::ModelFull)?,
            file_frame(&root.join(format!("nq/{arch}_int4.nq")), FrameKind::ModelFull)?,
        ],
        devices,
    )?;

    // Deployment C: one NestQuant container (both models in one file).
    let nest_path = root.join(format!("nq/{arch}_n8h4.nq"));
    let nest = push(vec![file_frame(&nest_path, FrameKind::ModelFull)?], devices)?;

    // Deployment D: staged provisioning — section A now, section B later.
    let container = nestquant::container::read(&nest_path, true)?;
    let blob = std::fs::read(&nest_path)?;
    let split = container.section_b_offset as usize;
    let stage_a = push(
        vec![Frame {
            kind: FrameKind::ModelPart,
            name: format!("{arch}.secA"),
            payload: blob[..split].to_vec(),
        }],
        devices,
    )?;
    let stage_b = push(
        vec![Frame {
            kind: FrameKind::ModelDelta,
            name: format!("{arch}.secB"),
            payload: blob[split..].to_vec(),
        }],
        devices,
    )?;

    let row = |name: &str, bytes: u64| {
        println!(
            "  {name:<28} {:>10.2} MB wire   ~{:>6.2}s on {} fleet-wide",
            bytes as f64 / 1e6,
            transmission_seconds(&RPI_4B, bytes),
            RPI_4B.name
        );
    };
    row("FP32", fp32);
    row("diverse INT8+INT4", diverse);
    row("NestQuant INT(8|4)", nest);
    row("  staged: section A first", stage_a);
    row("  staged: section B delta", stage_b);
    println!(
        "\nNestQuant vs diverse: {:.1}% less traffic; vs FP32: {:.1}% less",
        (1.0 - nest as f64 / diverse as f64) * 100.0,
        (1.0 - nest as f64 / fp32 as f64) * 100.0
    );
    println!("staged provisioning gets devices serving after {:.1}% of the bytes", stage_a as f64 / nest as f64 * 100.0);
    Ok(())
}
