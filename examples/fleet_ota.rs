//! Fleet OTA: an edge server distributes NestQuant models to a device
//! fleet through the `fleet` subsystem — staged provisioning (Section A
//! first, devices serve part-bit immediately), Section-B upgrade deltas,
//! a zoo-wide shared section cache, and resumable chunked transfers —
//! the fleet-scale extension of the paper's network-traffic experiment
//! (§4.3.1), with *measured wire bytes*. For the paper's FP32 vs
//! diverse-bitwidths vs NestQuant single-push comparison (Figs 13/14),
//! run `nestquant report traffic` against built artifacts.
//!
//! Works offline: when `make artifacts` hasn't run, a synthetic INT(8|4)
//! zoo is built on the fly.
//!
//! ```bash
//! cargo run --release --example fleet_ota [devices] [steps]
//! ```

use std::time::Duration;

use anyhow::Result;
use nestquant::device::{transmission_seconds, MemoryLedger, ResourceTrace, RPI_4B};
use nestquant::fleet::{FleetClient, FleetConfig, FleetServer, Zoo};
use nestquant::store::SectionSource;

fn main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let devices: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(8);
    let steps: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(24);

    // zoo: artifact containers when built, synthetic ones otherwise
    let root = nestquant::artifacts_dir();
    let mut zoo = Zoo::new();
    let nq_dir = root.join("nq");
    if nq_dir.is_dir() {
        zoo.scan_nest_dir(&nq_dir)?;
    }
    if zoo.is_empty() {
        let dir = std::env::temp_dir().join(format!("nq_ota_zoo_{}", std::process::id()));
        zoo = nestquant::fleet::synthetic_zoo(&dir, 3, 40)?;
        println!("(no artifacts found — synthetic INT(8|4) zoo)\n");
    }
    let model_ids: Vec<String> = zoo.ids().map(str::to_string).collect();

    println!(
        "== fleet OTA: {} models → {} devices over localhost TCP (measured wire bytes) ==\n",
        model_ids.len(),
        devices
    );

    // 8 KiB chunks so even the smallest Section B spans many chunks and
    // the kill/resume demo below genuinely interrupts a transfer
    let config = FleetConfig {
        chunk_bytes: 8 << 10,
        ..FleetConfig::default()
    };
    let handle = FleetServer::start(zoo, config)?;

    // Every device: staged provisioning (Section A → part-bit launch),
    // then a resource trace driving Section-B paging via server advice.
    let traces = ResourceTrace::fleet(devices, steps, 0x07A);
    let mut joins = Vec::new();
    for (d, trace) in traces.into_iter().enumerate() {
        let addr = handle.addr;
        let model = model_ids[d % model_ids.len()].clone();
        joins.push(std::thread::spawn(move || -> Result<(u64, u64, u64, u64)> {
            let mut client =
                FleetClient::connect(addr, &format!("dev-{d:02}"), Duration::from_secs(30))?;
            let mut ledger = MemoryLedger::new(4 << 30);
            let report = client.playback(&model, trace, &mut ledger)?;
            let (_, received) = client.wire();
            // measured: everything pulled beyond the Section-A provisioning
            // is Section-B delta traffic (partial/resumed pulls included)
            Ok((
                report.section_a_bytes,
                report.payload_pulled - report.section_a_bytes,
                report.payload_pulled,
                received,
            ))
        }));
    }
    let (mut a_total, mut delta_total, mut payload_total, mut wire_total) = (0u64, 0u64, 0u64, 0u64);
    for j in joins {
        let (a, deltas, payload, wire) = j.join().unwrap()?;
        a_total += a;
        delta_total += deltas;
        payload_total += payload;
        wire_total += wire;
    }

    // resume demo on the first model
    let model = &model_ids[0];
    let demo =
        nestquant::fleet::demo_kill_resume(handle.addr, "dev-flaky", model, 3, Duration::from_secs(30))?;
    if demo.killed.completed {
        println!("  (section B fits in ≤3 chunks here; nothing to resume)");
    }
    let (killed, resume_from, resumed) = (demo.killed, demo.resume_from, demo.resumed);

    // store-over-the-wire: open the same model as a *remote archive* —
    // identical typed views to a local file, bytes served by the fleet
    // tier (and its shared section cache)
    let remote = std::sync::Arc::new(nestquant::fleet::RemoteSource::connect(
        handle.addr,
        "dev-store",
        model.as_str(),
        Duration::from_secs(30),
    )?);
    let archive = nestquant::store::NqArchive::with_source(remote.clone())?;
    let part = archive.part_bit()?;
    println!(
        "\n  remote archive: {} tensors, INT({}|{}), {:.1} KB section A via {}",
        part.len(),
        archive.index().n,
        archive.index().h,
        archive.section_a_bytes() as f64 / 1e3,
        archive.source().describe()
    );
    drop(part);
    let (_, remote_received) = remote.wire();
    wire_total += remote_received;
    drop(archive);
    drop(remote);

    let cache = std::sync::Arc::clone(&handle.cache);
    let meter = std::sync::Arc::clone(&handle.meter);
    handle.stop();
    let stats = cache.stats();
    let (srv_sent, _) = meter.snapshot();

    let row = |name: &str, bytes: u64| {
        println!(
            "  {name:<40} {:>10.2} MB wire   ~{:>6.2}s fleet-wide on {}",
            bytes as f64 / 1e6,
            transmission_seconds(&RPI_4B, bytes),
            RPI_4B.name
        );
    };
    row("staged: Section A (part-bit launch)", a_total);
    row("staged: Section-B upgrade deltas", delta_total);
    row("total section payload", payload_total);
    println!();
    println!(
        "  devices came online after {:.1}% of the payload bytes (Section A first)",
        a_total as f64 / payload_total.max(1) as f64 * 100.0
    );
    println!(
        "  resume: killed after {} chunks, resumed at byte {resume_from}, moved {} more bytes \
         ({} bytes saved vs restart)",
        killed.chunks, resumed.payload_bytes, resume_from
    );
    println!(
        "  cache: {} hits / {} misses — {:.2} MB read from disk to serve {:.2} MB of wire payload",
        stats.hits,
        stats.misses,
        stats.disk_bytes as f64 / 1e6,
        payload_total as f64 / 1e6
    );
    println!(
        "  wire: server sent {:.2} MB total (devices received {:.2} MB incl. framing)",
        srv_sent as f64 / 1e6,
        wire_total as f64 / 1e6
    );
    Ok(())
}
