//! MULTI-TENANT SERVING DEMO — two architectures, one server, one
//! shared Section-B budget (artifact-free: synthetic containers).
//!
//!   1. Build a two-model zoo (`edge_cam` INT(8|4), `edge_mic`
//!      INT(6|3)) and host both through one `ModelStore`-backed server;
//!      clients route by model id.
//!   2. Upgrade both models under a budget that fits only ONE resident
//!      Section B: the second upgrade evicts the first tenant's
//!      low-bit section, which falls back to part-bit on its next
//!      batch — the printed eviction trace is the budget's own ledger.
//!   3. Every reply is checked against the model's single-tenant
//!      baseline (part-bit or full-bit, bit-for-bit), and the archives'
//!      byte accounting proves zero section-A re-reads throughout.
//!
//! ```bash
//! cargo run --release --example multi_tenant
//! ```

use std::sync::Arc;

use anyhow::Result;
use nestquant::container;
use nestquant::coordinator::server::{serve_tenants, Client, ServerConfig, TenantExecutor};
use nestquant::coordinator::tenant::{nest_tenants_from_dir, NestTenant};
use nestquant::coordinator::{Decision, Variant};
use nestquant::store::{ModelStore, NqArchive, StoreBudget};
use nestquant::util::prng::Rng;

fn probe_image(seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
}

/// Single-tenant baseline logits for one image (private archive: the
/// server's byte accounting stays untouched).
fn baseline(path: &std::path::Path, variant: Variant, img: &[f32]) -> Result<Vec<f32>> {
    let archive = Arc::new(NqArchive::open(path)?);
    let budget = Arc::new(StoreBudget::new(u64::MAX));
    let mut t = NestTenant::from_archive("baseline", archive, budget, 4)?;
    if variant == Variant::FullBit {
        t.switch(Decision::SwitchTo(Variant::FullBit))?;
    }
    let (_, image_len, classes) = t.shape();
    let mut input = vec![0f32; 4 * image_len];
    input[..image_len].copy_from_slice(img);
    Ok(t.run_batch(&input)?[..classes].to_vec())
}

fn check(tag: &str, got: &[f32], part: &[f32], full: &[f32]) {
    let which = if got == part {
        "part-bit"
    } else if got == full {
        "full-bit"
    } else {
        panic!("{tag}: reply matches neither baseline");
    };
    println!(
        "  {tag:<28} -> {which} logits, first 3 = {:?}",
        &got[..3.min(got.len())]
    );
}

fn main() -> Result<()> {
    println!("=== NestQuant multi-tenant serving: 2 architectures, 1 budget ===\n");

    // ---- 1. zoo + server ------------------------------------------------
    let dir = std::env::temp_dir().join(format!("nq_multi_tenant_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let cam = container::synthetic_nest(0xCA3, 8, 4, 512, 32)?;
    let mic = container::synthetic_nest(0x31C, 6, 3, 384, 16)?;
    let cam_path = dir.join("edge_cam.nq");
    let mic_path = dir.join("edge_mic.nq");
    let (_, _, cam_b) = container::write(&cam_path, &cam)?;
    let (_, _, mic_b) = container::write(&mic_path, &mic)?;

    // the shared budget fits the larger Section B, never both
    let cap = cam_b.max(mic_b);
    let store = ModelStore::new();
    let budget = Arc::new(StoreBudget::new(cap));
    let tenants = nest_tenants_from_dir(&dir, &store, &budget, 4)?;
    let archives: Vec<_> = tenants.iter().map(|(_, t)| Arc::clone(t.archive())).collect();
    let boxed: Vec<(String, Box<dyn TenantExecutor>)> = tenants
        .into_iter()
        .map(|(id, t)| (id, Box::new(t) as Box<dyn TenantExecutor>))
        .collect();
    let handle = serve_tenants(boxed, ServerConfig::default())?;
    println!(
        "[serve] {} models on {} — Section-B budget {cap} B (cam B {cam_b} / mic B {mic_b})",
        handle.models().len(),
        handle.addr
    );

    let mut client = Client::connect(handle.addr)?;
    println!("[serve] hosted: {:?}\n", client.models()?);

    // baselines per model
    let cam_img = probe_image(1, 512);
    let mic_img = probe_image(2, 384);
    let cam_part = baseline(&cam_path, Variant::PartBit, &cam_img)?;
    let cam_full = baseline(&cam_path, Variant::FullBit, &cam_img)?;
    let mic_part = baseline(&mic_path, Variant::PartBit, &mic_img)?;
    let mic_full = baseline(&mic_path, Variant::FullBit, &mic_img)?;

    // ---- 2. both tenants part-bit -------------------------------------
    println!("[step] part-bit launches:");
    check("edge_cam", &client.infer_model("edge_cam", &cam_img)?, &cam_part, &cam_full);
    check("edge_mic", &client.infer_model("edge_mic", &mic_img)?, &mic_part, &mic_full);

    // ---- 3. upgrade cam, then mic (evicts cam) -------------------------
    println!("\n[step] upgrade edge_cam (fits the budget):");
    handle.advise("edge_cam", Decision::SwitchTo(Variant::FullBit))?;
    check("edge_cam", &client.infer_model("edge_cam", &cam_img)?, &cam_part, &cam_full);

    println!("\n[step] upgrade edge_mic (must evict edge_cam's Section B):");
    handle.advise("edge_mic", Decision::SwitchTo(Variant::FullBit))?;
    check("edge_mic", &client.infer_model("edge_mic", &mic_img)?, &mic_part, &mic_full);
    check(
        "edge_cam (after eviction)",
        &client.infer_model("edge_cam", &cam_img)?,
        &cam_part,
        &cam_full,
    );

    // ---- 4. the shared-budget eviction trace ---------------------------
    println!(
        "\n[budget] resident {} / {} B, {} eviction(s); trace:",
        budget.resident_bytes(),
        cap,
        budget.evictions()
    );
    for e in budget.drain_events() {
        println!("    {e}");
    }

    for (id, a) in handle.models().iter().zip(&archives) {
        let s = a.stats();
        println!(
            "[bytes] {id:<10} A fetched {}x ({} B), B fetched {}x, B released {}x — zero A re-reads",
            s.a_fetches, s.a_bytes_fetched, s.b_fetches, s.b_releases
        );
    }
    for id in handle.models() {
        let m = handle.metrics(&id).unwrap();
        println!("[metrics] {id}: {}", m.summary());
    }

    client.stop_server()?;
    handle.stop();
    println!("\ndone: replies stayed baseline-exact through routing, upgrades, and eviction.");
    Ok(())
}
