// perf probe: a0 vs a8 latency per arch
use nestquant::runtime::{Engine, Manifest};
use nestquant::store::{NqArchive, PayloadView};
fn main() -> anyhow::Result<()> {
    let root = nestquant::artifacts_dir();
    let m = Manifest::load(&root)?;
    let engine = Engine::cpu()?;
    let mut scratch = Vec::new();
    for arch in ["cnn_m", "vit_s"] {
        let spec = m.model(arch)?;
        let model = NqArchive::open(m.abs(&spec.fp32_container))?.part_bit()?;
        let mut bufs = Vec::new();
        for (t, p) in model.tensors().zip(&spec.params) {
            if let PayloadView::Fp32(v) = t.payload() {
                v.read_into(&mut scratch);
                bufs.push(engine.upload(&scratch, &p.shape)?);
            }
        }
        let (x, _) = m.load_val()?;
        let il = m.img * m.img * m.channels;
        let input = engine.upload(&x[..m.batch * il], &[m.batch, m.img, m.img, m.channels])?;
        for act in [0u8, 8] {
            let exe = engine.load_hlo(&m.abs(&spec.hlo[&act]))?;
            let t0 = std::time::Instant::now();
            let iters = 10;
            for _ in 0..iters { let _ = exe.run(&input, &bufs)?; }
            println!("{arch} a{act}: {:.1}ms/batch", t0.elapsed().as_secs_f64()*1000.0/iters as f64);
        }
    }
    Ok(())
}
