// perf probe: a0 vs a8 latency per arch
use nestquant::container::{self, TensorData};
use nestquant::runtime::{Engine, Manifest};
fn main() -> anyhow::Result<()> {
    let root = nestquant::artifacts_dir();
    let m = Manifest::load(&root)?;
    let engine = Engine::cpu()?;
    for arch in ["cnn_m", "vit_s"] {
        let spec = m.model(arch)?;
        let c = container::read(&m.abs(&spec.fp32_container), false)?;
        let mut bufs = Vec::new();
        for (t, p) in c.tensors.iter().zip(&spec.params) {
            if let TensorData::Fp32(v) = &t.data { bufs.push(engine.upload(v, &p.shape)?); }
        }
        let (x, _) = m.load_val()?;
        let il = m.img * m.img * m.channels;
        let input = engine.upload(&x[..m.batch * il], &[m.batch, m.img, m.img, m.channels])?;
        for act in [0u8, 8] {
            let exe = engine.load_hlo(&m.abs(&spec.hlo[&act]))?;
            let t0 = std::time::Instant::now();
            let iters = 10;
            for _ in 0..iters { let _ = exe.run(&input, &bufs)?; }
            println!("{arch} a{act}: {:.1}ms/batch", t0.elapsed().as_secs_f64()*1000.0/iters as f64);
        }
    }
    Ok(())
}
