//! Quickstart: load a NestQuant model, classify an image, switch between
//! full-bit and part-bit, and see what each switch actually costs.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use nestquant::coordinator::Coordinator;

fn main() -> Result<()> {
    let root = nestquant::artifacts_dir();
    let arch = std::env::args().nth(1).unwrap_or_else(|| "cnn_m".into());

    // One .nq container holds BOTH models: INT8 full-bit and INT4 part-bit.
    let mut coord = Coordinator::new(&root, &arch, 8, 4)?;
    let (sec_a, sec_b) = coord.manager.section_bytes();
    println!("container sections: w_high+scales {:.1}KB | w_low {:.1}KB",
             sec_a as f64 / 1e3, sec_b as f64 / 1e3);

    // 1. Launch in part-bit mode — reads only section A.
    let cost = coord.manager.load_part_bit(&mut coord.ledger)?;
    println!("\n[part-bit launch] paged in {:.1}KB in {:.2}ms",
             cost.page_in_bytes as f64 / 1e3, cost.micros as f64 / 1e3);

    // Classify a validation image.
    let (x, y) = coord.manifest.load_val()?;
    let img_len = coord.manifest.img * coord.manifest.img * coord.manifest.channels;
    let mut batch = vec![0f32; coord.manifest.batch * img_len];
    batch[..img_len].copy_from_slice(&x[..img_len]);
    let logits = coord.infer_batch(&batch)?;
    let pred = argmax(&logits[..coord.manifest.num_classes]);
    println!("[part-bit] image 0: predicted class {pred}, label {}", y[0]);

    // 2. Upgrade to full-bit: page in w_low ONLY (zero page-out).
    let cost = coord.manager.upgrade(&mut coord.ledger)?;
    println!("\n[upgrade] paged in {:.1}KB, paged out 0B, in {:.2}ms",
             cost.page_in_bytes as f64 / 1e3, cost.micros as f64 / 1e3);
    let logits = coord.infer_batch(&batch)?;
    println!("[full-bit] image 0: predicted class {}", argmax(&logits[..coord.manifest.num_classes]));

    // 3. Accuracy of both variants over the validation set.
    let full_acc = coord.eval_accuracy(Some(1024))?;
    let cost = coord.manager.downgrade(&mut coord.ledger)?;
    println!("\n[downgrade] paged out {:.1}KB, paged in 0B, in {:.2}ms",
             cost.page_out_bytes as f64 / 1e3, cost.micros as f64 / 1e3);
    let part_acc = coord.eval_accuracy(Some(1024))?;
    println!("\naccuracy@1024: full-bit INT8 = {full_acc:.3}, part-bit INT4 = {part_acc:.3}");
    println!("\n{}", coord.metrics.summary());
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}
