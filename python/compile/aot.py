"""AOT lowering: JAX model graphs → HLO text artifacts for the Rust runtime.

Emits HLO *text* (not serialized HloModuleProto): the image's
xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit instruction ids); the
text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Per architecture we lower three graphs at the serving batch size, one per
activation config: a0 (FP32 baseline), a6 (INT6 nesting), a8 (INT8
nesting). Weights are HLO *arguments*, so Rust switches between FP32 /
full-bit / part-bit by swapping weight buffers — the executable never
changes (this is what makes model switching cheap on-device).

Also exports the validation set and golden logits as raw little-endian
binaries (Rust has no npz reader), plus artifacts/manifest.json describing
everything.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model, quantizer, train

BATCH = 16
ACT_CONFIGS = (0, 6, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(arch: str, act_bits: int) -> str:
    specs = model.param_specs(arch)
    x_spec = jax.ShapeDtypeStruct((BATCH, model.IMG, model.IMG, 3), jnp.float32)
    p_specs = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in specs]

    def fn(x, *params):
        return (model.forward(arch, list(params), x, act_bits),)

    lowered = jax.jit(fn).lower(x_spec, *p_specs)
    return to_hlo_text(lowered)


def _write_raw(path: str, arr: np.ndarray, dtype) -> None:
    np.ascontiguousarray(arr, dtype=dtype).tofile(path)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--archs", nargs="*", default=list(model.ARCHS))
    args = ap.parse_args()

    # The shipped HLO must contain the real Pallas kernel lowering.
    os.environ["NESTQUANT_KERNELS"] = "pallas"

    hlodir = os.path.join(args.out, "hlo")
    ddir = os.path.join(args.out, "data")
    edir = os.path.join(ddir, "expected")
    for d in (hlodir, ddir, edir):
        os.makedirs(d, exist_ok=True)

    ds = data.load(cache_dir=ddir)
    _write_raw(os.path.join(ddir, "val_x.f32"), ds["x_val"], np.float32)
    _write_raw(os.path.join(ddir, "val_y.u32"), ds["y_val"], np.uint32)

    manifest = {
        "batch": BATCH,
        "img": model.IMG,
        "channels": 3,
        "num_classes": model.NUM_CLASSES,
        "data": {
            "val_x": "data/val_x.f32",
            "val_y": "data/val_y.u32",
            "count": int(len(ds["y_val"])),
        },
        "models": {},
    }

    sample = jnp.asarray(ds["x_val"][:BATCH])
    for arch in args.archs:
        specs = model.param_specs(arch)
        entry = {
            "params": [
                {"name": s.name, "shape": list(s.shape), "quantized": s.quantized}
                for s in specs
            ],
            "hlo": {},
            "containers": {
                "fp32": f"nq/{arch}_fp32.nq",
                "mono": {str(k): f"nq/{arch}_int{k}.nq" for k in (2, 3, 4, 5, 6, 7, 8)},
            },
            "expected": {},
        }
        params = train.load_params(os.path.join(args.out, "weights", f"{arch}.npz"))
        for act in ACT_CONFIGS:
            path = os.path.join(hlodir, f"{arch}_a{act}.hlo.txt")
            if not os.path.exists(path):
                print(f"[aot] lowering {arch} a{act} ...", flush=True)
                text = lower_model(arch, act)
                with open(path, "w") as f:
                    f.write(text)
            entry["hlo"][str(act)] = f"hlo/{arch}_a{act}.hlo.txt"

        # Golden logits through the *Pallas* graph for Rust cross-checks:
        # (fp32 weights, a0) and (INT8 full-bit weights, a8).
        logits_fp32 = np.asarray(
            jax.jit(lambda x, *ps: model.forward(arch, list(ps), x, 0))(sample, *params)
        )
        mask = [s.quantized for s in specs]
        w_ints, scales = quantizer.quantize_model(params, mask, 8, "adaptive")
        dq = quantizer.dequant_model(params, w_ints, scales)
        logits_int8 = np.asarray(
            jax.jit(lambda x, *ps: model.forward(arch, list(ps), x, 8))(sample, *dq)
        )
        _write_raw(os.path.join(edir, f"{arch}_a0_fp32.f32"), logits_fp32, np.float32)
        _write_raw(os.path.join(edir, f"{arch}_a8_int8.f32"), logits_int8, np.float32)
        entry["expected"]["a0_fp32"] = f"data/expected/{arch}_a0_fp32.f32"
        entry["expected"]["a8_int8"] = f"data/expected/{arch}_a8_int8.f32"

        # NestQuant containers written by compile.nestquant; list what exists.
        nest = {}
        for n in (8, 6):
            for h in range(2, n):
                rel = f"nq/{arch}_n{n}h{h}.nq"
                if os.path.exists(os.path.join(args.out, rel)):
                    nest[f"{n}|{h}"] = rel
        entry["containers"]["nest"] = nest
        manifest["models"][arch] = entry
        print(f"[aot] {arch} done", flush=True)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("[aot] manifest written", flush=True)


if __name__ == "__main__":
    main()
