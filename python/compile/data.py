"""SynthShapes: the procedural stand-in for ImageNet-1K.

The paper evaluates on ImageNet-1K, which is unavailable here (repro band
0/5). SynthShapes is a deterministic, seeded 10-class 24x24x3 image
classification task whose classes are parametric textures/shapes with
per-sample jitter (phase, color, position, noise). It is hard enough that
quantization perturbations measurably move top-1 accuracy — which is the
only property the NestQuant evaluation needs from the dataset (DESIGN.md
§2) — while being trainable to high accuracy in seconds at build time.

Class taxonomy:
  0 horizontal bars   1 vertical bars    2 checkerboard   3 ring
  4 cross             5 diagonal stripes 6 radial gradient 7 blob square
  8 half-plane        9 dot grid
"""

from __future__ import annotations

import numpy as np

IMG = 24
CHANNELS = 3
NUM_CLASSES = 10
TRAIN_N = 8192
VAL_N = 2048
SEED = 20250710


def _coords() -> tuple[np.ndarray, np.ndarray]:
    ys, xs = np.mgrid[0:IMG, 0:IMG].astype(np.float32)
    return ys, xs


def _sample(cls: int, rng: np.random.Generator) -> np.ndarray:
    ys, xs = _coords()
    period = rng.uniform(3.0, 6.0)
    phase = rng.uniform(0, period)
    cx, cy = rng.uniform(7, IMG - 7, size=2)
    if cls == 0:
        base = ((ys + phase) % period < period / 2).astype(np.float32)
    elif cls == 1:
        base = ((xs + phase) % period < period / 2).astype(np.float32)
    elif cls == 2:
        base = ((((xs + phase) // (period / 2)) + ((ys + phase) // (period / 2))) % 2)
    elif cls == 3:
        r = np.sqrt((xs - cx) ** 2 + (ys - cy) ** 2)
        r0 = rng.uniform(4, 8)
        base = (np.abs(r - r0) < 1.8).astype(np.float32)
    elif cls == 4:
        wdt = rng.uniform(1.5, 3.0)
        base = ((np.abs(xs - cx) < wdt) | (np.abs(ys - cy) < wdt)).astype(np.float32)
    elif cls == 5:
        base = (((xs + ys + phase) % period) < period / 2).astype(np.float32)
    elif cls == 6:
        r = np.sqrt((xs - cx) ** 2 + (ys - cy) ** 2)
        base = np.clip(1.0 - r / rng.uniform(10, 16), 0, 1)
    elif cls == 7:
        half = rng.uniform(3, 6)
        base = ((np.abs(xs - cx) < half) & (np.abs(ys - cy) < half)).astype(np.float32)
    elif cls == 8:
        theta = rng.uniform(0, 2 * np.pi)
        base = (((xs - IMG / 2) * np.cos(theta) + (ys - IMG / 2) * np.sin(theta)) > 0)
        base = base.astype(np.float32)
    else:  # 9: dot grid
        sp = rng.uniform(4, 7)
        base = ((((xs + phase) % sp) < 2) & (((ys + phase) % sp) < 2)).astype(np.float32)

    fg = rng.uniform(0.4, 1.0, size=3).astype(np.float32)
    bg = rng.uniform(0.0, 0.35, size=3).astype(np.float32)
    img = base[..., None] * fg + (1 - base[..., None]) * bg
    img += rng.normal(0, 0.06, size=img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def make_split(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` (image, label) pairs deterministically from `seed`."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    imgs = np.stack([_sample(int(c), rng) for c in labels])
    return imgs, labels


def load(cache_dir: str | None = None) -> dict[str, np.ndarray]:
    """Train/val splits, optionally cached as .npz under `cache_dir`."""
    if cache_dir:
        import os

        path = os.path.join(cache_dir, "synthshapes.npz")
        if os.path.exists(path):
            z = np.load(path)
            return {k: z[k] for k in z.files}
    xtr, ytr = make_split(TRAIN_N, SEED)
    xva, yva = make_split(VAL_N, SEED + 1)
    out = {"x_train": xtr, "y_train": ytr, "x_val": xva, "y_val": yva}
    if cache_dir:
        import os

        os.makedirs(cache_dir, exist_ok=True)
        np.savez_compressed(os.path.join(cache_dir, "synthshapes.npz"), **out)
    return out
