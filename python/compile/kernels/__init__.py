"""L1: Pallas kernels for NestQuant's compute hot-spots.

- ``quantize``: activation fake-quant (absmax reduction + elementwise pass)
- ``matmul``:   fused activation-quantized tiled matmul
- ``nesting``:  integer weight decompose / residual / recompose
- ``ref``:      pure-jnp oracle for all of the above

All Pallas kernels run with interpret=True so the lowered HLO executes on
the CPU PJRT plugin (see /opt/xla-example/README.md).
"""

from . import matmul, nesting, quantize, ref  # noqa: F401
