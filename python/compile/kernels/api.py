"""Backend dispatch for the L1 kernels.

The L2 models call this facade. Backend selection:

  * ``pallas`` (default) — the real Pallas kernels (interpret=True). Used
    when lowering the shipped artifacts so the HLO contains the kernels'
    op structure.
  * ``ref`` — the pure-jnp oracle. Used for the large PTQ accuracy sweeps
    (hundreds of evals) where interpret-mode grid loops are pure overhead.

pytest asserts the two backends agree to float tolerance on kernel outputs
and on whole-model logits, so sweep numbers and shipped-artifact numbers
are interchangeable (python/tests/test_backends.py).
"""

from __future__ import annotations

import os

import jax.numpy as jnp

from . import matmul as _pallas_mm
from . import quantize as _pallas_q
from . import ref as _ref

_ENV = "NESTQUANT_KERNELS"


def backend() -> str:
    b = os.environ.get(_ENV, "pallas")
    if b not in ("pallas", "ref"):
        raise ValueError(f"{_ENV} must be 'pallas' or 'ref', got {b!r}")
    return b


def fake_quant_dynamic(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    if bits == 0:
        return x
    if backend() == "pallas":
        return _pallas_q.fake_quant_dynamic(x, bits)
    return _ref.fake_quant_dynamic(x, bits)


def qmatmul(x: jnp.ndarray, w: jnp.ndarray, bits: int) -> jnp.ndarray:
    if backend() == "pallas":
        return _pallas_mm.qmatmul(x, w, bits)
    return _ref.qmatmul(x, w, bits)
