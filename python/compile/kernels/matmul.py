"""L1 Pallas kernel: activation-quantized tiled matmul (the hot-spot).

``qmatmul(x, w, bits)`` computes ``fake_quant(x) @ w`` as one fused Pallas
kernel: the activation tile is quantize-dequantized in VMEM right before
feeding the MXU-shaped dot, so the quantized activation never round-trips
to HBM. Weights arrive already dequantized (the Rust device dequantizes
packed integers at page-in; see rust/src/coordinator/manager.rs).

TPU mapping (DESIGN.md §Hardware-Adaptation): grid tiles the output into
(BM, BN) blocks with a K-loop as the innermost grid axis; BM=BN=BK=128
matches the 128x128 MXU systolic array, and the f32 accumulator lives in
the output VMEM block across K steps (revisited output block). VMEM
footprint per step = BM*BK + BK*BN + BM*BN floats ≈ 192 KiB, far under
the ~16 MiB/core budget. interpret=True for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .quantize import absmax

_BM = 128
_BN = 128
_BK = 128


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def _qmatmul_kernel(x_ref, w_ref, s_ref, o_ref, *, bits: int, nk: int):
    """One (BM, BN) output tile; K is the innermost grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    if bits:
        lo, hi = ref.int_min_max(bits)
        s = s_ref[0, 0]
        x = jnp.clip(jnp.round(x / s), lo, hi) * s
    o_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnums=(2,))
def qmatmul(x: jnp.ndarray, w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """fake_quant(x, bits) @ w with 2-D x (M,K) and w (K,N); bits=0 → plain."""
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, (x.shape, w.shape)
    if bits == 0:
        # FP32 graph: no quantization, and training must differentiate
        # through this path — use the native dot (Pallas interpret kernels
        # are inference-only).
        return x @ w
    s = absmax(x, bits)

    bm, bn, bk = min(_BM, m), min(_BN, n), min(_BK, kdim)
    gm, gn, gk = _cdiv(m, bm), _cdiv(n, bn), _cdiv(kdim, bk)
    # Pad to block multiples; zero-padding is exact for matmul and for
    # fake-quant (scale is computed on the unpadded tensor; fq(0) == 0).
    xp = jnp.pad(x, ((0, gm * bm - m), (0, gk * bk - kdim)))
    wp = jnp.pad(w, ((0, gk * bk - kdim), (0, gn * bn - n)))

    out = pl.pallas_call(
        functools.partial(_qmatmul_kernel, bits=bits, nk=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), jnp.float32),
        interpret=True,
    )(xp, wp, jnp.asarray(s, jnp.float32).reshape(1, 1))
    return out[:m, :n]
