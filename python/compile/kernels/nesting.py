"""L1 Pallas kernels: integer weight decomposition / recomposition.

The bit-level core of NestQuant (paper §3.2, Fig 2): splitting an INTn
tensor into a higher-h-bit tensor and a lower-(l+1)-bit residual, and the
inverse recomposition performed at model-upgrade time. The Rust device
does the production recompose (rust/src/nest/); these kernels exist so the
*same* math is available inside JAX graphs (pipeline validation, ablation
sweeps) and are checked against ref.py and against Rust via the container
round-trip tests.

Integers travel as int32 lanes (Pallas interpret mode has no narrow int
vector types on CPU); the value ranges are enforced by the kernels'
clipping, exactly as the packed INTk storage enforces them on disk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_BLOCK = 65536  # see quantize.py: 256 KiB VMEM blocks, minimal grid steps


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def _decompose_kernel(w_ref, hi_ref, lo_ref, *, n: int, h: int, compensate: bool):
    """BitShift split of one tile: hi = w >> l (arithmetic), lo = residual."""
    l = n - h
    w = w_ref[...]
    hi = jnp.floor_divide(w, 2**l)  # arithmetic right shift for signed ints
    res = w - hi * (2**l)
    bits = l + 1 if compensate else l
    rlo, rhi = ref.int_min_max(bits)
    hi_ref[...] = hi
    lo_ref[...] = jnp.clip(res, rlo, rhi)


def _residual_kernel(w_ref, hi_ref, lo_ref, *, n: int, h: int, compensate: bool):
    """Residual w_low = clip(w_int - w_high * 2^l) for an arbitrary w_high."""
    l = n - h
    bits = l + 1 if compensate else l
    rlo, rhi = ref.int_min_max(bits)
    lo_ref[...] = jnp.clip(w_ref[...] - hi_ref[...] * (2**l), rlo, rhi)


def _recompose_kernel(hi_ref, lo_ref, o_ref, *, l: int):
    o_ref[...] = hi_ref[...] * (2**l) + lo_ref[...]


def _tiled_call(kernel, outs, *arrays):
    """Run an elementwise kernel over 1-D tiles of identically-shaped arrays."""
    shape = arrays[0].shape
    size = arrays[0].size
    padded = _cdiv(size, _BLOCK) * _BLOCK
    flats = []
    for a in arrays:
        f = a.reshape(-1)
        if padded != size:
            f = jnp.pad(f, (0, padded - size))
        flats.append(f.reshape(1, padded))
    nblk = padded // _BLOCK
    spec = pl.BlockSpec((1, _BLOCK), lambda i: (0, i))
    res = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[spec] * len(arrays),
        out_specs=[spec] * outs if outs > 1 else spec,
        out_shape=(
            [jax.ShapeDtypeStruct((1, padded), jnp.int32) for _ in range(outs)]
            if outs > 1
            else jax.ShapeDtypeStruct((1, padded), jnp.int32)
        ),
        interpret=True,
    )(*flats)
    if outs == 1:
        res = (res,)
    return tuple(r.reshape(-1)[:size].reshape(shape) for r in res)


@functools.partial(jax.jit, static_argnums=(1, 2, 3))
def decompose_shift(w_int: jnp.ndarray, n: int, h: int, compensate: bool = True):
    """BitShift decomposition (Eq. 7): returns (w_high, w_low)."""
    k = functools.partial(_decompose_kernel, n=n, h=h, compensate=compensate)
    return _tiled_call(k, 2, w_int.astype(jnp.int32))


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def residual_low(w_int: jnp.ndarray, w_high: jnp.ndarray, n: int, h: int,
                 compensate: bool = True):
    """w_low for an arbitrary (adaptively-rounded) w_high (Eq. 11)."""
    k = functools.partial(_residual_kernel, n=n, h=h, compensate=compensate)
    (lo,) = _tiled_call(k, 1, w_int.astype(jnp.int32), w_high.astype(jnp.int32))
    return lo


@functools.partial(jax.jit, static_argnums=(2,))
def recompose(w_high: jnp.ndarray, w_low: jnp.ndarray, l: int):
    """Upgrade path (Eq. 6): w_int = w_high * 2^l + w_low."""
    k = functools.partial(_recompose_kernel, l=l)
    (w,) = _tiled_call(k, 1, w_high.astype(jnp.int32), w_low.astype(jnp.int32))
    return w
