"""L1 Pallas kernels: activation fake-quantization.

The fake-quant (quantize → dequantize) of activations is applied in front
of every conv/dense layer of the L2 models (paper §4.2 uses A8 for INT8
nesting and A6 for INT6 nesting). The scale is a dynamic per-tensor
max-abs reduction computed by a first Pallas pass; the elementwise
round/clip/rescale is a second tiled pass.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers the kernel into
plain HLO ops that ship inside ``artifacts/*.hlo.txt``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the elementwise pass is
VPU-shaped — blocks are (8·k, 128)-aligned tiles streamed HBM→VMEM by the
BlockSpec grid; the reduction pass accumulates per-block maxima in a
(1, 1) SMEM-like scratch output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Tile edge for the elementwise grid. 64Ki f32 lanes = 256 KiB per VMEM
# block — well inside the ~16 MiB/core budget, and two orders of magnitude
# fewer grid steps than a 512-lane tile (each interpret-mode grid step
# lowers to an XLA loop iteration, so step count dominates CPU latency;
# on TPU the same choice amortizes the HBM->VMEM pipeline).
_BLOCK = 65536


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def _absmax_kernel(x_ref, o_ref):
    """Grid-wide max|x| accumulated into a (1,1) output block."""
    i = pl.program_id(0)
    block_max = jnp.max(jnp.abs(x_ref[...]))

    @pl.when(i == 0)
    def _init():
        o_ref[0, 0] = block_max

    @pl.when(i != 0)
    def _acc():
        o_ref[0, 0] = jnp.maximum(o_ref[0, 0], block_max)


def _fake_quant_kernel(x_ref, s_ref, o_ref, *, bits: int):
    """Elementwise s*clip(round(x/s), lo, hi) over one tile."""
    lo, hi = ref.int_min_max(bits)
    s = s_ref[0, 0]
    q = jnp.clip(jnp.round(x_ref[...] / s), lo, hi)
    o_ref[...] = q * s


def _pad_to_block(flat: jnp.ndarray) -> jnp.ndarray:
    pad = _cdiv(flat.shape[0], _BLOCK) * _BLOCK - flat.shape[0]
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat


@functools.partial(jax.jit, static_argnums=(1,))
def absmax(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Dynamic per-tensor activation scale via a Pallas reduction pass."""
    flat = _pad_to_block(x.reshape(-1)).reshape(1, -1)
    nblk = flat.shape[1] // _BLOCK
    m = pl.pallas_call(
        _absmax_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, _BLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), x.dtype),
        interpret=True,
    )(flat)
    _, hi = ref.int_min_max(bits)
    return jnp.maximum(m[0, 0], 1e-8) / hi


@functools.partial(jax.jit, static_argnums=(2,))
def fake_quant(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Tiled fake-quant of `x` with a given scalar scale."""
    shape = x.shape
    flat = _pad_to_block(x.reshape(-1)).reshape(1, -1)
    nblk = flat.shape[1] // _BLOCK
    s = jnp.asarray(scale, x.dtype).reshape(1, 1)
    y = pl.pallas_call(
        functools.partial(_fake_quant_kernel, bits=bits),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, _BLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, _BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=True,
    )(flat, s)
    return y.reshape(-1)[: x.size].reshape(shape)


def fake_quant_dynamic(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Dynamic per-tensor fake-quant (scale pass + elementwise pass)."""
    return fake_quant(x, absmax(x, bits), bits)
