"""Pure-jnp oracle for every L1 Pallas kernel.

These are the *correctness references*: small, obviously-right jnp
implementations of the same math the Pallas kernels compute. pytest
(``python/tests/``) asserts allclose between each kernel and its ref over
hypothesis-driven shape/bitwidth sweeps. Nothing here is ever lowered into
the shipped artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def int_min_max(bits: int) -> tuple[int, int]:
    """Signed-integer range [min, max] for a `bits`-bit type (paper §3.1)."""
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def act_scale(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Dynamic per-tensor activation scale: max|x| / (2^{b-1}-1).

    Data-free (computed from the live batch), matching the A-bit settings
    of paper §4.2 without a calibration set.
    """
    _, hi = int_min_max(bits)
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / hi


def fake_quant(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize-dequantize: s * clip(round(x/s), min, max). Eq. (2)+(3)."""
    lo, hi = int_min_max(bits)
    q = jnp.clip(jnp.round(x / scale), lo, hi)
    return q * scale


def fake_quant_dynamic(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """fake_quant with the dynamic per-tensor scale."""
    return fake_quant(x, act_scale(x, bits), bits)


def qmatmul(x: jnp.ndarray, w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Activation-quantized matmul: fq(x) @ w.

    `w` arrives already dequantized (the device dequantizes packed weights
    at page-in time), so only the activation side is quantized in-graph.
    bits==0 disables activation quantization (FP32 baseline).
    """
    if bits:
        x = fake_quant_dynamic(x, bits)
    return x @ w


def decompose_shift(w_int: jnp.ndarray, n: int, h: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """BitShift integer weight decomposition (paper Eq. 6/7, Fig 2).

    w_high = arithmetic-right-shift(w_int, l)  (== floor(w_int / 2^l))
    w_low  = w_int - w_high * 2^l              (in [0, 2^l-1] for shift)
    """
    l = n - h
    w_high = jnp.floor_divide(w_int, 2**l)
    w_low = w_int - w_high * (2**l)
    return w_high, w_low


def residual_low(w_int: jnp.ndarray, w_high: jnp.ndarray, n: int, h: int,
                 compensate: bool = True) -> jnp.ndarray:
    """Lower-bit residual for an arbitrary w_high (paper Eq. 11 + §3.3.2).

    Without compensation the residual is clipped to signed INTl; with the
    extra 1-bit it is clipped to signed INT(l+1), which §3.3.2 proves is
    lossless: residual range ⊆ [-2^l, 2^l - 1].
    """
    l = n - h
    bits = l + 1 if compensate else l
    lo, hi = int_min_max(bits)
    return jnp.clip(w_int - w_high * (2**l), lo, hi)


def recompose(w_high: jnp.ndarray, w_low: jnp.ndarray, l: int) -> jnp.ndarray:
    """Full-bit recomposition: w_high * 2^l + w_low (paper Eq. 6)."""
    return w_high * (2**l) + w_low


def dequant(w_int: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """ŵ = s · w_int (paper Eq. 3); scale broadcasts over the last axis."""
    return w_int.astype(jnp.float32) * scale
