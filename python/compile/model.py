"""L2: the model zoo — JAX forward passes calling the L1 Pallas kernels.

The paper's zoo (ResNet/DenseNet/ResNeXt, MobileNet/ShuffleNet/
EfficientNet, ViT/DeiT/Swin) is replaced by three families at laptop scale
(DESIGN.md §2): residual CNNs (`cnn_t/s/m/l`), depthwise-separable CNNs
(`mobile_t/s`), and pre-norm ViTs (`vit_t/s`). The family split is what
matters: the paper's Eq. 12 / Fig 7 claims are about how the critical
nested combination moves across families and sizes.

Design contract with the Rust runtime:
  * ``forward(arch, params, x, act_bits)`` is a pure function; `params` is
    a flat, deterministically-ordered list matching ``param_specs(arch)``.
  * Weights enter as *arguments*, already dequantized — one lowered HLO per
    (arch, act_bits) serves FP32 / full-bit / part-bit by swapping buffers.
  * Every dense layer goes through the fused Pallas ``qmatmul``; every conv
    input goes through the Pallas ``fake_quant`` pair; `act_bits == 0`
    disables activation quantization (FP32 baseline graph).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import api as kapi

NUM_CLASSES = 10
IMG = 24


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One model parameter: name, shape, and whether it is weight-quantized."""

    name: str
    shape: tuple[int, ...]
    quantized: bool


@dataclasses.dataclass(frozen=True)
class CnnArch:
    name: str
    stem: int
    blocks: tuple[tuple[int, int], ...]  # (channels, stride) per residual block


@dataclasses.dataclass(frozen=True)
class MobileArch:
    name: str
    stem: int
    blocks: tuple[tuple[int, int], ...]  # (channels, stride) per ds-block


@dataclasses.dataclass(frozen=True)
class VitArch:
    name: str
    dim: int
    depth: int
    heads: int
    mlp_ratio: float
    patch: int


ARCHS: dict[str, object] = {
    "cnn_t": CnnArch("cnn_t", 8, ((8, 1),)),
    "cnn_s": CnnArch("cnn_s", 16, ((16, 1), (32, 2))),
    "cnn_m": CnnArch("cnn_m", 24, ((24, 1), (48, 2), (48, 1))),
    "cnn_l": CnnArch("cnn_l", 32, ((32, 1), (64, 2), (64, 1), (128, 2), (128, 1))),
    "mobile_t": MobileArch("mobile_t", 16, ((24, 2), (32, 1))),
    "mobile_s": MobileArch("mobile_s", 24, ((32, 2), (48, 1), (64, 2))),
    "vit_t": VitArch("vit_t", 48, 2, 4, 2.0, 6),
    "vit_s": VitArch("vit_s", 96, 4, 4, 2.0, 4),
}

FAMILIES = {
    "cnn": ["cnn_t", "cnn_s", "cnn_m", "cnn_l"],
    "mobile": ["mobile_t", "mobile_s"],
    "vit": ["vit_t", "vit_s"],
}


def family_of(arch_name: str) -> str:
    for fam, members in FAMILIES.items():
        if arch_name in members:
            return fam
    raise KeyError(arch_name)


# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------


def param_specs(arch_name: str) -> list[ParamSpec]:
    """Deterministic flat parameter order for an architecture."""
    arch = ARCHS[arch_name]
    if isinstance(arch, CnnArch):
        return _cnn_specs(arch)
    if isinstance(arch, MobileArch):
        return _mobile_specs(arch)
    if isinstance(arch, VitArch):
        return _vit_specs(arch)
    raise TypeError(arch)


def _cnn_specs(a: CnnArch) -> list[ParamSpec]:
    specs = [
        ParamSpec("stem.w", (3, 3, 3, a.stem), True),
        ParamSpec("stem.b", (a.stem,), False),
    ]
    cin = a.stem
    for i, (ch, stride) in enumerate(a.blocks):
        p = f"block{i}"
        specs += [
            ParamSpec(f"{p}.conv1.w", (3, 3, cin, ch), True),
            ParamSpec(f"{p}.conv1.b", (ch,), False),
            ParamSpec(f"{p}.conv2.w", (3, 3, ch, ch), True),
            ParamSpec(f"{p}.conv2.b", (ch,), False),
        ]
        if stride != 1 or cin != ch:
            specs += [
                ParamSpec(f"{p}.proj.w", (1, 1, cin, ch), True),
                ParamSpec(f"{p}.proj.b", (ch,), False),
            ]
        cin = ch
    specs += [
        ParamSpec("head.w", (cin, NUM_CLASSES), True),
        ParamSpec("head.b", (NUM_CLASSES,), False),
    ]
    return specs


def _mobile_specs(a: MobileArch) -> list[ParamSpec]:
    specs = [
        ParamSpec("stem.w", (3, 3, 3, a.stem), True),
        ParamSpec("stem.b", (a.stem,), False),
    ]
    cin = a.stem
    for i, (ch, stride) in enumerate(a.blocks):
        p = f"block{i}"
        specs += [
            # depthwise 3x3: HWIO with feature_group_count=cin → (3,3,1,cin)
            ParamSpec(f"{p}.dw.w", (3, 3, 1, cin), True),
            ParamSpec(f"{p}.dw.b", (cin,), False),
            # pointwise 1x1 implemented as a dense qmatmul
            ParamSpec(f"{p}.pw.w", (cin, ch), True),
            ParamSpec(f"{p}.pw.b", (ch,), False),
        ]
        cin = ch
    specs += [
        ParamSpec("head.w", (cin, NUM_CLASSES), True),
        ParamSpec("head.b", (NUM_CLASSES,), False),
    ]
    return specs


def _vit_specs(a: VitArch) -> list[ParamSpec]:
    tokens = (IMG // a.patch) ** 2
    pdim = a.patch * a.patch * 3
    hidden = int(a.dim * a.mlp_ratio)
    specs = [
        ParamSpec("embed.w", (pdim, a.dim), True),
        ParamSpec("embed.b", (a.dim,), False),
        ParamSpec("pos", (tokens, a.dim), False),
    ]
    for i in range(a.depth):
        p = f"layer{i}"
        specs += [
            ParamSpec(f"{p}.ln1.g", (a.dim,), False),
            ParamSpec(f"{p}.ln1.b", (a.dim,), False),
            ParamSpec(f"{p}.qkv.w", (a.dim, 3 * a.dim), True),
            ParamSpec(f"{p}.qkv.b", (3 * a.dim,), False),
            ParamSpec(f"{p}.proj.w", (a.dim, a.dim), True),
            ParamSpec(f"{p}.proj.b", (a.dim,), False),
            ParamSpec(f"{p}.ln2.g", (a.dim,), False),
            ParamSpec(f"{p}.ln2.b", (a.dim,), False),
            ParamSpec(f"{p}.mlp1.w", (a.dim, hidden), True),
            ParamSpec(f"{p}.mlp1.b", (hidden,), False),
            ParamSpec(f"{p}.mlp2.w", (hidden, a.dim), True),
            ParamSpec(f"{p}.mlp2.b", (a.dim,), False),
        ]
    specs += [
        ParamSpec("final_ln.g", (a.dim,), False),
        ParamSpec("final_ln.b", (a.dim,), False),
        ParamSpec("head.w", (a.dim, NUM_CLASSES), True),
        ParamSpec("head.b", (NUM_CLASSES,), False),
    ]
    return specs


def init_params(arch_name: str, seed: int = 0) -> list[np.ndarray]:
    """He/trunc-normal init in the spec order (numpy, build-time only)."""
    rng = np.random.default_rng(seed)
    params: list[np.ndarray] = []
    for spec in param_specs(arch_name):
        if spec.name.endswith(".g"):  # layernorm gain
            params.append(np.ones(spec.shape, np.float32))
        elif spec.name.endswith(".b") or spec.name == "pos":
            if spec.name == "pos":
                params.append(rng.normal(0, 0.02, spec.shape).astype(np.float32))
            else:
                params.append(np.zeros(spec.shape, np.float32))
        else:
            fan_in = int(np.prod(spec.shape[:-1]))
            std = math.sqrt(2.0 / max(fan_in, 1))
            params.append(rng.normal(0, std, spec.shape).astype(np.float32))
    return params


def model_nbytes_fp32(arch_name: str) -> int:
    """FP32 "model size" (paper's D_fp32): total parameter bytes."""
    return sum(4 * int(np.prod(s.shape)) for s in param_specs(arch_name))


# --------------------------------------------------------------------------
# Forward passes
# --------------------------------------------------------------------------


def _fq(x: jnp.ndarray, act_bits: int) -> jnp.ndarray:
    return kapi.fake_quant_dynamic(x, act_bits) if act_bits else x


def _conv(x, w, b, stride=1, groups=1):
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    return y + b


def _dense(x2d, w, b, act_bits):
    return kapi.qmatmul(x2d, w, act_bits) + b


def _layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-6) * g + b


class _P:
    """Cursor over the flat param list, keyed by spec order."""

    def __init__(self, params):
        self.params = list(params)
        self.i = 0

    def take(self, k: int = 1):
        out = self.params[self.i : self.i + k]
        self.i += k
        return out[0] if k == 1 else out

    def done(self):
        assert self.i == len(self.params), (self.i, len(self.params))


def forward(arch_name: str, params: list, x: jnp.ndarray, act_bits: int) -> jnp.ndarray:
    """Logits for a batch of NHWC images in [0,1]."""
    arch = ARCHS[arch_name]
    if isinstance(arch, CnnArch):
        return _cnn_forward(arch, params, x, act_bits)
    if isinstance(arch, MobileArch):
        return _mobile_forward(arch, params, x, act_bits)
    if isinstance(arch, VitArch):
        return _vit_forward(arch, params, x, act_bits)
    raise TypeError(arch)


def _cnn_forward(a: CnnArch, params, x, act_bits):
    p = _P(params)
    w, b = p.take(2)
    y = jax.nn.relu(_conv(_fq(x, act_bits), w, b))
    cin = a.stem
    for ch, stride in a.blocks:
        w1, b1, w2, b2 = p.take(4)
        z = jax.nn.relu(_conv(_fq(y, act_bits), w1, b1, stride=stride))
        z = _conv(_fq(z, act_bits), w2, b2)
        if stride != 1 or cin != ch:
            pw, pb = p.take(2)
            y = _conv(_fq(y, act_bits), pw, pb, stride=stride)
        y = jax.nn.relu(y + z)
        cin = ch
    y = jnp.mean(y, axis=(1, 2))  # global average pool
    hw, hb = p.take(2)
    logits = _dense(y, hw, hb, act_bits)
    p.done()
    return logits


def _mobile_forward(a: MobileArch, params, x, act_bits):
    p = _P(params)
    w, b = p.take(2)
    y = jax.nn.relu(_conv(_fq(x, act_bits), w, b))
    cin = a.stem
    for ch, stride in a.blocks:
        dw, db, pw, pb = p.take(4)
        y = jax.nn.relu(_conv(_fq(y, act_bits), dw, db, stride=stride, groups=cin))
        bsz, hh, ww, _ = y.shape
        flat = y.reshape(bsz * hh * ww, cin)
        y = jax.nn.relu(_dense(flat, pw, pb, act_bits)).reshape(bsz, hh, ww, ch)
        cin = ch
    y = jnp.mean(y, axis=(1, 2))
    hw, hb = p.take(2)
    logits = _dense(y, hw, hb, act_bits)
    p.done()
    return logits


def _vit_forward(a: VitArch, params, x, act_bits):
    p = _P(params)
    bsz = x.shape[0]
    g = IMG // a.patch
    # patchify: (B, g, patch, g, patch, C) → (B, tokens, patch*patch*C)
    xp = x.reshape(bsz, g, a.patch, g, a.patch, 3)
    xp = xp.transpose(0, 1, 3, 2, 4, 5).reshape(bsz, g * g, a.patch * a.patch * 3)
    ew, eb = p.take(2)
    tok = _dense(xp.reshape(bsz * g * g, -1), ew, eb, act_bits).reshape(bsz, g * g, a.dim)
    tok = tok + p.take(1)
    tokens = g * g
    head_dim = a.dim // a.heads
    for _ in range(a.depth):
        g1, b1, qkvw, qkvb, pw, pb, g2, b2, m1w, m1b, m2w, m2b = p.take(12)
        y = _layernorm(tok, g1, b1)
        qkv = _dense(y.reshape(bsz * tokens, a.dim), qkvw, qkvb, act_bits)
        qkv = qkv.reshape(bsz, tokens, 3, a.heads, head_dim)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        attn = jnp.einsum("bthd,bshd->bhts", q, k) / math.sqrt(head_dim)
        attn = jax.nn.softmax(attn, axis=-1)
        o = jnp.einsum("bhts,bshd->bthd", attn, v).reshape(bsz * tokens, a.dim)
        tok = tok + _dense(o, pw, pb, act_bits).reshape(bsz, tokens, a.dim)
        y = _layernorm(tok, g2, b2)
        hdn = _dense(y.reshape(bsz * tokens, a.dim), m1w, m1b, act_bits)
        hdn = jax.nn.gelu(hdn)
        out = _dense(hdn, m2w, m2b, act_bits).reshape(bsz, tokens, a.dim)
        tok = tok + out
    fg, fb = p.take(2)
    y = _layernorm(tok, fg, fb).mean(axis=1)
    hw, hb = p.take(2)
    logits = _dense(y, hw, hb, act_bits)
    p.done()
    return logits
