"""The NestQuant PTQ pipeline — paper Algorithm 1, plus every sweep the
evaluation section needs.

Per architecture and full bitwidth n ∈ {8, 6}:

  Step 1  INTn Hessian-based (SQuant-style) quantization of FP32 weights.
  Step 2  secondary INTh quantization of w_int/2^l per candidate h, for
          the three rounding methods of Table 6; w_low residual with the
          extra-1-bit compensation of §3.3.2 (and without, for the
          ablation column).
  Step 3  pack h-bit w_high and (l+1)-bit w_low into `.nq` containers.

Outputs under artifacts/:
  nq/{arch}_n{n}h{h}.nq      NestQuant containers (effective combos)
  nq/{arch}_int{k}.nq        monolithic INTk baselines (diverse bitwidths)
  nq/{arch}_fp32.nq          FP32 baseline container
  report/accuracy.json       every accuracy the tables/figures cite
  report/sizes.json          byte accounting for Tables 9/10/11, Figs 13/14
  report/ptq_cost.json       Table 1 timings on this substrate
  report/combos.json         critical/effective combos + Eq 12 pattern fit

Run ``python -m compile.nestquant --help`` from python/.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from . import data, model, nqformat, quantizer, train

# Candidate nested bits per full bitwidth (paper §3.3.1).
H_SWEEP = {8: [2, 3, 4, 5, 6, 7], 6: [3, 4, 5]}
MONO_BITS = [2, 3, 4, 5, 6, 7, 8]
# Part-bit acc must stay above this fraction of full-bit acc to count as
# "effective" (the cliff detector; see DESIGN.md — calibrated so the
# paper's own numbers reproduce their critical combinations).
EFFECTIVE_FRACTION = 0.6
# Table 6 is reported for this architecture (the paper uses ResNet-18).
TABLE6_ARCH = "cnn_m"


def _quant_mask(arch: str) -> list[bool]:
    return [s.quantized for s in model.param_specs(arch)]


def _eval(arch, params, ds, act_bits, limit=None):
    x, y = ds["x_val"], ds["y_val"]
    if limit:
        x, y = x[:limit], y[:limit]
    return train.evaluate(arch, params, x, y, act_bits)


def _nest_params(params, w_ints, scales, n, h, method, *, part, compensate=True):
    """Dequantized param list for the part-bit or recomposed full-bit model."""
    l = n - h
    out = []
    for p, wi, s in zip(params, w_ints, scales):
        if wi is None:
            out.append(p)
            continue
        w_high = quantizer.nest_high(wi, n, h, method)
        if part:
            out.append(quantizer.dequant(w_high, s * (1 << l)))  # Eq. 10
        else:
            w_low = quantizer.nest_low(wi, w_high, n, h, compensate=compensate)
            out.append(quantizer.dequant(quantizer.recompose(w_high, w_low, l), s))
    return out


def nest_tensors(arch, params, w_ints, scales, n, h, method="adaptive"):
    """Container tensors for a NestQuant model (Step 3 packing)."""
    l = n - h
    specs = model.param_specs(arch)
    tensors = []
    for spec, p, wi, s in zip(specs, params, w_ints, scales):
        if wi is None:
            tensors.append(nqformat.Tensor(spec.name, fp32=p))
        else:
            w_high = quantizer.nest_high(wi, n, h, method)
            w_low = quantizer.nest_low(wi, w_high, n, h, compensate=True)
            tensors.append(nqformat.Tensor(
                spec.name, scales=s, shape=p.shape,
                w_high=w_high, high_bits=h, w_low=w_low, low_bits=l + 1,
            ))
    return tensors


def mono_tensors(arch, params, k, method="adaptive"):
    specs = model.param_specs(arch)
    w_ints, scales = quantizer.quantize_model(params, _quant_mask(arch), k, method)
    tensors = []
    for spec, p, wi, s in zip(specs, params, w_ints, scales):
        if wi is None:
            tensors.append(nqformat.Tensor(spec.name, fp32=p))
        else:
            tensors.append(nqformat.Tensor(
                spec.name, scales=s, shape=p.shape, w_int=wi, int_bits=k))
    return tensors


def critical_h(acc_by_h: dict[int, float], full_acc: float) -> int | None:
    """Smallest h whose part-bit accuracy is still effective (§3.3.1)."""
    ok = [h for h, a in acc_by_h.items() if a >= EFFECTIVE_FRACTION * full_acc]
    return min(ok) if ok else None


def eq12_pattern(fp32_bytes: int, n: int, cut_lo: float, cut_hi: float) -> int:
    """Eq. 12 rule: h from the model-size band. Cutoffs are re-derived for
    our zoo's size axis (paper: 30 MB / 300 MB on ImageNet models)."""
    mb = fp32_bytes / 1e6
    if mb < cut_lo:
        return n // 2 + 1
    if mb < cut_hi:
        return n // 2
    return n // 2 - 1


def process_arch(arch: str, ds: dict, out: str, log: dict, *, eval_limit=None,
                 verbose=True) -> None:
    params = train.load_params(os.path.join(out, "weights", f"{arch}.npz"))
    mask = _quant_mask(arch)
    acc: dict = {"nest": {}, "mono": {}, "table6": {}}
    sizes: dict = {"fp32_bytes": model.model_nbytes_fp32(arch), "nest": {}, "mono": {}}
    cost: dict = {}

    def say(msg):
        if verbose:
            print(f"  [{arch}] {msg}", flush=True)

    t0 = time.time()
    acc["fp32"] = _eval(arch, params, ds, 0, eval_limit)
    acc["act_only"] = {str(n): _eval(arch, params, ds, n, eval_limit) for n in (8, 6)}
    say(f"fp32 acc={acc['fp32']:.3f} (A8={acc['act_only']['8']:.3f})")

    nqdir = os.path.join(out, "nq")
    os.makedirs(nqdir, exist_ok=True)

    # FP32 container (baseline transmission/storage object)
    specs = model.param_specs(arch)
    fp32_tensors = [nqformat.Tensor(s.name, fp32=p) for s, p in zip(specs, params)]
    sizes["fp32_container"] = nqformat.write_container(
        os.path.join(nqdir, f"{arch}_fp32.nq"), nqformat.KIND_FP32, arch,
        fp32_tensors, meta={"arch": arch})["total"]

    # Monolithic INTk baselines (diverse-bitwidths deployment)
    for k in MONO_BITS:
        t1 = time.time()
        tensors = mono_tensors(arch, params, k)
        cost[f"mono_int{k}_s"] = round(time.time() - t1, 3)
        info = nqformat.write_container(
            os.path.join(nqdir, f"{arch}_int{k}.nq"), nqformat.KIND_MONO, arch,
            tensors, n=k, act_bits=min(k, 8),
            meta={"arch": arch, "bits": k})
        sizes["mono"][str(k)] = info["total"]
        w_ints, scales = quantizer.quantize_model(params, mask, k)
        dq = quantizer.dequant_model(params, w_ints, scales)
        acc["mono"][str(k)] = {
            "a8": _eval(arch, dq, ds, 8, eval_limit),
            f"a{k}": _eval(arch, dq, ds, min(k, 8), eval_limit),
        }
        say(f"INT{k} acc(A8)={acc['mono'][str(k)]['a8']:.3f}")

    # NestQuant sweeps
    for n in (8, 6):
        t1 = time.time()
        w_ints, scales = quantizer.quantize_model(params, mask, n, "adaptive")
        cost[f"squant_int{n}_s"] = round(time.time() - t1, 3)
        t1 = time.time()
        quantizer.quantize_model(params, mask, n, "rtn")
        cost[f"rtn_int{n}_s"] = round(time.time() - t1, 3)

        dq_full = quantizer.dequant_model(params, w_ints, scales)
        full_acc = _eval(arch, dq_full, ds, n, eval_limit)
        nacc: dict = {"full": full_acc, "h": {}}
        say(f"INT{n} full-bit acc={full_acc:.3f} "
            f"(squant {cost[f'squant_int{n}_s']}s)")

        for h in H_SWEEP[n]:
            part = _nest_params(params, w_ints, scales, n, h, "adaptive", part=True)
            full_nc = _nest_params(params, w_ints, scales, n, h, "adaptive",
                                   part=False, compensate=False)
            # compensated recomposition is lossless — verified, not re-evaled
            recomp = _nest_params(params, w_ints, scales, n, h, "adaptive",
                                  part=False, compensate=True)
            for a, b in zip(recomp, dq_full):
                assert np.array_equal(a, b), "compensated recompose must be exact"
            nacc["h"][str(h)] = {
                "part": _eval(arch, part, ds, n, eval_limit),
                "full_nc": _eval(arch, full_nc, ds, n, eval_limit),
                "full": full_acc,
            }
            say(f"INT({n}|{h}) part={nacc['h'][str(h)]['part']:.3f} "
                f"full_nc={nacc['h'][str(h)]['full_nc']:.3f}")

        part_by_h = {h: nacc["h"][str(h)]["part"] for h in H_SWEEP[n]}
        nacc["critical_h"] = critical_h(part_by_h, full_acc)
        acc["nest"][str(n)] = nacc

        # containers for every effective combo (>= critical, < n)
        crit = nacc["critical_h"] or (n // 2)
        for h in [h for h in H_SWEEP[n] if h >= crit]:
            tensors = nest_tensors(arch, params, w_ints, scales, n, h)
            info = nqformat.write_container(
                os.path.join(nqdir, f"{arch}_n{n}h{h}.nq"), nqformat.KIND_NEST,
                arch, tensors, n=n, h=h, act_bits=n,
                meta={"arch": arch,
                      "part_acc": part_by_h[h],
                      "full_acc": full_acc,
                      "critical": h == crit})
            sizes["nest"][f"{n}|{h}"] = info

    # Table 6: all three rounding methods on the designated arch (n=8)
    if arch == TABLE6_ARCH:
        w_ints, scales = quantizer.quantize_model(params, mask, 8, "adaptive")
        for method in quantizer.METHODS:
            macc = {}
            for h in H_SWEEP[8]:
                part = _nest_params(params, w_ints, scales, 8, h, method, part=True)
                fnc = _nest_params(params, w_ints, scales, 8, h, method,
                                   part=False, compensate=False)
                macc[str(h)] = {
                    "part": _eval(arch, part, ds, 8, eval_limit),
                    "full_nc": _eval(arch, fnc, ds, 8, eval_limit),
                }
                say(f"table6 {method} INT(8|{h}) part={macc[str(h)]['part']:.3f}")
            acc["table6"][method] = macc

    cost["total_s"] = round(time.time() - t0, 1)
    log["accuracy"][arch] = acc
    log["sizes"][arch] = sizes
    log["ptq_cost"][arch] = cost


def derive_combos(log: dict) -> dict:
    """Fig 7 / Eq 12: fit size-band cutoffs to the measured critical combos."""
    rows = []
    for arch, a in log["accuracy"].items():
        for n in ("8", "6"):
            ch = a["nest"][n].get("critical_h")
            if ch is not None:
                rows.append({
                    "arch": arch, "n": int(n), "critical_h": ch,
                    "fp32_mb": log["sizes"][arch]["fp32_bytes"] / 1e6,
                    "family": model.family_of(arch),
                })
    # re-derive cutoffs on our size axis for n=8: boundary between sizes
    # whose critical is n/2+1 vs n/2 vs n/2-1 (paper: 30 / 300 MB)
    n8 = sorted((r for r in rows if r["n"] == 8), key=lambda r: r["fp32_mb"])
    cuts = {"lo": None, "hi": None}
    for prev, cur in zip(n8, n8[1:]):
        if prev["critical_h"] > cur["critical_h"]:
            mid = float(np.sqrt(prev["fp32_mb"] * cur["fp32_mb"]))  # log-scale midpoint
            if prev["critical_h"] == 5 and cur["critical_h"] == 4:
                cuts["lo"] = mid
            elif prev["critical_h"] == 4 and cur["critical_h"] == 3:
                cuts["hi"] = mid
    return {"rows": rows, "cutoffs_mb": cuts,
            "paper_cutoffs_mb": {"lo": 30.0, "hi": 300.0}}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--archs", nargs="*", default=list(model.ARCHS))
    ap.add_argument("--eval-limit", type=int, default=None,
                    help="cap val images per eval (CI smoke)")
    args = ap.parse_args()

    # Sweeps default to the ref backend (same numerics as the Pallas
    # kernels — asserted by tests — at a fraction of the interpret cost).
    os.environ.setdefault("NESTQUANT_KERNELS", "ref")

    os.makedirs(os.path.join(args.out, "report"), exist_ok=True)
    ds = data.load(cache_dir=os.path.join(args.out, "data"))
    log = {"accuracy": {}, "sizes": {}, "ptq_cost": {}}
    for arch in args.archs:
        print(f"[nestquant] {arch}", flush=True)
        process_arch(arch, ds, args.out, log, eval_limit=args.eval_limit)
    log["combos"] = derive_combos(log)
    tl = os.path.join(args.out, "weights", "train_log.json")
    if os.path.exists(tl):
        log["train"] = json.load(open(tl))
    for key in ("accuracy", "sizes", "ptq_cost", "combos"):
        path = os.path.join(args.out, "report", f"{key}.json")
        json.dump(log[key], open(path, "w"), indent=2, default=float)
    print("[nestquant] report JSONs written", flush=True)


if __name__ == "__main__":
    main()
