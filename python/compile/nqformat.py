"""The `.nq` container: NestQuant's on-disk model format.

Binary little-endian format shared bit-for-bit with the Rust side
(`rust/src/container/`). Three kinds:

  kind 0 "nest"  — the NestQuant model: per-tensor scales + packed w_high
                   in *section A*, all packed w_low blobs in *section B*.
                   A part-bit launch reads only section A; an upgrade
                   page-in reads exactly section B (one contiguous read —
                   this is what makes Table 11's zero-overhead claims
                   literal file operations).
  kind 1 "mono"  — a single-bitwidth packed INTk model (the diverse-
                   bitwidths baseline stores one of these per bitwidth).
  kind 2 "fp32"  — raw FP32 tensors (the uncompressed baseline).

Layout:
  magic "NESTQNT1" | u32 version=1 | u8 kind | u8 n | u8 h | u8 act_bits
  u32 name_len + name | u32 meta_len + meta(JSON)
  u32 num_tensors | u64 section_b_offset (0 if none)
  section A, per tensor:
    u32 name_len + name | u8 ptype (0 quantized, 1 fp32) | u8 ndim | u32×ndim dims
    ptype 1: f32 × prod(dims)
    ptype 0: u32 n_scales + f32×n_scales (last-axis channels)
             kind 0: u8 h_bits  | u32 n_words | u64×n_words  (packed w_high)
             kind 1: u8 bits    | u32 n_words | u64×n_words  (packed w_int)
  section B (kind 0 only), per quantized tensor in section-A order:
    u8 low_bits | u32 n_words | u64×n_words                 (packed w_low)
  trailer (optional, appended by the packer):
    magic "NQCKSUM1" | u64 crc64_xz(section A) | u64 crc64_xz(section B)

The trailer carries per-section CRC-64/XZ integrity checksums, verified
by the Rust store at section fetch time and by the fleet client after
chunked reassembly. Readers accept its absence (pre-trailer artifacts).
Section byte ranges always exclude the trailer.
"""

from __future__ import annotations

import io
import json
import struct

import numpy as np

from . import packbits

MAGIC = b"NESTQNT1"
VERSION = 1
KIND_NEST, KIND_MONO, KIND_FP32 = 0, 1, 2

TRAILER_MAGIC = b"NQCKSUM1"
TRAILER_LEN = 24

_CRC64_POLY = 0xC96C5795D7870F42  # CRC-64/XZ, reflected
_CRC64_TABLE = None


def crc64(data: bytes) -> int:
    """CRC-64/XZ — bit-identical to rust/src/util/crc64.rs."""
    global _CRC64_TABLE
    if _CRC64_TABLE is None:
        table = []
        for i in range(256):
            crc = i
            for _ in range(8):
                crc = (crc >> 1) ^ _CRC64_POLY if crc & 1 else crc >> 1
            table.append(crc)
        _CRC64_TABLE = table
    crc = 0xFFFFFFFFFFFFFFFF
    for b in data:
        crc = _CRC64_TABLE[(crc ^ b) & 0xFF] ^ (crc >> 8)
    return crc ^ 0xFFFFFFFFFFFFFFFF


def _w(buf: io.BytesIO, fmt: str, *vals) -> None:
    buf.write(struct.pack("<" + fmt, *vals))


def _wbytes(buf: io.BytesIO, b: bytes) -> None:
    _w(buf, "I", len(b))
    buf.write(b)


def _wpacked(buf: io.BytesIO, values: np.ndarray, bits: int) -> None:
    words = packbits.pack(values, bits)
    _w(buf, "B", bits)
    _w(buf, "I", len(words))
    buf.write(words.tobytes())


class Tensor:
    """One tensor going into a container."""

    def __init__(self, name: str, *, fp32: np.ndarray | None = None,
                 scales: np.ndarray | None = None, shape=None,
                 w_high: np.ndarray | None = None, high_bits: int = 0,
                 w_low: np.ndarray | None = None, low_bits: int = 0,
                 w_int: np.ndarray | None = None, int_bits: int = 0):
        self.name = name
        self.fp32 = fp32
        self.scales = scales
        self.shape = tuple(shape) if shape is not None else tuple(fp32.shape)
        self.w_high, self.high_bits = w_high, high_bits
        self.w_low, self.low_bits = w_low, low_bits
        self.w_int, self.int_bits = w_int, int_bits


def write_container(path: str, kind: int, name: str, tensors: list[Tensor],
                    n: int = 0, h: int = 0, act_bits: int = 0,
                    meta: dict | None = None) -> dict:
    """Write a container; returns byte accounting {total, section_a, section_b}."""
    head = io.BytesIO()
    head.write(MAGIC)
    _w(head, "I", VERSION)
    _w(head, "BBBB", kind, n, h, act_bits)
    _wbytes(head, name.encode())
    _wbytes(head, json.dumps(meta or {}).encode())
    _w(head, "I", len(tensors))

    sec_a = io.BytesIO()
    for t in tensors:
        _wbytes(sec_a, t.name.encode())
        ptype = 1 if t.fp32 is not None else 0
        _w(sec_a, "BB", ptype, len(t.shape))
        for d in t.shape:
            _w(sec_a, "I", d)
        if ptype == 1:
            sec_a.write(np.ascontiguousarray(t.fp32, np.float32).tobytes())
        else:
            sc = np.ascontiguousarray(t.scales, np.float32)
            _w(sec_a, "I", sc.size)
            sec_a.write(sc.tobytes())
            if kind == KIND_NEST:
                _wpacked(sec_a, t.w_high, t.high_bits)
            elif kind == KIND_MONO:
                _wpacked(sec_a, t.w_int, t.int_bits)
            else:
                raise ValueError("fp32 container cannot hold quantized tensors")

    sec_b = io.BytesIO()
    if kind == KIND_NEST:
        for t in tensors:
            if t.fp32 is None:
                _wpacked(sec_b, t.w_low, t.low_bits)

    header = head.getvalue()
    a = sec_a.getvalue()
    b = sec_b.getvalue()
    # section_b_offset goes right after num_tensors; account for its 8 bytes
    off = len(header) + 8 + len(a) if b else 0
    sec_a_bytes = header + struct.pack("<Q", off) + a
    trailer = TRAILER_MAGIC + struct.pack("<QQ", crc64(sec_a_bytes), crc64(b))
    with open(path, "wb") as f:
        f.write(sec_a_bytes)
        f.write(b)
        f.write(trailer)
    return {
        "total": len(sec_a_bytes) + len(b) + TRAILER_LEN,
        "section_a": len(sec_a_bytes),
        "section_b": len(b),
    }


# -------------------------- reader (for tests) ----------------------------


class _R:
    def __init__(self, data: bytes):
        self.d = data
        self.o = 0

    def take(self, fmt: str):
        vals = struct.unpack_from("<" + fmt, self.d, self.o)
        self.o += struct.calcsize("<" + fmt)
        return vals if len(vals) > 1 else vals[0]

    def bytes_(self) -> bytes:
        n = self.take("I")
        b = self.d[self.o : self.o + n]
        self.o += n
        return b

    def raw(self, n: int) -> bytes:
        b = self.d[self.o : self.o + n]
        self.o += n
        return b


def read_container(path: str, *, part_bit_only: bool = False) -> dict:
    """Parse a container back into numpy (tests + tooling; Rust has its own)."""
    data = open(path, "rb").read()
    checksums = None
    if len(data) >= TRAILER_LEN and data[-TRAILER_LEN:][:8] == TRAILER_MAGIC:
        a_crc, b_crc = struct.unpack("<QQ", data[-16:])
        data = data[:-TRAILER_LEN]
        checksums = (a_crc, b_crc)
    r = _R(data)
    assert r.raw(8) == MAGIC, "bad magic"
    version = r.take("I")
    assert version == VERSION
    kind, n, h, act_bits = r.take("BBBB")
    name = r.bytes_().decode()
    meta = json.loads(r.bytes_().decode() or "{}")
    num = r.take("I")
    off_b = r.take("Q")
    if checksums is not None:
        a_end = off_b if off_b else len(data)
        assert crc64(data[:a_end]) == checksums[0], "section A checksum mismatch"
        assert crc64(data[a_end:]) == checksums[1], "section B checksum mismatch"
    tensors = []
    for _ in range(num):
        tname = r.bytes_().decode()
        ptype, ndim = r.take("BB")
        dims = tuple(r.take("I") for _ in range(ndim))
        count = int(np.prod(dims)) if dims else 1
        t = {"name": tname, "shape": dims}
        if ptype == 1:
            t["fp32"] = np.frombuffer(r.raw(4 * count), np.float32).reshape(dims)
        else:
            ns = r.take("I")
            t["scales"] = np.frombuffer(r.raw(4 * ns), np.float32)
            bits = r.take("B")
            nw = r.take("I")
            words = np.frombuffer(r.raw(8 * nw), np.uint64)
            vals = packbits.unpack(words, bits, count).reshape(dims)
            if kind == KIND_NEST:
                t["w_high"], t["high_bits"] = vals, bits
            else:
                t["w_int"], t["int_bits"] = vals, bits
        tensors.append(t)
    if kind == KIND_NEST and not part_bit_only:
        assert off_b == r.o, (off_b, r.o)
        for t in tensors:
            if "w_high" in t:
                bits = r.take("B")
                nw = r.take("I")
                words = np.frombuffer(r.raw(8 * nw), np.uint64)
                count = int(np.prod(t["shape"]))
                t["w_low"] = packbits.unpack(words, bits, count).reshape(t["shape"])
                t["low_bits"] = bits
    return {
        "kind": kind, "n": n, "h": h, "act_bits": act_bits,
        "name": name, "meta": meta, "tensors": tensors,
        "section_b_offset": off_b, "checksums": checksums,
    }
