"""Packed-bit tensors: k-bit signed integers packed into u64 words.

This module defines the *bit-layout contract* shared with the Rust side
(`rust/src/bits/`): element ``i`` of a flattened tensor lives in word
``i // lanes`` at bit offset ``(i % lanes) * k`` where ``lanes = 64 // k``,
stored as a two's-complement ``k``-bit field. The final partial word is
zero-padded. Changing anything here breaks on-device loading — the Rust
test-suite round-trips containers written by this module.

The packing algorithm follows the packed-bit tensor approach of
Petersen et al. (distquant / difflogic), cited as [38,39] in the paper.
"""

from __future__ import annotations

import numpy as np

MIN_BITS = 2
MAX_BITS = 16


def lanes(bits: int) -> int:
    """Number of k-bit lanes per 64-bit word."""
    _check_bits(bits)
    return 64 // bits


def _check_bits(bits: int) -> None:
    if not (MIN_BITS <= bits <= MAX_BITS):
        raise ValueError(f"bits must be in [{MIN_BITS},{MAX_BITS}], got {bits}")


def int_range(bits: int) -> tuple[int, int]:
    """[min, max] of a signed `bits`-bit integer."""
    _check_bits(bits)
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def pack(values: np.ndarray, bits: int) -> np.ndarray:
    """Pack signed integers (any int dtype) into a u64 word array.

    Values must already be within the signed `bits`-bit range.
    Returns a 1-D uint64 array of ceil(len / lanes) words.
    """
    _check_bits(bits)
    flat = np.ascontiguousarray(values).reshape(-1).astype(np.int64)
    lo, hi = int_range(bits)
    if flat.size and (flat.min() < lo or flat.max() > hi):
        raise ValueError(
            f"values out of signed INT{bits} range [{lo},{hi}]: "
            f"[{flat.min()},{flat.max()}]"
        )
    n_lanes = lanes(bits)
    n_words = (flat.size + n_lanes - 1) // n_lanes
    mask = np.uint64((1 << bits) - 1)
    # two's-complement field
    fields = (flat.astype(np.uint64)) & mask
    padded = np.zeros(n_words * n_lanes, dtype=np.uint64)
    padded[: flat.size] = fields
    padded = padded.reshape(n_words, n_lanes)
    words = np.zeros(n_words, dtype=np.uint64)
    for lane in range(n_lanes):
        words |= padded[:, lane] << np.uint64(lane * bits)
    return words


def unpack(words: np.ndarray, bits: int, count: int) -> np.ndarray:
    """Unpack `count` signed `bits`-bit integers from u64 words (int32 out)."""
    _check_bits(bits)
    words = np.ascontiguousarray(words, dtype=np.uint64)
    n_lanes = lanes(bits)
    need = (count + n_lanes - 1) // n_lanes
    if words.size < need:
        raise ValueError(f"need {need} words for {count} x INT{bits}, got {words.size}")
    mask = np.uint64((1 << bits) - 1)
    sign_bit = np.uint64(1 << (bits - 1))
    out = np.empty(words.size * n_lanes, dtype=np.int64)
    for lane in range(n_lanes):
        field = (words >> np.uint64(lane * bits)) & mask
        # sign-extend
        signed = field.astype(np.int64) - ((field & sign_bit).astype(np.int64) << 1)
        out[lane::n_lanes] = signed
    return out[:count].astype(np.int32)


def packed_nbytes(count: int, bits: int) -> int:
    """On-disk bytes for `count` packed `bits`-bit elements."""
    n_lanes = lanes(bits)
    return 8 * ((count + n_lanes - 1) // n_lanes)
