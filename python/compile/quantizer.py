"""Weight quantization: scales, RTN, and SQuant-style adaptive rounding.

Implements the build-time (server-side) half of paper Algorithm 1:

  Step 1 — INTn quantization of FP32 weights: per-output-channel symmetric
  scales (Eq. 2), rounding by RTN or by the data-free Hessian-based
  adaptive rounding of SQuant [19] (diagonal-Hessian ⇒ per-channel
  accumulated-error cancellation via rounding flips).

  Step 2 — secondary INTh quantization of w_int/2^l with the *same*
  adaptive rounding (Eq. 9), plus the BitShift / RTN baselines of Table 6.

Everything here is numpy (build path); the Pallas kernels / Rust port are
validated against these functions.
"""

from __future__ import annotations

import numpy as np

from . import packbits


def int_min_max(bits: int) -> tuple[int, int]:
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def channel_scales(w: np.ndarray, bits: int) -> np.ndarray:
    """Symmetric per-output-channel scales over the last axis (Eq. 2)."""
    _, hi = int_min_max(bits)
    flat = np.abs(w.reshape(-1, w.shape[-1]))
    amax = flat.max(axis=0)
    return np.maximum(amax, 1e-12).astype(np.float32) / hi


def quantize_rtn(w: np.ndarray, scales: np.ndarray, bits: int) -> np.ndarray:
    """Round-to-nearest quantization → int32 in signed `bits` range."""
    lo, hi = int_min_max(bits)
    t = w / scales  # scales broadcast over last axis
    return np.clip(np.round(t), lo, hi).astype(np.int32)


def _flip_round(t: np.ndarray, bits: int) -> np.ndarray:
    """SQuant-style adaptive rounding of real-valued targets `t`.

    Per output channel (last axis): start from RTN, then flip the rounding
    direction of the elements with the largest fractional residues until
    the channel's accumulated rounding error (the diagonal-Hessian proxy
    for Eq. 5/9) is within ±0.5. Flips move a value by exactly ±1, so every
    element stays an "up-or-down" rounding of its target — the same search
    space as AdaRound/SQuant.
    """
    lo, hi = int_min_max(bits)
    t2 = t.reshape(-1, t.shape[-1]).T.copy()  # (channels, elems)
    base = np.round(t2)
    frac = t2 - base  # in [-0.5, 0.5]
    # Accumulated per-channel error BEFORE clipping; flips correct it.
    err = frac.sum(axis=1)
    k = np.round(err).astype(np.int64)  # number of flips per channel
    order_up = np.argsort(-frac, axis=1)  # most-positive residue first
    order_dn = np.argsort(frac, axis=1)  # most-negative residue first
    n_ch, n_el = t2.shape
    for c in range(n_ch):
        kc = int(k[c])
        if kc > 0:
            idx = order_up[c, : min(kc, n_el)]
            base[c, idx] += 1.0  # round those up
        elif kc < 0:
            idx = order_dn[c, : min(-kc, n_el)]
            base[c, idx] -= 1.0
    base = np.clip(base, lo, hi)
    return base.T.reshape(t.shape).astype(np.int32)


def quantize_adaptive(w: np.ndarray, scales: np.ndarray, bits: int) -> np.ndarray:
    """Step-1 adaptive rounding of FP32 weights (SQuant-style, data-free)."""
    return _flip_round(w / scales, bits)


# --------------------------------------------------------------------------
# Secondary quantization (the nesting step) — paper §3.2.1/§3.2.3
# --------------------------------------------------------------------------

METHODS = ("bitshift", "rtn", "adaptive")


def nest_high(w_int: np.ndarray, n: int, h: int, method: str) -> np.ndarray:
    """w_high from w_int by one of Table 6's rounding methods."""
    l = n - h
    lo, hi = int_min_max(h)
    t = w_int.astype(np.float64) / (1 << l)
    if method == "bitshift":
        return np.clip(np.floor(t), lo, hi).astype(np.int32)
    if method == "rtn":
        return np.clip(np.round(t), lo, hi).astype(np.int32)
    if method == "adaptive":
        return _flip_round(t, h)
    raise ValueError(f"unknown nesting method {method!r}")


def nest_low(w_int: np.ndarray, w_high: np.ndarray, n: int, h: int,
             compensate: bool = True) -> np.ndarray:
    """w_low = clip(w_int - w_high·2^l) to INTl (or INT(l+1) compensated)."""
    l = n - h
    lo, hi = int_min_max(l + 1 if compensate else l)
    return np.clip(w_int - (w_high.astype(np.int64) << l), lo, hi).astype(np.int32)


def recompose(w_high: np.ndarray, w_low: np.ndarray, l: int) -> np.ndarray:
    return ((w_high.astype(np.int64) << l) + w_low).astype(np.int32)


def dequant(w_int: np.ndarray, scales: np.ndarray) -> np.ndarray:
    return (w_int.astype(np.float32) * scales).astype(np.float32)


# --------------------------------------------------------------------------
# Whole-model helpers
# --------------------------------------------------------------------------


def quantize_model(params: list[np.ndarray], quant_mask: list[bool], n: int,
                   method: str = "adaptive"):
    """Quantize a flat param list → (w_ints, scales) with None for fp32 params."""
    w_ints: list = []
    scales: list = []
    for p, q in zip(params, quant_mask):
        if not q:
            w_ints.append(None)
            scales.append(None)
            continue
        s = channel_scales(p, n)
        wi = quantize_adaptive(p, s, n) if method == "adaptive" else quantize_rtn(p, s, n)
        w_ints.append(wi)
        scales.append(s)
    return w_ints, scales


def dequant_model(params, w_ints, scales):
    """FP32 param list with quantized tensors replaced by dequantized ones."""
    out = []
    for p, wi, s in zip(params, w_ints, scales):
        out.append(p if wi is None else dequant(wi, s))
    return out


def packed_model_nbytes(w_ints, scales, params, bits: int) -> int:
    """Ideal packed size: packed ints + fp32 scales + fp32 params."""
    total = 0
    for p, wi, s in zip(params, w_ints, scales):
        if wi is None:
            total += 4 * p.size
        else:
            total += packbits.packed_nbytes(wi.size, bits) + 4 * s.size
    return total
