"""Build-time training of the model zoo on SynthShapes.

Runs once under ``make artifacts``; produces ``artifacts/weights/*.npz``
(FP32 parameters in spec order) plus per-model FP32 val accuracy in
``artifacts/weights/train_log.json``. No Python from here ever runs on the
request path.

Optimizer is a self-contained Adam (optax is not available offline).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model

EPOCHS = {
    "cnn_t": 18,
    "cnn_s": 14,
    "cnn_m": 12,
    "cnn_l": 10,
    "mobile_t": 16,
    "mobile_s": 12,
    "vit_t": 20,
    "vit_s": 14,
}
BATCH = 128
LR = 2e-3
WD = 1e-4


def _adam_init(params):
    return {
        "m": [jnp.zeros_like(p) for p in params],
        "v": [jnp.zeros_like(p) for p in params],
        "t": jnp.zeros((), jnp.int32),
    }


def _adam_update(params, grads, st, lr):
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = st["t"] + 1
    m = [b1 * m + (1 - b1) * g for m, g in zip(st["m"], grads)]
    v = [b2 * v + (1 - b2) * g * g for v, g in zip(st["v"], grads)]
    mhat = [mi / (1 - b1 ** t.astype(jnp.float32)) for mi in m]
    vhat = [vi / (1 - b2 ** t.astype(jnp.float32)) for vi in v]
    new = [
        p - lr * (mh / (jnp.sqrt(vh) + eps) + WD * p)
        for p, mh, vh in zip(params, mhat, vhat)
    ]
    return new, {"m": m, "v": v, "t": t}


def _loss_fn(arch, params, x, y):
    logits = model.forward(arch, params, x, act_bits=0)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


_EVAL_CACHE: dict = {}


def _eval_fn(arch: str, act_bits: int):
    """Cached jitted argmax-forward; the PTQ sweeps run hundreds of evals
    and must not recompile each time."""
    import os

    key = (arch, act_bits, os.environ.get("NESTQUANT_KERNELS", "pallas"))
    if key not in _EVAL_CACHE:
        _EVAL_CACHE[key] = jax.jit(
            lambda ps, xb: jnp.argmax(model.forward(arch, ps, xb, act_bits), axis=-1)
        )
    return _EVAL_CACHE[key]


def evaluate(arch: str, params, x: np.ndarray, y: np.ndarray, act_bits: int,
             batch: int = 256) -> float:
    """Top-1 accuracy, batched (shared by train.py and nestquant.py)."""
    fwd = _eval_fn(arch, act_bits)
    params = [jnp.asarray(p) for p in params]
    correct = 0
    for i in range(0, len(x), batch):
        xb = jnp.asarray(x[i : i + batch])
        pred = np.asarray(fwd(params, xb))
        correct += int((pred == y[i : i + batch]).sum())
    return correct / len(x)


def train_one(arch: str, ds: dict, seed: int = 0, epochs: int | None = None,
              verbose: bool = True) -> tuple[list, float]:
    """Train one architecture; returns (params, val_acc)."""
    params = [jnp.asarray(p) for p in model.init_params(arch, seed=seed)]
    st = _adam_init(params)
    step = jax.jit(
        lambda ps, s, xb, yb, lr: _step(arch, ps, s, xb, yb, lr)
    )
    xtr, ytr = ds["x_train"], ds["y_train"]
    n = len(xtr)
    rng = np.random.default_rng(seed + 1)
    nepochs = epochs if epochs is not None else EPOCHS[arch]
    total_steps = nepochs * (n // BATCH)
    k = 0
    t0 = time.time()
    for ep in range(nepochs):
        order = rng.permutation(n)
        for i in range(0, n - BATCH + 1, BATCH):
            idx = order[i : i + BATCH]
            lr = LR * 0.5 * (1 + np.cos(np.pi * k / total_steps))
            params, st, loss = step(
                params, st, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]),
                jnp.float32(lr),
            )
            k += 1
        if verbose and (ep % 4 == 0 or ep == nepochs - 1):
            acc = evaluate(arch, params, ds["x_val"][:512], ds["y_val"][:512], 0)
            print(f"  [{arch}] epoch {ep+1}/{nepochs} loss={float(loss):.3f} "
                  f"val@512={acc:.3f} ({time.time()-t0:.0f}s)", flush=True)
    val_acc = evaluate(arch, params, ds["x_val"], ds["y_val"], 0)
    return [np.asarray(p) for p in params], val_acc


def _step(arch, params, st, xb, yb, lr):
    loss, grads = jax.value_and_grad(lambda ps: _loss_fn(arch, ps, xb, yb))(params)
    params, st = _adam_update(params, grads, st, lr)
    return params, st, loss


def save_params(path: str, arch: str, params: list[np.ndarray]) -> None:
    specs = model.param_specs(arch)
    assert len(specs) == len(params)
    np.savez(path, **{f"{i:03d}|{s.name}": p for i, (s, p) in enumerate(zip(specs, params))})


def load_params(path: str) -> list[np.ndarray]:
    z = np.load(path)
    keys = sorted(z.files, key=lambda k: int(k.split("|")[0]))
    return [z[k] for k in keys]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--archs", nargs="*", default=list(model.ARCHS))
    ap.add_argument("--epochs", type=int, default=None, help="override per-arch epochs")
    args = ap.parse_args()

    wdir = os.path.join(args.out, "weights")
    os.makedirs(wdir, exist_ok=True)
    ds = data.load(cache_dir=os.path.join(args.out, "data"))

    logf = os.path.join(wdir, "train_log.json")
    log = json.load(open(logf)) if os.path.exists(logf) else {}
    for arch in args.archs:
        path = os.path.join(wdir, f"{arch}.npz")
        if os.path.exists(path) and arch in log:
            print(f"[train] {arch}: cached ({log[arch]['val_acc']:.3f})", flush=True)
            continue
        print(f"[train] {arch} ...", flush=True)
        t0 = time.time()
        params, acc = train_one(arch, ds, epochs=args.epochs)
        save_params(path, arch, params)
        log[arch] = {
            "val_acc": acc,
            "train_seconds": round(time.time() - t0, 1),
            "params": int(sum(p.size for p in params)),
            "fp32_bytes": model.model_nbytes_fp32(arch),
        }
        json.dump(log, open(logf, "w"), indent=2)
        print(f"[train] {arch} done: val_acc={acc:.3f}", flush=True)


if __name__ == "__main__":
    main()
