"""Artifact integrity: manifest, HLO text, report JSONs (skips until
`make artifacts` has run). This is the Python-side mirror of the Rust
integration suite's artifact checks."""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


@pytest.fixture(scope="module")
def manifest():
    return json.load(open(os.path.join(ART, "manifest.json")))


def test_manifest_models_complete(manifest):
    from compile import model

    assert set(manifest["models"]) == set(model.ARCHS)
    for arch, entry in manifest["models"].items():
        specs = model.param_specs(arch)
        assert len(entry["params"]) == len(specs)
        for p, s in zip(entry["params"], specs):
            assert p["name"] == s.name
            assert tuple(p["shape"]) == s.shape
            assert p["quantized"] == s.quantized


def test_all_referenced_files_exist(manifest):
    for entry in manifest["models"].values():
        for rel in entry["hlo"].values():
            assert os.path.exists(os.path.join(ART, rel)), rel
        assert os.path.exists(os.path.join(ART, entry["containers"]["fp32"]))
        for rel in entry["containers"]["mono"].values():
            assert os.path.exists(os.path.join(ART, rel)), rel
        for rel in entry["containers"]["nest"].values():
            assert os.path.exists(os.path.join(ART, rel)), rel


def test_hlo_text_declares_params(manifest):
    """The lowered HLO's entry layout must carry 1 input + all params."""
    arch = "cnn_t"
    entry = manifest["models"][arch]
    text = open(os.path.join(ART, entry["hlo"]["8"])).read()
    head = text.splitlines()[0]
    assert "entry_computation_layout" in head
    # input + every parameter appears as an f32 tensor in the layout
    assert head.count("f32[") >= 1 + len(entry["params"])


def test_val_data_consistent(manifest):
    d = manifest["data"]
    y = np.fromfile(os.path.join(ART, d["val_y"]), dtype=np.uint32)
    x = np.fromfile(os.path.join(ART, d["val_x"]), dtype=np.float32)
    assert len(y) == d["count"]
    img = manifest["img"]
    assert len(x) == d["count"] * img * img * manifest["channels"]
    assert x.min() >= 0.0 and x.max() <= 1.0


def test_accuracy_report_structure():
    acc = json.load(open(os.path.join(ART, "report", "accuracy.json")))
    for arch, a in acc.items():
        assert 0.0 <= a["fp32"] <= 1.0
        for n in ("8", "6"):
            nest = a["nest"][n]
            full = nest["full"]
            # full-bit ≈ the monolithic model at the same bits (same w_int)
            assert abs(full - a["mono"][n][f"a{n}"]) < 0.02, arch
            for h, cell in nest["h"].items():
                assert 0.0 <= cell["part"] <= 1.0
                # compensated full is asserted exact by the pipeline itself


def test_sizes_report_consistency():
    sizes = json.load(open(os.path.join(ART, "report", "sizes.json")))
    for arch, s in sizes.items():
        for key, info in s["nest"].items():
            assert info["section_a"] + info["section_b"] == info["total"], (arch, key)
            n, h = map(int, key.split("|"))
            # nest container strictly smaller than the diverse pair
            diverse = s["mono"][str(n)] + s["mono"][str(h)]
            assert info["total"] < diverse, (arch, key)
        # mono sizes monotone in bits
        monos = [s["mono"][str(k)] for k in range(2, 9)]
        assert monos == sorted(monos), arch


def test_golden_logits_finite(manifest):
    for arch, entry in manifest["models"].items():
        for rel in entry["expected"].values():
            g = np.fromfile(os.path.join(ART, rel), dtype=np.float32)
            assert len(g) == manifest["batch"] * manifest["num_classes"]
            assert np.isfinite(g).all(), arch
