"""Pallas vs ref backend equivalence on whole-model logits.

The PTQ sweeps run on the `ref` backend for speed while the shipped HLO
is lowered from the `pallas` backend; this test is what licenses treating
their numbers as interchangeable.
"""

import os

import jax
import numpy as np
import pytest

from compile import data, model


def _logits(arch, params, x, act_bits, backend):
    os.environ["NESTQUANT_KERNELS"] = backend
    try:
        fn = jax.jit(lambda ps, xb: model.forward(arch, ps, xb, act_bits))
        return np.asarray(fn(params, x))
    finally:
        os.environ["NESTQUANT_KERNELS"] = "pallas"


@pytest.mark.parametrize("arch", ["cnn_t", "mobile_t", "vit_t"])
@pytest.mark.parametrize("act_bits", [0, 6, 8])
def test_backends_agree(arch, act_bits):
    rng = np.random.default_rng(42)
    params = model.init_params(arch, seed=3)
    x = rng.random((4, model.IMG, model.IMG, 3)).astype(np.float32)
    lp = _logits(arch, params, x, act_bits, "pallas")
    lr = _logits(arch, params, x, act_bits, "ref")
    np.testing.assert_allclose(lp, lr, atol=2e-4, rtol=1e-4)


def test_param_specs_match_init():
    for arch in model.ARCHS:
        specs = model.param_specs(arch)
        params = model.init_params(arch)
        assert len(specs) == len(params)
        for s, p in zip(specs, params):
            assert tuple(p.shape) == s.shape, s.name


def test_forward_batch_independence_fp32():
    """With act_bits=0, row i of a batch must not depend on other rows."""
    arch = "cnn_t"
    params = model.init_params(arch, seed=1)
    rng = np.random.default_rng(0)
    x = rng.random((8, model.IMG, model.IMG, 3)).astype(np.float32)
    full = _logits(arch, params, x, 0, "ref")
    x2 = x.copy()
    x2[4:] = rng.random((4, model.IMG, model.IMG, 3))
    part = _logits(arch, params, x2, 0, "ref")
    np.testing.assert_allclose(full[:4], part[:4], atol=2e-5, rtol=1e-5)


def test_zero_padding_keeps_predictions():
    """The L3 dynamic batcher zero-pads partial batches. With *dynamic*
    per-tensor activation scales, zero rows can only shrink the batch max,
    so logits shift by at most one quantization step — argmax on real
    inputs must be stable. (This is the batcher's correctness contract.)"""
    arch = "cnn_t"
    params = [np.asarray(p) for p in model.init_params(arch, seed=1)]
    ds = data.make_split(8, 123)
    x = ds[0]
    full = _logits(arch, params, x, 8, "ref")
    xpad = np.concatenate([x, np.zeros_like(x)])  # pad to 16
    padded = _logits(arch, params, xpad, 8, "ref")[:8]
    assert (np.argmax(full, -1) == np.argmax(padded, -1)).mean() >= 0.9
    np.testing.assert_allclose(full, padded, atol=0.15)
