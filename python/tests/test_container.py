"""`.nq` container format: roundtrip, sectioning, corruption handling."""

import os

import numpy as np
import pytest

from compile import nqformat, packbits, quantizer as qz


def _nest_container(tmp_path, n=8, h=4, elems=100, channels=5, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.3, (elems, channels)).astype(np.float32)
    s = qz.channel_scales(w, n)
    wi = qz.quantize_adaptive(w, s, n)
    wh = qz.nest_high(wi, n, h, "adaptive")
    wl = qz.nest_low(wi, wh, n, h, compensate=True)
    bias = rng.normal(size=(channels,)).astype(np.float32)
    tensors = [
        nqformat.Tensor("layer.w", scales=s, shape=w.shape,
                        w_high=wh, high_bits=h, w_low=wl, low_bits=n - h + 1),
        nqformat.Tensor("layer.b", fp32=bias),
    ]
    path = os.path.join(tmp_path, "m.nq")
    info = nqformat.write_container(path, nqformat.KIND_NEST, "toy", tensors,
                                    n=n, h=h, act_bits=n, meta={"k": 1})
    return path, info, (wi, wh, wl, s, bias)


def test_nest_roundtrip(tmp_path):
    path, info, (wi, wh, wl, s, bias) = _nest_container(tmp_path)
    got = nqformat.read_container(path)
    assert got["kind"] == nqformat.KIND_NEST
    assert (got["n"], got["h"]) == (8, 4)
    assert got["meta"] == {"k": 1}
    t0, t1 = got["tensors"]
    np.testing.assert_array_equal(t0["w_high"], wh)
    np.testing.assert_array_equal(t0["w_low"], wl)
    np.testing.assert_allclose(t0["scales"], s)
    np.testing.assert_allclose(t1["fp32"], bias)
    # recompose from the container == original w_int
    rec = qz.recompose(t0["w_high"], t0["w_low"], 4)
    np.testing.assert_array_equal(rec, wi)


def test_part_bit_only_read_skips_section_b(tmp_path):
    """A part-bit launch parses section A only — w_low never touched."""
    path, info, _ = _nest_container(tmp_path)
    got = nqformat.read_container(path, part_bit_only=True)
    assert "w_low" not in got["tensors"][0]
    assert got["section_b_offset"] == info["section_a"]
    # sections + the integrity trailer tile the file exactly
    assert info["section_a"] + info["section_b"] + nqformat.TRAILER_LEN == info["total"]
    assert os.path.getsize(path) == info["total"]
    assert got["checksums"] is not None


def test_section_b_is_contiguous_tail(tmp_path):
    """Downgrade == drop the file tail; upgrade == read it back."""
    path, info, (wi, wh, wl, s, _) = _nest_container(tmp_path, n=8, h=5)
    blob = open(path, "rb").read()
    tail = blob[info["section_a"]:]
    # parse the single w_low blob manually: u8 bits, u32 nwords, words
    bits = tail[0]
    assert bits == 8 - 5 + 1
    nwords = int.from_bytes(tail[1:5], "little")
    words = np.frombuffer(tail[5 : 5 + 8 * nwords], np.uint64)
    vals = packbits.unpack(words, bits, wl.size).reshape(wl.shape)
    np.testing.assert_array_equal(vals, wl)


def test_mono_and_fp32_roundtrip(tmp_path):
    rng = np.random.default_rng(1)
    w = rng.normal(0, 0.3, (40, 8)).astype(np.float32)
    s = qz.channel_scales(w, 4)
    wi = qz.quantize_rtn(w, s, 4)
    path = os.path.join(tmp_path, "mono.nq")
    nqformat.write_container(path, nqformat.KIND_MONO, "toy", [
        nqformat.Tensor("w", scales=s, shape=w.shape, w_int=wi, int_bits=4)
    ], n=4)
    got = nqformat.read_container(path)
    np.testing.assert_array_equal(got["tensors"][0]["w_int"], wi)

    path2 = os.path.join(tmp_path, "fp32.nq")
    nqformat.write_container(path2, nqformat.KIND_FP32, "toy", [
        nqformat.Tensor("w", fp32=w)
    ])
    got2 = nqformat.read_container(path2)
    np.testing.assert_allclose(got2["tensors"][0]["fp32"], w)


def test_bad_magic_rejected(tmp_path):
    path = os.path.join(tmp_path, "bad.nq")
    with open(path, "wb") as f:
        f.write(b"NOTAMODL" + b"\x00" * 64)
    with pytest.raises(AssertionError):
        nqformat.read_container(path)


def test_empty_container(tmp_path):
    path = os.path.join(tmp_path, "empty.nq")
    info = nqformat.write_container(path, nqformat.KIND_FP32, "none", [])
    got = nqformat.read_container(path)
    assert got["tensors"] == []
    assert info["section_b"] == 0
