"""Dataset and model-zoo invariants."""

import numpy as np
import pytest

from compile import data, model


# ------------------------------- dataset ----------------------------------


def test_dataset_deterministic():
    a = data.make_split(64, 123)
    b = data.make_split(64, 123)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_dataset_seed_sensitivity():
    a = data.make_split(64, 123)
    b = data.make_split(64, 124)
    assert not np.array_equal(a[0], b[0])


def test_dataset_ranges_and_shapes():
    x, y = data.make_split(128, 7)
    assert x.shape == (128, data.IMG, data.IMG, 3)
    assert x.dtype == np.float32
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert y.min() >= 0 and y.max() < data.NUM_CLASSES


def test_dataset_class_coverage():
    _, y = data.make_split(500, 11)
    assert len(np.unique(y)) == data.NUM_CLASSES


def test_train_val_disjoint_seeds():
    """Train and val come from different seeds — no leakage by construction."""
    assert data.SEED + 1 != data.SEED


# ------------------------------- models -----------------------------------


@pytest.mark.parametrize("arch", list(model.ARCHS))
def test_forward_shapes_all_archs(arch):
    import os

    os.environ["NESTQUANT_KERNELS"] = "ref"
    try:
        params = model.init_params(arch, seed=0)
        x = np.random.default_rng(0).random((2, model.IMG, model.IMG, 3)).astype(np.float32)
        logits = np.asarray(model.forward(arch, params, x, act_bits=0))
        assert logits.shape == (2, model.NUM_CLASSES)
        assert np.isfinite(logits).all()
    finally:
        os.environ.pop("NESTQUANT_KERNELS", None)


def test_init_deterministic():
    a = model.init_params("cnn_s", seed=5)
    b = model.init_params("cnn_s", seed=5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_quantized_mask_covers_compute_weights():
    """Every ≥2-D parameter (conv/dense weight) is quantized; every 1-D
    (bias/LN/pos handled as 2-D pos exception) is not — matching the
    paper's weight-only quantization."""
    for arch in model.ARCHS:
        for s in model.param_specs(arch):
            if s.name == "pos":
                assert not s.quantized
            elif len(s.shape) >= 2:
                assert s.quantized, f"{arch}:{s.name}"
            else:
                assert not s.quantized, f"{arch}:{s.name}"


def test_family_sizes_monotone():
    """Within each family the zoo is strictly increasing in size — the
    Fig 7 x-axis needs this."""
    for fam, members in model.FAMILIES.items():
        sizes = [model.model_nbytes_fp32(m) for m in members]
        assert sizes == sorted(sizes) and len(set(sizes)) == len(sizes), fam


def test_family_of():
    assert model.family_of("cnn_l") == "cnn"
    assert model.family_of("vit_t") == "vit"
    with pytest.raises(KeyError):
        model.family_of("resnet50")
