"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/bitwidths; assert_allclose against ref.py. This
is the core correctness signal for the compute that ships inside the HLO
artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as kmm
from compile.kernels import nesting as kn
from compile.kernels import quantize as kq
from compile.kernels import ref

BITS = st.sampled_from([2, 3, 4, 5, 6, 7, 8])


def _arr(rng, shape, scale=3.0):
    return jnp.asarray(rng.normal(0, scale, shape).astype(np.float32))


# ------------------------------ fake_quant --------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 2000),
    bits=BITS,
    seed=st.integers(0, 2**31),
)
def test_fake_quant_matches_ref(n, bits, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (n,))
    got = kq.fake_quant_dynamic(x, bits)
    want = ref.fake_quant_dynamic(x, bits)
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=0)


def test_fake_quant_2d_shapes():
    rng = np.random.default_rng(0)
    for shape in [(1, 1), (16, 24, 24, 3), (5, 7, 11)]:
        x = _arr(rng, shape)
        got = kq.fake_quant_dynamic(x, 8)
        want = ref.fake_quant_dynamic(x, 8)
        np.testing.assert_allclose(got, want, atol=1e-6)
        assert got.shape == x.shape


def test_fake_quant_idempotent():
    """fq(fq(x)) == fq(x): quantized values are fixed points."""
    rng = np.random.default_rng(1)
    x = _arr(rng, (500,))
    once = kq.fake_quant_dynamic(x, 6)
    twice = kq.fake_quant_dynamic(once, 6)
    np.testing.assert_allclose(once, twice, atol=1e-6)


def test_fake_quant_levels():
    """Output takes at most 2^bits distinct values."""
    rng = np.random.default_rng(2)
    x = _arr(rng, (4096,))
    for bits in (2, 3, 4):
        y = np.asarray(kq.fake_quant_dynamic(x, bits))
        assert len(np.unique(y)) <= 2**bits


def test_fake_quant_zero_input():
    x = jnp.zeros((64,), jnp.float32)
    y = kq.fake_quant_dynamic(x, 8)
    np.testing.assert_array_equal(np.asarray(y), 0)


# ------------------------------- qmatmul ----------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 300),
    n=st.integers(1, 40),
    bits=st.sampled_from([0, 4, 6, 8]),
    seed=st.integers(0, 2**31),
)
def test_qmatmul_matches_ref(m, k, n, bits, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (m, k), 1.0)
    w = _arr(rng, (k, n), 1.0)
    got = kmm.qmatmul(x, w, bits)
    want = ref.qmatmul(x, w, bits)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-4)


def test_qmatmul_multi_block():
    """Shapes crossing the 128-tile boundary exercise the K-loop + grid."""
    rng = np.random.default_rng(3)
    x = _arr(rng, (130, 257), 1.0)
    w = _arr(rng, (257, 140), 1.0)
    got = kmm.qmatmul(x, w, 8)
    want = ref.qmatmul(x, w, 8)
    np.testing.assert_allclose(got, want, atol=1e-2, rtol=1e-4)


def test_qmatmul_bits0_is_plain_matmul():
    rng = np.random.default_rng(4)
    x = _arr(rng, (8, 32))
    w = _arr(rng, (32, 8))
    np.testing.assert_allclose(kmm.qmatmul(x, w, 0), x @ w, atol=1e-5)


# --------------------------- nesting kernels ------------------------------


@settings(max_examples=30, deadline=None)
@given(
    n=st.sampled_from([8, 6]),
    h=st.integers(2, 7),
    size=st.integers(1, 3000),
    seed=st.integers(0, 2**31),
)
def test_decompose_recompose_lossless(n, h, size, seed):
    """Compensated decompose∘recompose is the identity (paper §3.3.2)."""
    if h >= n:
        return
    rng = np.random.default_rng(seed)
    lo, hi = ref.int_min_max(n)
    w = jnp.asarray(rng.integers(lo, hi + 1, size=(size,)).astype(np.int32))
    w_high, w_low = kn.decompose_shift(w, n, h, compensate=True)
    rec = kn.recompose(w_high, w_low, n - h)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(w))


@settings(max_examples=30, deadline=None)
@given(
    n=st.sampled_from([8, 6]),
    h=st.integers(2, 7),
    seed=st.integers(0, 2**31),
)
def test_decompose_matches_ref(n, h, seed):
    if h >= n:
        return
    rng = np.random.default_rng(seed)
    lo, hi = ref.int_min_max(n)
    w = jnp.asarray(rng.integers(lo, hi + 1, size=(777,)).astype(np.int32))
    gh, gl = kn.decompose_shift(w, n, h)
    rh, rl = ref.decompose_shift(w, n, h)
    np.testing.assert_array_equal(np.asarray(gh), np.asarray(rh))
    # kernel clips residual to the compensated range; shift residual fits
    np.testing.assert_array_equal(np.asarray(gl), np.asarray(rl))


def test_decompose_ranges_exhaustive_int8():
    """All 256 int8 values: w_high within INTh, compensated w_low within
    INT(l+1) — the §3.3.2 containment proof, checked exhaustively."""
    w = jnp.arange(-128, 128, dtype=jnp.int32)
    for h in range(2, 8):
        l = 8 - h
        w_high, w_low = kn.decompose_shift(w, 8, h, compensate=True)
        hlo, hhi = ref.int_min_max(h)
        llo, lhi = ref.int_min_max(l + 1)
        assert int(jnp.min(w_high)) >= hlo and int(jnp.max(w_high)) <= hhi
        assert int(jnp.min(w_low)) >= llo and int(jnp.max(w_low)) <= lhi
        rec = kn.recompose(w_high, w_low, l)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(w))


@settings(max_examples=20, deadline=None)
@given(h=st.integers(2, 7), seed=st.integers(0, 2**31))
def test_residual_low_arbitrary_high(h, seed):
    """residual_low must agree with ref for adaptively-perturbed w_high."""
    n = 8
    if h >= n:
        return
    rng = np.random.default_rng(seed)
    lo, hi = ref.int_min_max(n)
    w = jnp.asarray(rng.integers(lo, hi + 1, size=(512,)).astype(np.int32))
    base, _ = ref.decompose_shift(w, n, h)
    hlo, hhi = ref.int_min_max(h)
    jitter = rng.integers(-1, 2, size=(512,)).astype(np.int32)
    w_high = jnp.clip(base + jitter, hlo, hhi).astype(jnp.int32)
    got = kn.residual_low(w, w_high, n, h, True)
    want = ref.residual_low(w, w_high, n, h, True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
