"""Packed-bit tensor layout: the Python↔Rust interchange contract."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import packbits


@settings(max_examples=60, deadline=None)
@given(
    bits=st.integers(2, 16),
    n=st.integers(0, 2000),
    seed=st.integers(0, 2**31),
)
def test_pack_unpack_roundtrip(bits, n, seed):
    rng = np.random.default_rng(seed)
    lo, hi = packbits.int_range(bits)
    vals = rng.integers(lo, hi + 1, size=n).astype(np.int32)
    words = packbits.pack(vals, bits)
    back = packbits.unpack(words, bits, n)
    np.testing.assert_array_equal(back, vals)


def test_pack_extremes_all_bits():
    for bits in range(2, 17):
        lo, hi = packbits.int_range(bits)
        vals = np.array([lo, hi, 0, -1, 1, lo, hi], dtype=np.int64)
        back = packbits.unpack(packbits.pack(vals, bits), bits, len(vals))
        np.testing.assert_array_equal(back, vals)


def test_known_layout_int4():
    """Golden words pin the LSB-first lane layout shared with Rust."""
    vals = np.array([1, 2, 3, -1], dtype=np.int32)
    words = packbits.pack(vals, 4)
    # lanes: 0x1 | 0x2<<4 | 0x3<<8 | 0xF<<12
    assert words.tolist() == [0x1 | (0x2 << 4) | (0x3 << 8) | (0xF << 12)]


def test_known_layout_int3_spans_words():
    vals = np.arange(-4, 4, dtype=np.int32)  # 8 values, 21 lanes/word
    words = packbits.pack(np.tile(vals, 4), 3)  # 32 values → 2 words
    assert len(words) == 2
    back = packbits.unpack(words, 3, 32)
    np.testing.assert_array_equal(back, np.tile(vals, 4))


def test_out_of_range_rejected():
    with pytest.raises(ValueError):
        packbits.pack(np.array([8]), 4)  # INT4 max is 7
    with pytest.raises(ValueError):
        packbits.pack(np.array([-9]), 4)


def test_packed_nbytes():
    assert packbits.packed_nbytes(0, 4) == 0
    assert packbits.packed_nbytes(16, 4) == 8  # exactly one word
    assert packbits.packed_nbytes(17, 4) == 16
    assert packbits.packed_nbytes(21, 3) == 8
    assert packbits.packed_nbytes(22, 3) == 16


def test_bad_bits_rejected():
    with pytest.raises(ValueError):
        packbits.pack(np.array([0]), 1)
    with pytest.raises(ValueError):
        packbits.unpack(np.zeros(1, np.uint64), 17, 1)


def test_unpack_insufficient_words():
    with pytest.raises(ValueError):
        packbits.unpack(np.zeros(1, np.uint64), 4, 17)
