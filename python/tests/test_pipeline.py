"""PTQ pipeline invariants on a toy model (fast; no trained weights)."""

import numpy as np
import pytest

from compile import model, nestquant, quantizer as qz


@pytest.fixture(scope="module")
def toy():
    arch = "cnn_t"
    params = [np.asarray(p) for p in model.init_params(arch, seed=9)]
    mask = [s.quantized for s in model.param_specs(arch)]
    w_ints, scales = qz.quantize_model(params, mask, 8, "adaptive")
    return arch, params, mask, w_ints, scales


def test_quantize_model_masks(toy):
    arch, params, mask, w_ints, scales = toy
    for q, wi, s in zip(mask, w_ints, scales):
        assert (wi is not None) == q
        assert (s is not None) == q


def test_full_bit_recompose_exact_model_level(toy):
    """Compensated part+low recomposition reproduces w_int for every layer
    and every h — the model-level §3.3.2 guarantee the pipeline asserts."""
    arch, params, mask, w_ints, scales = toy
    for h in (3, 4, 5, 6, 7):
        rec = nestquant._nest_params(params, w_ints, scales, 8, h, "adaptive",
                                     part=False, compensate=True)
        full = qz.dequant_model(params, w_ints, scales)
        for a, b in zip(rec, full):
            np.testing.assert_array_equal(a, b)


def test_part_bit_scale_inflation(toy):
    """Part-bit dequant uses s·2^l (Eq. 10): values land on the coarser grid."""
    arch, params, mask, w_ints, scales = toy
    out = nestquant._nest_params(params, w_ints, scales, 8, 4, "adaptive", part=True)
    for spec, p, wi, s, o in zip(model.param_specs(arch), params, w_ints, scales, out):
        if wi is None:
            assert o is p
        else:
            grid = s * 16  # l = 4
            q = o / grid
            np.testing.assert_allclose(q, np.round(q), atol=1e-4)


def test_nest_tensors_bit_budget(toy):
    arch, params, mask, w_ints, scales = toy
    tensors = nestquant.nest_tensors(arch, params, w_ints, scales, 8, 5)
    for t in tensors:
        if t.fp32 is None:
            assert t.high_bits == 5
            assert t.low_bits == 4  # l+1 = 8-5+1
            lo, hi = qz.int_min_max(5)
            assert t.w_high.min() >= lo and t.w_high.max() <= hi


def test_critical_h_rule():
    accs = {2: 0.05, 3: 0.10, 4: 0.62, 5: 0.68, 6: 0.70, 7: 0.71}
    assert nestquant.critical_h(accs, 0.71) == 4
    assert nestquant.critical_h({2: 0.0}, 0.7) is None


def test_eq12_pattern_bands():
    assert nestquant.eq12_pattern(int(10e6), 8, 30, 300) == 5
    assert nestquant.eq12_pattern(int(100e6), 8, 30, 300) == 4
    assert nestquant.eq12_pattern(int(400e6), 8, 30, 300) == 3
    assert nestquant.eq12_pattern(int(10e6), 6, 30, 300) == 4
