"""Quantizer: scales, RTN, SQuant-style flips, nesting math (paper §3)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantizer as qz


def _w(seed, shape=(64, 32)):
    return np.random.default_rng(seed).normal(0, 0.5, shape).astype(np.float32)


# ------------------------------- scales -----------------------------------


def test_channel_scales_shape_and_coverage():
    w = _w(0)
    s = qz.channel_scales(w, 8)
    assert s.shape == (32,)
    # RTN at the computed scale may not clip: |w/s| <= 127 per channel
    t = np.abs(w / s)
    assert t.max() <= 127.0 + 1e-4


def test_scales_positive_even_for_zero_channel():
    w = np.zeros((16, 4), np.float32)
    s = qz.channel_scales(w, 8)
    assert (s > 0).all()


# --------------------------------- RTN ------------------------------------


@settings(max_examples=30, deadline=None)
@given(bits=st.sampled_from([3, 4, 6, 8]), seed=st.integers(0, 2**31))
def test_rtn_within_range(bits, seed):
    w = _w(seed)
    s = qz.channel_scales(w, bits)
    wi = qz.quantize_rtn(w, s, bits)
    lo, hi = qz.int_min_max(bits)
    assert wi.min() >= lo and wi.max() <= hi


def test_rtn_error_bound():
    """|w - s*w_int| <= s/2 elementwise when no clipping occurs."""
    w = _w(1)
    s = qz.channel_scales(w, 8)
    wi = qz.quantize_rtn(w, s, 8)
    err = np.abs(w - wi * s)
    assert (err <= s / 2 + 1e-7).all()


# --------------------------- adaptive rounding -----------------------------


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from([4, 6, 8]), seed=st.integers(0, 2**31))
def test_adaptive_is_up_or_down_rounding(bits, seed):
    """Every adaptively-rounded value is floor or ceil of its target —
    the AdaRound/SQuant search space."""
    w = _w(seed)
    s = qz.channel_scales(w, bits)
    wi = qz.quantize_adaptive(w, s, bits)
    t = w / s
    lo, hi = qz.int_min_max(bits)
    ok = (wi == np.clip(np.floor(t), lo, hi)) | (wi == np.clip(np.ceil(t), lo, hi))
    assert ok.all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_adaptive_channel_error_cancellation(seed):
    """Accumulated per-channel rounding error stays within ±0.5+1 of zero,
    vs RTN which can drift ~sqrt(N) — the diagonal-Hessian objective."""
    w = _w(seed, (256, 16))
    s = qz.channel_scales(w, 8)
    wi_ad = qz.quantize_adaptive(w, s, 8)
    err_ad = np.abs((w / s - wi_ad).sum(axis=0))
    assert (err_ad <= 1.5).all(), err_ad.max()


def test_adaptive_beats_rtn_on_channel_error():
    w = _w(7, (512, 8))
    s = qz.channel_scales(w, 8)
    e_ad = np.abs((w / s - qz.quantize_adaptive(w, s, 8)).sum(axis=0))
    e_rtn = np.abs((w / s - qz.quantize_rtn(w, s, 8)).sum(axis=0))
    assert e_ad.mean() <= e_rtn.mean() + 1e-9


# ------------------------------- nesting ----------------------------------


@pytest.mark.parametrize("method", qz.METHODS)
@pytest.mark.parametrize("n,h", [(8, 4), (8, 5), (8, 7), (6, 4), (6, 3)])
def test_nest_high_range(method, n, h):
    rng = np.random.default_rng(0)
    lo, hi = qz.int_min_max(n)
    wi = rng.integers(lo, hi + 1, size=1000).astype(np.int32)
    wh = qz.nest_high(wi, n, h, method)
    hlo, hhi = qz.int_min_max(h)
    assert wh.min() >= hlo and wh.max() <= hhi


@pytest.mark.parametrize("method", qz.METHODS)
@pytest.mark.parametrize("n,h", [(8, 3), (8, 4), (8, 6), (6, 4), (6, 5)])
def test_compensated_recompose_lossless_all_values(method, n, h):
    """THE paper claim (§3.3.2): with the extra 1-bit, recomposition is
    exact for every representable INTn value and every rounding method."""
    lo, hi = qz.int_min_max(n)
    wi = np.arange(lo, hi + 1, dtype=np.int32)
    wh = qz.nest_high(wi, n, h, method)
    wl = qz.nest_low(wi, wh, n, h, compensate=True)
    rec = qz.recompose(wh, wl, n - h)
    np.testing.assert_array_equal(rec, wi)
    # and w_low really fits in (l+1) signed bits
    llo, lhi = qz.int_min_max(n - h + 1)
    assert wl.min() >= llo and wl.max() <= lhi


@pytest.mark.parametrize("n,h", [(8, 4), (8, 5), (6, 4)])
def test_uncompensated_recompose_is_lossy(n, h):
    """Without the extra bit, RoundingUp-style w_high loses information
    (Table 7's non-zero error counts)."""
    lo, hi = qz.int_min_max(n)
    wi = np.arange(lo, hi + 1, dtype=np.int32)
    wh = qz.nest_high(wi, n, h, "rtn")
    wl = qz.nest_low(wi, wh, n, h, compensate=False)
    rec = qz.recompose(wh, wl, n - h)
    assert (rec != wi).any()


def test_paper_fig9_worked_example():
    """Fig 9: w_int=-67, INT(8|4): BitShift w_high=-5, clipped w_low=7 →
    recomposed -73 (error 6); compensated w_low=13 → exact."""
    wi = np.array([-67], dtype=np.int32)
    wh = qz.nest_high(wi, 8, 4, "bitshift")
    assert wh[0] == -5
    wl_nc = qz.nest_low(wi, wh, 8, 4, compensate=False)
    assert wl_nc[0] == 7
    assert qz.recompose(wh, wl_nc, 4)[0] == -73
    wl_c = qz.nest_low(wi, wh, 8, 4, compensate=True)
    assert wl_c[0] == 13
    assert qz.recompose(wh, wl_c, 4)[0] == -67


def test_dequant_scale_inflation():
    """Eq. 10: part-bit dequant uses s_high = s * 2^l."""
    wi = np.array([[-128, 64]], dtype=np.int32)
    s = np.array([0.01, 0.02], dtype=np.float32)
    wh = qz.nest_high(wi, 8, 4, "bitshift")
    deq = qz.dequant(wh, s * 16)
    np.testing.assert_allclose(deq, wh.astype(np.float32) * s * 16)
