"""Generate + verify golden vectors for the Rust stats substrate.

scipy is the ground truth. This test writes
``artifacts/golden/stats_golden.json`` consumed by
``rust/src/stats/`` unit tests (cargo test reads the same file), and
verifies the JSON is self-consistent. Deterministic inputs → the file is
reproducible byte-for-byte.
"""

import json
import os

import numpy as np
import pytest
from scipy import stats as sps

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "golden")


def _cases():
    rng = np.random.default_rng(777)
    cases = []
    for i, (na, nb) in enumerate([(50, 50), (200, 100), (1000, 1000), (31, 97)]):
        a = rng.normal(0, 1, na)
        b = rng.normal(0.2 * i, 1 + 0.1 * i, nb)
        cases.append((a, b))
    # ties case (integers)
    a = rng.integers(-5, 6, 300).astype(float)
    b = rng.integers(-4, 7, 300).astype(float)
    cases.append((a, b))
    return cases


def test_write_golden():
    os.makedirs(OUT, exist_ok=True)
    out = []
    for a, b in _cases():
        n = min(len(a), len(b))
        pear = sps.pearsonr(a[:n], b[:n])
        spear = sps.spearmanr(a[:n], b[:n])
        kend = sps.kendalltau(a[:n], b[:n])
        ranksum = sps.ranksums(a, b)
        mean_a = float(np.mean(a))
        out.append({
            "a": a.tolist(),
            "b": b.tolist(),
            "pearson": float(pear.statistic),
            "spearman": float(spear.statistic),
            "kendall": float(kend.statistic),
            "wilcoxon_z": float(ranksum.statistic),
            "wilcoxon_p": float(ranksum.pvalue),
            "mean_a": mean_a,
            "std_a": float(np.std(a, ddof=1)),
            "percentile_a_2_5": float(np.percentile(a, 2.5)),
            "percentile_a_97_5": float(np.percentile(a, 97.5)),
        })
    with open(os.path.join(OUT, "stats_golden.json"), "w") as f:
        json.dump(out, f)
    assert len(out) == 5


def test_goldens_sane():
    for a, b in _cases():
        n = min(len(a), len(b))
        r = sps.pearsonr(a[:n], b[:n]).statistic
        assert -1 <= r <= 1
