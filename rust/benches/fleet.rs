//! Bench: fleet-distribution hot paths — zoo-wide section cache hit vs
//! cold disk read, chunk framing, and an end-to-end localhost Section-B
//! delta pull through the resumable transfer protocol. Artifact-free:
//! runs on synthetic containers, so it always measures.

use std::time::Duration;

use nestquant::container;
use nestquant::fleet::{FleetClient, FleetConfig, FleetServer, Section, SectionCache, Zoo};
use nestquant::store::{FileSource, SectionSource};
use nestquant::transport::{chunk_frame, parse_chunk, ChunkHeader};
use nestquant::util::benchkit::Bench;

fn main() {
    let b = Bench::quick();
    let dir = std::env::temp_dir().join(format!("nq_fleet_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // a mid-sized synthetic model: 512x256 INT(8|4), ~150 KB packed
    let path = dir.join("bench.nq");
    let c = container::synthetic_nest(1, 8, 4, 512, 256).unwrap();
    let (total, a_len, b_len) = container::write(&path, &c).unwrap();
    println!(
        "bench: --- fleet: container {:.1} KB (A {:.1} / B {:.1}) ---",
        total as f64 / 1e3,
        a_len as f64 / 1e3,
        b_len as f64 / 1e3
    );

    // header probe (the random-access entry point; un-memoized)
    b.run("fleet probe section index", || {
        std::hint::black_box(FileSource::new(&path).index().unwrap());
    });

    // section cache: cold read vs hit
    let source = FileSource::new(&path);
    b.run("fleet cache miss (disk section read)", || {
        let cache = SectionCache::new(u64::MAX);
        std::hint::black_box(cache.get("m", &source, Section::B).unwrap());
    });
    let cache = SectionCache::new(u64::MAX);
    cache.get("m", &source, Section::B).unwrap();
    b.run_throughput("fleet cache hit", b_len as f64, "B", || {
        std::hint::black_box(cache.get("m", &source, Section::B).unwrap());
    });

    // chunk framing
    let blob = vec![7u8; 64 << 10];
    b.run_throughput("fleet chunk encode+decode 64KiB", blob.len() as f64, "B", || {
        let f = chunk_frame(
            "m",
            ChunkHeader {
                xfer_id: 1,
                offset: 0,
                total_len: blob.len() as u64,
            },
            &blob,
        );
        let (h, d) = parse_chunk(&f).unwrap();
        std::hint::black_box((h, d.len()));
    });

    // end-to-end: a full Section-B delta pull over localhost TCP with
    // per-chunk acks (the paging path a device upgrade takes)
    let mut zoo = Zoo::new();
    zoo.add("m", &path);
    let handle = FleetServer::start(zoo, FleetConfig::default()).unwrap();
    let mut client =
        FleetClient::connect(handle.addr, "bench-dev", Duration::from_secs(30)).unwrap();
    let mut sink = Vec::new();
    b.run_throughput("fleet section-B pull (localhost, acked)", b_len as f64, "B", || {
        let out = client
            .pull_section("m", Section::B, 0, &mut sink, None)
            .unwrap();
        assert!(out.completed);
    });
    drop(client);
    handle.stop();
}
