//! Bench: the fused switching kernels vs the legacy multi-pass
//! composition — the measured floor under the paper's cheap-switching
//! claim (§3.3, Table 5). Writes `BENCH_kernels.json` with bytes/sec
//! per (bitwidth, fused-vs-legacy) cell so the perf trajectory is a
//! recorded artifact, and asserts the fused one-pass path never loses
//! to the legacy composition it replaced.
//!
//! Two operations per nesting config:
//!
//! * **launch** (part-bit): packed `w_high` → f32.
//!   legacy = `unpack_into` + scale-inflate + `dequant` (2 passes +
//!   an inflated scale vector); fused = `kernels::unpack_dequant_into`.
//! * **upgrade** (full-bit): packed `w_high` + `w_low` → f32.
//!   legacy = `unpack_into` ×2 + `recompose_into` + `dequant`
//!   (4 passes, 3 transient i32 vectors); fused =
//!   `kernels::recompose_dequant_into`.
//!
//! Throughput denominates in *packed input bytes* (the section bytes a
//! switch actually moves), so the number is comparable across
//! bitwidths. Artifact-free; iteration budget capped via
//! `NQ_BENCH_BUDGET_MS` (see `Bench::from_env`).

use nestquant::bits::{int_range, packed_nbytes, PackedTensor};
use nestquant::kernels;
use nestquant::nest::{self, NestConfig, Rounding};
use nestquant::quant;
use nestquant::util::benchkit::Bench;
use nestquant::util::json;
use nestquant::util::prng::Rng;

/// Elements per tensor: big enough to be bandwidth-bound, small enough
/// for a capped CI budget.
const ELEMS: usize = 1 << 18;
const CHANNELS: usize = 64;

struct Cell {
    n: u8,
    h: u8,
    op: &'static str,
    fused_bps: f64,
    legacy_bps: f64,
}

/// One nesting config: build a synthetic tensor, time all four cells.
fn bench_config(b: &Bench, n: u8, h: u8, cells: &mut Vec<Cell>) {
    let cfg = NestConfig::new(n, h).unwrap();
    let mut rng = Rng::new(0xD1CE ^ ((n as u64) << 8) ^ h as u64);
    let (lo, hi) = int_range(n);
    let w_int: Vec<i32> = (0..ELEMS)
        .map(|_| rng.int(lo as i64, hi as i64) as i32)
        .collect();
    let scales: Vec<f32> = (0..CHANNELS)
        .map(|_| (rng.f64() * 0.05 + 1e-4) as f32)
        .collect();
    let (hs, ls) = nest::decompose(&w_int, cfg, Rounding::BitShift, true);
    let th = PackedTensor::pack(&hs, h).unwrap();
    let tl = PackedTensor::pack(&ls, cfg.low_bits()).unwrap();
    let (hb, lb) = (th.to_le_bytes(), tl.to_le_bytes());
    let high_bytes = packed_nbytes(ELEMS, h) as f64;
    let both_bytes = (packed_nbytes(ELEMS, h) + packed_nbytes(ELEMS, cfg.low_bits())) as f64;

    let mut out = Vec::with_capacity(ELEMS);

    // --- launch: packed w_high -> f32 ---------------------------------
    let s = b.run(&format!("INT({n}|{h}) launch FUSED"), || {
        kernels::unpack_dequant_into(&hb, h, ELEMS, &scales, cfg.scale_inflation(), &mut out);
        std::hint::black_box(&out);
    });
    let fused_launch = high_bytes / s.min.as_secs_f64();

    let mut scratch_int = Vec::with_capacity(ELEMS);
    let mut scratch_scales = Vec::with_capacity(CHANNELS);
    let s = b.run(&format!("INT({n}|{h}) launch LEGACY"), || {
        th.unpack_into(&mut scratch_int);
        scratch_scales.clear();
        scratch_scales.extend(scales.iter().map(|s| s * cfg.scale_inflation()));
        quant::dequant(&scratch_int, &scratch_scales, &mut out);
        std::hint::black_box(&out);
    });
    let legacy_launch = high_bytes / s.min.as_secs_f64();
    cells.push(Cell {
        n,
        h,
        op: "launch",
        fused_bps: fused_launch,
        legacy_bps: legacy_launch,
    });

    // --- upgrade: w_high + w_low -> f32 -------------------------------
    let s = b.run(&format!("INT({n}|{h}) upgrade FUSED"), || {
        kernels::recompose_dequant_into(
            &hb,
            h,
            &lb,
            cfg.low_bits(),
            cfg.l(),
            ELEMS,
            &scales,
            &mut out,
        );
        std::hint::black_box(&out);
    });
    let fused_up = both_bytes / s.min.as_secs_f64();

    let mut scratch_high = Vec::with_capacity(ELEMS);
    let mut scratch_low = Vec::with_capacity(ELEMS);
    let s = b.run(&format!("INT({n}|{h}) upgrade LEGACY"), || {
        th.unpack_into(&mut scratch_high);
        tl.unpack_into(&mut scratch_low);
        nest::recompose_into(&scratch_high, &scratch_low, cfg.l(), &mut scratch_int);
        quant::dequant(&scratch_int, &scales, &mut out);
        std::hint::black_box(&out);
    });
    let legacy_up = both_bytes / s.min.as_secs_f64();
    cells.push(Cell {
        n,
        h,
        op: "upgrade",
        fused_bps: fused_up,
        legacy_bps: legacy_up,
    });
}

fn main() {
    let b = Bench::from_env();
    // (7|4)/(11|8): both streams lane-aligned (paired SWAR); (8|4)/(16|8):
    // w_high aligned only; (8|5)/(8|6)/(6|3)/(7|3): scalar fallbacks
    let configs: [(u8, u8); 8] =
        [(8, 4), (8, 5), (8, 6), (6, 3), (16, 8), (7, 3), (7, 4), (11, 8)];
    let mut cells = Vec::new();
    for (n, h) in configs {
        bench_config(&b, n, h, &mut cells);
    }

    let mut rows = Vec::new();
    let mut all_win = true;
    for c in &cells {
        let speedup = c.fused_bps / c.legacy_bps;
        println!(
            "bench: INT({}|{}) {:<8} fused {:>8.1} MB/s  legacy {:>8.1} MB/s  speedup {speedup:.2}x",
            c.n,
            c.h,
            c.op,
            c.fused_bps / 1e6,
            c.legacy_bps / 1e6
        );
        // upgrade (1 pass vs 4) must strictly win — the acceptance gate.
        // launch (1 pass vs 2, both SWAR when aligned) has thinner
        // margins, so it gets a noise band instead of a flaky hard gate.
        all_win &= match c.op {
            "upgrade" => c.fused_bps >= c.legacy_bps,
            _ => c.fused_bps >= 0.9 * c.legacy_bps,
        };
        rows.push(json::obj(vec![
            ("n", json::num(c.n as f64)),
            ("h", json::num(c.h as f64)),
            ("op", json::str_(c.op)),
            ("fused_bytes_per_s", json::num(c.fused_bps)),
            ("legacy_bytes_per_s", json::num(c.legacy_bps)),
            ("speedup", json::num(speedup)),
        ]));
    }

    let doc = json::obj(vec![
        ("elements", json::num(ELEMS as f64)),
        ("channels", json::num(CHANNELS as f64)),
        ("cells", json::arr(rows)),
        (
            "note",
            json::str_(
                "packed-input bytes/sec of the fused one-pass kernels vs the legacy \
                 unpack/recompose/dequant composition; best-of-iterations per cell",
            ),
        ),
    ]);
    let out = "BENCH_kernels.json";
    std::fs::write(out, json::to_string(&doc)).unwrap();
    println!("bench: wrote {out}");

    // the acceptance gate: the one-pass upgrade path must never lose to
    // the four-pass composition it replaced, at any measured bitwidth
    // (launch cells carry the 0.9 noise band above)
    assert!(
        all_win,
        "fused kernel lost to the legacy composition on at least one cell — see {out}"
    );
    println!("bench: fused holds the gate on all {} cells", cells.len());
}
