//! Bench: the switch-path decode floor, per dispatch tier — the
//! measured cost under the paper's cheap-switching claim (§3.3,
//! Table 5). Writes `BENCH_kernels.json` with bytes/sec per
//! (bitwidth, op, tier) cell so the perf trajectory is a recorded
//! artifact; `nestquant bench-guard` turns the file into a CI gate
//! (SIMD must not lose to SWAR on any lane-aligned cell).
//!
//! Four operations per nesting config:
//!
//! * **launch** (part-bit): packed `w_high` → f32.
//! * **upgrade** (full-bit): packed `w_high` + `w_low` → f32.
//! * **forward_part** / **forward_full**: one whole forward pass —
//!   int-domain (activation quant + packed-weight i32 GEMM + scale
//!   epilogue) per tier vs the f32-decode baseline (SIMD fused decode
//!   + f32 matmul), in tokens/sec.
//!
//! Four cells per decode op: the legacy multi-pass composition
//! (`unpack_into` [+ `recompose_into`] + `dequant`) and the fused
//! one-pass kernel pinned to each tier (`scalar` | `swar` | `simd`)
//! via `kernels::plan_for` — so the file records both the fused-vs-
//! legacy win and the per-tier ladder on one machine.
//!
//! Decode throughput denominates in *packed input bytes* (the section
//! bytes a switch actually moves), so the number is comparable across
//! bitwidths; forward throughput denominates in tokens (full passes)
//! per second, comparing the dequantization-free path against decode-
//! then-matmul end to end. Artifact-free; iteration budget capped via
//! `NQ_BENCH_BUDGET_MS` (see `Bench::from_env`).

use nestquant::bits::{self, int_range, packed_nbytes, PackedTensor};
use nestquant::kernels::{self, Tier};
use nestquant::nest::{self, NestConfig, Rounding};
use nestquant::quant;
use nestquant::util::benchkit::Bench;
use nestquant::util::json;
use nestquant::util::prng::Rng;

/// Elements per tensor: big enough to be bandwidth-bound, small enough
/// for a capped CI budget.
const ELEMS: usize = 1 << 18;
const CHANNELS: usize = 64;
/// Forward-pass shape: `ROWS` input features against `CHANNELS`
/// classes — exactly the `ELEMS` weight tensor, channel-fastest.
const ROWS: usize = ELEMS / CHANNELS;

struct Cell {
    n: u8,
    h: u8,
    op: &'static str,
    /// Both packed streams lane-aligned (the SWAR fast-path cells the
    /// guard gates SIMD against).
    aligned: bool,
    legacy_bps: f64,
    tier_bps: [f64; 3], // scalar, swar, simd
}

/// One whole forward pass per measurement: int-domain tier ladder vs
/// the f32-decode reference, in tokens (passes) per second.
struct FwdCell {
    n: u8,
    h: u8,
    op: &'static str,
    aligned: bool,
    f32_decode_tps: f64,
    tier_tps: [f64; 3], // scalar, swar, simd
}

/// One nesting config: build a synthetic tensor, time every cell.
fn bench_config(b: &Bench, n: u8, h: u8, cells: &mut Vec<Cell>) {
    let cfg = NestConfig::new(n, h).unwrap();
    let mut rng = Rng::new(0xD1CE ^ ((n as u64) << 8) ^ h as u64);
    let (lo, hi) = int_range(n);
    let w_int: Vec<i32> = (0..ELEMS)
        .map(|_| rng.int(lo as i64, hi as i64) as i32)
        .collect();
    let scales: Vec<f32> = (0..CHANNELS)
        .map(|_| (rng.f64() * 0.05 + 1e-4) as f32)
        .collect();
    let (hs, ls) = nest::decompose(&w_int, cfg, Rounding::BitShift, true);
    let th = PackedTensor::pack(&hs, h).unwrap();
    let tl = PackedTensor::pack(&ls, cfg.low_bits()).unwrap();
    let (hb, lb) = (th.to_le_bytes(), tl.to_le_bytes());
    let high_bytes = packed_nbytes(ELEMS, h) as f64;
    let both_bytes = (packed_nbytes(ELEMS, h) + packed_nbytes(ELEMS, cfg.low_bits())) as f64;

    let mut out = Vec::with_capacity(ELEMS);

    // --- launch: packed w_high -> f32 ---------------------------------
    let mut launch = Cell {
        n,
        h,
        op: "launch",
        aligned: kernels::swar_aligned(h),
        legacy_bps: 0.0,
        tier_bps: [0.0; 3],
    };
    for (i, tier) in Tier::all().into_iter().enumerate() {
        let plan = kernels::plan_for(tier);
        let s = b.run(&format!("INT({n}|{h}) launch {}", tier.label().to_uppercase()), || {
            plan.unpack_dequant_into(&hb, h, ELEMS, &scales, cfg.scale_inflation(), &mut out);
            std::hint::black_box(&out);
        });
        launch.tier_bps[i] = high_bytes / s.min.as_secs_f64();
    }
    // the legacy baseline is pinned to the pre-dispatch word-stream
    // decode (`bits::unpack_words_into`) — `PackedTensor::unpack_into`
    // now routes through the active kernel tier, which would silently
    // turn "legacy" into an already-SIMD baseline
    let mut scratch_int = Vec::with_capacity(ELEMS);
    let mut scratch_scales = Vec::with_capacity(CHANNELS);
    let s = b.run(&format!("INT({n}|{h}) launch LEGACY"), || {
        bits::unpack_words_into(th.words().iter().copied(), h, ELEMS, &mut scratch_int);
        scratch_scales.clear();
        scratch_scales.extend(scales.iter().map(|s| s * cfg.scale_inflation()));
        quant::dequant(&scratch_int, &scratch_scales, &mut out);
        std::hint::black_box(&out);
    });
    launch.legacy_bps = high_bytes / s.min.as_secs_f64();
    cells.push(launch);

    // --- upgrade: w_high + w_low -> f32 -------------------------------
    let mut upgrade = Cell {
        n,
        h,
        op: "upgrade",
        aligned: kernels::swar_aligned(h) && kernels::swar_aligned(cfg.low_bits()),
        legacy_bps: 0.0,
        tier_bps: [0.0; 3],
    };
    for (i, tier) in Tier::all().into_iter().enumerate() {
        let plan = kernels::plan_for(tier);
        let s = b.run(&format!("INT({n}|{h}) upgrade {}", tier.label().to_uppercase()), || {
            plan.recompose_dequant_into(
                &hb,
                h,
                &lb,
                cfg.low_bits(),
                cfg.l(),
                ELEMS,
                &scales,
                &mut out,
            );
            std::hint::black_box(&out);
        });
        upgrade.tier_bps[i] = both_bytes / s.min.as_secs_f64();
    }
    let mut scratch_high = Vec::with_capacity(ELEMS);
    let mut scratch_low = Vec::with_capacity(ELEMS);
    let s = b.run(&format!("INT({n}|{h}) upgrade LEGACY"), || {
        bits::unpack_words_into(th.words().iter().copied(), h, ELEMS, &mut scratch_high);
        let low_words = tl.words().iter().copied();
        bits::unpack_words_into(low_words, cfg.low_bits(), ELEMS, &mut scratch_low);
        nest::recompose_into(&scratch_high, &scratch_low, cfg.l(), &mut scratch_int);
        quant::dequant(&scratch_int, &scales, &mut out);
        std::hint::black_box(&out);
    });
    upgrade.legacy_bps = both_bytes / s.min.as_secs_f64();
    cells.push(upgrade);
}

/// Forward-pass cells: the tenant's two inference paths, end to end.
///
/// The int-domain side mirrors `NestTenant::forward_int` exactly —
/// activation RTN quant, packed-weight i32 GEMM per tier, per-class
/// scale epilogue (part-bit folds `2^l` into the scale; full-bit
/// recomposes `(hi << l) + lo` on i64 accumulators). The baseline is
/// what `ForwardMode::F32Decode` runs: the fused SIMD decode followed
/// by an f32 matmul over the materialized weights.
fn bench_forward(b: &Bench, n: u8, h: u8, cells: &mut Vec<FwdCell>) {
    let cfg = NestConfig::new(n, h).unwrap();
    let mut rng = Rng::new(0xF052D ^ ((n as u64) << 8) ^ h as u64);
    let (lo, hi) = int_range(n);
    let w_int: Vec<i32> = (0..ELEMS)
        .map(|_| rng.int(lo as i64, hi as i64) as i32)
        .collect();
    let scales: Vec<f32> = (0..CHANNELS)
        .map(|_| (rng.f64() * 0.05 + 1e-4) as f32)
        .collect();
    let x: Vec<f32> = (0..ROWS).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let (hs, ls) = nest::decompose(&w_int, cfg, Rounding::BitShift, true);
    let th = PackedTensor::pack(&hs, h).unwrap();
    let tl = PackedTensor::pack(&ls, cfg.low_bits()).unwrap();
    let (hb, lb) = (th.to_le_bytes(), tl.to_le_bytes());
    let simd = kernels::plan_for(Tier::Simd);

    let mut x_int: Vec<i32> = Vec::with_capacity(ROWS);
    let mut acc_hi: Vec<i32> = Vec::with_capacity(CHANNELS);
    let mut acc_lo: Vec<i32> = Vec::with_capacity(CHANNELS);
    let mut weights: Vec<f32> = Vec::with_capacity(ELEMS);
    let mut logits = [0f32; CHANNELS];

    // --- forward_part: x · dequant(w_high) ----------------------------
    let mut part = FwdCell {
        n,
        h,
        op: "forward_part",
        aligned: kernels::swar_aligned(h),
        f32_decode_tps: 0.0,
        tier_tps: [0.0; 3],
    };
    for (i, tier) in Tier::all().into_iter().enumerate() {
        let plan = kernels::plan_for(tier);
        let label = format!("INT({n}|{h}) fwd-part INT {}", tier.label().to_uppercase());
        let s = b.run(&label, || {
            let sx = quant::quantize_activations(&x, n, &mut x_int);
            plan.gemm_i32_into(&hb, h, &x_int, CHANNELS, &mut acc_hi);
            for (o, (&a, &sc)) in logits.iter_mut().zip(acc_hi.iter().zip(scales.iter())) {
                *o = a as f32 * (sx * (cfg.scale_inflation() * sc));
            }
            std::hint::black_box(&logits);
        });
        part.tier_tps[i] = 1.0 / s.min.as_secs_f64();
    }
    let s = b.run(&format!("INT({n}|{h}) fwd-part F32-DECODE"), || {
        simd.unpack_dequant_into(&hb, h, ELEMS, &scales, cfg.scale_inflation(), &mut weights);
        logits.fill(0.0);
        for (r, &xv) in x.iter().enumerate() {
            let row = &weights[r * CHANNELS..(r + 1) * CHANNELS];
            for (o, &w) in logits.iter_mut().zip(row) {
                *o += xv * w;
            }
        }
        std::hint::black_box(&logits);
    });
    part.f32_decode_tps = 1.0 / s.min.as_secs_f64();
    cells.push(part);

    // --- forward_full: x · dequant(w_high·2^l + w_low) ----------------
    let mut full = FwdCell {
        n,
        h,
        op: "forward_full",
        aligned: kernels::swar_aligned(h) && kernels::swar_aligned(cfg.low_bits()),
        f32_decode_tps: 0.0,
        tier_tps: [0.0; 3],
    };
    for (i, tier) in Tier::all().into_iter().enumerate() {
        let plan = kernels::plan_for(tier);
        let label = format!("INT({n}|{h}) fwd-full INT {}", tier.label().to_uppercase());
        let s = b.run(&label, || {
            let sx = quant::quantize_activations(&x, n, &mut x_int);
            plan.gemm_i32_into(&hb, h, &x_int, CHANNELS, &mut acc_hi);
            plan.gemm_i32_into(&lb, cfg.low_bits(), &x_int, CHANNELS, &mut acc_lo);
            for (c, o) in logits.iter_mut().enumerate() {
                let v = ((acc_hi[c] as i64) << cfg.l()) + acc_lo[c] as i64;
                *o = v as f32 * (sx * scales[c]);
            }
            std::hint::black_box(&logits);
        });
        full.tier_tps[i] = 1.0 / s.min.as_secs_f64();
    }
    let s = b.run(&format!("INT({n}|{h}) fwd-full F32-DECODE"), || {
        simd.recompose_dequant_into(
            &hb,
            h,
            &lb,
            cfg.low_bits(),
            cfg.l(),
            ELEMS,
            &scales,
            &mut weights,
        );
        logits.fill(0.0);
        for (r, &xv) in x.iter().enumerate() {
            let row = &weights[r * CHANNELS..(r + 1) * CHANNELS];
            for (o, &w) in logits.iter_mut().zip(row) {
                *o += xv * w;
            }
        }
        std::hint::black_box(&logits);
    });
    full.f32_decode_tps = 1.0 / s.min.as_secs_f64();
    cells.push(full);
}

fn main() {
    let b = Bench::from_env();
    // (7|4)/(11|8): both streams lane-aligned (paired SWAR); (8|4)/(16|8):
    // w_high aligned only; (8|5)/(8|6)/(6|3)/(7|3): scalar-in-SWAR-tier
    // widths where the SIMD tier's gather path is the first vector path
    let configs: [(u8, u8); 8] =
        [(8, 4), (8, 5), (8, 6), (6, 3), (16, 8), (7, 3), (7, 4), (11, 8)];
    let mut cells = Vec::new();
    let mut fwd_cells = Vec::new();
    for (n, h) in configs {
        bench_config(&b, n, h, &mut cells);
        bench_forward(&b, n, h, &mut fwd_cells);
    }

    let mut rows = Vec::new();
    let mut fused_holds = true;
    for c in &cells {
        let [scalar_bps, swar_bps, simd_bps] = c.tier_bps;
        let vs_legacy = simd_bps / c.legacy_bps;
        let vs_swar = simd_bps / swar_bps;
        println!(
            "bench: INT({}|{}) {:<8} legacy {:>8.1}  scalar {:>8.1}  swar {:>8.1}  \
             simd {:>8.1} MB/s  simd/swar {vs_swar:.2}x  simd/legacy {vs_legacy:.2}x{}",
            c.n,
            c.h,
            c.op,
            c.legacy_bps / 1e6,
            scalar_bps / 1e6,
            swar_bps / 1e6,
            simd_bps / 1e6,
            if c.aligned { "  [aligned]" } else { "" }
        );
        // the SHIPPED default tier (Simd, whatever sub-path it resolved
        // to on this host) must never lose to the legacy multi-pass
        // composition (upgrade strictly; launch gets a noise band) —
        // gating max(simd, swar) would hide a Simd-below-legacy
        // regression behind a healthy SWAR cell
        fused_holds &= match c.op {
            "upgrade" => simd_bps >= c.legacy_bps,
            _ => simd_bps >= 0.9 * c.legacy_bps,
        };
        rows.push(json::obj(vec![
            ("n", json::uint(c.n as u64)),
            ("h", json::uint(c.h as u64)),
            ("op", json::str_(c.op)),
            ("aligned", json::bool_(c.aligned)),
            ("legacy_bytes_per_s", json::num(c.legacy_bps)),
            ("scalar_bytes_per_s", json::num(scalar_bps)),
            ("swar_bytes_per_s", json::num(swar_bps)),
            ("simd_bytes_per_s", json::num(simd_bps)),
            ("simd_vs_swar", json::num(vs_swar)),
            ("simd_vs_legacy", json::num(vs_legacy)),
        ]));
    }

    for c in &fwd_cells {
        let [scalar_tps, swar_tps, simd_tps] = c.tier_tps;
        let vs_f32 = simd_tps / c.f32_decode_tps;
        let vs_swar = simd_tps / swar_tps;
        println!(
            "bench: INT({}|{}) {:<12} f32-decode {:>8.1}  int scalar {:>8.1}  \
             int swar {:>8.1}  int simd {:>8.1} tok/s  simd/swar {vs_swar:.2}x  \
             int/f32 {vs_f32:.2}x{}",
            c.n,
            c.h,
            c.op,
            c.f32_decode_tps,
            scalar_tps,
            swar_tps,
            simd_tps,
            if c.aligned { "  [aligned]" } else { "" }
        );
        rows.push(json::obj(vec![
            ("n", json::uint(c.n as u64)),
            ("h", json::uint(c.h as u64)),
            ("op", json::str_(c.op)),
            ("aligned", json::bool_(c.aligned)),
            ("f32_decode_tokens_per_s", json::num(c.f32_decode_tps)),
            ("scalar_tokens_per_s", json::num(scalar_tps)),
            ("swar_tokens_per_s", json::num(swar_tps)),
            ("simd_tokens_per_s", json::num(simd_tps)),
            ("int_simd_vs_swar", json::num(vs_swar)),
            ("int_simd_vs_f32_decode", json::num(vs_f32)),
        ]));
    }

    let doc = json::obj(vec![
        ("elements", json::uint(ELEMS as u64)),
        ("channels", json::uint(CHANNELS as u64)),
        ("rows", json::uint(ROWS as u64)),
        ("simd_path", json::str_(kernels::plan_for(Tier::Simd).path)),
        ("cells", json::arr(rows)),
        (
            "note",
            json::str_(
                "launch/upgrade: packed-input bytes/sec per (bitwidth, op, tier) — \
                 legacy multi-pass composition vs the fused kernel pinned to each \
                 dispatch tier. forward_part/forward_full: whole forward passes \
                 (tokens)/sec — int-domain GEMM per tier vs the f32-decode+matmul \
                 baseline. Best-of-iterations per cell. Gate with `nestquant \
                 bench-guard`.",
            ),
        ),
    ]);
    let out = "BENCH_kernels.json";
    std::fs::write(out, json::to_string(&doc)).unwrap();
    println!("bench: wrote {out} (simd path: {})", kernels::plan_for(Tier::Simd).path);

    // hard gate #1 (in-bench, launch/upgrade cells only): the fused
    // one-pass path never loses to the four-pass composition it
    // replaced. Gate #2 (simd vs swar on lane-aligned cells) and the
    // forward-cell gates (int simd vs int swar, int vs f32-decode)
    // live in `nestquant bench-guard`, which CI runs against the file
    // just written.
    assert!(
        fused_holds,
        "fused kernel lost to the legacy composition on at least one cell — see {out}"
    );
    println!(
        "bench: fused holds the gate on all {} decode cells ({} forward cells recorded)",
        cells.len(),
        fwd_cells.len()
    );
}
