//! Bench: packed-bit tensor substrate (S1) — pack/unpack throughput per
//! bitwidth, and the decompose/recompose bit ops (S2). Companion to
//! Tables 8–11: these ops sit on every switch path.

use nestquant::bits::{int_range, PackedTensor};
use nestquant::nest::{self, NestConfig, Rounding};
use nestquant::util::benchkit::Bench;
use nestquant::util::prng::Rng;

const N: usize = 1_000_000;

fn main() {
    let b = Bench::default();
    let mut rng = Rng::new(42);

    for bits in [3u8, 4, 5, 8] {
        let (lo, hi) = int_range(bits);
        let vals: Vec<i32> = (0..N).map(|_| rng.int(lo as i64, hi as i64) as i32).collect();
        let packed = PackedTensor::pack(&vals, bits).unwrap();

        b.run_throughput(&format!("pack INT{bits} x1M"), N as f64 / 1e6, "Melem", || {
            std::hint::black_box(PackedTensor::pack(&vals, bits).unwrap());
        });
        let mut out = Vec::with_capacity(N);
        b.run_throughput(&format!("unpack INT{bits} x1M"), N as f64 / 1e6, "Melem", || {
            packed.unpack_into(&mut out);
            std::hint::black_box(&out);
        });
    }

    // decompose / recompose over INT8 (the upgrade/downgrade hot ops)
    let (lo, hi) = int_range(8);
    let w: Vec<i32> = (0..N).map(|_| rng.int(lo as i64, hi as i64) as i32).collect();
    let cfg = NestConfig::new(8, 4).unwrap();
    let (hs, ls) = nest::decompose(&w, cfg, Rounding::Rtn, true);
    b.run_throughput("decompose INT(8|4) x1M", N as f64 / 1e6, "Melem", || {
        std::hint::black_box(nest::decompose(&w, cfg, Rounding::Rtn, true));
    });
    let mut rec = Vec::with_capacity(N);
    b.run_throughput("recompose INT(8|4) x1M", N as f64 / 1e6, "Melem", || {
        nest::recompose_into(&hs, &ls, cfg.l(), &mut rec);
        std::hint::black_box(&rec);
    });
}
