//! Bench: end-to-end inference through the full L3→PJRT stack — batch
//! latency and request throughput per architecture and activation
//! config, plus batcher overhead. The serving-side companion to the
//! paper's deployment claims (and the §Perf L3 target).

use std::time::Duration;

use nestquant::coordinator::Coordinator;
use nestquant::util::benchkit::Bench;

fn main() {
    let root = nestquant::artifacts_dir();
    if !root.join("manifest.json").exists() {
        println!("bench: SKIP pipeline (run `make artifacts` first)");
        return;
    }
    let b = Bench::quick();

    for arch in ["cnn_t", "cnn_m", "cnn_l", "mobile_s", "vit_t", "vit_s"] {
        let mut c = match Coordinator::new(&root, arch, 8, 4) {
            Ok(c) => c,
            Err(_) => continue,
        };
        c.manager.load_full_bit(&mut c.ledger).unwrap();
        let (x, _) = c.manifest.load_val().unwrap();
        let img_len = c.manifest.img * c.manifest.img * c.manifest.channels;
        let batch = c.manifest.batch;
        let input = &x[..batch * img_len];

        let s = b.run(&format!("{arch} a8 full-bit batch16 infer"), || {
            std::hint::black_box(c.infer_batch(input).unwrap());
        });
        println!(
            "bench: {arch:<44}        throughput {:>12.1} req/s (batch {batch})",
            batch as f64 / s.mean.as_secs_f64()
        );
    }

    // batcher overhead: assemble/respond without any model execution
    {
        use nestquant::coordinator::batcher::{self, BatcherConfig, Request};
        use std::sync::mpsc;
        use std::time::Instant;
        let cfg = BatcherConfig {
            batch_size: 16,
            image_len: 24 * 24 * 3,
            max_wait: Duration::from_millis(5),
        };
        let image = vec![0.5f32; cfg.image_len];
        let logits = vec![0.1f32; 16 * 10];
        b.run("batcher assemble+respond x16 (no model)", || {
            let (tx, rx) = mpsc::channel();
            let mut replies = Vec::new();
            for _ in 0..16 {
                let (rtx, rrx) = mpsc::channel();
                replies.push(rrx);
                tx.send(Request {
                    image: image.clone(),
                    reply: rtx,
                    enqueued: Instant::now(),
                })
                .unwrap();
            }
            let batch = batcher::next_batch(&rx, &cfg).unwrap();
            batcher::respond(batch, &logits, 10);
            for r in &replies {
                r.recv().unwrap().unwrap();
            }
        });
    }
}
