//! Bench: quantizer substrate (S3) — Table 1's companion measured on
//! this device: RTN vs SQuant-style adaptive rounding, plus dequant
//! (the per-switch materialization cost).

use nestquant::quant;
use nestquant::util::benchkit::Bench;
use nestquant::util::prng::Rng;

fn main() {
    let b = Bench::default();
    let mut rng = Rng::new(7);

    for (rows, ch) in [(4096usize, 64usize), (16384, 128)] {
        let n = rows * ch;
        let w: Vec<f32> = (0..n).map(|_| (rng.normal() * 0.4) as f32).collect();
        let scales = quant::channel_scales(&w, ch, 8).unwrap();

        b.run_throughput(
            &format!("channel_scales {rows}x{ch}"),
            n as f64 / 1e6,
            "Melem",
            || {
                std::hint::black_box(quant::channel_scales(&w, ch, 8).unwrap());
            },
        );
        b.run_throughput(
            &format!("quantize_rtn {rows}x{ch}"),
            n as f64 / 1e6,
            "Melem",
            || {
                std::hint::black_box(quant::quantize_rtn(&w, &scales, 8));
            },
        );
        b.run_throughput(
            &format!("quantize_adaptive(squant) {rows}x{ch}"),
            n as f64 / 1e6,
            "Melem",
            || {
                std::hint::black_box(quant::quantize_adaptive(&w, &scales, 8));
            },
        );
        let wi = quant::quantize_rtn(&w, &scales, 8);
        let mut out = Vec::with_capacity(n);
        b.run_throughput(
            &format!("dequant {rows}x{ch}"),
            n as f64 / 1e6,
            "Melem",
            || {
                quant::dequant(&wi, &scales, &mut out);
                std::hint::black_box(&out);
            },
        );
    }
}
