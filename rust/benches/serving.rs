//! Bench: the multi-tenant serving path — request round-trip latency
//! against 1 vs 3 hosted models, concurrent-client throughput, and the
//! advise (upgrade+downgrade) cycle under a shared Section-B budget.
//! Artifact-free (synthetic zoo, reference tenants); writes
//! `BENCH_serving.json`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use nestquant::container;
use nestquant::coordinator::server::{serve_tenants, Client, ServerConfig, TenantExecutor};
use nestquant::coordinator::tenant::nest_tenants_from_dir;
use nestquant::coordinator::{Decision, Variant};
use nestquant::store::{ModelStore, StoreBudget};
use nestquant::util::benchkit::Bench;
use nestquant::util::json;

fn build_zoo(dir: &std::path::Path, count: usize) -> Vec<String> {
    std::fs::create_dir_all(dir).unwrap();
    let mut ids = Vec::new();
    for i in 0..count {
        let id = format!("model_{i}");
        let c = container::synthetic_nest(0x5E4E + i as u64, 8, 4, 256, 16).unwrap();
        container::write(&dir.join(format!("{id}.nq")), &c).unwrap();
        ids.push(id);
    }
    ids
}

fn main() {
    let b = Bench::quick();
    let dir = std::env::temp_dir().join(format!("nq_serving_bench_{}", std::process::id()));
    let ids = build_zoo(&dir, 3);

    let store = ModelStore::new();
    let budget = Arc::new(StoreBudget::new(u64::MAX));
    let tenants = nest_tenants_from_dir(&dir, &store, &budget, 4).unwrap();
    let image_len = tenants[0].1.shape().1;
    let boxed: Vec<(String, Box<dyn TenantExecutor>)> = tenants
        .into_iter()
        .map(|(id, t)| (id, Box::new(t) as Box<dyn TenantExecutor>))
        .collect();
    // tight batching window: the bench measures the path, not the wait
    let handle = serve_tenants(
        boxed,
        ServerConfig {
            max_wait: Duration::from_micros(200),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    println!(
        "bench: --- serving: {} tenants on {} (image_len {image_len}) ---",
        ids.len(),
        handle.addr
    );
    let img = vec![0.5f32; image_len];

    // 1. single-tenant round-trip latency
    let mut client = Client::connect(handle.addr).unwrap();
    let s_single = b.run("serve round-trip 1 tenant", || {
        client.infer_model(&ids[0], &img).unwrap();
    });

    // 2. round-robin across 3 tenants on one connection
    let mut i = 0usize;
    let s_rr = b.run("serve round-trip 3-tenant round-robin", || {
        client.infer_model(&ids[i % ids.len()], &img).unwrap();
        i += 1;
    });

    // 3. concurrent throughput: 2 clients per tenant for a fixed window
    let window = Duration::from_secs(1);
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut joins = Vec::new();
    for c in 0..(2 * ids.len()) {
        let id = ids[c % ids.len()].clone();
        let img = img.clone();
        let addr = handle.addr;
        let stop = Arc::clone(&stop);
        joins.push(std::thread::spawn(move || -> u64 {
            let mut client = Client::connect(addr).unwrap();
            let mut n = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                client.infer_model(&id, &img).unwrap();
                n += 1;
            }
            n
        }));
    }
    // a switch storm runs through the same window (advise is part of
    // the measured path: it contends for each tenant's executor lock)
    let t0 = Instant::now();
    let mut switches = 0u64;
    while t0.elapsed() < window {
        for id in &ids {
            handle.advise(id, Decision::SwitchTo(Variant::FullBit)).unwrap();
            handle.advise(id, Decision::SwitchTo(Variant::PartBit)).unwrap();
            switches += 2;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let total: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
    let rps = total as f64 / t0.elapsed().as_secs_f64();
    println!(
        "bench: serve 6-client mixed throughput              {total:>6} reqs  {rps:>10.1} req/s  ({switches} switches mid-traffic)"
    );

    // 4. advise cycle latency (no traffic)
    let s_advise = b.run("advise upgrade+downgrade cycle", || {
        handle.advise(&ids[0], Decision::SwitchTo(Variant::FullBit)).unwrap();
        handle.advise(&ids[0], Decision::SwitchTo(Variant::PartBit)).unwrap();
    });

    // 5. open-loop load: requests fire on a fixed arrival schedule
    // regardless of completions, so queueing delay shows up in the
    // latency tail instead of silently throttling the offered rate.
    // Latency is measured from the *scheduled* send time.
    let open_threads = 8usize;
    let open_rps = 2_000.0f64;
    let open_window = Duration::from_secs(2);
    let per_thread_n = (open_rps * open_window.as_secs_f64() / open_threads as f64) as usize;
    let interval = Duration::from_secs_f64(open_threads as f64 / open_rps);
    let mut lat_joins = Vec::new();
    for c in 0..open_threads {
        let id = ids[c % ids.len()].clone();
        let img = img.clone();
        let addr = handle.addr;
        lat_joins.push(std::thread::spawn(move || -> Vec<Duration> {
            let mut client = Client::connect(addr).unwrap();
            let start = Instant::now();
            let mut lats = Vec::with_capacity(per_thread_n);
            for k in 0..per_thread_n {
                let scheduled = start + interval * k as u32;
                if let Some(wait) = scheduled.checked_duration_since(Instant::now()) {
                    std::thread::sleep(wait);
                }
                client.infer_model(&id, &img).unwrap();
                lats.push(scheduled.elapsed());
            }
            lats
        }));
    }
    let mut open_lats: Vec<Duration> = lat_joins
        .into_iter()
        .flat_map(|j| j.join().unwrap())
        .collect();
    open_lats.sort_unstable();
    let pct = |p: f64| -> f64 {
        let i = ((open_lats.len() - 1) as f64 * p).round() as usize;
        open_lats[i].as_secs_f64() * 1e6
    };
    let (open_p50_us, open_p99_us) = (pct(0.50), pct(0.99));
    println!(
        "bench: open-loop {open_rps:.0} req/s offered              p50 {open_p50_us:>8.1} us  p99 {open_p99_us:>8.1} us  ({} samples)",
        open_lats.len()
    );

    let doc = json::obj(vec![
        ("tenants", json::num(ids.len() as f64)),
        ("image_len", json::num(image_len as f64)),
        (
            "round_trip_us_1_tenant",
            json::num(s_single.mean.as_secs_f64() * 1e6),
        ),
        (
            "round_trip_us_3_tenant_rr",
            json::num(s_rr.mean.as_secs_f64() * 1e6),
        ),
        ("mixed_throughput_rps", json::num(rps)),
        ("switches_mid_traffic", json::num(switches as f64)),
        ("open_loop_offered_rps", json::num(open_rps)),
        ("open_loop_p50_us", json::num(open_p50_us)),
        ("open_loop_p99_us", json::num(open_p99_us)),
        (
            "advise_cycle_us",
            json::num(s_advise.mean.as_secs_f64() * 1e6),
        ),
        (
            "note",
            json::str_(
                "synthetic 3-model zoo through the multi-tenant router; reference \
                 tenants (no PJRT), so numbers isolate the serving path itself",
            ),
        ),
    ]);
    let out = "BENCH_serving.json";
    std::fs::write(out, json::to_string(&doc)).unwrap();
    println!("bench: wrote {out}");

    let mut c2 = Client::connect(handle.addr).unwrap();
    c2.stop_server().unwrap();
    handle.stop();
}
