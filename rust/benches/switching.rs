//! Bench: the switching hot path (Table 11's latency companion) —
//! part-bit launch, upgrade, downgrade, and the diverse-bitwidths
//! baseline's full swap, measured on real artifacts through the real
//! ModelManager (container I/O + unpack + recompose + dequant + PJRT
//! buffer upload).
//!
//! The artifact-free first half compares the **legacy upgrade chain**
//! (`read → parse → attach_section_b`, per-tensor word-vector copies)
//! against the **store view path** (`NqArchive::attach_b` + borrowed
//! views, zero intermediate copies) on a synthetic container, and
//! writes the measured bytes-copied/latency numbers to
//! `BENCH_switching.json`.

use nestquant::container::{self, TensorData};
use nestquant::coordinator::{Coordinator, DiverseBitwidths};
use nestquant::device::MemoryLedger;
use nestquant::runtime::{Engine, Manifest};
use nestquant::store::NqArchive;
use nestquant::util::benchkit::Bench;
use nestquant::util::json;

/// Upgrade-path byte movement of one strategy, measured per cycle.
struct CycleCost {
    /// Bytes fetched from the source per upgrade (the page-in itself).
    fetch_bytes: u64,
    /// Bytes additionally copied into intermediate owned buffers
    /// (word vectors, re-parsed tensors) per upgrade.
    copied_bytes: u64,
    micros: f64,
}

fn cost_json(c: &CycleCost) -> json::Value {
    json::obj(vec![
        ("fetch_bytes_per_upgrade", json::num(c.fetch_bytes as f64)),
        ("copied_bytes_per_upgrade", json::num(c.copied_bytes as f64)),
        ("us_per_upgrade_downgrade_cycle", json::num(c.micros)),
    ])
}

/// The pre-store upgrade chain, kept callable through the deprecated
/// shims exactly so this comparison stays honest.
#[allow(deprecated)]
fn bench_legacy(b: &Bench, path: &std::path::Path, b_len: u64) -> CycleCost {
    let mut c = container::read(path, true).unwrap();
    // bytes attach_section_b copies into per-tensor word vectors
    let mut word_bytes = 0u64;
    {
        let probe = container::read(path, false).unwrap();
        for t in &probe.tensors {
            if let TensorData::Nest { w_low: Some(l), .. } = &t.data {
                word_bytes += l.nbytes() as u64;
            }
        }
    }
    let s = b.run("switch synthetic LEGACY upgrade+downgrade", || {
        container::read_section_b(path, &mut c).unwrap(); // blob Vec + word Vec copies
        for t in &mut c.tensors {
            if let TensorData::Nest { w_low, .. } = &mut t.data {
                *w_low = None; // downgrade: drop
            }
        }
    });
    CycleCost {
        fetch_bytes: b_len,
        copied_bytes: word_bytes,
        micros: s.mean.as_secs_f64() * 1e6,
    }
}

/// The store view path: attach/release one `Arc` per cycle.
fn bench_store(b: &Bench, path: &std::path::Path) -> CycleCost {
    let arch = NqArchive::open(path).unwrap();
    arch.part_bit().unwrap(); // launch state: A resident, layout parsed
    let before = arch.stats();
    let s = b.run("switch synthetic STORE upgrade+downgrade", || {
        let full = arch.full_bit().unwrap(); // upgrade: one B fetch
        std::hint::black_box(&full);
        drop(full);
        arch.release_b(); // downgrade: drop the Arc
    });
    let after = arch.stats();
    let cycles = (after.b_fetches - before.b_fetches).max(1);
    CycleCost {
        fetch_bytes: (after.b_bytes_fetched - before.b_bytes_fetched) / cycles,
        copied_bytes: 0, // views decode straight from the fetched Arc
        micros: s.mean.as_secs_f64() * 1e6,
    }
}

/// Artifact-free: legacy vs store upgrade/downgrade byte movement on a
/// synthetic INT(8|4) container; writes BENCH_switching.json.
fn bench_synthetic(b: &Bench) {
    let dir = std::env::temp_dir().join(format!("nq_switch_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("switch.nq");
    let c = container::synthetic_nest(0xBE7C4, 8, 4, 2048, 64).unwrap();
    let (total, a_len, b_len) = container::write(&path, &c).unwrap();
    println!(
        "bench: --- synthetic switching: container {:.1} KB (A {:.1} / B {:.1}) ---",
        total as f64 / 1e3,
        a_len as f64 / 1e3,
        b_len as f64 / 1e3
    );

    let legacy = bench_legacy(b, &path, b_len);
    let store = bench_store(b, &path);
    println!(
        "bench: upgrade bytes  legacy fetch {} + copy {}  |  store fetch {} + copy {}",
        legacy.fetch_bytes, legacy.copied_bytes, store.fetch_bytes, store.copied_bytes
    );

    let doc = json::obj(vec![
        ("container_bytes", json::num(total as f64)),
        ("section_a_bytes", json::num(a_len as f64)),
        ("section_b_bytes", json::num(b_len as f64)),
        ("legacy", cost_json(&legacy)),
        ("store", cost_json(&store)),
        (
            "note",
            json::str_(
                "bytes per upgrade/downgrade cycle on a synthetic INT(8|4) container; \
                 downgrades copy zero bytes on both paths",
            ),
        ),
    ]);
    let out = "BENCH_switching.json";
    std::fs::write(out, json::to_string(&doc)).unwrap();
    println!("bench: wrote {out}");
}

fn main() {
    let b = Bench::quick();
    bench_synthetic(&b);

    let root = nestquant::artifacts_dir();
    if !root.join("manifest.json").exists() {
        println!("bench: SKIP artifact switching (run `make artifacts` first)");
        return;
    }
    let manifest = Manifest::load(&root).unwrap();

    for arch in ["cnn_t", "cnn_m", "cnn_l", "vit_s"] {
        if !manifest.models.contains_key(arch) {
            continue;
        }
        let spec = manifest.model(arch).unwrap();
        let Some(_) = spec.nest_container(8, 4) else { continue };
        let mut c = match Coordinator::new(&root, arch, 8, 4) {
            Ok(c) => c,
            Err(e) => {
                println!("bench: SKIP {arch}: {e:#}");
                continue;
            }
        };
        let (sec_a, sec_b) = c.manager.section_bytes();
        println!(
            "bench: --- {arch}: sections {:.1}/{:.1} KB ---",
            sec_a as f64 / 1e3,
            sec_b as f64 / 1e3
        );

        b.run(&format!("{arch} part-bit launch"), || {
            c.manager.load_part_bit(&mut c.ledger).unwrap();
            c.manager.unload(&mut c.ledger).unwrap();
        });
        c.manager.load_part_bit(&mut c.ledger).unwrap();
        b.run(&format!("{arch} upgrade+downgrade cycle"), || {
            c.manager.upgrade(&mut c.ledger).unwrap();
            c.manager.downgrade(&mut c.ledger).unwrap();
        });
        let stats = c.manager.archive().stats();
        println!(
            "bench: {arch} archive accounting: A fetched {}x, layout parsed {}x, B fetched {}x",
            stats.a_fetches, stats.layout_parses, stats.b_fetches
        );
        c.manager.unload(&mut c.ledger).unwrap();

        // diverse-bitwidths baseline: full INT8 ⇄ INT4 swap
        let engine = Engine::cpu().unwrap();
        let mut base =
            DiverseBitwidths::new(&engine, spec.clone(), 8, &root, &[8, 4]).unwrap();
        let mut ledger = MemoryLedger::new(u64::MAX / 2);
        base.switch_to(8, &mut ledger).unwrap();
        b.run(&format!("{arch} DIVERSE swap INT8<->INT4"), || {
            base.switch_to(4, &mut ledger).unwrap();
            base.switch_to(8, &mut ledger).unwrap();
        });
    }
}
