//! Bench: the switching hot path (Table 11's latency companion) —
//! part-bit launch, upgrade, downgrade, and the diverse-bitwidths
//! baseline's full swap, measured on real artifacts through the real
//! ModelManager (container I/O + unpack + recompose + dequant + PJRT
//! buffer upload).

use nestquant::coordinator::{Coordinator, DiverseBitwidths};
use nestquant::device::MemoryLedger;
use nestquant::runtime::{Engine, Manifest};
use nestquant::util::benchkit::Bench;

fn main() {
    let root = nestquant::artifacts_dir();
    if !root.join("manifest.json").exists() {
        println!("bench: SKIP switching (run `make artifacts` first)");
        return;
    }
    let b = Bench::quick();
    let manifest = Manifest::load(&root).unwrap();

    for arch in ["cnn_t", "cnn_m", "cnn_l", "vit_s"] {
        if !manifest.models.contains_key(arch) {
            continue;
        }
        let spec = manifest.model(arch).unwrap();
        let Some(_) = spec.nest_container(8, 4) else { continue };
        let mut c = match Coordinator::new(&root, arch, 8, 4) {
            Ok(c) => c,
            Err(e) => {
                println!("bench: SKIP {arch}: {e:#}");
                continue;
            }
        };
        let (sec_a, sec_b) = c.manager.section_bytes();
        println!(
            "bench: --- {arch}: sections {:.1}/{:.1} KB ---",
            sec_a as f64 / 1e3,
            sec_b as f64 / 1e3
        );

        b.run(&format!("{arch} part-bit launch"), || {
            c.manager.load_part_bit(&mut c.ledger).unwrap();
            c.manager.unload(&mut c.ledger).unwrap();
        });
        c.manager.load_part_bit(&mut c.ledger).unwrap();
        b.run(&format!("{arch} upgrade+downgrade cycle"), || {
            c.manager.upgrade(&mut c.ledger).unwrap();
            c.manager.downgrade(&mut c.ledger).unwrap();
        });
        c.manager.unload(&mut c.ledger).unwrap();

        // diverse-bitwidths baseline: full INT8 ⇄ INT4 swap
        let engine = Engine::cpu().unwrap();
        let mut base =
            DiverseBitwidths::new(&engine, spec.clone(), 8, &root, &[8, 4]).unwrap();
        let mut ledger = MemoryLedger::new(u64::MAX / 2);
        base.switch_to(8, &mut ledger).unwrap();
        b.run(&format!("{arch} DIVERSE swap INT8<->INT4"), || {
            base.switch_to(4, &mut ledger).unwrap();
            base.switch_to(8, &mut ledger).unwrap();
        });
    }
}
