//! Packed-bit tensors: k-bit signed integers in u64 words (S1).
//!
//! Bit-layout contract shared with `python/compile/packbits.py`: element
//! `i` lives in word `i / lanes` at bit offset `(i % lanes) * k`,
//! `lanes = 64 / k`, two's-complement field, zero-padded final word.
//! The paper deploys arbitrary-bitwidth weights this way ([38,39], §3.3.3)
//! because no on-device DL library supports sub-8-bit dtypes (Table 3).

use anyhow::{bail, ensure, Result};

pub const MIN_BITS: u8 = 2;
pub const MAX_BITS: u8 = 16;

/// Lanes (elements) per 64-bit word for a `bits`-bit type.
#[inline]
pub fn lanes(bits: u8) -> usize {
    64 / bits as usize
}

/// Signed range [min, max] of a `bits`-bit integer.
#[inline]
pub fn int_range(bits: u8) -> (i32, i32) {
    (-(1 << (bits - 1)), (1 << (bits - 1)) - 1)
}

fn check_bits(bits: u8) -> Result<()> {
    ensure!(
        (MIN_BITS..=MAX_BITS).contains(&bits),
        "bits must be in [{MIN_BITS},{MAX_BITS}], got {bits}"
    );
    Ok(())
}

/// An immutable packed tensor of `len` signed `bits`-bit integers.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedTensor {
    bits: u8,
    len: usize,
    words: Vec<u64>,
}

impl PackedTensor {
    /// Pack `values` (each within the signed `bits` range) into words.
    pub fn pack(values: &[i32], bits: u8) -> Result<Self> {
        check_bits(bits)?;
        let (lo, hi) = int_range(bits);
        let n_lanes = lanes(bits);
        let n_words = values.len().div_ceil(n_lanes);
        let mask = (1u64 << bits) - 1;
        let mut words = vec![0u64; n_words];
        for (i, &v) in values.iter().enumerate() {
            if v < lo || v > hi {
                bail!("value {v} out of signed INT{bits} range [{lo},{hi}] at index {i}");
            }
            let field = (v as i64 as u64) & mask;
            words[i / n_lanes] |= field << ((i % n_lanes) * bits as usize);
        }
        Ok(PackedTensor {
            bits,
            len: values.len(),
            words,
        })
    }

    /// Adopt existing words (e.g. read from a container). Validates length.
    pub fn from_words(words: Vec<u64>, bits: u8, len: usize) -> Result<Self> {
        check_bits(bits)?;
        let need = len.div_ceil(lanes(bits));
        ensure!(
            words.len() == need,
            "INT{bits} x {len} needs {need} words, got {}",
            words.len()
        );
        Ok(PackedTensor { bits, len, words })
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Serialize the words as little-endian bytes — the exact payload
    /// layout of a `.nq` packed block (what `store::PackedView` and the
    /// `crate::kernels` decode loops consume).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        self.words.iter().flat_map(|w| w.to_le_bytes()).collect()
    }

    /// On-disk payload bytes (words only).
    pub fn nbytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Element at `i`, sign-extended.
    #[inline]
    pub fn get(&self, i: usize) -> i32 {
        debug_assert!(i < self.len);
        let n_lanes = lanes(self.bits);
        let word = self.words[i / n_lanes];
        let shift = (i % n_lanes) * self.bits as usize;
        let field = (word >> shift) & ((1u64 << self.bits) - 1);
        sign_extend(field, self.bits)
    }

    /// Unpack everything into i32s.
    pub fn unpack(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.len);
        self.unpack_into(&mut out);
        out
    }

    /// Unpack into a caller buffer (hot path: avoids realloc on
    /// re-page-in). On little-endian targets the owned words are viewed
    /// as the packed byte stream and decoded through the dispatched
    /// kernel tier (`crate::kernels::unpack_ints_into` — SWAR/SIMD per
    /// the process `KernelPlan`); elsewhere the portable word-stream
    /// path runs.
    pub fn unpack_into(&self, out: &mut Vec<i32>) {
        #[cfg(target_endian = "little")]
        {
            // Safety: reinterpreting &[u64] as &[u8] is always valid
            // (alignment only loosens, lifetime carried over); on LE the
            // in-memory bytes ARE the packed LE byte stream.
            let bytes = unsafe {
                std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.words.len() * 8)
            };
            crate::kernels::unpack_ints_into(bytes, self.bits, self.len, out);
        }
        #[cfg(not(target_endian = "little"))]
        unpack_words_into(self.words.iter().copied(), self.bits, self.len, out);
    }

    /// Iterator over the values without materializing.
    pub fn iter(&self) -> impl Iterator<Item = i32> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }
}

#[inline]
pub(crate) fn sign_extend(field: u64, bits: u8) -> i32 {
    let shift = 64 - bits as u32;
    (((field << shift) as i64) >> shift) as i32
}

/// Sign-extend a field via xor-sub given the precomputed sign bit
/// (`1 << (bits - 1)`): the SWAR idiom shared by the word-parallel
/// decode loops here and in `crate::kernels` — one op pair per lane,
/// no width-dependent double shift.
#[inline(always)]
pub(crate) fn sext(field: u64, sign: u64) -> i32 {
    ((field ^ sign) as i64 - sign as i64) as i32
}

/// Ideal packed payload size in bytes for `count` `bits`-bit elements.
pub fn packed_nbytes(count: usize, bits: u8) -> usize {
    count.div_ceil(lanes(bits)) * 8
}

/// Packed words needed for `count` `bits`-bit elements.
pub fn packed_nwords(count: usize, bits: u8) -> usize {
    count.div_ceil(lanes(bits))
}

/// Unpack `len` sign-extended `bits`-bit values from a word stream into a
/// caller buffer. This is the decode kernel shared by [`PackedTensor`]
/// and the zero-copy `store::PackedView` (which feeds words straight from
/// an `Arc<[u8]>` archive slice, never materializing a word `Vec`).
/// Callers must supply at least `packed_nwords(len, bits)` words; the
/// caller is trusted on `bits` being in range (the packed containers
/// validate it at parse time).
///
/// Lane-aligned bitwidths (`bits ∣ 64`) take a SWAR path: the per-word
/// lane loop has a constant trip count the compiler unrolls and
/// vectorizes, with xor-sub sign extension instead of a double shift.
///
/// This is the *portable* word-stream entry (any `u64` iterator, any
/// endianness). Consumers holding contiguous packed bytes — tensors,
/// archive views — route through `crate::kernels::unpack_ints_into`
/// instead, which dispatches into the process-selected kernel tier
/// (scalar / SWAR / SIMD, `NQ_KERNEL` override) and covers every
/// bitwidth with a vector path where the hardware has one.
pub fn unpack_words_into<I: Iterator<Item = u64>>(
    words: I,
    bits: u8,
    len: usize,
    out: &mut Vec<i32>,
) {
    out.clear();
    out.reserve(len);
    match bits {
        2 => unpack_words_swar::<2, I>(words, len, out),
        4 => unpack_words_swar::<4, I>(words, len, out),
        8 => unpack_words_swar::<8, I>(words, len, out),
        16 => unpack_words_swar::<16, I>(words, len, out),
        _ => unpack_words_scalar(words, bits, len, out),
    }
}

fn unpack_words_scalar<I: Iterator<Item = u64>>(
    words: I,
    bits: u8,
    len: usize,
    out: &mut Vec<i32>,
) {
    let n_lanes = lanes(bits);
    let b = bits as usize;
    let mask = (1u64 << b) - 1;
    let mut remaining = len;
    for mut word in words {
        if remaining == 0 {
            break;
        }
        let take = remaining.min(n_lanes);
        // word-at-a-time main loop: one load per `lanes` outputs
        for _ in 0..take {
            out.push(sign_extend(word & mask, bits));
            word >>= b;
        }
        remaining -= take;
    }
    debug_assert_eq!(remaining, 0, "word stream shorter than {len} x INT{bits}");
}

fn unpack_words_swar<const BITS: u32, I: Iterator<Item = u64>>(
    words: I,
    len: usize,
    out: &mut Vec<i32>,
) {
    let n_lanes = (64 / BITS) as usize;
    let mask = (1u64 << BITS) - 1;
    let sign = 1u64 << (BITS - 1);
    let mut remaining = len;
    for mut word in words {
        if remaining == 0 {
            break;
        }
        if remaining >= n_lanes {
            // full word: constant-trip unrolled lane loop
            for _ in 0..n_lanes {
                out.push(sext(word & mask, sign));
                word >>= BITS;
            }
            remaining -= n_lanes;
        } else {
            for _ in 0..remaining {
                out.push(sext(word & mask, sign));
                word >>= BITS;
            }
            remaining = 0;
        }
    }
    debug_assert_eq!(remaining, 0, "word stream shorter than {len} x INT{}", BITS);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, vec_i64};

    #[test]
    fn golden_layout_int4_matches_python() {
        let t = PackedTensor::pack(&[1, 2, 3, -1], 4).unwrap();
        assert_eq!(t.words(), &[0x1 | (0x2 << 4) | (0x3 << 8) | (0xF << 12)]);
    }

    #[test]
    fn golden_layout_int3_spans_words() {
        let vals: Vec<i32> = (-4..4).cycle().take(32).collect();
        let t = PackedTensor::pack(&vals, 3).unwrap();
        assert_eq!(t.words().len(), 2);
        assert_eq!(t.unpack(), vals);
    }

    #[test]
    fn roundtrip_extremes_all_bits() {
        for bits in MIN_BITS..=MAX_BITS {
            let (lo, hi) = int_range(bits);
            let vals = [lo, hi, 0, -1, 1, lo, hi];
            let t = PackedTensor::pack(&vals, bits).unwrap();
            assert_eq!(t.unpack(), vals, "bits={bits}");
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(t.get(i), v);
            }
        }
    }

    #[test]
    fn prop_roundtrip_random() {
        for bits in [2u8, 3, 4, 5, 6, 7, 8, 11, 16] {
            let (lo, hi) = int_range(bits);
            check(
                &format!("pack-roundtrip-{bits}"),
                60,
                move |r, s| vec_i64(r, s, 2000, lo as i64, hi as i64),
                move |vals| {
                    let v32: Vec<i32> = vals.iter().map(|&v| v as i32).collect();
                    let t = PackedTensor::pack(&v32, bits).unwrap();
                    t.unpack() == v32 && t.nbytes() == packed_nbytes(v32.len(), bits)
                },
            );
        }
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(PackedTensor::pack(&[8], 4).is_err());
        assert!(PackedTensor::pack(&[-9], 4).is_err());
        assert!(PackedTensor::pack(&[0], 1).is_err());
        assert!(PackedTensor::pack(&[0], 17).is_err());
    }

    #[test]
    fn from_words_validates_length() {
        assert!(PackedTensor::from_words(vec![0], 4, 17).is_err());
        assert!(PackedTensor::from_words(vec![0, 0], 4, 17).is_ok());
    }

    #[test]
    fn empty_tensor() {
        let t = PackedTensor::pack(&[], 5).unwrap();
        assert!(t.is_empty());
        assert_eq!(t.nbytes(), 0);
        assert_eq!(t.unpack(), Vec::<i32>::new());
    }

    #[test]
    fn packed_nbytes_matches_python() {
        assert_eq!(packed_nbytes(0, 4), 0);
        assert_eq!(packed_nbytes(16, 4), 8);
        assert_eq!(packed_nbytes(17, 4), 16);
        assert_eq!(packed_nbytes(21, 3), 8);
        assert_eq!(packed_nbytes(22, 3), 16);
    }

    #[test]
    fn unpack_words_into_matches_packed_tensor() {
        for bits in [2u8, 3, 4, 7, 8, 11, 16] {
            let (lo, hi) = int_range(bits);
            let vals: Vec<i32> = (0..77).map(|i| lo + (i * 13) % (hi - lo + 1)).collect();
            let t = PackedTensor::pack(&vals, bits).unwrap();
            let mut via_stream = Vec::new();
            unpack_words_into(t.words().iter().copied(), bits, vals.len(), &mut via_stream);
            assert_eq!(via_stream, vals, "bits={bits}");
            // and from raw LE bytes, the container/store decode path
            let bytes: Vec<u8> = t.words().iter().flat_map(|w| w.to_le_bytes()).collect();
            let mut via_bytes = Vec::new();
            unpack_words_into(
                bytes.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())),
                bits,
                vals.len(),
                &mut via_bytes,
            );
            assert_eq!(via_bytes, vals, "bits={bits}");
        }
    }

    #[test]
    fn unpack_into_reuses_buffer() {
        let t = PackedTensor::pack(&[1, -2, 3], 8).unwrap();
        let mut buf = Vec::with_capacity(100);
        t.unpack_into(&mut buf);
        assert_eq!(buf, vec![1, -2, 3]);
        t.unpack_into(&mut buf);
        assert_eq!(buf, vec![1, -2, 3]);
    }
}
