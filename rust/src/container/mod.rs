//! `.nq` container reader/writer (S5) — byte-compatible with
//! `python/compile/nqformat.py` (see that module's layout doc).
//!
//! The crucial affordance is *sectioned reads*: a part-bit launch parses
//! section A only; the upgrade path reads section B as one contiguous
//! tail. Those two byte counts ARE the paper's page-in/page-out
//! overheads (Table 11).
//!
//! Integrity: the writer appends a 24-byte trailer (`NQCKSUM1` + per-
//! section CRC-64/XZ). Readers treat it as optional — pre-trailer
//! artifacts parse unchanged — and the store verifies the checksums at
//! section fetch time ([`crate::store::NqArchive`]), as does
//! `fleet::RemoteSource` after chunked reassembly. Section byte ranges
//! always exclude the trailer.
//!
//! This module owns the **format**: the byte layout, the typed
//! [`Container`] decode, the [`SectionIndex`], and the writer
//! ([`serialize`]/[`write`]/[`synthetic_nest`]). **Access** goes through
//! [`crate::store`]: open a `store::NqArchive` once and hand out views —
//! the free functions `read`/`parse`/`probe`/`read_range`/
//! `attach_section_b`/`read_section_b` remain as deprecated shims over
//! the same internals for out-of-tree callers.

use std::io::Write;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::bits::{packed_nbytes, PackedTensor};
use crate::util::crc64::crc64;

pub const MAGIC: &[u8; 8] = b"NESTQNT1";
pub const VERSION: u32 = 1;

/// Magic of the optional integrity trailer appended after section B.
pub const TRAILER_MAGIC: &[u8; 8] = b"NQCKSUM1";
/// Trailer size: magic + CRC-64/XZ of section A + CRC-64/XZ of section B.
pub const TRAILER_LEN: usize = 24;

/// Per-section CRC-64/XZ checksums from the `.nq` trailer.
///
/// The geometry walk (`SectionIndex`, `ModelLayout`) validates byte
/// *ranges*; these catch bit flips *inside* payloads — verified at
/// `store::NqArchive` section fetch and by `fleet::RemoteSource` after
/// chunked reassembly. Pre-trailer artifacts (and the Python pipeline's
/// old output) simply have none: readers treat the trailer as optional.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SectionChecksums {
    /// CRC-64/XZ of the section-A bytes.
    pub a: u64,
    /// CRC-64/XZ of the section-B bytes (0 ≡ crc64 of empty for
    /// mono/fp32 containers, which have no section B).
    pub b: u64,
}

/// Split serialized container bytes into (payload, trailer checksums).
/// The trailer is detected by its magic in the final [`TRAILER_LEN`]
/// bytes; absent or unrecognized trailers yield the whole input.
pub(crate) fn split_trailer(data: &[u8]) -> (&[u8], Option<SectionChecksums>) {
    if data.len() >= TRAILER_LEN {
        let t = &data[data.len() - TRAILER_LEN..];
        if &t[..8] == TRAILER_MAGIC {
            let a = u64::from_le_bytes(t[8..16].try_into().unwrap());
            let b = u64::from_le_bytes(t[16..24].try_into().unwrap());
            return (
                &data[..data.len() - TRAILER_LEN],
                Some(SectionChecksums { a, b }),
            );
        }
    }
    (data, None)
}

/// Decode an exactly-trailer-sized tail read from the end of a file.
pub(crate) fn split_trailer_tail(tail: &[u8; TRAILER_LEN]) -> Option<SectionChecksums> {
    if &tail[..8] == TRAILER_MAGIC {
        Some(SectionChecksums {
            a: u64::from_le_bytes(tail[8..16].try_into().unwrap()),
            b: u64::from_le_bytes(tail[16..24].try_into().unwrap()),
        })
    } else {
        None
    }
}

fn encode_trailer(ck: SectionChecksums) -> [u8; TRAILER_LEN] {
    let mut t = [0u8; TRAILER_LEN];
    t[..8].copy_from_slice(TRAILER_MAGIC);
    t[8..16].copy_from_slice(&ck.a.to_le_bytes());
    t[16..24].copy_from_slice(&ck.b.to_le_bytes());
    t
}

/// Container kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// NestQuant: w_high in section A, w_low in section B.
    Nest,
    /// Monolithic packed INTk model.
    Mono,
    /// Raw FP32 model.
    Fp32,
}

impl Kind {
    pub(crate) fn from_u8(v: u8) -> Result<Kind> {
        Ok(match v {
            0 => Kind::Nest,
            1 => Kind::Mono,
            2 => Kind::Fp32,
            _ => bail!("unknown container kind {v}"),
        })
    }

    pub(crate) fn as_u8(self) -> u8 {
        match self {
            Kind::Nest => 0,
            Kind::Mono => 1,
            Kind::Fp32 => 2,
        }
    }
}

/// Payload of one tensor.
#[derive(Debug, Clone)]
pub enum TensorData {
    /// FP32 parameter (bias, layernorm, pos-emb).
    Fp32(Vec<f32>),
    /// NestQuant weight: per-channel scales + packed w_high (+ w_low once
    /// section B has been paged in).
    Nest {
        scales: Vec<f32>,
        w_high: PackedTensor,
        w_low: Option<PackedTensor>,
    },
    /// Monolithic packed weight.
    Mono {
        scales: Vec<f32>,
        w_int: PackedTensor,
    },
}

/// One tensor: name, logical shape, payload.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A parsed container.
#[derive(Debug, Clone)]
pub struct Container {
    pub kind: Kind,
    pub n: u8,
    pub h: u8,
    pub act_bits: u8,
    pub name: String,
    pub meta: String,
    pub tensors: Vec<Tensor>,
    /// Byte offset of section B (0 when absent).
    pub section_b_offset: u64,
    /// Section payload bytes (A ++ B; excludes the integrity trailer).
    pub file_len: u64,
}

impl Container {
    /// Section-A bytes == part-bit page-in cost (D_high in §4.3.3).
    pub fn section_a_bytes(&self) -> u64 {
        if self.section_b_offset == 0 {
            self.file_len
        } else {
            self.section_b_offset
        }
    }

    /// Section-B bytes == upgrade page-in / downgrade page-out (D_low).
    pub fn section_b_bytes(&self) -> u64 {
        if self.section_b_offset == 0 {
            0
        } else {
            self.file_len - self.section_b_offset
        }
    }
}

/// Byte-range index of one `.nq` file: everything a distribution server
/// needs to serve section-granular reads without parsing tensor payloads.
/// Produced by [`probe`], which reads only the header prefix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SectionIndex {
    pub kind: Kind,
    pub n: u8,
    pub h: u8,
    pub act_bits: u8,
    pub name: String,
    pub section_b_offset: u64,
    pub file_len: u64,
    /// Per-section CRC-64 checksums when the artifact carries the
    /// integrity trailer (`None` for pre-trailer artifacts).
    pub checksums: Option<SectionChecksums>,
}

impl SectionIndex {
    /// Bytes of the integrity trailer at the end of the file (0 when
    /// absent).
    pub fn trailer_len(&self) -> u64 {
        if self.checksums.is_some() {
            TRAILER_LEN as u64
        } else {
            0
        }
    }

    /// Section payload bytes: the file minus the trailer (== section A
    /// ++ section B).
    pub fn payload_len(&self) -> u64 {
        self.file_len - self.trailer_len()
    }

    /// Byte range of section A (header + scales + w_high + fp32 params).
    pub fn section_a(&self) -> std::ops::Range<u64> {
        if self.section_b_offset == 0 {
            0..self.payload_len()
        } else {
            0..self.section_b_offset
        }
    }

    /// Byte range of section B (the packed w_low tail; empty when absent).
    pub fn section_b(&self) -> std::ops::Range<u64> {
        if self.section_b_offset == 0 {
            self.payload_len()..self.payload_len()
        } else {
            self.section_b_offset..self.payload_len()
        }
    }

    /// Section-A bytes (the part-bit page-in cost).
    pub fn section_a_bytes(&self) -> u64 {
        let r = self.section_a();
        r.end - r.start
    }

    /// Section-B bytes (the upgrade delta).
    pub fn section_b_bytes(&self) -> u64 {
        let r = self.section_b();
        r.end - r.start
    }
}

// ---------------------------------------------------------------------------
// reading
// ---------------------------------------------------------------------------

pub(crate) struct Cursor<'a> {
    pub(crate) d: &'a [u8],
    pub(crate) o: usize,
}

/// Marker message for reads past the end of the buffer; [`probe`] keys
/// window growth on it (any other parse error is final).
const TRUNCATED: &str = "truncated container";

impl<'a> Cursor<'a> {
    pub(crate) fn raw(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.o + n <= self.d.len(), "{TRUNCATED} at {}", self.o);
        let s = &self.d[self.o..self.o + n];
        self.o += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.raw(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        let b = self.raw(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        let b = self.raw(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub(crate) fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        ensure!(n < 1 << 20, "unreasonable string length {n}");
        Ok(String::from_utf8(self.raw(n)?.to_vec())?)
    }

    pub(crate) fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.raw(4 * n)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub(crate) fn packed(&mut self, count: usize) -> Result<(u8, PackedTensor)> {
        let bits = self.u8()?;
        let nw = self.u32()? as usize;
        let b = self.raw(8 * nw)?;
        let words: Vec<u64> = b
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok((bits, PackedTensor::from_words(words, bits, count)?))
    }
}

/// Read a container. `part_bit_only` stops after section A (w_low = None):
/// this is the *part-bit launch* read path and touches no section-B bytes.
#[deprecated(note = "open a `store::NqArchive` once and use its views \
                     (`part_bit`/`full_bit`/`to_container`) instead of per-call file reads")]
pub fn read(path: &Path, part_bit_only: bool) -> Result<Container> {
    read_impl(path, part_bit_only)
}

pub(crate) fn read_impl(path: &Path, part_bit_only: bool) -> Result<Container> {
    let data = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_impl(&data, part_bit_only).with_context(|| format!("parsing {}", path.display()))
}

/// Parse from memory (transport hands over received bytes directly).
#[deprecated(note = "use `store::NqArchive::from_bytes` (zero-copy views) or \
                     `NqArchive::to_container` for an owned decode")]
pub fn parse(data: &[u8], part_bit_only: bool) -> Result<Container> {
    parse_impl(data, part_bit_only)
}

pub(crate) fn parse_impl(data: &[u8], part_bit_only: bool) -> Result<Container> {
    // strip (and verify) the optional integrity trailer first, so the
    // body walk below sees exactly the section payload
    let (data, checksums) = split_trailer(data);
    let p = parse_prefix(data)?;
    if let Some(ck) = checksums {
        let a_end = if p.section_b_offset == 0 {
            data.len()
        } else {
            p.section_b_offset as usize
        };
        ensure!(a_end <= data.len(), "section B offset beyond payload");
        ensure!(
            crc64(&data[..a_end]) == ck.a,
            "section A checksum mismatch (corrupt container)"
        );
        ensure!(
            crc64(&data[a_end..]) == ck.b,
            "section B checksum mismatch (corrupt container)"
        );
    }
    let mut c = Cursor {
        d: data,
        o: p.consumed,
    };
    let (kind, n, h, act_bits) = (p.kind, p.n, p.h, p.act_bits);
    let (name, meta) = (p.name, p.meta);
    let num = p.num_tensors;
    let off_b = p.section_b_offset;

    let mut tensors = Vec::with_capacity(num);
    for _ in 0..num {
        let tname = c.str()?;
        let ptype = c.u8()?;
        let ndim = c.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u32()? as usize);
        }
        let count: usize = shape.iter().product();
        let data = match (ptype, kind) {
            (1, _) => TensorData::Fp32(c.f32s(count)?),
            (0, Kind::Nest) => {
                let ns = c.u32()? as usize;
                let scales = c.f32s(ns)?;
                let (bits, w_high) = c.packed(count)?;
                ensure!(bits == h, "w_high bits {bits} != header h {h}");
                TensorData::Nest {
                    scales,
                    w_high,
                    w_low: None,
                }
            }
            (0, Kind::Mono) => {
                let ns = c.u32()? as usize;
                let scales = c.f32s(ns)?;
                let (bits, w_int) = c.packed(count)?;
                ensure!(bits == n, "w_int bits {bits} != header n {n}");
                TensorData::Mono { scales, w_int }
            }
            (0, Kind::Fp32) => bail!("fp32 container cannot hold quantized tensors"),
            (p, _) => bail!("unknown ptype {p}"),
        };
        tensors.push(Tensor {
            name: tname,
            shape,
            data,
        });
    }

    let mut container = Container {
        kind,
        n,
        h,
        act_bits,
        name,
        meta,
        tensors,
        section_b_offset: off_b,
        file_len: data.len() as u64,
    };

    if kind == Kind::Nest {
        ensure!(off_b as usize == c.o, "section B offset mismatch: {} vs {}", off_b, c.o);
        if !part_bit_only {
            attach_section_b_impl(&mut container, &data[off_b as usize..])?;
        }
    } else {
        ensure!(off_b == 0, "non-nest container with section B");
        ensure!(c.o == data.len(), "trailing bytes");
    }
    Ok(container)
}

/// Parse section-B bytes (the upgrade page-in blob) into w_low tensors.
#[deprecated(note = "use `store::NqArchive::attach_b` — the archive keeps section B as one \
                     shared `Arc` and decodes it lazily instead of copying into word vectors")]
pub fn attach_section_b(container: &mut Container, blob: &[u8]) -> Result<()> {
    attach_section_b_impl(container, blob)
}

pub(crate) fn attach_section_b_impl(container: &mut Container, blob: &[u8]) -> Result<()> {
    ensure!(container.kind == Kind::Nest, "section B only exists for nest containers");
    let expect_low = container.n - container.h + 1;
    let mut c = Cursor { d: blob, o: 0 };
    for t in &mut container.tensors {
        let count = t.shape.iter().product();
        if let TensorData::Nest { w_low, .. } = &mut t.data {
            let (bits, packed) = c.packed(count)?;
            ensure!(bits == expect_low, "w_low bits {bits} != l+1 {expect_low}");
            *w_low = Some(packed);
        }
    }
    ensure!(c.o == blob.len(), "trailing bytes in section B");
    Ok(())
}

/// Read only the section-B tail from disk (the literal upgrade page-in).
#[deprecated(note = "use `store::NqArchive::attach_b` — same single section-B read, \
                     without re-decoding into per-tensor word vectors")]
pub fn read_section_b(path: &Path, container: &mut Container) -> Result<u64> {
    ensure!(container.section_b_offset > 0, "container has no section B");
    // the container's file_len is the *payload* length (sections only),
    // so the read naturally stops before any integrity trailer
    let payload_end = container.file_len;
    ensure!(
        container.section_b_offset <= payload_end,
        "section B offset {} beyond payload length {payload_end}",
        container.section_b_offset
    );
    let blob = read_range_impl(path, container.section_b_offset..payload_end)?;
    let nbytes = blob.len() as u64;
    attach_section_b_impl(container, &blob)?;
    Ok(nbytes)
}

/// The fixed header prefix of a `.nq` file — the one decoder of these
/// fields, shared by [`probe`], the in-memory indexer, and the store's
/// layout walk.
pub(crate) struct HeaderPrefix {
    pub(crate) kind: Kind,
    pub(crate) n: u8,
    pub(crate) h: u8,
    pub(crate) act_bits: u8,
    pub(crate) name: String,
    pub(crate) meta: String,
    pub(crate) num_tensors: usize,
    pub(crate) section_b_offset: u64,
    /// Bytes consumed by the prefix (the first tensor record follows).
    pub(crate) consumed: usize,
}

/// Parse just the fixed header prefix. Errors with "truncated container"
/// when `data` is too short — [`probe`] uses that to grow its read
/// window.
pub(crate) fn parse_prefix(data: &[u8]) -> Result<HeaderPrefix> {
    let mut c = Cursor { d: data, o: 0 };
    ensure!(c.raw(8)? == MAGIC, "bad magic");
    let version = c.u32()?;
    ensure!(version == VERSION, "unsupported version {version}");
    let kind = Kind::from_u8(c.u8()?)?;
    let n = c.u8()?;
    let h = c.u8()?;
    let act_bits = c.u8()?;
    let name = c.str()?;
    let meta = c.str()?;
    let num_tensors = c.u32()? as usize;
    ensure!(num_tensors < 100_000, "unreasonable tensor count {num_tensors}");
    let section_b_offset = c.u64()?;
    Ok(HeaderPrefix {
        kind,
        n,
        h,
        act_bits,
        name,
        meta,
        num_tensors,
        section_b_offset,
        consumed: c.o,
    })
}

/// Validate header-derived section geometry against the payload length
/// (file minus any trailer).
fn check_section_geometry(kind: Kind, section_b_offset: u64, payload_len: u64) -> Result<()> {
    ensure!(
        section_b_offset <= payload_len,
        "section B offset {section_b_offset} beyond payload length {payload_len}"
    );
    if kind == Kind::Nest {
        ensure!(section_b_offset > 0, "nest container without section B");
    } else {
        ensure!(section_b_offset == 0, "non-nest container with section B");
    }
    Ok(())
}

/// Build a [`SectionIndex`] for a whole container already in memory
/// (the `store::MemorySource` path; no file I/O).
pub(crate) fn index_of_bytes(data: &[u8]) -> Result<SectionIndex> {
    let file_len = data.len() as u64;
    let (payload, checksums) = split_trailer(data);
    let p = parse_prefix(payload)?;
    check_section_geometry(p.kind, p.section_b_offset, payload.len() as u64)?;
    Ok(SectionIndex {
        kind: p.kind,
        n: p.n,
        h: p.h,
        act_bits: p.act_bits,
        name: p.name,
        section_b_offset: p.section_b_offset,
        file_len,
        checksums,
    })
}

/// Probe a `.nq` file's section layout by reading only the header prefix
/// (a few KB), never the tensor payloads. This is the random-access entry
/// point the fleet distribution layer uses to serve section reads for
/// containers it has not (and will not) fully load.
#[deprecated(note = "use `store::FileSource::index` (memoized) or `store::NqArchive::index`")]
pub fn probe(path: &Path) -> Result<SectionIndex> {
    probe_impl(path)
}

pub(crate) fn probe_impl(path: &Path) -> Result<SectionIndex> {
    let file_len = std::fs::metadata(path)
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    let f = std::fs::File::open(path)?;
    // the integrity trailer (when present) lives in the final 24 bytes;
    // one positioned read detects it without touching payloads
    let checksums = if file_len >= TRAILER_LEN as u64 {
        let mut tail = [0u8; TRAILER_LEN];
        read_exact_at(&f, &mut tail, file_len - TRAILER_LEN as u64)
            .with_context(|| format!("reading trailer of {}", path.display()))?;
        split_trailer_tail(&tail)
    } else {
        None
    };
    let payload_len = file_len - if checksums.is_some() { TRAILER_LEN as u64 } else { 0 };
    let mut buf: Vec<u8> = Vec::new();
    let mut want: usize = 4096;
    // name + meta are each < 1 MiB, so a legal header prefix fits well
    // inside this window; anything needing more is corrupt.
    const MAX_HEADER_WINDOW: usize = 4 << 20;
    loop {
        // extend the window to `want` bytes (or EOF); positioned reads —
        // probing never moves a shared cursor
        let target = want.min(file_len as usize);
        if buf.len() < target {
            let old = buf.len();
            buf.resize(target, 0);
            read_exact_at(&f, &mut buf[old..], old as u64)
                .with_context(|| format!("reading header of {}", path.display()))?;
        }
        match parse_prefix(&buf) {
            Ok(p) => {
                check_section_geometry(p.kind, p.section_b_offset, payload_len)?;
                return Ok(SectionIndex {
                    kind: p.kind,
                    n: p.n,
                    h: p.h,
                    act_bits: p.act_bits,
                    name: p.name,
                    section_b_offset: p.section_b_offset,
                    file_len,
                    checksums,
                });
            }
            // grow ONLY on truncation (header longer than the window);
            // any other parse error — bad magic, bad version — is final,
            // so a stray non-container file never gets slurped whole
            Err(e)
                if e.to_string().contains(TRUNCATED)
                    && buf.len() < file_len as usize
                    && want < MAX_HEADER_WINDOW =>
            {
                want *= 2;
            }
            Err(e) => return Err(e.context(format!("probing {}", path.display()))),
        }
    }
}

/// Positioned read: never touches the handle's seek cursor, so concurrent
/// section reads on one file never race (the fleet server's disk path).
#[cfg(unix)]
pub(crate) fn read_exact_at(f: &std::fs::File, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
    use std::os::unix::fs::FileExt;
    f.read_exact_at(buf, offset)
}

/// Non-unix fallback: seek a *private clone* of the handle so the
/// caller's descriptor keeps positioned-read semantics.
#[cfg(not(unix))]
pub(crate) fn read_exact_at(f: &std::fs::File, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
    use std::io::{Read, Seek, SeekFrom};
    let mut f = f.try_clone()?;
    f.seek(SeekFrom::Start(offset))?;
    f.read_exact(buf)
}

/// Read an arbitrary byte range from a container file (pread-style random
/// access; the fleet section cache's disk path).
#[deprecated(note = "use `store::FileSource::fetch` for section reads, or \
                     `store::read_file_range` for raw ranges")]
pub fn read_range(path: &Path, range: std::ops::Range<u64>) -> Result<Vec<u8>> {
    read_range_impl(path, range)
}

pub(crate) fn read_range_impl(path: &Path, range: std::ops::Range<u64>) -> Result<Vec<u8>> {
    ensure!(range.start <= range.end, "inverted range");
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let len = (range.end - range.start) as usize;
    let mut out = vec![0u8; len];
    read_exact_at(&f, &mut out, range.start).with_context(|| {
        format!(
            "reading [{}, {}) of {}",
            range.start,
            range.end,
            path.display()
        )
    })?;
    Ok(out)
}

/// Build a deterministic synthetic nest container: `rows x channels`
/// quantized weights plus an fp32 bias, fully populated (w_low present)
/// and ready to [`write`]/[`serialize`]. Used by the fleet demo, benches,
/// and every artifact-independent test. Requires `2 <= h < n <= 16` so
/// both sections pack.
pub fn synthetic_nest(seed: u64, n: u8, h: u8, rows: usize, channels: usize) -> Result<Container> {
    ensure!(h >= 2 && h < n && n <= 16, "need 2 <= h < n <= 16, got n={n} h={h}");
    let mut rng = crate::util::prng::Rng::new(seed);
    let w: Vec<f32> = (0..rows * channels)
        .map(|_| (rng.normal() * 0.4) as f32)
        .collect();
    let scales = crate::quant::channel_scales(&w, channels, n)?;
    let w_int = crate::quant::quantize_adaptive(&w, &scales, n);
    let cfg = crate::nest::NestConfig::new(n, h)?;
    let wh = crate::quant::nest_high(&w_int, channels, cfg, crate::quant::NestMethod::Adaptive);
    let wl: Vec<i32> = w_int
        .iter()
        .zip(&wh)
        .map(|(&wi, &whv)| crate::nest::low_of(wi, whv, cfg, true))
        .collect();
    let bias: Vec<f32> = (0..channels).map(|_| rng.f32()).collect();
    Ok(Container {
        kind: Kind::Nest,
        n,
        h,
        act_bits: n,
        name: format!("synthetic_{seed}"),
        meta: format!("{{\"seed\":{seed}}}"),
        tensors: vec![
            Tensor {
                name: "layer.w".into(),
                shape: vec![rows, channels],
                data: TensorData::Nest {
                    scales,
                    w_high: PackedTensor::pack(&wh, h)?,
                    w_low: Some(PackedTensor::pack(&wl, cfg.low_bits())?),
                },
            },
            Tensor {
                name: "layer.b".into(),
                shape: vec![channels],
                data: TensorData::Fp32(bias),
            },
        ],
        section_b_offset: 0,
        file_len: 0,
    })
}

// ---------------------------------------------------------------------------
// writing
// ---------------------------------------------------------------------------

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_packed(out: &mut Vec<u8>, t: &PackedTensor) {
    out.push(t.bits());
    out.extend_from_slice(&(t.words().len() as u32).to_le_bytes());
    for w in t.words() {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

/// Serialize a container to bytes (the Python writer's exact layout).
pub fn serialize(c: &Container) -> Result<Vec<u8>> {
    let mut head = Vec::new();
    head.extend_from_slice(MAGIC);
    head.extend_from_slice(&VERSION.to_le_bytes());
    head.extend_from_slice(&[c.kind.as_u8(), c.n, c.h, c.act_bits]);
    put_str(&mut head, &c.name);
    put_str(&mut head, &c.meta);
    head.extend_from_slice(&(c.tensors.len() as u32).to_le_bytes());

    let mut sec_a = Vec::new();
    let mut sec_b = Vec::new();
    for t in &c.tensors {
        put_str(&mut sec_a, &t.name);
        let ptype = match &t.data {
            TensorData::Fp32(_) => 1u8,
            _ => 0,
        };
        sec_a.push(ptype);
        sec_a.push(t.shape.len() as u8);
        for &d in &t.shape {
            sec_a.extend_from_slice(&(d as u32).to_le_bytes());
        }
        match &t.data {
            TensorData::Fp32(vals) => {
                ensure!(vals.len() == t.count(), "{}: fp32 len mismatch", t.name);
                for v in vals {
                    sec_a.extend_from_slice(&v.to_le_bytes());
                }
            }
            TensorData::Nest {
                scales,
                w_high,
                w_low,
            } => {
                ensure!(c.kind == Kind::Nest, "nest tensor in non-nest container");
                sec_a.extend_from_slice(&(scales.len() as u32).to_le_bytes());
                for s in scales {
                    sec_a.extend_from_slice(&s.to_le_bytes());
                }
                put_packed(&mut sec_a, w_high);
                let low = w_low
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("{}: missing w_low for write", t.name))?;
                put_packed(&mut sec_b, low);
            }
            TensorData::Mono { scales, w_int } => {
                ensure!(c.kind == Kind::Mono, "mono tensor in non-mono container");
                sec_a.extend_from_slice(&(scales.len() as u32).to_le_bytes());
                for s in scales {
                    sec_a.extend_from_slice(&s.to_le_bytes());
                }
                put_packed(&mut sec_a, w_int);
            }
        }
    }

    let off = if sec_b.is_empty() {
        0u64
    } else {
        (head.len() + 8 + sec_a.len()) as u64
    };
    let mut out = head;
    out.extend_from_slice(&off.to_le_bytes());
    out.extend_from_slice(&sec_a);
    let a_crc = crc64(&out);
    out.extend_from_slice(&sec_b);
    // integrity trailer: per-section CRC-64/XZ, verified at archive
    // fetch time and after fleet reassembly (readers accept its absence)
    out.extend_from_slice(&encode_trailer(SectionChecksums {
        a: a_crc,
        b: crc64(&sec_b),
    }));
    Ok(out)
}

/// Write a container file; returns (total, section_a, section_b) bytes.
/// `total` is the on-disk file length — section bytes plus the
/// [`TRAILER_LEN`]-byte integrity trailer.
pub fn write(path: &Path, c: &Container) -> Result<(u64, u64, u64)> {
    let bytes = serialize(c)?;
    let total = bytes.len() as u64;
    let payload = total - TRAILER_LEN as u64;
    let sec_b = if c.kind == Kind::Nest {
        let mut n = 0u64;
        for t in &c.tensors {
            if let TensorData::Nest { w_low: Some(l), .. } = &t.data {
                n += 5 + l.nbytes() as u64; // u8 bits + u32 nwords + words
            }
        }
        n
    } else {
        0
    };
    let mut f = std::fs::File::create(path)?;
    f.write_all(&bytes)?;
    Ok((total, payload - sec_b, sec_b))
}

/// Ideal (paper §4.3.3) byte split for a nest container of `counts`
/// quantized elements: D_high ≈ h/(h+l+1)·D, D_low ≈ (l+1)/(h+l+1)·D.
pub fn ideal_split(counts: &[usize], n: u8, h: u8) -> (u64, u64) {
    let l1 = n - h + 1;
    let mut hi = 0u64;
    let mut lo = 0u64;
    for &c in counts {
        hi += packed_nbytes(c, h) as u64;
        lo += packed_nbytes(c, l1) as u64;
    }
    (hi, lo)
}

#[cfg(test)]
#[allow(deprecated)] // the shims must keep working for out-of-tree callers
mod tests {
    use super::*;
    use crate::nest;
    use crate::quant;
    use crate::util::prng::Rng;

    fn toy_container(seed: u64, n: u8, h: u8) -> Container {
        synthetic_nest(seed, n, h, 40, 6).unwrap()
    }

    #[test]
    fn roundtrip_nest() {
        let c = toy_container(1, 8, 4);
        let bytes = serialize(&c).unwrap();
        let back = parse(&bytes, false).unwrap();
        assert_eq!(back.kind, Kind::Nest);
        assert_eq!((back.n, back.h, back.act_bits), (8, 4, 8));
        assert_eq!(back.name, "synthetic_1");
        assert_eq!(back.tensors.len(), 2);
        match (&c.tensors[0].data, &back.tensors[0].data) {
            (
                TensorData::Nest {
                    scales: s1,
                    w_high: h1,
                    w_low: Some(l1),
                },
                TensorData::Nest {
                    scales: s2,
                    w_high: h2,
                    w_low: Some(l2),
                },
            ) => {
                assert_eq!(s1, s2);
                assert_eq!(h1.unpack(), h2.unpack());
                assert_eq!(l1.unpack(), l2.unpack());
            }
            _ => panic!("wrong payload"),
        }
    }

    #[test]
    fn part_bit_read_stops_at_section_a() {
        let c = toy_container(2, 8, 5);
        let bytes = serialize(&c).unwrap();
        let part = parse(&bytes, true).unwrap();
        match &part.tensors[0].data {
            TensorData::Nest { w_low, .. } => assert!(w_low.is_none()),
            _ => panic!(),
        }
        assert!(part.section_b_offset > 0);
        // A ++ B is the payload; the trailer rides after it
        assert_eq!(
            part.section_a_bytes() + part.section_b_bytes(),
            (bytes.len() - TRAILER_LEN) as u64
        );
    }

    #[test]
    fn section_b_attach_after_part_read() {
        let c = toy_container(3, 6, 4);
        let bytes = serialize(&c).unwrap();
        let mut part = parse(&bytes, true).unwrap();
        let off = part.section_b_offset as usize;
        let payload_end = bytes.len() - TRAILER_LEN;
        attach_section_b(&mut part, &bytes[off..payload_end]).unwrap();
        match &part.tensors[0].data {
            TensorData::Nest {
                w_low: Some(l), ..
            } => {
                assert_eq!(l.bits(), 3); // 6-4+1
            }
            _ => panic!(),
        }
    }

    #[test]
    fn recompose_from_container_is_lossless() {
        let n = 8;
        let h = 4;
        let c = toy_container(4, n, h);
        let bytes = serialize(&c).unwrap();
        let back = parse(&bytes, false).unwrap();
        if let TensorData::Nest {
            w_high,
            w_low: Some(w_low),
            ..
        } = &back.tensors[0].data
        {
            let hs = w_high.unpack();
            let ls = w_low.unpack();
            let mut rec = Vec::new();
            nest::recompose_into(&hs, &ls, n - h, &mut rec);
            // every value must be a valid INTn
            let (lo, hi) = crate::bits::int_range(n);
            assert!(rec.iter().all(|&v| v >= lo && v <= hi));
        } else {
            panic!()
        }
    }

    #[test]
    fn corruption_rejected() {
        let c = toy_container(5, 8, 4);
        let mut bytes = serialize(&c).unwrap();
        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(parse(&bad, false).is_err());
        // truncation (anywhere) must error, not panic
        for cut in [10, 40, bytes.len() / 2, bytes.len() - 3] {
            assert!(parse(&bytes[..cut], false).is_err(), "cut={cut}");
        }
        // a payload bit flip is caught by the trailer checksum (the
        // geometry walk alone cannot see it)
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x10;
        let err = parse(&flipped, false).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        // version bump
        bytes[8] = 99;
        assert!(parse(&bytes, false).is_err());
    }

    #[test]
    fn trailer_roundtrip_and_absence() {
        let c = toy_container(31, 8, 4);
        let bytes = serialize(&c).unwrap();
        let (payload, ck) = split_trailer(&bytes);
        let ck = ck.expect("writer emits the trailer");
        let off = c_off(&bytes);
        assert_eq!(ck.a, crc64(&payload[..off]));
        assert_eq!(ck.b, crc64(&payload[off..]));
        // a pre-trailer artifact (payload only) still parses — with no
        // checksums in its index
        let legacy = parse(payload, false).unwrap();
        assert_eq!(legacy.tensors.len(), 2);
        let idx = index_of_bytes(payload).unwrap();
        assert!(idx.checksums.is_none());
        assert_eq!(idx.trailer_len(), 0);
        assert_eq!(idx.payload_len(), payload.len() as u64);
        // and the trailered form indexes with checksums + payload math
        let idx = index_of_bytes(&bytes).unwrap();
        assert_eq!(idx.checksums, Some(ck));
        assert_eq!(idx.trailer_len(), TRAILER_LEN as u64);
        assert_eq!(idx.payload_len(), payload.len() as u64);
        assert_eq!(idx.section_b().end, payload.len() as u64);
    }

    /// Section-B offset of serialized bytes (test helper).
    fn c_off(bytes: &[u8]) -> usize {
        let p = parse_prefix(bytes).unwrap();
        p.section_b_offset as usize
    }

    #[test]
    fn file_roundtrip_with_section_b_read() {
        let dir = std::env::temp_dir().join("nq_container_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.nq");
        let c = toy_container(6, 8, 6);
        let (total, a, b) = write(&path, &c).unwrap();
        assert_eq!(total, a + b + TRAILER_LEN as u64);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), total);
        let mut part = read(&path, true).unwrap();
        let paged = read_section_b(&path, &mut part).unwrap();
        assert_eq!(paged, b);
        match &part.tensors[0].data {
            TensorData::Nest { w_low: Some(_), .. } => {}
            _ => panic!("w_low not attached"),
        }
    }

    #[test]
    fn probe_matches_full_parse_and_reads_header_only() {
        let dir = std::env::temp_dir().join(format!("nq_probe_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("probe.nq");
        let c = toy_container(11, 8, 4);
        let (total, a, b) = write(&path, &c).unwrap();
        let idx = probe(&path).unwrap();
        assert_eq!(idx.kind, Kind::Nest);
        assert_eq!((idx.n, idx.h, idx.act_bits), (8, 4, 8));
        assert_eq!(idx.name, "synthetic_11");
        assert_eq!(idx.file_len, total);
        assert_eq!(idx.section_a_bytes(), a);
        assert_eq!(idx.section_b_bytes(), b);
        let full = read(&path, true).unwrap();
        assert_eq!(idx.section_b_offset, full.section_b_offset);
    }

    #[test]
    fn read_range_section_bytes_match_full_file() {
        let dir = std::env::temp_dir().join(format!("nq_range_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("range.nq");
        let c = toy_container(12, 8, 5);
        write(&path, &c).unwrap();
        let whole = std::fs::read(&path).unwrap();
        let idx = probe(&path).unwrap();
        let a = read_range(&path, idx.section_a()).unwrap();
        let b = read_range(&path, idx.section_b()).unwrap();
        // sections tile the payload; the trailer is the remaining tail
        assert_eq!(a.len() as u64 + b.len() as u64, idx.payload_len());
        assert_eq!(idx.payload_len() + idx.trailer_len(), idx.file_len);
        assert_eq!(&whole[..a.len()], &a[..]);
        assert_eq!(&whole[a.len()..a.len() + b.len()], &b[..]);
        // a section-A blob parses as a part-bit container on its own
        let part = parse(&a, true).unwrap();
        assert_eq!(part.n, 8);
        // and the section-B blob attaches to it losslessly
        let mut part2 = parse(&a, true).unwrap();
        // parse() sets file_len to the blob length; restore the payload
        part2.file_len = idx.payload_len();
        attach_section_b(&mut part2, &b).unwrap();
        match &part2.tensors[0].data {
            TensorData::Nest { w_low: Some(_), .. } => {}
            _ => panic!("w_low not attached from ranged read"),
        }
        // out-of-bounds ranges error
        assert!(read_range(&path, 0..idx.file_len + 1).is_err());
    }

    #[test]
    fn mono_and_fp32_roundtrip() {
        let mut rng = Rng::new(7);
        let w: Vec<f32> = (0..64).map(|_| rng.f32() - 0.5).collect();
        let scales = quant::channel_scales(&w, 4, 4).unwrap();
        let wi = quant::quantize_rtn(&w, &scales, 4);
        let mono = Container {
            kind: Kind::Mono,
            n: 4,
            h: 0,
            act_bits: 4,
            name: "m".into(),
            meta: String::new(),
            tensors: vec![Tensor {
                name: "w".into(),
                shape: vec![16, 4],
                data: TensorData::Mono {
                    scales,
                    w_int: PackedTensor::pack(&wi, 4).unwrap(),
                },
            }],
            section_b_offset: 0,
            file_len: 0,
        };
        let bytes = serialize(&mono).unwrap();
        let back = parse(&bytes, false).unwrap();
        assert_eq!(back.kind, Kind::Mono);
        match &back.tensors[0].data {
            TensorData::Mono { w_int, .. } => assert_eq!(w_int.unpack(), wi),
            _ => panic!(),
        }

        let fp = Container {
            kind: Kind::Fp32,
            n: 0,
            h: 0,
            act_bits: 0,
            name: "f".into(),
            meta: String::new(),
            tensors: vec![Tensor {
                name: "w".into(),
                shape: vec![64],
                data: TensorData::Fp32(w.clone()),
            }],
            section_b_offset: 0,
            file_len: 0,
        };
        let bytes = serialize(&fp).unwrap();
        let back = parse(&bytes, false).unwrap();
        match &back.tensors[0].data {
            TensorData::Fp32(vals) => assert_eq!(vals, &w),
            _ => panic!(),
        }
    }

    #[test]
    fn ideal_split_proportions() {
        // INT(8|6): D_high/D ≈ 6/9, D_low/D ≈ 3/9 (±packing roundup)
        let counts = vec![64 * 21]; // multiple of every lane count used
        let (hi, lo) = ideal_split(&counts, 8, 6);
        let total = (hi + lo) as f64;
        assert!((hi as f64 / total - 6.0 / 9.0).abs() < 0.02);
        assert!((lo as f64 / total - 3.0 / 9.0).abs() < 0.02);
    }
}
