//! Diverse-bitwidths baseline: the deployment the paper compares against
//! (Fig 1, Tables 9–11) — one monolithic packed INTk container per
//! bitwidth, switching by full unload + full load.
//!
//! NestQuant's win is exactly that this baseline pays `size(INTa)`
//! page-out plus `size(INTb)` page-in per switch, while NestQuant moves
//! only section B. Access goes through the store like everything else —
//! each switch fetches the whole archive and releases it again, so the
//! archive's `a_fetches` counter *is* the baseline's re-read count.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::container::Kind;
use crate::device::MemoryLedger;
use crate::runtime::{Engine, Executable, ModelSpec};
use crate::store::{ModelStore, NqArchive, PayloadView};

use super::manager::SwitchCost;

/// Diverse-bitwidths deployment of one architecture: a set of monolithic
/// INTk models, at most one resident at a time.
pub struct DiverseBitwidths {
    spec: ModelSpec,
    engine: Engine,
    exe: Executable,
    /// bits → (archive, file bytes)
    models: BTreeMap<u8, (Arc<NqArchive>, u64)>,
    active: Option<u8>,
    weight_bufs: Vec<crate::runtime::DeviceBuffer>,
}

impl DiverseBitwidths {
    /// `bits` selects which INTk containers to register.
    pub fn new(
        engine: &Engine,
        spec: ModelSpec,
        act_bits: u8,
        artifacts_root: &std::path::Path,
        bits: &[u8],
    ) -> Result<DiverseBitwidths> {
        let hlo_rel = spec
            .hlo
            .get(&act_bits)
            .ok_or_else(|| anyhow::anyhow!("no a{act_bits} HLO for {}", spec.name))?;
        let exe = engine.load_hlo(&artifacts_root.join(hlo_rel))?;
        let mut models = BTreeMap::new();
        for &k in bits {
            let rel = spec
                .mono_containers
                .get(&k)
                .ok_or_else(|| anyhow::anyhow!("no INT{k} container for {}", spec.name))?;
            let archive = ModelStore::global().open_path(artifacts_root.join(rel))?;
            ensure!(
                archive.kind() == Kind::Mono,
                "baseline requires mono containers, got {:?} for INT{k}",
                archive.kind()
            );
            // payload bytes only: the integrity trailer is never
            // fetched, and the ledger must match the moved bytes
            let bytes = archive.index().payload_len();
            models.insert(k, (archive, bytes));
        }
        Ok(DiverseBitwidths {
            spec,
            engine: engine.clone(),
            exe,
            models,
            active: None,
            weight_bufs: Vec::new(),
        })
    }

    pub fn active(&self) -> Option<u8> {
        self.active
    }

    pub fn model_bytes(&self, bits: u8) -> Option<u64> {
        self.models.get(&bits).map(|(_, b)| *b)
    }

    /// Total storage the baseline consumes on disk (all bitwidths).
    pub fn total_storage(&self) -> u64 {
        self.models.values().map(|(_, b)| *b).sum()
    }

    /// Switch to the INTk model: page out the active one entirely, page
    /// in the new one entirely (the Fig 1 deployment's cost model). The
    /// archive is released afterwards, so every switch is a real full
    /// re-fetch — the cost NestQuant's sectioned archive avoids.
    pub fn switch_to(&mut self, bits: u8, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let t0 = Instant::now();
        let (archive, in_bytes) = self
            .models
            .get(&bits)
            .map(|(a, b)| (Arc::clone(a), *b))
            .ok_or_else(|| anyhow::anyhow!("INT{bits} not registered"))?;
        let mut out_bytes = 0;
        if let Some(cur) = self.active {
            let (_, b) = self.models[&cur];
            ledger.page_out(b).context("baseline page-out")?;
            out_bytes = b;
            self.weight_bufs.clear();
        }
        ledger.page_in(in_bytes).context("baseline page-in")?;
        let model = archive.part_bit()?; // mono: section A is the whole model
        let mut bufs = Vec::with_capacity(model.len());
        let mut scratch_scales = Vec::new();
        let mut scratch_f32 = Vec::new();
        for (view, spec) in model.tensors().zip(&self.spec.params) {
            ensure!(view.name() == spec.name, "tensor order mismatch");
            match view.payload() {
                PayloadView::Fp32(vals) => {
                    vals.read_into(&mut scratch_f32);
                }
                PayloadView::Mono { scales, w_int } => {
                    // fused one-pass decode (scale_mul = 1: mono scales
                    // are exact, no inflation)
                    scales.read_into(&mut scratch_scales);
                    w_int.unpack_dequant_into(&scratch_scales, 1.0, &mut scratch_f32);
                }
                PayloadView::Nest { .. } => bail!("nest tensor in mono container"),
            }
            bufs.push(self.engine.upload(&scratch_f32, &spec.shape)?);
        }
        drop(model);
        archive.release_a(); // the baseline holds nothing between switches
        self.weight_bufs = bufs;
        self.active = Some(bits);
        Ok(SwitchCost {
            page_in_bytes: in_bytes,
            page_out_bytes: out_bytes,
            micros: t0.elapsed().as_micros(),
        })
    }

    /// Run a padded batch through the active model.
    pub fn infer(
        &self,
        batch: &[f32],
        batch_size: usize,
        img: usize,
        channels: usize,
    ) -> Result<Vec<f32>> {
        ensure!(self.active.is_some(), "no active baseline model");
        let x = self.engine.upload(batch, &[batch_size, img, img, channels])?;
        self.exe.run(&x, &self.weight_bufs)
    }
}
