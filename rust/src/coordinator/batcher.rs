//! Dynamic batcher: collect inference requests into fixed-size padded
//! batches (the AOT executables are shape-specialized at `batch`).
//!
//! vLLM-router-style behaviour at IoT scale: a batch closes when it is
//! full OR when the oldest request has waited `max_wait`; partial batches
//! are zero-padded (safe: zero rows cannot raise the dynamic activation
//! scale — see python/tests/test_backends.py).

use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One queued request: the flattened image + a reply channel.
pub struct Request {
    pub image: Vec<f32>,
    pub reply: mpsc::Sender<Reply>,
    pub enqueued: Instant,
}

/// The reply: logits for this image (or an error string).
pub type Reply = Result<Vec<f32>, String>;

/// A closed batch ready for execution.
pub struct Batch {
    /// Zero-padded flattened input, `batch_size * img * img * ch`.
    pub input: Vec<f32>,
    /// The live requests (≤ batch_size), in input order.
    pub requests: Vec<Request>,
    /// Wall time the oldest member waited before the batch closed.
    pub oldest_wait: Duration,
}

/// Batch assembly parameters.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub batch_size: usize,
    pub image_len: usize,
    pub max_wait: Duration,
}

/// Pull requests off `rx` and form one batch. Returns None when the
/// channel is closed and drained. Blocks up to `max_wait` past the first
/// request.
pub fn next_batch(rx: &mpsc::Receiver<Request>, cfg: &BatcherConfig) -> Option<Batch> {
    // Block for the first request.
    let first = rx.recv().ok()?;
    let deadline = Instant::now() + cfg.max_wait;
    let mut requests = vec![first];
    while requests.len() < cfg.batch_size {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => requests.push(r),
            Err(mpsc::RecvTimeoutError::Timeout) => break,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(assemble(requests, cfg))
}

fn assemble(requests: Vec<Request>, cfg: &BatcherConfig) -> Batch {
    let mut input = vec![0f32; cfg.batch_size * cfg.image_len];
    for (i, r) in requests.iter().enumerate() {
        debug_assert_eq!(r.image.len(), cfg.image_len);
        input[i * cfg.image_len..(i + 1) * cfg.image_len].copy_from_slice(&r.image);
    }
    let oldest_wait = requests
        .iter()
        .map(|r| r.enqueued.elapsed())
        .max()
        .unwrap_or_default();
    Batch {
        input,
        requests,
        oldest_wait,
    }
}

/// Distribute logits rows back to the batch's requests.
pub fn respond(batch: Batch, logits: &[f32], num_classes: usize) {
    for (i, r) in batch.requests.into_iter().enumerate() {
        let row = logits[i * num_classes..(i + 1) * num_classes].to_vec();
        let _ = r.reply.send(Ok(row)); // receiver may have gone away
    }
}

/// Fail every request in the batch (executor error path).
pub fn respond_error(batch: Batch, msg: &str) {
    for r in batch.requests {
        let _ = r.reply.send(Err(msg.to_string()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            batch_size: 4,
            image_len: 8,
            max_wait: Duration::from_millis(30),
        }
    }

    fn req(v: f32, tx_reply: &mut Vec<mpsc::Receiver<Reply>>) -> Request {
        let (tx, rx) = mpsc::channel();
        tx_reply.push(rx);
        Request {
            image: vec![v; 8],
            reply: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn full_batch_closes_immediately() {
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        for i in 0..4 {
            tx.send(req(i as f32, &mut replies)).unwrap();
        }
        let t0 = Instant::now();
        let b = next_batch(&rx, &cfg()).unwrap();
        assert!(t0.elapsed() < Duration::from_millis(25), "waited for timeout");
        assert_eq!(b.requests.len(), 4);
        assert_eq!(b.input.len(), 32);
        assert_eq!(&b.input[0..8], &[0.0; 8]);
        assert_eq!(&b.input[24..32], &[3.0; 8]);
    }

    #[test]
    fn partial_batch_closes_on_timeout_and_pads() {
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        tx.send(req(7.0, &mut replies)).unwrap();
        tx.send(req(8.0, &mut replies)).unwrap();
        let b = next_batch(&rx, &cfg()).unwrap();
        assert_eq!(b.requests.len(), 2);
        // padding rows are zero
        assert_eq!(&b.input[16..32], &[0.0; 16]);
    }

    #[test]
    fn never_exceeds_batch_size() {
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        for i in 0..10 {
            tx.send(req(i as f32, &mut replies)).unwrap();
        }
        let b = next_batch(&rx, &cfg()).unwrap();
        assert_eq!(b.requests.len(), 4);
        // the rest remain queued for the next batch
        let b2 = next_batch(&rx, &cfg()).unwrap();
        assert_eq!(b2.requests.len(), 4);
        assert_eq!(&b2.input[0..8], &[4.0; 8]);
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        assert!(next_batch(&rx, &cfg()).is_none());
    }

    #[test]
    fn respond_routes_rows() {
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        tx.send(req(1.0, &mut replies)).unwrap();
        tx.send(req(2.0, &mut replies)).unwrap();
        let b = next_batch(&rx, &cfg()).unwrap();
        let logits: Vec<f32> = (0..4 * 10).map(|i| i as f32).collect();
        respond(b, &logits, 10);
        let r0 = replies[0].recv().unwrap().unwrap();
        let r1 = replies[1].recv().unwrap().unwrap();
        assert_eq!(r0, (0..10).map(|i| i as f32).collect::<Vec<_>>());
        assert_eq!(r1, (10..20).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn no_starvation_under_trickle() {
        // a slow producer: each request must still be answered within
        // ~max_wait, not held until a full batch forms
        let (tx, rx) = mpsc::channel();
        let producer = thread::spawn(move || {
            let mut replies = Vec::new();
            for i in 0..3 {
                let (rtx, rrx) = mpsc::channel();
                replies.push(rrx);
                tx.send(Request {
                    image: vec![i as f32; 8],
                    reply: rtx,
                    enqueued: Instant::now(),
                })
                .unwrap();
                thread::sleep(Duration::from_millis(45)); // > max_wait
            }
            replies
        });
        let mut batches = 0;
        while let Some(b) = next_batch(&rx, &cfg()) {
            assert_eq!(b.requests.len(), 1, "trickle must form singleton batches");
            respond(b, &vec![0.0; 40], 10);
            batches += 1;
        }
        assert_eq!(batches, 3);
        let replies = producer.join().unwrap();
        for r in replies {
            assert!(r.recv().unwrap().is_ok());
        }
    }
}
