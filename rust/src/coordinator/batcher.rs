//! Dynamic batcher: collect inference requests into fixed-size padded
//! batches (the AOT executables are shape-specialized at `batch`).
//!
//! vLLM-router-style behaviour at IoT scale: a batch closes when it is
//! full OR when the oldest request has waited `max_wait`; partial batches
//! are zero-padded (safe: zero rows cannot raise the dynamic activation
//! scale — see python/tests/test_backends.py).

use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One queued request: the flattened image + a reply channel.
pub struct Request {
    pub image: Vec<f32>,
    pub reply: mpsc::Sender<Reply>,
    pub enqueued: Instant,
}

/// The reply: logits for this image (or an error string).
pub type Reply = Result<Vec<f32>, String>;

/// A closed batch ready for execution.
pub struct Batch {
    /// Zero-padded flattened input, `batch_size * img * img * ch`.
    pub input: Vec<f32>,
    /// The live requests (≤ batch_size), in input order.
    pub requests: Vec<Request>,
    /// Wall time the oldest member waited before the batch closed.
    pub oldest_wait: Duration,
}

/// Batch assembly parameters.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub batch_size: usize,
    pub image_len: usize,
    pub max_wait: Duration,
}

/// Pull requests off `rx` and form one batch. Returns None when the
/// channel is closed and drained. Blocks up to `max_wait` past the
/// *oldest member's enqueue time*.
pub fn next_batch(rx: &mpsc::Receiver<Request>, cfg: &BatcherConfig) -> Option<Batch> {
    // Block for the first request.
    let first = rx.recv().ok()?;
    // Fairness on batch close: the deadline anchors at the oldest
    // request's enqueue time, not at pop time. A request that already
    // sat in a backlogged queue for max_wait closes its batch with
    // whatever is immediately available instead of waiting a second
    // full window (total latency ≤ max_wait + one batch execution).
    let deadline = first.enqueued + cfg.max_wait;
    let mut requests = vec![first];
    while requests.len() < cfg.batch_size {
        let now = Instant::now();
        if now >= deadline {
            // past the window: never block again, but DO drain what is
            // already queued — a backlog must ship full batches, not a
            // stream of zero-padded singletons
            match rx.try_recv() {
                Ok(r) => requests.push(r),
                Err(_) => break,
            }
            continue;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => requests.push(r),
            Err(mpsc::RecvTimeoutError::Timeout) => break,
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(assemble(requests, cfg))
}

fn assemble(requests: Vec<Request>, cfg: &BatcherConfig) -> Batch {
    let mut input = vec![0f32; cfg.batch_size * cfg.image_len];
    for (i, r) in requests.iter().enumerate() {
        debug_assert_eq!(r.image.len(), cfg.image_len);
        input[i * cfg.image_len..(i + 1) * cfg.image_len].copy_from_slice(&r.image);
    }
    let oldest_wait = requests
        .iter()
        .map(|r| r.enqueued.elapsed())
        .max()
        .unwrap_or_default();
    Batch {
        input,
        requests,
        oldest_wait,
    }
}

/// Drive one tenant's queue until its channel closes: the per-tenant
/// executor loop of the multi-tenant server. Each hosted model gets its
/// own queue + one `drain_queue` thread, so a flooding tenant can fill
/// its own batches but never delays another tenant's batch close.
pub fn drain_queue(
    rx: &mpsc::Receiver<Request>,
    cfg: &BatcherConfig,
    mut serve: impl FnMut(Batch),
) {
    while let Some(batch) = next_batch(rx, cfg) {
        serve(batch);
    }
}

/// Distribute logits rows back to the batch's requests.
pub fn respond(batch: Batch, logits: &[f32], num_classes: usize) {
    for (i, r) in batch.requests.into_iter().enumerate() {
        let row = logits[i * num_classes..(i + 1) * num_classes].to_vec();
        let _ = r.reply.send(Ok(row)); // receiver may have gone away
    }
}

/// Fail every request in the batch (executor error path).
pub fn respond_error(batch: Batch, msg: &str) {
    for r in batch.requests {
        let _ = r.reply.send(Err(msg.to_string()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            batch_size: 4,
            image_len: 8,
            max_wait: Duration::from_millis(30),
        }
    }

    fn req(v: f32, tx_reply: &mut Vec<mpsc::Receiver<Reply>>) -> Request {
        let (tx, rx) = mpsc::channel();
        tx_reply.push(rx);
        Request {
            image: vec![v; 8],
            reply: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn full_batch_closes_immediately() {
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        for i in 0..4 {
            tx.send(req(i as f32, &mut replies)).unwrap();
        }
        let t0 = Instant::now();
        let b = next_batch(&rx, &cfg()).unwrap();
        assert!(t0.elapsed() < Duration::from_millis(25), "waited for timeout");
        assert_eq!(b.requests.len(), 4);
        assert_eq!(b.input.len(), 32);
        assert_eq!(&b.input[0..8], &[0.0; 8]);
        assert_eq!(&b.input[24..32], &[3.0; 8]);
    }

    #[test]
    fn partial_batch_closes_on_timeout_and_pads() {
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        tx.send(req(7.0, &mut replies)).unwrap();
        tx.send(req(8.0, &mut replies)).unwrap();
        let b = next_batch(&rx, &cfg()).unwrap();
        assert_eq!(b.requests.len(), 2);
        // padding rows are zero
        assert_eq!(&b.input[16..32], &[0.0; 16]);
    }

    #[test]
    fn never_exceeds_batch_size() {
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        for i in 0..10 {
            tx.send(req(i as f32, &mut replies)).unwrap();
        }
        let b = next_batch(&rx, &cfg()).unwrap();
        assert_eq!(b.requests.len(), 4);
        // the rest remain queued for the next batch
        let b2 = next_batch(&rx, &cfg()).unwrap();
        assert_eq!(b2.requests.len(), 4);
        assert_eq!(&b2.input[0..8], &[4.0; 8]);
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        assert!(next_batch(&rx, &cfg()).is_none());
    }

    #[test]
    fn respond_routes_rows() {
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        tx.send(req(1.0, &mut replies)).unwrap();
        tx.send(req(2.0, &mut replies)).unwrap();
        let b = next_batch(&rx, &cfg()).unwrap();
        let logits: Vec<f32> = (0..4 * 10).map(|i| i as f32).collect();
        respond(b, &logits, 10);
        let r0 = replies[0].recv().unwrap().unwrap();
        let r1 = replies[1].recv().unwrap().unwrap();
        assert_eq!(r0, (0..10).map(|i| i as f32).collect::<Vec<_>>());
        assert_eq!(r1, (10..20).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn backlogged_request_is_not_double_waited() {
        // A request that already waited ≥ max_wait in the queue must
        // close its batch immediately on pop, not wait another window.
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        tx.send(req(1.0, &mut replies)).unwrap();
        thread::sleep(Duration::from_millis(40)); // > max_wait of 30ms
        let t0 = Instant::now();
        let b = next_batch(&rx, &cfg()).unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(20),
            "deadline must anchor at enqueue time, waited {:?}",
            t0.elapsed()
        );
        assert_eq!(b.requests.len(), 1);
    }

    #[test]
    fn backlog_ships_full_batches_not_singletons() {
        // A queue that built up while the executor was busy: the stale
        // deadline must not close size-1 batches while >= batch_size
        // requests sit ready in the channel.
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        for i in 0..8 {
            tx.send(req(i as f32, &mut replies)).unwrap();
        }
        thread::sleep(Duration::from_millis(40)); // all now past max_wait
        let t0 = Instant::now();
        let b1 = next_batch(&rx, &cfg()).unwrap();
        let b2 = next_batch(&rx, &cfg()).unwrap();
        assert!(t0.elapsed() < Duration::from_millis(20), "backlog must not re-wait");
        assert_eq!(b1.requests.len(), 4, "first backlog batch full");
        assert_eq!(b2.requests.len(), 4, "second backlog batch full");
        assert_eq!(&b2.input[0..8], &[4.0; 8], "order preserved across batches");
    }

    #[test]
    fn drain_queue_serves_every_request_then_exits() {
        let (tx, rx) = mpsc::channel();
        let mut replies = Vec::new();
        for i in 0..9 {
            tx.send(req(i as f32, &mut replies)).unwrap();
        }
        drop(tx);
        let mut served = 0usize;
        drain_queue(&rx, &cfg(), |b| {
            served += b.requests.len();
            respond(b, &vec![0.0; 40], 10);
        });
        assert_eq!(served, 9);
        for r in &replies {
            assert!(r.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn no_starvation_under_trickle() {
        // a slow producer: each request must still be answered within
        // ~max_wait, not held until a full batch forms
        let (tx, rx) = mpsc::channel();
        let producer = thread::spawn(move || {
            let mut replies = Vec::new();
            for i in 0..3 {
                let (rtx, rrx) = mpsc::channel();
                replies.push(rrx);
                tx.send(Request {
                    image: vec![i as f32; 8],
                    reply: rtx,
                    enqueued: Instant::now(),
                })
                .unwrap();
                thread::sleep(Duration::from_millis(45)); // > max_wait
            }
            replies
        });
        let mut batches = 0;
        while let Some(b) = next_batch(&rx, &cfg()) {
            assert_eq!(b.requests.len(), 1, "trickle must form singleton batches");
            respond(b, &vec![0.0; 40], 10);
            batches += 1;
        }
        assert_eq!(batches, 3);
        let replies = producer.join().unwrap();
        for r in replies {
            assert!(r.recv().unwrap().is_ok());
        }
    }
}
