//! ModelManager: the on-device NestQuant switching mechanism (§3.3),
//! rebuilt on the [`crate::store`] access layer.
//!
//! Holds one shared [`NqArchive`] and the compiled executable for its
//! architecture, and realizes the paper's three switch transitions:
//!
//! * **part-bit launch** — fetch section A once; dequantize `w_high`
//!   straight from the archive bytes with the inflated scale `s·2^l`
//!   (Eq. 10).
//! * **upgrade** — attach section B (the only bytes moved), recompose
//!   `w_int = w_high·2^l + w_low` (Eq. 6), dequantize with `s`.
//!   Zero page-out. **Zero section-A re-reads and zero container
//!   re-parses** — the archive's byte accounting proves it
//!   (`tests/store.rs`).
//! * **downgrade** — release the section-B `Arc` and the full-bit
//!   weights; the part-bit weights rebuild from the still-resident
//!   section-A bytes. Zero page-in.
//!
//! Memory accounting follows the paper's convention (§4.3.3): the ledger
//! tracks *packed* bytes (what a packed-int runtime holds). The PJRT CPU
//! backend computes in f32, so dequantized buffers exist at the XLA
//! boundary exactly as the paper's PyTorch deployment dequantizes for
//! compute; the packed accounting is what Table 11 reports.
//!
//! Hot path: weights live as device-resident PJRT buffers, rebuilt only
//! on a switch; a request uploads just its input batch. The decode path
//! is one fused pass per tensor: packed words stream from the archive's
//! `Arc<[u8]>` sections straight into dequantized f32s
//! (`crate::kernels` — no i32 intermediates), and tensors decode in
//! parallel across scoped threads so a multi-tensor switch is bounded
//! by memory bandwidth, not one core.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::container::Kind;
use crate::device::MemoryLedger;
use crate::nest;
use crate::runtime::{Engine, Executable, ModelSpec, ParamSpec};
use crate::store::{NqArchive, PayloadView, StoreBudget, TensorView};

/// Which weights are currently active.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Part-bit model: INTh weights at scale s·2^l.
    PartBit,
    /// Full-bit model: recomposed INTn weights at scale s.
    FullBit,
}

/// Latency + byte cost of one switch operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwitchCost {
    pub page_in_bytes: u64,
    pub page_out_bytes: u64,
    pub micros: u128,
}

/// The manager's observable state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    Unloaded,
    Active(Variant),
}

/// One model's switching state machine + weight materialization.
pub struct ModelManager {
    spec: ModelSpec,
    engine: Engine,
    exe: Executable,
    /// Shared handle to the `.nq` artifact; owns the section bytes.
    archive: Arc<NqArchive>,
    /// When set, section-B residency routes through a shared budget:
    /// upgrades may evict other tenants' B sections, and this manager's
    /// own B may be evicted between batches (already-materialized
    /// weight buffers stay valid — only the packed bytes are reclaimed).
    budget: Option<(String, Arc<StoreBudget>)>,
    /// Packed section sizes (bytes) for ledger accounting.
    sec_a_bytes: u64,
    sec_b_bytes: u64,
    /// Device-resident weight buffers for the active variant.
    weight_bufs: Vec<crate::runtime::DeviceBuffer>,
    /// Cached part-bit buffers. Legitimate: they derive only from w_high
    /// (+ scales), which stays resident in BOTH states by design — so a
    /// downgrade becomes a pointer swap instead of an unpack+dequant+
    /// upload pass (§Perf L3). Full-bit buffers are never cached across a
    /// downgrade: they derive from the paged-out w_low.
    part_bufs: Vec<crate::runtime::DeviceBuffer>,
    state: State,
    /// Per-worker decode slots (one wave's worth, ≤ `decode_workers`) —
    /// the single scratch that replaced the old high/low/int triple.
    /// The f32 payloads are transient (released after each wave's
    /// upload, so only the packed sections stay resident between — and
    /// during — switches); the slot vector and the small scales buffers
    /// persist.
    decode_slots: Vec<DecodeSlot>,
}

/// One tensor's decode buffers. Each worker thread owns one slot
/// exclusively during a wave; `f32s` lives only from decode to upload.
#[derive(Default)]
struct DecodeSlot {
    f32s: Vec<f32>,
    scales: Vec<f32>,
}

/// Worker threads for the per-tensor decode fan-out: one per tensor up
/// to the machine's parallelism. The cap bounds what *one* switch can
/// grab (the fused kernels go bandwidth-bound well before high core
/// counts); it is per-manager, so N managers switching at the same
/// instant can still hold N·cap threads — a process-global decode pool
/// is future work if zoo-scale concurrent switching shows up in traces.
fn decode_workers(tensors: usize) -> usize {
    if tensors < 2 {
        return 1;
    }
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(tensors)
        .min(8)
}

/// Decode one tensor's payload into `slot.f32s` through the fused
/// one-pass kernels. Free function so scoped workers borrow only their
/// own slot, never the manager.
fn decode_tensor(
    view: &TensorView<'_>,
    spec: &ParamSpec,
    variant: Variant,
    cfg: nest::NestConfig,
    slot: &mut DecodeSlot,
) -> Result<()> {
    ensure!(
        view.name() == spec.name,
        "tensor order: {} vs {}",
        view.name(),
        spec.name
    );
    ensure!(view.shape() == spec.shape, "{}: shape mismatch", view.name());
    match view.payload() {
        PayloadView::Fp32(vals) => vals.read_into(&mut slot.f32s),
        PayloadView::Nest {
            scales,
            w_high,
            w_low,
        } => {
            scales.read_into(&mut slot.scales);
            match variant {
                Variant::PartBit => {
                    // Eq. 10: the 2^l inflation rides into the kernel as
                    // the scale multiplier — no inflated scale vector
                    let inflate = cfg.scale_inflation();
                    w_high.unpack_dequant_into(&slot.scales, inflate, &mut slot.f32s);
                }
                Variant::FullBit => {
                    let low = w_low
                        .ok_or_else(|| anyhow::anyhow!("{}: w_low not paged in", view.name()))?;
                    w_high.recompose_dequant_into(&low, cfg.l(), &slot.scales, &mut slot.f32s);
                }
            }
        }
        PayloadView::Mono { .. } => bail!("mono tensor in nest container"),
    }
    Ok(())
}

impl ModelManager {
    /// Create a manager for `spec` over the nest container at
    /// `container_rel`, serving with the `act_bits` graph. The manager
    /// *owns* its archive (its upgrade/downgrade lifecycle releases
    /// section bytes, which must not evict them under another manager);
    /// deliberate sharing goes through [`ModelManager::from_archive`]
    /// with an archive from a [`crate::store::ModelStore`].
    pub fn new(
        engine: &Engine,
        spec: ModelSpec,
        act_bits: u8,
        artifacts_root: &std::path::Path,
        container_rel: &str,
    ) -> Result<ModelManager> {
        let archive = Arc::new(NqArchive::open(artifacts_root.join(container_rel))?);
        ModelManager::from_archive(engine, spec, act_bits, artifacts_root, archive)
    }

    /// Create a manager over an already-open archive — any
    /// [`crate::store::SectionSource`] works, including a fleet
    /// `RemoteSource` (serve a model this device never had on disk).
    pub fn from_archive(
        engine: &Engine,
        spec: ModelSpec,
        act_bits: u8,
        artifacts_root: &std::path::Path,
        archive: Arc<NqArchive>,
    ) -> Result<ModelManager> {
        let hlo_rel = spec
            .hlo
            .get(&act_bits)
            .ok_or_else(|| anyhow::anyhow!("no a{act_bits} HLO for {}", spec.name))?;
        let exe = engine.load_hlo(&artifacts_root.join(hlo_rel))?;
        // header probe only: sizes come from the index, no payload read
        ensure!(archive.kind() == Kind::Nest, "manager requires a nest container");
        Ok(ModelManager {
            spec,
            engine: engine.clone(),
            exe,
            sec_a_bytes: archive.section_a_bytes(),
            sec_b_bytes: archive.section_b_bytes(),
            archive,
            budget: None,
            weight_bufs: Vec::new(),
            part_bufs: Vec::new(),
            state: State::Unloaded,
            decode_slots: Vec::new(),
        })
    }

    pub fn state(&self) -> State {
        self.state
    }

    /// Route this manager's section-B residency through a shared
    /// [`StoreBudget`] under `id`: upgrades attach via the budget
    /// (evicting other tenants' B sections LRU-first), downgrades and
    /// unloads release through it, so N managers share one RAM cap.
    pub fn set_store_budget(&mut self, id: impl Into<String>, budget: Arc<StoreBudget>) {
        self.budget = Some((id.into(), budget));
    }

    /// Release section B: through the budget when one is set (keeps the
    /// shared ledger balanced). When the budget does not list us — the
    /// bytes were fetched outside it (e.g. `load_full_bit`) or already
    /// evicted — fall back to the archive directly so resident bytes
    /// never outlive the manager's full-bit state (a counted no-op when
    /// nothing is resident).
    fn release_b(&self) {
        if let Some((id, budget)) = &self.budget {
            if budget.release_b(id) {
                return;
            }
        }
        self.archive.release_b();
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// The shared archive handle (byte accounting, views).
    pub fn archive(&self) -> &Arc<NqArchive> {
        &self.archive
    }

    /// Nest config (n, h) of the archive.
    pub fn nest_config(&self) -> Option<nest::NestConfig> {
        let idx = self.archive.index();
        nest::NestConfig::new(idx.n, idx.h).ok()
    }

    /// Packed bytes of {w_high + scales + fp32 params} / {w_low}.
    pub fn section_bytes(&self) -> (u64, u64) {
        (self.sec_a_bytes, self.sec_b_bytes)
    }

    /// Launch the part-bit model: section-A fetch only (Eq. 10 dequant).
    pub fn load_part_bit(&mut self, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let t0 = Instant::now();
        ensure!(self.state == State::Unloaded, "load_part_bit from {:?}", self.state);
        ledger.page_in(self.sec_a_bytes).context("part-bit page-in")?;
        self.materialize(Variant::PartBit)?;
        self.state = State::Active(Variant::PartBit);
        Ok(SwitchCost {
            page_in_bytes: self.sec_a_bytes,
            page_out_bytes: 0,
            micros: t0.elapsed().as_micros(),
        })
    }

    /// Launch directly as full-bit (both sections fetched).
    pub fn load_full_bit(&mut self, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let t0 = Instant::now();
        ensure!(self.state == State::Unloaded, "load_full_bit from {:?}", self.state);
        ledger
            .page_in(self.sec_a_bytes + self.sec_b_bytes)
            .context("full-bit page-in")?;
        self.materialize(Variant::FullBit)?;
        self.state = State::Active(Variant::FullBit);
        Ok(SwitchCost {
            page_in_bytes: self.sec_a_bytes + self.sec_b_bytes,
            page_out_bytes: 0,
            micros: t0.elapsed().as_micros(),
        })
    }

    /// Upgrade part-bit → full-bit: attach section B, recompose.
    /// **Zero page-out** — the NestQuant claim of Table 11 — and zero
    /// section-A bytes touched (the archive re-serves its resident `Arc`).
    pub fn upgrade(&mut self, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let t0 = Instant::now();
        ensure!(
            self.state == State::Active(Variant::PartBit),
            "upgrade from {:?}",
            self.state
        );
        if let Some((id, budget)) = &self.budget {
            // budgeted attach first (it can refuse): may LRU-evict other
            // tenants' B sections; materialize below hits the resident Arc
            budget.attach_b(id, &self.archive).context("budgeted upgrade")?;
        }
        if let Err(e) = ledger.page_in(self.sec_b_bytes) {
            // roll the budgeted attach back: a refused upgrade must not
            // leave this tenant's B resident under the shared cap
            if let Some((id, budget)) = &self.budget {
                budget.release_b(id);
            }
            return Err(e).context("upgrade page-in");
        }
        // stash the current part-bit buffers for an O(1) later downgrade
        let part = std::mem::take(&mut self.weight_bufs);
        if let Err(e) = self.materialize(Variant::FullBit) {
            // roll back everything the failed upgrade charged: hand the
            // (budgeted) B bytes back, un-charge the ledger, and restore
            // the part-bit buffers — the manager keeps serving part-bit
            self.release_b();
            let _ = ledger.page_out(self.sec_b_bytes);
            self.weight_bufs = part;
            return Err(e);
        }
        if let Some((id, budget)) = &self.budget {
            if !budget.is_resident(id) {
                // evicted between attach_b and materialize: full_bit()
                // silently re-fetched B outside the ledger. Hand the
                // bytes back — the dequantized buffers stay valid, and
                // the state is simply "full-bit whose B was already
                // evicted", which the next downgrade handles as usual.
                self.archive.release_b();
            }
        }
        self.part_bufs = part;
        self.state = State::Active(Variant::FullBit);
        Ok(SwitchCost {
            page_in_bytes: self.sec_b_bytes,
            page_out_bytes: 0,
            micros: t0.elapsed().as_micros(),
        })
    }

    /// Downgrade full-bit → part-bit: release the section-B `Arc`.
    /// **Zero page-in** — the part-bit weights are rebuilt (or swapped
    /// back) from section A already resident.
    pub fn downgrade(&mut self, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let t0 = Instant::now();
        ensure!(
            self.state == State::Active(Variant::FullBit),
            "downgrade from {:?}",
            self.state
        );
        self.release_b(); // page out
        ledger.page_out(self.sec_b_bytes).context("downgrade page-out")?;
        if self.part_bufs.is_empty() {
            self.materialize(Variant::PartBit)?;
        } else {
            // hot path: the part-bit buffers derive from the still-resident
            // section A — swap them in without touching the packed data
            self.weight_bufs = std::mem::take(&mut self.part_bufs);
        }
        self.state = State::Active(Variant::PartBit);
        Ok(SwitchCost {
            page_in_bytes: 0,
            page_out_bytes: self.sec_b_bytes,
            micros: t0.elapsed().as_micros(),
        })
    }

    /// Unload everything (diverse-bitwidths baseline switching path).
    pub fn unload(&mut self, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let bytes = match self.state {
            State::Unloaded => 0,
            State::Active(Variant::PartBit) => self.sec_a_bytes,
            State::Active(Variant::FullBit) => self.sec_a_bytes + self.sec_b_bytes,
        };
        ledger.page_out(bytes)?;
        self.release_b(); // keep a shared budget's ledger balanced
        self.archive.release_a(); // drops both sections; layout survives
        self.weight_bufs.clear();
        self.part_bufs.clear();
        self.state = State::Unloaded;
        Ok(SwitchCost {
            page_in_bytes: 0,
            page_out_bytes: bytes,
            micros: 0,
        })
    }

    /// Dequantize the archive's views into device-resident weight
    /// buffers. Fetches exactly the sections the variant needs.
    fn materialize(&mut self, variant: Variant) -> Result<()> {
        match variant {
            Variant::PartBit => {
                let model = self.archive.part_bit()?;
                self.upload_views(model.tensors(), variant)
            }
            Variant::FullBit => {
                let model = self.archive.full_bit()?;
                self.upload_views(model.tensors(), variant)
            }
        }
    }

    /// The shared decode+upload path: every tensor runs one fused
    /// kernel pass (packed section bytes → dequantized f32, no i32
    /// intermediates), fanned out across scoped threads in bounded
    /// waves so a multi-tensor switch saturates memory bandwidth
    /// without holding the whole dequantized model; uploads happen in
    /// spec order on the calling thread (PJRT buffers stay
    /// thread-affine).
    fn upload_views<'m>(
        &mut self,
        views: impl ExactSizeIterator<Item = TensorView<'m>>,
        variant: Variant,
    ) -> Result<()> {
        ensure!(
            views.len() == self.spec.params.len(),
            "container/spec tensor count mismatch: {} vs {}",
            views.len(),
            self.spec.params.len()
        );
        let idx = self.archive.index();
        let cfg = nest::NestConfig::new(idx.n, idx.h)?;
        let views: Vec<TensorView<'m>> = views.collect();
        let workers = decode_workers(views.len());
        if self.decode_slots.len() < workers {
            self.decode_slots.resize_with(workers, DecodeSlot::default);
        }
        let slots = &mut self.decode_slots[..workers];
        let params = &self.spec.params;
        // Wave pipeline: decode up to `workers` tensors in parallel (one
        // thread each), then upload that wave in spec order and release
        // its f32s before the next wave — so the during-switch host peak
        // is one wave of dequantized tensors, never the whole model.
        let mut bufs = Vec::with_capacity(views.len());
        for (vwave, pwave) in views.chunks(workers).zip(params.chunks(workers)) {
            let wave_slots = &mut slots[..vwave.len()];
            if workers <= 1 {
                decode_tensor(&vwave[0], &pwave[0], variant, cfg, &mut wave_slots[0])?;
            } else {
                std::thread::scope(|scope| -> Result<()> {
                    let mut handles = Vec::new();
                    for ((view, spec), slot) in
                        vwave.iter().zip(pwave).zip(wave_slots.iter_mut())
                    {
                        handles.push(scope.spawn(move || -> Result<()> {
                            decode_tensor(view, spec, variant, cfg, slot)
                        }));
                    }
                    for h in handles {
                        h.join().expect("decode worker panicked")?;
                    }
                    Ok(())
                })?;
            }
            for (slot, spec) in wave_slots.iter_mut().zip(pwave) {
                bufs.push(self.engine.upload(&slot.f32s, &spec.shape)?);
                // release the transient host copy: the device buffer
                // owns the weights now, and keeping dequantized tensors
                // resident would dwarf the packed sections the ledger
                // accounts for
                slot.f32s = Vec::new();
            }
        }
        self.weight_bufs = bufs;
        Ok(())
    }

    /// Run a padded batch (flattened NHWC) through the active model.
    pub fn infer(
        &self,
        batch: &[f32],
        batch_size: usize,
        img: usize,
        channels: usize,
    ) -> Result<Vec<f32>> {
        ensure!(self.state != State::Unloaded, "no active model");
        ensure!(
            batch.len() == batch_size * img * img * channels,
            "batch size mismatch: {} vs {}",
            batch.len(),
            batch_size * img * img * channels
        );
        let x = self.engine.upload(batch, &[batch_size, img, img, channels])?;
        self.exe.run(&x, &self.weight_bufs)
    }
}
