//! ModelManager: the on-device NestQuant switching mechanism (§3.3).
//!
//! Holds one `.nq` container and the compiled executable for its
//! architecture, and realizes the paper's three switch transitions:
//!
//! * **part-bit launch** — read section A only; dequantize `w_high` with
//!   the inflated scale `s·2^l` (Eq. 10).
//! * **upgrade** — page in section B (the only bytes moved), recompose
//!   `w_int = w_high·2^l + w_low` (Eq. 6), dequantize with `s`.
//!   Zero page-out.
//! * **downgrade** — drop `w_low` and the full-bit weights; rebuild the
//!   part-bit weights from `w_high` already in memory. Zero page-in.
//!
//! Memory accounting follows the paper's convention (§4.3.3): the ledger
//! tracks *packed* bytes (what a packed-int runtime holds). The PJRT CPU
//! backend computes in f32, so dequantized buffers exist at the XLA
//! boundary exactly as the paper's PyTorch deployment dequantizes for
//! compute; the packed accounting is what Table 11 reports.
//!
//! Hot path: weights live as device-resident PJRT buffers, rebuilt only
//! on a switch; a request uploads just its input batch.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::container::{self, Container, Kind, TensorData};
use crate::device::MemoryLedger;
use crate::nest;
use crate::quant;
use crate::runtime::{Engine, Executable, ModelSpec};

/// Which weights are currently active.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Part-bit model: INTh weights at scale s·2^l.
    PartBit,
    /// Full-bit model: recomposed INTn weights at scale s.
    FullBit,
}

/// Latency + byte cost of one switch operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwitchCost {
    pub page_in_bytes: u64,
    pub page_out_bytes: u64,
    pub micros: u128,
}

/// The manager's observable state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    Unloaded,
    Active(Variant),
}

/// One model's switching state machine + weight materialization.
pub struct ModelManager {
    spec: ModelSpec,
    engine: Engine,
    exe: Executable,
    container_path: PathBuf,
    container: Option<Container>,
    /// Packed section sizes (bytes) for ledger accounting.
    sec_a_bytes: u64,
    sec_b_bytes: u64,
    /// Device-resident weight buffers for the active variant.
    weight_bufs: Vec<crate::runtime::DeviceBuffer>,
    /// Cached part-bit buffers. Legitimate: they derive only from w_high
    /// (+ scales), which stays resident in BOTH states by design — so a
    /// downgrade becomes a pointer swap instead of an unpack+dequant+
    /// upload pass (§Perf L3). Full-bit buffers are never cached across a
    /// downgrade: they derive from the paged-out w_low.
    part_bufs: Vec<crate::runtime::DeviceBuffer>,
    state: State,
    /// Scratch buffers reused across switches (no realloc on the path).
    scratch_high: Vec<i32>,
    scratch_low: Vec<i32>,
    scratch_int: Vec<i32>,
    scratch_f32: Vec<f32>,
}

impl ModelManager {
    /// Create a manager for `spec` over the nest container at
    /// `container_rel`, serving with the `act_bits` graph.
    pub fn new(
        engine: &Engine,
        spec: ModelSpec,
        act_bits: u8,
        artifacts_root: &std::path::Path,
        container_rel: &str,
    ) -> Result<ModelManager> {
        let hlo_rel = spec
            .hlo
            .get(&act_bits)
            .ok_or_else(|| anyhow::anyhow!("no a{act_bits} HLO for {}", spec.name))?;
        let exe = engine.load_hlo(&artifacts_root.join(hlo_rel))?;
        let container_path = artifacts_root.join(container_rel);
        // probe sizes without keeping data
        let probe = container::read(&container_path, true)?;
        ensure!(probe.kind == Kind::Nest, "manager requires a nest container");
        Ok(ModelManager {
            spec,
            engine: engine.clone(),
            exe,
            sec_a_bytes: probe.section_a_bytes(),
            sec_b_bytes: probe.section_b_bytes(),
            container_path,
            container: None,
            weight_bufs: Vec::new(),
            part_bufs: Vec::new(),
            state: State::Unloaded,
            scratch_high: Vec::new(),
            scratch_low: Vec::new(),
            scratch_int: Vec::new(),
            scratch_f32: Vec::new(),
        })
    }

    pub fn state(&self) -> State {
        self.state
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Nest config (n, h) of the loaded container.
    pub fn nest_config(&self) -> Option<nest::NestConfig> {
        self.container
            .as_ref()
            .and_then(|c| nest::NestConfig::new(c.n, c.h).ok())
    }

    /// Packed bytes of {w_high + scales + fp32 params} / {w_low}.
    pub fn section_bytes(&self) -> (u64, u64) {
        (self.sec_a_bytes, self.sec_b_bytes)
    }

    /// Launch the part-bit model: section-A read only (Eq. 10 dequant).
    pub fn load_part_bit(&mut self, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let t0 = Instant::now();
        ensure!(self.state == State::Unloaded, "load_part_bit from {:?}", self.state);
        ledger.page_in(self.sec_a_bytes).context("part-bit page-in")?;
        let c = container::read(&self.container_path, true)?;
        self.materialize(&c, Variant::PartBit)?;
        self.container = Some(c);
        self.state = State::Active(Variant::PartBit);
        Ok(SwitchCost {
            page_in_bytes: self.sec_a_bytes,
            page_out_bytes: 0,
            micros: t0.elapsed().as_micros(),
        })
    }

    /// Launch directly as full-bit (whole-file read).
    pub fn load_full_bit(&mut self, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let t0 = Instant::now();
        ensure!(self.state == State::Unloaded, "load_full_bit from {:?}", self.state);
        ledger
            .page_in(self.sec_a_bytes + self.sec_b_bytes)
            .context("full-bit page-in")?;
        let c = container::read(&self.container_path, false)?;
        self.materialize(&c, Variant::FullBit)?;
        self.container = Some(c);
        self.state = State::Active(Variant::FullBit);
        Ok(SwitchCost {
            page_in_bytes: self.sec_a_bytes + self.sec_b_bytes,
            page_out_bytes: 0,
            micros: t0.elapsed().as_micros(),
        })
    }

    /// Upgrade part-bit → full-bit: page in section B, recompose.
    /// **Zero page-out** — the NestQuant claim of Table 11.
    pub fn upgrade(&mut self, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let t0 = Instant::now();
        ensure!(
            self.state == State::Active(Variant::PartBit),
            "upgrade from {:?}",
            self.state
        );
        ledger.page_in(self.sec_b_bytes).context("upgrade page-in")?;
        let mut c = self.container.take().expect("container loaded");
        container::read_section_b(&self.container_path, &mut c)?;
        // stash the current part-bit buffers for an O(1) later downgrade
        let part = std::mem::take(&mut self.weight_bufs);
        self.materialize(&c, Variant::FullBit)?;
        self.part_bufs = part;
        self.container = Some(c);
        self.state = State::Active(Variant::FullBit);
        Ok(SwitchCost {
            page_in_bytes: self.sec_b_bytes,
            page_out_bytes: 0,
            micros: t0.elapsed().as_micros(),
        })
    }

    /// Downgrade full-bit → part-bit: drop w_low. **Zero page-in** — the
    /// part-bit weights are rebuilt from w_high already resident.
    pub fn downgrade(&mut self, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let t0 = Instant::now();
        ensure!(
            self.state == State::Active(Variant::FullBit),
            "downgrade from {:?}",
            self.state
        );
        let mut c = self.container.take().expect("container loaded");
        for t in &mut c.tensors {
            if let TensorData::Nest { w_low, .. } = &mut t.data {
                *w_low = None; // page out
            }
        }
        ledger.page_out(self.sec_b_bytes).context("downgrade page-out")?;
        if self.part_bufs.is_empty() {
            self.materialize(&c, Variant::PartBit)?;
        } else {
            // hot path: the part-bit buffers derive from the still-resident
            // w_high — swap them in without touching the packed data
            self.weight_bufs = std::mem::take(&mut self.part_bufs);
        }
        self.container = Some(c);
        self.state = State::Active(Variant::PartBit);
        Ok(SwitchCost {
            page_in_bytes: 0,
            page_out_bytes: self.sec_b_bytes,
            micros: t0.elapsed().as_micros(),
        })
    }

    /// Unload everything (diverse-bitwidths baseline switching path).
    pub fn unload(&mut self, ledger: &mut MemoryLedger) -> Result<SwitchCost> {
        let bytes = match self.state {
            State::Unloaded => 0,
            State::Active(Variant::PartBit) => self.sec_a_bytes,
            State::Active(Variant::FullBit) => self.sec_a_bytes + self.sec_b_bytes,
        };
        ledger.page_out(bytes)?;
        self.container = None;
        self.weight_bufs.clear();
        self.part_bufs.clear();
        self.state = State::Unloaded;
        Ok(SwitchCost {
            page_in_bytes: 0,
            page_out_bytes: bytes,
            micros: 0,
        })
    }

    /// Dequantize the container into device-resident weight buffers.
    fn materialize(&mut self, c: &Container, variant: Variant) -> Result<()> {
        ensure!(
            c.tensors.len() == self.spec.params.len(),
            "container/spec tensor count mismatch: {} vs {}",
            c.tensors.len(),
            self.spec.params.len()
        );
        let cfg = nest::NestConfig::new(c.n, c.h)?;
        let mut bufs = Vec::with_capacity(c.tensors.len());
        for (t, spec) in c.tensors.iter().zip(&self.spec.params) {
            ensure!(t.name == spec.name, "tensor order: {} vs {}", t.name, spec.name);
            ensure!(t.shape == spec.shape, "{}: shape mismatch", t.name);
            let out = &mut self.scratch_f32;
            match &t.data {
                TensorData::Fp32(vals) => {
                    out.clear();
                    out.extend_from_slice(vals);
                }
                TensorData::Nest {
                    scales,
                    w_high,
                    w_low,
                } => match variant {
                    Variant::PartBit => {
                        w_high.unpack_into(&mut self.scratch_high);
                        let inflated: Vec<f32> =
                            scales.iter().map(|&s| s * cfg.scale_inflation()).collect();
                        quant::dequant(&self.scratch_high, &inflated, out);
                    }
                    Variant::FullBit => {
                        let low = w_low
                            .as_ref()
                            .ok_or_else(|| anyhow::anyhow!("{}: w_low not paged in", t.name))?;
                        w_high.unpack_into(&mut self.scratch_high);
                        low.unpack_into(&mut self.scratch_low);
                        nest::recompose_into(
                            &self.scratch_high,
                            &self.scratch_low,
                            cfg.l(),
                            &mut self.scratch_int,
                        );
                        quant::dequant(&self.scratch_int, scales, out);
                    }
                },
                TensorData::Mono { .. } => bail!("mono tensor in nest container"),
            }
            bufs.push(self.engine.upload(out, &spec.shape)?);
        }
        self.weight_bufs = bufs;
        Ok(())
    }

    /// Run a padded batch (flattened NHWC) through the active model.
    pub fn infer(
        &self,
        batch: &[f32],
        batch_size: usize,
        img: usize,
        channels: usize,
    ) -> Result<Vec<f32>> {
        ensure!(self.state != State::Unloaded, "no active model");
        ensure!(
            batch.len() == batch_size * img * img * channels,
            "batch size mismatch: {} vs {}",
            batch.len(),
            batch_size * img * img * channels
        );
        let x = self.engine.upload(batch, &[batch_size, img, img, channels])?;
        self.exe.run(&x, &self.weight_bufs)
    }
}
