//! Deprecated shim: the metrics registry moved to [`crate::telemetry`]
//! (the fleet-wide registry every subsystem records into and every
//! scrape surface reads from). This module re-exports the promoted
//! types so existing call sites keep compiling; new code should import
//! from `crate::telemetry` directly.

pub use crate::telemetry::{LatencyHisto, Metrics};
