//! Metrics registry: counters + log-bucket latency histograms for the
//! coordinator (requests, batches, switches, paging volumes).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log2-bucketed latency histogram from 1µs to ~17min.
#[derive(Debug)]
pub struct LatencyHisto {
    /// bucket i covers [2^i, 2^{i+1}) microseconds.
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        LatencyHisto {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHisto {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(31);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile from the log buckets (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us()
    }
}

/// Coordinator-wide metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batch_occupancy_sum: AtomicU64,
    pub upgrades: AtomicU64,
    pub downgrades: AtomicU64,
    pub page_in_bytes: AtomicU64,
    pub page_out_bytes: AtomicU64,
    pub errors: AtomicU64,
    pub request_latency: LatencyHisto,
    pub execute_latency: LatencyHisto,
    pub switch_latency: LatencyHisto,
}

impl Metrics {
    pub fn mean_batch_occupancy(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batch_occupancy_sum.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Render a human-readable summary block.
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} occupancy={:.2} upgrades={} downgrades={} \
             page_in={}B page_out={}B errors={}\n\
             latency: exec mean={:.0}us p50={}us p99={}us max={}us | \
             request mean={:.0}us p99={}us | switch mean={:.0}us max={}us",
            self.requests.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_occupancy(),
            self.upgrades.load(Ordering::Relaxed),
            self.downgrades.load(Ordering::Relaxed),
            self.page_in_bytes.load(Ordering::Relaxed),
            self.page_out_bytes.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.execute_latency.mean_us(),
            self.execute_latency.quantile_us(0.5),
            self.execute_latency.quantile_us(0.99),
            self.execute_latency.max_us(),
            self.request_latency.mean_us(),
            self.request_latency.quantile_us(0.99),
            self.switch_latency.mean_us(),
            self.switch_latency.max_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histo_records_and_quantiles() {
        let h = LatencyHisto::default();
        for us in [10u64, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 10);
        assert!(h.mean_us() > 0.0);
        assert!(h.quantile_us(0.5) >= 80 && h.quantile_us(0.5) <= 512);
        assert!(h.quantile_us(0.99) >= 65536);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn histo_empty() {
        let h = LatencyHisto::default();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn summary_renders() {
        let m = Metrics::default();
        m.requests.fetch_add(5, Ordering::Relaxed);
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batch_occupancy_sum.fetch_add(5, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("requests=5"));
        assert!(s.contains("occupancy=2.50"));
    }
}
