//! L3 coordinator (S9): the paper's system contribution as a serving
//! stack — NestQuant model switching driven by a resource policy, behind
//! a dynamically-batched inference loop.
//!
//! ```text
//!   TCP clients ──(model id, image)──▶ server router
//!                                        ├─ tenant queue ▶ batcher ▶ executor
//!                                        ├─ tenant queue ▶ batcher ▶ executor
//!                                        └─ shared StoreBudget (Section B)
//!   ResourceTrace ──▶ PolicyState ── advise(model) ──▶ tenant switch
//! ```
//!
//! The server hosts any number of models from one `store::ModelStore`
//! (`server::serve_tenants`); the single-coordinator path (`server::serve`)
//! is the one-tenant special case.

pub mod baseline;
pub mod batcher;
pub mod manager;
pub mod metrics;
pub mod monitor;
pub mod policy;
pub mod server;
pub mod tenant;

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::Instant;

use anyhow::{Context, Result};

pub use baseline::DiverseBitwidths;
pub use manager::{ModelManager, State, SwitchCost, Variant};
pub use metrics::Metrics;
pub use policy::{Decision, PolicyState, SwitchPolicy};
pub use server::TenantExecutor;
pub use tenant::{ForwardMode, NestTenant};

use crate::device::{DeviceProfile, MemoryLedger, ResourceTrace, RPI_4B};
use crate::runtime::{Engine, Manifest};

/// Everything needed to serve one NestQuant model on one device.
pub struct Coordinator {
    pub manifest: Manifest,
    pub manager: ModelManager,
    pub ledger: MemoryLedger,
    pub profile: DeviceProfile,
    pub metrics: std::sync::Arc<Metrics>,
    root: PathBuf,
}

impl Coordinator {
    /// Build a coordinator for `arch` with the INT(n|h) nest container.
    pub fn new(root: &std::path::Path, arch: &str, n: u8, h: u8) -> Result<Coordinator> {
        let manifest = Manifest::load(root)?;
        let spec = manifest.model(arch)?.clone();
        let container_rel = spec
            .nest_container(n, h)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no INT({n}|{h}) container for {arch}; available: {:?}",
                    spec.nest_containers.keys().collect::<Vec<_>>()
                )
            })?
            .to_string();
        let engine = Engine::cpu()?;
        let manager = ModelManager::new(&engine, spec, n, root, &container_rel)
            .with_context(|| format!("manager for {arch} INT({n}|{h})"))?;
        Ok(Coordinator {
            manifest,
            manager,
            ledger: MemoryLedger::new(RPI_4B.mem_bytes),
            profile: RPI_4B,
            metrics: std::sync::Arc::new(Metrics::default()),
            root: root.to_path_buf(),
        })
    }

    pub fn artifacts_root(&self) -> &std::path::Path {
        &self.root
    }

    fn record_switch(&self, cost: &SwitchCost, upgrade: bool) {
        self.metrics
            .page_in_bytes
            .fetch_add(cost.page_in_bytes, Ordering::Relaxed);
        self.metrics
            .page_out_bytes
            .fetch_add(cost.page_out_bytes, Ordering::Relaxed);
        let s = &crate::telemetry::registry().serving;
        s.page_in_bytes.add(cost.page_in_bytes);
        s.page_out_bytes.add(cost.page_out_bytes);
        if upgrade {
            self.metrics.upgrades.fetch_add(1, Ordering::Relaxed);
            s.upgrades.inc();
        } else {
            self.metrics.downgrades.fetch_add(1, Ordering::Relaxed);
            s.downgrades.inc();
        }
        crate::nq_trace!(
            crate::telemetry::TraceKind::Switch,
            "{}: {} (+{} B / -{} B)",
            self.manager.spec().name,
            if upgrade { "upgrade" } else { "downgrade" },
            cost.page_in_bytes,
            cost.page_out_bytes
        );
        self.metrics
            .switch_latency
            .record(std::time::Duration::from_micros(cost.micros as u64));
        s.switch_latency
            .record(std::time::Duration::from_micros(cost.micros as u64));
    }

    /// Apply one policy decision, performing the switch if required.
    pub fn apply(&mut self, decision: Decision) -> Result<Option<SwitchCost>> {
        match decision {
            Decision::Stay => Ok(None),
            Decision::SwitchTo(Variant::FullBit) => {
                let cost = self.manager.upgrade(&mut self.ledger)?;
                self.record_switch(&cost, true);
                Ok(Some(cost))
            }
            Decision::SwitchTo(Variant::PartBit) => {
                let cost = self.manager.downgrade(&mut self.ledger)?;
                self.record_switch(&cost, false);
                Ok(Some(cost))
            }
        }
    }

    /// Run a padded batch and record latency metrics.
    pub fn infer_batch(&self, input: &[f32]) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let out = self.manager.infer(
            input,
            self.manifest.batch,
            self.manifest.img,
            self.manifest.channels,
        );
        self.metrics.execute_latency.record(t0.elapsed());
        if out.is_err() {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// Top-1 accuracy over the validation set (first `limit` images).
    pub fn eval_accuracy(&self, limit: Option<usize>) -> Result<f64> {
        let (x, y) = self.manifest.load_val()?;
        let img_len = self.manifest.img * self.manifest.img * self.manifest.channels;
        let n = limit.unwrap_or(y.len()).min(y.len());
        let b = self.manifest.batch;
        let classes = self.manifest.num_classes;
        let mut correct = 0usize;
        let mut i = 0;
        let mut input = vec![0f32; b * img_len];
        while i < n {
            let take = (n - i).min(b);
            input[..take * img_len].copy_from_slice(&x[i * img_len..(i + take) * img_len]);
            input[take * img_len..].fill(0.0);
            let logits = self.infer_batch(&input)?;
            for r in 0..take {
                let row = &logits[r * classes..(r + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred as u32 == y[i + r] {
                    correct += 1;
                }
            }
            i += take;
        }
        Ok(correct as f64 / n as f64)
    }

    /// Drive the coordinator through a resource trace, serving `reqs_per_step`
    /// random validation images per step. Returns the lifecycle report.
    pub fn run_trace(
        &mut self,
        mut trace: ResourceTrace,
        policy: SwitchPolicy,
        reqs_per_step: usize,
    ) -> Result<TraceReport> {
        let (x, y) = self.manifest.load_val()?;
        let img_len = self.manifest.img * self.manifest.img * self.manifest.channels;
        let b = self.manifest.batch;
        let classes = self.manifest.num_classes;

        let initial = match self.manager.state() {
            State::Active(v) => v,
            State::Unloaded => {
                let cost = self.manager.load_full_bit(&mut self.ledger)?;
                self.metrics
                    .page_in_bytes
                    .fetch_add(cost.page_in_bytes, Ordering::Relaxed);
                Variant::FullBit
            }
        };
        let mut pstate = PolicyState::new(policy, initial);
        let mut rng = crate::util::prng::Rng::new(0x5eed);
        let mut report = TraceReport::default();
        let mut input = vec![0f32; b * img_len];

        let mut step = 0usize;
        while let Some(level) = trace.next_level() {
            step += 1;
            let decision = pstate.decide(level);
            if let Some(cost) = self.apply(decision)? {
                report.switches.push(SwitchEvent {
                    step,
                    level,
                    to: pstate.current(),
                    cost,
                });
            }
            // serve this step's requests in padded batches
            let mut served = 0;
            while served < reqs_per_step {
                let take = (reqs_per_step - served).min(b);
                let mut idxs = Vec::with_capacity(take);
                for r in 0..take {
                    let j = rng.index(y.len());
                    idxs.push(j);
                    input[r * img_len..(r + 1) * img_len]
                        .copy_from_slice(&x[j * img_len..(j + 1) * img_len]);
                }
                input[take * img_len..].fill(0.0);
                let t0 = Instant::now();
                let logits = self.infer_batch(&input)?;
                self.metrics.request_latency.record(t0.elapsed());
                self.metrics
                    .requests
                    .fetch_add(take as u64, Ordering::Relaxed);
                self.metrics.batches.fetch_add(1, Ordering::Relaxed);
                self.metrics
                    .batch_occupancy_sum
                    .fetch_add(take as u64, Ordering::Relaxed);
                for (r, &j) in idxs.iter().enumerate() {
                    let row = &logits[r * classes..(r + 1) * classes];
                    let pred = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    let correct = pred as u32 == y[j];
                    match pstate.current() {
                        Variant::FullBit => {
                            report.full_served += 1;
                            report.full_correct += correct as u64;
                        }
                        Variant::PartBit => {
                            report.part_served += 1;
                            report.part_correct += correct as u64;
                        }
                    }
                }
                served += take;
            }
        }
        report.steps = step;
        Ok(report)
    }
}

/// One switch that happened during a trace run.
#[derive(Debug, Clone, Copy)]
pub struct SwitchEvent {
    pub step: usize,
    pub level: f64,
    pub to: Variant,
    pub cost: SwitchCost,
}

/// Lifecycle summary of a trace run.
#[derive(Debug, Default)]
pub struct TraceReport {
    pub steps: usize,
    pub switches: Vec<SwitchEvent>,
    pub full_served: u64,
    pub full_correct: u64,
    pub part_served: u64,
    pub part_correct: u64,
}

impl TraceReport {
    pub fn full_acc(&self) -> f64 {
        if self.full_served == 0 {
            f64::NAN
        } else {
            self.full_correct as f64 / self.full_served as f64
        }
    }

    pub fn part_acc(&self) -> f64 {
        if self.part_served == 0 {
            f64::NAN
        } else {
            self.part_correct as f64 / self.part_served as f64
        }
    }

    pub fn total_page_in(&self) -> u64 {
        self.switches.iter().map(|s| s.cost.page_in_bytes).sum()
    }

    pub fn total_page_out(&self) -> u64 {
        self.switches.iter().map(|s| s.cost.page_out_bytes).sum()
    }
}
