//! ResourceMonitor: the background thread that closes the loop between
//! device resources and the switch policy while the server runs.
//!
//! Samples a resource source at a fixed interval, runs the hysteresis
//! policy, and applies switches through the shared coordinator mutex
//! (serializing with in-flight batches — a switch can never tear weights
//! out from under an executing batch).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::device::ResourceTrace;

use super::policy::{Decision, PolicyState, SwitchPolicy};
use super::{Coordinator, Variant};

/// A source of resource levels in [0, 1].
pub trait ResourceSource: Send + 'static {
    /// Next sample; None ends monitoring.
    fn sample(&mut self) -> Option<f64>;
}

impl ResourceSource for ResourceTrace {
    fn sample(&mut self) -> Option<f64> {
        self.next_level()
    }
}

/// Looping wrapper: replays a trace forever (long-running servers).
pub struct LoopingTrace {
    trace: ResourceTrace,
    original: ResourceTrace,
}

impl LoopingTrace {
    pub fn new(trace: ResourceTrace) -> Self {
        LoopingTrace {
            original: trace.clone(),
            trace,
        }
    }
}

impl ResourceSource for LoopingTrace {
    fn sample(&mut self) -> Option<f64> {
        match self.trace.next_level() {
            Some(v) => Some(v),
            None => {
                self.trace = self.original.clone();
                self.trace.next_level()
            }
        }
    }
}

/// Handle to a running monitor.
pub struct MonitorHandle {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<MonitorLog>>,
}

/// What the monitor did.
#[derive(Debug, Default, Clone)]
pub struct MonitorLog {
    pub samples: u64,
    pub upgrades: u64,
    pub downgrades: u64,
    pub switch_errors: u64,
}

impl MonitorHandle {
    /// Stop monitoring; returns the activity log.
    pub fn stop(mut self) -> MonitorLog {
        self.stop.store(true, Ordering::SeqCst);
        self.thread
            .take()
            .map(|t| t.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

/// Spawn the monitor over a shared coordinator.
pub fn spawn(
    coordinator: Arc<Mutex<Coordinator>>,
    mut source: impl ResourceSource,
    policy: SwitchPolicy,
    interval: Duration,
) -> Result<MonitorHandle> {
    let initial = {
        let c = coordinator.lock().unwrap();
        match c.manager.state() {
            super::State::Active(v) => v,
            super::State::Unloaded => anyhow::bail!("monitor requires a loaded model"),
        }
    };
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let thread = std::thread::Builder::new()
        .name("nq-monitor".into())
        .spawn(move || {
            let mut state = PolicyState::new(policy, initial);
            let mut log = MonitorLog::default();
            while !stop2.load(Ordering::SeqCst) {
                let Some(level) = source.sample() else { break };
                log.samples += 1;
                let decision = state.decide(level);
                if !matches!(decision, Decision::Stay) {
                    let mut c = coordinator.lock().unwrap();
                    match c.apply(decision) {
                        Ok(Some(_)) => match decision {
                            Decision::SwitchTo(Variant::FullBit) => log.upgrades += 1,
                            Decision::SwitchTo(Variant::PartBit) => log.downgrades += 1,
                            Decision::Stay => {}
                        },
                        Ok(None) => {}
                        Err(_) => log.switch_errors += 1,
                    }
                }
                drop_sleep(interval, &stop2);
            }
            log
        })?;
    Ok(MonitorHandle {
        stop,
        thread: Some(thread),
    })
}

/// Sleep in small slices so stop() is responsive.
fn drop_sleep(total: Duration, stop: &AtomicBool) {
    let slice = Duration::from_millis(10);
    let mut left = total;
    while left > Duration::ZERO && !stop.load(Ordering::SeqCst) {
        let d = left.min(slice);
        std::thread::sleep(d);
        left = left.saturating_sub(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Constant(f64, usize);
    impl ResourceSource for Constant {
        fn sample(&mut self) -> Option<f64> {
            if self.1 == 0 {
                return None;
            }
            self.1 -= 1;
            Some(self.0)
        }
    }

    #[test]
    fn looping_trace_wraps() {
        let mut lt = LoopingTrace::new(ResourceTrace::new(vec![0.1, 0.2]));
        let got: Vec<f64> = (0..5).map(|_| lt.sample().unwrap()).collect();
        assert_eq!(got, vec![0.1, 0.2, 0.1, 0.2, 0.1]);
    }

    #[test]
    fn constant_source_ends() {
        let mut c = Constant(0.5, 3);
        assert!(c.sample().is_some());
        assert!(c.sample().is_some());
        assert!(c.sample().is_some());
        assert!(c.sample().is_none());
    }
}
