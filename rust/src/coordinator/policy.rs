//! SwitchPolicy: resource level → target variant, with hysteresis.
//!
//! The paper's motivation (§1) switches to an energy-saving mode when the
//! battery drops past a threshold (e.g. 50%) and back when resources
//! recover. A naive single threshold oscillates when the level hovers at
//! the boundary; we use a hysteresis band [downgrade_below,
//! upgrade_above] and prove non-oscillation in tests.

use super::manager::Variant;

/// Hysteresis switching policy.
#[derive(Debug, Clone, Copy)]
pub struct SwitchPolicy {
    /// Downgrade to part-bit when the level falls strictly below this.
    pub downgrade_below: f64,
    /// Upgrade to full-bit when the level rises to/above this.
    pub upgrade_above: f64,
    /// Minimum decisions between switches (debounce).
    pub min_dwell: u32,
}

impl Default for SwitchPolicy {
    fn default() -> Self {
        // the paper's 50% example, with a 10-point band
        SwitchPolicy {
            downgrade_below: 0.45,
            upgrade_above: 0.55,
            min_dwell: 2,
        }
    }
}

/// A policy decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Stay,
    SwitchTo(Variant),
}

impl Decision {
    /// Wire encoding used by the fleet server's advice replies.
    pub fn wire(&self) -> &'static str {
        match self {
            Decision::Stay => "stay",
            Decision::SwitchTo(Variant::FullBit) => "upgrade",
            Decision::SwitchTo(Variant::PartBit) => "downgrade",
        }
    }

    /// Parse the wire encoding back into a decision.
    pub fn from_wire(s: &str) -> anyhow::Result<Decision> {
        Ok(match s {
            "stay" => Decision::Stay,
            "upgrade" => Decision::SwitchTo(Variant::FullBit),
            "downgrade" => Decision::SwitchTo(Variant::PartBit),
            other => anyhow::bail!("unknown decision {other:?}"),
        })
    }
}

/// Stateful policy evaluator.
#[derive(Debug, Clone)]
pub struct PolicyState {
    policy: SwitchPolicy,
    current: Variant,
    dwell: u32,
    switches: u64,
}

impl PolicyState {
    pub fn new(policy: SwitchPolicy, initial: Variant) -> Self {
        assert!(
            policy.downgrade_below <= policy.upgrade_above,
            "hysteresis band inverted"
        );
        PolicyState {
            policy,
            current: initial,
            dwell: policy.min_dwell, // allow an immediate first switch
            switches: 0,
        }
    }

    pub fn current(&self) -> Variant {
        self.current
    }

    pub fn switches(&self) -> u64 {
        self.switches
    }

    /// Evaluate one resource sample in [0, 1].
    pub fn decide(&mut self, level: f64) -> Decision {
        self.dwell = self.dwell.saturating_add(1);
        let target = match self.current {
            Variant::FullBit if level < self.policy.downgrade_below => Variant::PartBit,
            Variant::PartBit if level >= self.policy.upgrade_above => Variant::FullBit,
            _ => return Decision::Stay,
        };
        if self.dwell <= self.policy.min_dwell {
            return Decision::Stay;
        }
        self.current = target;
        self.dwell = 0;
        self.switches += 1;
        Decision::SwitchTo(target)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn decision_wire_roundtrip() {
        for d in [
            Decision::Stay,
            Decision::SwitchTo(Variant::FullBit),
            Decision::SwitchTo(Variant::PartBit),
        ] {
            assert_eq!(Decision::from_wire(d.wire()).unwrap(), d);
        }
        assert!(Decision::from_wire("sideways").is_err());
    }

    #[test]
    fn downgrades_below_threshold() {
        let mut p = PolicyState::new(SwitchPolicy::default(), Variant::FullBit);
        assert_eq!(p.decide(0.9), Decision::Stay);
        assert_eq!(p.decide(0.4), Decision::SwitchTo(Variant::PartBit));
        assert_eq!(p.current(), Variant::PartBit);
    }

    #[test]
    fn upgrades_above_threshold() {
        let mut p = PolicyState::new(SwitchPolicy::default(), Variant::PartBit);
        assert_eq!(p.decide(0.5), Decision::Stay); // inside the band
        assert_eq!(p.decide(0.56), Decision::SwitchTo(Variant::FullBit));
    }

    #[test]
    fn constant_level_never_oscillates() {
        for level in [0.0, 0.3, 0.45, 0.5, 0.55, 0.7, 1.0] {
            let mut p = PolicyState::new(SwitchPolicy::default(), Variant::FullBit);
            let mut switches = 0;
            for _ in 0..1000 {
                if matches!(p.decide(level), Decision::SwitchTo(_)) {
                    switches += 1;
                }
            }
            assert!(switches <= 1, "level {level}: {switches} switches");
        }
    }

    #[test]
    fn band_hover_is_debounced() {
        // level oscillating *inside* the band must cause zero switches
        let mut p = PolicyState::new(SwitchPolicy::default(), Variant::FullBit);
        for i in 0..1000 {
            let level = 0.46 + 0.08 * ((i % 2) as f64); // 0.46 / 0.54
            assert_eq!(p.decide(level), Decision::Stay);
        }
    }

    #[test]
    fn min_dwell_limits_switch_rate() {
        let policy = SwitchPolicy {
            downgrade_below: 0.45,
            upgrade_above: 0.55,
            min_dwell: 5,
        };
        let mut p = PolicyState::new(policy, Variant::FullBit);
        let mut switches = 0;
        // worst-case adversarial level alternating across both thresholds
        for i in 0..600 {
            let level = if i % 2 == 0 { 0.1 } else { 0.9 };
            if matches!(p.decide(level), Decision::SwitchTo(_)) {
                switches += 1;
            }
        }
        assert!(switches <= 100 + 1, "{switches} switches"); // ≤ 1 per 6 samples
    }

    #[test]
    fn prop_switch_rate_bounded_under_random_traces() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let mut p = PolicyState::new(SwitchPolicy::default(), Variant::FullBit);
            let n = 2000;
            let mut switches = 0;
            for _ in 0..n {
                if matches!(p.decide(rng.f64()), Decision::SwitchTo(_)) {
                    switches += 1;
                }
            }
            // dwell=2 → at most one switch every 3 samples
            assert!(switches <= n / 3 + 1);
        }
    }
}
