//! Multi-tenant inference server: one reactor event loop routing
//! model-id-tagged frames to a shared weighted-fair worker pool.
//!
//! ```text
//!                 ┌────────────────────────────────────────────────┐
//!   client ──────▶│ reactor loop: conns are slab state, not threads│
//!      ⋮          │   "infer"  ─▶ FairScheduler (DRR per tenant) ──┼─▶ worker pool
//!   client ──────▶│   "models"/"metrics" ─▶ control-class queue  ──┘   (shared,
//!                 │   replies injected back through the loop waker │    ≤ cores)
//!                 └────────────────────────────────────────────────┘
//! ```
//!
//! Protocol (all `Control` frames, unchanged from the thread-per-conn
//! server): clients send `infer` whose payload is
//! `u16 id_len | model id | flattened NHWC f32 image`
//! ([`crate::transport::encode_tagged`]); the server replies `logits`
//! (same tagged form), `error` (utf8), or `busy` (utf8 — typed
//! overload refusal from queue shedding or an open per-tenant circuit
//! breaker; the client should back off and retry). `models` lists the
//! hosted model ids (newline-joined). `stop` shuts the server down.
//!
//! Each connection is an explicit state machine on the loop: a request
//! pauses the connection (dropping read interest) until its reply is
//! injected, so per-connection request/response ordering is preserved
//! without a thread. Tenants share the worker pool through the
//! scheduler's deficit-round-robin infer class with the same batch
//! deadline semantics the old per-tenant executor threads had; control
//! traffic (`models`/`metrics`) preempts inference. Switch advice
//! ([`ServerHandle::advise`]) serializes with execution through the
//! tenant's executor mutex: a switch lands between batches, never
//! tearing weights out from under one.

use std::collections::{BTreeMap, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::faults::{self, Breaker};
use crate::nq_trace;
use crate::reactor::{
    self, Admit, BatchPolicy, ConnId, Ctl, Entry, FairScheduler, ReactorHandle, ReactorOpts,
    Remote, Service, Work,
};
use crate::telemetry::{registry, Snapshot, TraceKind};
use crate::transport::{
    decode_model_list, decode_tagged, encode_model_list, encode_tagged, recv_frame, send_frame,
    Frame, FrameKind, Meter,
};

use super::{Coordinator, Decision, Metrics, State, SwitchCost, Variant};

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub max_wait: Duration,
    /// Per-tenant infer queue depth cap: pushes beyond it are shed
    /// with a typed `busy` reply instead of queuing without bound.
    pub infer_queue_cap: usize,
    /// Consecutive executor failures before a tenant's circuit breaker
    /// opens (requests then get `busy` until the cooldown elapses and a
    /// half-open probe succeeds).
    pub breaker_threshold: u32,
    /// How long an open breaker refuses traffic before probing.
    pub breaker_cooldown: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(5),
            infer_queue_cap: 1024,
            breaker_threshold: 5,
            breaker_cooldown: Duration::from_millis(500),
        }
    }
}

/// Abandon a half-received request frame after this long without
/// progress (generous: coordinator clients send frames whole).
const PARTIAL_FRAME_TIMEOUT: Duration = Duration::from_secs(30);

// ---------------------------------------------------------------------------
// tenants
// ---------------------------------------------------------------------------

/// One hosted model's executor: shape-specialized batch inference plus
/// the upgrade/downgrade switch hooks. Implemented by [`Coordinator`]
/// (PJRT-backed, manifest-described) and `tenant::NestTenant` (served
/// straight from a store archive, PJRT-free).
pub trait TenantExecutor: Send {
    /// `(batch_size, image_len, num_classes)` the executor is
    /// specialized for.
    fn shape(&self) -> (usize, usize, usize);

    /// Run one zero-padded batch (`batch_size * image_len` floats);
    /// returns `batch_size * num_classes` logits.
    fn run_batch(&mut self, input: &[f32]) -> Result<Vec<f32>>;

    /// Apply switch advice. Serialized with `run_batch` by the server's
    /// per-tenant mutex, so a switch never tears a running batch.
    fn switch(&mut self, decision: Decision) -> Result<Option<SwitchCost>>;

    /// Variant currently served.
    fn variant(&self) -> Variant;

    /// Metrics sink to record serving counters into; `None` lets the
    /// server allocate a private one per tenant.
    fn metrics(&self) -> Option<Arc<Metrics>> {
        None
    }

    /// Whether `switch` already records switch counters into
    /// `metrics()` itself ([`Coordinator::apply`] does) — the server's
    /// advice path then skips double-recording.
    fn switch_is_metered(&self) -> bool {
        false
    }
}

impl TenantExecutor for Coordinator {
    fn shape(&self) -> (usize, usize, usize) {
        (
            self.manifest.batch,
            self.manifest.img * self.manifest.img * self.manifest.channels,
            self.manifest.num_classes,
        )
    }

    fn run_batch(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        self.infer_batch(input)
    }

    fn switch(&mut self, decision: Decision) -> Result<Option<SwitchCost>> {
        self.apply(decision)
    }

    fn variant(&self) -> Variant {
        match self.manager.state() {
            State::Active(v) => v,
            State::Unloaded => Variant::PartBit,
        }
    }

    fn metrics(&self) -> Option<Arc<Metrics>> {
        Some(Arc::clone(&self.metrics))
    }

    fn switch_is_metered(&self) -> bool {
        true
    }
}

/// A coordinator shared with out-of-server switch drivers (e.g. a
/// policy loop applying decisions through the same mutex). The legacy
/// single-tenant [`serve`] entry point wraps its coordinator in this.
pub struct SharedCoordinator(pub Arc<Mutex<Coordinator>>);

impl SharedCoordinator {
    /// Poison-recovering lock: a panic isolated by the worker pool must
    /// not brick the shared coordinator for out-of-server drivers.
    fn lock(&self) -> std::sync::MutexGuard<'_, Coordinator> {
        self.0.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl TenantExecutor for SharedCoordinator {
    fn shape(&self) -> (usize, usize, usize) {
        self.lock().shape()
    }

    fn run_batch(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        self.lock().infer_batch(input)
    }

    fn switch(&mut self, decision: Decision) -> Result<Option<SwitchCost>> {
        self.lock().apply(decision)
    }

    fn variant(&self) -> Variant {
        self.lock().variant()
    }

    fn metrics(&self) -> Option<Arc<Metrics>> {
        Some(Arc::clone(&self.lock().metrics))
    }

    fn switch_is_metered(&self) -> bool {
        true
    }
}

/// Per-tenant runtime shared between the router service, the worker
/// pool, and the advice path.
struct Tenant {
    /// Position in sorted-id order; doubles as the scheduler's tenant
    /// index for DRR fairness.
    index: usize,
    exec: Arc<Mutex<Box<dyn TenantExecutor>>>,
    metrics: Arc<Metrics>,
    /// Per-tenant circuit breaker: opens after consecutive executor
    /// failures so a persistently broken tenant fails fast with `busy`
    /// instead of burning worker time, and recovers via a half-open
    /// probe. Other tenants are unaffected.
    breaker: Breaker,
    image_len: usize,
    batch_size: usize,
    classes: usize,
}

impl Tenant {
    /// Lock the executor, recovering from poison: a worker panic is
    /// isolated by `catch_unwind`, so the executor state a later batch
    /// sees is whatever the panicking batch left — the breaker, not the
    /// mutex, decides whether the tenant keeps taking traffic.
    fn exec(&self) -> std::sync::MutexGuard<'_, Box<dyn TenantExecutor>> {
        self.exec.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Publish the breaker state to this tenant's scrape-visible gauge.
    fn publish_breaker(&self) {
        self.metrics
            .breaker_state
            .store(self.breaker.state().code(), Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// handle
// ---------------------------------------------------------------------------

/// Handle to a running server. Dropping it (or calling
/// [`ServerHandle::stop`]) shuts the server down deterministically:
/// the scheduler drains every queued job, the worker pool joins, and
/// the reactor flushes in-flight replies before its loop thread exits.
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    tenants: Arc<BTreeMap<String, Tenant>>,
    sched: Arc<FairScheduler<Job>>,
    reactor: Option<ReactorHandle>,
    workers: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// Hosted model ids.
    pub fn models(&self) -> Vec<String> {
        self.tenants.keys().cloned().collect()
    }

    /// Serving metrics of one hosted model.
    pub fn metrics(&self, model: &str) -> Option<Arc<Metrics>> {
        self.tenants.get(model).map(|t| Arc::clone(&t.metrics))
    }

    /// Variant one hosted model currently serves.
    pub fn variant(&self, model: &str) -> Option<Variant> {
        self.tenants.get(model).map(|t| t.exec().variant())
    }

    /// Apply switch advice to one hosted model. Serialized with that
    /// model's batch execution; other tenants keep serving throughout.
    pub fn advise(&self, model: &str, decision: Decision) -> Result<Option<SwitchCost>> {
        let t = self
            .tenants
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model:?}"))?;
        let (cost, metered) = {
            let mut e = t.exec();
            (e.switch(decision)?, e.switch_is_metered())
        };
        if let (Some(c), false) = (&cost, metered) {
            t.metrics
                .page_in_bytes
                .fetch_add(c.page_in_bytes, Ordering::Relaxed);
            t.metrics
                .page_out_bytes
                .fetch_add(c.page_out_bytes, Ordering::Relaxed);
            let s = &registry().serving;
            s.page_in_bytes.add(c.page_in_bytes);
            s.page_out_bytes.add(c.page_out_bytes);
            match decision {
                Decision::SwitchTo(Variant::FullBit) => {
                    t.metrics.upgrades.fetch_add(1, Ordering::Relaxed);
                    s.upgrades.inc();
                    nq_trace!(TraceKind::Switch, "{model}: upgrade (+{} B)", c.page_in_bytes);
                }
                Decision::SwitchTo(Variant::PartBit) => {
                    t.metrics.downgrades.fetch_add(1, Ordering::Relaxed);
                    s.downgrades.inc();
                    nq_trace!(TraceKind::Switch, "{model}: downgrade (-{} B)", c.page_out_bytes);
                }
                Decision::Stay => {}
            }
            t.metrics
                .switch_latency
                .record(Duration::from_micros(c.micros as u64));
            s.switch_latency.record(Duration::from_micros(c.micros as u64));
        }
        Ok(cost)
    }

    /// Whether a `stop` frame (or a prior `stop()` call) has shut the
    /// server down.
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Stop the server and join every thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        // 1. flag first so stopped() flips immediately
        self.stop.store(true, Ordering::SeqCst);
        // 2. close the scheduler: workers drain every queued job
        //    (injecting its reply) and exit; join them so every claimed
        //    request has answered before the loop drains
        self.sched.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // 3. drain the reactor: the listener closes, idle conns close in
        //    on_stop, conns awaiting a reply flush it first, and the
        //    loop exits once its slab is empty
        if let Some(mut r) = self.reactor.take() {
            r.request_stop();
            r.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

/// Legacy single-tenant entry point: serve one shared coordinator under
/// its architecture name. Untagged `infer` frames (empty model id)
/// route to it as the sole tenant.
pub fn serve(coordinator: Arc<Mutex<Coordinator>>, config: ServerConfig) -> Result<ServerHandle> {
    let id = coordinator.lock().unwrap().manager.spec().name.clone();
    serve_tenants(
        vec![(id, Box::new(SharedCoordinator(coordinator)) as Box<dyn TenantExecutor>)],
        config,
    )
}

/// Start a multi-tenant server hosting `tenants` on a fresh localhost
/// port. All tenants share the reactor loop and worker pool; `infer`
/// frames route by model id and batch per tenant.
pub fn serve_tenants(
    tenants: Vec<(String, Box<dyn TenantExecutor>)>,
    config: ServerConfig,
) -> Result<ServerHandle> {
    ensure!(!tenants.is_empty(), "serve_tenants needs at least one tenant");

    let mut map: BTreeMap<String, Tenant> = BTreeMap::new();
    for (id, exec) in tenants {
        ensure!(!map.contains_key(&id), "duplicate tenant id {id:?}");
        ensure!(
            !id.is_empty() && !id.contains('\n'),
            "tenant id {id:?} must be non-empty and newline-free \
             (empty routes to the sole tenant; newline is the list separator)"
        );
        let (batch_size, image_len, classes) = exec.shape();
        ensure!(
            batch_size > 0 && image_len > 0 && classes > 0,
            "{id}: degenerate tenant shape ({batch_size}, {image_len}, {classes})"
        );
        let metrics = exec.metrics().unwrap_or_default();
        map.insert(
            id,
            Tenant {
                index: 0, // fixed up below once the id order is final
                exec: Arc::new(Mutex::new(exec)),
                metrics,
                breaker: Breaker::new(config.breaker_threshold, config.breaker_cooldown),
                image_len,
                batch_size,
                classes,
            },
        );
    }
    let mut order = Vec::with_capacity(map.len());
    let mut policies = Vec::with_capacity(map.len());
    let mut weights = Vec::with_capacity(map.len());
    for (idx, (id, t)) in map.iter_mut().enumerate() {
        t.index = idx;
        order.push(id.clone());
        policies.push(BatchPolicy {
            batch_size: t.batch_size,
            max_wait: config.max_wait,
        });
        weights.push(1u32);
    }
    let tenants = Arc::new(map);
    let sched: Arc<FairScheduler<Job>> =
        Arc::new(FairScheduler::with_infer_cap(&weights, config.infer_queue_cap));
    let inject: Inject = Arc::new(Mutex::new(Vec::new()));
    let stop = Arc::new(AtomicBool::new(false));

    let listener = TcpListener::bind("127.0.0.1:0").context("bind")?;
    let service = RouterService {
        tenants: Arc::clone(&tenants),
        sched: Arc::clone(&sched),
        inject: Arc::clone(&inject),
        stop_flag: Arc::clone(&stop),
        stopping: false,
        open: HashSet::new(),
        in_flight: HashSet::new(),
    };
    let reactor = reactor::spawn(
        listener,
        service,
        ReactorOpts {
            name: "coordinator".into(),
            meter: Arc::new(Meter::default()),
            partial_frame_timeout: Some(PARTIAL_FRAME_TIMEOUT),
        },
    )
    .context("spawn reactor")?;
    let addr = reactor.addr;

    let ctx = Arc::new(WorkerCtx {
        sched: Arc::clone(&sched),
        tenants: Arc::clone(&tenants),
        order,
        policies,
        inject,
        remote: reactor.remote(),
    });
    let n_workers = ctx
        .order
        .len()
        .max(std::thread::available_parallelism().map_or(2, |n| n.get()))
        .min(32);
    let mut workers = Vec::with_capacity(n_workers);
    for i in 0..n_workers {
        let ctx = Arc::clone(&ctx);
        workers.push(
            std::thread::Builder::new()
                .name(format!("nq-worker-{i}"))
                // Respawn-in-place: a panic escaping the loop (batch
                // panics are already isolated inside run_infer_batch)
                // restarts it on the same thread, so the pool never
                // shrinks and the thread count stays bounded.
                .spawn(move || loop {
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        worker_loop(&ctx)
                    })) {
                        Ok(()) => return, // clean shutdown
                        Err(_) => {
                            registry().faults.worker_panics.inc();
                            nq_trace!(TraceKind::WorkerPanic, "nq-worker-{i} respawned after panic");
                        }
                    }
                })?,
        );
    }

    Ok(ServerHandle {
        addr,
        stop,
        tenants,
        sched,
        reactor: Some(reactor),
        workers,
    })
}

// ---------------------------------------------------------------------------
// router service (runs on the reactor loop)
// ---------------------------------------------------------------------------

/// A job claimed from the scheduler by a worker. Infer jobs are
/// batch-scheduled per tenant; control jobs preempt them.
enum Job {
    Infer {
        conn: ConnId,
        model: String,
        image: Vec<f32>,
    },
    Models {
        conn: ConnId,
    },
    Metrics {
        conn: ConnId,
    },
}

/// Worker → loop reply channel: finished frames parked here until the
/// waker nudges the loop to inject them.
type Inject = Arc<Mutex<Vec<(ConnId, Frame)>>>;

struct RouterService {
    tenants: Arc<BTreeMap<String, Tenant>>,
    sched: Arc<FairScheduler<Job>>,
    inject: Inject,
    stop_flag: Arc<AtomicBool>,
    stopping: bool,
    open: HashSet<ConnId>,
    in_flight: HashSet<ConnId>,
}

impl RouterService {
    /// Enqueue an async job for `conn`, pausing it until the reply
    /// comes back so per-connection ordering is preserved.
    fn enqueue(&mut self, conn: ConnId, ctl: &mut Ctl, accepted: bool, id: &str) {
        if accepted {
            self.in_flight.insert(conn);
            ctl.pause(conn);
        } else {
            ctl.send(conn, error_frame(format!("{id}: server shutting down").into_bytes()));
        }
    }
}

impl Service for RouterService {
    fn on_open(&mut self, conn: ConnId, _ctl: &mut Ctl) {
        self.open.insert(conn);
    }

    fn on_close(&mut self, conn: ConnId, _ctl: &mut Ctl) {
        self.open.remove(&conn);
        // a dead conn's reply is dropped by the reactor's generation
        // guard; just forget it was waiting
        self.in_flight.remove(&conn);
    }

    fn on_frame(&mut self, conn: ConnId, frame: Frame, ctl: &mut Ctl) {
        match (frame.kind, frame.name.as_str()) {
            (FrameKind::Control, "stop") => {
                self.stop_flag.store(true, Ordering::SeqCst);
                ctl.stop();
            }
            (FrameKind::Control, "models") => {
                let ok = self.sched.push_control(Job::Models { conn });
                self.enqueue(conn, ctl, ok, "models");
            }
            (FrameKind::Control, "metrics") => {
                let ok = self.sched.push_control(Job::Metrics { conn });
                self.enqueue(conn, ctl, ok, "metrics");
            }
            (FrameKind::Control, "infer") => match route_infer(&frame.payload, &self.tenants) {
                Ok((tenant, model, image)) => {
                    let id = model.clone();
                    let t = &self.tenants[&id];
                    // Circuit-breaker gate: an open circuit fails fast
                    // with a typed `busy` before the request costs queue
                    // space or worker time. `admit` may flip the breaker
                    // to half-open, so re-publish the gauge either way.
                    let admitted = t.breaker.admit();
                    t.publish_breaker();
                    if !admitted {
                        ctl.send(conn, busy_frame(format!("{id}: circuit open, retry later")));
                        return;
                    }
                    match self
                        .sched
                        .push_infer(tenant, Job::Infer { conn, model, image })
                    {
                        Admit::Queued => {
                            registry().serving.queue_depth.inc();
                            self.in_flight.insert(conn);
                            ctl.pause(conn);
                        }
                        Admit::Shed => {
                            ctl.send(conn, busy_frame(format!("{id}: queue full, retry later")));
                        }
                        Admit::Closed => {
                            ctl.send(
                                conn,
                                error_frame(format!("{id}: server shutting down").into_bytes()),
                            );
                        }
                    }
                }
                Err(e) => {
                    ctl.send(conn, error_frame(format!("{e:#}").into_bytes()));
                }
            },
            _ => {
                ctl.send(conn, error_frame(b"unknown frame".to_vec()));
            }
        }
    }

    fn on_wake(&mut self, ctl: &mut Ctl) {
        let replies: Vec<(ConnId, Frame)> = std::mem::take(&mut *self.inject.lock().unwrap());
        for (conn, frame) in replies {
            self.in_flight.remove(&conn);
            ctl.send(conn, frame);
            if self.stopping {
                ctl.close_after_flush(conn);
            } else {
                ctl.resume(conn);
            }
        }
    }

    fn on_stop(&mut self, ctl: &mut Ctl) {
        self.stopping = true;
        self.stop_flag.store(true, Ordering::SeqCst);
        for &conn in &self.open {
            if !self.in_flight.contains(&conn) {
                ctl.close_after_flush(conn);
            }
        }
    }
}

fn error_frame(msg: impl Into<Vec<u8>>) -> Frame {
    Frame {
        kind: FrameKind::Control,
        name: "error".into(),
        payload: msg.into(),
    }
}

/// Typed overload refusal (shed queue or open breaker): the connection
/// stays open and the client should back off and retry.
fn busy_frame(msg: impl Into<Vec<u8>>) -> Frame {
    Frame {
        kind: FrameKind::Control,
        name: "busy".into(),
        payload: msg.into(),
    }
}

/// Resolve a model id to its tenant; an empty id routes to the sole
/// tenant when exactly one is hosted.
fn resolve<'t>(tenants: &'t BTreeMap<String, Tenant>, model: &str) -> Result<(&'t Tenant, String)> {
    if model.is_empty() {
        ensure!(
            tenants.len() == 1,
            "model id required ({} models hosted)",
            tenants.len()
        );
        let (id, t) = tenants.iter().next().unwrap();
        return Ok((t, id.clone()));
    }
    match tenants.get(model) {
        Some(t) => Ok((t, model.to_string())),
        None => bail!(
            "unknown model {model:?} (hosted: {:?})",
            tenants.keys().collect::<Vec<_>>()
        ),
    }
}

/// Decode, route, and validate one `infer` request (cheap, runs on the
/// loop); returns the tenant index, resolved model id, and image.
fn route_infer(
    payload: &[u8],
    tenants: &BTreeMap<String, Tenant>,
) -> Result<(usize, String, Vec<f32>)> {
    let (model, img_bytes) = decode_tagged(payload)?;
    let (tenant, id) = resolve(tenants, model)?;
    ensure!(
        img_bytes.len() == tenant.image_len * 4,
        "{id}: bad image size {} (want {})",
        img_bytes.len(),
        tenant.image_len * 4
    );
    let image: Vec<f32> = img_bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((tenant.index, id, image))
}

// ---------------------------------------------------------------------------
// worker pool
// ---------------------------------------------------------------------------

struct WorkerCtx {
    sched: Arc<FairScheduler<Job>>,
    tenants: Arc<BTreeMap<String, Tenant>>,
    /// Tenant index → model id (sorted-id order, mirrors `Tenant::index`).
    order: Vec<String>,
    /// Tenant index → batch policy.
    policies: Vec<BatchPolicy>,
    inject: Inject,
    remote: Arc<Remote>,
}

impl WorkerCtx {
    fn reply(&self, out: Vec<(ConnId, Frame)>) {
        if out.is_empty() {
            return;
        }
        self.inject.lock().unwrap().extend(out);
        self.remote.wake();
    }
}

fn worker_loop(ctx: &WorkerCtx) {
    loop {
        match ctx.sched.next_work(&ctx.policies) {
            Work::Shutdown => return,
            Work::One(_, entry) => match entry.payload {
                Job::Models { conn } => {
                    let ids: Vec<&str> = ctx.order.iter().map(String::as_str).collect();
                    ctx.reply(vec![(
                        conn,
                        Frame {
                            kind: FrameKind::Control,
                            name: "models".into(),
                            payload: encode_model_list(&ids),
                        },
                    )]);
                }
                Job::Metrics { conn } => {
                    let tm: Vec<(String, Arc<Metrics>)> = ctx
                        .tenants
                        .iter()
                        .map(|(id, t)| (id.clone(), Arc::clone(&t.metrics)))
                        .collect();
                    let snap = Snapshot::gather(&tm);
                    ctx.reply(vec![(
                        conn,
                        Frame {
                            kind: FrameKind::Control,
                            name: "metrics".into(),
                            payload: snap.to_json().into_bytes(),
                        },
                    )]);
                }
                Job::Infer { .. } => unreachable!("infer jobs are batch-scheduled"),
            },
            Work::Batch(t, entries) => {
                run_infer_batch(ctx, t, entries);
                ctx.sched.finish_batch(t);
            }
        }
    }
}

/// Execute one tenant batch: zero-pad, lock the executor, run, record
/// metrics, and inject per-request replies.
fn run_infer_batch(ctx: &WorkerCtx, t: usize, entries: Vec<Entry<Job>>) {
    if entries.is_empty() {
        return;
    }
    let tenant = &ctx.tenants[&ctx.order[t]];
    let occupancy = entries.len() as u64;
    let mut input = vec![0f32; tenant.batch_size * tenant.image_len];
    for (i, e) in entries.iter().enumerate() {
        if let Job::Infer { image, .. } = &e.payload {
            input[i * tenant.image_len..(i + 1) * tenant.image_len].copy_from_slice(image);
        }
    }
    let t0 = Instant::now();
    // The `worker.job` failpoint covers the whole executor section, so
    // an injected panic exercises the same isolation a real one gets:
    // catch_unwind contains it, every request in the batch receives a
    // typed error, the poisoned mutex is recovered on the next lock,
    // and the tenant keeps serving.
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        faults::fail_point("worker.job")?;
        tenant.exec().run_batch(&input)
    }));
    let result = match caught {
        Ok(r) => r,
        Err(panic) => {
            registry().faults.worker_panics.inc();
            let msg = panic_message(panic.as_ref());
            nq_trace!(
                TraceKind::WorkerPanic,
                "{}: batch panicked: {msg}",
                ctx.order[t]
            );
            Err(anyhow::anyhow!("worker panicked while executing batch: {msg}"))
        }
    };
    let mut out = Vec::with_capacity(entries.len());
    match result {
        Ok(logits) => {
            tenant.breaker.on_success();
            tenant.publish_breaker();
            tenant.metrics.requests.fetch_add(occupancy, Ordering::Relaxed);
            tenant.metrics.batches.fetch_add(1, Ordering::Relaxed);
            tenant
                .metrics
                .batch_occupancy_sum
                .fetch_add(occupancy, Ordering::Relaxed);
            let s = &registry().serving;
            s.requests.add(occupancy);
            s.batches.inc();
            s.batch_latency.record(t0.elapsed());
            for (i, e) in entries.iter().enumerate() {
                let waited = e.enqueued.elapsed();
                tenant.metrics.request_latency.record(waited);
                s.request_latency.record(waited);
                let Job::Infer { conn, model, .. } = &e.payload else {
                    continue;
                };
                let bytes: Vec<u8> = logits[i * tenant.classes..(i + 1) * tenant.classes]
                    .iter()
                    .flat_map(|v| v.to_le_bytes())
                    .collect();
                let frame = match encode_tagged(model, &bytes) {
                    Ok(p) => Frame {
                        kind: FrameKind::Control,
                        name: "logits".into(),
                        payload: p,
                    },
                    Err(err) => error_frame(format!("{err:#}").into_bytes()),
                };
                out.push((*conn, frame));
                registry().serving.queue_depth.dec();
            }
        }
        Err(e2) => {
            tenant.breaker.on_failure();
            tenant.publish_breaker();
            tenant.metrics.errors.fetch_add(occupancy, Ordering::Relaxed);
            registry().serving.errors.add(occupancy);
            let msg = format!("{e2:#}");
            for e in &entries {
                let Job::Infer { conn, .. } = &e.payload else {
                    continue;
                };
                out.push((*conn, error_frame(msg.clone().into_bytes())));
                registry().serving.queue_depth.dec();
            }
        }
    }
    ctx.reply(out);
}

/// Best-effort text of a caught panic payload (panics carry `&str` or
/// `String` in practice).
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// Simple blocking client for the protocol above.
pub struct Client {
    sock: TcpStream,
    meter: Meter,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        Ok(Client {
            sock: TcpStream::connect(addr)?,
            meter: Meter::default(),
        })
    }

    /// Classify one image against the sole hosted model (legacy
    /// single-tenant sugar: empty model id).
    pub fn infer(&mut self, image: &[f32]) -> Result<Vec<f32>> {
        self.infer_model("", image)
    }

    /// Classify one image against a specific hosted model; returns
    /// logits.
    pub fn infer_model(&mut self, model: &str, image: &[f32]) -> Result<Vec<f32>> {
        let bytes: Vec<u8> = image.iter().flat_map(|v| v.to_le_bytes()).collect();
        send_frame(
            &mut self.sock,
            &Frame {
                kind: FrameKind::Control,
                name: "infer".into(),
                payload: encode_tagged(model, &bytes)?,
            },
            &self.meter,
        )?;
        let (reply, _) = recv_frame(&mut self.sock, &self.meter)?;
        match reply.name.as_str() {
            "logits" => {
                let (_, data) = decode_tagged(&reply.payload)?;
                Ok(data
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect())
            }
            "busy" => anyhow::bail!("server busy: {}", String::from_utf8_lossy(&reply.payload)),
            "error" => anyhow::bail!("server error: {}", String::from_utf8_lossy(&reply.payload)),
            other => anyhow::bail!("unexpected reply {other:?}"),
        }
    }

    /// List the hosted model ids.
    pub fn models(&mut self) -> Result<Vec<String>> {
        send_frame(
            &mut self.sock,
            &Frame {
                kind: FrameKind::Control,
                name: "models".into(),
                payload: Vec::new(),
            },
            &self.meter,
        )?;
        let (reply, _) = recv_frame(&mut self.sock, &self.meter)?;
        ensure!(reply.name == "models", "unexpected reply {:?}", reply.name);
        decode_model_list(&reply.payload)
    }

    /// Scrape the server's telemetry snapshot (versioned JSON — parse
    /// with [`Snapshot::from_json`]).
    pub fn metrics(&mut self) -> Result<String> {
        send_frame(
            &mut self.sock,
            &Frame {
                kind: FrameKind::Control,
                name: "metrics".into(),
                payload: Vec::new(),
            },
            &self.meter,
        )?;
        let (reply, _) = recv_frame(&mut self.sock, &self.meter)?;
        ensure!(reply.name == "metrics", "unexpected reply {:?}", reply.name);
        String::from_utf8(reply.payload).context("metrics payload")
    }

    pub fn stop_server(&mut self) -> Result<()> {
        send_frame(
            &mut self.sock,
            &Frame {
                kind: FrameKind::Control,
                name: "stop".into(),
                payload: Vec::new(),
            },
            &self.meter,
        )?;
        Ok(())
    }
}
