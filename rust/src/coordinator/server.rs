//! Multi-tenant inference server: one TCP front-end routing
//! model-id-tagged frames to per-tenant batcher queues + executors.
//!
//! ```text
//!                        ┌──────────────────────────────────────────┐
//!   client ──"infer"─────│ router: model id → tenant                │
//!   client ──(id,image)──│   tenant A: queue ─▶ batcher ─▶ executor │
//!      ⋮                 │   tenant B: queue ─▶ batcher ─▶ executor │
//!   client ──"models"────│   shared StoreBudget (Section-B bytes)   │
//!                        └──────────────────────────────────────────┘
//! ```
//!
//! Protocol (all `Control` frames): clients send `infer` whose payload
//! is `u16 id_len | model id | flattened NHWC f32 image`
//! ([`crate::transport::encode_tagged`]); the server replies `logits`
//! (same tagged form) or `error` (utf8). `models` lists the hosted
//! model ids (newline-joined). `stop` shuts the server down; the
//! handler both sets the stop flag *and* pokes the listener, so a bare
//! `stop` frame suffices without racing `ServerHandle::stop`.
//!
//! Each hosted model owns its queue and executor thread, so tenants
//! batch independently (a flood on one model never delays another's
//! batch close — see `batcher::drain_queue`). Switch advice
//! ([`ServerHandle::advise`]) serializes with execution through the
//! tenant's executor mutex: a switch lands between batches, never
//! tearing weights out from under one.

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::nq_trace;
use crate::telemetry::{registry, Snapshot, TraceKind};
use crate::transport::{
    decode_model_list, decode_tagged, encode_model_list, encode_tagged, recv_frame, send_frame,
    Frame, FrameKind, Meter,
};

use super::batcher::{self, BatcherConfig, Request};
use super::{Coordinator, Decision, Metrics, State, SwitchCost, Variant};

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(5),
        }
    }
}

// ---------------------------------------------------------------------------
// tenants
// ---------------------------------------------------------------------------

/// One hosted model's executor: shape-specialized batch inference plus
/// the upgrade/downgrade switch hooks. Implemented by [`Coordinator`]
/// (PJRT-backed, manifest-described) and `tenant::NestTenant` (served
/// straight from a store archive, PJRT-free).
pub trait TenantExecutor: Send {
    /// `(batch_size, image_len, num_classes)` the executor is
    /// specialized for.
    fn shape(&self) -> (usize, usize, usize);

    /// Run one zero-padded batch (`batch_size * image_len` floats);
    /// returns `batch_size * num_classes` logits.
    fn run_batch(&mut self, input: &[f32]) -> Result<Vec<f32>>;

    /// Apply switch advice. Serialized with `run_batch` by the server's
    /// per-tenant mutex, so a switch never tears a running batch.
    fn switch(&mut self, decision: Decision) -> Result<Option<SwitchCost>>;

    /// Variant currently served.
    fn variant(&self) -> Variant;

    /// Metrics sink to record serving counters into; `None` lets the
    /// server allocate a private one per tenant.
    fn metrics(&self) -> Option<Arc<Metrics>> {
        None
    }

    /// Whether `switch` already records switch counters into
    /// `metrics()` itself ([`Coordinator::apply`] does) — the server's
    /// advice path then skips double-recording.
    fn switch_is_metered(&self) -> bool {
        false
    }
}

impl TenantExecutor for Coordinator {
    fn shape(&self) -> (usize, usize, usize) {
        (
            self.manifest.batch,
            self.manifest.img * self.manifest.img * self.manifest.channels,
            self.manifest.num_classes,
        )
    }

    fn run_batch(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        self.infer_batch(input)
    }

    fn switch(&mut self, decision: Decision) -> Result<Option<SwitchCost>> {
        self.apply(decision)
    }

    fn variant(&self) -> Variant {
        match self.manager.state() {
            State::Active(v) => v,
            State::Unloaded => Variant::PartBit,
        }
    }

    fn metrics(&self) -> Option<Arc<Metrics>> {
        Some(Arc::clone(&self.metrics))
    }

    fn switch_is_metered(&self) -> bool {
        true
    }
}

/// A coordinator shared with out-of-server switch drivers (e.g. a
/// policy loop applying decisions through the same mutex). The legacy
/// single-tenant [`serve`] entry point wraps its coordinator in this.
pub struct SharedCoordinator(pub Arc<Mutex<Coordinator>>);

impl TenantExecutor for SharedCoordinator {
    fn shape(&self) -> (usize, usize, usize) {
        self.0.lock().unwrap().shape()
    }

    fn run_batch(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        self.0.lock().unwrap().infer_batch(input)
    }

    fn switch(&mut self, decision: Decision) -> Result<Option<SwitchCost>> {
        self.0.lock().unwrap().apply(decision)
    }

    fn variant(&self) -> Variant {
        self.0.lock().unwrap().variant()
    }

    fn metrics(&self) -> Option<Arc<Metrics>> {
        Some(Arc::clone(&self.0.lock().unwrap().metrics))
    }

    fn switch_is_metered(&self) -> bool {
        true
    }
}

/// Per-tenant runtime shared between the router, the handlers, and the
/// advice path.
struct Tenant {
    exec: Arc<Mutex<Box<dyn TenantExecutor>>>,
    metrics: Arc<Metrics>,
    image_len: usize,
    /// Request queue sender; taken (closed) on shutdown so the
    /// executor's `drain_queue` loop drains and exits.
    tx: Mutex<Option<mpsc::Sender<Request>>>,
}

// ---------------------------------------------------------------------------
// handle
// ---------------------------------------------------------------------------

/// Handle to a running server. Dropping it (or calling
/// [`ServerHandle::stop`]) shuts the server down deterministically:
/// every acceptor, executor, and connection-handler thread is joined.
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    tenants: Arc<BTreeMap<String, Tenant>>,
    acceptor: Option<JoinHandle<()>>,
    executors: Vec<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ServerHandle {
    /// Hosted model ids.
    pub fn models(&self) -> Vec<String> {
        self.tenants.keys().cloned().collect()
    }

    /// Serving metrics of one hosted model.
    pub fn metrics(&self, model: &str) -> Option<Arc<Metrics>> {
        self.tenants.get(model).map(|t| Arc::clone(&t.metrics))
    }

    /// Variant one hosted model currently serves.
    pub fn variant(&self, model: &str) -> Option<Variant> {
        self.tenants
            .get(model)
            .map(|t| t.exec.lock().unwrap().variant())
    }

    /// Apply switch advice to one hosted model. Serialized with that
    /// model's batch execution; other tenants keep serving throughout.
    pub fn advise(&self, model: &str, decision: Decision) -> Result<Option<SwitchCost>> {
        let t = self
            .tenants
            .get(model)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model:?}"))?;
        let (cost, metered) = {
            let mut e = t.exec.lock().unwrap();
            (e.switch(decision)?, e.switch_is_metered())
        };
        if let (Some(c), false) = (&cost, metered) {
            t.metrics
                .page_in_bytes
                .fetch_add(c.page_in_bytes, Ordering::Relaxed);
            t.metrics
                .page_out_bytes
                .fetch_add(c.page_out_bytes, Ordering::Relaxed);
            let s = &registry().serving;
            s.page_in_bytes.add(c.page_in_bytes);
            s.page_out_bytes.add(c.page_out_bytes);
            match decision {
                Decision::SwitchTo(Variant::FullBit) => {
                    t.metrics.upgrades.fetch_add(1, Ordering::Relaxed);
                    s.upgrades.inc();
                    nq_trace!(TraceKind::Switch, "{model}: upgrade (+{} B)", c.page_in_bytes);
                }
                Decision::SwitchTo(Variant::PartBit) => {
                    t.metrics.downgrades.fetch_add(1, Ordering::Relaxed);
                    s.downgrades.inc();
                    nq_trace!(TraceKind::Switch, "{model}: downgrade (-{} B)", c.page_out_bytes);
                }
                Decision::Stay => {}
            }
            t.metrics
                .switch_latency
                .record(Duration::from_micros(c.micros as u64));
            s.switch_latency.record(Duration::from_micros(c.micros as u64));
        }
        Ok(cost)
    }

    /// Whether a `stop` frame (or a prior `stop()` call) has shut the
    /// server down.
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Stop the server and join every thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        // 1. flag first, THEN poke: the accept loop re-checks the flag
        //    after every accept (including the poke's), so no connection
        //    accepted after this line is dispatched to a handler
        self.stop.store(true, Ordering::SeqCst);
        // 2. close every tenant queue so executors drain and exit once
        //    the last in-flight handler drops its sender clone
        for t in self.tenants.values() {
            t.tx.lock().unwrap().take();
        }
        // 3. wake the acceptor even when no client ever sent `stop`
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // 4. handlers observe the flag within their poll interval; join
        //    them BEFORE executors (a handler may be awaiting a reply
        //    that an executor still has to produce)
        let conns: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for c in conns {
            let _ = c.join();
        }
        for e in self.executors.drain(..) {
            let _ = e.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

/// Legacy single-tenant entry point: serve one shared coordinator under
/// its architecture name. Untagged `infer` frames (empty model id)
/// route to it as the sole tenant.
pub fn serve(coordinator: Arc<Mutex<Coordinator>>, config: ServerConfig) -> Result<ServerHandle> {
    let id = coordinator.lock().unwrap().manager.spec().name.clone();
    serve_tenants(
        vec![(id, Box::new(SharedCoordinator(coordinator)) as Box<dyn TenantExecutor>)],
        config,
    )
}

/// Start a multi-tenant server hosting `tenants` on a fresh localhost
/// port. Each tenant gets its own batcher queue and executor thread;
/// `infer` frames route by model id.
pub fn serve_tenants(
    tenants: Vec<(String, Box<dyn TenantExecutor>)>,
    config: ServerConfig,
) -> Result<ServerHandle> {
    ensure!(!tenants.is_empty(), "serve_tenants needs at least one tenant");
    let listener = TcpListener::bind("127.0.0.1:0").context("bind")?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));

    let mut map: BTreeMap<String, Tenant> = BTreeMap::new();
    let mut executors = Vec::new();
    for (id, exec) in tenants {
        ensure!(!map.contains_key(&id), "duplicate tenant id {id:?}");
        ensure!(
            !id.is_empty() && !id.contains('\n'),
            "tenant id {id:?} must be non-empty and newline-free \
             (empty routes to the sole tenant; newline is the list separator)"
        );
        let (batch_size, image_len, classes) = exec.shape();
        ensure!(
            batch_size > 0 && image_len > 0 && classes > 0,
            "{id}: degenerate tenant shape ({batch_size}, {image_len}, {classes})"
        );
        let metrics = exec.metrics().unwrap_or_default();
        let exec = Arc::new(Mutex::new(exec));
        let (tx, rx) = mpsc::channel::<Request>();
        let bcfg = BatcherConfig {
            batch_size,
            image_len,
            max_wait: config.max_wait,
        };
        let exec2 = Arc::clone(&exec);
        let metrics2 = Arc::clone(&metrics);
        let thread = std::thread::Builder::new()
            .name(format!("nq-exec-{id}"))
            .spawn(move || {
                batcher::drain_queue(&rx, &bcfg, |batch| {
                    let mut e = exec2.lock().unwrap();
                    let occupancy = batch.requests.len() as u64;
                    let t0 = Instant::now();
                    match e.run_batch(&batch.input) {
                        Ok(logits) => {
                            drop(e);
                            metrics2.requests.fetch_add(occupancy, Ordering::Relaxed);
                            metrics2.batches.fetch_add(1, Ordering::Relaxed);
                            metrics2
                                .batch_occupancy_sum
                                .fetch_add(occupancy, Ordering::Relaxed);
                            let s = &registry().serving;
                            s.requests.add(occupancy);
                            s.batches.inc();
                            s.batch_latency.record(t0.elapsed());
                            for r in &batch.requests {
                                let waited = r.enqueued.elapsed();
                                metrics2.request_latency.record(waited);
                                s.request_latency.record(waited);
                            }
                            batcher::respond(batch, &logits, classes);
                        }
                        Err(e2) => {
                            drop(e);
                            metrics2.errors.fetch_add(occupancy, Ordering::Relaxed);
                            registry().serving.errors.add(occupancy);
                            batcher::respond_error(batch, &format!("{e2:#}"));
                        }
                    }
                });
            })?;
        executors.push(thread);
        map.insert(
            id,
            Tenant {
                exec,
                metrics,
                image_len,
                tx: Mutex::new(Some(tx)),
            },
        );
    }
    let tenants = Arc::new(map);

    let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let stop2 = Arc::clone(&stop);
    let tenants2 = Arc::clone(&tenants);
    let aconns = Arc::clone(&conns);
    let acceptor = std::thread::Builder::new()
        .name("nq-acceptor".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(sock) = conn else { continue };
                // deterministic shutdown: re-check AFTER the accept, so
                // a poke connection (or any racer) accepted at stop time
                // is dropped instead of dispatched to a handler
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let hstop = Arc::clone(&stop2);
                let htenants = Arc::clone(&tenants2);
                let handle = std::thread::spawn(move || {
                    let _ = handle_connection(sock, htenants, hstop, addr);
                });
                let mut conns = aconns.lock().unwrap();
                conns.retain(|h| !h.is_finished());
                conns.push(handle);
            }
        })?;

    Ok(ServerHandle {
        addr,
        stop,
        tenants,
        acceptor: Some(acceptor),
        executors,
        conns,
    })
}

fn error_frame(msg: impl Into<Vec<u8>>) -> Frame {
    Frame {
        kind: FrameKind::Control,
        name: "error".into(),
        payload: msg.into(),
    }
}

/// Resolve a model id to its tenant; an empty id routes to the sole
/// tenant when exactly one is hosted.
fn resolve<'t>(tenants: &'t BTreeMap<String, Tenant>, model: &str) -> Result<(&'t Tenant, String)> {
    if model.is_empty() {
        ensure!(
            tenants.len() == 1,
            "model id required ({} models hosted)",
            tenants.len()
        );
        let (id, t) = tenants.iter().next().unwrap();
        return Ok((t, id.clone()));
    }
    match tenants.get(model) {
        Some(t) => Ok((t, model.to_string())),
        None => bail!(
            "unknown model {model:?} (hosted: {:?})",
            tenants.keys().collect::<Vec<_>>()
        ),
    }
}

fn handle_connection(
    sock: TcpStream,
    tenants: Arc<BTreeMap<String, Tenant>>,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
) -> Result<()> {
    let meter = Meter::default();
    // Poll the socket with a short timeout so handler threads observe
    // the stop flag and release their batcher senders.
    sock.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut writer = sock.try_clone()?;
    let mut reader = BufReader::new(sock);
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let (frame, _) = match recv_frame(&mut reader, &meter) {
            Ok(f) => f,
            Err(e) => {
                if crate::transport::is_timeout(&e) {
                    continue; // idle poll: re-check stop and keep waiting
                }
                return Ok(()); // client closed / protocol error
            }
        };
        match (frame.kind, frame.name.as_str()) {
            (FrameKind::Control, "stop") => {
                stop.store(true, Ordering::SeqCst);
                // poke the listener ourselves: a bare `stop` frame must
                // shut the acceptor down without racing ServerHandle::stop
                let _ = TcpStream::connect(addr);
                return Ok(());
            }
            (FrameKind::Control, "models") => {
                let ids: Vec<&str> = tenants.keys().map(String::as_str).collect();
                send_frame(
                    &mut writer,
                    &Frame {
                        kind: FrameKind::Control,
                        name: "models".into(),
                        payload: encode_model_list(&ids),
                    },
                    &meter,
                )?;
            }
            (FrameKind::Control, "metrics") => {
                let tm: Vec<(String, Arc<Metrics>)> = tenants
                    .iter()
                    .map(|(id, t)| (id.clone(), Arc::clone(&t.metrics)))
                    .collect();
                let snap = Snapshot::gather(&tm);
                send_frame(
                    &mut writer,
                    &Frame {
                        kind: FrameKind::Control,
                        name: "metrics".into(),
                        payload: snap.to_json().into_bytes(),
                    },
                    &meter,
                )?;
            }
            (FrameKind::Control, "infer") => {
                match serve_infer(&frame.payload, &tenants) {
                    Ok((model, logits)) => {
                        let payload: Vec<u8> =
                            logits.iter().flat_map(|v| v.to_le_bytes()).collect();
                        send_frame(
                            &mut writer,
                            &Frame {
                                kind: FrameKind::Control,
                                name: "logits".into(),
                                payload: encode_tagged(&model, &payload)?,
                            },
                            &meter,
                        )?;
                    }
                    Err(e) => {
                        let msg = format!("{e:#}").into_bytes();
                        send_frame(&mut writer, &error_frame(msg), &meter)?;
                    }
                }
            }
            _ => {
                send_frame(&mut writer, &error_frame(b"unknown frame".to_vec()), &meter)?;
            }
        }
    }
}

/// Decode, route, enqueue, and await one `infer` request.
fn serve_infer(
    payload: &[u8],
    tenants: &BTreeMap<String, Tenant>,
) -> Result<(String, Vec<f32>)> {
    let (model, img_bytes) = decode_tagged(payload)?;
    let (tenant, id) = resolve(tenants, model)?;
    ensure!(
        img_bytes.len() == tenant.image_len * 4,
        "{id}: bad image size {} (want {})",
        img_bytes.len(),
        tenant.image_len * 4
    );
    let image: Vec<f32> = img_bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let tx = tenant
        .tx
        .lock()
        .unwrap()
        .clone()
        .ok_or_else(|| anyhow::anyhow!("{id}: server shutting down"))?;
    let (rtx, rrx) = mpsc::channel();
    registry().serving.queue_depth.inc();
    let sent = tx
        .send(Request {
            image,
            reply: rtx,
            enqueued: Instant::now(),
        })
        .map_err(|_| anyhow::anyhow!("{id}: executor gone"));
    drop(tx); // release our sender clone before blocking on the reply
    let reply = sent.and_then(|()| match rrx.recv() {
        Ok(Ok(logits)) => Ok((id.clone(), logits)),
        Ok(Err(msg)) => bail!("{msg}"),
        Err(_) => bail!("{id}: executor dropped the request"),
    });
    registry().serving.queue_depth.dec();
    reply
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// Simple blocking client for the protocol above.
pub struct Client {
    sock: TcpStream,
    meter: Meter,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        Ok(Client {
            sock: TcpStream::connect(addr)?,
            meter: Meter::default(),
        })
    }

    /// Classify one image against the sole hosted model (legacy
    /// single-tenant sugar: empty model id).
    pub fn infer(&mut self, image: &[f32]) -> Result<Vec<f32>> {
        self.infer_model("", image)
    }

    /// Classify one image against a specific hosted model; returns
    /// logits.
    pub fn infer_model(&mut self, model: &str, image: &[f32]) -> Result<Vec<f32>> {
        let bytes: Vec<u8> = image.iter().flat_map(|v| v.to_le_bytes()).collect();
        send_frame(
            &mut self.sock,
            &Frame {
                kind: FrameKind::Control,
                name: "infer".into(),
                payload: encode_tagged(model, &bytes)?,
            },
            &self.meter,
        )?;
        let (reply, _) = recv_frame(&mut self.sock, &self.meter)?;
        match reply.name.as_str() {
            "logits" => {
                let (_, data) = decode_tagged(&reply.payload)?;
                Ok(data
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect())
            }
            "error" => anyhow::bail!("server error: {}", String::from_utf8_lossy(&reply.payload)),
            other => anyhow::bail!("unexpected reply {other:?}"),
        }
    }

    /// List the hosted model ids.
    pub fn models(&mut self) -> Result<Vec<String>> {
        send_frame(
            &mut self.sock,
            &Frame {
                kind: FrameKind::Control,
                name: "models".into(),
                payload: Vec::new(),
            },
            &self.meter,
        )?;
        let (reply, _) = recv_frame(&mut self.sock, &self.meter)?;
        ensure!(reply.name == "models", "unexpected reply {:?}", reply.name);
        decode_model_list(&reply.payload)
    }

    /// Scrape the server's telemetry snapshot (versioned JSON — parse
    /// with [`Snapshot::from_json`]).
    pub fn metrics(&mut self) -> Result<String> {
        send_frame(
            &mut self.sock,
            &Frame {
                kind: FrameKind::Control,
                name: "metrics".into(),
                payload: Vec::new(),
            },
            &self.meter,
        )?;
        let (reply, _) = recv_frame(&mut self.sock, &self.meter)?;
        ensure!(reply.name == "metrics", "unexpected reply {:?}", reply.name);
        String::from_utf8(reply.payload).context("metrics payload")
    }

    pub fn stop_server(&mut self) -> Result<()> {
        send_frame(
            &mut self.sock,
            &Frame {
                kind: FrameKind::Control,
                name: "stop".into(),
                payload: Vec::new(),
            },
            &self.meter,
        )?;
        Ok(())
    }
}
