//! Inference server: TCP front-end + batcher + executor loop.
//!
//! Protocol: clients send `Control` frames named "infer" whose payload is
//! one flattened NHWC f32 image; the server replies with a `Control`
//! frame named "logits" (f32 payload) or "error" (utf8 message). A frame
//! named "stop" shuts the server down (used by tests/examples).

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::transport::{recv_frame, send_frame, Frame, FrameKind, Meter};

use super::batcher::{self, BatcherConfig, Request};
use super::Coordinator;

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_wait: Duration::from_millis(5),
        }
    }
}

/// Handle to a running server.
pub struct ServerHandle {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the listener so accept() returns
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start serving `coordinator` on a fresh localhost port.
///
/// The coordinator is shared behind a mutex: the executor thread takes it
/// per batch; switch operations (driven externally via the same mutex)
/// serialize with execution — a switch never tears weights out from under
/// a running batch.
pub fn serve(
    coordinator: Arc<Mutex<Coordinator>>,
    config: ServerConfig,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind("127.0.0.1:0").context("bind")?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<Request>();

    // executor thread: batcher → coordinator → replies
    let exec_coord = Arc::clone(&coordinator);
    let (img_len, batch_size, classes) = {
        let c = exec_coord.lock().unwrap();
        (
            c.manifest.img * c.manifest.img * c.manifest.channels,
            c.manifest.batch,
            c.manifest.num_classes,
        )
    };
    let bcfg = BatcherConfig {
        batch_size,
        image_len: img_len,
        max_wait: config.max_wait,
    };
    let executor = std::thread::Builder::new()
        .name("nq-executor".into())
        .spawn(move || {
            while let Some(batch) = batcher::next_batch(&rx, &bcfg) {
                let c = exec_coord.lock().unwrap();
                let occupancy = batch.requests.len() as u64;
                match c.infer_batch(&batch.input) {
                    Ok(logits) => {
                        c.metrics.requests.fetch_add(occupancy, Ordering::Relaxed);
                        c.metrics.batches.fetch_add(1, Ordering::Relaxed);
                        c.metrics
                            .batch_occupancy_sum
                            .fetch_add(occupancy, Ordering::Relaxed);
                        for r in &batch.requests {
                            c.metrics.request_latency.record(r.enqueued.elapsed());
                        }
                        drop(c);
                        batcher::respond(batch, &logits, classes);
                    }
                    Err(e) => {
                        drop(c);
                        batcher::respond_error(batch, &format!("{e:#}"));
                    }
                }
            }
        })?;

    // acceptor thread: one handler thread per connection
    let stop2 = Arc::clone(&stop);
    let acceptor = std::thread::Builder::new()
        .name("nq-acceptor".into())
        .spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(sock) = conn else { continue };
                let tx = tx.clone();
                let stop3 = Arc::clone(&stop2);
                std::thread::spawn(move || {
                    let _ = handle_connection(sock, tx, img_len, stop3);
                });
            }
            // tx drops here → executor drains and exits
        })?;

    Ok(ServerHandle {
        addr,
        stop,
        threads: vec![executor, acceptor],
    })
}

fn handle_connection(
    sock: TcpStream,
    tx: mpsc::Sender<Request>,
    img_len: usize,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let meter = Meter::default();
    // Poll the socket with a short timeout so handler threads observe the
    // stop flag and release their batcher senders (otherwise a lingering
    // idle client would keep the executor alive after stop()).
    sock.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut writer = sock.try_clone()?;
    let mut reader = BufReader::new(sock);
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let (frame, _) = match recv_frame(&mut reader, &meter) {
            Ok(f) => f,
            Err(e) => {
                // timeout while idle → re-check stop and keep waiting
                let timed_out = e.downcast_ref::<std::io::Error>().is_some_and(|io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    )
                });
                if timed_out {
                    continue;
                }
                return Ok(()); // client closed / protocol error
            }
        };
        match (frame.kind, frame.name.as_str()) {
            (FrameKind::Control, "stop") => {
                stop.store(true, Ordering::SeqCst);
                return Ok(());
            }
            (FrameKind::Control, "infer") => {
                if frame.payload.len() != img_len * 4 {
                    send_frame(
                        &mut writer,
                        &Frame {
                            kind: FrameKind::Control,
                            name: "error".into(),
                            payload: format!(
                                "bad image size {} (want {})",
                                frame.payload.len(),
                                img_len * 4
                            )
                            .into_bytes(),
                        },
                        &meter,
                    )?;
                    continue;
                }
                let image: Vec<f32> = frame
                    .payload
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                let (rtx, rrx) = mpsc::channel();
                tx.send(Request {
                    image,
                    reply: rtx,
                    enqueued: Instant::now(),
                })
                .map_err(|_| anyhow::anyhow!("executor gone"))?;
                match rrx.recv() {
                    Ok(Ok(logits)) => {
                        let payload: Vec<u8> =
                            logits.iter().flat_map(|v| v.to_le_bytes()).collect();
                        send_frame(
                            &mut writer,
                            &Frame {
                                kind: FrameKind::Control,
                                name: "logits".into(),
                                payload,
                            },
                            &meter,
                        )?;
                    }
                    Ok(Err(msg)) => {
                        send_frame(
                            &mut writer,
                            &Frame {
                                kind: FrameKind::Control,
                                name: "error".into(),
                                payload: msg.into_bytes(),
                            },
                            &meter,
                        )?;
                    }
                    Err(_) => return Ok(()),
                }
            }
            _ => {
                send_frame(
                    &mut writer,
                    &Frame {
                        kind: FrameKind::Control,
                        name: "error".into(),
                        payload: b"unknown frame".to_vec(),
                    },
                    &meter,
                )?;
            }
        }
    }
}

/// Simple blocking client for the protocol above.
pub struct Client {
    sock: TcpStream,
    meter: Meter,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        Ok(Client {
            sock: TcpStream::connect(addr)?,
            meter: Meter::default(),
        })
    }

    /// Classify one image; returns logits.
    pub fn infer(&mut self, image: &[f32]) -> Result<Vec<f32>> {
        let payload: Vec<u8> = image.iter().flat_map(|v| v.to_le_bytes()).collect();
        send_frame(
            &mut self.sock,
            &Frame {
                kind: FrameKind::Control,
                name: "infer".into(),
                payload,
            },
            &self.meter,
        )?;
        let (reply, _) = recv_frame(&mut self.sock, &self.meter)?;
        match reply.name.as_str() {
            "logits" => Ok(reply
                .payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            "error" => anyhow::bail!("server error: {}", String::from_utf8_lossy(&reply.payload)),
            other => anyhow::bail!("unexpected reply {other:?}"),
        }
    }

    pub fn stop_server(&mut self) -> Result<()> {
        send_frame(
            &mut self.sock,
            &Frame {
                kind: FrameKind::Control,
                name: "stop".into(),
                payload: Vec::new(),
            },
            &self.meter,
        )?;
        Ok(())
    }
}
