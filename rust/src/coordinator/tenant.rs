//! Store-backed serving tenants: host any nest `.nq` straight from a
//! [`ModelStore`] — no manifest, no HLO, no PJRT — under one shared
//! [`StoreBudget`] for resident Section-B bytes.
//!
//! [`NestTenant`] serves a deterministic *reference forward*: a linear
//! probe `logits = x·W + b` over the archive's first 2-D quantized
//! tensor. It is not the paper's CNN; it exists so the serving layer's
//! claims — id routing, per-tenant batching, switch atomicity, budget
//! eviction — are *numerically* checkable offline: every reply must
//! equal the part-bit or the full-bit baseline for its model
//! bit-for-bit, so a torn switch or a cross-tenant routing slip shows
//! up as a wrong float, not a narrated assertion (`tests/serving.rs`).
//! With `--features pjrt` and built artifacts, [`Coordinator`]-backed
//! tenants serve the real graphs through the same router.
//!
//! # Forward modes
//!
//! The default forward is **integer-domain** ([`ForwardMode::IntDomain`]):
//! activations are RTN-quantized per image, the matmul runs over the
//! *packed* weight stream (`store::PackedView::gemm_i32_into` — no
//! decode pass, no f32 weight vector ever allocated), and the scales
//! fold into one per-class epilogue — `s_x·2^l·s_w · acc_high` for
//! part-bit (Eq. 10) and `s_x·s_w · (acc_high·2^l + acc_low)` for
//! full-bit (Eq. 6), with the recomposition done on the i64
//! *accumulators* rather than per weight. Upgrade really is "attach
//! bytes": the full-bit forward reads the same section-B words the
//! budget just attached. [`ForwardMode::F32Decode`] keeps the legacy
//! decode-then-matmul path (dequantized exactly the way `ModelManager`
//! does — inflated scales for part-bit, recomposed `w_high·2^l + w_low`
//! for full-bit); `NQ_FORWARD=f32` selects it process-wide, and the
//! differential tests pin both to prove they agree within the
//! activation-quantization error bound.
//!
//! Eviction semantics: when another tenant's upgrade evicts this
//! tenant's Section-B bytes from the shared budget, the next batch
//! observes it and rebuilds part-bit weights from the still-resident
//! section A (zero fetches, zero re-parses — the archive's
//! [`ArchiveStats`] prove it). The packed accounting follows the
//! paper's convention: which *section bytes* are resident decides which
//! variant a tenant serves.
//!
//! [`Coordinator`]: super::Coordinator
//! [`ArchiveStats`]: crate::store::ArchiveStats

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::container::Kind;
use crate::nest::NestConfig;
use crate::quant;
use crate::store::{ModelStore, NqArchive, PayloadView, StoreBudget};

use super::server::TenantExecutor;
use super::{Decision, SwitchCost, Variant};

/// How a [`NestTenant`] computes its forward (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForwardMode {
    /// Dequantization-free: quantized activations × packed weights in
    /// i32, scales folded into a per-class epilogue. The default.
    IntDomain,
    /// Legacy: decode the active variant to f32 once per switch, then
    /// an f32 matmul per batch. `NQ_FORWARD=f32` selects this.
    F32Decode,
}

/// Resolve the `NQ_FORWARD` override (`"f32"` → [`ForwardMode::F32Decode`],
/// anything else or unset → the integer-domain default).
fn forward_mode_from_env() -> ForwardMode {
    match std::env::var("NQ_FORWARD").ok().as_deref() {
        Some(s) if s.eq_ignore_ascii_case("f32") => ForwardMode::F32Decode,
        _ => ForwardMode::IntDomain,
    }
}

/// Activation bitwidth for the int-domain forward: the layout's
/// `act_bits` when it names a packable width, else INT8 (archives
/// written before activation metadata carry 0 there).
fn act_bits_or_default(layout_bits: u8) -> u8 {
    if (2..=16).contains(&layout_bits) {
        layout_bits
    } else {
        8
    }
}

/// One nest archive served through the reference forward.
pub struct NestTenant {
    id: String,
    archive: Arc<NqArchive>,
    budget: Arc<StoreBudget>,
    cfg: NestConfig,
    batch: usize,
    /// Image length == rows of the served weight matrix.
    rows: usize,
    /// Logit count == channels of the served weight matrix.
    classes: usize,
    /// Index of the served 2-D quantized tensor in the layout.
    w_idx: usize,
    variant: Variant,
    mode: ForwardMode,
    /// Activation quantization width for the int-domain forward.
    act_bits: u8,
    /// Dequantized serving weights for the active variant
    /// (`rows * classes`, row-major, channel fastest). **Always empty
    /// in [`ForwardMode::IntDomain`]** — the whole point.
    weights: Vec<f32>,
    bias: Vec<f32>,
    forced_downgrades: u64,
    /// Raw per-channel scales, reused across switches (the fused
    /// kernels take them as-is — no inflated copy, no i32 scratch).
    scratch_scales: Vec<f32>,
    /// Int-domain scratch: quantized activations for one image.
    x_int: Vec<i32>,
    /// Int-domain scratch: `w_high` accumulators, one per class.
    acc_hi: Vec<i32>,
    /// Int-domain scratch: `w_low` accumulators (full-bit only).
    acc_lo: Vec<i32>,
}

impl NestTenant {
    /// Serve `archive` as `id` with `batch_size`-padded batches, paging
    /// section B through `budget`. Launches part-bit (section A only).
    /// The forward mode comes from `NQ_FORWARD` (default: int-domain).
    pub fn from_archive(
        id: impl Into<String>,
        archive: Arc<NqArchive>,
        budget: Arc<StoreBudget>,
        batch_size: usize,
    ) -> Result<NestTenant> {
        Self::with_mode(id, archive, budget, batch_size, forward_mode_from_env())
    }

    /// [`from_archive`](Self::from_archive) with an explicit forward
    /// mode (differential tests pin both sides regardless of env).
    pub fn with_mode(
        id: impl Into<String>,
        archive: Arc<NqArchive>,
        budget: Arc<StoreBudget>,
        batch_size: usize,
        mode: ForwardMode,
    ) -> Result<NestTenant> {
        let id = id.into();
        ensure!(batch_size > 0, "{id}: batch_size must be positive");
        ensure!(
            archive.kind() == Kind::Nest,
            "{id}: serving tenants need a nest container, got {:?}",
            archive.kind()
        );
        let layout = archive.layout()?;
        let cfg = NestConfig::new(layout.n(), layout.h())?;
        let w_idx = layout
            .tensors()
            .iter()
            .position(|t| t.is_quantized() && t.shape().len() == 2)
            .with_context(|| format!("{id}: no 2-D quantized tensor to serve"))?;
        let shape = layout.tensors()[w_idx].shape();
        let (rows, classes) = (shape[0], shape[1]);
        ensure!(rows > 0 && classes > 0, "{id}: degenerate weight shape {shape:?}");
        // optional bias: the first fp32 tensor with one value per class
        let bias = layout
            .tensors()
            .iter()
            .position(|t| !t.is_quantized() && t.count() == classes);
        let act_bits = act_bits_or_default(layout.act_bits());
        let mut tenant = NestTenant {
            id,
            archive,
            budget,
            cfg,
            batch: batch_size,
            rows,
            classes,
            w_idx,
            variant: Variant::PartBit,
            mode,
            act_bits,
            weights: Vec::new(),
            bias: vec![0.0; classes],
            forced_downgrades: 0,
            scratch_scales: Vec::new(),
            x_int: Vec::new(),
            acc_hi: Vec::new(),
            acc_lo: Vec::new(),
        };
        if let Some(b_idx) = bias {
            let part = tenant.archive.part_bit()?;
            let PayloadView::Fp32(v) = part.tensor(b_idx).payload() else {
                bail!("{}: bias tensor is not fp32", tenant.id);
            };
            tenant.bias = v.to_vec();
        }
        tenant.rebuild(Variant::PartBit)?;
        if tenant.mode == ForwardMode::IntDomain && tenant.scratch_scales.len() != tenant.classes {
            // the int epilogue folds one scale per class; an archive
            // whose scale vector doesn't line up with the class axis
            // serves through the decode path instead of failing
            tenant.mode = ForwardMode::F32Decode;
            tenant.rebuild(Variant::PartBit)?;
        }
        Ok(tenant)
    }

    pub fn id(&self) -> &str {
        &self.id
    }

    /// The forward mode this tenant resolved to.
    pub fn mode(&self) -> ForwardMode {
        self.mode
    }

    /// The shared archive handle (byte accounting, residency).
    pub fn archive(&self) -> &Arc<NqArchive> {
        &self.archive
    }

    /// Downgrades forced by budget eviction (observed at batch time).
    pub fn forced_downgrades(&self) -> u64 {
        self.forced_downgrades
    }

    /// Activate a variant. In [`ForwardMode::F32Decode`] this
    /// dequantizes the variant's weights from the archive views into
    /// the serving buffer — one fused kernel pass straight from the
    /// section bytes (`crate::kernels`). In [`ForwardMode::IntDomain`]
    /// no decode happens at all: the views are validated and the scales
    /// cached, and the per-batch forward reads the packed words
    /// directly — switching really is just section residency. Part-bit
    /// reads only resident section-A bytes; full-bit requires section B
    /// already attached (through the budget — this method never
    /// attaches behind its back).
    fn rebuild(&mut self, variant: Variant) -> Result<()> {
        let decode = self.mode == ForwardMode::F32Decode;
        let mut w = std::mem::take(&mut self.weights);
        w.clear();
        match variant {
            Variant::PartBit => {
                let model = self.archive.part_bit()?;
                let PayloadView::Nest { scales, w_high, .. } = model.tensor(self.w_idx).payload()
                else {
                    bail!("{}: served tensor is not a nest payload", self.id);
                };
                scales.read_into(&mut self.scratch_scales);
                if decode {
                    let inflate = self.cfg.scale_inflation();
                    w_high.unpack_dequant_into(&self.scratch_scales, inflate, &mut w);
                }
            }
            Variant::FullBit => {
                ensure!(
                    self.archive.b_resident(),
                    "{}: section B not resident (attach through the budget first)",
                    self.id
                );
                let model = self.archive.full_bit()?;
                let PayloadView::Nest {
                    scales,
                    w_high,
                    w_low: Some(w_low),
                } = model.tensor(self.w_idx).payload()
                else {
                    bail!("{}: full-bit view is missing w_low", self.id);
                };
                scales.read_into(&mut self.scratch_scales);
                if decode {
                    w_high.recompose_dequant_into(
                        &w_low,
                        self.cfg.l(),
                        &self.scratch_scales,
                        &mut w,
                    );
                }
            }
        }
        self.weights = w;
        self.variant = variant;
        // Close the attach→rebuild race: if another tenant's upgrade
        // evicted us between our budgeted attach and the view build
        // above, `full_bit()` silently re-fetched section B outside the
        // budget's ledger. Hand those bytes back and serve part-bit —
        // the evictor won; our accounting stays balanced.
        if variant == Variant::FullBit && !self.budget.is_resident(&self.id) {
            self.archive.release_b();
            return self.rebuild(Variant::PartBit);
        }
        Ok(())
    }

    /// The legacy f32 forward: batch matmul over the decoded weights.
    fn forward_f32(&self, input: &[f32]) -> Vec<f32> {
        // reference forward: logits = x · W + b, accumulation order
        // fixed so replies are bit-comparable against baselines
        let mut out = vec![0f32; self.batch * self.classes];
        for (img, row) in input
            .chunks_exact(self.rows)
            .zip(out.chunks_exact_mut(self.classes))
        {
            row.copy_from_slice(&self.bias);
            for (r, &x) in img.iter().enumerate() {
                let wrow = &self.weights[r * self.classes..(r + 1) * self.classes];
                for (o, &wv) in row.iter_mut().zip(wrow) {
                    *o += x * wv;
                }
            }
        }
        out
    }

    /// The dequantization-free forward: per image, RTN-quantize the
    /// activations, GEMV over the *packed* weight words, and fold every
    /// scale into one per-class epilogue. Part-bit computes
    /// `b + s_x·(2^l·s_w) · acc_high` (Eq. 10); full-bit recomposes on
    /// the accumulators — `b + s_x·s_w · (acc_high·2^l + acc_low)`
    /// (Eq. 6) — so upgrade work is one extra GEMV over the attached
    /// section-B words, never a decode.
    fn forward_int(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; self.batch * self.classes];
        let mut x_int = std::mem::take(&mut self.x_int);
        let mut acc_hi = std::mem::take(&mut self.acc_hi);
        let mut acc_lo = std::mem::take(&mut self.acc_lo);
        let res = (|| -> Result<()> {
            match self.variant {
                Variant::PartBit => {
                    let model = self.archive.part_bit()?;
                    let PayloadView::Nest { w_high, .. } = model.tensor(self.w_idx).payload()
                    else {
                        bail!("{}: served tensor is not a nest payload", self.id);
                    };
                    let inflate = self.cfg.scale_inflation();
                    for (img, row) in input
                        .chunks_exact(self.rows)
                        .zip(out.chunks_exact_mut(self.classes))
                    {
                        let sx = quant::quantize_activations(img, self.act_bits, &mut x_int);
                        w_high.gemm_i32_into(&x_int, self.classes, &mut acc_hi);
                        for (c, o) in row.iter_mut().enumerate() {
                            *o = self.bias[c]
                                + acc_hi[c] as f32 * (sx * (inflate * self.scratch_scales[c]));
                        }
                    }
                }
                Variant::FullBit => {
                    let model = self.archive.full_bit()?;
                    let PayloadView::Nest {
                        w_high,
                        w_low: Some(w_low),
                        ..
                    } = model.tensor(self.w_idx).payload()
                    else {
                        bail!("{}: full-bit view is missing w_low", self.id);
                    };
                    let l = self.cfg.l();
                    for (img, row) in input
                        .chunks_exact(self.rows)
                        .zip(out.chunks_exact_mut(self.classes))
                    {
                        let sx = quant::quantize_activations(img, self.act_bits, &mut x_int);
                        w_high.gemm_i32_into(&x_int, self.classes, &mut acc_hi);
                        w_low.gemm_i32_into(&x_int, self.classes, &mut acc_lo);
                        for (c, o) in row.iter_mut().enumerate() {
                            // recompose on the accumulators (i64: the
                            // shifted i32 sum can exceed i32)
                            let v = ((acc_hi[c] as i64) << l) + acc_lo[c] as i64;
                            *o = self.bias[c] + v as f32 * (sx * self.scratch_scales[c]);
                        }
                    }
                }
            }
            Ok(())
        })();
        self.x_int = x_int;
        self.acc_hi = acc_hi;
        self.acc_lo = acc_lo;
        res?;
        // Mirror rebuild's post-check: if an eviction raced this batch,
        // `full_bit()` above re-fetched section B outside the budget's
        // ledger — hand the bytes back; `reconcile` downgrades us before
        // the next batch.
        if self.variant == Variant::FullBit && !self.budget.is_resident(&self.id) {
            self.archive.release_b();
        }
        Ok(out)
    }

    /// Observe budget eviction: a full-bit tenant whose B bytes are
    /// gone falls back to part-bit before serving the next batch.
    fn reconcile(&mut self) -> Result<()> {
        if self.variant == Variant::FullBit && !self.archive.b_resident() {
            self.rebuild(Variant::PartBit)?;
            self.forced_downgrades += 1;
            crate::telemetry::registry().serving.forced_downgrades.inc();
            crate::nq_trace!(
                crate::telemetry::TraceKind::Switch,
                "{}: forced downgrade (section B evicted)",
                self.id
            );
        }
        Ok(())
    }
}

impl TenantExecutor for NestTenant {
    fn shape(&self) -> (usize, usize, usize) {
        (self.batch, self.rows, self.classes)
    }

    fn run_batch(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        ensure!(
            input.len() == self.batch * self.rows,
            "{}: batch size mismatch: {} vs {}",
            self.id,
            input.len(),
            self.batch * self.rows
        );
        self.reconcile()?;
        if self.variant == Variant::FullBit {
            self.budget.touch(&self.id);
        }
        match self.mode {
            ForwardMode::F32Decode => Ok(self.forward_f32(input)),
            ForwardMode::IntDomain => self.forward_int(input),
        }
    }

    fn switch(&mut self, decision: Decision) -> Result<Option<SwitchCost>> {
        self.reconcile()?;
        let b_bytes = self.archive.section_b_bytes();
        match decision {
            Decision::Stay => Ok(None),
            Decision::SwitchTo(Variant::FullBit) => {
                if self.variant == Variant::FullBit {
                    return Ok(None);
                }
                let t0 = Instant::now();
                self.budget
                    .attach_b(&self.id, &self.archive)
                    .with_context(|| format!("{}: budgeted upgrade", self.id))?;
                if let Err(e) = self.rebuild(Variant::FullBit) {
                    // a failed rebuild must not leave B charged to the
                    // budget while the tenant still serves part-bit
                    self.budget.release_b(&self.id);
                    return Err(e);
                }
                if self.variant != Variant::FullBit {
                    // evicted mid-switch: rebuild's post-check fell back
                    // to part-bit, so no upgrade took effect — don't
                    // report one (the evictor's switch is the real event)
                    return Ok(None);
                }
                Ok(Some(SwitchCost {
                    page_in_bytes: b_bytes,
                    page_out_bytes: 0,
                    micros: t0.elapsed().as_micros(),
                }))
            }
            Decision::SwitchTo(Variant::PartBit) => {
                if self.variant == Variant::PartBit {
                    return Ok(None);
                }
                let t0 = Instant::now();
                self.budget.release_b(&self.id);
                self.rebuild(Variant::PartBit)?;
                Ok(Some(SwitchCost {
                    page_in_bytes: 0,
                    page_out_bytes: b_bytes,
                    micros: t0.elapsed().as_micros(),
                }))
            }
        }
    }

    fn variant(&self) -> Variant {
        self.variant
    }
}

/// Open every nest `.nq` in `dir` through `store` (shared archives,
/// keyed by file stem) and build a tenant per model, all paging section
/// B through one `budget`. Non-nest and unreadable files are skipped.
/// The `nestquant serve --store <dir>` entry point.
pub fn nest_tenants_from_dir(
    dir: &Path,
    store: &ModelStore,
    budget: &Arc<StoreBudget>,
    batch_size: usize,
) -> Result<Vec<(String, NestTenant)>> {
    let mut paths: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("scanning {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "nq"))
        .collect();
    paths.sort();
    let mut tenants = Vec::new();
    for path in paths {
        let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else {
            continue;
        };
        if stem.is_empty() || stem.contains('\n') {
            continue; // ids must be routable AND listable (see serve_tenants)
        }
        // register under the stem ONLY (one id per model in the store);
        // an id someone already claimed is shared, not replaced
        let archive = match store.get(stem) {
            Some(a) => a,
            None => match NqArchive::open(&path) {
                // unreadable, not a container, or not nest: never registered
                Ok(a) if a.kind() == Kind::Nest => store.insert(stem.to_string(), Arc::new(a)),
                _ => continue,
            },
        };
        if archive.kind() != Kind::Nest {
            continue;
        }
        tenants.push((
            stem.to_string(),
            NestTenant::from_archive(stem, archive, Arc::clone(budget), batch_size)
                .with_context(|| format!("tenant for {}", path.display()))?,
        ));
    }
    Ok(tenants)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::int_range;
    use crate::container::synthetic_nest;

    fn tenant(seed: u64, budget: &Arc<StoreBudget>) -> NestTenant {
        let c = synthetic_nest(seed, 8, 4, 32, 6).unwrap();
        let archive = Arc::new(NqArchive::from_container(&c).unwrap());
        NestTenant::from_archive(format!("t{seed}"), archive, Arc::clone(budget), 2).unwrap()
    }

    fn tenant_mode(
        seed: u64,
        n: u8,
        h: u8,
        budget: &Arc<StoreBudget>,
        mode: ForwardMode,
    ) -> NestTenant {
        let c = synthetic_nest(seed, n, h, 32, 6).unwrap();
        let archive = Arc::new(NqArchive::from_container(&c).unwrap());
        let id = format!("m{seed}-{n}-{h}-{mode:?}");
        NestTenant::with_mode(id, archive, Arc::clone(budget), 2, mode).unwrap()
    }

    #[test]
    fn part_and_full_logits_differ_and_are_deterministic() {
        let budget = Arc::new(StoreBudget::new(u64::MAX));
        let mut t = tenant(1, &budget);
        assert_eq!(t.shape(), (2, 32, 6));
        let input: Vec<f32> = (0..64).map(|i| (i as f32 / 64.0) - 0.5).collect();
        let part = t.run_batch(&input).unwrap();
        let part2 = t.run_batch(&input).unwrap();
        assert_eq!(part, part2, "deterministic");
        t.switch(Decision::SwitchTo(Variant::FullBit)).unwrap();
        assert_eq!(t.variant(), Variant::FullBit);
        let full = t.run_batch(&input).unwrap();
        assert_ne!(part, full, "variants must be distinguishable");
        t.switch(Decision::SwitchTo(Variant::PartBit)).unwrap();
        assert_eq!(t.run_batch(&input).unwrap(), part, "downgrade restores part-bit");
        // switch is idempotent per target
        assert!(t.switch(Decision::SwitchTo(Variant::PartBit)).unwrap().is_none());
        assert!(t.switch(Decision::Stay).unwrap().is_none());
    }

    #[test]
    fn eviction_forces_downgrade_at_next_batch() {
        // budget holds exactly one section B
        let probe = {
            let c = synthetic_nest(2, 8, 4, 32, 6).unwrap();
            NqArchive::from_container(&c).unwrap().section_b_bytes()
        };
        let budget = Arc::new(StoreBudget::new(probe));
        let mut a = tenant(2, &budget);
        let mut b = tenant(3, &budget);
        let input = vec![0.25f32; 64];
        a.switch(Decision::SwitchTo(Variant::FullBit)).unwrap();
        let a_full = a.run_batch(&input).unwrap();
        let a_part_baseline = {
            let fresh_budget = Arc::new(StoreBudget::new(u64::MAX));
            let mut fresh = tenant(2, &fresh_budget);
            fresh.run_batch(&input).unwrap()
        };
        // b's upgrade evicts a's section B
        b.switch(Decision::SwitchTo(Variant::FullBit)).unwrap();
        assert!(!a.archive().b_resident());
        assert_eq!(budget.evictions(), 1);
        let a_after = a.run_batch(&input).unwrap();
        assert_eq!(a.forced_downgrades(), 1);
        assert_eq!(a.variant(), Variant::PartBit);
        assert_eq!(a_after, a_part_baseline, "evicted tenant serves part-bit");
        assert_ne!(a_after, a_full);
        // and the forced path never re-read section A or re-parsed
        let s = a.archive().stats();
        assert_eq!(s.a_fetches, 1);
        assert_eq!(s.layout_parses, 1);
    }

    #[test]
    fn int_domain_never_materializes_f32_weights() {
        let budget = Arc::new(StoreBudget::new(u64::MAX));
        let mut t = tenant_mode(5, 8, 4, &budget, ForwardMode::IntDomain);
        assert_eq!(t.mode(), ForwardMode::IntDomain);
        let input: Vec<f32> = (0..64).map(|i| (i as f32 / 40.0) - 0.7).collect();
        assert!(t.weights.is_empty(), "no f32 weights at launch");
        t.run_batch(&input).unwrap();
        t.switch(Decision::SwitchTo(Variant::FullBit)).unwrap();
        t.run_batch(&input).unwrap();
        t.switch(Decision::SwitchTo(Variant::PartBit)).unwrap();
        t.run_batch(&input).unwrap();
        assert!(
            t.weights.is_empty() && t.weights.capacity() == 0,
            "int-domain tenants must never allocate the f32 weight buffer"
        );
    }

    /// The int-domain forward against the f32-decode reference, part-
    /// and full-bit, across nest configs: the only divergence allowed
    /// is activation quantization, so each logit must sit within the
    /// analytic RTN bound `0.5·s_x·Σ_r|w̃[r][c]|` (plus f32 slop).
    #[test]
    fn int_forward_matches_f32_reference_within_activation_bound() {
        let budget = Arc::new(StoreBudget::new(u64::MAX));
        for (seed, n, h) in [(11u64, 8u8, 4u8), (12, 8, 5), (13, 6, 3), (14, 16, 8), (15, 7, 3)] {
            let mut ti = tenant_mode(seed, n, h, &budget, ForwardMode::IntDomain);
            let mut tf = tenant_mode(seed, n, h, &budget, ForwardMode::F32Decode);
            let input: Vec<f32> = (0..64)
                .map(|i| ((i * 7 + seed as usize) % 29) as f32 / 14.0 - 1.0)
                .collect();
            for variant in [Variant::PartBit, Variant::FullBit] {
                if variant == Variant::FullBit {
                    ti.switch(Decision::SwitchTo(Variant::FullBit)).unwrap();
                    tf.switch(Decision::SwitchTo(Variant::FullBit)).unwrap();
                    assert_eq!(ti.variant(), Variant::FullBit);
                    assert_eq!(tf.variant(), Variant::FullBit);
                }
                let got = ti.run_batch(&input).unwrap();
                let want = tf.run_batch(&input).unwrap();
                let (_, act_hi) = int_range(ti.act_bits);
                let (batch, rows, classes) = ti.shape();
                for b in 0..batch {
                    let img = &input[b * rows..(b + 1) * rows];
                    let amax = img.iter().fold(0f32, |a, &v| a.max(v.abs()));
                    let sx = amax.max(1e-12) / act_hi as f32;
                    for c in 0..classes {
                        // Σ_r |w̃[r][c]| from the f32 tenant's decoded copy
                        let wsum: f32 = (0..rows)
                            .map(|r| tf.weights[r * classes + c].abs())
                            .sum();
                        let bound = 0.5 * sx * wsum * 1.001 + 1e-4;
                        let diff = (got[b * classes + c] - want[b * classes + c]).abs();
                        assert!(
                            diff <= bound,
                            "INT({n}|{h}) {variant:?} b={b} c={c}: |{}| > {bound}",
                            diff
                        );
                    }
                }
            }
        }
    }
}
