//! Device simulator (S6): the Raspberry-Pi-class IoT device the paper
//! measures on (§4.1), reduced to what Table 11 / Figs 13-14 actually
//! depend on — byte-accounted storage, memory paging, link bandwidth, and
//! a battery trace driving the switching policy.
//!
//! The paper's switching overheads are *numerical computations over file
//! sizes* (§4.3.3); `MemoryLedger` reproduces that accounting while also
//! enforcing capacity so failure paths (page-in with insufficient memory)
//! are testable.

use anyhow::{bail, ensure, Result};

/// Static hardware profile (Table 2 rows).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Peak compute, GFLOPS (Table 2).
    pub gflops: f64,
    /// Total RAM bytes.
    pub mem_bytes: u64,
    /// Persistent storage bytes available to models.
    pub storage_bytes: u64,
    /// Link bandwidth, bytes/second (802.11ac-class for the Pi).
    pub link_bytes_per_s: f64,
}

/// Raspberry Pi 4B (the paper's deployment device).
pub const RPI_4B: DeviceProfile = DeviceProfile {
    name: "raspberry-pi-4b",
    gflops: 9.69,
    mem_bytes: 4 * 1024 * 1024 * 1024,
    storage_bytes: 8 * 1024 * 1024 * 1024,
    link_bytes_per_s: 30e6, // ~240 Mbps effective 802.11ac
};

/// Raspberry Pi 3B+ (Table 2).
pub const RPI_3B_PLUS: DeviceProfile = DeviceProfile {
    name: "raspberry-pi-3b+",
    gflops: 5.3,
    mem_bytes: 4 * 1024 * 1024 * 1024,
    storage_bytes: 8 * 1024 * 1024 * 1024,
    link_bytes_per_s: 10e6,
};

/// Jetson Nano B01 (Table 2).
pub const JETSON_NANO: DeviceProfile = DeviceProfile {
    name: "jetson-nano-b01",
    gflops: 472.0,
    mem_bytes: 4 * 1024 * 1024 * 1024,
    storage_bytes: 16 * 1024 * 1024 * 1024,
    link_bytes_per_s: 100e6,
};

/// Edge server with RTX 2080Ti (Table 2's comparison row).
pub const EDGE_SERVER: DeviceProfile = DeviceProfile {
    name: "edge-server-2080ti",
    gflops: 13_400.0,
    mem_bytes: 64 * 1024 * 1024 * 1024,
    storage_bytes: 1024 * 1024 * 1024 * 1024,
    link_bytes_per_s: 125e6,
};

/// Cumulative paging statistics (the Table 11 quantities).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PagingStats {
    pub page_in_bytes: u64,
    pub page_out_bytes: u64,
    pub page_in_ops: u64,
    pub page_out_ops: u64,
}

/// Byte-accounted memory ledger with capacity enforcement.
#[derive(Debug)]
pub struct MemoryLedger {
    capacity: u64,
    used: u64,
    stats: PagingStats,
}

impl MemoryLedger {
    pub fn new(capacity: u64) -> Self {
        MemoryLedger {
            capacity,
            used: 0,
            stats: PagingStats::default(),
        }
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn stats(&self) -> PagingStats {
        self.stats
    }

    /// Page bytes into memory (model load / upgrade). Fails when the
    /// capacity would be exceeded — the caller downgrades instead.
    pub fn page_in(&mut self, bytes: u64) -> Result<()> {
        ensure!(
            self.used + bytes <= self.capacity,
            "page-in of {bytes}B exceeds capacity ({} used / {} cap)",
            self.used,
            self.capacity
        );
        self.used += bytes;
        self.stats.page_in_bytes += bytes;
        self.stats.page_in_ops += 1;
        Ok(())
    }

    /// Page bytes out of memory (downgrade / unload).
    pub fn page_out(&mut self, bytes: u64) -> Result<()> {
        if bytes > self.used {
            bail!("page-out of {bytes}B exceeds used {}B", self.used);
        }
        self.used -= bytes;
        self.stats.page_out_bytes += bytes;
        self.stats.page_out_ops += 1;
        Ok(())
    }

    /// Artificially shrink capacity (external memory pressure).
    pub fn set_capacity(&mut self, capacity: u64) {
        self.capacity = capacity;
    }
}

/// A deterministic battery/pressure trace driving the switch policy.
/// Levels are in [0, 1]; the motivation example in §1 switches modes at a
/// threshold (e.g. 50%).
#[derive(Debug, Clone)]
pub struct ResourceTrace {
    levels: Vec<f64>,
    pos: usize,
}

impl ResourceTrace {
    pub fn new(levels: Vec<f64>) -> Self {
        ResourceTrace { levels, pos: 0 }
    }

    /// Linear discharge from `start` to `end` over `steps` samples.
    pub fn discharge(start: f64, end: f64, steps: usize) -> Self {
        let levels = (0..steps)
            .map(|i| start + (end - start) * i as f64 / (steps - 1).max(1) as f64)
            .collect();
        Self::new(levels)
    }

    /// Solar-day trace: discharge overnight, recharge during the day —
    /// the monitoring-camera scenario of §3.3.3.
    pub fn solar_day(steps: usize) -> Self {
        let levels = (0..steps)
            .map(|i| {
                let t = i as f64 / steps as f64 * std::f64::consts::TAU;
                (0.55 - 0.45 * t.cos()).clamp(0.0, 1.0)
            })
            .collect();
        Self::new(levels)
    }

    /// Heterogeneous per-device traces for a fleet simulation: each
    /// device gets a phase-shifted solar day (devices in different time
    /// zones / duty cycles) with bounded per-device noise, deterministic
    /// in `seed`. Drives the fleet playback in examples, benches, and the
    /// `fleet` subcommand.
    pub fn fleet(devices: usize, steps: usize, seed: u64) -> Vec<ResourceTrace> {
        let mut rng = crate::util::prng::Rng::new(seed);
        (0..devices)
            .map(|d| {
                let phase = d as f64 / devices.max(1) as f64 * std::f64::consts::TAU;
                let noise_amp = 0.02 + 0.06 * rng.f64();
                let levels = (0..steps)
                    .map(|i| {
                        let t = i as f64 / steps.max(1) as f64 * std::f64::consts::TAU;
                        let noise = noise_amp * (rng.f64() * 2.0 - 1.0);
                        (0.55 - 0.45 * (t + phase).cos() + noise).clamp(0.0, 1.0)
                    })
                    .collect();
                ResourceTrace::new(levels)
            })
            .collect()
    }

    pub fn next_level(&mut self) -> Option<f64> {
        let v = self.levels.get(self.pos).copied();
        self.pos += 1;
        v
    }

    pub fn len(&self) -> usize {
        self.levels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.levels.is_empty()
    }
}

/// Transmission-time model for a profile's link (Fig 13/14 companion).
pub fn transmission_seconds(profile: &DeviceProfile, bytes: u64) -> f64 {
    bytes as f64 / profile.link_bytes_per_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_accounting() {
        let mut m = MemoryLedger::new(100);
        m.page_in(60).unwrap();
        assert_eq!(m.used(), 60);
        assert_eq!(m.free(), 40);
        m.page_out(20).unwrap();
        assert_eq!(m.used(), 40);
        let s = m.stats();
        assert_eq!(s.page_in_bytes, 60);
        assert_eq!(s.page_out_bytes, 20);
        assert_eq!((s.page_in_ops, s.page_out_ops), (1, 1));
    }

    #[test]
    fn ledger_rejects_overflow_and_underflow() {
        let mut m = MemoryLedger::new(100);
        assert!(m.page_in(101).is_err());
        m.page_in(50).unwrap();
        assert!(m.page_in(51).is_err());
        assert!(m.page_out(51).is_err());
        // failed ops must not corrupt accounting
        assert_eq!(m.used(), 50);
        assert_eq!(m.stats().page_in_bytes, 50);
    }

    #[test]
    fn ledger_never_negative_under_random_ops() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(9);
        let mut m = MemoryLedger::new(1000);
        for _ in 0..10_000 {
            let b = rng.int(0, 300) as u64;
            if rng.bool() {
                let _ = m.page_in(b);
            } else {
                let _ = m.page_out(b);
            }
            assert!(m.used() <= m.capacity());
        }
    }

    #[test]
    fn traces() {
        let mut t = ResourceTrace::discharge(1.0, 0.0, 5);
        assert_eq!(t.len(), 5);
        assert_eq!(t.next_level(), Some(1.0));
        let mut last = 1.0;
        while let Some(v) = t.next_level() {
            assert!(v <= last);
            last = v;
        }
        let s = ResourceTrace::solar_day(100);
        assert!(s.levels.iter().all(|&v| (0.0..=1.0).contains(&v)));
        // charges up during the "day" (max well above start)
        let max = s.levels.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.9 && s.levels[0] < 0.2);
    }

    #[test]
    fn fleet_traces_are_heterogeneous_and_deterministic() {
        let a = ResourceTrace::fleet(4, 64, 42);
        let b = ResourceTrace::fleet(4, 64, 42);
        assert_eq!(a.len(), 4);
        for (ta, tb) in a.iter().zip(&b) {
            assert_eq!(ta.levels, tb.levels, "same seed must reproduce");
            assert!(ta.levels.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
        // phase shift: device 0 and device 2 are anti-phase, so they must
        // differ substantially somewhere
        let diff = a[0]
            .levels
            .iter()
            .zip(&a[2].levels)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max);
        assert!(diff > 0.3, "max diff {diff}");
    }

    #[test]
    fn profiles_sane() {
        assert!(EDGE_SERVER.gflops / RPI_4B.gflops > 1000.0); // paper: ~1400x
        assert!((transmission_seconds(&RPI_4B, 30_000_000) - 1.0).abs() < 1e-9);
    }
}
