//! Failpoints (S15): process-global, deterministic fault injection plus
//! the graceful-degradation primitives built on top of it.
//!
//! A *failpoint* is a named site in production code — `store.read_b`,
//! `fleet.chunk`, `worker.job` — that normally does nothing. When armed
//! (via the `NQ_FAULTS` env var or programmatically from a test), the
//! site fires a fault: a typed error, an injected delay, or a panic.
//! Arming is process-global so chaos tests exercise the exact binaries
//! that ship, and every probabilistic decision comes from a per-site
//! seeded [`Rng`], so a chaos run replays bit-for-bit from its seed.
//!
//! **Zero cost when off**: a disabled check is one relaxed atomic load
//! — the same discipline as `nq_trace!`. Sites only take the registry
//! lock once something is armed.
//!
//! Grammar (semicolon-separated specs):
//!
//! ```text
//! NQ_FAULTS=store.read_b=err:1;fleet.chunk=delay_ms:50;worker.job=panic:0.01@7
//!           site        =mode:arg                                       @seed
//! ```
//!
//! - `err:P`      — return a typed error with probability `P` ∈ [0, 1]
//! - `delay_ms:N` — sleep `N` milliseconds, then continue normally
//! - `panic:P`    — panic with probability `P` (exercises the worker
//!   pool's `catch_unwind` isolation)
//! - `@seed`      — per-site PRNG seed; defaults to a hash of the site
//!   name so replay is deterministic even unseeded
//!
//! Site names follow `layer.verb`: `store.read_a`, `store.read_b`,
//! `store.crc`, `store.map`, `store.evict`, `transport.send`,
//! `transport.recv`, `fleet.chunk`, `fleet.ack`, `client.chunk`,
//! `worker.job`.
//!
//! The module also hosts the two degradation building blocks the
//! serving stack composes with failpoints: [`Breaker`], a per-tenant
//! circuit breaker (N consecutive failures → open with cooldown →
//! half-open probe), and [`Backoff`], deterministic exponential retry
//! delays with full jitter.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::nq_trace;
use crate::telemetry::{registry, TraceKind};
use crate::util::prng::Rng;

// ---------------------------------------------------------------------------
// specs and actions
// ---------------------------------------------------------------------------

/// What an armed failpoint does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultMode {
    /// The site returns a typed error.
    Err,
    /// The site sleeps for the duration, then proceeds normally.
    Delay(Duration),
    /// The site panics (isolated by the worker pool's `catch_unwind`).
    Panic,
}

/// The fired outcome a site must enact (see [`check`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Return an injected error.
    Err,
    /// Sleep this long, then continue.
    Delay(Duration),
    /// Panic now.
    Panic,
}

/// One armed fault: what to do, how often, and from which seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub mode: FaultMode,
    /// Firing probability in [0, 1], evaluated per check from the
    /// site's PRNG (always consumed, so replays stay aligned).
    pub prob: f64,
    /// Checks to pass through untouched before the fault is eligible
    /// (programmatic arming only — e.g. "fail after N chunks").
    pub skip: u64,
    /// Cap on total fires; `None` is unlimited.
    pub max_fires: Option<u64>,
    /// PRNG seed for deterministic replay.
    pub seed: u64,
}

impl FaultSpec {
    /// A spec that fires `mode` on every check (prob 1, no skip/cap).
    pub fn always(mode: FaultMode) -> FaultSpec {
        FaultSpec {
            mode,
            prob: 1.0,
            skip: 0,
            max_fires: None,
            seed: 0,
        }
    }

    /// Builder: pass through the first `n` checks before firing.
    pub fn after(mut self, n: u64) -> FaultSpec {
        self.skip = n;
        self
    }

    /// Builder: fire at most `n` times, then fall dormant.
    pub fn times(mut self, n: u64) -> FaultSpec {
        self.max_fires = Some(n);
        self
    }

    /// Builder: fire with probability `p` from `seed`.
    pub fn with_prob(mut self, p: f64, seed: u64) -> FaultSpec {
        self.prob = p;
        self.seed = seed;
        self
    }
}

struct SiteState {
    spec: FaultSpec,
    rng: Rng,
    checks: u64,
    fires: u64,
}

impl SiteState {
    fn new(spec: FaultSpec) -> SiteState {
        SiteState {
            rng: Rng::new(spec.seed),
            spec,
            checks: 0,
            fires: 0,
        }
    }
}

// ---------------------------------------------------------------------------
// the global registry
// ---------------------------------------------------------------------------

const UNINIT: u8 = 0;
const OFF: u8 = 1;
const ON: u8 = 2;

/// Tri-state gate: `UNINIT` until the first check or arm parses
/// `NQ_FAULTS`, then `OFF`/`ON`. A disabled check is exactly one
/// relaxed load of this.
static STATE: AtomicU8 = AtomicU8::new(UNINIT);

fn sites() -> &'static Mutex<HashMap<String, SiteState>> {
    static SITES: OnceLock<Mutex<HashMap<String, SiteState>>> = OnceLock::new();
    SITES.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Parse `NQ_FAULTS` exactly once (idempotent; bad specs are reported
/// on stderr and skipped so a typo degrades instead of aborting).
/// Must be called with the sites lock held.
fn init_locked(map: &mut HashMap<String, SiteState>) {
    if STATE.load(Ordering::Relaxed) != UNINIT {
        return;
    }
    if let Ok(val) = std::env::var("NQ_FAULTS") {
        for spec in val.split(';').filter(|s| !s.trim().is_empty()) {
            match parse_spec(spec) {
                Ok((site, fs)) => {
                    map.insert(site, SiteState::new(fs));
                }
                Err(e) => eprintln!("NQ_FAULTS: ignoring bad spec {spec:?}: {e}"),
            }
        }
    }
    let armed = !map.is_empty();
    STATE.store(if armed { ON } else { OFF }, Ordering::Relaxed);
}

/// Parse one `site=mode:arg[@seed]` spec.
pub fn parse_spec(spec: &str) -> Result<(String, FaultSpec)> {
    let spec = spec.trim();
    let (site, rest) = spec
        .split_once('=')
        .with_context(|| format!("fault spec {spec:?}: expected site=mode:arg"))?;
    let site = site.trim();
    anyhow::ensure!(
        !site.is_empty() && site.chars().all(|c| c.is_ascii_alphanumeric() || ".-_".contains(c)),
        "fault spec {spec:?}: bad site name {site:?}"
    );
    let (rest, seed) = match rest.rsplit_once('@') {
        Some((r, s)) => (
            r,
            s.trim()
                .parse::<u64>()
                .with_context(|| format!("fault spec {spec:?}: bad seed {s:?}"))?,
        ),
        None => (rest, site_seed(site)),
    };
    let (mode, arg) = rest
        .split_once(':')
        .with_context(|| format!("fault spec {spec:?}: expected mode:arg"))?;
    let (mode, prob) = match mode.trim() {
        "err" => (FaultMode::Err, parse_prob(spec, arg)?),
        "panic" => (FaultMode::Panic, parse_prob(spec, arg)?),
        "delay_ms" => {
            let ms: u64 = arg
                .trim()
                .parse()
                .with_context(|| format!("fault spec {spec:?}: bad delay {arg:?}"))?;
            (FaultMode::Delay(Duration::from_millis(ms)), 1.0)
        }
        other => bail!("fault spec {spec:?}: unknown mode {other:?}"),
    };
    Ok((
        site.to_string(),
        FaultSpec {
            mode,
            prob,
            skip: 0,
            max_fires: None,
            seed,
        },
    ))
}

fn parse_prob(spec: &str, arg: &str) -> Result<f64> {
    let p: f64 = arg
        .trim()
        .parse()
        .with_context(|| format!("fault spec {spec:?}: bad probability {arg:?}"))?;
    anyhow::ensure!(
        (0.0..=1.0).contains(&p),
        "fault spec {spec:?}: probability {p} outside [0, 1]"
    );
    Ok(p)
}

/// Default per-site seed: FNV-1a of the site name, so an unseeded spec
/// still replays deterministically and distinct sites decorrelate.
/// Public so degradation helpers (e.g. [`Backoff`] jitter) can derive
/// stable seeds from names the same way.
pub fn site_seed(site: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in site.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Arm `site` with `spec` (replacing any existing arming). Used by
/// chaos tests and the fleet client's disconnect shim; production
/// arming goes through `NQ_FAULTS`.
pub fn arm(site: impl Into<String>, spec: FaultSpec) {
    let mut g = sites().lock().unwrap_or_else(|e| e.into_inner());
    init_locked(&mut g);
    g.insert(site.into(), SiteState::new(spec));
    STATE.store(ON, Ordering::Relaxed);
}

/// Arm every spec in an `NQ_FAULTS`-grammar string.
pub fn arm_from_str(s: &str) -> Result<()> {
    for spec in s.split(';').filter(|s| !s.trim().is_empty()) {
        let (site, fs) = parse_spec(spec)?;
        arm(site, fs);
    }
    Ok(())
}

/// Disarm one site. Returns whether it was armed.
pub fn disarm(site: &str) -> bool {
    let mut g = sites().lock().unwrap_or_else(|e| e.into_inner());
    init_locked(&mut g);
    let was = g.remove(site).is_some();
    if g.is_empty() {
        STATE.store(OFF, Ordering::Relaxed);
    }
    was
}

/// Disarm everything; checks return to the one-load fast path.
pub fn clear() {
    let mut g = sites().lock().unwrap_or_else(|e| e.into_inner());
    init_locked(&mut g);
    g.clear();
    STATE.store(OFF, Ordering::Relaxed);
}

/// Currently armed site names (sorted; diagnostics).
pub fn armed_sites() -> Vec<String> {
    let mut g = sites().lock().unwrap_or_else(|e| e.into_inner());
    init_locked(&mut g);
    let mut v: Vec<String> = g.keys().cloned().collect();
    v.sort();
    v
}

/// Evaluate the failpoint at `site`. `None` means proceed normally —
/// and costs one relaxed atomic load when nothing is armed anywhere.
/// A fired fault is counted (`nq_faults_fired_total` + per-site) and
/// traced before being returned.
#[inline]
pub fn check(site: &str) -> Option<FaultAction> {
    match STATE.load(Ordering::Relaxed) {
        OFF => None,
        ON => check_armed(site),
        _ => {
            let mut g = sites().lock().unwrap_or_else(|e| e.into_inner());
            init_locked(&mut g);
            drop(g);
            check(site)
        }
    }
}

#[cold]
fn check_armed(site: &str) -> Option<FaultAction> {
    let mut g = sites().lock().unwrap_or_else(|e| e.into_inner());
    let st = g.get_mut(site)?;
    st.checks += 1;
    if st.checks <= st.spec.skip {
        return None;
    }
    if st.spec.max_fires.is_some_and(|m| st.fires >= m) {
        return None;
    }
    // the roll is consumed unconditionally so a replay's PRNG stream
    // stays aligned regardless of prob edits between runs of one seed
    let roll = st.rng.f64();
    if roll >= st.spec.prob {
        return None;
    }
    st.fires += 1;
    let action = match st.spec.mode {
        FaultMode::Err => FaultAction::Err,
        FaultMode::Delay(d) => FaultAction::Delay(d),
        FaultMode::Panic => FaultAction::Panic,
    };
    drop(g);
    registry().faults.site_fired(site);
    nq_trace!(TraceKind::FaultFired, "{site}: {action:?}");
    Some(action)
}

/// Site helper for fallible paths: sleeps through a `Delay`, panics on
/// a `Panic`, returns a typed error on `Err`, and is a no-op otherwise.
#[inline]
pub fn fail_point(site: &str) -> Result<()> {
    match check(site) {
        None => Ok(()),
        Some(FaultAction::Delay(d)) => {
            std::thread::sleep(d);
            Ok(())
        }
        Some(FaultAction::Panic) => panic!("failpoint {site}: injected panic"),
        Some(FaultAction::Err) => Err(anyhow!("failpoint {site}: injected fault")),
    }
}

/// Site helper for paths that branch on a fault instead of returning
/// one (e.g. "treat this CRC as corrupt"): `true` when an `Err`-mode
/// fault fired. Delays sleep, panics panic.
#[inline]
pub fn fires(site: &str) -> bool {
    match check(site) {
        None => false,
        Some(FaultAction::Delay(d)) => {
            std::thread::sleep(d);
            false
        }
        Some(FaultAction::Panic) => panic!("failpoint {site}: injected panic"),
        Some(FaultAction::Err) => true,
    }
}

/// Number of times `site` has fired (from the telemetry ledger, so it
/// survives [`clear`]).
pub fn fired(site: &str) -> u64 {
    registry()
        .faults
        .sites()
        .into_iter()
        .find(|(s, _)| s == site)
        .map(|(_, n)| n)
        .unwrap_or(0)
}

// ---------------------------------------------------------------------------
// circuit breaker
// ---------------------------------------------------------------------------

/// Breaker states, also the gauge encoding surfaced per tenant
/// (`nq_tenant_breaker_state`): 0 closed, 1 open, 2 half-open.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: requests flow.
    Closed,
    /// Tripped: requests are refused until the cooldown elapses.
    Open,
    /// Cooldown elapsed: exactly one probe request is in flight.
    HalfOpen,
}

impl BreakerState {
    pub fn code(self) -> u64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::Open => 1,
            BreakerState::HalfOpen => 2,
        }
    }
}

struct BreakerInner {
    state: BreakerState,
    consecutive_failures: u32,
    opened_at: Option<Instant>,
}

/// Per-tenant circuit breaker: `threshold` consecutive executor
/// failures trip it open; after `cooldown` the next request is
/// admitted as a half-open probe whose outcome closes or re-opens it.
///
/// The caller contract is `admit()` → run → `on_success()` /
/// `on_failure()`. A refused admit should be answered with a typed
/// `busy` reply, never silence.
pub struct Breaker {
    threshold: u32,
    cooldown: Duration,
    inner: Mutex<BreakerInner>,
}

impl Breaker {
    pub fn new(threshold: u32, cooldown: Duration) -> Breaker {
        Breaker {
            threshold: threshold.max(1),
            cooldown,
            inner: Mutex::new(BreakerInner {
                state: BreakerState::Closed,
                consecutive_failures: 0,
                opened_at: None,
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BreakerInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Gate one request. `false` means refuse it (reply `busy`).
    /// Transitions `Open` → `HalfOpen` when the cooldown has elapsed,
    /// admitting the caller as the sole probe.
    pub fn admit(&self) -> bool {
        let mut g = self.lock();
        match g.state {
            BreakerState::Closed => true,
            BreakerState::HalfOpen => false,
            BreakerState::Open => {
                let elapsed = g.opened_at.map(|t| t.elapsed() >= self.cooldown);
                if elapsed.unwrap_or(true) {
                    g.state = BreakerState::HalfOpen;
                    nq_trace!(TraceKind::Breaker, "half-open probe admitted");
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a successful execution: closes from any state.
    pub fn on_success(&self) {
        let mut g = self.lock();
        if g.state != BreakerState::Closed {
            nq_trace!(TraceKind::Breaker, "closed after success");
        }
        g.state = BreakerState::Closed;
        g.consecutive_failures = 0;
        g.opened_at = None;
    }

    /// Record a failed execution. A half-open probe failure re-opens
    /// immediately; otherwise `threshold` consecutive failures trip it.
    pub fn on_failure(&self) {
        let mut g = self.lock();
        match g.state {
            BreakerState::HalfOpen => {
                g.state = BreakerState::Open;
                g.opened_at = Some(Instant::now());
                nq_trace!(TraceKind::Breaker, "re-opened: probe failed");
            }
            _ => {
                g.consecutive_failures += 1;
                if g.consecutive_failures >= self.threshold && g.state == BreakerState::Closed {
                    g.state = BreakerState::Open;
                    g.opened_at = Some(Instant::now());
                    nq_trace!(
                        TraceKind::Breaker,
                        "opened after {} consecutive failures",
                        g.consecutive_failures
                    );
                }
            }
        }
    }

    pub fn state(&self) -> BreakerState {
        self.lock().state
    }
}

// ---------------------------------------------------------------------------
// retry backoff
// ---------------------------------------------------------------------------

/// Deterministic exponential backoff with full jitter: delay `i` is
/// uniform in `[0, min(cap, base·2^i))`, drawn from a seeded [`Rng`]
/// so retry schedules replay in chaos runs.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        Backoff {
            base,
            cap,
            attempt: 0,
            rng: Rng::new(seed),
        }
    }

    /// The jittered delay to sleep before the next retry.
    pub fn next_delay(&mut self) -> Duration {
        let ceil_ms = (self.base.as_millis() as u64)
            .saturating_mul(1u64 << self.attempt.min(20))
            .min(self.cap.as_millis() as u64)
            .max(1);
        self.attempt = self.attempt.saturating_add(1);
        Duration::from_millis((self.rng.f64() * ceil_ms as f64) as u64)
    }

    /// Retries attempted so far.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes tests that touch the process-global registry. Site
    /// names are namespaced `test.*` so armed faults never collide with
    /// real sites exercised by other lib tests in this process.
    static LOCK: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_check_is_none() {
        let _g = locked();
        clear();
        assert_eq!(check("test.nowhere"), None);
        assert!(fail_point("test.nowhere").is_ok());
        assert!(!fires("test.nowhere"));
    }

    #[test]
    fn grammar_parses_the_documented_examples() {
        let (site, fs) =
            parse_spec("store.read_b=err:1").unwrap();
        assert_eq!(site, "store.read_b");
        assert_eq!(fs.mode, FaultMode::Err);
        assert_eq!(fs.prob, 1.0);
        assert_eq!(fs.seed, site_seed("store.read_b"));

        let (site, fs) = parse_spec("fleet.chunk=delay_ms:50").unwrap();
        assert_eq!(site, "fleet.chunk");
        assert_eq!(fs.mode, FaultMode::Delay(Duration::from_millis(50)));

        let (site, fs) = parse_spec("worker.job=panic:0.01@7").unwrap();
        assert_eq!(site, "worker.job");
        assert_eq!(fs.mode, FaultMode::Panic);
        assert_eq!(fs.prob, 0.01);
        assert_eq!(fs.seed, 7);
    }

    #[test]
    fn grammar_rejects_malformed_specs() {
        assert!(parse_spec("no-equals").is_err());
        assert!(parse_spec("site=badmode:1").is_err());
        assert!(parse_spec("site=err:2").is_err(), "prob > 1");
        assert!(parse_spec("site=err:x").is_err());
        assert!(parse_spec("site=delay_ms:-5").is_err());
        assert!(parse_spec("site=err:1@notanum").is_err());
        assert!(parse_spec("bad site=err:1").is_err());
        assert!(parse_spec("=err:1").is_err());
    }

    #[test]
    fn seeded_fire_pattern_replays_bitwise() {
        let _g = locked();
        clear();
        let spec = FaultSpec::always(FaultMode::Err).with_prob(0.5, 42);
        let run = |spec: FaultSpec| {
            arm("test.replay", spec);
            let pat: Vec<bool> = (0..200).map(|_| check("test.replay").is_some()).collect();
            clear();
            pat
        };
        let a = run(spec);
        let b = run(spec);
        assert_eq!(a, b, "same seed, same schedule");
        assert!(a.iter().any(|&f| f) && a.iter().any(|&f| !f));
        let c = run(FaultSpec::always(FaultMode::Err).with_prob(0.5, 43));
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn skip_and_max_fires_bound_the_fault() {
        let _g = locked();
        clear();
        arm(
            "test.bounded",
            FaultSpec::always(FaultMode::Err).after(3).times(2),
        );
        let fired: Vec<bool> = (0..10).map(|_| check("test.bounded").is_some()).collect();
        assert_eq!(
            fired,
            [false, false, false, true, true, false, false, false, false, false]
        );
        clear();
    }

    #[test]
    fn fail_point_and_fires_enact_err_mode() {
        let _g = locked();
        clear();
        arm("test.err", FaultSpec::always(FaultMode::Err));
        let e = fail_point("test.err").unwrap_err();
        assert!(e.to_string().contains("injected fault"), "{e}");
        assert!(fires("test.err"));
        assert!(fired("test.err") >= 2);
        assert!(disarm("test.err"));
        assert!(fail_point("test.err").is_ok());
        clear();
    }

    #[test]
    fn arm_from_str_arms_every_spec() {
        let _g = locked();
        clear();
        arm_from_str("test.a=err:1;test.b=delay_ms:1").unwrap();
        assert_eq!(armed_sites(), ["test.a", "test.b"]);
        assert!(arm_from_str("test.c=bogus:1").is_err());
        clear();
        assert!(armed_sites().is_empty());
    }

    #[test]
    fn delay_mode_sleeps_then_proceeds() {
        let _g = locked();
        clear();
        arm(
            "test.delay",
            FaultSpec::always(FaultMode::Delay(Duration::from_millis(20))),
        );
        let t0 = Instant::now();
        assert!(fail_point("test.delay").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(15));
        clear();
    }

    #[test]
    fn breaker_trips_cools_probes_and_recovers() {
        let b = Breaker::new(3, Duration::from_millis(30));
        assert_eq!(b.state(), BreakerState::Closed);
        for _ in 0..2 {
            assert!(b.admit());
            b.on_failure();
        }
        assert_eq!(b.state(), BreakerState::Closed, "under threshold");
        assert!(b.admit());
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open, "tripped at threshold");
        assert!(!b.admit(), "refused while cooling down");
        std::thread::sleep(Duration::from_millis(40));
        assert!(b.admit(), "cooldown elapsed: probe admitted");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.admit(), "only one probe in flight");
        b.on_failure();
        assert_eq!(b.state(), BreakerState::Open, "probe failure re-opens");
        std::thread::sleep(Duration::from_millis(40));
        assert!(b.admit());
        b.on_success();
        assert_eq!(b.state(), BreakerState::Closed, "probe success closes");
        assert!(b.admit());
    }

    #[test]
    fn breaker_state_codes_are_the_gauge_encoding() {
        assert_eq!(BreakerState::Closed.code(), 0);
        assert_eq!(BreakerState::Open.code(), 1);
        assert_eq!(BreakerState::HalfOpen.code(), 2);
    }

    #[test]
    fn backoff_is_deterministic_jittered_and_capped() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(80);
        let mut a = Backoff::new(base, cap, 9);
        let mut b = Backoff::new(base, cap, 9);
        let da: Vec<Duration> = (0..8).map(|_| a.next_delay()).collect();
        let db: Vec<Duration> = (0..8).map(|_| b.next_delay()).collect();
        assert_eq!(da, db, "same seed, same schedule");
        assert!(da.iter().all(|d| *d < cap), "full jitter stays under cap");
        assert_eq!(a.attempts(), 8);
        // ceilings grow 10,20,40,80,80...: late draws can exceed the
        // first ceiling, proving the exponent actually grows
        assert!(da.iter().skip(3).any(|d| *d >= base), "{da:?}");
    }
}
