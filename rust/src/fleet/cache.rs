//! Zoo-wide section cache: one RAM budget, LRU eviction, section-granular
//! fetches through the store's [`SectionSource`] abstraction.
//!
//! N devices pulling M models must not re-read or duplicate section
//! bytes server-side: the first request for a (model, section) pair
//! fetches exactly that section from its source (for a
//! [`crate::store::FileSource`], a memoized header probe plus one
//! positioned range read — never the whole file), and every concurrent
//! or later request gets the same `Arc` bytes. Loading is **per-key
//! single-flight**: racers for the same section wait on a condvar and
//! then hit, while the source fetch itself happens *outside* the cache
//! lock — a cold multi-megabyte read never blocks hits on unrelated
//! sections.
//!
//! Eviction is LRU over entries other than the one being inserted; a
//! single section larger than the whole budget is allowed to overshoot
//! (it is evicted as soon as something else lands), and in-flight
//! transfers keep their bytes alive through the `Arc` regardless of
//! eviction.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::store::{Bytes, SectionSource};
use crate::telemetry::registry;

use super::Section;

/// Point-in-time cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Bytes fetched from sources (== sum of missed section lengths).
    pub disk_bytes: u64,
    /// Bytes currently resident.
    pub used_bytes: u64,
    /// Entries currently resident.
    pub entries: usize,
}

struct Entry {
    bytes: Bytes,
    last_used: u64,
}

struct Inner {
    map: HashMap<(String, Section), Entry>,
    /// Keys currently being fetched by some thread (single-flight).
    loading: HashSet<(String, Section)>,
    used: u64,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    disk_bytes: u64,
}

/// Shared section cache with a fixed RAM budget, keyed by model id.
pub struct SectionCache {
    budget: u64,
    inner: Mutex<Inner>,
    /// Signalled whenever a load finishes (either way).
    loaded: Condvar,
}

impl SectionCache {
    pub fn new(budget_bytes: u64) -> SectionCache {
        SectionCache {
            budget: budget_bytes,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                loading: HashSet::new(),
                used: 0,
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                disk_bytes: 0,
            }),
            loaded: Condvar::new(),
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Bytes of one section, from cache or the model's source. The
    /// fetch happens outside the lock; concurrent requesters of the
    /// SAME key wait and then hit (single-flight), requesters of other
    /// keys proceed.
    pub fn get(&self, model: &str, source: &dyn SectionSource, section: Section) -> Result<Bytes> {
        let key = (model.to_string(), section);
        let mut guard = self.inner.lock().unwrap();
        loop {
            let g = &mut *guard;
            g.tick += 1;
            let tick = g.tick;
            if let Some(e) = g.map.get_mut(&key) {
                e.last_used = tick;
                g.hits += 1;
                registry().fleet.cache_hits.inc();
                return Ok(e.bytes.clone());
            }
            if g.loading.contains(&key) {
                guard = self.loaded.wait(guard).unwrap();
                continue;
            }
            break; // this thread becomes the loader for `key`
        }
        guard.loading.insert(key.clone());
        drop(guard);

        // ALL I/O — header probe included — happens unlocked; the
        // `loading` entry keeps same-key racers parked on the condvar
        let fetched = source.fetch(section);

        let mut guard = self.inner.lock().unwrap();
        guard.loading.remove(&key);
        self.loaded.notify_all();
        // on error the waiters retry as loaders themselves
        let bytes = fetched?;
        let len = bytes.len() as u64;
        let g = &mut *guard;
        g.tick += 1;
        let tick = g.tick;
        g.misses += 1;
        g.disk_bytes += len;
        registry().fleet.cache_misses.inc();
        g.map.insert(
            key.clone(),
            Entry {
                bytes: bytes.clone(),
                last_used: tick,
            },
        );
        g.used += len;
        // LRU-evict until within budget, never evicting the entry just
        // inserted (a section bigger than the budget overshoots once)
        while g.used > self.budget && g.map.len() > 1 {
            let victim = g
                .map
                .iter()
                .filter(|(k, _)| **k != key)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| (*k).clone());
            let Some(v) = victim else { break };
            if let Some(e) = g.map.remove(&v) {
                g.used -= e.bytes.len() as u64;
                g.evictions += 1;
                registry().fleet.cache_evictions.inc();
            }
        }
        Ok(bytes)
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            disk_bytes: g.disk_bytes,
            used_bytes: g.used,
            entries: g.map.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{self, synthetic_nest};
    use crate::store::FileSource;
    use std::path::{Path, PathBuf};

    fn write_container(dir: &Path, name: &str, seed: u64) -> (Arc<FileSource>, u64, u64) {
        let path = dir.join(format!("{name}.nq"));
        let c = synthetic_nest(seed, 8, 4, 64, 8).unwrap();
        let (_, a, b) = container::write(&path, &c).unwrap();
        (Arc::new(FileSource::new(path)), a, b)
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("nq_cache_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn sections_read_once_then_hit() {
        let dir = temp_dir("hit");
        let (src, a_len, b_len) = write_container(&dir, "m", 1);
        let cache = SectionCache::new(u64::MAX);
        let a1 = cache.get("m", src.as_ref(), Section::A).unwrap();
        let a2 = cache.get("m", src.as_ref(), Section::A).unwrap();
        let b1 = cache.get("m", src.as_ref(), Section::B).unwrap();
        assert_eq!(a1.len() as u64, a_len);
        assert_eq!(b1.len() as u64, b_len);
        assert!(a1.ptr_eq(&a2), "hit must share bytes");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 2, 0));
        assert_eq!(s.disk_bytes, a_len + b_len);
        assert_eq!(s.used_bytes, a_len + b_len);
        assert_eq!(s.entries, 2);
        // bytes match a direct disk read (the integrity trailer rides
        // after the sections and is never cached)
        let whole = std::fs::read(src.path()).unwrap();
        assert_eq!(&whole[..a1.len()], &a1[..]);
        assert_eq!(&whole[a1.len()..a1.len() + b1.len()], &b1[..]);
        assert_eq!(whole.len(), a1.len() + b1.len() + container::TRAILER_LEN);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let dir = temp_dir("lru");
        let (s1, a1, _) = write_container(&dir, "m1", 2);
        let (s2, a2, _) = write_container(&dir, "m2", 3);
        let (s3, a3, _) = write_container(&dir, "m3", 4);
        // budget fits two section-As but not three
        let cache = SectionCache::new(a1 + a2 + a3 / 2);
        cache.get("m1", s1.as_ref(), Section::A).unwrap();
        cache.get("m2", s2.as_ref(), Section::A).unwrap();
        cache.get("m1", s1.as_ref(), Section::A).unwrap(); // refresh m1 → m2 is LRU
        cache.get("m3", s3.as_ref(), Section::A).unwrap(); // evicts m2
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.used_bytes <= cache.budget());
        assert_eq!(s.entries, 2);
        // m1 must still be resident (it was refreshed)
        cache.get("m1", s1.as_ref(), Section::A).unwrap();
        assert_eq!(cache.stats().hits, 2);
    }

    #[test]
    fn oversized_entry_overshoots_once_then_evicts() {
        let dir = temp_dir("big");
        let (s1, a1, _) = write_container(&dir, "m1", 5);
        let (s2, _, _) = write_container(&dir, "m2", 6);
        let cache = SectionCache::new(a1 / 2); // smaller than any section
        let bytes = cache.get("m1", s1.as_ref(), Section::A).unwrap();
        assert_eq!(cache.stats().entries, 1, "oversized entry admitted");
        cache.get("m2", s2.as_ref(), Section::A).unwrap();
        // the oversized entry was evicted, but our Arc keeps it alive
        assert_eq!(bytes.len() as u64, a1);
        let s = cache.stats();
        assert!(s.evictions >= 1);
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn memory_sources_work_too() {
        // the cache is source-agnostic: a synthetic in-memory zoo entry
        // costs zero disk reads
        let c = synthetic_nest(7, 8, 4, 32, 8).unwrap();
        let src = crate::store::MemorySource::from_container(&c).unwrap();
        let cache = SectionCache::new(u64::MAX);
        let a = cache.get("mem", &src, Section::A).unwrap();
        let b = cache.get("mem", &src, Section::B).unwrap();
        let idx = src.index().unwrap();
        assert_eq!(a.len() as u64, idx.section_a_bytes());
        assert_eq!(b.len() as u64, idx.section_b_bytes());
    }
}
