//! Zoo-wide section cache: one RAM budget, LRU eviction, section-granular
//! `.nq` reads.
//!
//! N devices pulling M models must not re-read or duplicate section
//! bytes server-side: the first request for a (container, section) pair
//! reads exactly that byte range from disk ([`container::probe`] +
//! [`container::read_range`] — never the whole file), and every
//! concurrent or later request gets the same `Arc` bytes. Loading is
//! **per-key single-flight**: racers for the same section wait on a
//! condvar and then hit, while the disk read itself happens *outside*
//! the cache lock — a cold multi-megabyte read never blocks hits on
//! unrelated sections.
//!
//! Eviction is LRU over entries other than the one being inserted; a
//! single section larger than the whole budget is allowed to overshoot
//! (it is evicted as soon as something else lands), and in-flight
//! transfers keep their bytes alive through the `Arc` regardless of
//! eviction.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::container::{self, SectionIndex};

use super::Section;

/// Point-in-time cache statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Bytes read from disk (== sum of missed section lengths).
    pub disk_bytes: u64,
    /// Bytes currently resident.
    pub used_bytes: u64,
    /// Entries currently resident.
    pub entries: usize,
}

struct Entry {
    bytes: Arc<Vec<u8>>,
    last_used: u64,
}

struct Inner {
    map: HashMap<(PathBuf, Section), Entry>,
    indexes: HashMap<PathBuf, SectionIndex>,
    /// Keys currently being read from disk by some thread (single-flight).
    loading: HashSet<(PathBuf, Section)>,
    used: u64,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    disk_bytes: u64,
}

/// Shared section cache with a fixed RAM budget.
pub struct SectionCache {
    budget: u64,
    inner: Mutex<Inner>,
    /// Signalled whenever a load finishes (either way).
    loaded: Condvar,
}

impl SectionCache {
    pub fn new(budget_bytes: u64) -> SectionCache {
        SectionCache {
            budget: budget_bytes,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                indexes: HashMap::new(),
                loading: HashSet::new(),
                used: 0,
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                disk_bytes: 0,
            }),
            loaded: Condvar::new(),
        }
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Section layout of a container, probed once (header-only read) and
    /// memoized for the zoo's lifetime.
    pub fn index(&self, path: &Path) -> Result<SectionIndex> {
        let mut guard = self.inner.lock().unwrap();
        let g = &mut *guard;
        if let Some(i) = g.indexes.get(path) {
            return Ok(i.clone());
        }
        let idx = container::probe(path)?;
        g.indexes.insert(path.to_path_buf(), idx.clone());
        Ok(idx)
    }

    /// Bytes of one section, from cache or disk. The disk read happens
    /// outside the lock; concurrent requesters of the SAME key wait and
    /// then hit (single-flight), requesters of other keys proceed.
    pub fn get(&self, path: &Path, section: Section) -> Result<Arc<Vec<u8>>> {
        let key = (path.to_path_buf(), section);
        let mut guard = self.inner.lock().unwrap();
        loop {
            let g = &mut *guard;
            g.tick += 1;
            let tick = g.tick;
            if let Some(e) = g.map.get_mut(&key) {
                e.last_used = tick;
                g.hits += 1;
                return Ok(Arc::clone(&e.bytes));
            }
            if g.loading.contains(&key) {
                guard = self.loaded.wait(guard).unwrap();
                continue;
            }
            break; // this thread becomes the loader for `key`
        }
        let cached_idx = guard.indexes.get(&key.0).cloned();
        guard.loading.insert(key.clone());
        drop(guard);

        // ALL disk I/O — header probe included — happens unlocked; the
        // `loading` entry keeps same-key racers parked on the condvar
        let read = load_section(&key.0, section, cached_idx);

        let mut guard = self.inner.lock().unwrap();
        guard.loading.remove(&key);
        self.loaded.notify_all();
        // on error the waiters retry as loaders themselves
        let (probed_idx, bytes) = read?;
        if let Some(i) = probed_idx {
            guard.indexes.insert(key.0.clone(), i);
        }
        let len = bytes.len() as u64;
        let g = &mut *guard;
        g.tick += 1;
        let tick = g.tick;
        g.misses += 1;
        g.disk_bytes += len;
        let arc = Arc::new(bytes);
        g.map.insert(
            key.clone(),
            Entry {
                bytes: Arc::clone(&arc),
                last_used: tick,
            },
        );
        g.used += len;
        // LRU-evict until within budget, never evicting the entry just
        // inserted (a section bigger than the budget overshoots once)
        while g.used > self.budget && g.map.len() > 1 {
            let victim = g
                .map
                .iter()
                .filter(|(k, _)| **k != key)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| (*k).clone());
            let Some(v) = victim else { break };
            if let Some(e) = g.map.remove(&v) {
                g.used -= e.bytes.len() as u64;
                g.evictions += 1;
            }
        }
        Ok(arc)
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            disk_bytes: g.disk_bytes,
            used_bytes: g.used,
            entries: g.map.len(),
        }
    }
}

/// The unlocked I/O half of [`SectionCache::get`]: probe the header if
/// the index wasn't memoized yet, then read the section's byte range.
/// Returns the newly probed index (for memoization) alongside the bytes.
fn load_section(
    path: &Path,
    section: Section,
    idx: Option<SectionIndex>,
) -> Result<(Option<SectionIndex>, Vec<u8>)> {
    let (idx, probed) = match idx {
        Some(i) => (i, None),
        None => {
            let i = container::probe(path)?;
            (i.clone(), Some(i))
        }
    };
    let range = match section {
        Section::A => idx.section_a(),
        Section::B => idx.section_b(),
    };
    Ok((probed, container::read_range(path, range)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::synthetic_nest;

    fn write_container(dir: &Path, name: &str, seed: u64) -> (PathBuf, u64, u64) {
        let path = dir.join(format!("{name}.nq"));
        let c = synthetic_nest(seed, 8, 4, 64, 8).unwrap();
        let (_, a, b) = container::write(&path, &c).unwrap();
        (path, a, b)
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("nq_cache_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn sections_read_once_then_hit() {
        let dir = temp_dir("hit");
        let (path, a_len, b_len) = write_container(&dir, "m", 1);
        let cache = SectionCache::new(u64::MAX);
        let a1 = cache.get(&path, Section::A).unwrap();
        let a2 = cache.get(&path, Section::A).unwrap();
        let b1 = cache.get(&path, Section::B).unwrap();
        assert_eq!(a1.len() as u64, a_len);
        assert_eq!(b1.len() as u64, b_len);
        assert!(Arc::ptr_eq(&a1, &a2), "hit must share bytes");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (1, 2, 0));
        assert_eq!(s.disk_bytes, a_len + b_len);
        assert_eq!(s.used_bytes, a_len + b_len);
        assert_eq!(s.entries, 2);
        // bytes match a direct disk read
        let whole = std::fs::read(&path).unwrap();
        assert_eq!(&whole[..a1.len()], &a1[..]);
        assert_eq!(&whole[a1.len()..], &b1[..]);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let dir = temp_dir("lru");
        let (p1, a1, _) = write_container(&dir, "m1", 2);
        let (p2, a2, _) = write_container(&dir, "m2", 3);
        let (p3, a3, _) = write_container(&dir, "m3", 4);
        // budget fits two section-As but not three
        let cache = SectionCache::new(a1 + a2 + a3 / 2);
        cache.get(&p1, Section::A).unwrap();
        cache.get(&p2, Section::A).unwrap();
        cache.get(&p1, Section::A).unwrap(); // refresh m1 → m2 is LRU
        cache.get(&p3, Section::A).unwrap(); // evicts m2
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.used_bytes <= cache.budget());
        assert_eq!(s.entries, 2);
        // m1 must still be resident (it was refreshed)
        cache.get(&p1, Section::A).unwrap();
        assert_eq!(cache.stats().hits, 2);
    }

    #[test]
    fn oversized_entry_overshoots_once_then_evicts() {
        let dir = temp_dir("big");
        let (p1, a1, _) = write_container(&dir, "m1", 5);
        let (p2, _, _) = write_container(&dir, "m2", 6);
        let cache = SectionCache::new(a1 / 2); // smaller than any section
        let bytes = cache.get(&p1, Section::A).unwrap();
        assert_eq!(cache.stats().entries, 1, "oversized entry admitted");
        cache.get(&p2, Section::A).unwrap();
        // the oversized entry was evicted, but our Arc keeps it alive
        assert_eq!(bytes.len() as u64, a1);
        let s = cache.stats();
        assert!(s.evictions >= 1);
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn index_memoized() {
        let dir = temp_dir("idx");
        let (path, a_len, b_len) = write_container(&dir, "m", 7);
        let cache = SectionCache::new(u64::MAX);
        let i1 = cache.index(&path).unwrap();
        let i2 = cache.index(&path).unwrap();
        assert_eq!(i1, i2);
        assert_eq!(i1.section_a_bytes(), a_len);
        assert_eq!(i1.section_b_bytes(), b_len);
    }
}
