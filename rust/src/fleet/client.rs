//! Device-side fleet client: the IoT endpoint of the distribution
//! protocol. Pulls sections as acked chunk streams (resumable), reports
//! resource levels, obeys upgrade/downgrade advice, and plays back a
//! whole resource trace against a live server — the fleet-scale version
//! of `coordinator::run_trace`.
//!
//! [`RemoteSource`] adapts a client connection into a
//! [`crate::store::SectionSource`], so a device can open a
//! `store::NqArchive` over a model it never had on disk and get the
//! same typed part-bit/full-bit views as a local file.

use std::net::{SocketAddr, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::container::SectionIndex;
use crate::coordinator::{Decision, Variant};
use crate::device::{MemoryLedger, ResourceTrace};
use crate::faults;
use crate::nq_trace;
use crate::store::{Bytes, SectionSource};
use crate::telemetry::{registry, TraceKind};
use crate::transport::{ack_frame, parse_chunk, recv_frame, send_frame, Frame, FrameKind, Meter};

use super::{control, decode_index, decode_index2, encode_pull, encode_section_req, Section};

/// Outcome of one [`FleetClient::pull_section`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PullOutcome {
    /// Total section length (learned from the first chunk header).
    pub total_len: u64,
    /// Offset reached (== `total_len` iff `completed`).
    pub received_to: u64,
    /// Payload bytes moved by THIS call (excludes earlier attempts).
    pub payload_bytes: u64,
    /// Chunks received and acked by this call.
    pub chunks: usize,
    /// Whether the section is now fully received.
    pub completed: bool,
}

/// One device's connection to the fleet server.
pub struct FleetClient {
    sock: TcpStream,
    meter: Meter,
    pub device_id: String,
}

impl FleetClient {
    /// Connect and register `device_id`. Reconnecting with the same id
    /// resumes the server-side session (residency, policy, resume
    /// offsets).
    pub fn connect(addr: SocketAddr, device_id: &str, timeout: Duration) -> Result<FleetClient> {
        let sock = TcpStream::connect(addr).context("connect fleet server")?;
        sock.set_read_timeout(Some(timeout))?;
        let mut c = FleetClient {
            sock,
            meter: Meter::default(),
            device_id: device_id.to_string(),
        };
        let reply = c.request(control("hello", device_id.as_bytes().to_vec()))?;
        ensure!(reply.name == "ok", "hello rejected: {:?}", reply.name);
        Ok(c)
    }

    /// Wire bytes (sent, received) from this device's perspective.
    pub fn wire(&self) -> (u64, u64) {
        self.meter.snapshot()
    }

    fn request(&mut self, frame: Frame) -> Result<Frame> {
        send_frame(&mut self.sock, &frame, &self.meter)?;
        let (reply, _) = recv_frame(&mut self.sock, &self.meter)?;
        if reply.kind == FrameKind::Control && reply.name == "error" {
            bail!("server error: {}", String::from_utf8_lossy(&reply.payload));
        }
        Ok(reply)
    }

    /// Section layout of a zoo model, served from the server's memoized
    /// header probe — one wire round-trip, no payload bytes. Tries the
    /// checksummed v2 command first and falls back to the v1 form
    /// against pre-checksum servers (whose artifacts carry no trailer
    /// to verify anyway), so mixed-version fleets keep paging.
    pub fn model_index(&mut self, model: &str) -> Result<SectionIndex> {
        match self.request(control("index2", model.as_bytes().to_vec())) {
            Ok(reply) => {
                ensure!(reply.name == "index2", "unexpected reply {:?}", reply.name);
                decode_index2(&reply.payload)
            }
            Err(e) if format!("{e}").contains("unknown command") => {
                let reply = self.request(control("index", model.as_bytes().to_vec()))?;
                ensure!(reply.name == "index", "unexpected reply {:?}", reply.name);
                decode_index(&reply.payload)
            }
            Err(e) => Err(e),
        }
    }

    /// List the server's zoo model ids (newline-joined on the wire) —
    /// the discovery step before opening one as a [`RemoteSource`].
    pub fn models(&mut self) -> Result<Vec<String>> {
        let reply = self.request(control("models", Vec::new()))?;
        ensure!(reply.name == "models", "unexpected reply {:?}", reply.name);
        crate::transport::decode_model_list(&reply.payload)
    }

    /// Ask the server where a previous transfer of (model, section) got
    /// to — the resume offset (0 when never started or dropped).
    pub fn server_offset(&mut self, model: &str, section: Section) -> Result<u64> {
        let reply = self.request(control("offset", encode_section_req(model, section)))?;
        ensure!(reply.name == "offset", "unexpected reply {:?}", reply.name);
        ensure!(reply.payload.len() == 8, "bad offset payload");
        Ok(u64::from_le_bytes(reply.payload[..].try_into().unwrap()))
    }

    /// Report a resource level and get the server's policy decision.
    pub fn report_level(&mut self, level: f64) -> Result<Decision> {
        let reply = self.request(control("level", level.to_le_bytes().to_vec()))?;
        ensure!(reply.name == "advice", "unexpected reply {:?}", reply.name);
        Decision::from_wire(std::str::from_utf8(&reply.payload)?)
    }

    /// Server-side session state for this device: current policy variant
    /// and whether the server believes Section B is fully resident.
    pub fn server_state(&mut self, model: &str) -> Result<(Variant, bool)> {
        let reply = self.request(control("state", model.as_bytes().to_vec()))?;
        ensure!(reply.name == "state", "unexpected reply {:?}", reply.name);
        ensure!(reply.payload.len() == 2, "bad state payload");
        let variant = match reply.payload[0] {
            0 => Variant::PartBit,
            1 => Variant::FullBit,
            v => bail!("unknown variant tag {v}"),
        };
        Ok((variant, reply.payload[1] != 0))
    }

    /// Tell the server this device paged a section out (downgrade).
    pub fn notify_dropped(&mut self, model: &str, section: Section) -> Result<()> {
        let reply = self.request(control("dropped", encode_section_req(model, section)))?;
        ensure!(reply.name == "ok", "unexpected reply {:?}", reply.name);
        Ok(())
    }

    /// Pull one section starting at `offset`, acking each chunk into
    /// `sink`, which grows only as data actually arrives (the header's
    /// `total_len` is untrusted and never drives an allocation); earlier
    /// bytes from a previous attempt are preserved.
    ///
    /// `max_chunks` bounds how many chunks to ack before returning early
    /// with `completed == false` — tests and the CLI use it to simulate a
    /// device dying mid-transfer (drop the client afterwards to cut the
    /// connection; the server keeps the last acked offset for resume).
    pub fn pull_section(
        &mut self,
        model: &str,
        section: Section,
        offset: u64,
        sink: &mut Vec<u8>,
        max_chunks: Option<usize>,
    ) -> Result<PullOutcome> {
        self.pull_section_deadline(model, section, offset, sink, max_chunks, None)
    }

    /// [`FleetClient::pull_section`] with a whole-transfer deadline: the
    /// per-frame read timeout bounds one silent socket, but a slow
    /// trickle of chunks can stretch a fetch indefinitely — the deadline
    /// caps the *total* wall time. On expiry the pull fails with the
    /// reached offset in the error; every acked chunk is already
    /// recorded server-side, so a later pull resumes from there
    /// ([`FleetClient::resume_section`]).
    pub fn pull_section_deadline(
        &mut self,
        model: &str,
        section: Section,
        offset: u64,
        sink: &mut Vec<u8>,
        max_chunks: Option<usize>,
        deadline: Option<Instant>,
    ) -> Result<PullOutcome> {
        // a resume may only continue where the sink left off — pulling
        // from beyond it would zero-fill the gap and silently corrupt
        // the reassembled section
        ensure!(
            offset <= sink.len() as u64,
            "pull offset {offset} beyond sink length {} (restart from 0 or the sink's end)",
            sink.len()
        );
        send_frame(
            &mut self.sock,
            &control("pull", encode_pull(model, section, offset)),
            &self.meter,
        )?;
        let mut pos = offset;
        let mut chunks = 0usize;
        loop {
            // Failpoint `client.chunk`: the device-side stand-in for a
            // flaky edge link — cut the connection exactly as a real
            // mid-pull death would (acked chunks stay resumable
            // server-side). `inject_disconnect_after_chunks` arms this.
            if faults::fires("client.chunk") {
                let _ = self.sock.shutdown(std::net::Shutdown::Both);
                bail!(
                    "connection lost pulling {model} section {section} at offset {pos} (injected)"
                );
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    // the transfer is mid-stream: chunk frames for this
                    // pull may still be in flight, so this connection can
                    // no longer be trusted for request/response — kill it
                    // loudly rather than let a later request read a stale
                    // chunk as its reply
                    let _ = self.sock.shutdown(std::net::Shutdown::Both);
                    bail!(
                        "fetch of {model} section {section} timed out at offset {pos} \
                         (acked chunks are resumable on a fresh connection)"
                    );
                }
            }
            let (frame, _) = recv_frame(&mut self.sock, &self.meter)?;
            if frame.kind == FrameKind::Control && frame.name == "error" {
                bail!("server error: {}", String::from_utf8_lossy(&frame.payload));
            }
            let (header, data) = parse_chunk(&frame)?;
            ensure!(
                header.offset == pos,
                "chunk at {}, expected {pos}",
                header.offset
            );
            let end = header.end(data.len());
            // grow with received bytes only — a lying total_len cannot
            // force a large allocation (cf. recv_frame's capped reads)
            if (sink.len() as u64) < end {
                sink.resize(end as usize, 0);
            }
            sink[pos as usize..end as usize].copy_from_slice(data);
            send_frame(&mut self.sock, &ack_frame(header.xfer_id, end), &self.meter)?;
            pos = end;
            chunks += 1;
            let completed = pos >= header.total_len;
            if completed || max_chunks.is_some_and(|k| chunks >= k) {
                return Ok(PullOutcome {
                    total_len: header.total_len,
                    received_to: pos,
                    payload_bytes: pos - offset,
                    chunks,
                    completed,
                });
            }
        }
    }

    /// Resume (or start) a section pull from the server's recorded ack
    /// offset — clamped to what `sink` actually holds, so a device that
    /// lost its local copy (fresh process, empty sink) re-pulls the real
    /// bytes instead of trusting the server's ack history.
    pub fn resume_section(
        &mut self,
        model: &str,
        section: Section,
        sink: &mut Vec<u8>,
    ) -> Result<PullOutcome> {
        let offset = self
            .server_offset(model, section)?
            .min(sink.len() as u64);
        self.pull_section(model, section, offset, sink, None)
    }

    /// Shut the whole server down (tests / CLI teardown).
    pub fn stop_server(&mut self) -> Result<()> {
        send_frame(&mut self.sock, &control("stop", Vec::new()), &self.meter)?;
        Ok(())
    }

    /// Play a resource trace against the server: provision Section A
    /// (part-bit launch), then follow upgrade/downgrade advice, paging
    /// Section B in (resumable pull) and out (drop + notify) against the
    /// device's memory ledger. Returns the lifecycle report.
    pub fn playback(
        &mut self,
        model: &str,
        mut trace: ResourceTrace,
        ledger: &mut MemoryLedger,
    ) -> Result<PlaybackReport> {
        let mut sec_a = Vec::new();
        let mut sec_b = Vec::new();
        let out = self.pull_section(model, Section::A, 0, &mut sec_a, None)?;
        ensure!(out.completed, "section A pull incomplete");
        ledger.page_in(out.total_len).context("section A page-in")?;
        let mut report = PlaybackReport {
            section_a_bytes: out.total_len,
            payload_pulled: out.payload_bytes,
            ..PlaybackReport::default()
        };
        let mut b_len = 0u64;
        let mut have_b = false;
        // reconcile with the server's persisted session: a reconnecting
        // device whose policy state is already full-bit must hold Section
        // B before following further advice (resume_section re-pulls the
        // bytes this process doesn't actually have)
        let (variant, _) = self.server_state(model)?;
        if variant == Variant::FullBit {
            let out = self.resume_section(model, Section::B, &mut sec_b)?;
            ensure!(out.completed, "section B reconcile incomplete");
            b_len = out.total_len;
            report.section_b_bytes = b_len;
            report.payload_pulled += out.payload_bytes;
            ledger.page_in(b_len).context("reconcile page-in")?;
            have_b = true;
        }
        while let Some(level) = trace.next_level() {
            report.steps += 1;
            match self.report_level(level.clamp(0.0, 1.0))? {
                Decision::Stay => {}
                Decision::SwitchTo(Variant::FullBit) => {
                    let out = self.resume_section(model, Section::B, &mut sec_b)?;
                    ensure!(out.completed, "section B pull incomplete");
                    b_len = out.total_len;
                    report.section_b_bytes = b_len;
                    report.payload_pulled += out.payload_bytes;
                    ledger.page_in(b_len).context("upgrade page-in")?;
                    have_b = true;
                    report.upgrades += 1;
                }
                Decision::SwitchTo(Variant::PartBit) => {
                    ensure!(have_b, "downgrade advice without section B resident");
                    ledger.page_out(b_len).context("downgrade page-out")?;
                    self.notify_dropped(model, Section::B)?;
                    have_b = false;
                    report.downgrades += 1;
                }
            }
        }
        report.final_variant = if have_b {
            Variant::FullBit
        } else {
            Variant::PartBit
        };
        Ok(report)
    }
}

/// Lifecycle summary of one device's [`FleetClient::playback`].
#[derive(Debug, Clone, Copy)]
pub struct PlaybackReport {
    pub steps: usize,
    pub upgrades: u64,
    pub downgrades: u64,
    pub section_a_bytes: u64,
    pub section_b_bytes: u64,
    /// Section payload bytes actually transferred (A + every B page-in).
    pub payload_pulled: u64,
    pub final_variant: Variant,
}

impl Default for PlaybackReport {
    fn default() -> Self {
        PlaybackReport {
            steps: 0,
            upgrades: 0,
            downgrades: 0,
            section_a_bytes: 0,
            section_b_bytes: 0,
            payload_pulled: 0,
            final_variant: Variant::PartBit,
        }
    }
}

// ---------------------------------------------------------------------------
// RemoteSource: the fleet transport as a store SectionSource
// ---------------------------------------------------------------------------

/// One zoo model behind a fleet server, exposed as a
/// [`SectionSource`]: `index` is one wire round-trip, `fetch` is a
/// resumable chunked pull. Open a `store::NqArchive` over it and the
/// whole store API — typed views, attach/release, byte accounting —
/// works against remote bytes.
///
/// The client connection is serialized behind a mutex (the protocol is
/// request/response per connection). A fetch returns only complete
/// sections — an archive never holds partial bytes — but it is NOT
/// all-or-nothing on the wire: when a pull dies mid-transfer, the fetch
/// reconnects under the same device id and resumes from the server's
/// last recorded ack instead of byte zero (up to
/// [`RemoteSource::FETCH_ATTEMPTS`] attempts per fetch, with jittered
/// exponential backoff between attempts so a knocked-out fleet does not
/// stampede back in lockstep). Resumed vs rewound bytes are counted in
/// the telemetry registry (`nq_fleet_resumed_bytes` /
/// `nq_fleet_restarted_bytes`).
///
/// Every fetch runs under a whole-transfer deadline
/// ([`RemoteSource::DEFAULT_FETCH_TIMEOUT`] unless overridden with
/// [`RemoteSource::set_fetch_timeout`]): the per-frame read timeout only
/// bounds one silent socket, while the deadline bounds a server that
/// trickles chunks forever — a hung fetch surfaces as an error instead
/// of wedging the archive open.
pub struct RemoteSource {
    client: Mutex<FleetClient>,
    model: String,
    addr: SocketAddr,
    fetch_timeout: Option<Duration>,
    /// Memoized index (one wire round-trip): section geometry plus the
    /// integrity checksums every completed fetch is verified against.
    index: std::sync::OnceLock<SectionIndex>,
}

impl RemoteSource {
    /// Default whole-fetch deadline: generous for a section on a slow
    /// edge link, far below "wedged forever".
    pub const DEFAULT_FETCH_TIMEOUT: Duration = Duration::from_secs(120);

    /// How many pull attempts one fetch makes before giving up (the
    /// first plus the reconnect-and-resume retries).
    pub const FETCH_ATTEMPTS: usize = 3;

    /// Connect a fresh device session and bind it to `model`.
    pub fn connect(
        addr: SocketAddr,
        device_id: &str,
        model: impl Into<String>,
        timeout: Duration,
    ) -> Result<RemoteSource> {
        Ok(RemoteSource::new(
            FleetClient::connect(addr, device_id, timeout)?,
            model,
        ))
    }

    /// Wrap an existing client connection.
    pub fn new(client: FleetClient, model: impl Into<String>) -> RemoteSource {
        let addr = client
            .sock
            .peer_addr()
            .unwrap_or_else(|_| SocketAddr::from(([0, 0, 0, 0], 0)));
        RemoteSource {
            client: Mutex::new(client),
            model: model.into(),
            addr,
            fetch_timeout: Some(RemoteSource::DEFAULT_FETCH_TIMEOUT),
            index: std::sync::OnceLock::new(),
        }
    }

    /// Drop the pull connection after `chunks` acked chunks (one-shot).
    /// The fetch then reconnects and resumes from the server's recorded
    /// ack — the deterministic stand-in for a flaky edge link, used by
    /// tests and the fleet demo. Thin shim over the failpoint registry:
    /// arms the `client.chunk` site, which `pull_section_deadline`
    /// checks once per chunk (equivalent to
    /// `NQ_FAULTS=client.chunk=err:1` with a skip count).
    pub fn inject_disconnect_after_chunks(&self, chunks: usize) {
        faults::arm(
            "client.chunk",
            faults::FaultSpec::always(faults::FaultMode::Err)
                .after(chunks as u64)
                .times(1),
        );
    }

    /// The memoized index, fetching it over the held client connection
    /// on first use.
    fn index_via(&self, c: &mut FleetClient) -> Result<SectionIndex> {
        if let Some(i) = self.index.get() {
            return Ok(i.clone());
        }
        let idx = c.model_index(&self.model)?;
        Ok(self.index.get_or_init(|| idx).clone())
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Override the per-fetch deadline (`None` disables it).
    pub fn set_fetch_timeout(&mut self, timeout: Option<Duration>) {
        self.fetch_timeout = timeout;
    }

    /// Builder form of [`RemoteSource::set_fetch_timeout`].
    pub fn with_fetch_timeout(mut self, timeout: Option<Duration>) -> RemoteSource {
        self.fetch_timeout = timeout;
        self
    }

    /// Wire bytes (sent, received) of the underlying connection.
    pub fn wire(&self) -> (u64, u64) {
        self.client.lock().unwrap().wire()
    }

    /// Verify a reassembled section against the artifact's integrity
    /// trailer: chunked transfer + resume must hand the archive exactly
    /// the bytes the packer checksummed. An index failure fails the
    /// fetch — silently skipping verification would defeat the trailer
    /// exactly when the link is flaky. (In practice the index is
    /// memoized from archive open, so this never costs an extra
    /// round-trip.)
    fn verify(&self, c: &mut FleetClient, section: Section, sink: Vec<u8>) -> Result<Bytes> {
        let idx = self
            .index_via(c)
            .with_context(|| format!("index for checksum verification of {}", self.model))?;
        if let Some(ck) = idx.checksums {
            let want = match section {
                Section::A => ck.a,
                Section::B => ck.b,
            };
            ensure!(
                crate::util::crc64::crc64(&sink) == want,
                "section {section} of {} failed checksum after reassembly",
                self.model
            );
        }
        Ok(sink.into())
    }
}

impl SectionSource for RemoteSource {
    fn index(&self) -> Result<SectionIndex> {
        let mut c = self.client.lock().unwrap();
        self.index_via(&mut c)
    }

    fn fetch(&self, section: Section) -> Result<Bytes> {
        let mut c = self.client.lock().unwrap();
        let mut sink = Vec::new();
        let mut last_err = None;
        // Jittered exponential backoff between resume attempts: a fleet
        // of devices knocked offline by one server hiccup must not
        // stampede back in lockstep. Seeded from the model name so a
        // chaos run replays bitwise.
        let mut backoff = faults::Backoff::new(
            Duration::from_millis(10),
            Duration::from_millis(500),
            faults::site_seed(&self.model),
        );
        for attempt in 0..RemoteSource::FETCH_ATTEMPTS {
            let deadline = self.fetch_timeout.map(|t| Instant::now() + t);
            let offset = sink.len() as u64;
            match c.pull_section_deadline(&self.model, section, offset, &mut sink, None, deadline)
            {
                Ok(out) if out.completed => {
                    return self.verify(&mut c, section, sink);
                }
                Ok(out) => {
                    // a capped pull (max_chunks) stands in for a
                    // connection dying after that many acked chunks
                    let _ = c.sock.shutdown(std::net::Shutdown::Both);
                    last_err = Some(anyhow!(
                        "connection lost pulling section {section} of {} at {}/{}",
                        self.model,
                        out.received_to,
                        out.total_len
                    ));
                }
                Err(e) => last_err = Some(e),
            }
            // a failed pull aborts mid-stream (a deadline expiry even
            // shuts the socket down), so the connection is no longer on
            // a request/response boundary. Back off (jittered), then
            // reconnect under the same device id — the server resumes
            // the session, so its last recorded ack is this fetch's
            // resume point. If reconnecting fails, the dead client
            // stays and later fetches error loudly.
            std::thread::sleep(backoff.next_delay());
            let device_id = c.device_id.clone();
            let timeout = c
                .sock
                .read_timeout()
                .ok()
                .flatten()
                .unwrap_or(RemoteSource::DEFAULT_FETCH_TIMEOUT);
            let Ok(fresh) = FleetClient::connect(self.addr, &device_id, timeout) else {
                break;
            };
            *c = fresh;
            if attempt + 1 >= RemoteSource::FETCH_ATTEMPTS {
                break;
            }
            // resume from the server's ack, clamped to what the sink
            // actually holds; everything past it is re-pulled
            let prev = sink.len() as u64;
            let acked = c
                .server_offset(&self.model, section)
                .unwrap_or(0)
                .min(prev);
            sink.truncate(acked as usize);
            registry().fleet.resumed_bytes.add(acked);
            registry().fleet.restarted_bytes.add(prev - acked);
            nq_trace!(
                TraceKind::ChunkRetry,
                "retrying section {section} of {} from {acked} (kept {acked} B, rewound {} B)",
                self.model,
                prev - acked
            );
        }
        Err(last_err.unwrap_or_else(|| {
            anyhow!("section {section} fetch of {} failed", self.model)
        }))
    }

    fn describe(&self) -> String {
        format!("fleet://{}/{}", self.addr, self.model)
    }
}
