//! Fleet distribution subsystem (S10): the edge-side serving layer that
//! turns the single-device NestQuant reproduction into a multi-tenant
//! system (§4.3.1 at fleet scale).
//!
//! ```text
//!                        ┌────────────────────────────────────────┐
//!   device 0 ──framed────│ FleetServer                            │
//!   device 1 ──TCP───────│   SessionTable   (residency + policy)  │
//!      ⋮                 │   SectionCache   (zoo-wide RAM budget) │
//!   device N ────────────│   Zoo            (model id → .nq path) │
//!                        └────────────────────────────────────────┘
//! ```
//!
//! Three properties the paper's one-device prototype lacks:
//!
//! * **Tracked residency** — the server knows which (arch, n, h)
//!   container and which sections every device holds, so upgrade and
//!   downgrade advice (driven through the existing
//!   `coordinator::policy` hysteresis) moves only Section-B deltas.
//! * **Resumable delta paging** — section transfers are chunked
//!   ([`crate::transport::ChunkHeader`]) with per-chunk acks; an
//!   interrupted page-in restarts from the last acked chunk, not byte
//!   zero.
//! * **Zoo-wide section cache** — one RAM budget over section-granular
//!   `.nq` reads, served through the store's [`crate::store::FileSource`]
//!   (memoized header probe + positioned range reads), so N devices
//!   pulling M models never re-read or duplicate section bytes
//!   server-side.
//!
//! The device side closes the loop: [`RemoteSource`] implements
//! [`crate::store::SectionSource`] over this protocol, so a device can
//! open a `store::NqArchive` whose bytes live behind the fleet server —
//! the same typed views whether the artifact is local, in memory, or
//! remote.
//!
//! Wire protocol (all frames from `transport`):
//!
//! | client → server                  | server → client                |
//! |----------------------------------|--------------------------------|
//! | `Control "hello"` device id      | `Control "ok"`                 |
//! | `Control "level"` f64 LE         | `Control "advice"` decision    |
//! | `Control "index"` model          | `Control "index"` SectionIndex (v1, no checksums) |
//! | `Control "index2"` model         | `Control "index2"` SectionIndex + trailer checksums |
//! | `Control "models"`               | `Control "models"` id list     |
//! | `Control "offset"` section+model | `Control "offset"` u64 LE      |
//! | `Control "state"` model          | `Control "state"` variant+held |
//! | `Control "pull"` sec+off+model   | `Chunk` stream (ack each)      |
//! | `Control "dropped"` sec+model    | `Control "ok"`                 |
//! | `Control "metrics"`              | `Control "metrics"` JSON telemetry snapshot |
//! | `Control "stop"`                 | — (server shuts down)          |

pub mod cache;
pub mod client;
pub mod session;

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::container::SectionIndex;
use crate::coordinator::SwitchPolicy;
use crate::store::{FileSource, SectionSource};
use crate::telemetry::{registry, LatencyHisto, Snapshot};
use crate::transport::{
    chunk_frame, parse_ack, recv_frame, send_frame, ChunkHeader, Frame, FrameKind, Meter,
};

pub use cache::{CacheStats, SectionCache};
pub use client::{FleetClient, PlaybackReport, PullOutcome, RemoteSource};
pub use session::{SessionSummary, SessionTable, TransferProgress};

/// Which `.nq` section a transfer moves (the store's canonical enum;
/// its tags are part of this wire protocol).
pub use crate::store::Section;

/// The model zoo: model id → shared [`FileSource`]. Immutable once the
/// server starts; each source memoizes its header probe, so section
/// layouts are read from disk at most once per model.
#[derive(Debug, Clone, Default)]
pub struct Zoo {
    entries: BTreeMap<String, Arc<FileSource>>,
}

impl Zoo {
    pub fn new() -> Zoo {
        Zoo::default()
    }

    /// Register one container under `id`.
    pub fn add(&mut self, id: impl Into<String>, path: impl Into<PathBuf>) {
        self.entries
            .insert(id.into(), Arc::new(FileSource::new(path.into())));
    }

    /// Register every `*.nq` file in `dir` under its file stem; returns
    /// how many were added.
    pub fn scan_dir(&mut self, dir: &Path) -> Result<usize> {
        let mut added = 0;
        for entry in
            std::fs::read_dir(dir).with_context(|| format!("scanning {}", dir.display()))?
        {
            let p = entry?.path();
            if p.extension().is_some_and(|x| x == "nq") {
                if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                    self.entries
                        .insert(stem.to_string(), Arc::new(FileSource::new(p.clone())));
                    added += 1;
                }
            }
        }
        Ok(added)
    }

    /// Like [`Zoo::scan_dir`], but probe each container and register only
    /// nest-kind ones (the fleet's paging protocol moves Section-B
    /// deltas, which fp32/mono containers don't have). Unreadable files
    /// are skipped. The probe is memoized in the registered source.
    pub fn scan_nest_dir(&mut self, dir: &Path) -> Result<usize> {
        let mut added = 0;
        for entry in
            std::fs::read_dir(dir).with_context(|| format!("scanning {}", dir.display()))?
        {
            let p = entry?.path();
            if p.extension().is_some_and(|x| x == "nq") {
                let src = FileSource::new(&p);
                let Ok(idx) = src.index() else { continue };
                if idx.kind != crate::container::Kind::Nest {
                    continue;
                }
                if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                    self.entries.insert(stem.to_string(), Arc::new(src));
                    added += 1;
                }
            }
        }
        Ok(added)
    }

    /// The shared byte source for a model (what the cache fetches from).
    pub fn source(&self, id: &str) -> Result<Arc<FileSource>> {
        self.entries
            .get(id)
            .map(Arc::clone)
            .ok_or_else(|| anyhow::anyhow!("unknown model {id:?} (zoo has {})", self.entries.len()))
    }

    pub fn path(&self, id: &str) -> Result<&Path> {
        self.entries
            .get(id)
            .map(|s| s.path())
            .ok_or_else(|| anyhow::anyhow!("unknown model {id:?} (zoo has {})", self.entries.len()))
    }

    pub fn ids(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Fleet server configuration.
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// Bytes per transfer chunk (the resume granularity).
    pub chunk_bytes: usize,
    /// RAM budget of the zoo-wide section cache.
    pub cache_budget_bytes: u64,
    /// How long the server waits for a chunk ack before declaring the
    /// device dead (the transfer stays resumable from the last ack).
    pub ack_timeout: Duration,
    /// Hysteresis switching policy applied per device session.
    pub policy: SwitchPolicy,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            chunk_bytes: 64 << 10,
            cache_budget_bytes: 64 << 20,
            ack_timeout: Duration::from_secs(10),
            policy: SwitchPolicy::default(),
        }
    }
}

/// Build `count` synthetic INT(8|4) containers in `dir` (sizes varied
/// per model) and register them as `synth_0..`: the offline zoo used by
/// the `fleet` subcommand and the `fleet_ota` example when `make
/// artifacts` hasn't run.
pub fn synthetic_zoo(dir: &Path, count: usize, seed: u64) -> Result<Zoo> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let mut zoo = Zoo::new();
    for i in 0..count.max(1) {
        let id = format!("synth_{i}");
        let path = dir.join(format!("{id}.nq"));
        // big enough that Section B spans several chunks even at the
        // default 64 KiB chunk size (the kill/resume demo relies on it)
        let rows = 4096 + 2048 * (i % 3);
        let c = crate::container::synthetic_nest(seed + i as u64, 8, 4, rows, 64)?;
        crate::container::write(&path, &c)?;
        zoo.add(id, path);
    }
    Ok(zoo)
}

/// Outcome of [`demo_kill_resume`].
#[derive(Debug, Clone, Copy)]
pub struct KillResumeReport {
    /// The interrupted pull (what the victim acked before dying).
    pub killed: client::PullOutcome,
    /// Where the server said to resume (== the victim's last ack once
    /// the server has processed it).
    pub resume_from: u64,
    /// The resumed pull that completed the section.
    pub resumed: client::PullOutcome,
    /// Device-side wire bytes (sent, received) across both connections.
    pub wire: (u64, u64),
}

/// Shared demo driver: kill a Section-B pull after `kill_after_chunks`
/// acked chunks (by dropping the connection), reconnect under the same
/// device id, wait (bounded) for the server to process the final ack,
/// and resume from the recorded offset. Used by the `fleet` subcommand
/// and the `fleet_ota` example.
pub fn demo_kill_resume(
    addr: SocketAddr,
    device_id: &str,
    model: &str,
    kill_after_chunks: usize,
    timeout: Duration,
) -> Result<KillResumeReport> {
    let mut sink = Vec::new();
    let mut victim = client::FleetClient::connect(addr, device_id, timeout)?;
    let killed = victim.pull_section(model, Section::B, 0, &mut sink, Some(kill_after_chunks))?;
    let victim_wire = victim.wire();
    drop(victim); // cut the connection mid-transfer

    let mut back = client::FleetClient::connect(addr, device_id, timeout)?;
    // bounded wait: the server may still be processing the final ack
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut resume_from = back.server_offset(model, Section::B)?;
    while resume_from != killed.received_to && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
        resume_from = back.server_offset(model, Section::B)?;
    }
    let resumed = back.pull_section(
        model,
        Section::B,
        resume_from.min(sink.len() as u64),
        &mut sink,
        None,
    )?;
    let back_wire = back.wire();
    Ok(KillResumeReport {
        killed,
        resume_from,
        resumed,
        wire: (victim_wire.0 + back_wire.0, victim_wire.1 + back_wire.1),
    })
}

// ---------------------------------------------------------------------------
// request codecs
// ---------------------------------------------------------------------------

pub(crate) fn encode_pull(model: &str, section: Section, offset: u64) -> Vec<u8> {
    let mut p = Vec::with_capacity(9 + model.len());
    p.push(section.tag());
    p.extend_from_slice(&offset.to_le_bytes());
    p.extend_from_slice(model.as_bytes());
    p
}

pub(crate) fn decode_pull(payload: &[u8]) -> Result<(Section, u64, String)> {
    ensure!(payload.len() > 9, "short pull request");
    let section = Section::from_tag(payload[0])?;
    let offset = u64::from_le_bytes(payload[1..9].try_into().unwrap());
    let model = String::from_utf8(payload[9..].to_vec()).context("model id")?;
    Ok((section, offset, model))
}

/// Legacy wire form of a [`SectionIndex`] (the v1 `index` reply): fixed
/// 20-byte prefix + model name, no checksums. Kept so mixed-version
/// fleets keep paging — checksums travel on the `index2` command
/// ([`encode_index2`]), which new clients try first and old servers
/// reject cleanly.
///
/// The length field carries `payload_len()`, not the on-disk length:
/// pre-trailer clients compute section B as `offset..file_len`, and the
/// server only ever serves payload bytes — sending the trailer-inclusive
/// length would make their reassembled section 24 bytes short of the
/// advertised end.
pub(crate) fn encode_index(idx: &SectionIndex) -> Vec<u8> {
    let mut p = Vec::with_capacity(20 + idx.name.len());
    p.push(idx.kind.as_u8());
    p.push(idx.n);
    p.push(idx.h);
    p.push(idx.act_bits);
    p.extend_from_slice(&idx.section_b_offset.to_le_bytes());
    p.extend_from_slice(&idx.payload_len().to_le_bytes());
    p.extend_from_slice(idx.name.as_bytes());
    p
}

pub(crate) fn decode_index(payload: &[u8]) -> Result<SectionIndex> {
    ensure!(payload.len() >= 20, "short index payload");
    Ok(SectionIndex {
        kind: crate::container::Kind::from_u8(payload[0])?,
        n: payload[1],
        h: payload[2],
        act_bits: payload[3],
        section_b_offset: u64::from_le_bytes(payload[4..12].try_into().unwrap()),
        file_len: u64::from_le_bytes(payload[12..20].try_into().unwrap()),
        checksums: None,
        name: String::from_utf8(payload[20..].to_vec()).context("model name")?,
    })
}

/// v2 wire form (`index2` reply): the 20-byte prefix, then a checksum
/// flag byte (0 absent, 1 present + two u64 CRCs), then the model name.
pub(crate) fn encode_index2(idx: &SectionIndex) -> Vec<u8> {
    let mut p = Vec::with_capacity(37 + idx.name.len());
    p.push(idx.kind.as_u8());
    p.push(idx.n);
    p.push(idx.h);
    p.push(idx.act_bits);
    p.extend_from_slice(&idx.section_b_offset.to_le_bytes());
    p.extend_from_slice(&idx.file_len.to_le_bytes());
    match idx.checksums {
        Some(ck) => {
            p.push(1);
            p.extend_from_slice(&ck.a.to_le_bytes());
            p.extend_from_slice(&ck.b.to_le_bytes());
        }
        None => p.push(0),
    }
    p.extend_from_slice(idx.name.as_bytes());
    p
}

pub(crate) fn decode_index2(payload: &[u8]) -> Result<SectionIndex> {
    ensure!(payload.len() >= 21, "short index2 payload");
    let (checksums, name_at) = match payload[20] {
        0 => (None, 21),
        1 => {
            ensure!(payload.len() >= 37, "short checksummed index2 payload");
            (
                Some(crate::container::SectionChecksums {
                    a: u64::from_le_bytes(payload[21..29].try_into().unwrap()),
                    b: u64::from_le_bytes(payload[29..37].try_into().unwrap()),
                }),
                37,
            )
        }
        f => bail!("unknown index2 checksum flag {f}"),
    };
    Ok(SectionIndex {
        kind: crate::container::Kind::from_u8(payload[0])?,
        n: payload[1],
        h: payload[2],
        act_bits: payload[3],
        section_b_offset: u64::from_le_bytes(payload[4..12].try_into().unwrap()),
        file_len: u64::from_le_bytes(payload[12..20].try_into().unwrap()),
        checksums,
        name: String::from_utf8(payload[name_at..].to_vec()).context("model name")?,
    })
}

pub(crate) fn encode_section_req(model: &str, section: Section) -> Vec<u8> {
    let mut p = Vec::with_capacity(1 + model.len());
    p.push(section.tag());
    p.extend_from_slice(model.as_bytes());
    p
}

pub(crate) fn decode_section_req(payload: &[u8]) -> Result<(Section, String)> {
    ensure!(payload.len() > 1, "short section request");
    let section = Section::from_tag(payload[0])?;
    let model = String::from_utf8(payload[1..].to_vec()).context("model id")?;
    Ok((section, model))
}

pub(crate) fn control(name: &str, payload: Vec<u8>) -> Frame {
    Frame {
        kind: FrameKind::Control,
        name: name.to_string(),
        payload,
    }
}

// ---------------------------------------------------------------------------
// server
// ---------------------------------------------------------------------------

/// Poll interval for idle connections (stop-flag observation latency).
const IDLE_POLL: Duration = Duration::from_millis(100);

/// Read timeouts that mean "no data yet", as opposed to a dead peer.
fn is_io_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

#[derive(Clone)]
struct Ctx {
    addr: SocketAddr,
    zoo: Arc<Zoo>,
    cache: Arc<SectionCache>,
    sessions: Arc<SessionTable>,
    meter: Arc<Meter>,
    /// Per-transfer wall latency (reuses the coordinator's histogram).
    xfer_latency: Arc<LatencyHisto>,
    xfer_ids: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    config: FleetConfig,
}

/// The running fleet server: accept loop + one handler thread per device
/// connection, all sharing the zoo, the section cache, and the session
/// table.
pub struct FleetServer;

/// Handle to a running [`FleetServer`]; stopping joins every thread so
/// wire accounting is exact afterwards.
pub struct FleetHandle {
    pub addr: SocketAddr,
    pub meter: Arc<Meter>,
    pub cache: Arc<SectionCache>,
    pub sessions: Arc<SessionTable>,
    /// Wall latency of completed section transfers.
    pub xfer_latency: Arc<LatencyHisto>,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl FleetServer {
    /// Start serving `zoo` on a fresh localhost port.
    pub fn start(zoo: Zoo, config: FleetConfig) -> Result<FleetHandle> {
        ensure!(
            config.chunk_bytes > 0,
            "chunk_bytes must be positive (zero would live-lock transfers)"
        );
        let listener = TcpListener::bind("127.0.0.1:0").context("bind fleet server")?;
        let addr = listener.local_addr()?;
        let ctx = Ctx {
            addr,
            zoo: Arc::new(zoo),
            cache: Arc::new(SectionCache::new(config.cache_budget_bytes)),
            sessions: Arc::new(SessionTable::new(config.policy)),
            meter: Arc::new(Meter::default()),
            xfer_latency: Arc::new(LatencyHisto::default()),
            xfer_ids: Arc::new(AtomicU64::new(0)),
            stop: Arc::new(AtomicBool::new(false)),
            config,
        };
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let actx = ctx.clone();
        let aconns = Arc::clone(&conns);
        let acceptor = std::thread::Builder::new()
            .name("nq-fleet-acceptor".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if actx.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(sock) = conn else { continue };
                    let cctx = actx.clone();
                    let handle = std::thread::spawn(move || {
                        let _ = handle_connection(sock, cctx);
                    });
                    // reap finished handlers so a long-lived server with
                    // reconnecting devices doesn't accumulate dead handles
                    let mut conns = aconns.lock().unwrap();
                    conns.retain(|h| !h.is_finished());
                    conns.push(handle);
                }
            })?;

        Ok(FleetHandle {
            addr,
            meter: Arc::clone(&ctx.meter),
            cache: Arc::clone(&ctx.cache),
            sessions: Arc::clone(&ctx.sessions),
            xfer_latency: Arc::clone(&ctx.xfer_latency),
            stop: ctx.stop,
            acceptor: Some(acceptor),
            conns,
        })
    }
}

impl FleetHandle {
    /// Stop the server and join every thread (handler threads observe the
    /// stop flag within the idle poll interval when idle).
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr); // poke accept()
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for FleetHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(sock: TcpStream, ctx: Ctx) -> Result<()> {
    use std::io::BufRead;
    sock.set_read_timeout(Some(IDLE_POLL))?;
    let mut writer = sock.try_clone()?;
    let mut reader = BufReader::new(sock);
    let mut device: Option<String> = None;
    loop {
        if ctx.stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        // idle wait: poll (without consuming) until the first bytes of a
        // frame arrive, so the stop flag is observed every IDLE_POLL...
        match reader.fill_buf() {
            Ok([]) => return Ok(()), // EOF: client hung up
            Ok(_) => {}
            Err(ref e) if is_io_timeout(e) => continue,
            Err(_) => return Ok(()),
        }
        // ...then read the whole frame under the generous ack timeout, so
        // a slow-but-healthy peer whose frame spans >IDLE_POLL on the
        // wire is not mistaken for a dead one
        reader.get_ref().set_read_timeout(Some(ctx.config.ack_timeout))?;
        let received = recv_frame(&mut reader, &ctx.meter);
        reader.get_ref().set_read_timeout(Some(IDLE_POLL))?;
        let frame = match received {
            Ok((f, _)) => f,
            Err(_) => return Ok(()), // dead peer / protocol failure
        };
        if frame.kind != FrameKind::Control {
            if send_frame(&mut writer, &control("error", b"expected control frame".to_vec()), &ctx.meter).is_err() {
                return Ok(());
            }
            continue;
        }
        match frame.name.as_str() {
            "stop" => {
                ctx.stop.store(true, Ordering::SeqCst);
                // unblock the acceptor so the listener actually closes
                // (FleetHandle::stop pokes too, but a bare stop_server()
                // must suffice on its own)
                let _ = TcpStream::connect(ctx.addr);
                return Ok(());
            }
            "metrics" => {
                // telemetry scrape: allowed pre-hello so monitoring needs
                // no device identity
                let snap = Snapshot::gather_full(
                    &[],
                    &[("nq_fleet_xfer_latency", &ctx.xfer_latency)],
                );
                let body = snap.to_json().into_bytes();
                if send_frame(&mut writer, &control("metrics", body), &ctx.meter).is_err() {
                    return Ok(());
                }
            }
            "hello" => {
                match String::from_utf8(frame.payload.clone()).ok().filter(|s| !s.is_empty()) {
                    Some(id) => {
                        ctx.sessions.hello(&id);
                        device = Some(id);
                        if send_frame(&mut writer, &control("ok", Vec::new()), &ctx.meter).is_err() {
                            return Ok(());
                        }
                    }
                    None => {
                        if send_frame(&mut writer, &control("error", b"bad device id".to_vec()), &ctx.meter).is_err() {
                            return Ok(());
                        }
                    }
                }
            }
            cmd => {
                let Some(dev) = device.clone() else {
                    if send_frame(&mut writer, &control("error", b"hello required".to_vec()), &ctx.meter).is_err() {
                        return Ok(());
                    }
                    continue;
                };
                let mut streamed = false;
                if let Err(e) =
                    dispatch(cmd, &frame.payload, &dev, &mut writer, &mut reader, &ctx, &mut streamed)
                {
                    if streamed {
                        // the peer died mid-transfer; residency already
                        // records the last acked chunk for resume
                        return Ok(());
                    }
                    let msg = format!("{e:#}");
                    if send_frame(&mut writer, &control("error", msg.into_bytes()), &ctx.meter).is_err() {
                        return Ok(());
                    }
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatch(
    cmd: &str,
    payload: &[u8],
    device: &str,
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    ctx: &Ctx,
    streamed: &mut bool,
) -> Result<()> {
    match cmd {
        "level" => {
            ensure!(payload.len() == 8, "level payload must be 8 bytes");
            let level = f64::from_le_bytes(payload.try_into().unwrap());
            let decision = ctx.sessions.decide(device, level)?;
            match decision {
                crate::coordinator::Decision::Stay => registry().fleet.advice_stay.inc(),
                crate::coordinator::Decision::SwitchTo(crate::coordinator::Variant::FullBit) => {
                    registry().fleet.advice_upgrade.inc()
                }
                crate::coordinator::Decision::SwitchTo(crate::coordinator::Variant::PartBit) => {
                    registry().fleet.advice_downgrade.inc()
                }
            }
            send_frame(
                writer,
                &control("advice", decision.wire().as_bytes().to_vec()),
                &ctx.meter,
            )?;
            Ok(())
        }
        "index" => {
            // section layout of one model — the v1 (pre-checksum) wire
            // form, kept for mixed-version fleets
            let model = std::str::from_utf8(payload).context("model id")?;
            let idx = ctx.zoo.source(model)?.index()?;
            send_frame(writer, &control("index", encode_index(&idx)), &ctx.meter)?;
            Ok(())
        }
        "index2" => {
            // v2: same layout plus the integrity-trailer checksums —
            // what a device-side `RemoteSource` answers
            // `SectionSource::index` with (falling back to `index`
            // against pre-checksum servers)
            let model = std::str::from_utf8(payload).context("model id")?;
            let idx = ctx.zoo.source(model)?.index()?;
            send_frame(writer, &control("index2", encode_index2(&idx)), &ctx.meter)?;
            Ok(())
        }
        "models" => {
            // list the zoo's model ids, so a device can discover what
            // it may open as a `RemoteSource` without knowing paths
            let ids: Vec<&str> = ctx.zoo.ids().collect();
            send_frame(
                writer,
                &control("models", crate::transport::encode_model_list(&ids)),
                &ctx.meter,
            )?;
            Ok(())
        }
        "offset" => {
            let (section, model) = decode_section_req(payload)?;
            let acked = ctx.sessions.acked(device, &model, section);
            send_frame(
                writer,
                &control("offset", acked.to_le_bytes().to_vec()),
                &ctx.meter,
            )?;
            Ok(())
        }
        "dropped" => {
            let (section, model) = decode_section_req(payload)?;
            ctx.sessions.drop_section(device, &model, section)?;
            send_frame(writer, &control("ok", Vec::new()), &ctx.meter)?;
            Ok(())
        }
        "state" => {
            // payload = model id; reply = [variant tag, section-B complete]
            let model = std::str::from_utf8(payload).context("model id")?;
            let variant = ctx.sessions.variant(device)?;
            let complete = ctx
                .sessions
                .progress(device, model, Section::B)
                .is_some_and(|p| p.complete);
            let tag = match variant {
                crate::coordinator::Variant::PartBit => 0u8,
                crate::coordinator::Variant::FullBit => 1u8,
            };
            send_frame(
                writer,
                &control("state", vec![tag, complete as u8]),
                &ctx.meter,
            )?;
            Ok(())
        }
        "pull" => {
            let (section, offset, model) = decode_pull(payload)?;
            serve_pull(device, &model, section, offset, writer, reader, ctx, streamed)
        }
        other => bail!("unknown command {other:?}"),
    }
}

/// Stream one section to the device as acked chunks, resuming at
/// `offset`. Residency bookkeeping happens per chunk, so the last acked
/// offset survives a dead connection.
#[allow(clippy::too_many_arguments)]
fn serve_pull(
    device: &str,
    model: &str,
    section: Section,
    offset: u64,
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    ctx: &Ctx,
    streamed: &mut bool,
) -> Result<()> {
    let source = ctx.zoo.source(model)?;
    let blob = ctx.cache.get(model, source.as_ref(), section)?;
    let total = blob.len() as u64;
    ensure!(
        offset <= total,
        "pull offset {offset} beyond section {section} length {total}"
    );
    let xfer_id = ctx.xfer_ids.fetch_add(1, Ordering::SeqCst) + 1;
    ctx.sessions.begin(device, model, section, total, offset)?;

    // a dead peer must not hold this thread forever: bound the ack wait
    reader.get_ref().set_read_timeout(Some(ctx.config.ack_timeout))?;
    let t0 = Instant::now();
    let result = stream_chunks(
        device, model, section, offset, xfer_id, &blob, writer, reader, ctx, streamed,
    );
    // restore the idle poll regardless of how the transfer ended
    let _ = reader.get_ref().set_read_timeout(Some(IDLE_POLL));
    if result.is_ok() {
        ctx.xfer_latency.record(t0.elapsed());
    }
    result
}

/// The acked chunk loop of [`serve_pull`]; sets `streamed` once bytes
/// are on the wire so the caller can tell protocol errors (reply) from a
/// dead peer mid-transfer (hang up, keep the resume point).
#[allow(clippy::too_many_arguments)]
fn stream_chunks(
    device: &str,
    model: &str,
    section: Section,
    offset: u64,
    xfer_id: u64,
    blob: &[u8],
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    ctx: &Ctx,
    streamed: &mut bool,
) -> Result<()> {
    let total = blob.len() as u64;
    let mut pos = offset;
    loop {
        let end = (pos + ctx.config.chunk_bytes as u64).min(total);
        let header = ChunkHeader {
            xfer_id,
            offset: pos,
            total_len: total,
        };
        *streamed = true;
        send_frame(
            writer,
            &chunk_frame(model, header, &blob[pos as usize..end as usize]),
            &ctx.meter,
        )?;
        ctx.sessions.record_send(device, model, section, pos, end)?;
        let (ack, _) = recv_frame(reader, &ctx.meter).context("awaiting chunk ack")?;
        let (axfer, aend) = parse_ack(&ack)?;
        ensure!(axfer == xfer_id, "ack for transfer {axfer}, expected {xfer_id}");
        ensure!(aend == end, "acked {aend}, expected {end}");
        ctx.sessions.record_ack(device, model, section, aend)?;
        registry().fleet.chunks_sent.inc();
        registry().fleet.chunk_bytes_sent.add(end - pos);
        pos = end;
        if pos >= total {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pull_request_roundtrip() {
        let p = encode_pull("cnn_m_n8h4", Section::B, 123_456);
        let (s, o, m) = decode_pull(&p).unwrap();
        assert_eq!((s, o, m.as_str()), (Section::B, 123_456, "cnn_m_n8h4"));
        assert!(decode_pull(&p[..5]).is_err());
    }

    #[test]
    fn index_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nq_idx_codec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.nq");
        let c = crate::container::synthetic_nest(21, 8, 4, 32, 8).unwrap();
        crate::container::write(&path, &c).unwrap();
        let idx = FileSource::new(&path).index().unwrap();
        assert!(idx.checksums.is_some(), "writer emits the trailer");
        // v2 carries the checksums through
        let back2 = decode_index2(&encode_index2(&idx)).unwrap();
        assert_eq!(back2, idx);
        // v1 stays self-consistent for pre-checksum peers: no
        // checksums, and the advertised length is the payload a server
        // actually serves (so an old client's section_b range check
        // still balances) — section geometry identical
        let back1 = decode_index(&encode_index(&idx)).unwrap();
        assert_eq!(back1.checksums, None);
        assert_eq!(back1.file_len, idx.payload_len());
        assert_eq!(back1.payload_len(), idx.payload_len());
        assert_eq!(back1.section_a(), idx.section_a());
        assert_eq!(back1.section_b(), idx.section_b());
        assert_eq!((back1.n, back1.h, back1.kind), (idx.n, idx.h, idx.kind));
        assert!(decode_index(&[0u8; 10]).is_err());
        assert!(decode_index2(&[0u8; 10]).is_err());
    }

    #[test]
    fn section_request_roundtrip() {
        let p = encode_section_req("vit_s", Section::A);
        let (s, m) = decode_section_req(&p).unwrap();
        assert_eq!((s, m.as_str()), (Section::A, "vit_s"));
        assert!(decode_section_req(&[]).is_err());
        assert!(Section::from_tag(9).is_err());
    }

    #[test]
    fn zoo_registry() {
        let dir = std::env::temp_dir().join(format!("nq_zoo_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m1.nq"), b"x").unwrap();
        std::fs::write(dir.join("m2.nq"), b"x").unwrap();
        std::fs::write(dir.join("notes.txt"), b"x").unwrap();
        let mut zoo = Zoo::new();
        let added = zoo.scan_dir(&dir).unwrap();
        assert_eq!(added, 2);
        assert_eq!(zoo.len(), 2);
        assert!(zoo.path("m1").is_ok());
        assert!(zoo.path("notes").is_err());
        zoo.add("extra", dir.join("m1.nq"));
        assert_eq!(zoo.ids().count(), 3);
    }

    #[test]
    fn scan_nest_dir_filters_kinds() {
        let dir = std::env::temp_dir().join(format!("nq_zoo_nest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("junk.nq"), b"not a container").unwrap();
        let c = crate::container::synthetic_nest(9, 8, 4, 16, 4).unwrap();
        crate::container::write(&dir.join("real.nq"), &c).unwrap();
        let mut zoo = Zoo::new();
        let added = zoo.scan_nest_dir(&dir).unwrap();
        assert_eq!(added, 1);
        assert!(zoo.path("real").is_ok());
        assert!(zoo.path("junk").is_err());
    }
}
