//! Fleet distribution subsystem (S10): the edge-side serving layer that
//! turns the single-device NestQuant reproduction into a multi-tenant
//! system (§4.3.1 at fleet scale).
//!
//! ```text
//!                        ┌────────────────────────────────────────┐
//!   device 0 ──framed────│ FleetServer                            │
//!   device 1 ──TCP───────│   SessionTable   (residency + policy)  │
//!      ⋮                 │   SectionCache   (zoo-wide RAM budget) │
//!   device N ────────────│   Zoo            (model id → .nq path) │
//!                        └────────────────────────────────────────┘
//! ```
//!
//! Three properties the paper's one-device prototype lacks:
//!
//! * **Tracked residency** — the server knows which (arch, n, h)
//!   container and which sections every device holds, so upgrade and
//!   downgrade advice (driven through the existing
//!   `coordinator::policy` hysteresis) moves only Section-B deltas.
//! * **Resumable delta paging** — section transfers are chunked
//!   ([`crate::transport::ChunkHeader`]) with per-chunk acks; an
//!   interrupted page-in restarts from the last acked chunk, not byte
//!   zero.
//! * **Zoo-wide section cache** — one RAM budget over section-granular
//!   `.nq` reads, served through the store's [`crate::store::MmapSource`]
//!   (memoized header probe + OS-paged section windows, positioned
//!   reads as fallback), so N devices pulling M models never re-read or
//!   duplicate section bytes server-side.
//!
//! The device side closes the loop: [`RemoteSource`] implements
//! [`crate::store::SectionSource`] over this protocol, so a device can
//! open a `store::NqArchive` whose bytes live behind the fleet server —
//! the same typed views whether the artifact is local, in memory, or
//! remote.
//!
//! Wire protocol (all frames from `transport`):
//!
//! | client → server                  | server → client                |
//! |----------------------------------|--------------------------------|
//! | `Control "hello"` device id      | `Control "ok"`                 |
//! | `Control "level"` f64 LE         | `Control "advice"` decision    |
//! | `Control "index"` model          | `Control "index"` SectionIndex (v1, no checksums) |
//! | `Control "index2"` model         | `Control "index2"` SectionIndex + trailer checksums |
//! | `Control "models"`               | `Control "models"` id list     |
//! | `Control "offset"` section+model | `Control "offset"` u64 LE      |
//! | `Control "state"` model          | `Control "state"` variant+held |
//! | `Control "pull"` sec+off+model   | `Chunk` stream (ack each)      |
//! | `Control "dropped"` sec+model    | `Control "ok"`                 |
//! | `Control "metrics"`              | `Control "metrics"` JSON telemetry snapshot |
//! | `Control "stop"`                 | — (server shuts down)          |

pub mod cache;
pub mod client;
pub mod session;

use std::collections::{BTreeMap, HashMap, HashSet};
use std::net::{SocketAddr, TcpListener};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::container::SectionIndex;
use crate::coordinator::SwitchPolicy;
use crate::faults;
use crate::reactor::{
    self, Admit, BatchPolicy, ConnId, Ctl, FairScheduler, ReactorHandle, ReactorOpts, Remote,
    Service, TokenBucket, Work,
};
use crate::store::{Bytes, MmapSource, SectionSource};
use crate::telemetry::{registry, LatencyHisto, Snapshot};
use crate::transport::{chunk_frame, parse_ack, ChunkHeader, Frame, FrameKind, Meter};

pub use cache::{CacheStats, SectionCache};
pub use client::{FleetClient, PlaybackReport, PullOutcome, RemoteSource};
pub use session::{SessionSummary, SessionTable, TransferProgress};

/// Re-exported so fleet operators can set [`FleetConfig::rate_limit`]
/// without importing the reactor module.
pub use crate::reactor::RateLimit;

/// Which `.nq` section a transfer moves (the store's canonical enum;
/// its tags are part of this wire protocol).
pub use crate::store::Section;

/// The model zoo: model id → shared [`MmapSource`]. Immutable once the
/// server starts; each source memoizes its header probe, so section
/// layouts are read from disk at most once per model — and with the
/// `mmap` feature, section bytes are OS-paged windows of the artifact
/// (positioned reads elsewhere), so registering a 1000-model zoo costs
/// no eager section reads at all.
#[derive(Debug, Clone, Default)]
pub struct Zoo {
    entries: BTreeMap<String, Arc<MmapSource>>,
}

impl Zoo {
    pub fn new() -> Zoo {
        Zoo::default()
    }

    /// Register one container under `id`.
    pub fn add(&mut self, id: impl Into<String>, path: impl Into<PathBuf>) {
        self.entries
            .insert(id.into(), Arc::new(MmapSource::new(path.into())));
    }

    /// Register every `*.nq` file in `dir` under its file stem; returns
    /// how many were added.
    pub fn scan_dir(&mut self, dir: &Path) -> Result<usize> {
        let mut added = 0;
        for entry in
            std::fs::read_dir(dir).with_context(|| format!("scanning {}", dir.display()))?
        {
            let p = entry?.path();
            if p.extension().is_some_and(|x| x == "nq") {
                if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                    self.entries
                        .insert(stem.to_string(), Arc::new(MmapSource::new(p.clone())));
                    added += 1;
                }
            }
        }
        Ok(added)
    }

    /// Like [`Zoo::scan_dir`], but probe each container and register only
    /// nest-kind ones (the fleet's paging protocol moves Section-B
    /// deltas, which fp32/mono containers don't have). Unreadable files
    /// are skipped. The probe is memoized in the registered source.
    pub fn scan_nest_dir(&mut self, dir: &Path) -> Result<usize> {
        let mut added = 0;
        for entry in
            std::fs::read_dir(dir).with_context(|| format!("scanning {}", dir.display()))?
        {
            let p = entry?.path();
            if p.extension().is_some_and(|x| x == "nq") {
                let src = MmapSource::new(&p);
                let Ok(idx) = src.index() else { continue };
                if idx.kind != crate::container::Kind::Nest {
                    continue;
                }
                if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                    self.entries.insert(stem.to_string(), Arc::new(src));
                    added += 1;
                }
            }
        }
        Ok(added)
    }

    /// The shared byte source for a model (what the cache fetches from).
    pub fn source(&self, id: &str) -> Result<Arc<MmapSource>> {
        self.entries
            .get(id)
            .map(Arc::clone)
            .ok_or_else(|| anyhow::anyhow!("unknown model {id:?} (zoo has {})", self.entries.len()))
    }

    pub fn path(&self, id: &str) -> Result<&Path> {
        self.entries
            .get(id)
            .map(|s| s.path())
            .ok_or_else(|| anyhow::anyhow!("unknown model {id:?} (zoo has {})", self.entries.len()))
    }

    pub fn ids(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Fleet server configuration.
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// Bytes per transfer chunk (the resume granularity).
    pub chunk_bytes: usize,
    /// RAM budget of the zoo-wide section cache.
    pub cache_budget_bytes: u64,
    /// How long the server waits for a chunk ack before declaring the
    /// device dead (the transfer stays resumable from the last ack).
    pub ack_timeout: Duration,
    /// Hysteresis switching policy applied per device session.
    pub policy: SwitchPolicy,
    /// Optional per-device token-bucket rate limit on `level` (advice)
    /// requests; a refused request gets an `error "rate limited"` reply
    /// and ticks `nq_reactor_rate_limited`. `None`: unlimited.
    pub rate_limit: Option<RateLimit>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            chunk_bytes: 64 << 10,
            cache_budget_bytes: 64 << 20,
            ack_timeout: Duration::from_secs(10),
            policy: SwitchPolicy::default(),
            rate_limit: None,
        }
    }
}

/// Build `count` synthetic INT(8|4) containers in `dir` (sizes varied
/// per model) and register them as `synth_0..`: the offline zoo used by
/// the `fleet` subcommand and the `fleet_ota` example when `make
/// artifacts` hasn't run.
pub fn synthetic_zoo(dir: &Path, count: usize, seed: u64) -> Result<Zoo> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let mut zoo = Zoo::new();
    for i in 0..count.max(1) {
        let id = format!("synth_{i}");
        let path = dir.join(format!("{id}.nq"));
        // big enough that Section B spans several chunks even at the
        // default 64 KiB chunk size (the kill/resume demo relies on it)
        let rows = 4096 + 2048 * (i % 3);
        let c = crate::container::synthetic_nest(seed + i as u64, 8, 4, rows, 64)?;
        crate::container::write(&path, &c)?;
        zoo.add(id, path);
    }
    Ok(zoo)
}

/// Outcome of [`demo_kill_resume`].
#[derive(Debug, Clone, Copy)]
pub struct KillResumeReport {
    /// The interrupted pull (what the victim acked before dying).
    pub killed: client::PullOutcome,
    /// Where the server said to resume (== the victim's last ack once
    /// the server has processed it).
    pub resume_from: u64,
    /// The resumed pull that completed the section.
    pub resumed: client::PullOutcome,
    /// Device-side wire bytes (sent, received) across both connections.
    pub wire: (u64, u64),
}

/// Shared demo driver: kill a Section-B pull after `kill_after_chunks`
/// acked chunks (by dropping the connection), reconnect under the same
/// device id, wait (bounded) for the server to process the final ack,
/// and resume from the recorded offset. Used by the `fleet` subcommand
/// and the `fleet_ota` example.
pub fn demo_kill_resume(
    addr: SocketAddr,
    device_id: &str,
    model: &str,
    kill_after_chunks: usize,
    timeout: Duration,
) -> Result<KillResumeReport> {
    let mut sink = Vec::new();
    let mut victim = client::FleetClient::connect(addr, device_id, timeout)?;
    let killed = victim.pull_section(model, Section::B, 0, &mut sink, Some(kill_after_chunks))?;
    let victim_wire = victim.wire();
    drop(victim); // cut the connection mid-transfer

    let mut back = client::FleetClient::connect(addr, device_id, timeout)?;
    // bounded wait: the server may still be processing the final ack
    let deadline = Instant::now() + Duration::from_secs(5);
    let mut resume_from = back.server_offset(model, Section::B)?;
    while resume_from != killed.received_to && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
        resume_from = back.server_offset(model, Section::B)?;
    }
    let resumed = back.pull_section(
        model,
        Section::B,
        resume_from.min(sink.len() as u64),
        &mut sink,
        None,
    )?;
    let back_wire = back.wire();
    Ok(KillResumeReport {
        killed,
        resume_from,
        resumed,
        wire: (victim_wire.0 + back_wire.0, victim_wire.1 + back_wire.1),
    })
}

// ---------------------------------------------------------------------------
// request codecs
// ---------------------------------------------------------------------------

pub(crate) fn encode_pull(model: &str, section: Section, offset: u64) -> Vec<u8> {
    let mut p = Vec::with_capacity(9 + model.len());
    p.push(section.tag());
    p.extend_from_slice(&offset.to_le_bytes());
    p.extend_from_slice(model.as_bytes());
    p
}

pub(crate) fn decode_pull(payload: &[u8]) -> Result<(Section, u64, String)> {
    ensure!(payload.len() > 9, "short pull request");
    let section = Section::from_tag(payload[0])?;
    let offset = u64::from_le_bytes(payload[1..9].try_into().unwrap());
    let model = String::from_utf8(payload[9..].to_vec()).context("model id")?;
    Ok((section, offset, model))
}

/// Legacy wire form of a [`SectionIndex`] (the v1 `index` reply): fixed
/// 20-byte prefix + model name, no checksums. Kept so mixed-version
/// fleets keep paging — checksums travel on the `index2` command
/// ([`encode_index2`]), which new clients try first and old servers
/// reject cleanly.
///
/// The length field carries `payload_len()`, not the on-disk length:
/// pre-trailer clients compute section B as `offset..file_len`, and the
/// server only ever serves payload bytes — sending the trailer-inclusive
/// length would make their reassembled section 24 bytes short of the
/// advertised end.
pub(crate) fn encode_index(idx: &SectionIndex) -> Vec<u8> {
    let mut p = Vec::with_capacity(20 + idx.name.len());
    p.push(idx.kind.as_u8());
    p.push(idx.n);
    p.push(idx.h);
    p.push(idx.act_bits);
    p.extend_from_slice(&idx.section_b_offset.to_le_bytes());
    p.extend_from_slice(&idx.payload_len().to_le_bytes());
    p.extend_from_slice(idx.name.as_bytes());
    p
}

pub(crate) fn decode_index(payload: &[u8]) -> Result<SectionIndex> {
    ensure!(payload.len() >= 20, "short index payload");
    Ok(SectionIndex {
        kind: crate::container::Kind::from_u8(payload[0])?,
        n: payload[1],
        h: payload[2],
        act_bits: payload[3],
        section_b_offset: u64::from_le_bytes(payload[4..12].try_into().unwrap()),
        file_len: u64::from_le_bytes(payload[12..20].try_into().unwrap()),
        checksums: None,
        name: String::from_utf8(payload[20..].to_vec()).context("model name")?,
    })
}

/// v2 wire form (`index2` reply): the 20-byte prefix, then a checksum
/// flag byte (0 absent, 1 present + two u64 CRCs), then the model name.
pub(crate) fn encode_index2(idx: &SectionIndex) -> Vec<u8> {
    let mut p = Vec::with_capacity(37 + idx.name.len());
    p.push(idx.kind.as_u8());
    p.push(idx.n);
    p.push(idx.h);
    p.push(idx.act_bits);
    p.extend_from_slice(&idx.section_b_offset.to_le_bytes());
    p.extend_from_slice(&idx.file_len.to_le_bytes());
    match idx.checksums {
        Some(ck) => {
            p.push(1);
            p.extend_from_slice(&ck.a.to_le_bytes());
            p.extend_from_slice(&ck.b.to_le_bytes());
        }
        None => p.push(0),
    }
    p.extend_from_slice(idx.name.as_bytes());
    p
}

pub(crate) fn decode_index2(payload: &[u8]) -> Result<SectionIndex> {
    ensure!(payload.len() >= 21, "short index2 payload");
    let (checksums, name_at) = match payload[20] {
        0 => (None, 21),
        1 => {
            ensure!(payload.len() >= 37, "short checksummed index2 payload");
            (
                Some(crate::container::SectionChecksums {
                    a: u64::from_le_bytes(payload[21..29].try_into().unwrap()),
                    b: u64::from_le_bytes(payload[29..37].try_into().unwrap()),
                }),
                37,
            )
        }
        f => bail!("unknown index2 checksum flag {f}"),
    };
    Ok(SectionIndex {
        kind: crate::container::Kind::from_u8(payload[0])?,
        n: payload[1],
        h: payload[2],
        act_bits: payload[3],
        section_b_offset: u64::from_le_bytes(payload[4..12].try_into().unwrap()),
        file_len: u64::from_le_bytes(payload[12..20].try_into().unwrap()),
        checksums,
        name: String::from_utf8(payload[name_at..].to_vec()).context("model name")?,
    })
}

pub(crate) fn encode_section_req(model: &str, section: Section) -> Vec<u8> {
    let mut p = Vec::with_capacity(1 + model.len());
    p.push(section.tag());
    p.extend_from_slice(model.as_bytes());
    p
}

pub(crate) fn decode_section_req(payload: &[u8]) -> Result<(Section, String)> {
    ensure!(payload.len() > 1, "short section request");
    let section = Section::from_tag(payload[0])?;
    let model = String::from_utf8(payload[1..].to_vec()).context("model id")?;
    Ok((section, model))
}

pub(crate) fn control(name: &str, payload: Vec<u8>) -> Frame {
    Frame {
        kind: FrameKind::Control,
        name: name.to_string(),
        payload,
    }
}

// ---------------------------------------------------------------------------
// server
// ---------------------------------------------------------------------------

/// One queued unit of fleet work. Anything that touches disk, the
/// section cache, or the policy table runs on the worker pool; the
/// reactor loop itself only parses frames and shuffles bytes.
enum FleetJob {
    /// `level`: a resource report wanting switch advice (Switch class).
    Level {
        conn: ConnId,
        device: String,
        level: f64,
    },
    /// `metrics`: a telemetry scrape (control class, allowed pre-hello).
    Metrics { conn: ConnId },
    /// `models`: the zoo listing (control class).
    Models { conn: ConnId },
    /// `index`/`index2`: section layout of one model (control class).
    Index {
        conn: ConnId,
        payload: Vec<u8>,
        v2: bool,
    },
    /// `pull`: open + cache the section, then hand the loop a stream.
    Pull {
        conn: ConnId,
        device: String,
        model: String,
        section: Section,
        offset: u64,
    },
}

/// What a worker hands back to the loop once a job finishes.
enum InjectMsg {
    /// Terminal reply; the connection resumes reading afterwards.
    Reply(ConnId, Frame),
    /// A validated pull: the loop takes over lockstep chunk/ack
    /// streaming from `offset`.
    Start {
        conn: ConnId,
        device: String,
        model: String,
        section: Section,
        offset: u64,
        blob: Bytes,
        xfer_id: u64,
    },
}

type Inject = Arc<Mutex<Vec<InjectMsg>>>;

/// An in-progress section transfer owned by the reactor loop. Chunks go
/// out one at a time and the next is sent only once the previous ack
/// arrives, so residency bookkeeping survives a dead connection at the
/// last acked offset exactly like the blocking server did.
struct StreamState {
    device: String,
    model: String,
    section: Section,
    blob: Bytes,
    xfer_id: u64,
    /// Resume point: everything below this offset is acknowledged.
    acked: u64,
    /// End offset of the chunk currently in flight.
    sent_to: u64,
    total: u64,
    t0: Instant,
}

/// The per-connection protocol state machine every device talks to.
/// Cheap lookups (`offset`, `dropped`, `state`, `hello`) answer inline
/// on the loop; everything else is queued to the worker pool with the
/// connection paused until its reply comes back.
struct FleetService {
    sessions: Arc<SessionTable>,
    xfer_latency: Arc<LatencyHisto>,
    sched: Arc<FairScheduler<FleetJob>>,
    inject: Inject,
    config: FleetConfig,
    stop_flag: Arc<AtomicBool>,
    stopping: bool,
    /// Connection -> device id (`None` until a valid `hello`).
    conns: HashMap<ConnId, Option<String>>,
    streams: HashMap<ConnId, StreamState>,
    /// Connections paused while a worker owns their reply.
    in_flight: HashSet<ConnId>,
    /// Per-device token buckets (only when `config.rate_limit` is set).
    buckets: HashMap<String, TokenBucket>,
}

impl FleetService {
    /// Park the connection until its worker reply comes back, or refuse
    /// outright when the queue already closed for shutdown.
    fn gate(&mut self, conn: ConnId, ctl: &mut Ctl, accepted: bool) {
        if accepted {
            self.in_flight.insert(conn);
            ctl.pause(conn);
        } else {
            ctl.send(conn, control("error", b"server shutting down".to_vec()));
        }
    }

    /// Commands that need a device identity (everything but `hello`,
    /// `metrics`, and `stop`). An `Err` becomes an `error` reply.
    fn command(
        &mut self,
        conn: ConnId,
        device: &str,
        cmd: &str,
        payload: &[u8],
        ctl: &mut Ctl,
    ) -> Result<()> {
        match cmd {
            "level" => {
                ensure!(payload.len() == 8, "level payload must be 8 bytes");
                let level = f64::from_le_bytes(payload.try_into().unwrap());
                if let Some(limit) = self.config.rate_limit {
                    let bucket = self
                        .buckets
                        .entry(device.to_string())
                        .or_insert_with(|| TokenBucket::new(limit, Instant::now()));
                    if !bucket.admit(Instant::now()) {
                        registry().reactor.rate_limited.inc();
                        ctl.send(conn, control("error", b"rate limited".to_vec()));
                        return Ok(());
                    }
                }
                let ok = self.sched.push_switch(FleetJob::Level {
                    conn,
                    device: device.to_string(),
                    level,
                });
                self.gate(conn, ctl, ok);
            }
            "index" => {
                let ok = self.sched.push_control(FleetJob::Index {
                    conn,
                    payload: payload.to_vec(),
                    v2: false,
                });
                self.gate(conn, ctl, ok);
            }
            "index2" => {
                let ok = self.sched.push_control(FleetJob::Index {
                    conn,
                    payload: payload.to_vec(),
                    v2: true,
                });
                self.gate(conn, ctl, ok);
            }
            "models" => {
                let ok = self.sched.push_control(FleetJob::Models { conn });
                self.gate(conn, ctl, ok);
            }
            "offset" => {
                let (section, model) = decode_section_req(payload)?;
                let acked = self.sessions.acked(device, &model, section);
                ctl.send(conn, control("offset", acked.to_le_bytes().to_vec()));
            }
            "dropped" => {
                let (section, model) = decode_section_req(payload)?;
                self.sessions.drop_section(device, &model, section)?;
                ctl.send(conn, control("ok", Vec::new()));
            }
            "state" => {
                // payload = model id; reply = [variant tag, section-B complete]
                let model = std::str::from_utf8(payload).context("model id")?;
                let variant = self.sessions.variant(device)?;
                let complete = self
                    .sessions
                    .progress(device, model, Section::B)
                    .is_some_and(|p| p.complete);
                let tag = match variant {
                    crate::coordinator::Variant::PartBit => 0u8,
                    crate::coordinator::Variant::FullBit => 1u8,
                };
                ctl.send(conn, control("state", vec![tag, complete as u8]));
            }
            "pull" => {
                let (section, offset, model) = decode_pull(payload)?;
                match self.sched.push_infer(
                    0,
                    FleetJob::Pull {
                        conn,
                        device: device.to_string(),
                        model,
                        section,
                        offset,
                    },
                ) {
                    Admit::Queued => self.gate(conn, ctl, true),
                    Admit::Shed => {
                        ctl.send(conn, control("busy", b"pull queue full, retry later".to_vec()));
                    }
                    Admit::Closed => self.gate(conn, ctl, false),
                }
            }
            other => bail!("unknown command {other:?}"),
        }
        Ok(())
    }

    /// The device acked the chunk in flight: advance the resume point
    /// and either finish the transfer or put the next chunk on the wire.
    fn on_ack(&mut self, conn: ConnId, frame: &Frame, ctl: &mut Ctl) {
        let Some(st) = self.streams.get(&conn) else {
            ctl.close(conn);
            return;
        };
        // Failpoint `fleet.ack`: forge a bad ack, closing only this
        // connection — the session table keeps the last good offset, so
        // the device resumes exactly like after a real corrupt ack.
        if faults::fires("fleet.ack") {
            ctl.close(conn);
            return;
        }
        let ok = parse_ack(frame)
            .map(|(axfer, aend)| axfer == st.xfer_id && aend == st.sent_to)
            .unwrap_or(false);
        // A bad ack closes the connection; the session table still holds
        // the last good offset, so the device resumes from there.
        if !ok {
            ctl.close(conn);
            return;
        }
        let from = st.acked;
        let to = st.sent_to;
        if self
            .sessions
            .record_ack(&st.device, &st.model, st.section, to)
            .is_err()
        {
            ctl.close(conn);
            return;
        }
        registry().fleet.chunks_sent.inc();
        registry().fleet.chunk_bytes_sent.add(to - from);
        let st = self.streams.get_mut(&conn).expect("stream state");
        st.acked = to;
        if st.acked >= st.total {
            let st = self.streams.remove(&conn).expect("stream state");
            self.xfer_latency.record(st.t0.elapsed());
            ctl.set_deadline(conn, None);
            if self.stopping {
                ctl.close_after_flush(conn);
            }
            return;
        }
        self.send_chunk(conn, ctl);
    }

    /// Queue the next chunk of `conn`'s stream and (re)arm the ack
    /// deadline, so a dead peer cannot hold its slot past `ack_timeout`.
    fn send_chunk(&mut self, conn: ConnId, ctl: &mut Ctl) {
        // Failpoint `fleet.chunk`: drop the connection before the chunk
        // goes out (delay mode stalls it instead) — the transfer stays
        // resumable from the last acked offset.
        if faults::fail_point("fleet.chunk").is_err() {
            self.streams.remove(&conn);
            ctl.close(conn);
            return;
        }
        let Some(st) = self.streams.get_mut(&conn) else {
            return;
        };
        let end = (st.acked + self.config.chunk_bytes as u64).min(st.total);
        let header = ChunkHeader {
            xfer_id: st.xfer_id,
            offset: st.acked,
            total_len: st.total,
        };
        let frame = chunk_frame(&st.model, header, &st.blob[st.acked as usize..end as usize]);
        st.sent_to = end;
        if self
            .sessions
            .record_send(&st.device, &st.model, st.section, st.acked, end)
            .is_err()
        {
            ctl.close(conn);
            return;
        }
        ctl.send(conn, frame);
        ctl.set_deadline(conn, Some(Instant::now() + self.config.ack_timeout));
    }
}

impl Service for FleetService {
    fn on_open(&mut self, conn: ConnId, _ctl: &mut Ctl) {
        self.conns.insert(conn, None);
    }

    fn on_close(&mut self, conn: ConnId, _ctl: &mut Ctl) {
        self.conns.remove(&conn);
        self.streams.remove(&conn);
        self.in_flight.remove(&conn);
    }

    fn on_frame(&mut self, conn: ConnId, frame: Frame, ctl: &mut Ctl) {
        if self.streams.contains_key(&conn) {
            // mid-transfer the only legal frame is the ack for the chunk
            // in flight
            if frame.kind == FrameKind::Ack {
                self.on_ack(conn, &frame, ctl);
            } else {
                ctl.close(conn);
            }
            return;
        }
        if frame.kind != FrameKind::Control {
            ctl.send(conn, control("error", b"expected control frame".to_vec()));
            return;
        }
        match frame.name.as_str() {
            "stop" => {
                self.stop_flag.store(true, Ordering::SeqCst);
                ctl.stop();
            }
            "metrics" => {
                // telemetry scrape: allowed pre-hello so monitoring needs
                // no device identity
                let ok = self.sched.push_control(FleetJob::Metrics { conn });
                self.gate(conn, ctl, ok);
            }
            "hello" => match String::from_utf8(frame.payload).ok().filter(|s| !s.is_empty()) {
                Some(id) => {
                    self.sessions.hello(&id);
                    self.conns.insert(conn, Some(id));
                    ctl.send(conn, control("ok", Vec::new()));
                }
                None => ctl.send(conn, control("error", b"bad device id".to_vec())),
            },
            cmd => {
                let Some(device) = self.conns.get(&conn).cloned().flatten() else {
                    ctl.send(conn, control("error", b"hello required".to_vec()));
                    return;
                };
                if let Err(e) = self.command(conn, &device, cmd, &frame.payload, ctl) {
                    ctl.send(conn, control("error", format!("{e:#}").into_bytes()));
                }
            }
        }
    }

    fn on_wake(&mut self, ctl: &mut Ctl) {
        let msgs: Vec<InjectMsg> = std::mem::take(&mut *self.inject.lock().unwrap());
        for msg in msgs {
            match msg {
                InjectMsg::Reply(conn, frame) => {
                    self.in_flight.remove(&conn);
                    ctl.send(conn, frame);
                    if self.stopping {
                        ctl.close_after_flush(conn);
                    } else {
                        ctl.resume(conn);
                    }
                }
                InjectMsg::Start {
                    conn,
                    device,
                    model,
                    section,
                    offset,
                    blob,
                    xfer_id,
                } => {
                    self.in_flight.remove(&conn);
                    if !self.conns.contains_key(&conn) {
                        continue; // device hung up while the worker ran
                    }
                    let total = blob.len() as u64;
                    self.streams.insert(
                        conn,
                        StreamState {
                            device,
                            model,
                            section,
                            blob,
                            xfer_id,
                            acked: offset,
                            sent_to: offset,
                            total,
                            t0: Instant::now(),
                        },
                    );
                    // the device reads chunks and writes acks, so resume
                    // reading before the first chunk goes out
                    ctl.resume(conn);
                    self.send_chunk(conn, ctl);
                }
            }
        }
    }

    fn on_stop(&mut self, ctl: &mut Ctl) {
        self.stopping = true;
        self.stop_flag.store(true, Ordering::SeqCst);
        // drain: idle connections close once queued replies flush;
        // connections awaiting a worker reply or mid-transfer finish
        // first (their completion paths check `stopping`)
        for &conn in self.conns.keys() {
            if !self.in_flight.contains(&conn) && !self.streams.contains_key(&conn) {
                ctl.close_after_flush(conn);
            }
        }
    }
}

/// Shared state of the fleet worker pool.
struct FleetWorkerCtx {
    sched: Arc<FairScheduler<FleetJob>>,
    zoo: Arc<Zoo>,
    cache: Arc<SectionCache>,
    sessions: Arc<SessionTable>,
    xfer_latency: Arc<LatencyHisto>,
    xfer_ids: Arc<AtomicU64>,
    inject: Inject,
    remote: Arc<Remote>,
}

impl FleetWorkerCtx {
    fn reply(&self, msg: InjectMsg) {
        self.inject.lock().unwrap().push(msg);
        self.remote.wake();
    }
}

/// Pulls ride the Infer class as a single logical tenant with batch
/// size 1: strict class priority means control and advice never wait
/// behind a pull setup (disk open + cache fill).
const FLEET_POLICIES: [BatchPolicy; 1] = [BatchPolicy {
    batch_size: 1,
    max_wait: Duration::ZERO,
}];

fn fleet_worker(ctx: &FleetWorkerCtx) {
    loop {
        match ctx.sched.next_work(&FLEET_POLICIES) {
            Work::Shutdown => return,
            Work::One(_, e) => run_job(ctx, e.payload),
            Work::Batch(t, entries) => {
                for e in entries {
                    run_job(ctx, e.payload);
                }
                ctx.sched.finish_batch(t);
            }
        }
    }
}

fn run_job(ctx: &FleetWorkerCtx, job: FleetJob) {
    match job {
        FleetJob::Level {
            conn,
            device,
            level,
        } => {
            let frame = match ctx.sessions.decide(&device, level) {
                Ok(decision) => {
                    match decision {
                        crate::coordinator::Decision::Stay => registry().fleet.advice_stay.inc(),
                        crate::coordinator::Decision::SwitchTo(
                            crate::coordinator::Variant::FullBit,
                        ) => registry().fleet.advice_upgrade.inc(),
                        crate::coordinator::Decision::SwitchTo(
                            crate::coordinator::Variant::PartBit,
                        ) => registry().fleet.advice_downgrade.inc(),
                    }
                    control("advice", decision.wire().as_bytes().to_vec())
                }
                Err(e) => control("error", format!("{e:#}").into_bytes()),
            };
            ctx.reply(InjectMsg::Reply(conn, frame));
        }
        FleetJob::Metrics { conn } => {
            let snap =
                Snapshot::gather_full(&[], &[("nq_fleet_xfer_latency", &ctx.xfer_latency)]);
            let body = snap.to_json().into_bytes();
            ctx.reply(InjectMsg::Reply(conn, control("metrics", body)));
        }
        FleetJob::Models { conn } => {
            // list the zoo's model ids, so a device can discover what it
            // may open as a `RemoteSource` without knowing paths
            let ids: Vec<&str> = ctx.zoo.ids().collect();
            let body = crate::transport::encode_model_list(&ids);
            ctx.reply(InjectMsg::Reply(conn, control("models", body)));
        }
        FleetJob::Index { conn, payload, v2 } => {
            let frame = match index_reply(ctx, &payload, v2) {
                Ok(f) => f,
                Err(e) => control("error", format!("{e:#}").into_bytes()),
            };
            ctx.reply(InjectMsg::Reply(conn, frame));
        }
        FleetJob::Pull {
            conn,
            device,
            model,
            section,
            offset,
        } => match start_pull(ctx, &device, &model, section, offset) {
            Ok((blob, xfer_id)) => ctx.reply(InjectMsg::Start {
                conn,
                device,
                model,
                section,
                offset,
                blob,
                xfer_id,
            }),
            Err(e) => ctx.reply(InjectMsg::Reply(
                conn,
                control("error", format!("{e:#}").into_bytes()),
            )),
        },
    }
}

/// Section layout of one model. v1 is the pre-checksum wire form, kept
/// for mixed-version fleets; v2 adds the integrity-trailer checksums —
/// what a device-side `RemoteSource` answers `SectionSource::index`
/// with (falling back to v1 against pre-checksum servers).
fn index_reply(ctx: &FleetWorkerCtx, payload: &[u8], v2: bool) -> Result<Frame> {
    let model = std::str::from_utf8(payload).context("model id")?;
    let idx = ctx.zoo.source(model)?.index()?;
    Ok(if v2 {
        control("index2", encode_index2(&idx))
    } else {
        control("index", encode_index(&idx))
    })
}

/// Pull setup off the reactor loop: resolve the model, fill the section
/// cache (the disk I/O), validate the resume offset, and register the
/// transfer — the loop then streams from the shared `Bytes` blob.
fn start_pull(
    ctx: &FleetWorkerCtx,
    device: &str,
    model: &str,
    section: Section,
    offset: u64,
) -> Result<(Bytes, u64)> {
    let source = ctx.zoo.source(model)?;
    let blob = ctx.cache.get(model, source.as_ref(), section)?;
    let total = blob.len() as u64;
    ensure!(
        offset <= total,
        "pull offset {offset} beyond section {section} length {total}"
    );
    let xfer_id = ctx.xfer_ids.fetch_add(1, Ordering::SeqCst) + 1;
    ctx.sessions.begin(device, model, section, total, offset)?;
    Ok((blob, xfer_id))
}

/// The running fleet server: one readiness-driven reactor loop owns
/// every device connection (sessions are state, not threads) and a
/// small worker pool runs disk- and policy-bound jobs behind
/// weighted-fair priority queues (control > advice > pulls).
pub struct FleetServer;

/// Handle to a running [`FleetServer`]; stopping drains the reactor and
/// joins every thread so wire accounting is exact afterwards.
pub struct FleetHandle {
    pub addr: SocketAddr,
    pub meter: Arc<Meter>,
    pub cache: Arc<SectionCache>,
    pub sessions: Arc<SessionTable>,
    /// Wall latency of completed section transfers.
    pub xfer_latency: Arc<LatencyHisto>,
    stop: Arc<AtomicBool>,
    sched: Arc<FairScheduler<FleetJob>>,
    reactor: Option<ReactorHandle>,
    workers: Vec<JoinHandle<()>>,
}

impl FleetServer {
    /// Start serving `zoo` on a fresh localhost port.
    pub fn start(zoo: Zoo, config: FleetConfig) -> Result<FleetHandle> {
        ensure!(
            config.chunk_bytes > 0,
            "chunk_bytes must be positive (zero would live-lock transfers)"
        );
        let listener = TcpListener::bind("127.0.0.1:0").context("bind fleet server")?;
        let zoo = Arc::new(zoo);
        let cache = Arc::new(SectionCache::new(config.cache_budget_bytes));
        let sessions = Arc::new(SessionTable::new(config.policy));
        let meter = Arc::new(Meter::default());
        let xfer_latency = Arc::new(LatencyHisto::default());
        let stop = Arc::new(AtomicBool::new(false));
        let sched: Arc<FairScheduler<FleetJob>> = Arc::new(FairScheduler::new(&[1]));
        let inject: Inject = Arc::new(Mutex::new(Vec::new()));

        let service = FleetService {
            sessions: Arc::clone(&sessions),
            xfer_latency: Arc::clone(&xfer_latency),
            sched: Arc::clone(&sched),
            inject: Arc::clone(&inject),
            config,
            stop_flag: Arc::clone(&stop),
            stopping: false,
            conns: HashMap::new(),
            streams: HashMap::new(),
            in_flight: HashSet::new(),
            buckets: HashMap::new(),
        };
        let reactor = reactor::spawn(
            listener,
            service,
            ReactorOpts {
                name: "fleet".into(),
                meter: Arc::clone(&meter),
                // a stalled half-frame is as dead as a missed ack
                partial_frame_timeout: Some(config.ack_timeout),
            },
        )
        .context("spawn fleet reactor")?;
        let addr = reactor.addr;

        let ctx = Arc::new(FleetWorkerCtx {
            sched: Arc::clone(&sched),
            zoo,
            cache: Arc::clone(&cache),
            sessions: Arc::clone(&sessions),
            xfer_latency: Arc::clone(&xfer_latency),
            xfer_ids: Arc::new(AtomicU64::new(0)),
            inject,
            remote: reactor.remote(),
        });
        let n_workers = std::thread::available_parallelism()
            .map_or(2, |n| n.get())
            .clamp(2, 8);
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let ctx = Arc::clone(&ctx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("nq-fleet-worker-{i}"))
                    // respawn-in-place: a panicking job restarts the
                    // loop on the same thread, so the pool never shrinks
                    .spawn(move || loop {
                        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            fleet_worker(&ctx)
                        })) {
                            Ok(()) => return,
                            Err(_) => registry().faults.worker_panics.inc(),
                        }
                    })?,
            );
        }

        Ok(FleetHandle {
            addr,
            meter,
            cache,
            sessions,
            xfer_latency,
            stop,
            sched,
            reactor: Some(reactor),
            workers,
        })
    }
}

impl FleetHandle {
    /// Stop the server: close the queues, join the workers, drain the
    /// reactor.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // 1. refuse new jobs; workers run out what is queued and exit,
        //    so every gated connection has its reply injected
        self.sched.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // 2. drain the reactor: the listener closes, idle connections
        //    flush and close in on_stop, injected replies and running
        //    transfers finish first, then the loop exits empty
        if let Some(mut r) = self.reactor.take() {
            r.request_stop();
            r.join();
        }
    }
}

impl Drop for FleetHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pull_request_roundtrip() {
        let p = encode_pull("cnn_m_n8h4", Section::B, 123_456);
        let (s, o, m) = decode_pull(&p).unwrap();
        assert_eq!((s, o, m.as_str()), (Section::B, 123_456, "cnn_m_n8h4"));
        assert!(decode_pull(&p[..5]).is_err());
    }

    #[test]
    fn index_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nq_idx_codec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.nq");
        let c = crate::container::synthetic_nest(21, 8, 4, 32, 8).unwrap();
        crate::container::write(&path, &c).unwrap();
        let idx = crate::store::FileSource::new(&path).index().unwrap();
        assert!(idx.checksums.is_some(), "writer emits the trailer");
        // v2 carries the checksums through
        let back2 = decode_index2(&encode_index2(&idx)).unwrap();
        assert_eq!(back2, idx);
        // v1 stays self-consistent for pre-checksum peers: no
        // checksums, and the advertised length is the payload a server
        // actually serves (so an old client's section_b range check
        // still balances) — section geometry identical
        let back1 = decode_index(&encode_index(&idx)).unwrap();
        assert_eq!(back1.checksums, None);
        assert_eq!(back1.file_len, idx.payload_len());
        assert_eq!(back1.payload_len(), idx.payload_len());
        assert_eq!(back1.section_a(), idx.section_a());
        assert_eq!(back1.section_b(), idx.section_b());
        assert_eq!((back1.n, back1.h, back1.kind), (idx.n, idx.h, idx.kind));
        assert!(decode_index(&[0u8; 10]).is_err());
        assert!(decode_index2(&[0u8; 10]).is_err());
    }

    #[test]
    fn section_request_roundtrip() {
        let p = encode_section_req("vit_s", Section::A);
        let (s, m) = decode_section_req(&p).unwrap();
        assert_eq!((s, m.as_str()), (Section::A, "vit_s"));
        assert!(decode_section_req(&[]).is_err());
        assert!(Section::from_tag(9).is_err());
    }

    #[test]
    fn zoo_registry() {
        let dir = std::env::temp_dir().join(format!("nq_zoo_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m1.nq"), b"x").unwrap();
        std::fs::write(dir.join("m2.nq"), b"x").unwrap();
        std::fs::write(dir.join("notes.txt"), b"x").unwrap();
        let mut zoo = Zoo::new();
        let added = zoo.scan_dir(&dir).unwrap();
        assert_eq!(added, 2);
        assert_eq!(zoo.len(), 2);
        assert!(zoo.path("m1").is_ok());
        assert!(zoo.path("notes").is_err());
        zoo.add("extra", dir.join("m1.nq"));
        assert_eq!(zoo.ids().count(), 3);
    }

    #[test]
    fn scan_nest_dir_filters_kinds() {
        let dir = std::env::temp_dir().join(format!("nq_zoo_nest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("junk.nq"), b"not a container").unwrap();
        let c = crate::container::synthetic_nest(9, 8, 4, 16, 4).unwrap();
        crate::container::write(&dir.join("real.nq"), &c).unwrap();
        let mut zoo = Zoo::new();
        let added = zoo.scan_nest_dir(&dir).unwrap();
        assert_eq!(added, 1);
        assert!(zoo.path("real").is_ok());
        assert!(zoo.path("junk").is_err());
    }
}
