//! Per-device session state: residency (which sections of which model a
//! device holds), resumable transfer progress, and the hysteresis policy
//! evaluator reused from `coordinator::policy`.
//!
//! The table is the server's source of truth for resume points: every
//! chunk ack is recorded here, so a transfer interrupted by a dead
//! connection restarts from the last acked chunk when the device
//! reconnects — not from byte zero.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, ensure, Result};

use crate::coordinator::{Decision, PolicyState, SwitchPolicy, Variant};

use super::Section;

/// Progress of one (device, model, section) residency entry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferProgress {
    /// Section length in bytes.
    pub total: u64,
    /// Last acked offset — the resume point.
    pub acked: u64,
    /// Highest offset ever sent (may exceed `acked` by in-flight chunks).
    pub sent_high_water: u64,
    /// Cumulative payload bytes sent for this residency (all attempts).
    pub bytes_sent: u64,
    /// Payload bytes sent more than once (the waste a resume avoids).
    pub bytes_resent: u64,
    /// Whether the device holds the complete section.
    pub complete: bool,
}

impl TransferProgress {
    fn record_send(&mut self, start: u64, end: u64) {
        self.bytes_sent += end - start;
        if start < self.sent_high_water {
            self.bytes_resent += self.sent_high_water.min(end) - start;
        }
        self.sent_high_water = self.sent_high_water.max(end);
    }

    fn record_ack(&mut self, end: u64) {
        self.acked = self.acked.max(end);
        self.complete = self.acked >= self.total;
    }
}

/// One device's server-side session.
#[derive(Debug)]
struct DeviceSession {
    policy: PolicyState,
    levels_seen: u64,
    residency: HashMap<(String, Section), TransferProgress>,
}

/// Point-in-time summary of one session (reporting / the `fleet` CLI).
#[derive(Debug, Clone)]
pub struct SessionSummary {
    pub id: String,
    pub variant: Variant,
    pub levels_seen: u64,
    pub switches: u64,
    pub bytes_sent: u64,
    pub bytes_resent: u64,
    /// Complete (fully acked) sections currently resident.
    pub resident_sections: usize,
}

/// Thread-safe registry of device sessions.
pub struct SessionTable {
    policy: SwitchPolicy,
    inner: Mutex<HashMap<String, DeviceSession>>,
}

impl SessionTable {
    pub fn new(policy: SwitchPolicy) -> SessionTable {
        SessionTable {
            policy,
            inner: Mutex::new(HashMap::new()),
        }
    }

    /// Poison-recovering lock: the table is the fleet's source of truth
    /// for resume points, and a panic isolated in a worker must not
    /// take every device's residency state down with it (updates are
    /// single-field writes, so any observed state is consistent).
    fn lock(&self) -> std::sync::MutexGuard<'_, HashMap<String, DeviceSession>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Register a device (idempotent: a reconnect keeps residency and
    /// policy state, which is exactly what makes transfers resumable).
    pub fn hello(&self, id: &str) {
        let mut g = self.lock();
        g.entry(id.to_string()).or_insert_with(|| {
            crate::telemetry::registry().fleet.sessions.inc();
            DeviceSession {
                // devices come online part-bit after a Section-A pull
                policy: PolicyState::new(self.policy, Variant::PartBit),
                levels_seen: 0,
                residency: HashMap::new(),
            }
        });
    }

    fn with<T>(&self, id: &str, f: impl FnOnce(&mut DeviceSession) -> T) -> Result<T> {
        let mut g = self.lock();
        let s = g
            .get_mut(id)
            .ok_or_else(|| anyhow!("unknown device {id:?} (hello required)"))?;
        Ok(f(s))
    }

    /// Evaluate one resource report through the device's hysteresis
    /// policy state.
    pub fn decide(&self, id: &str, level: f64) -> Result<Decision> {
        ensure!((0.0..=1.0).contains(&level), "level {level} outside [0, 1]");
        self.with(id, |s| {
            s.levels_seen += 1;
            s.policy.decide(level)
        })
    }

    /// Begin (or resume) a transfer; validates the offset against the
    /// section length and records the section total.
    pub fn begin(&self, id: &str, model: &str, section: Section, total: u64, offset: u64) -> Result<()> {
        ensure!(offset <= total, "offset {offset} beyond total {total}");
        self.with(id, |s| {
            let p = s
                .residency
                .entry((model.to_string(), section))
                .or_default();
            p.total = total;
        })
    }

    /// Record payload bytes `[start, end)` going out on the wire.
    pub fn record_send(&self, id: &str, model: &str, section: Section, start: u64, end: u64) -> Result<()> {
        self.with(id, |s| {
            if let Some(p) = s.residency.get_mut(&(model.to_string(), section)) {
                p.record_send(start, end);
            }
        })
    }

    /// Record a device ack up to `end` (the new resume point).
    pub fn record_ack(&self, id: &str, model: &str, section: Section, end: u64) -> Result<()> {
        self.with(id, |s| {
            if let Some(p) = s.residency.get_mut(&(model.to_string(), section)) {
                p.record_ack(end);
            }
        })
    }

    /// The device's current policy variant (server-side source of truth;
    /// a reconnecting device reconciles against this).
    pub fn variant(&self, id: &str) -> Result<Variant> {
        self.with(id, |s| s.policy.current())
    }

    /// Last acked offset for a residency entry (0 when unknown): where a
    /// resumed pull should restart.
    pub fn acked(&self, id: &str, model: &str, section: Section) -> u64 {
        let g = self.lock();
        g.get(id)
            .and_then(|s| s.residency.get(&(model.to_string(), section)))
            .map(|p| p.acked)
            .unwrap_or(0)
    }

    /// Full progress snapshot for a residency entry.
    pub fn progress(&self, id: &str, model: &str, section: Section) -> Option<TransferProgress> {
        let g = self.lock();
        g.get(id)
            .and_then(|s| s.residency.get(&(model.to_string(), section)))
            .copied()
    }

    /// The device paged the section out (downgrade): reset the resume
    /// state so a future upgrade re-pulls from zero, keeping cumulative
    /// byte counters for reporting.
    pub fn drop_section(&self, id: &str, model: &str, section: Section) -> Result<()> {
        self.with(id, |s| {
            if let Some(p) = s.residency.get_mut(&(model.to_string(), section)) {
                p.acked = 0;
                p.sent_high_water = 0;
                p.complete = false;
            }
        })
    }

    pub fn device_count(&self) -> usize {
        self.lock().len()
    }

    /// Summaries of every session, sorted by device id.
    pub fn summaries(&self) -> Vec<SessionSummary> {
        let g = self.lock();
        let mut out: Vec<SessionSummary> = g
            .iter()
            .map(|(id, s)| SessionSummary {
                id: id.clone(),
                variant: s.policy.current(),
                levels_seen: s.levels_seen,
                switches: s.policy.switches(),
                bytes_sent: s.residency.values().map(|p| p.bytes_sent).sum(),
                bytes_resent: s.residency.values().map(|p| p.bytes_resent).sum(),
                resident_sections: s.residency.values().filter(|p| p.complete).count(),
            })
            .collect();
        out.sort_by(|a, b| a.id.cmp(&b.id));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> SessionTable {
        SessionTable::new(SwitchPolicy::default())
    }

    #[test]
    fn hello_is_idempotent_and_required() {
        let t = table();
        assert!(t.decide("d0", 0.5).is_err());
        t.hello("d0");
        t.hello("d0");
        assert_eq!(t.device_count(), 1);
        assert!(t.decide("d0", 0.5).is_ok());
    }

    #[test]
    fn transfer_progress_tracks_resume_point_and_resends() {
        let t = table();
        t.hello("d0");
        t.begin("d0", "m", Section::B, 1000, 0).unwrap();
        // four 250-byte chunks; the third is sent but never acked
        t.record_send("d0", "m", Section::B, 0, 250).unwrap();
        t.record_ack("d0", "m", Section::B, 250).unwrap();
        t.record_send("d0", "m", Section::B, 250, 500).unwrap();
        t.record_ack("d0", "m", Section::B, 500).unwrap();
        t.record_send("d0", "m", Section::B, 500, 750).unwrap();
        // connection dies here
        assert_eq!(t.acked("d0", "m", Section::B), 500);
        let p = t.progress("d0", "m", Section::B).unwrap();
        assert_eq!(p.sent_high_water, 750);
        assert!(!p.complete);

        // resume from the acked offset: only the unacked chunk re-sends
        t.begin("d0", "m", Section::B, 1000, 500).unwrap();
        t.record_send("d0", "m", Section::B, 500, 750).unwrap();
        t.record_ack("d0", "m", Section::B, 750).unwrap();
        t.record_send("d0", "m", Section::B, 750, 1000).unwrap();
        t.record_ack("d0", "m", Section::B, 1000).unwrap();
        let p = t.progress("d0", "m", Section::B).unwrap();
        assert!(p.complete);
        assert_eq!(p.bytes_sent, 1250);
        assert_eq!(p.bytes_resent, 250); // exactly the unacked chunk
    }

    #[test]
    fn drop_section_resets_resume_state() {
        let t = table();
        t.hello("d0");
        t.begin("d0", "m", Section::B, 100, 0).unwrap();
        t.record_send("d0", "m", Section::B, 0, 100).unwrap();
        t.record_ack("d0", "m", Section::B, 100).unwrap();
        assert!(t.progress("d0", "m", Section::B).unwrap().complete);
        t.drop_section("d0", "m", Section::B).unwrap();
        let p = t.progress("d0", "m", Section::B).unwrap();
        assert!(!p.complete);
        assert_eq!(p.acked, 0);
        assert_eq!(p.bytes_sent, 100, "cumulative counters survive drops");
    }

    #[test]
    fn begin_validates_offset() {
        let t = table();
        t.hello("d0");
        assert!(t.begin("d0", "m", Section::A, 10, 11).is_err());
        assert!(t.begin("d0", "m", Section::A, 10, 10).is_ok());
    }

    #[test]
    fn summaries_aggregate_per_device() {
        let t = table();
        t.hello("b");
        t.hello("a");
        t.begin("a", "m", Section::A, 10, 0).unwrap();
        t.record_send("a", "m", Section::A, 0, 10).unwrap();
        t.record_ack("a", "m", Section::A, 10).unwrap();
        let s = t.summaries();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].id, "a");
        assert_eq!(s[0].resident_sections, 1);
        assert_eq!(s[0].bytes_sent, 10);
        assert_eq!(s[1].resident_sections, 0);
        assert_eq!(s[0].variant, Variant::PartBit);
    }
}
