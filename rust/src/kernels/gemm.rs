//! Integer-domain GEMV: `acc[c] = Σ_r x[r] · w[r·classes + c]` computed
//! straight from the packed section bytes — the dequantization-free
//! forward (DQT-style nested integer arithmetic). The weight matrix is
//! the usual channel-fastest layout (`rows × classes`, element
//! `r·classes + c`), exactly the flat element order of the packed
//! stream, so the matmul walks the stream once, front to back, and no
//! f32 weight vector and no unpacked i32 vector ever exists.
//!
//! Contract shared by every tier (and required for bit-identity):
//!
//! * accumulation is **wrapping i32** — SIMD multiply/add lanes wrap,
//!   so the scalar reference wraps too; all tiers agree on every input,
//!   including adversarial full-range ones,
//! * accumulation order per output channel is ascending `r` (each
//!   channel's sum sees the rows in the same order in every tier —
//!   integer adds commute, but the wrapping contract is easiest to
//!   audit when the order is fixed too),
//! * `acc` arrives zeroed with `acc.len() == classes` (the vtable entry
//!   in `kernels::mod` owns clearing/validation/telemetry).
//!
//! The scale never appears here: callers fold `s_x · s_w` (and the
//! part-bit `2^l` inflation) into one f32 rescale of the i32
//! accumulators — Eq. 10's `s·2^l·w_high` and Eq. 6's
//! `s·(w_high·2^l + w_low)` become epilogues over `classes` values
//! instead of decode passes over `rows × classes` values.
//!
//! This module holds the scalar reference, the mid-stream tail the SIMD
//! drivers resume with, and the SWAR word-parallel path; the AVX2/NEON
//! drivers live in `x86.rs`/`neon.rs` beside their decode siblings.

use crate::bits::lanes;

use super::scalar::LaneCursor;
use super::{swar, swar_aligned, MAX_LANES};

/// Scalar reference: one lane cursor, sequential over the whole stream.
/// The row index never needs a divide — the channel position wraps at
/// `classes`, advancing the activation.
pub(crate) fn gemm(words: &[u8], bits: u8, x: &[i32], classes: usize, acc: &mut [i32]) {
    debug_assert_eq!(acc.len(), classes);
    let mut cur = LaneCursor::new(words, bits);
    for &xv in x {
        for a in acc.iter_mut() {
            *a = a.wrapping_add(xv.wrapping_mul(cur.next()));
        }
    }
}

/// Resume a GEMV at flat element `start` (the SIMD tail entry — same
/// role as `scalar::unpack_dequant_tail`): derives the row/channel
/// phase and picks the cursor up mid-word.
pub(crate) fn gemm_tail(
    words: &[u8],
    bits: u8,
    x: &[i32],
    classes: usize,
    start: usize,
    acc: &mut [i32],
) {
    let len = x.len() * classes;
    if start >= len {
        return;
    }
    let mut cur = LaneCursor::new_at(words, bits, start);
    let (mut r, mut ch) = (start / classes, start % classes);
    for _ in start..len {
        acc[ch] = acc[ch].wrapping_add(x[r].wrapping_mul(cur.next()));
        ch += 1;
        if ch == classes {
            ch = 0;
            r += 1;
        }
    }
}

/// SWAR tier: word-parallel field extraction for lane-aligned widths
/// (one u64 load + constant-trip shift/mask per `lanes(bits)` MACs),
/// scalar cursor otherwise. Also the SIMD tier's fallback on targets
/// without a vector path and the SSE2 baseline's integer path (SSE2
/// has no packed 32-bit multiply).
pub(crate) fn gemm_swar(words: &[u8], bits: u8, x: &[i32], classes: usize, acc: &mut [i32]) {
    if !swar_aligned(bits) {
        gemm(words, bits, x, classes, acc);
        return;
    }
    let n_lanes = lanes(bits);
    let len = x.len() * classes;
    let full = len / n_lanes;
    let mut buf = [0i32; MAX_LANES];
    let (mut r, mut ch) = (0usize, 0usize);
    for w in 0..full {
        swar::decode_words_swar(words, bits, w, 1, &mut buf[..n_lanes]);
        for &v in &buf[..n_lanes] {
            acc[ch] = acc[ch].wrapping_add(x[r].wrapping_mul(v));
            ch += 1;
            if ch == classes {
                ch = 0;
                r += 1;
            }
        }
    }
    gemm_tail(words, bits, x, classes, full * n_lanes, acc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::{int_range, PackedTensor};

    /// Brute-force reference straight from the unpacked values.
    fn naive(vals: &[i32], x: &[i32], classes: usize) -> Vec<i32> {
        let mut acc = vec![0i32; classes];
        for (r, &xv) in x.iter().enumerate() {
            for c in 0..classes {
                acc[c] = acc[c].wrapping_add(xv.wrapping_mul(vals[r * classes + c]));
            }
        }
        acc
    }

    #[test]
    fn scalar_swar_and_tail_match_naive_all_widths() {
        for bits in 2..=16u8 {
            let (lo, hi) = int_range(bits);
            // shapes straddling word boundaries and tiny channel counts
            for (rows, classes) in [(1usize, 1usize), (3, 5), (7, 8), (13, 6), (33, 3)] {
                let len = rows * classes;
                let vals: Vec<i32> = (0..len as i32)
                    .map(|i| lo + (i * 41) % (hi - lo + 1))
                    .collect();
                let x: Vec<i32> = (0..rows as i32).map(|i| (i * 37) % 255 - 127).collect();
                let bytes = PackedTensor::pack(&vals, bits).unwrap().to_le_bytes();
                let want = naive(&vals, &x, classes);

                let mut acc = vec![0i32; classes];
                gemm(&bytes, bits, &x, classes, &mut acc);
                assert_eq!(acc, want, "scalar bits={bits} {rows}x{classes}");

                acc.iter_mut().for_each(|a| *a = 0);
                gemm_swar(&bytes, bits, &x, classes, &mut acc);
                assert_eq!(acc, want, "swar bits={bits} {rows}x{classes}");

                // tail from every resume point equals full minus prefix:
                // run the prefix scalarly, then hand over mid-stream
                for start in [0usize, 1, classes, len / 2, len.saturating_sub(1), len] {
                    let mut acc = vec![0i32; classes];
                    let mut cur = LaneCursor::new(&bytes, bits);
                    for e in 0..start {
                        let (r, c) = (e / classes, e % classes);
                        acc[c] = acc[c].wrapping_add(x[r].wrapping_mul(cur.next()));
                    }
                    gemm_tail(&bytes, bits, &x, classes, start, &mut acc);
                    assert_eq!(acc, want, "tail bits={bits} start={start}");
                }
            }
        }
    }

    #[test]
    fn wrapping_accumulation_is_defined() {
        // full-range INT16 weights against big activations overflow i32;
        // all paths must agree on the wrapped value instead of panicking
        let vals = vec![i16::MAX as i32; 64];
        let bytes = PackedTensor::pack(&vals, 16).unwrap().to_le_bytes();
        let x = vec![i32::MAX / 2; 16];
        let mut scalar_acc = vec![0i32; 4];
        let mut swar_acc = vec![0i32; 4];
        gemm(&bytes, 16, &x, 4, &mut scalar_acc);
        gemm_swar(&bytes, 16, &x, 4, &mut swar_acc);
        assert_eq!(scalar_acc, swar_acc);
    }
}
