//! Switching kernels (S12): runtime-dispatched one-pass packed-bytes →
//! f32 decode.
//!
//! The paper's headline operation — cheap on-device bitwidth switching
//! (§3.3, Table 5) — is gated by how fast packed section bytes become
//! dequantized f32 weights. The legacy composition is four passes with
//! three transient `Vec<i32>`s per tensor:
//!
//! ```text
//!   unpack(w_high) → unpack(w_low) → recompose → dequant      (legacy)
//!   ───────────────── one fused pass ─────────────────────    (here)
//! ```
//!
//! Both kernels read little-endian packed u64 words straight from
//! section byte slices (the `.nq` payload is not 8-aligned — loads are
//! unaligned) and write only the final f32s:
//!
//! * [`unpack_dequant_into`] — part-bit launch: packed `w_high` words →
//!   `s·2^l · w_high` (Eq. 10; the inflation factor is the `scale_mul`
//!   argument, so callers never materialize an inflated scale vector).
//! * [`recompose_dequant_into`] — full-bit upgrade: `w_high` + `w_low`
//!   word streams → `s·(w_high·2^l + w_low)` (Eq. 6), with **no i32
//!   materialization** between the packed bytes and the output f32s.
//! * [`unpack_ints_into`] — the plain i32 unpack for non-dequantizing
//!   consumers (`PackedTensor`/`PackedView::unpack_into`).
//! * [`gemm_i32_into`] — the integer-domain GEMV (`gemm` module): packed
//!   words × i32 activations → i32 accumulators with **no decode at
//!   all**; the scale is folded into a per-class f32 epilogue by the
//!   caller (`NestTenant`'s dequantization-free forward).
//!
//! # Dispatch tiers
//!
//! Three implementations sit behind one [`KernelPlan`] vtable, selected
//! **once per process** (capability probe hoisted into a `OnceLock` —
//! tenant executor threads never re-detect inside a decode loop):
//!
//! | tier | module | what it is |
//! |------|--------|------------|
//! | [`Tier::Scalar`] | `scalar` | portable lane cursor; the reference semantics |
//! | [`Tier::Swar`]   | `swar`   | word-parallel GPR decode for `bits ∣ 64`, paired-stream blocks, scalar cursor otherwise |
//! | [`Tier::Simd`]   | `x86`/`neon` | explicit `std::arch` paths for **every** width 2..=16: AVX2 (runtime-detected) with an SSE2 baseline on x86-64, NEON on aarch64; falls back to the SWAR dispatch on other targets |
//!
//! The active tier defaults to `Simd` (each arch path degrades
//! gracefully) and can be pinned with the `NQ_KERNEL` environment
//! variable — `NQ_KERNEL=scalar|swar|simd`, read once at first use;
//! unknown values fall back to the default rather than failing a decode
//! (see [`tier_from_env`]). Benches and the differential property tests
//! bypass the process default via [`plan_for`].
//!
//! Numerical contract: **all tiers are bit-identical** to each other
//! and to the legacy composition (`bits::unpack_words_into` →
//! `nest::recompose_into` → `quant::dequant`). Same integer ops, same
//! f32 multiply order — every path computes `v as f32 * (s * scale_mul)`
//! with one pre-folded scale product per channel. `tests/kernels_prop.rs`
//! proves it per tier over every legal `(n, h)`, compensated and
//! uncompensated `w_low`, and lengths not divisible by `lanes(bits)`.
//! DESIGN.md §4e holds the per-arch tier table and the safety argument
//! for the `unsafe` intrinsic blocks.

mod gemm;
mod plan;
mod scalar;
mod swar;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::sync::OnceLock;

use crate::bits::packed_nwords;

/// Max lanes per word (`bits = 2` → 32): sizes the SWAR block buffers.
const MAX_LANES: usize = 32;

/// Is `bits` lane-aligned (divides the 64-bit word evenly)? These are
/// the widths the SWAR tier decodes word-parallel; the SIMD tier covers
/// every width.
#[inline]
pub fn swar_aligned(bits: u8) -> bool {
    matches!(bits, 2 | 4 | 8 | 16)
}

#[inline(always)]
fn word_at(bytes: &[u8], w: usize) -> u64 {
    u64::from_le_bytes(bytes[8 * w..8 * w + 8].try_into().unwrap())
}

/// Per-channel scales with `scale_mul` folded in, extended by
/// `group - 1` wrapped entries so a vector path can load `group`
/// consecutive scales at any channel phase with one unaligned load.
/// The fold (`s * scale_mul` first, then one multiply per value) is the
/// exact f32 op order of every scalar path — bit-identity preserved.
pub(crate) fn fold_rep(scales: &[f32], scale_mul: f32, group: usize) -> Vec<f32> {
    let c = scales.len();
    let mut rep = Vec::with_capacity(c + group - 1);
    rep.extend(scales.iter().map(|&s| s * scale_mul));
    for i in 0..group - 1 {
        rep.push(rep[i % c]);
    }
    rep
}

// ---------------------------------------------------------------------------
// tiers + dispatch
// ---------------------------------------------------------------------------

/// One decode implementation tier (see the module docs for the table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Portable lane-cursor decode.
    Scalar,
    /// Word-parallel GPR decode for lane-aligned widths.
    Swar,
    /// Explicit `std::arch` vector paths (AVX2/SSE2/NEON).
    Simd,
}

impl Tier {
    /// Every tier, in escalation order.
    pub fn all() -> [Tier; 3] {
        [Tier::Scalar, Tier::Swar, Tier::Simd]
    }

    /// Parse an `NQ_KERNEL` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Tier> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(Tier::Scalar),
            "swar" => Some(Tier::Swar),
            "simd" => Some(Tier::Simd),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Swar => "swar",
            Tier::Simd => "simd",
        }
    }

    /// Index into the telemetry registry's per-tier counter rows
    /// (matches `telemetry::KERNEL_TIERS` order).
    pub fn index(self) -> usize {
        match self {
            Tier::Scalar => 0,
            Tier::Swar => 1,
            Tier::Simd => 2,
        }
    }
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Resolve the `NQ_KERNEL` override: `None` or an unknown value selects
/// the default ([`Tier::Simd`], which degrades gracefully per arch — a
/// host without AVX2 runs the SSE2 baseline, a non-SIMD target runs the
/// SWAR dispatch). A decode must never fail because of an env var, so
/// unknown values are ignored, not errors.
pub fn tier_from_env(value: Option<&str>) -> Tier {
    value.and_then(Tier::parse).unwrap_or(Tier::Simd)
}

type UnpackDequantFn = fn(&[u8], u8, usize, &[f32], f32, &mut Vec<f32>);
type RecomposeDequantFn = fn(&[u8], u8, &[u8], u8, u8, usize, &[f32], &mut Vec<f32>);
type UnpackIntsFn = fn(&[u8], u8, usize, &mut Vec<i32>);
type GemmI32Fn = fn(&[u8], u8, &[i32], usize, &mut [i32]);

/// One tier's dispatch table: the function pointers every consumer
/// (`store::PackedView`, `ModelManager` decode waves, `NestTenant`,
/// `DiverseBitwidths`, fleet reassembly) routes through, plus the
/// resolved sub-path name for diagnostics ("avx2", "sse2", "neon",
/// "swar", "scalar", "swar-fallback").
pub struct KernelPlan {
    pub tier: Tier,
    pub path: &'static str,
    unpack_dequant: UnpackDequantFn,
    recompose_dequant: RecomposeDequantFn,
    unpack_ints: UnpackIntsFn,
    gemm_i32: GemmI32Fn,
}

impl KernelPlan {
    /// Fused one-pass launch decode through this tier (see the module
    /// docs for the contract; validates like [`unpack_dequant_into`]).
    pub fn unpack_dequant_into(
        &self,
        words: &[u8],
        bits: u8,
        len: usize,
        scales: &[f32],
        scale_mul: f32,
        out: &mut Vec<f32>,
    ) {
        out.clear();
        if len == 0 {
            return;
        }
        assert!(!scales.is_empty(), "unpack_dequant_into: empty scales");
        assert!(
            len % scales.len() == 0,
            "unpack_dequant_into: len {len} not a multiple of {} channels — \
             scales would wrap mid-row",
            scales.len()
        );
        assert!(
            words.len() >= 8 * packed_nwords(len, bits),
            "unpack_dequant_into: {} word bytes < {} needed for INT{bits} x {len}",
            words.len(),
            8 * packed_nwords(len, bits)
        );
        out.reserve(len);
        (self.unpack_dequant)(words, bits, len, scales, scale_mul, out);
        debug_assert_eq!(out.len(), len);
        // hot-path telemetry: exactly two relaxed atomic adds
        crate::telemetry::registry().kernels.record(
            crate::telemetry::OP_UNPACK_DEQUANT,
            self.tier.index(),
            (len * 4) as u64,
        );
    }

    /// Fused one-pass upgrade decode through this tier.
    #[allow(clippy::too_many_arguments)]
    pub fn recompose_dequant_into(
        &self,
        high_words: &[u8],
        h_bits: u8,
        low_words: &[u8],
        low_bits: u8,
        l: u8,
        len: usize,
        scales: &[f32],
        out: &mut Vec<f32>,
    ) {
        out.clear();
        if len == 0 {
            return;
        }
        assert!(!scales.is_empty(), "recompose_dequant_into: empty scales");
        assert!(
            len % scales.len() == 0,
            "recompose_dequant_into: len {len} not a multiple of {} channels — \
             scales would wrap mid-row",
            scales.len()
        );
        assert!(
            high_words.len() >= 8 * packed_nwords(len, h_bits),
            "recompose_dequant_into: {} w_high bytes < {} needed for INT{h_bits} x {len}",
            high_words.len(),
            8 * packed_nwords(len, h_bits)
        );
        assert!(
            low_words.len() >= 8 * packed_nwords(len, low_bits),
            "recompose_dequant_into: {} w_low bytes < {} needed for INT{low_bits} x {len}",
            low_words.len(),
            8 * packed_nwords(len, low_bits)
        );
        out.reserve(len);
        (self.recompose_dequant)(high_words, h_bits, low_words, low_bits, l, len, scales, out);
        debug_assert_eq!(out.len(), len);
        // hot-path telemetry: exactly two relaxed atomic adds
        crate::telemetry::registry().kernels.record(
            crate::telemetry::OP_RECOMPOSE_DEQUANT,
            self.tier.index(),
            (len * 4) as u64,
        );
    }

    /// Plain i32 unpack through this tier.
    pub fn unpack_ints_into(&self, words: &[u8], bits: u8, len: usize, out: &mut Vec<i32>) {
        out.clear();
        if len == 0 {
            return;
        }
        assert!(
            words.len() >= 8 * packed_nwords(len, bits),
            "unpack_ints_into: {} word bytes < {} needed for INT{bits} x {len}",
            words.len(),
            8 * packed_nwords(len, bits)
        );
        out.reserve(len);
        (self.unpack_ints)(words, bits, len, out);
        debug_assert_eq!(out.len(), len);
        // hot-path telemetry: exactly two relaxed atomic adds
        crate::telemetry::registry().kernels.record(
            crate::telemetry::OP_UNPACK_INTS,
            self.tier.index(),
            (len * 4) as u64,
        );
    }

    /// Integer-domain GEMV through this tier:
    /// `acc[c] = Σ_r x[r] · w[r·classes + c]` over `x.len()` packed rows
    /// read straight from `words`, **no decode pass and no f32**.
    /// Accumulation is wrapping i32 and bit-identical across tiers (see
    /// the `gemm` module docs for the contract). `acc` is cleared and
    /// resized to `classes` zeros first.
    pub fn gemm_i32_into(
        &self,
        words: &[u8],
        bits: u8,
        x: &[i32],
        classes: usize,
        acc: &mut Vec<i32>,
    ) {
        acc.clear();
        acc.resize(classes, 0);
        if x.is_empty() || classes == 0 {
            return;
        }
        let len = x
            .len()
            .checked_mul(classes)
            .expect("gemm_i32_into: rows * classes overflows");
        assert!(
            words.len() >= 8 * packed_nwords(len, bits),
            "gemm_i32_into: {} word bytes < {} needed for INT{bits} x {len}",
            words.len(),
            8 * packed_nwords(len, bits)
        );
        (self.gemm_i32)(words, bits, x, classes, acc);
        // hot-path telemetry: exactly two relaxed atomic adds; bytes =
        // the packed fields the matmul consumed, scaled like the decode
        // ops (fields × 4) so tiers compare on one axis
        crate::telemetry::registry().kernels.record(
            crate::telemetry::OP_GEMM_I32,
            self.tier.index(),
            (len * 4) as u64,
        );
    }
}

/// The SIMD tier's fn pointers + path name for this target, resolved
/// from the one-time capability probe.
type SimdImpl = (
    UnpackDequantFn,
    RecomposeDequantFn,
    UnpackIntsFn,
    GemmI32Fn,
    &'static str,
);

#[cfg(target_arch = "x86_64")]
fn simd_impl() -> SimdImpl {
    if x86::caps().avx2 {
        (
            x86::unpack_dequant_avx2,
            x86::recompose_dequant_avx2,
            x86::unpack_ints_avx2,
            x86::gemm_i32_avx2,
            x86::path_name(),
        )
    } else {
        (
            x86::unpack_dequant_sse2,
            x86::recompose_dequant_sse2,
            x86::unpack_ints_sse2,
            x86::gemm_i32_sse2,
            x86::path_name(),
        )
    }
}

#[cfg(target_arch = "aarch64")]
fn simd_impl() -> SimdImpl {
    (
        neon::unpack_dequant,
        neon::recompose_dequant,
        neon::unpack_ints,
        neon::gemm_i32,
        neon::path_name(),
    )
}

/// No explicit vector path on this target: the SIMD tier *is* the SWAR
/// dispatch (graceful fallback, never a failure).
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn simd_impl() -> SimdImpl {
    (
        swar::unpack_dequant,
        swar::recompose_dequant,
        swar::unpack_ints,
        gemm::gemm_swar,
        "swar-fallback",
    )
}

/// All three tier plans, built once per process (this is where the
/// capability probe runs — exactly once).
fn plans() -> &'static [KernelPlan; 3] {
    static PLANS: OnceLock<[KernelPlan; 3]> = OnceLock::new();
    PLANS.get_or_init(|| {
        let (ud, rd, ui, gm, path) = simd_impl();
        [
            KernelPlan {
                tier: Tier::Scalar,
                path: "scalar",
                unpack_dequant: scalar::unpack_dequant,
                recompose_dequant: scalar::recompose_dequant,
                unpack_ints: scalar::unpack_ints,
                gemm_i32: gemm::gemm,
            },
            KernelPlan {
                tier: Tier::Swar,
                path: "swar",
                unpack_dequant: swar::unpack_dequant,
                recompose_dequant: swar::recompose_dequant,
                unpack_ints: swar::unpack_ints,
                gemm_i32: gemm::gemm_swar,
            },
            KernelPlan {
                tier: Tier::Simd,
                path,
                unpack_dequant: ud,
                recompose_dequant: rd,
                unpack_ints: ui,
                gemm_i32: gm,
            },
        ]
    })
}

/// The plan for one tier — benches and differential tests use this to
/// pin a tier regardless of `NQ_KERNEL`. Never panics: on targets
/// without a vector path, `Tier::Simd` resolves to the SWAR dispatch.
pub fn plan_for(tier: Tier) -> &'static KernelPlan {
    match tier {
        Tier::Scalar => &plans()[0],
        Tier::Swar => &plans()[1],
        Tier::Simd => &plans()[2],
    }
}

/// The process-wide active plan: `NQ_KERNEL` override (read once) over
/// the default `Simd` tier.
pub fn active() -> &'static KernelPlan {
    static ACTIVE: OnceLock<&'static KernelPlan> = OnceLock::new();
    *ACTIVE.get_or_init(|| plan_for(tier_from_env(std::env::var("NQ_KERNEL").ok().as_deref())))
}

// ---------------------------------------------------------------------------
// module-level entry points (dispatch through the active plan)
// ---------------------------------------------------------------------------

/// Fused one-pass decode: `len` packed `bits`-bit values (LE u64 words
/// in `words`) → `value · scales[i % c] · scale_mul` appended to `out`
/// (cleared first). `scale_mul` is 1.0 for mono weights and `2^l` for
/// the part-bit launch (Eq. 10) — the caller never builds an inflated
/// scale vector. Routed through the process-wide [`KernelPlan`].
///
/// Bit-identical to `unpack_words_into` → scale-inflate → `dequant`.
pub fn unpack_dequant_into(
    words: &[u8],
    bits: u8,
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    active().unpack_dequant_into(words, bits, len, scales, scale_mul, out);
}

/// Fused full-bit upgrade decode: `len` values recomposed from the
/// packed `w_high` (INT `h_bits`) and `w_low` (INT `low_bits`) word
/// streams as `s · (w_high·2^l + w_low)` (Eq. 6), appended to `out`
/// (cleared first). No intermediate i32 vectors exist at any point.
/// Routed through the process-wide [`KernelPlan`].
///
/// Bit-identical to `unpack → unpack → recompose_into → dequant`.
/// `low_bits` is `l+1` for compensated residuals (the `.nq` on-disk
/// format) and `l` for uncompensated ones — the kernel only requires
/// both streams to hold `len` values.
#[allow(clippy::too_many_arguments)]
pub fn recompose_dequant_into(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    active().recompose_dequant_into(
        high_words, h_bits, low_words, low_bits, l, len, scales, out,
    );
}

/// Plain i32 unpack from packed LE bytes, routed through the
/// process-wide [`KernelPlan`] — the dispatched successor of the
/// iterator-based `bits::unpack_words_into` (which remains the portable
/// entry for non-contiguous word streams).
pub fn unpack_ints_into(words: &[u8], bits: u8, len: usize, out: &mut Vec<i32>) {
    active().unpack_ints_into(words, bits, len, out);
}

/// Integer-domain GEMV routed through the process-wide [`KernelPlan`]:
/// `acc[c] = Σ_r x[r] · w[r·classes + c]` with `x.len() · classes`
/// packed `bits`-bit weights consumed straight from `words` — no decode
/// pass, no f32, wrapping i32 accumulation, bit-identical across tiers.
/// `acc` is cleared and resized to `classes` zeros first. The caller
/// folds `s_x · s_w` (and the part-bit `2^l`) into one rescale of the
/// `classes` accumulators.
pub fn gemm_i32_into(words: &[u8], bits: u8, x: &[i32], classes: usize, acc: &mut Vec<i32>) {
    active().gemm_i32_into(words, bits, x, classes, acc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::{int_range, lanes, PackedTensor};
    use crate::nest;
    use crate::quant;

    /// Legacy composition the kernels must match bit-for-bit.
    fn legacy_unpack_dequant(t: &PackedTensor, scales: &[f32], mul: f32) -> Vec<f32> {
        let mut ints = Vec::new();
        t.unpack_into(&mut ints);
        let inflated: Vec<f32> = scales.iter().map(|&s| s * mul).collect();
        let mut out = Vec::new();
        quant::dequant(&ints, &inflated, &mut out);
        out
    }

    fn legacy_recompose_dequant(
        hi: &PackedTensor,
        lo: &PackedTensor,
        l: u8,
        scales: &[f32],
    ) -> Vec<f32> {
        let (mut hs, mut ls, mut rec) = (Vec::new(), Vec::new(), Vec::new());
        hi.unpack_into(&mut hs);
        lo.unpack_into(&mut ls);
        nest::recompose_into(&hs, &ls, l, &mut rec);
        let mut out = Vec::new();
        quant::dequant(&rec, scales, &mut out);
        out
    }

    fn toy_scales(c: usize) -> Vec<f32> {
        (0..c).map(|i| 0.01 + 0.003 * i as f32).collect()
    }

    #[test]
    fn unpack_dequant_matches_legacy_all_bits_all_tiers() {
        for bits in 2..=16u8 {
            let (lo, hi) = int_range(bits);
            // base length deliberately NOT a multiple of lanes(bits);
            // rounded up per channel count so rows are whole
            let base = 5 * lanes(bits) + 3;
            for c in [1usize, 2, 3, 7, base] {
                let len = base.div_ceil(c) * c;
                let vals: Vec<i32> = (0..len as i32)
                    .map(|i| lo + (i * 37) % (hi - lo + 1))
                    .collect();
                let t = PackedTensor::pack(&vals, bits).unwrap();
                let bytes = t.to_le_bytes();
                let scales = toy_scales(c);
                for mul in [1.0f32, 16.0] {
                    let want = legacy_unpack_dequant(&t, &scales, mul);
                    for tier in Tier::all() {
                        let mut got = Vec::new();
                        plan_for(tier)
                            .unpack_dequant_into(&bytes, bits, len, &scales, mul, &mut got);
                        assert_eq!(got, want, "tier={tier} bits={bits} c={c} mul={mul}");
                    }
                }
            }
        }
    }

    #[test]
    fn recompose_dequant_matches_legacy_grid_all_tiers() {
        // (7|4), (11|8), (5|2) hit the paired-SWAR path (both streams
        // lane-aligned); the rest cover mixed and fully scalar fallbacks
        for (n, h) in [
            (8u8, 4u8),
            (8, 5),
            (8, 6),
            (6, 3),
            (16, 8),
            (7, 3),
            (4, 2),
            (7, 4),
            (11, 8),
            (5, 2),
        ] {
            let cfg = nest::NestConfig::new(n, h).unwrap();
            let (lo, hi) = int_range(n);
            // base length NOT a multiple of either stream's lane count;
            // rounded up per channel count so rows are whole
            let base = 3 * lanes(h) * lanes(cfg.low_bits()) + 11;
            for c in [1usize, 4, 5, 64] {
                let len = base.div_ceil(c) * c;
                let vals: Vec<i32> = (0..len as i32)
                    .map(|i| lo + (i * 101) % (hi - lo + 1))
                    .collect();
                let (hs, ls) = nest::decompose(&vals, cfg, nest::Rounding::BitShift, true);
                let th = PackedTensor::pack(&hs, h).unwrap();
                let tl = PackedTensor::pack(&ls, cfg.low_bits()).unwrap();
                let (hb, lb) = (th.to_le_bytes(), tl.to_le_bytes());
                let scales = toy_scales(c);
                let want = legacy_recompose_dequant(&th, &tl, cfg.l(), &scales);
                for tier in Tier::all() {
                    let mut got = Vec::new();
                    plan_for(tier).recompose_dequant_into(
                        &hb,
                        h,
                        &lb,
                        cfg.low_bits(),
                        cfg.l(),
                        len,
                        &scales,
                        &mut got,
                    );
                    assert_eq!(got, want, "tier={tier} INT({n}|{h}) c={c}");
                }
            }
        }
    }

    #[test]
    fn unpack_ints_matches_packed_tensor_all_tiers() {
        for bits in 2..=16u8 {
            let (lo, hi) = int_range(bits);
            let len = 4 * lanes(bits) + 1;
            let vals: Vec<i32> = (0..len as i32)
                .map(|i| lo + (i * 13) % (hi - lo + 1))
                .collect();
            let t = PackedTensor::pack(&vals, bits).unwrap();
            let bytes = t.to_le_bytes();
            for tier in Tier::all() {
                let mut got = Vec::new();
                plan_for(tier).unpack_ints_into(&bytes, bits, len, &mut got);
                assert_eq!(got, vals, "tier={tier} bits={bits}");
            }
        }
    }

    #[test]
    fn empty_and_buffer_reuse() {
        let mut out = vec![1.0f32; 8];
        unpack_dequant_into(&[], 4, 0, &[], 1.0, &mut out);
        assert!(out.is_empty());
        recompose_dequant_into(&[], 4, &[], 5, 4, 0, &[], &mut out);
        assert!(out.is_empty());
        // reuse: second decode overwrites, never appends
        let t = PackedTensor::pack(&[1, -2, 3], 8).unwrap();
        let bytes = t.to_le_bytes();
        unpack_dequant_into(&bytes, 8, 3, &[2.0], 1.0, &mut out);
        assert_eq!(out, vec![2.0, -4.0, 6.0]);
        unpack_dequant_into(&bytes, 8, 3, &[1.0], 1.0, &mut out);
        assert_eq!(out, vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn swar_alignment_table() {
        let aligned: Vec<u8> = (2..=16).filter(|&b| swar_aligned(b)).collect();
        assert_eq!(aligned, vec![2, 4, 8, 16]);
        for b in aligned {
            assert_eq!(64 % b as usize, 0);
        }
    }

    #[test]
    fn tier_env_contract() {
        assert_eq!(tier_from_env(Some("scalar")), Tier::Scalar);
        assert_eq!(tier_from_env(Some("SWAR")), Tier::Swar);
        assert_eq!(tier_from_env(Some("simd")), Tier::Simd);
        // unknown / unset fall back to the default, never panic
        assert_eq!(tier_from_env(Some("avx9000")), Tier::Simd);
        assert_eq!(tier_from_env(None), Tier::Simd);
        for tier in Tier::all() {
            let p = plan_for(tier);
            assert_eq!(p.tier, tier);
            assert!(!p.path.is_empty());
            assert_eq!(Tier::parse(tier.label()), Some(tier));
        }
        // the active plan is one of the three
        assert!(Tier::all().contains(&active().tier));
    }

    #[test]
    fn telemetry_counts_decoded_bytes_per_tier() {
        use crate::telemetry::{registry, KERNEL_TIERS, OP_UNPACK_DEQUANT};
        for tier in Tier::all() {
            assert_eq!(KERNEL_TIERS[tier.index()], tier.label());
        }
        let t = PackedTensor::pack(&[1, -2, 3, 4], 8).unwrap();
        let bytes = t.to_le_bytes();
        let k = &registry().kernels;
        let idx = Tier::Scalar.index();
        let (calls0, bytes0) = (k.calls(OP_UNPACK_DEQUANT, idx), k.bytes(OP_UNPACK_DEQUANT, idx));
        let mut out = Vec::new();
        plan_for(Tier::Scalar).unpack_dequant_into(&bytes, 8, 4, &[1.0], 1.0, &mut out);
        // >= because parallel tests in this binary also decode via scalar
        assert!(k.calls(OP_UNPACK_DEQUANT, idx) >= calls0 + 1);
        assert!(k.bytes(OP_UNPACK_DEQUANT, idx) >= bytes0 + 16);
    }

    #[test]
    fn fold_rep_wraps_channels() {
        let rep = fold_rep(&[1.0, 2.0, 3.0], 2.0, 8);
        assert_eq!(rep.len(), 3 + 7);
        assert_eq!(&rep[..3], &[2.0, 4.0, 6.0]);
        // wrapped tail repeats the folded scales
        assert_eq!(&rep[3..], &[2.0, 4.0, 6.0, 2.0, 4.0, 6.0, 2.0]);
    }

    // channel-count validation (satellite bugfix): a len that is not a
    // multiple of the channel count used to wrap scales mid-tensor
    // silently — now it is rejected up front

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn unpack_dequant_rejects_mismatched_channel_count() {
        let t = PackedTensor::pack(&[1, 2, 3, 4, 5, 6, 7], 8).unwrap();
        let bytes = t.to_le_bytes();
        let mut out = Vec::new();
        // 7 values over 2 channels: 3.5 rows — must panic, not mis-scale
        unpack_dequant_into(&bytes, 8, 7, &[0.5, 0.25], 1.0, &mut out);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn recompose_dequant_rejects_mismatched_channel_count() {
        let cfg = nest::NestConfig::new(8, 4).unwrap();
        let vals: Vec<i32> = (0..9).collect();
        let (hs, ls) = nest::decompose(&vals, cfg, nest::Rounding::BitShift, true);
        let hb = PackedTensor::pack(&hs, 4).unwrap().to_le_bytes();
        let lb = PackedTensor::pack(&ls, cfg.low_bits()).unwrap().to_le_bytes();
        let mut out = Vec::new();
        recompose_dequant_into(
            &hb,
            4,
            &lb,
            cfg.low_bits(),
            cfg.l(),
            9,
            &[0.5, 0.25],
            &mut out,
        );
    }

    #[test]
    fn gemm_i32_matches_scalar_reference_all_bits_all_tiers() {
        // every width × shapes where 8/4-element SIMD groups straddle
        // row boundaries (classes not a multiple of the group size)
        for bits in 2..=16u8 {
            let (lo, hi) = int_range(bits);
            for (rows, classes) in [(1usize, 3usize), (4, 6), (9, 5), (17, 13), (3, 64)] {
                let len = rows * classes;
                let vals: Vec<i32> = (0..len as i32)
                    .map(|i| lo + (i * 53) % (hi - lo + 1))
                    .collect();
                let bytes = PackedTensor::pack(&vals, bits).unwrap().to_le_bytes();
                let x: Vec<i32> = (0..rows as i32).map(|i| (i * 29) % 200 - 100).collect();
                let mut want = Vec::new();
                plan_for(Tier::Scalar).gemm_i32_into(&bytes, bits, &x, classes, &mut want);
                // cross-check the scalar tier against naive i64 math
                // (no wrap at these magnitudes)
                for c in 0..classes {
                    let exact: i64 = (0..rows)
                        .map(|r| x[r] as i64 * vals[r * classes + c] as i64)
                        .sum();
                    assert_eq!(want[c] as i64, exact, "bits={bits} c={c}");
                }
                for tier in [Tier::Swar, Tier::Simd] {
                    let mut got = Vec::new();
                    plan_for(tier).gemm_i32_into(&bytes, bits, &x, classes, &mut got);
                    assert_eq!(got, want, "tier={tier} bits={bits} {rows}x{classes}");
                }
            }
        }
    }

    #[test]
    fn gemm_i32_clears_and_handles_empty() {
        let mut acc = vec![7i32; 3];
        gemm_i32_into(&[], 8, &[], 4, &mut acc);
        assert_eq!(acc, vec![0; 4]);
        gemm_i32_into(&[], 8, &[1, 2], 0, &mut acc);
        assert!(acc.is_empty());
    }
}
