//! Fused switching kernels (S12): one-pass packed-bytes → f32 decode.
//!
//! The paper's headline operation — cheap on-device bitwidth switching
//! (§3.3, Table 5) — is gated by how fast packed section bytes become
//! dequantized f32 weights. The legacy composition is four passes with
//! three transient `Vec<i32>`s per tensor:
//!
//! ```text
//!   unpack(w_high) → unpack(w_low) → recompose → dequant      (legacy)
//!   ───────────────── one fused pass ─────────────────────    (here)
//! ```
//!
//! Both kernels read little-endian packed u64 words straight from
//! section byte slices (the `.nq` payload is not 8-aligned — words are
//! loaded with `u64::from_le_bytes`, a single unaligned mov) and write
//! only the final f32s:
//!
//! * [`unpack_dequant_into`] — part-bit launch: packed `w_high` words →
//!   `s·2^l · w_high` (Eq. 10; the inflation factor is the `scale_mul`
//!   argument, so callers never materialize an inflated scale vector).
//! * [`recompose_dequant_into`] — full-bit upgrade: `w_high` + `w_low`
//!   word streams → `s·(w_high·2^l + w_low)` (Eq. 6), with **no i32
//!   materialization** between the packed bytes and the output f32s.
//!
//! Each has a SWAR fast path for lane-aligned bitwidths (`bits ∣ 64`,
//! i.e. 2/4/8/16: whole u64 words are decoded with a constant-trip
//! unrolled mask/shift loop the compiler vectorizes, sign-extension via
//! the xor-sub idiom instead of two shifts) and hoisted per-channel
//! scales (when the channel count divides the lane block, the scale
//! pattern repeats per word and is precomputed once). Everything else
//! falls back to the scalar lane loop — same single-pass structure,
//! per-lane refill.
//!
//! Numerical contract: outputs are bit-identical to the legacy
//! composition (`bits::unpack_words_into` → `nest::recompose_into` →
//! `quant::dequant`). Same integer ops, same f32 multiply order —
//! `tests/kernels_prop.rs` proves it over every legal `(n, h)`,
//! compensated and uncompensated `w_low`, and lengths not divisible by
//! `lanes(bits)`.

use crate::bits::{lanes, packed_nwords, sext};

/// Max lanes per word (`bits = 2` → 32): sizes the SWAR block buffers.
const MAX_LANES: usize = 32;

/// Is `bits` lane-aligned (divides the 64-bit word evenly)?
#[inline]
pub fn swar_aligned(bits: u8) -> bool {
    matches!(bits, 2 | 4 | 8 | 16)
}

#[inline(always)]
fn word_at(bytes: &[u8], w: usize) -> u64 {
    u64::from_le_bytes(bytes[8 * w..8 * w + 8].try_into().unwrap())
}

// ---------------------------------------------------------------------------
// scalar lane cursor (general fallback)
// ---------------------------------------------------------------------------

/// Streaming lane decoder over packed LE words: one `u64` load per
/// `lanes` values, shift-and-mask per lane. The state the scalar paths
/// carry instead of materializing word or i32 vectors.
struct LaneCursor<'a> {
    bytes: &'a [u8],
    /// Next word index to load.
    next_word: usize,
    word: u64,
    /// Lanes left in the loaded word.
    left: usize,
    bits: u32,
    lanes: usize,
    mask: u64,
    sign: u64,
}

impl<'a> LaneCursor<'a> {
    fn new(bytes: &'a [u8], bits: u8) -> LaneCursor<'a> {
        LaneCursor {
            bytes,
            next_word: 0,
            word: 0,
            left: 0,
            bits: bits as u32,
            lanes: lanes(bits),
            mask: (1u64 << bits) - 1,
            sign: 1u64 << (bits - 1),
        }
    }

    #[inline(always)]
    fn next(&mut self) -> i32 {
        if self.left == 0 {
            self.word = word_at(self.bytes, self.next_word);
            self.next_word += 1;
            self.left = self.lanes;
        }
        let v = sext(self.word & self.mask, self.sign);
        self.word >>= self.bits;
        self.left -= 1;
        v
    }
}

// ---------------------------------------------------------------------------
// part-bit launch kernel: packed → dequantized f32
// ---------------------------------------------------------------------------

/// Fused one-pass decode: `len` packed `bits`-bit values (LE u64 words
/// in `words`) → `value · scales[i % c] · scale_mul` appended to `out`
/// (cleared first). `scale_mul` is 1.0 for mono weights and `2^l` for
/// the part-bit launch (Eq. 10) — the caller never builds an inflated
/// scale vector.
///
/// Bit-identical to `unpack_words_into` → scale-inflate → `dequant`.
pub fn unpack_dequant_into(
    words: &[u8],
    bits: u8,
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    out.clear();
    if len == 0 {
        return;
    }
    assert!(!scales.is_empty(), "unpack_dequant_into: empty scales");
    assert!(
        words.len() >= 8 * packed_nwords(len, bits),
        "unpack_dequant_into: {} word bytes < {} needed for INT{bits} x {len}",
        words.len(),
        8 * packed_nwords(len, bits)
    );
    out.reserve(len);
    match bits {
        2 => unpack_dequant_swar::<2>(words, len, scales, scale_mul, out),
        4 => unpack_dequant_swar::<4>(words, len, scales, scale_mul, out),
        8 => unpack_dequant_swar::<8>(words, len, scales, scale_mul, out),
        16 => unpack_dequant_swar::<16>(words, len, scales, scale_mul, out),
        _ => unpack_dequant_scalar(words, bits, len, scales, scale_mul, out),
    }
}

fn unpack_dequant_scalar(
    words: &[u8],
    bits: u8,
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    let mut cur = LaneCursor::new(words, bits);
    let c = scales.len();
    let mut done = 0;
    // channel-sized row chunks: the channel index is the position in the
    // chunk, so there is no per-element modulo
    while done < len {
        let take = c.min(len - done);
        for &s in &scales[..take] {
            out.push(cur.next() as f32 * (s * scale_mul));
        }
        done += take;
    }
}

/// SWAR path (`BITS ∣ 64`): constant-trip unrolled mask/shift over whole
/// words; per-channel scales hoisted into a per-word table when the
/// channel count divides the lane count.
fn unpack_dequant_swar<const BITS: u32>(
    words: &[u8],
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    let n_lanes = (64 / BITS) as usize;
    let mask = (1u64 << BITS) - 1;
    let sign = 1u64 << (BITS - 1);
    let c = scales.len();
    let full = len / n_lanes;
    let rem = len - full * n_lanes;
    if c <= n_lanes && n_lanes % c == 0 {
        // channel phase repeats exactly per word: hoist scales (with the
        // inflation folded in) into one table, indexed by lane
        let mut tbl = [0f32; MAX_LANES];
        for (i, t) in tbl.iter_mut().take(n_lanes).enumerate() {
            *t = scales[i % c] * scale_mul;
        }
        for w in 0..full {
            let mut word = word_at(words, w);
            for &t in tbl.iter().take(n_lanes) {
                out.push(sext(word & mask, sign) as f32 * t);
                word >>= BITS;
            }
        }
        if rem > 0 {
            let mut word = word_at(words, full);
            for &t in tbl.iter().take(rem) {
                out.push(sext(word & mask, sign) as f32 * t);
                word >>= BITS;
            }
        }
    } else {
        // general channel stride: running channel cursor, still one
        // word load per `n_lanes` outputs
        let mut ch = 0usize;
        for w in 0..full {
            let mut word = word_at(words, w);
            for _ in 0..n_lanes {
                out.push(sext(word & mask, sign) as f32 * (scales[ch] * scale_mul));
                word >>= BITS;
                ch += 1;
                if ch == c {
                    ch = 0;
                }
            }
        }
        if rem > 0 {
            let mut word = word_at(words, full);
            for _ in 0..rem {
                out.push(sext(word & mask, sign) as f32 * (scales[ch] * scale_mul));
                word >>= BITS;
                ch += 1;
                if ch == c {
                    ch = 0;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// full-bit upgrade kernel: w_high + w_low word streams → f32
// ---------------------------------------------------------------------------

/// Fused full-bit upgrade decode: `len` values recomposed from the
/// packed `w_high` (INT `h_bits`) and `w_low` (INT `low_bits`) word
/// streams as `s · (w_high·2^l + w_low)` (Eq. 6), appended to `out`
/// (cleared first). No intermediate i32 vectors exist at any point.
///
/// Bit-identical to `unpack → unpack → recompose_into → dequant`.
/// `low_bits` is `l+1` for compensated residuals (the `.nq` on-disk
/// format) and `l` for uncompensated ones — the kernel only requires
/// both streams to hold `len` values.
#[allow(clippy::too_many_arguments)]
pub fn recompose_dequant_into(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    out.clear();
    if len == 0 {
        return;
    }
    assert!(!scales.is_empty(), "recompose_dequant_into: empty scales");
    assert!(
        high_words.len() >= 8 * packed_nwords(len, h_bits),
        "recompose_dequant_into: {} w_high bytes < {} needed for INT{h_bits} x {len}",
        high_words.len(),
        8 * packed_nwords(len, h_bits)
    );
    assert!(
        low_words.len() >= 8 * packed_nwords(len, low_bits),
        "recompose_dequant_into: {} w_low bytes < {} needed for INT{low_bits} x {len}",
        low_words.len(),
        8 * packed_nwords(len, low_bits)
    );
    out.reserve(len);
    if swar_aligned(h_bits) && swar_aligned(low_bits) {
        recompose_dequant_swar(high_words, h_bits, low_words, low_bits, l, len, scales, out);
    } else {
        recompose_dequant_scalar(high_words, h_bits, low_words, low_bits, l, len, scales, out);
    }
}

#[allow(clippy::too_many_arguments)]
fn recompose_dequant_scalar(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    let mut hc = LaneCursor::new(high_words, h_bits);
    let mut lc = LaneCursor::new(low_words, low_bits);
    let shift = l as u32;
    let c = scales.len();
    let mut done = 0;
    while done < len {
        let take = c.min(len - done);
        for &s in &scales[..take] {
            let v = (hc.next() << shift) + lc.next();
            out.push(v as f32 * s);
        }
        done += take;
    }
}

/// Decode `n_words` whole words starting at word `first` into `dst`
/// (`dst.len() == n_words · lanes`), SWAR-unrolled per word.
fn decode_words_swar_inner<const BITS: u32>(
    bytes: &[u8],
    first: usize,
    n_words: usize,
    dst: &mut [i32],
) {
    let n_lanes = (64 / BITS) as usize;
    let mask = (1u64 << BITS) - 1;
    let sign = 1u64 << (BITS - 1);
    debug_assert_eq!(dst.len(), n_words * n_lanes);
    for (w, chunk) in dst.chunks_exact_mut(n_lanes).enumerate() {
        let mut word = word_at(bytes, first + w);
        for d in chunk {
            *d = sext(word & mask, sign);
            word >>= BITS;
        }
    }
}

fn decode_words_swar(bytes: &[u8], bits: u8, first: usize, n_words: usize, dst: &mut [i32]) {
    match bits {
        2 => decode_words_swar_inner::<2>(bytes, first, n_words, dst),
        4 => decode_words_swar_inner::<4>(bytes, first, n_words, dst),
        8 => decode_words_swar_inner::<8>(bytes, first, n_words, dst),
        16 => decode_words_swar_inner::<16>(bytes, first, n_words, dst),
        _ => unreachable!("decode_words_swar on non-aligned bits {bits}"),
    }
}

/// SWAR pair path: both bitwidths divide 64, so their lane counts are
/// powers of two and the smaller divides the larger — a block of
/// `max(h_lanes, low_lanes)` elements is whole words of *both* streams.
/// Each block decodes into two stack buffers (≤ 32 lanes, registers/L1)
/// and combines straight into the output f32s.
#[allow(clippy::too_many_arguments)]
fn recompose_dequant_swar(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    let h_lanes = lanes(h_bits);
    let l_lanes = lanes(low_bits);
    let block = h_lanes.max(l_lanes);
    let shift = l as u32;
    let c = scales.len();
    let mut hbuf = [0i32; MAX_LANES];
    let mut lbuf = [0i32; MAX_LANES];
    let hoist = c <= block && block % c == 0;
    let mut tbl = [0f32; MAX_LANES];
    if hoist {
        // block boundaries land on channel boundaries: one scale table
        for (i, t) in tbl.iter_mut().take(block).enumerate() {
            *t = scales[i % c];
        }
    }
    let (mut done, mut hw, mut lw, mut ch) = (0usize, 0usize, 0usize, 0usize);
    while done < len {
        let take = block.min(len - done);
        let need_hw = take.div_ceil(h_lanes);
        let need_lw = take.div_ceil(l_lanes);
        decode_words_swar(high_words, h_bits, hw, need_hw, &mut hbuf[..need_hw * h_lanes]);
        decode_words_swar(low_words, low_bits, lw, need_lw, &mut lbuf[..need_lw * l_lanes]);
        hw += need_hw;
        lw += need_lw;
        if hoist {
            for ((&h, &lo), &t) in hbuf[..take].iter().zip(&lbuf[..take]).zip(&tbl[..take]) {
                out.push(((h << shift) + lo) as f32 * t);
            }
        } else {
            for (&h, &lo) in hbuf[..take].iter().zip(&lbuf[..take]) {
                out.push(((h << shift) + lo) as f32 * scales[ch]);
                ch += 1;
                if ch == c {
                    ch = 0;
                }
            }
        }
        done += take;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::{int_range, PackedTensor};
    use crate::nest;
    use crate::quant;

    /// Legacy composition the kernels must match bit-for-bit.
    fn legacy_unpack_dequant(t: &PackedTensor, scales: &[f32], mul: f32) -> Vec<f32> {
        let mut ints = Vec::new();
        t.unpack_into(&mut ints);
        let inflated: Vec<f32> = scales.iter().map(|&s| s * mul).collect();
        let mut out = Vec::new();
        quant::dequant(&ints, &inflated, &mut out);
        out
    }

    fn legacy_recompose_dequant(
        hi: &PackedTensor,
        lo: &PackedTensor,
        l: u8,
        scales: &[f32],
    ) -> Vec<f32> {
        let (mut hs, mut ls, mut rec) = (Vec::new(), Vec::new(), Vec::new());
        hi.unpack_into(&mut hs);
        lo.unpack_into(&mut ls);
        nest::recompose_into(&hs, &ls, l, &mut rec);
        let mut out = Vec::new();
        quant::dequant(&rec, scales, &mut out);
        out
    }

    fn toy_scales(c: usize) -> Vec<f32> {
        (0..c).map(|i| 0.01 + 0.003 * i as f32).collect()
    }

    #[test]
    fn unpack_dequant_matches_legacy_all_bits() {
        for bits in 2..=16u8 {
            let (lo, hi) = int_range(bits);
            // length deliberately NOT a multiple of lanes(bits)
            let len = 5 * lanes(bits) + 3;
            let vals: Vec<i32> = (0..len as i32)
                .map(|i| lo + (i * 37) % (hi - lo + 1))
                .collect();
            let t = PackedTensor::pack(&vals, bits).unwrap();
            let bytes = t.to_le_bytes();
            for c in [1usize, 2, 3, 7, len] {
                let scales = toy_scales(c);
                for mul in [1.0f32, 16.0] {
                    let want = legacy_unpack_dequant(&t, &scales, mul);
                    let mut got = Vec::new();
                    unpack_dequant_into(&bytes, bits, len, &scales, mul, &mut got);
                    assert_eq!(got, want, "bits={bits} c={c} mul={mul}");
                }
            }
        }
    }

    #[test]
    fn recompose_dequant_matches_legacy_grid() {
        // (7|4), (11|8), (5|2) hit the paired-SWAR path (both streams
        // lane-aligned); the rest cover mixed and fully scalar fallbacks
        for (n, h) in [
            (8u8, 4u8),
            (8, 5),
            (8, 6),
            (6, 3),
            (16, 8),
            (7, 3),
            (4, 2),
            (7, 4),
            (11, 8),
            (5, 2),
        ] {
            let cfg = nest::NestConfig::new(n, h).unwrap();
            let (lo, hi) = int_range(n);
            let len = 3 * lanes(h) * lanes(cfg.low_bits()) + 11;
            let vals: Vec<i32> = (0..len as i32)
                .map(|i| lo + (i * 101) % (hi - lo + 1))
                .collect();
            let (hs, ls) = nest::decompose(&vals, cfg, nest::Rounding::BitShift, true);
            let th = PackedTensor::pack(&hs, h).unwrap();
            let tl = PackedTensor::pack(&ls, cfg.low_bits()).unwrap();
            let (hb, lb) = (th.to_le_bytes(), tl.to_le_bytes());
            for c in [1usize, 4, 5, 64] {
                let scales = toy_scales(c);
                let want = legacy_recompose_dequant(&th, &tl, cfg.l(), &scales);
                let mut got = Vec::new();
                recompose_dequant_into(
                    &hb,
                    h,
                    &lb,
                    cfg.low_bits(),
                    cfg.l(),
                    len,
                    &scales,
                    &mut got,
                );
                assert_eq!(got, want, "INT({n}|{h}) c={c}");
            }
        }
    }

    #[test]
    fn empty_and_buffer_reuse() {
        let mut out = vec![1.0f32; 8];
        unpack_dequant_into(&[], 4, 0, &[], 1.0, &mut out);
        assert!(out.is_empty());
        recompose_dequant_into(&[], 4, &[], 5, 4, 0, &[], &mut out);
        assert!(out.is_empty());
        // reuse: second decode overwrites, never appends
        let t = PackedTensor::pack(&[1, -2, 3], 8).unwrap();
        let bytes = t.to_le_bytes();
        unpack_dequant_into(&bytes, 8, 3, &[2.0], 1.0, &mut out);
        assert_eq!(out, vec![2.0, -4.0, 6.0]);
        unpack_dequant_into(&bytes, 8, 3, &[1.0], 1.0, &mut out);
        assert_eq!(out, vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn swar_alignment_table() {
        let aligned: Vec<u8> = (2..=16).filter(|&b| swar_aligned(b)).collect();
        assert_eq!(aligned, vec![2, 4, 8, 16]);
        for b in aligned {
            assert_eq!(64 % b as usize, 0);
        }
    }
}
