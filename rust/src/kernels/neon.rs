//! aarch64 NEON tier: 4 elements per iteration for every bitwidth.
//!
//! NEON is mandatory on aarch64, so there is no runtime probe — the
//! whole module is compile-time gated. Field extraction loads each
//! lane's 4-byte window with *safe* `u32::from_le_bytes` slice reads
//! (bounds come from the plan's `span` check, same contract as the x86
//! tier), then does the per-lane variable right shift in-register:
//! `vshlq_u32` with negated counts is NEON's `vpsrlvd`. Mask, xor-sub
//! sign extension, convert and scale-multiply all stay in the same
//! `uint32x4`/`float32x4` registers.
//!
//! # Safety
//!
//! The only `unsafe` is the NEON intrinsics themselves (always
//! available on this target) and the raw stores into the output
//! vector's reserved capacity — `set_len` is called with exactly the
//! element count the body produced, and every one of those elements was
//! stored first. All input loads are safe slice reads.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::aarch64::*;

use super::plan::{self, plan4, Group};
use super::{fold_rep, scalar};

/// Sub-path name for diagnostics and the bench artifact.
pub(crate) fn path_name() -> &'static str {
    "neon"
}

/// Load one group's four windows (safe reads) and extract the
/// sign-extended fields as an `int32x4_t`.
///
/// Safety: NEON intrinsics only; caller verified `base + g.span <=
/// bytes.len()`, which bounds every `off[k] + 4`.
#[inline(always)]
#[allow(clippy::needless_range_loop)] // k indexes two parallel fixed arrays
unsafe fn extract4(bytes: &[u8], base: usize, g: &Group, mask: u32, sign: u32) -> int32x4_t {
    let mut w = [0u32; 4];
    for k in 0..4 {
        let o = base + g.off[k] as usize;
        w[k] = u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
    }
    let wv = vld1q_u32(w.as_ptr());
    let sh = vld1q_s32(g.shift.as_ptr());
    // variable right shift: vshl by negated counts
    let f = vandq_u32(vshlq_u32(wv, vnegq_s32(sh)), vdupq_n_u32(mask));
    let sv = vdupq_n_s32(sign as i32);
    vsubq_s32(veorq_s32(vreinterpretq_s32_u32(f), sv), sv)
}

unsafe fn unpack_dequant_body(
    bytes: &[u8],
    bits: u8,
    len: usize,
    rep: &[f32],
    c: usize,
    dst: *mut f32,
) -> usize {
    let plan = plan4(bits);
    let mask = (1u32 << bits) - 1;
    let sign = 1u32 << (bits - 1);
    let mut e = 0usize;
    let mut pbase = 0usize;
    let mut ph = 0usize;
    'periods: loop {
        for g in &plan.groups {
            if e + 4 > len || pbase + g.span > bytes.len() {
                break 'periods;
            }
            let v = extract4(bytes, pbase, g, mask, sign);
            let f = vcvtq_f32_s32(v);
            let sc = vld1q_f32(rep.as_ptr().add(ph));
            vst1q_f32(dst.add(e), vmulq_f32(f, sc));
            e += 4;
            ph += 4;
            if ph >= c {
                ph %= c;
            }
        }
        pbase += plan.period_bytes;
    }
    e
}

#[allow(clippy::too_many_arguments)]
unsafe fn recompose_dequant_body(
    hb: &[u8],
    h_bits: u8,
    lb: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    rep: &[f32],
    c: usize,
    dst: *mut f32,
) -> usize {
    let hp = plan4(h_bits);
    let lp = plan4(low_bits);
    let (hmask, hsign) = ((1u32 << h_bits) - 1, 1u32 << (h_bits - 1));
    let (lmask, lsign) = ((1u32 << low_bits) - 1, 1u32 << (low_bits - 1));
    let shl = vdupq_n_s32(l as i32);
    let (mut e, mut ph) = (0usize, 0usize);
    let (mut hgi, mut hbase) = (0usize, 0usize);
    let (mut lgi, mut lbase) = (0usize, 0usize);
    loop {
        if e + 4 > len {
            break;
        }
        let gh = &hp.groups[hgi];
        let gl = &lp.groups[lgi];
        if hbase + gh.span > hb.len() || lbase + gl.span > lb.len() {
            break;
        }
        let vh = extract4(hb, hbase, gh, hmask, hsign);
        let vl = extract4(lb, lbase, gl, lmask, lsign);
        let v = vaddq_s32(vshlq_s32(vh, shl), vl);
        let f = vcvtq_f32_s32(v);
        let sc = vld1q_f32(rep.as_ptr().add(ph));
        vst1q_f32(dst.add(e), vmulq_f32(f, sc));
        e += 4;
        hgi += 1;
        if hgi == hp.groups.len() {
            hgi = 0;
            hbase += hp.period_bytes;
        }
        lgi += 1;
        if lgi == lp.groups.len() {
            lgi = 0;
            lbase += lp.period_bytes;
        }
        ph += 4;
        if ph >= c {
            ph %= c;
        }
    }
    e
}

unsafe fn unpack_ints_body(bytes: &[u8], bits: u8, len: usize, dst: *mut i32) -> usize {
    let plan = plan4(bits);
    let mask = (1u32 << bits) - 1;
    let sign = 1u32 << (bits - 1);
    let mut e = 0usize;
    let mut pbase = 0usize;
    'periods: loop {
        for g in &plan.groups {
            if e + 4 > len || pbase + g.span > bytes.len() {
                break 'periods;
            }
            let v = extract4(bytes, pbase, g, mask, sign);
            vst1q_s32(dst.add(e), v);
            e += 4;
        }
        pbase += plan.period_bytes;
    }
    e
}

/// Integer-domain GEMV body: extract 4 fields per group and
/// multiply-accumulate into `acc` (`vmulq_s32` + `vaddq_s32`, wrapping
/// like every tier). A group wholly inside one weight row is a vector
/// MAC (broadcast activation, load/add/store of `acc[ch..ch+4]` — in
/// bounds because `ch + 4 <= classes` was just checked); a group that
/// straddles a row boundary extracts through the same plan windows and
/// accumulates scalarly. Returns elements consumed (a multiple of 4).
unsafe fn gemm_i32_body(bytes: &[u8], bits: u8, x: &[i32], classes: usize, acc: &mut [i32]) -> usize {
    let len = x.len() * classes;
    let plan = plan4(bits);
    let mask = (1u32 << bits) - 1;
    let sign = 1u32 << (bits - 1);
    let mut buf = [0i32; plan::MAX_GROUP];
    let mut e = 0usize;
    let mut pbase = 0usize;
    let (mut r, mut ch) = (0usize, 0usize);
    'periods: loop {
        for g in &plan.groups {
            if e + 4 > len || pbase + g.span > bytes.len() {
                break 'periods;
            }
            if ch + 4 <= classes {
                // all 4 fields live in row r: vector MAC
                let v = extract4(bytes, pbase, g, mask, sign);
                let prod = vmulq_s32(v, vdupq_n_s32(x[r]));
                let p = acc.as_mut_ptr().add(ch);
                vst1q_s32(p, vaddq_s32(vld1q_s32(p), prod));
                ch += 4;
                if ch == classes {
                    ch = 0;
                    r += 1;
                }
            } else {
                // the activation changes mid-group: same plan windows,
                // scalar MAC across the row boundary
                plan::extract_group(bytes, pbase, g, 4, mask, sign, &mut buf);
                for &v in &buf[..4] {
                    acc[ch] = acc[ch].wrapping_add(x[r].wrapping_mul(v));
                    ch += 1;
                    if ch == classes {
                        ch = 0;
                        r += 1;
                    }
                }
            }
            e += 4;
        }
        pbase += plan.period_bytes;
    }
    e
}

// ---------------------------------------------------------------------------
// safe tier entries (fn-pointer targets for the KernelPlan vtable)
// ---------------------------------------------------------------------------

pub(crate) fn unpack_dequant(
    words: &[u8],
    bits: u8,
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    let rep = fold_rep(scales, scale_mul, 4);
    unsafe {
        let d = unpack_dequant_body(words, bits, len, &rep, scales.len(), out.as_mut_ptr());
        out.set_len(d);
    }
    scalar::unpack_dequant_tail(words, bits, len, scales, scale_mul, out);
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn recompose_dequant(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    let rep = fold_rep(scales, 1.0, 4);
    unsafe {
        let d = recompose_dequant_body(
            high_words,
            h_bits,
            low_words,
            low_bits,
            l,
            len,
            &rep,
            scales.len(),
            out.as_mut_ptr(),
        );
        out.set_len(d);
    }
    scalar::recompose_dequant_tail(high_words, h_bits, low_words, low_bits, l, len, scales, out);
}

pub(crate) fn unpack_ints(words: &[u8], bits: u8, len: usize, out: &mut Vec<i32>) {
    unsafe {
        let d = unpack_ints_body(words, bits, len, out.as_mut_ptr());
        out.set_len(d);
    }
    scalar::unpack_ints_tail(words, bits, len, out);
}

pub(crate) fn gemm_i32(words: &[u8], bits: u8, x: &[i32], classes: usize, acc: &mut [i32]) {
    let done = unsafe { gemm_i32_body(words, bits, x, classes, acc) };
    super::gemm::gemm_tail(words, bits, x, classes, done, acc);
}
