//! Per-bitwidth lane plans: the precomputed window/shift tables the
//! vector tiers consume.
//!
//! The packed stream is u64 words, `lanes = 64 / bits` fields per word,
//! fields never straddling a word (the top `64 % bits` bits of each
//! word are padding). A vector path wants, for a *group* of `G`
//! consecutive elements, where to load and how far to shift — and that
//! recipe is periodic: after `lcm(lanes, G)` elements the byte/bit
//! phase repeats exactly one word-multiple later. So each bitwidth gets
//! one [`LanePlan`]: `period_elems / G` [`Group`]s, each holding
//!
//! * `off[k]`  — byte offset (relative to the period base) of the
//!   4-byte little-endian *window* containing element `k`'s field,
//! * `shift[k]` — the field's bit offset inside that window (0..=7, so
//!   `shift + bits <= 23 < 32` for every legal width: any field is
//!   extractable from one unaligned u32 load),
//! * a *broadcast* alternative for narrow widths: when all `G` fields
//!   fit in one u32 window (`fits32`, true for bits <= 4 with G = 8),
//!   one load at `base` plus per-lane shifts `bshift[k]` replaces the
//!   per-lane windows — one load instead of a gather.
//!
//! `span` bounds every load the group performs (`off[G-1] + 4`, and the
//! contiguous 8/16-byte loads of the byte/word-aligned fast paths are
//! within it); drivers check `period_base + span <= bytes.len()` before
//! touching a group and leave the remainder to the scalar tail, so no
//! vector load ever reads past the slice.
//!
//! Everything here is pure safe Rust. [`decode_via_windows`] is the
//! reference consumer: the exact extraction the SIMD tiers perform,
//! expressed scalarly — the SSE2 tier uses it for field extraction, and
//! the unit tests prove plan-driven extraction ≡ the lane-cursor decode
//! for every width and phase, which is what makes the `unsafe` SIMD
//! bodies small enough to audit (they change *how* the same windows are
//! loaded, not *which*).

use std::sync::OnceLock;

use crate::bits::lanes;

/// Widest group any tier asks for (AVX2 decodes 8 lanes per iteration).
pub(crate) const MAX_GROUP: usize = 8;

/// Extraction recipe for one group of `group_len` consecutive elements.
#[derive(Debug, Clone)]
pub(crate) struct Group {
    /// Per-element window byte offset, relative to the period base.
    /// Monotonic non-decreasing; entries past the plan's group size are
    /// zero and unused.
    pub off: [i32; MAX_GROUP],
    /// Right-shift inside the loaded u32 window (0..=7).
    pub shift: [i32; MAX_GROUP],
    /// Broadcast form: shifts relative to one window at `base`.
    pub bshift: [i32; MAX_GROUP],
    /// Window byte offset of the broadcast form (== `off[0]`).
    pub base: i32,
    /// True when every field of the group fits in the one u32 window at
    /// `base` (`bshift[k] + bits <= 32` for all lanes).
    pub fits32: bool,
    /// Upper bound (relative to the period base) on every byte this
    /// group reads: `off[last] + 4`.
    pub span: usize,
}

/// One bitwidth's periodic extraction table for a fixed group size.
#[derive(Debug, Clone)]
pub(crate) struct LanePlan {
    pub bits: u8,
    /// Elements per group (8 for AVX2, 4 for SSE2/NEON).
    pub group: usize,
    /// Elements after which the byte phase repeats (`lcm(lanes, group)`).
    pub period_elems: usize,
    /// Bytes per period (`period_elems / lanes * 8`).
    pub period_bytes: usize,
    /// `period_elems / group` groups covering one period.
    pub groups: Vec<Group>,
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Build the plan for one `(bits, group)` pair. Pure arithmetic from the
/// packed layout contract (element `e` lives in word `e / lanes` at bit
/// `(e % lanes) * bits`).
#[allow(clippy::needless_range_loop)] // parallel fixed-size arrays, k is the lane id
pub(crate) fn build_plan(bits: u8, group: usize) -> LanePlan {
    assert!(group <= MAX_GROUP);
    let n_lanes = lanes(bits);
    let period_elems = n_lanes * group / gcd(n_lanes, group);
    let period_bytes = period_elems / n_lanes * 8;
    let mut groups = Vec::with_capacity(period_elems / group);
    for g0 in (0..period_elems).step_by(group) {
        let mut g = Group {
            off: [0; MAX_GROUP],
            shift: [0; MAX_GROUP],
            bshift: [0; MAX_GROUP],
            base: 0,
            fits32: true,
            span: 0,
        };
        for k in 0..group {
            let e = g0 + k;
            let bit = (e % n_lanes) * bits as usize;
            g.off[k] = ((e / n_lanes) * 8 + bit / 8) as i32;
            g.shift[k] = (bit % 8) as i32;
        }
        g.base = g.off[0];
        for k in 0..group {
            g.bshift[k] = (g.off[k] - g.base) * 8 + g.shift[k];
            if g.bshift[k] + bits as i32 > 32 {
                g.fits32 = false;
            }
        }
        g.span = g.off[group - 1] as usize + 4;
        groups.push(g);
    }
    LanePlan {
        bits,
        group,
        period_elems,
        period_bytes,
        groups,
    }
}

fn plans(cell: &'static OnceLock<Vec<LanePlan>>, group: usize, bits: u8) -> &'static LanePlan {
    let all = cell.get_or_init(|| (2..=16).map(|b| build_plan(b, group)).collect());
    &all[bits as usize - 2]
}

/// The 8-lane plan for `bits` (built once per process).
pub(crate) fn plan8(bits: u8) -> &'static LanePlan {
    static PLANS8: OnceLock<Vec<LanePlan>> = OnceLock::new();
    plans(&PLANS8, 8, bits)
}

/// The 4-lane plan for `bits` (built once per process).
pub(crate) fn plan4(bits: u8) -> &'static LanePlan {
    static PLANS4: OnceLock<Vec<LanePlan>> = OnceLock::new();
    plans(&PLANS4, 4, bits)
}

/// Extract one sign-extended field through its window: the scalar
/// spelling of exactly what the SIMD lanes do (u32 load, shift, mask,
/// xor-sub sign extension). Safe — slice indexing; callers stay in
/// bounds via the group `span` check.
#[inline(always)]
pub(crate) fn extract_window(bytes: &[u8], off: usize, shift: u32, mask: u32, sign: u32) -> i32 {
    let w = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
    let f = (w >> shift) & mask;
    ((f ^ sign) as i32).wrapping_sub(sign as i32)
}

/// Decode one group's fields into `dst[..group]` via the plan windows —
/// the reference extraction shared by the SSE2 tier and the plan tests.
#[inline(always)]
#[allow(clippy::needless_range_loop, clippy::too_many_arguments)]
pub(crate) fn extract_group(
    bytes: &[u8],
    base: usize,
    g: &Group,
    group: usize,
    mask: u32,
    sign: u32,
    dst: &mut [i32],
) {
    for k in 0..group {
        dst[k] = extract_window(
            bytes,
            base + g.off[k] as usize,
            g.shift[k] as u32,
            mask,
            sign,
        );
    }
}

/// Plan-driven whole-stream decode (pure safe Rust): walks periods and
/// groups exactly like the SIMD drivers — including the `span` bounds
/// check and the "stop and leave the rest to the tail" behavior — and
/// returns how many elements it produced (always a multiple of the
/// group size, `<= len`). The unit tests pin this against the
/// lane-cursor decode; the SIMD bodies only vectorize its inner loop.
pub(crate) fn decode_via_windows(
    bytes: &[u8],
    plan: &LanePlan,
    len: usize,
    out: &mut Vec<i32>,
) -> usize {
    let mask = (1u32 << plan.bits) - 1;
    let sign = 1u32 << (plan.bits - 1);
    let mut buf = [0i32; MAX_GROUP];
    let mut e = 0usize;
    let mut pbase = 0usize;
    'periods: loop {
        for g in &plan.groups {
            if e + plan.group > len || pbase + g.span > bytes.len() {
                break 'periods;
            }
            extract_group(bytes, pbase, g, plan.group, mask, sign, &mut buf);
            out.extend_from_slice(&buf[..plan.group]);
            e += plan.group;
        }
        pbase += plan.period_bytes;
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::{int_range, PackedTensor};

    /// Every width × both group sizes: plan-driven window extraction
    /// equals the packed-tensor decode on every element it covers, for
    /// lengths straddling word and period boundaries.
    #[test]
    fn window_decode_matches_lane_cursor_all_widths() {
        for bits in 2..=16u8 {
            let (lo, hi) = int_range(bits);
            for plan in [plan8(bits), plan4(bits)] {
                for len in [
                    0,
                    1,
                    plan.group - 1,
                    plan.group,
                    lanes(bits),
                    lanes(bits) + 1,
                    plan.period_elems - 1,
                    plan.period_elems,
                    3 * plan.period_elems + plan.group + 1,
                ] {
                    let vals: Vec<i32> = (0..len as i32)
                        .map(|i| lo + (i * 29) % (hi - lo + 1))
                        .collect();
                    let t = PackedTensor::pack(&vals, bits).unwrap();
                    let bytes = t.to_le_bytes();
                    let mut got = Vec::new();
                    let done = decode_via_windows(&bytes, plan, len, &mut got);
                    assert!(done <= len && done % plan.group == 0);
                    assert_eq!(got.len(), done);
                    assert_eq!(&got[..], &vals[..done], "bits={bits} g={} len={len}", plan.group);
                }
            }
        }
    }

    /// Structural invariants the unsafe drivers rely on: shifts fit a
    /// u32 window, offsets are monotonic, spans bound every load, and
    /// the broadcast form is available exactly when it is sound.
    #[test]
    fn plan_invariants() {
        for bits in 2..=16u8 {
            for plan in [plan8(bits), plan4(bits)] {
                assert_eq!(plan.period_elems % plan.group, 0);
                assert_eq!(plan.period_elems % lanes(bits), 0);
                assert_eq!(plan.period_bytes, plan.period_elems / lanes(bits) * 8);
                for g in &plan.groups {
                    for k in 0..plan.group {
                        assert!((0..8).contains(&g.shift[k]), "bits={bits}");
                        assert!(g.shift[k] + (bits as i32) <= 23, "window fits u32");
                        assert!(g.off[k] + 4 <= g.span as i32);
                        if k > 0 {
                            assert!(g.off[k] >= g.off[k - 1], "monotonic windows");
                        }
                        if g.fits32 {
                            assert!(g.bshift[k] + (bits as i32) <= 32);
                            assert!(g.base + 4 <= g.span as i32);
                        }
                    }
                }
                // every width <= 4 gets the broadcast form on all groups
                if bits <= 4 && plan.group == 8 {
                    assert!(plan.groups.iter().all(|g| g.fits32), "bits={bits}");
                }
            }
        }
    }
}
