//! Scalar tier: the portable lane-cursor decode — one u64 load per
//! `lanes(bits)` values, shift-and-mask per lane. This is the reference
//! semantics every other tier must match bit-for-bit, the fallback on
//! architectures without an explicit vector path, and (via the `*_tail`
//! entry points, which can start mid-stream) the tail handler the SIMD
//! drivers use for the elements their bounds checks leave behind.

use crate::bits::{lanes, sext};

use super::word_at;

/// Streaming lane decoder over packed LE words: the state the scalar
/// paths carry instead of materializing word or i32 vectors.
pub(crate) struct LaneCursor<'a> {
    bytes: &'a [u8],
    /// Next word index to load.
    next_word: usize,
    word: u64,
    /// Lanes left in the loaded word.
    left: usize,
    bits: u32,
    lanes: usize,
    mask: u64,
    sign: u64,
}

impl<'a> LaneCursor<'a> {
    pub(crate) fn new(bytes: &'a [u8], bits: u8) -> LaneCursor<'a> {
        LaneCursor {
            bytes,
            next_word: 0,
            word: 0,
            left: 0,
            bits: bits as u32,
            lanes: lanes(bits),
            mask: (1u64 << bits) - 1,
            sign: 1u64 << (bits - 1),
        }
    }

    /// Cursor positioned at element `start` (the SIMD tail entry: the
    /// vector body stopped at a group boundary, the cursor picks up
    /// mid-word from there).
    pub(crate) fn new_at(bytes: &'a [u8], bits: u8, start: usize) -> LaneCursor<'a> {
        let mut c = LaneCursor::new(bytes, bits);
        let lane = start % c.lanes;
        let word_idx = start / c.lanes;
        if lane > 0 {
            c.word = word_at(bytes, word_idx) >> (lane as u32 * c.bits);
            c.left = c.lanes - lane;
            c.next_word = word_idx + 1;
        } else {
            c.next_word = word_idx;
        }
        c
    }

    #[inline(always)]
    pub(crate) fn next(&mut self) -> i32 {
        if self.left == 0 {
            self.word = word_at(self.bytes, self.next_word);
            self.next_word += 1;
            self.left = self.lanes;
        }
        let v = sext(self.word & self.mask, self.sign);
        self.word >>= self.bits;
        self.left -= 1;
        v
    }
}

/// Scalar part-bit launch: cursor + channel-sized row chunks (the
/// channel index is the position in the chunk — no per-element modulo).
pub(crate) fn unpack_dequant(
    words: &[u8],
    bits: u8,
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    let mut cur = LaneCursor::new(words, bits);
    let c = scales.len();
    let mut done = 0;
    while done < len {
        let take = c.min(len - done);
        for &s in &scales[..take] {
            out.push(cur.next() as f32 * (s * scale_mul));
        }
        done += take;
    }
}

/// Scalar full-bit upgrade: two cursors, fused recompose + dequant.
#[allow(clippy::too_many_arguments)]
pub(crate) fn recompose_dequant(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    let mut hc = LaneCursor::new(high_words, h_bits);
    let mut lc = LaneCursor::new(low_words, low_bits);
    let shift = l as u32;
    let c = scales.len();
    let mut done = 0;
    while done < len {
        let take = c.min(len - done);
        for &s in &scales[..take] {
            let v = (hc.next() << shift) + lc.next();
            out.push(v as f32 * s);
        }
        done += take;
    }
}

/// Scalar i32 unpack (the non-dequantizing entry).
pub(crate) fn unpack_ints(words: &[u8], bits: u8, len: usize, out: &mut Vec<i32>) {
    let mut cur = LaneCursor::new(words, bits);
    for _ in 0..len {
        out.push(cur.next());
    }
}

// ---------------------------------------------------------------------------
// mid-stream tails for the SIMD drivers
// ---------------------------------------------------------------------------

/// Finish a launch decode from `out.len()` to `len` (channel phase and
/// cursor position derived from the resume element).
pub(crate) fn unpack_dequant_tail(
    words: &[u8],
    bits: u8,
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    let start = out.len();
    if start >= len {
        return;
    }
    let mut cur = LaneCursor::new_at(words, bits, start);
    let c = scales.len();
    let mut ch = start % c;
    for _ in start..len {
        out.push(cur.next() as f32 * (scales[ch] * scale_mul));
        ch += 1;
        if ch == c {
            ch = 0;
        }
    }
}

/// Finish an upgrade decode from `out.len()` to `len`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn recompose_dequant_tail(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    let start = out.len();
    if start >= len {
        return;
    }
    let mut hc = LaneCursor::new_at(high_words, h_bits, start);
    let mut lc = LaneCursor::new_at(low_words, low_bits, start);
    let shift = l as u32;
    let c = scales.len();
    let mut ch = start % c;
    for _ in start..len {
        let v = (hc.next() << shift) + lc.next();
        out.push(v as f32 * scales[ch]);
        ch += 1;
        if ch == c {
            ch = 0;
        }
    }
}

/// Finish an i32 unpack from `out.len()` to `len`.
pub(crate) fn unpack_ints_tail(words: &[u8], bits: u8, len: usize, out: &mut Vec<i32>) {
    let start = out.len();
    if start >= len {
        return;
    }
    let mut cur = LaneCursor::new_at(words, bits, start);
    for _ in start..len {
        out.push(cur.next());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bits::{int_range, PackedTensor};

    /// `new_at(k)` ≡ skipping k values of a fresh cursor, for every
    /// width and every in-word phase.
    #[test]
    fn cursor_resume_equals_skip() {
        for bits in 2..=16u8 {
            let (lo, hi) = int_range(bits);
            let len = 3 * lanes(bits) + 2;
            let vals: Vec<i32> = (0..len as i32)
                .map(|i| lo + (i * 17) % (hi - lo + 1))
                .collect();
            let bytes = PackedTensor::pack(&vals, bits).unwrap().to_le_bytes();
            for start in 0..len {
                let mut cur = LaneCursor::new_at(&bytes, bits, start);
                let got: Vec<i32> = (start..len).map(|_| cur.next()).collect();
                assert_eq!(got, &vals[start..], "bits={bits} start={start}");
            }
        }
    }
}
