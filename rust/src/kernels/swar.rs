//! SWAR tier: word-parallel decode in general-purpose registers for
//! lane-aligned bitwidths (`bits ∣ 64`, i.e. 2/4/8/16) — constant-trip
//! unrolled mask/shift loops the compiler vectorizes, xor-sub sign
//! extension, hoisted per-channel scale tables, and a paired-stream
//! block decode when both upgrade streams are aligned. Widths that
//! don't divide 64 fall through to the scalar lane cursor, which is
//! exactly what the SIMD tier exists to fix.

use crate::bits::{lanes, sext};

use super::{scalar, swar_aligned, word_at, MAX_LANES};

/// SWAR-tier part-bit launch: aligned widths take the word-parallel
/// path, everything else the scalar cursor.
pub(crate) fn unpack_dequant(
    words: &[u8],
    bits: u8,
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    match bits {
        2 => unpack_dequant_swar::<2>(words, len, scales, scale_mul, out),
        4 => unpack_dequant_swar::<4>(words, len, scales, scale_mul, out),
        8 => unpack_dequant_swar::<8>(words, len, scales, scale_mul, out),
        16 => unpack_dequant_swar::<16>(words, len, scales, scale_mul, out),
        _ => scalar::unpack_dequant(words, bits, len, scales, scale_mul, out),
    }
}

/// SWAR path (`BITS ∣ 64`): constant-trip unrolled mask/shift over whole
/// words; per-channel scales hoisted into a per-word table when the
/// channel count divides the lane count.
fn unpack_dequant_swar<const BITS: u32>(
    words: &[u8],
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    let n_lanes = (64 / BITS) as usize;
    let mask = (1u64 << BITS) - 1;
    let sign = 1u64 << (BITS - 1);
    let c = scales.len();
    let full = len / n_lanes;
    let rem = len - full * n_lanes;
    if c <= n_lanes && n_lanes % c == 0 {
        // channel phase repeats exactly per word: hoist scales (with the
        // inflation folded in) into one table, indexed by lane
        let mut tbl = [0f32; MAX_LANES];
        for (i, t) in tbl.iter_mut().take(n_lanes).enumerate() {
            *t = scales[i % c] * scale_mul;
        }
        for w in 0..full {
            let mut word = word_at(words, w);
            for &t in tbl.iter().take(n_lanes) {
                out.push(sext(word & mask, sign) as f32 * t);
                word >>= BITS;
            }
        }
        if rem > 0 {
            let mut word = word_at(words, full);
            for &t in tbl.iter().take(rem) {
                out.push(sext(word & mask, sign) as f32 * t);
                word >>= BITS;
            }
        }
    } else {
        // general channel stride: running channel cursor, still one
        // word load per `n_lanes` outputs
        let mut ch = 0usize;
        for w in 0..full {
            let mut word = word_at(words, w);
            for _ in 0..n_lanes {
                out.push(sext(word & mask, sign) as f32 * (scales[ch] * scale_mul));
                word >>= BITS;
                ch += 1;
                if ch == c {
                    ch = 0;
                }
            }
        }
        if rem > 0 {
            let mut word = word_at(words, full);
            for _ in 0..rem {
                out.push(sext(word & mask, sign) as f32 * (scales[ch] * scale_mul));
                word >>= BITS;
                ch += 1;
                if ch == c {
                    ch = 0;
                }
            }
        }
    }
}

/// SWAR-tier full-bit upgrade: the paired path when both streams are
/// aligned, scalar cursors otherwise.
#[allow(clippy::too_many_arguments)]
pub(crate) fn recompose_dequant(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    if swar_aligned(h_bits) && swar_aligned(low_bits) {
        recompose_dequant_swar(high_words, h_bits, low_words, low_bits, l, len, scales, out);
    } else {
        scalar::recompose_dequant(high_words, h_bits, low_words, low_bits, l, len, scales, out);
    }
}

/// Decode `n_words` whole words starting at word `first` into `dst`
/// (`dst.len() == n_words · lanes`), SWAR-unrolled per word.
fn decode_words_swar_inner<const BITS: u32>(
    bytes: &[u8],
    first: usize,
    n_words: usize,
    dst: &mut [i32],
) {
    let n_lanes = (64 / BITS) as usize;
    let mask = (1u64 << BITS) - 1;
    let sign = 1u64 << (BITS - 1);
    debug_assert_eq!(dst.len(), n_words * n_lanes);
    for (w, chunk) in dst.chunks_exact_mut(n_lanes).enumerate() {
        let mut word = word_at(bytes, first + w);
        for d in chunk {
            *d = sext(word & mask, sign);
            word >>= BITS;
        }
    }
}

pub(crate) fn decode_words_swar(
    bytes: &[u8],
    bits: u8,
    first: usize,
    n_words: usize,
    dst: &mut [i32],
) {
    match bits {
        2 => decode_words_swar_inner::<2>(bytes, first, n_words, dst),
        4 => decode_words_swar_inner::<4>(bytes, first, n_words, dst),
        8 => decode_words_swar_inner::<8>(bytes, first, n_words, dst),
        16 => decode_words_swar_inner::<16>(bytes, first, n_words, dst),
        _ => unreachable!("decode_words_swar on non-aligned bits {bits}"),
    }
}

/// SWAR pair path: both bitwidths divide 64, so their lane counts are
/// powers of two and the smaller divides the larger — a block of
/// `max(h_lanes, low_lanes)` elements is whole words of *both* streams.
/// Each block decodes into two stack buffers (≤ 32 lanes, registers/L1)
/// and combines straight into the output f32s.
#[allow(clippy::too_many_arguments)]
fn recompose_dequant_swar(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    let h_lanes = lanes(h_bits);
    let l_lanes = lanes(low_bits);
    let block = h_lanes.max(l_lanes);
    let shift = l as u32;
    let c = scales.len();
    let mut hbuf = [0i32; MAX_LANES];
    let mut lbuf = [0i32; MAX_LANES];
    let hoist = c <= block && block % c == 0;
    let mut tbl = [0f32; MAX_LANES];
    if hoist {
        // block boundaries land on channel boundaries: one scale table
        for (i, t) in tbl.iter_mut().take(block).enumerate() {
            *t = scales[i % c];
        }
    }
    let (mut done, mut hw, mut lw, mut ch) = (0usize, 0usize, 0usize, 0usize);
    while done < len {
        let take = block.min(len - done);
        let need_hw = take.div_ceil(h_lanes);
        let need_lw = take.div_ceil(l_lanes);
        decode_words_swar(high_words, h_bits, hw, need_hw, &mut hbuf[..need_hw * h_lanes]);
        decode_words_swar(low_words, low_bits, lw, need_lw, &mut lbuf[..need_lw * l_lanes]);
        hw += need_hw;
        lw += need_lw;
        if hoist {
            for ((&h, &lo), &t) in hbuf[..take].iter().zip(&lbuf[..take]).zip(&tbl[..take]) {
                out.push(((h << shift) + lo) as f32 * t);
            }
        } else {
            for (&h, &lo) in hbuf[..take].iter().zip(&lbuf[..take]) {
                out.push(((h << shift) + lo) as f32 * scales[ch]);
                ch += 1;
                if ch == c {
                    ch = 0;
                }
            }
        }
        done += take;
    }
}

/// SWAR-tier i32 unpack (aligned widths word-parallel, scalar cursor
/// otherwise) — the byte-slice successor of `bits::unpack_words_into`'s
/// word-stream dispatch.
pub(crate) fn unpack_ints(words: &[u8], bits: u8, len: usize, out: &mut Vec<i32>) {
    if !swar_aligned(bits) {
        scalar::unpack_ints(words, bits, len, out);
        return;
    }
    let full = len / lanes(bits);
    out.resize(full * lanes(bits), 0);
    decode_words_swar(words, bits, 0, full, &mut out[..]);
    scalar::unpack_ints_tail(words, bits, len, out);
}
