//! x86-64 SIMD tier: explicit `std::arch` decode paths.
//!
//! Two sub-paths, selected once per process from the capability probe
//! ([`caps`], a `OnceLock` — executor threads never re-detect in the
//! decode loop):
//!
//! * **AVX2** — 8 elements per iteration. Field extraction per group is
//!   the cheapest form the lane plan allows: `vpmovsxbd`/`vpmovsxwd`
//!   contiguous loads for 8/16-bit streams, one u32 broadcast +
//!   `vpsrlvd` for widths ≤ 4 (all eight fields share one window), and
//!   a byte-offset `vpgatherdd` + `vpsrlvd` for everything else —
//!   which is how the previously-scalar widths (3, 5, 6, 7, 9..15) get
//!   a vector path. Sign extension is the same xor-sub idiom as the
//!   SWAR tier, convert + scale-multiply ride in the same registers.
//! * **SSE2 baseline** (x86-64 guarantees SSE2) — 4 elements per
//!   iteration. Pre-AVX2 x86 has no per-lane variable shifts, so field
//!   extraction uses the plan's scalar windows ([`plan::extract_group`])
//!   and only the convert + multiply half is vectorized
//!   (`cvtdq2ps`/`mulps`). A real win over the lane cursor on the f32
//!   half; the honest tier table lives in DESIGN.md §4e.
//!
//! # Safety
//!
//! All `unsafe` here is (a) `std::arch` intrinsics behind the matching
//! `#[target_feature]` (AVX2 fns are only reachable through the runtime
//! probe), (b) raw stores into the output vector's reserved-but-unset
//! capacity (the callers in `kernels::mod` reserve `len` up front and
//! `set_len` to exactly the element count the body reports), and (c)
//! unaligned loads whose every byte is bounds-checked *before* the
//! group runs: each group's `span` is an upper bound on every offset it
//! reads (gather offsets, broadcast window, and the contiguous movsx
//! loads are all ≤ `span` by construction, see `plan.rs`), and the
//! driver breaks to the scalar tail as soon as
//! `period_base + span > bytes.len()` — and, because the gather's
//! offsets are i32 lanes, as soon as the offsets would pass
//! `i32::MAX` (a ≥2 GiB stream finishes scalarly instead of wrapping
//! an offset negative). No vector load ever touches a byte outside the
//! input slice, and every output element is written exactly once
//! before `set_len` exposes it.

#![allow(unsafe_op_in_unsafe_fn)]

use std::arch::x86_64::*;
use std::sync::OnceLock;

use super::plan::{self, plan4, plan8, Group, LanePlan};
use super::{fold_rep, scalar};

/// Capabilities probed once per process (the `OnceLock` hoist: tenant
/// executor threads and decode waves share this single probe).
pub(crate) struct Caps {
    pub avx2: bool,
}

pub(crate) fn caps() -> &'static Caps {
    static CAPS: OnceLock<Caps> = OnceLock::new();
    CAPS.get_or_init(|| Caps {
        avx2: is_x86_feature_detected!("avx2"),
    })
}

/// Human-readable sub-path name for diagnostics and the bench artifact.
pub(crate) fn path_name() -> &'static str {
    if caps().avx2 {
        "avx2"
    } else {
        "sse2"
    }
}

// ---------------------------------------------------------------------------
// AVX2 sub-path
// ---------------------------------------------------------------------------

/// Extract 8 sign-extended fields of one group as packed i32 lanes.
///
/// Safety: caller has verified `base + g.span <= bytes.len()` and runs
/// under the AVX2 target feature.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn extract8(
    bytes: &[u8],
    base: usize,
    g: &Group,
    bits: u8,
    mask: __m256i,
    sign: __m256i,
) -> __m256i {
    match bits {
        8 => {
            // 8 contiguous bytes are the 8 fields: vpmovsxbd
            let p = bytes.as_ptr().add(base + g.off[0] as usize);
            _mm256_cvtepi8_epi32(_mm_loadl_epi64(p as *const __m128i))
        }
        16 => {
            // 16 contiguous bytes: vpmovsxwd
            let p = bytes.as_ptr().add(base + g.off[0] as usize);
            _mm256_cvtepi16_epi32(_mm_loadu_si128(p as *const __m128i))
        }
        _ if g.fits32 => {
            // all 8 fields inside one u32 window: broadcast + vpsrlvd
            let p = bytes.as_ptr().add(base + g.base as usize);
            let w = _mm256_set1_epi32((p as *const u32).read_unaligned() as i32);
            let sh = _mm256_loadu_si256(g.bshift.as_ptr() as *const __m256i);
            let f = _mm256_and_si256(_mm256_srlv_epi32(w, sh), mask);
            _mm256_sub_epi32(_mm256_xor_si256(f, sign), sign)
        }
        _ => {
            // general width: per-lane byte-offset gather + vpsrlvd
            let offs = _mm256_add_epi32(
                _mm256_loadu_si256(g.off.as_ptr() as *const __m256i),
                _mm256_set1_epi32(base as i32),
            );
            let w = _mm256_i32gather_epi32::<1>(bytes.as_ptr() as *const i32, offs);
            let sh = _mm256_loadu_si256(g.shift.as_ptr() as *const __m256i);
            let f = _mm256_and_si256(_mm256_srlv_epi32(w, sh), mask);
            _mm256_sub_epi32(_mm256_xor_si256(f, sign), sign)
        }
    }
}

#[inline(always)]
fn mask_sign(bits: u8) -> (i32, i32) {
    (((1u32 << bits) - 1) as i32, (1u32 << (bits - 1)) as i32)
}

/// AVX2 launch body: decode groups until the bounds check or the length
/// stops us; returns elements produced (a multiple of 8).
#[target_feature(enable = "avx2")]
unsafe fn unpack_dequant_avx2_body(
    bytes: &[u8],
    bits: u8,
    len: usize,
    rep: &[f32],
    c: usize,
    dst: *mut f32,
) -> usize {
    let plan = plan8(bits);
    let (m, s) = mask_sign(bits);
    let mask = _mm256_set1_epi32(m);
    let sign = _mm256_set1_epi32(s);
    // every byte offset a group touches must also fit the gather's i32
    // lanes — past 2 GiB the scalar tail takes over instead of wrapping
    let limit = bytes.len().min(i32::MAX as usize);
    let mut e = 0usize;
    let mut pbase = 0usize;
    let mut ph = 0usize;
    'periods: loop {
        for g in &plan.groups {
            if e + 8 > len || pbase + g.span > limit {
                break 'periods;
            }
            let v = extract8(bytes, pbase, g, bits, mask, sign);
            let f = _mm256_cvtepi32_ps(v);
            let sc = _mm256_loadu_ps(rep.as_ptr().add(ph));
            _mm256_storeu_ps(dst.add(e), _mm256_mul_ps(f, sc));
            e += 8;
            ph += 8;
            if ph >= c {
                ph %= c;
            }
        }
        pbase += plan.period_bytes;
    }
    e
}

/// AVX2 upgrade body: both streams walk their own plans group-by-group
/// (group boundaries coincide — every period is a multiple of 8).
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn recompose_dequant_avx2_body(
    hb: &[u8],
    h_bits: u8,
    lb: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    rep: &[f32],
    c: usize,
    dst: *mut f32,
) -> usize {
    let hp: &LanePlan = plan8(h_bits);
    let lp: &LanePlan = plan8(low_bits);
    let (hm, hs) = mask_sign(h_bits);
    let (lm, ls) = mask_sign(low_bits);
    let hmask = _mm256_set1_epi32(hm);
    let hsign = _mm256_set1_epi32(hs);
    let lmask = _mm256_set1_epi32(lm);
    let lsign = _mm256_set1_epi32(ls);
    let shl = _mm_cvtsi32_si128(l as i32);
    // gather offsets are i32 lanes: stop vectorizing past 2 GiB
    let hlimit = hb.len().min(i32::MAX as usize);
    let llimit = lb.len().min(i32::MAX as usize);
    let (mut e, mut ph) = (0usize, 0usize);
    let (mut hgi, mut hbase) = (0usize, 0usize);
    let (mut lgi, mut lbase) = (0usize, 0usize);
    loop {
        if e + 8 > len {
            break;
        }
        let gh = &hp.groups[hgi];
        let gl = &lp.groups[lgi];
        if hbase + gh.span > hlimit || lbase + gl.span > llimit {
            break;
        }
        let vh = extract8(hb, hbase, gh, h_bits, hmask, hsign);
        let vl = extract8(lb, lbase, gl, low_bits, lmask, lsign);
        let v = _mm256_add_epi32(_mm256_sll_epi32(vh, shl), vl);
        let f = _mm256_cvtepi32_ps(v);
        let sc = _mm256_loadu_ps(rep.as_ptr().add(ph));
        _mm256_storeu_ps(dst.add(e), _mm256_mul_ps(f, sc));
        e += 8;
        hgi += 1;
        if hgi == hp.groups.len() {
            hgi = 0;
            hbase += hp.period_bytes;
        }
        lgi += 1;
        if lgi == lp.groups.len() {
            lgi = 0;
            lbase += lp.period_bytes;
        }
        ph += 8;
        if ph >= c {
            ph %= c;
        }
    }
    e
}

/// AVX2 i32 unpack body.
#[target_feature(enable = "avx2")]
unsafe fn unpack_ints_avx2_body(bytes: &[u8], bits: u8, len: usize, dst: *mut i32) -> usize {
    let plan = plan8(bits);
    let (m, s) = mask_sign(bits);
    let mask = _mm256_set1_epi32(m);
    let sign = _mm256_set1_epi32(s);
    // gather offsets are i32 lanes: stop vectorizing past 2 GiB
    let limit = bytes.len().min(i32::MAX as usize);
    let mut e = 0usize;
    let mut pbase = 0usize;
    'periods: loop {
        for g in &plan.groups {
            if e + 8 > len || pbase + g.span > limit {
                break 'periods;
            }
            let v = extract8(bytes, pbase, g, bits, mask, sign);
            _mm256_storeu_si256(dst.add(e) as *mut __m256i, v);
            e += 8;
        }
        pbase += plan.period_bytes;
    }
    e
}

/// AVX2 integer-domain GEMV body: extract 8 packed fields per group and
/// multiply-accumulate into `acc` (`vpmulld` + `vpaddd`, wrapping like
/// every other tier). When all 8 fields share one weight row the MAC is
/// fully vectorized (one broadcast activation, unaligned load/add/store
/// of `acc[ch..ch+8]` — in bounds because `ch + 8 <= classes` was just
/// checked); a group that straddles a row boundary extracts through the
/// same plan windows and accumulates scalarly. Returns elements
/// consumed (a multiple of 8); the caller finishes with the stream tail.
#[target_feature(enable = "avx2")]
unsafe fn gemm_i32_avx2_body(
    bytes: &[u8],
    bits: u8,
    x: &[i32],
    classes: usize,
    acc: &mut [i32],
) -> usize {
    let len = x.len() * classes;
    let plan = plan8(bits);
    let (m, s) = mask_sign(bits);
    let mask = _mm256_set1_epi32(m);
    let sign = _mm256_set1_epi32(s);
    let (masku, signu) = ((1u32 << bits) - 1, 1u32 << (bits - 1));
    // gather offsets are i32 lanes: stop vectorizing past 2 GiB
    let limit = bytes.len().min(i32::MAX as usize);
    let mut buf = [0i32; plan::MAX_GROUP];
    let mut e = 0usize;
    let mut pbase = 0usize;
    let (mut r, mut ch) = (0usize, 0usize);
    'periods: loop {
        for g in &plan.groups {
            if e + 8 > len || pbase + g.span > limit {
                break 'periods;
            }
            if ch + 8 <= classes {
                // all 8 fields live in row r: vector MAC
                let v = extract8(bytes, pbase, g, bits, mask, sign);
                let prod = _mm256_mullo_epi32(v, _mm256_set1_epi32(x[r]));
                let p = acc.as_mut_ptr().add(ch);
                let cur = _mm256_loadu_si256(p as *const __m256i);
                _mm256_storeu_si256(p as *mut __m256i, _mm256_add_epi32(cur, prod));
                ch += 8;
                if ch == classes {
                    ch = 0;
                    r += 1;
                }
            } else {
                // the activation changes mid-group: same plan windows,
                // scalar MAC across the row boundary
                plan::extract_group(bytes, pbase, g, 8, masku, signu, &mut buf);
                for &v in &buf[..8] {
                    acc[ch] = acc[ch].wrapping_add(x[r].wrapping_mul(v));
                    ch += 1;
                    if ch == classes {
                        ch = 0;
                        r += 1;
                    }
                }
            }
            e += 8;
        }
        pbase += plan.period_bytes;
    }
    e
}

// ---------------------------------------------------------------------------
// SSE2 sub-path (baseline: no gathers, no per-lane variable shifts)
// ---------------------------------------------------------------------------

/// SSE2 launch body: plan-window extraction (scalar), convert + scale
/// multiply in xmm registers, 4 elements per iteration.
unsafe fn unpack_dequant_sse2_body(
    bytes: &[u8],
    bits: u8,
    len: usize,
    rep: &[f32],
    c: usize,
    dst: *mut f32,
) -> usize {
    let plan = plan4(bits);
    let mask = (1u32 << bits) - 1;
    let sign = 1u32 << (bits - 1);
    let mut buf = [0i32; plan::MAX_GROUP];
    let mut e = 0usize;
    let mut pbase = 0usize;
    let mut ph = 0usize;
    'periods: loop {
        for g in &plan.groups {
            if e + 4 > len || pbase + g.span > bytes.len() {
                break 'periods;
            }
            plan::extract_group(bytes, pbase, g, 4, mask, sign, &mut buf);
            let v = _mm_loadu_si128(buf.as_ptr() as *const __m128i);
            let f = _mm_cvtepi32_ps(v);
            let sc = _mm_loadu_ps(rep.as_ptr().add(ph));
            _mm_storeu_ps(dst.add(e), _mm_mul_ps(f, sc));
            e += 4;
            ph += 4;
            if ph >= c {
                ph %= c;
            }
        }
        pbase += plan.period_bytes;
    }
    e
}

/// SSE2 upgrade body.
#[allow(clippy::too_many_arguments)]
unsafe fn recompose_dequant_sse2_body(
    hb: &[u8],
    h_bits: u8,
    lb: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    rep: &[f32],
    c: usize,
    dst: *mut f32,
) -> usize {
    let hp = plan4(h_bits);
    let lp = plan4(low_bits);
    let (hmask, hsign) = ((1u32 << h_bits) - 1, 1u32 << (h_bits - 1));
    let (lmask, lsign) = ((1u32 << low_bits) - 1, 1u32 << (low_bits - 1));
    let shl = _mm_cvtsi32_si128(l as i32);
    let mut hbuf = [0i32; plan::MAX_GROUP];
    let mut lbuf = [0i32; plan::MAX_GROUP];
    let (mut e, mut ph) = (0usize, 0usize);
    let (mut hgi, mut hbase) = (0usize, 0usize);
    let (mut lgi, mut lbase) = (0usize, 0usize);
    loop {
        if e + 4 > len {
            break;
        }
        let gh = &hp.groups[hgi];
        let gl = &lp.groups[lgi];
        if hbase + gh.span > hb.len() || lbase + gl.span > lb.len() {
            break;
        }
        plan::extract_group(hb, hbase, gh, 4, hmask, hsign, &mut hbuf);
        plan::extract_group(lb, lbase, gl, 4, lmask, lsign, &mut lbuf);
        let vh = _mm_loadu_si128(hbuf.as_ptr() as *const __m128i);
        let vl = _mm_loadu_si128(lbuf.as_ptr() as *const __m128i);
        let v = _mm_add_epi32(_mm_sll_epi32(vh, shl), vl);
        let f = _mm_cvtepi32_ps(v);
        let sc = _mm_loadu_ps(rep.as_ptr().add(ph));
        _mm_storeu_ps(dst.add(e), _mm_mul_ps(f, sc));
        e += 4;
        hgi += 1;
        if hgi == hp.groups.len() {
            hgi = 0;
            hbase += hp.period_bytes;
        }
        lgi += 1;
        if lgi == lp.groups.len() {
            lgi = 0;
            lbase += lp.period_bytes;
        }
        ph += 4;
        if ph >= c {
            ph %= c;
        }
    }
    e
}

// ---------------------------------------------------------------------------
// safe tier entries (fn-pointer targets for the KernelPlan vtable)
// ---------------------------------------------------------------------------

pub(crate) fn unpack_dequant_avx2(
    words: &[u8],
    bits: u8,
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    let rep = fold_rep(scales, scale_mul, 8);
    let done = unsafe {
        let d = unpack_dequant_avx2_body(words, bits, len, &rep, scales.len(), out.as_mut_ptr());
        out.set_len(d);
        d
    };
    debug_assert!(done <= len);
    scalar::unpack_dequant_tail(words, bits, len, scales, scale_mul, out);
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn recompose_dequant_avx2(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    let rep = fold_rep(scales, 1.0, 8);
    unsafe {
        let d = recompose_dequant_avx2_body(
            high_words,
            h_bits,
            low_words,
            low_bits,
            l,
            len,
            &rep,
            scales.len(),
            out.as_mut_ptr(),
        );
        out.set_len(d);
    }
    scalar::recompose_dequant_tail(high_words, h_bits, low_words, low_bits, l, len, scales, out);
}

pub(crate) fn unpack_ints_avx2(words: &[u8], bits: u8, len: usize, out: &mut Vec<i32>) {
    unsafe {
        let d = unpack_ints_avx2_body(words, bits, len, out.as_mut_ptr());
        out.set_len(d);
    }
    scalar::unpack_ints_tail(words, bits, len, out);
}

pub(crate) fn unpack_dequant_sse2(
    words: &[u8],
    bits: u8,
    len: usize,
    scales: &[f32],
    scale_mul: f32,
    out: &mut Vec<f32>,
) {
    let rep = fold_rep(scales, scale_mul, 4);
    unsafe {
        let d = unpack_dequant_sse2_body(words, bits, len, &rep, scales.len(), out.as_mut_ptr());
        out.set_len(d);
    }
    scalar::unpack_dequant_tail(words, bits, len, scales, scale_mul, out);
}

#[allow(clippy::too_many_arguments)]
pub(crate) fn recompose_dequant_sse2(
    high_words: &[u8],
    h_bits: u8,
    low_words: &[u8],
    low_bits: u8,
    l: u8,
    len: usize,
    scales: &[f32],
    out: &mut Vec<f32>,
) {
    let rep = fold_rep(scales, 1.0, 4);
    unsafe {
        let d = recompose_dequant_sse2_body(
            high_words,
            h_bits,
            low_words,
            low_bits,
            l,
            len,
            &rep,
            scales.len(),
            out.as_mut_ptr(),
        );
        out.set_len(d);
    }
    scalar::recompose_dequant_tail(high_words, h_bits, low_words, low_bits, l, len, scales, out);
}

/// SSE2 has no vector win for a pure i32 unpack (extraction is already
/// scalar there); route to the SWAR word-parallel path.
pub(crate) fn unpack_ints_sse2(words: &[u8], bits: u8, len: usize, out: &mut Vec<i32>) {
    super::swar::unpack_ints(words, bits, len, out);
}

pub(crate) fn gemm_i32_avx2(words: &[u8], bits: u8, x: &[i32], classes: usize, acc: &mut [i32]) {
    let done = unsafe { gemm_i32_avx2_body(words, bits, x, classes, acc) };
    super::gemm::gemm_tail(words, bits, x, classes, done, acc);
}

/// SSE2 has no packed 32-bit multiply (`pmulld` is SSE4.1), so the
/// integer MAC would be scalar anyway — route to the SWAR word-parallel
/// extraction.
pub(crate) fn gemm_i32_sse2(words: &[u8], bits: u8, x: &[i32], classes: usize, acc: &mut [i32]) {
    super::gemm::gemm_swar(words, bits, x, classes, acc);
}
