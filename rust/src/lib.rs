//! # NestQuant
//!
//! Production reproduction of *NestQuant: Post-Training Integer-Nesting
//! Quantization for On-Device DNN* (IEEE TMC 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the on-device coordinator: model manager with
//!   full-bit/part-bit switching, resource-driven policy, dynamic
//!   batcher, PJRT runtime (feature `pjrt`, with a pure-Rust offline
//!   fallback), device simulator, transmission system, the fleet
//!   distribution subsystem (resumable delta paging + zoo-wide section
//!   cache), the open-loop [`loadgen`] fleet driver (seeded synthetic
//!   load against a live server), the zero-copy [`store`] access layer
//!   (`NqArchive` + `SectionSource`, mmap-backed with lazy first-touch
//!   CRC) every tier reads models through, the
//!   runtime-dispatched switching [`kernels`] (one-pass packed → f32
//!   decode; scalar/SWAR/SIMD tiers behind a per-process `KernelPlan`),
//!   the readiness-driven [`reactor`] serving core (epoll event loop +
//!   weighted-fair worker queues) both TCP servers run on, the
//!   deterministic [`faults`] failpoint layer (chaos injection plus the
//!   circuit-breaker/backoff degradation primitives), and every
//!   substrate they need (packed bits, `.nq` containers with integrity
//!   trailers, quantizer, statistics). Python never runs on the
//!   request path.
//! - **L2 (python/compile)** — the JAX model zoo + PTQ pipeline, AOT-
//!   lowered once to `artifacts/*.hlo.txt`.
//! - **L1 (python/compile/kernels)** — Pallas kernels (interpret=True)
//!   for the quantization hot-spots, inside the lowered HLO.
//!
//! See DESIGN.md for the system inventory and the experiment index
//! mapping every paper table/figure to a module and bench target.

pub mod bits;
pub mod container;
pub mod coordinator;
pub mod device;
pub mod faults;
pub mod fleet;
pub mod kernels;
pub mod loadgen;
pub mod nest;
pub mod quant;
pub mod reactor;
pub mod report;
pub mod runtime;
pub mod stats;
pub mod store;
pub mod telemetry;
pub mod transport;
pub mod util;

use std::path::{Path, PathBuf};

/// Root of the artifacts directory (env `NESTQUANT_ARTIFACTS` or
/// `<manifest-dir>/artifacts`).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("NESTQUANT_ARTIFACTS") {
        return PathBuf::from(p);
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
