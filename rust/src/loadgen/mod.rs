//! Open-loop synthetic fleet driver: the load generator that earns the
//! zoo-scale claim.
//!
//! `nestquant loadgen` replays a **deterministic seeded schedule** of
//! device arrivals against a live fleet server through the real
//! [`FleetClient`] wire protocol — no shortcuts around the transport.
//! The schedule mixes three scenarios:
//!
//! * **cold-start waves** — the whole fleet (re)connects in bursts and
//!   provisions Section A, the worst case for archive opens and the
//!   section cache;
//! * **steady state** — Poisson arrivals of `level` reports at a
//!   configured offered rate, devices following the server's policy
//!   advice (upgrade → pull Section B, downgrade → drop it);
//! * **switch storms** — windows where a fraction of the fleet
//!   oscillates between extreme resource levels, hammering the
//!   bitwidth-switch path (B pulls + drops back to back).
//!
//! Device → model assignment is Zipf-tailed (`1/rank^s`), so a handful
//! of popular models absorb most traffic while the tail keeps the cache
//! honest — the access pattern a real zoo serves.
//!
//! The driver is **open-loop**: events fire at their scheduled wall
//! time whether or not earlier ones finished, so a slow server shows up
//! as queueing delay in the recorded latencies instead of silently
//! throttling the offered load (closed-loop drivers measure their own
//! backoff, not the server). Latency is measured from the *scheduled*
//! instant, not the send instant.
//!
//! Determinism contract: [`Schedule::generate`] is a pure function of
//! `(LoadgenConfig, n_models)` — same seed, same config ⇒ byte-identical
//! event list (asserted by test). Wall-clock execution of that schedule
//! is of course timing-dependent; the *schedule* is not.
//!
//! Output is a schema-versioned report (`nq-load-v1`, written to
//! `BENCH_load.json` by the CLI): sustained RPS, bytes paged over the
//! wire, per-scenario latency cells, switch p50/p99, shed count, and —
//! when the server answers a `metrics` scrape — the server-side deltas
//! (chunk bytes, cache evictions, mapped bytes, map faults) over the
//! run. `nestquant bench-guard --load` gates CI on cell completeness
//! and a bounded shed rate.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{Decision, Variant};
use crate::fleet::{FleetClient, Section};
use crate::util::json::{self, Value};
use crate::util::prng::Rng;

/// Knobs of one loadgen run. Everything that shapes the schedule is
/// here, so the (config, model-count) pair fully determines it.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Device population size.
    pub devices: u32,
    /// Schedule horizon (events are generated in `[0, duration)`).
    pub duration: Duration,
    /// Offered steady-state rate of `level` reports, fleet-wide.
    pub rps: f64,
    /// Schedule seed — same seed, same schedule.
    pub seed: u64,
    /// Zipf exponent for model popularity (higher ⇒ heavier head).
    pub zipf_s: f64,
    /// Cold-start waves in the first ~30% of the run.
    pub waves: u32,
    /// Bitwidth-switch storm windows in the 40–90% span of the run.
    pub storms: u32,
    /// Fraction of the fleet participating in each storm.
    pub storm_frac: f64,
    /// Driver threads (devices are partitioned across them).
    pub threads: usize,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            devices: 16,
            duration: Duration::from_secs(10),
            rps: 50.0,
            seed: 42,
            zipf_s: 1.1,
            waves: 2,
            storms: 2,
            storm_frac: 0.5,
            threads: 8,
        }
    }
}

/// Which traffic pattern an event belongs to — the report keeps a
/// latency cell per scenario so a storm can't hide inside the steady
/// average.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scenario {
    Steady,
    Storm,
    ColdStart,
}

impl Scenario {
    pub const ALL: [Scenario; 3] = [Scenario::Steady, Scenario::Storm, Scenario::ColdStart];

    /// Stable label used in `BENCH_load.json` cells (and gated on by
    /// `bench-guard --load`).
    pub fn label(self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Storm => "storm",
            Scenario::ColdStart => "coldstart",
        }
    }
}

/// One scheduled device action.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    /// (Re)connect and provision Section A from scratch.
    Connect,
    /// Report a resource level and follow the server's advice.
    Level(f64),
}

/// One entry of the schedule: at offset `at` from run start, device
/// `device` performs `action`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub at: Duration,
    pub device: u32,
    pub action: Action,
    pub scenario: Scenario,
}

/// The full deterministic run plan: time-sorted events plus the Zipf
/// device → model-index assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    pub events: Vec<Event>,
    /// `device_model[d]` is the model *index* device `d` pulls from
    /// (mod the actual zoo size at run time).
    pub device_model: Vec<u32>,
}

impl Schedule {
    /// Pure function of `(cfg, n_models)`: same inputs ⇒ identical
    /// schedule. All randomness flows through one seeded [`Rng`].
    pub fn generate(cfg: &LoadgenConfig, n_models: usize) -> Schedule {
        let mut rng = Rng::new(cfg.seed);
        let n_models = n_models.max(1);
        let devices = cfg.devices.max(1);
        let dur = cfg.duration.as_secs_f64().max(0.001);

        // Zipf-tailed popularity: weight 1/rank^s, sampled by inverse CDF.
        let weights: Vec<f64> = (0..n_models)
            .map(|i| 1.0 / ((i + 1) as f64).powf(cfg.zipf_s))
            .collect();
        let total: f64 = weights.iter().sum();
        let mut device_model = Vec::with_capacity(devices as usize);
        for _ in 0..devices {
            let mut u = rng.f64() * total;
            let mut pick = n_models - 1;
            for (i, w) in weights.iter().enumerate() {
                if u < *w {
                    pick = i;
                    break;
                }
                u -= w;
            }
            device_model.push(pick as u32);
        }

        let mut events = Vec::new();

        // Cold-start waves: every device (re)connects in a jittered
        // burst; waves land inside the first 30% of the horizon so the
        // steady tail measures a warm fleet.
        for w in 0..cfg.waves.max(1) {
            let base = 0.3 * dur * w as f64 / cfg.waves.max(1) as f64;
            for d in 0..devices {
                let at = base + rng.f64() * 0.05 * dur;
                events.push(Event {
                    at: Duration::from_secs_f64(at),
                    device: d,
                    action: Action::Connect,
                    scenario: Scenario::ColdStart,
                });
            }
        }

        // Steady state: Poisson arrivals (exponential gaps) of level
        // reports at the offered rate, uniform over devices, levels in
        // the hysteresis mid-band so advice stays data-dependent.
        let rps = cfg.rps.max(0.1);
        let mut t = 0.0;
        loop {
            t += -(1.0 - rng.f64()).ln() / rps;
            let at = Duration::from_secs_f64(t);
            // nanosecond rounding can nudge a value just under `dur`
            // onto it — compare the rounded Duration, not the f64
            if at >= cfg.duration {
                break;
            }
            events.push(Event {
                at,
                device: rng.index(devices as usize) as u32,
                action: Action::Level(0.2 + 0.6 * rng.f64()),
                scenario: Scenario::Steady,
            });
        }

        // Switch storms: short windows in the 40–90% span where a
        // fraction of the fleet alternates extreme levels — every
        // oscillation is a potential B pull or drop.
        let storm_devs =
            ((devices as f64 * cfg.storm_frac.clamp(0.0, 1.0)).ceil() as u32).clamp(1, devices);
        for s in 0..cfg.storms {
            let start = dur * (0.4 + 0.5 * s as f64 / cfg.storms.max(1) as f64);
            let width = dur * 0.05;
            let mut ids: Vec<u32> = (0..devices).collect();
            rng.shuffle(&mut ids);
            for d in ids.into_iter().take(storm_devs as usize) {
                for i in 0..6u32 {
                    let level = if i % 2 == 0 { 0.95 } else { 0.05 };
                    events.push(Event {
                        at: Duration::from_secs_f64(start + width * i as f64 / 6.0),
                        device: d,
                        action: Action::Level(level),
                        scenario: Scenario::Storm,
                    });
                }
            }
        }

        // Stable sort: ties keep generation order, so the sorted list
        // is as deterministic as the unsorted one.
        events.sort_by_key(|e| e.at);
        Schedule {
            events,
            device_model,
        }
    }
}

/// One per-scenario latency cell of the report.
#[derive(Debug, Clone, Default)]
pub struct Cell {
    pub requests: u64,
    pub completed: u64,
    pub shed: u64,
    latencies_us: Vec<u64>,
}

impl Cell {
    pub fn p50_us(&self) -> u64 {
        percentile(&self.latencies_us, 50)
    }

    pub fn p99_us(&self) -> u64 {
        percentile(&self.latencies_us, 99)
    }
}

/// Server-side counter deltas over the run (from two `metrics` scrapes;
/// absent when the server refuses the scrape).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerDelta {
    pub chunk_bytes_sent: u64,
    pub cache_evictions: u64,
    pub rate_limited: u64,
    /// Gauge at end of run, not a delta: live mmap'd bytes.
    pub mapped_bytes: u64,
    pub map_faults: u64,
}

/// Everything `BENCH_load.json` carries.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub seed: u64,
    pub devices: u32,
    pub duration: Duration,
    pub offered_rps: f64,
    pub models: usize,
    pub requests: u64,
    pub completed: u64,
    pub shed: u64,
    pub sustained_rps: f64,
    /// Section payload bytes pulled over the wire by all devices.
    pub bytes_paged: u64,
    /// Completed full-bit upgrades (timed Section-B pulls).
    pub switches: u64,
    pub switch_p50_us: u64,
    pub switch_p99_us: u64,
    pub eviction_rate_per_s: f64,
    pub cells: Vec<(Scenario, Cell)>,
    pub server: Option<ServerDelta>,
}

impl LoadReport {
    /// The `nq-load-v1` document `bench-guard --load` checks.
    pub fn to_json(&self) -> Value {
        let cells = self
            .cells
            .iter()
            .map(|(sc, c)| {
                json::obj(vec![
                    ("scenario", json::str_(sc.label())),
                    ("requests", json::uint(c.requests)),
                    ("completed", json::uint(c.completed)),
                    ("shed", json::uint(c.shed)),
                    ("p50_us", json::uint(c.p50_us())),
                    ("p99_us", json::uint(c.p99_us())),
                ])
            })
            .collect();
        let mut doc = vec![
            ("schema", json::str_("nq-load-v1")),
            ("seed", json::uint(self.seed)),
            ("devices", json::uint(self.devices as u64)),
            ("duration_s", json::num(self.duration.as_secs_f64())),
            ("offered_rps", json::num(self.offered_rps)),
            ("models", json::uint(self.models as u64)),
            ("requests", json::uint(self.requests)),
            ("completed", json::uint(self.completed)),
            ("shed", json::uint(self.shed)),
            ("sustained_rps", json::num(self.sustained_rps)),
            ("bytes_paged", json::uint(self.bytes_paged)),
            ("switches", json::uint(self.switches)),
            ("switch_p50_us", json::uint(self.switch_p50_us)),
            ("switch_p99_us", json::uint(self.switch_p99_us)),
            ("eviction_rate_per_s", json::num(self.eviction_rate_per_s)),
            ("cells", json::arr(cells)),
        ];
        if let Some(s) = &self.server {
            doc.push((
                "server",
                json::obj(vec![
                    ("chunk_bytes_sent", json::uint(s.chunk_bytes_sent)),
                    ("cache_evictions", json::uint(s.cache_evictions)),
                    ("rate_limited", json::uint(s.rate_limited)),
                    ("mapped_bytes", json::uint(s.mapped_bytes)),
                    ("map_faults", json::uint(s.map_faults)),
                ]),
            ));
        }
        json::obj(doc)
    }
}

fn percentile(sorted_or_not: &[u64], p: u64) -> u64 {
    if sorted_or_not.is_empty() {
        return 0;
    }
    let mut v = sorted_or_not.to_vec();
    v.sort_unstable();
    let idx = ((v.len() as u64 * p) / 100).min(v.len() as u64 - 1) as usize;
    v[idx]
}

/// Per-device live state inside a driver thread.
struct DeviceState {
    client: Option<FleetClient>,
    model: String,
    b_resident: bool,
}

/// Per-thread measurement accumulator, merged after join.
#[derive(Default)]
struct ThreadStats {
    cells: Vec<Cell>, // indexed by Scenario::ALL position
    bytes_paged: u64,
    switches: u64,
    switch_us: Vec<u64>,
}

impl ThreadStats {
    fn new() -> ThreadStats {
        ThreadStats {
            cells: vec![Cell::default(); Scenario::ALL.len()],
            ..ThreadStats::default()
        }
    }

    fn cell(&mut self, sc: Scenario) -> &mut Cell {
        let i = Scenario::ALL.iter().position(|s| *s == sc).unwrap();
        &mut self.cells[i]
    }
}

const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// Provision a device from scratch: hello + full Section-A pull (the
/// part-bit launch path). Returns payload bytes pulled.
fn provision(addr: SocketAddr, device: u32, model: &str) -> Result<(FleetClient, u64)> {
    let mut client = FleetClient::connect(addr, &format!("lg-{device:04}"), CONNECT_TIMEOUT)?;
    let mut sink = Vec::new();
    let out = client.pull_section(model, Section::A, 0, &mut sink, None)?;
    Ok((client, out.payload_bytes))
}

/// Execute one event against live state. Returns payload bytes moved;
/// an `Err` is recorded as a shed request and drops the connection (the
/// next event on the device reconnects).
fn execute(
    addr: SocketAddr,
    ev: &Event,
    dev: &mut DeviceState,
    stats: &mut ThreadStats,
) -> Result<u64> {
    match ev.action {
        Action::Connect => {
            // A cold start is a *fresh* provision even when connected:
            // drop the old session first so the wave measures real opens.
            dev.client = None;
            dev.b_resident = false;
            let (client, paged) = provision(addr, ev.device, &dev.model)?;
            dev.client = Some(client);
            Ok(paged)
        }
        Action::Level(level) => {
            if dev.client.is_none() {
                let (client, paged) = provision(addr, ev.device, &dev.model)?;
                dev.client = Some(client);
                dev.b_resident = false;
                stats.bytes_paged += paged;
            }
            let client = dev.client.as_mut().unwrap();
            match client.report_level(level)? {
                Decision::Stay => Ok(0),
                Decision::SwitchTo(Variant::FullBit) => {
                    if dev.b_resident {
                        return Ok(0);
                    }
                    let t0 = Instant::now();
                    let mut sink = Vec::new();
                    let out = client.pull_section(&dev.model, Section::B, 0, &mut sink, None)?;
                    dev.b_resident = true;
                    stats.switches += 1;
                    stats.switch_us.push(t0.elapsed().as_micros() as u64);
                    Ok(out.payload_bytes)
                }
                Decision::SwitchTo(Variant::PartBit) => {
                    if dev.b_resident {
                        client.notify_dropped(&dev.model, Section::B)?;
                        dev.b_resident = false;
                    }
                    Ok(0)
                }
            }
        }
    }
}

/// Scrape `nq_*` counters the report wants off a live server.
fn scrape(addr: SocketAddr) -> Result<crate::telemetry::Snapshot> {
    use crate::transport::{recv_frame, send_frame, Frame, FrameKind, Meter};
    let mut sock = std::net::TcpStream::connect(addr)?;
    sock.set_read_timeout(Some(Duration::from_secs(10)))?;
    let meter = Meter::default();
    send_frame(
        &mut sock,
        &Frame {
            kind: FrameKind::Control,
            name: "metrics".into(),
            payload: Vec::new(),
        },
        &meter,
    )?;
    let (reply, _) = recv_frame(&mut sock, &meter)?;
    anyhow::ensure!(reply.name == "metrics", "unexpected reply {:?}", reply.name);
    crate::telemetry::Snapshot::from_json(std::str::from_utf8(&reply.payload)?)
}

/// Drive the schedule against a live fleet server and measure.
///
/// Open-loop: each driver thread owns a device partition
/// (`device % threads`) and fires that partition's events at their
/// scheduled wall time, sleeping only *forward* — when the driver falls
/// behind, events fire back-to-back and the delay lands in the recorded
/// latency, which is the honest open-loop accounting.
pub fn run(addr: SocketAddr, cfg: &LoadgenConfig) -> Result<LoadReport> {
    let models = FleetClient::connect(addr, "lg-probe", CONNECT_TIMEOUT)
        .and_then(|mut c| c.models())
        .context("listing models on the target server")?;
    anyhow::ensure!(!models.is_empty(), "target server hosts no models");

    let schedule = Schedule::generate(cfg, models.len());
    let threads = cfg.threads.clamp(1, cfg.devices.max(1) as usize);
    let before = scrape(addr).ok();
    let start = Instant::now();

    let mut joins = Vec::new();
    for tid in 0..threads {
        let events: Vec<Event> = schedule
            .events
            .iter()
            .filter(|e| e.device as usize % threads == tid)
            .copied()
            .collect();
        let mut devices: std::collections::HashMap<u32, DeviceState> = schedule
            .device_model
            .iter()
            .enumerate()
            .filter(|(d, _)| d % threads == tid)
            .map(|(d, m)| {
                (
                    d as u32,
                    DeviceState {
                        client: None,
                        model: models[*m as usize % models.len()].clone(),
                        b_resident: false,
                    },
                )
            })
            .collect();
        joins.push(std::thread::spawn(move || -> ThreadStats {
            let mut stats = ThreadStats::new();
            for ev in &events {
                let scheduled = start + ev.at;
                if let Some(wait) = scheduled.checked_duration_since(Instant::now()) {
                    std::thread::sleep(wait);
                }
                let dev = devices.get_mut(&ev.device).unwrap();
                let r = execute(addr, ev, dev, &mut stats);
                let latency_us = scheduled.elapsed().as_micros() as u64;
                let cell = stats.cell(ev.scenario);
                cell.requests += 1;
                match r {
                    Ok(paged) => {
                        cell.completed += 1;
                        cell.latencies_us.push(latency_us);
                        stats.bytes_paged += paged;
                    }
                    Err(_) => {
                        // shed: drop the session; the next event on this
                        // device provisions a fresh one
                        cell.shed += 1;
                        dev.client = None;
                        dev.b_resident = false;
                    }
                }
            }
            stats
        }));
    }

    let mut cells = vec![Cell::default(); Scenario::ALL.len()];
    let mut bytes_paged = 0u64;
    let mut switches = 0u64;
    let mut switch_us = Vec::new();
    for j in joins {
        let s = j.join().expect("loadgen driver thread panicked");
        for (acc, c) in cells.iter_mut().zip(s.cells) {
            acc.requests += c.requests;
            acc.completed += c.completed;
            acc.shed += c.shed;
            acc.latencies_us.extend(c.latencies_us);
        }
        bytes_paged += s.bytes_paged;
        switches += s.switches;
        switch_us.extend(s.switch_us);
    }
    let elapsed = start.elapsed().as_secs_f64().max(0.001);
    let after = scrape(addr).ok();

    let server = match (&before, &after) {
        (Some(b), Some(a)) => {
            let delta = |name: &str| {
                a.counter(name)
                    .unwrap_or(0)
                    .saturating_sub(b.counter(name).unwrap_or(0))
            };
            Some(ServerDelta {
                chunk_bytes_sent: delta("nq_fleet_chunk_bytes_sent"),
                cache_evictions: delta("nq_fleet_cache_evictions"),
                rate_limited: delta("nq_reactor_rate_limited"),
                mapped_bytes: a.gauge("nq_store_mapped_bytes").unwrap_or(0),
                map_faults: delta("nq_store_map_faults"),
            })
        }
        _ => None,
    };
    let eviction_rate_per_s = server
        .map(|s| s.cache_evictions as f64 / elapsed)
        .unwrap_or(0.0);

    let (requests, completed, shed) = cells.iter().fold((0, 0, 0), |(r, c, s), cell| {
        (r + cell.requests, c + cell.completed, s + cell.shed)
    });
    Ok(LoadReport {
        seed: cfg.seed,
        devices: cfg.devices,
        duration: cfg.duration,
        offered_rps: cfg.rps,
        models: models.len(),
        requests,
        completed,
        shed,
        sustained_rps: completed as f64 / elapsed,
        bytes_paged,
        switches,
        switch_p50_us: percentile(&switch_us, 50),
        switch_p99_us: percentile(&switch_us, 99),
        eviction_rate_per_s,
        cells: Scenario::ALL.into_iter().zip(cells).collect(),
        server,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> LoadgenConfig {
        LoadgenConfig {
            devices: 8,
            duration: Duration::from_secs(5),
            rps: 40.0,
            seed: 7,
            ..LoadgenConfig::default()
        }
    }

    #[test]
    fn same_seed_same_schedule() {
        let cfg = small_cfg();
        let a = Schedule::generate(&cfg, 3);
        let b = Schedule::generate(&cfg, 3);
        assert_eq!(a, b, "schedule must be a pure function of (cfg, n_models)");
    }

    #[test]
    fn different_seed_different_schedule() {
        let a = Schedule::generate(&small_cfg(), 3);
        let mut cfg = small_cfg();
        cfg.seed = 8;
        let b = Schedule::generate(&cfg, 3);
        assert_ne!(a, b);
    }

    #[test]
    fn schedule_is_sorted_in_horizon_and_covers_all_scenarios() {
        let cfg = small_cfg();
        let s = Schedule::generate(&cfg, 3);
        assert!(!s.events.is_empty());
        assert_eq!(s.device_model.len(), cfg.devices as usize);
        let mut last = Duration::ZERO;
        for ev in &s.events {
            assert!(ev.at >= last, "events must be time-sorted");
            assert!(ev.at < cfg.duration, "event at {:?} past horizon", ev.at);
            assert!(ev.device < cfg.devices);
            last = ev.at;
        }
        for sc in Scenario::ALL {
            assert!(
                s.events.iter().any(|e| e.scenario == sc),
                "schedule missing scenario {sc:?}"
            );
        }
        // Zipf head: model 0 must own at least one device at s > 1
        assert!(s.device_model.iter().any(|m| *m == 0));
    }

    #[test]
    fn storm_events_oscillate_extremes() {
        let s = Schedule::generate(&small_cfg(), 2);
        let storm_levels: Vec<f64> = s
            .events
            .iter()
            .filter(|e| e.scenario == Scenario::Storm)
            .filter_map(|e| match e.action {
                Action::Level(l) => Some(l),
                Action::Connect => None,
            })
            .collect();
        assert!(storm_levels.iter().any(|l| *l > 0.9));
        assert!(storm_levels.iter().any(|l| *l < 0.1));
    }

    #[test]
    fn report_json_has_every_cell_and_schema() {
        let report = LoadReport {
            seed: 42,
            devices: 4,
            duration: Duration::from_secs(2),
            offered_rps: 10.0,
            models: 2,
            requests: 20,
            completed: 19,
            shed: 1,
            sustained_rps: 9.5,
            bytes_paged: 1 << 20,
            switches: 3,
            switch_p50_us: 100,
            switch_p99_us: 900,
            eviction_rate_per_s: 0.5,
            cells: Scenario::ALL
                .into_iter()
                .map(|sc| {
                    (
                        sc,
                        Cell {
                            requests: 5,
                            completed: 5,
                            shed: 0,
                            latencies_us: vec![50, 100, 200],
                        },
                    )
                })
                .collect(),
            server: Some(ServerDelta::default()),
        };
        let doc = json::parse(&json::to_string(&report.to_json())).unwrap();
        assert_eq!(
            doc.path(&["schema"]).unwrap().as_str().unwrap(),
            "nq-load-v1"
        );
        assert_eq!(doc.path(&["completed"]).unwrap().as_u64().unwrap(), 19);
        let cells = doc.path(&["cells"]).unwrap().as_array().unwrap();
        let labels: Vec<&str> = cells
            .iter()
            .map(|c| c.path(&["scenario"]).unwrap().as_str().unwrap())
            .collect();
        assert_eq!(labels, ["steady", "storm", "coldstart"]);
        for c in cells {
            let p99 = c.path(&["p99_us"]).unwrap().as_u64().unwrap();
            let p50 = c.path(&["p50_us"]).unwrap().as_u64().unwrap();
            assert!(p99 >= p50);
        }
        assert!(doc.get("server").is_some());
    }

    #[test]
    fn percentile_handles_edges() {
        assert_eq!(percentile(&[], 99), 0);
        assert_eq!(percentile(&[7], 50), 7);
        assert_eq!(percentile(&[7], 99), 7);
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 50), 51);
        assert_eq!(percentile(&v, 99), 100);
    }
}
