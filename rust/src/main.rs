//! `nestquant` — the L3 coordinator CLI.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! nestquant info                          artifact + zoo overview
//! nestquant inspect <model.nq>            section index + per-tensor layout
//! nestquant eval --arch cnn_m --n 8 --h 4 [--variant part|full] [--limit N]
//! nestquant trace --arch cnn_m --n 8 --h 4 [--steps N] [--trace solar|discharge]
//! nestquant serve --arch cnn_m --n 8 --h 4
//! nestquant serve --store artifacts/nq [--budget-mb 64] [--batch 4] [--synth N]
//! nestquant fleet [--devices D] [--steps K] [--budget-mb M] [--chunk-kb C]
//! nestquant loadgen (--addr H:P | --store DIR [--synth N]) [--devices D] [--rps R]
//! nestquant metrics --addr H:P [--prom] [--check] [--require a,b] [--out F]
//! nestquant top --addr H:P                one-shot human telemetry table
//! nestquant report <table|fig|all>        regenerate paper tables/figures
//! ```

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use nestquant::coordinator::{server, Coordinator, SwitchPolicy};
use nestquant::device::ResourceTrace;
use nestquant::report;

fn usage() -> ! {
    eprintln!(
        "usage: nestquant <command> [flags]\n\
         commands:\n\
         \x20 info                               artifacts overview\n\
         \x20 inspect <model.nq>                 section index, per-tensor dims/bits,\n\
         \x20                                    A/B byte split (any .nq file)\n\
         \x20 eval   --arch A --n N --h H [--variant part|full] [--limit K]\n\
         \x20 trace  --arch A --n N --h H [--steps K] [--trace solar|discharge] [--reqs R]\n\
         \x20 serve  --arch A --n N --h H        start the inference server (one model)\n\
         \x20 serve  --store DIR [--budget-mb M] [--batch B] [--synth N]\n\
         \x20                                    host every nest .nq in DIR behind one\n\
         \x20                                    multi-tenant server + shared B budget\n\
         \x20                                    (--synth N seeds DIR with N synthetic\n\
         \x20                                    containers first — CI/demo without artifacts)\n\
         \x20 fleet  [--devices D] [--steps K] [--budget-mb M] [--chunk-kb C] [--models M]\n\
         \x20                                    fleet-distribution simulation (synthetic zoo\n\
         \x20                                    when artifacts are missing)\n\
         \x20 loadgen (--addr HOST:PORT | --store DIR [--synth N])\n\
         \x20        [--devices D] [--rps R] [--duration-s S] [--seed N]\n\
         \x20        [--threads T] [--out FILE]\n\
         \x20                                    open-loop synthetic fleet load (Poisson\n\
         \x20                                    steady state + cold-start waves + switch\n\
         \x20                                    storms, Zipf model popularity) replaying a\n\
         \x20                                    deterministic seeded schedule; writes\n\
         \x20                                    BENCH_load.json (--store boots a local\n\
         \x20                                    fleet server over DIR first)\n\
         \x20 metrics --addr HOST:PORT [--prom] [--check] [--require n1,n2] [--out FILE]\n\
         \x20                                    scrape a live server's telemetry snapshot\n\
         \x20                                    (JSON by default, --prom for Prometheus text)\n\
         \x20 top    --addr HOST:PORT            one-shot telemetry table (tenants, store,\n\
         \x20                                    kernels, fleet, trace tail)\n\
\x20 select --arch A [--n N] [--live]   adaptive nesting selection (future-work)\n\
         \x20 bench-guard [BENCH_kernels.json]   fail if any expected bench cell is\n\
         \x20        [--load BENCH_load.json]    missing, the SIMD tier regressed below\n\
         \x20                                    SWAR on lane-aligned cells, or the\n\
         \x20                                    int-domain forward lost to f32-decode;\n\
         \x20                                    --load also gates a loadgen report\n\
         \x20                                    (all scenario cells, bounded shed)\n\
         \x20 report <what>                      one of: errors storage-ideal storage\n\
         \x20                                    switching similarity nesting nesting-test\n\
         \x20                                    cliff combos traffic comparison ptq-cost\n\
         \x20                                    hardware libraries all\n\
         flags: --artifacts DIR overrides the artifacts root\n\
         env:   NQ_FAULTS=site=mode:arg[@seed];...   deterministic fault injection\n\
         \x20                                   (e.g. store.read_b=err:1;fleet.chunk=delay_ms:50;\n\
         \x20                                   worker.job=panic:0.01@7 — see DESIGN.md §4h)"
    );
    std::process::exit(2);
}

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = it
                .peek()
                .filter(|v| !v.starts_with("--"))
                .cloned()
                .map(|v| {
                    it.next();
                    v
                })
                .unwrap_or_else(|| "true".to_string());
            flags.insert(name.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Args { positional, flags }
}

impl Args {
    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn req(&self, name: &str) -> Result<&str> {
        self.flag(name)
            .with_context(|| format!("missing required flag --{name}"))
    }

    fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}={v}: {e}")),
        }
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = parse_args();
    let root = args
        .flag("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(nestquant::artifacts_dir);
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        usage()
    };
    match cmd {
        "info" => cmd_info(&root),
        "inspect" => cmd_inspect(&args),
        "eval" => cmd_eval(&root, &args),
        "trace" => cmd_trace(&root, &args),
        "serve" => cmd_serve(&root, &args),
        "fleet" => cmd_fleet(&root, &args),
        "loadgen" => cmd_loadgen(&args),
        "metrics" => cmd_metrics(&args),
        "top" => cmd_top(&args),
        "select" => cmd_select(&root, &args),
        "report" => cmd_report(&root, &args),
        "bench-guard" => cmd_bench_guard(&args),
        _ => usage(),
    }
}

/// CI bench-regression guard: read a `BENCH_kernels.json` written by
/// `cargo bench --bench kernels` and fail (exit 1) on a tier
/// regression. The file must carry every expected (bitwidth, op)
/// cell — a missing cell fails with its own message naming the cell
/// (a truncated or stale bench file should never pass as "no
/// regressions").
///
/// Gates, all with a small noise band so one jittery CI run does not
/// flag a false regression (a real one blows way past it):
///
/// * decode cells (`launch`/`upgrade`), lane-aligned: SIMD ≥ 0.95x
///   SWAR. Unaligned cells — where the SWAR tier is really the scalar
///   lane cursor — are reported but not hard-gated (their ratios swing
///   more across microarchitectures).
/// * forward cells (`forward_part`/`forward_full`), lane-aligned:
///   int-domain SIMD ≥ 0.95x int-domain SWAR.
/// * forward cells, every alignment: int-domain SIMD ≥ 0.9x the
///   f32-decode baseline — the dequantization-free path must never
///   lose meaningfully to decode-then-matmul, or it has no reason to
///   be the default `ForwardMode`.
///
/// `--load FILE` additionally (or, without a kernels path, *only*)
/// checks a `BENCH_load.json` written by `nestquant loadgen`: schema
/// `nq-load-v1`, every scenario cell present and exercised, a bounded
/// shed rate, and sane latency ordering — a truncated or idle load run
/// should never pass as "the fleet held up".
fn cmd_bench_guard(args: &Args) -> Result<()> {
    use nestquant::util::json;

    if let Some(load_path) = args.flag("load") {
        check_load_report(load_path)?;
        // --load alone gates only the load run; kernels still checked
        // when a kernels file is named explicitly
        if args.positional.get(1).is_none() {
            return Ok(());
        }
    }

    const NOISE_BAND: f64 = 0.95;
    const FWD_VS_F32_BAND: f64 = 0.9;
    /// Must mirror `configs` in `benches/kernels.rs`.
    const CONFIGS: [(u64, u64); 8] =
        [(8, 4), (8, 5), (8, 6), (6, 3), (16, 8), (7, 3), (7, 4), (11, 8)];
    const OPS: [&str; 4] = ["launch", "upgrade", "forward_part", "forward_full"];

    let path = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("BENCH_kernels.json");
    let doc = json::parse_file(std::path::Path::new(path))?;
    let cells = doc.path(&["cells"])?.as_array()?;
    anyhow::ensure!(
        !cells.is_empty(),
        "{path} has no cells — run `cargo bench --bench kernels` first \
         (the committed trajectory seed carries none by design)"
    );
    let mut by_key: HashMap<(u64, u64, String), &json::Value> = HashMap::new();
    for cell in cells {
        let n = cell.path(&["n"])?.as_u64()?;
        let h = cell.path(&["h"])?.as_u64()?;
        let op = cell.path(&["op"])?.as_str()?;
        by_key.insert((n, h, op.to_string()), cell);
    }
    let mut missing = Vec::new();
    let mut losses = Vec::new();
    let mut unaligned_wins = 0usize;
    let mut unaligned = 0usize;
    let mut checked = 0usize;
    for (n, h) in CONFIGS {
        for op in OPS {
            let Some(cell) = by_key.get(&(n, h, op.to_string())) else {
                missing.push(format!("INT({n}|{h}) {op}"));
                continue;
            };
            checked += 1;
            let field = |name: &str| -> Result<f64> {
                cell.path(&[name])
                    .and_then(|v| v.as_f64())
                    .with_context(|| format!("INT({n}|{h}) {op}: bad or missing `{name}`"))
            };
            let aligned = cell
                .path(&["aligned"])
                .and_then(|v| v.as_bool())
                .with_context(|| format!("INT({n}|{h}) {op}: bad or missing `aligned`"))?;
            if op.starts_with("forward") {
                let swar = field("swar_tokens_per_s")?;
                let simd = field("simd_tokens_per_s")?;
                let f32_decode = field("f32_decode_tokens_per_s")?;
                if aligned && simd < NOISE_BAND * swar {
                    losses.push(format!(
                        "INT({n}|{h}) {op}: int simd {simd:.1} tok/s < int swar \
                         {swar:.1} tok/s ({:.2}x)",
                        simd / swar
                    ));
                }
                if simd < FWD_VS_F32_BAND * f32_decode {
                    losses.push(format!(
                        "INT({n}|{h}) {op}: int simd {simd:.1} tok/s < {FWD_VS_F32_BAND}x \
                         f32-decode {f32_decode:.1} tok/s ({:.2}x)",
                        simd / f32_decode
                    ));
                }
            } else {
                let swar = field("swar_bytes_per_s")?;
                let simd = field("simd_bytes_per_s")?;
                let ratio = simd / swar;
                if aligned {
                    if simd < NOISE_BAND * swar {
                        losses.push(format!(
                            "INT({n}|{h}) {op}: simd {:.1} MB/s < swar {:.1} MB/s ({ratio:.2}x)",
                            simd / 1e6,
                            swar / 1e6
                        ));
                    }
                } else {
                    unaligned += 1;
                    if ratio > 1.0 {
                        unaligned_wins += 1;
                    }
                    println!(
                        "bench-guard: unaligned INT({n}|{h}) {op}: simd/lane-cursor {ratio:.2}x"
                    );
                }
            }
        }
    }
    anyhow::ensure!(
        missing.is_empty(),
        "{path} is missing {} expected cell(s):\n  {}\n\
         re-run `cargo bench --bench kernels` to regenerate the full grid",
        missing.len(),
        missing.join("\n  ")
    );
    println!(
        "bench-guard: {checked} cells checked ({unaligned} unaligned decode, \
         {unaligned_wins} simd wins there)"
    );
    anyhow::ensure!(
        losses.is_empty(),
        "bench gates failed:\n  {}",
        losses.join("\n  ")
    );
    println!(
        "bench-guard: SIMD holds ≥{NOISE_BAND}x SWAR on aligned cells; int-domain \
         forward holds ≥{FWD_VS_F32_BAND}x f32-decode everywhere"
    );
    Ok(())
}

/// The `bench-guard --load` gate over a `nestquant loadgen` report:
/// completeness (every scenario cell present *and* exercised — a
/// schedule that skipped cold starts proves nothing about opens) and
/// health (shed rate bounded, sustained throughput nonzero, per-cell
/// p99 ≥ p50).
fn check_load_report(path: &str) -> Result<()> {
    use nestquant::util::json;

    /// An open-loop driver sheds when the server can't keep up; some
    /// shed under storms is expected, a majority means collapse.
    const MAX_SHED_RATE: f64 = 0.5;

    let doc = json::parse_file(std::path::Path::new(path))?;
    let schema = doc.path(&["schema"])?.as_str()?;
    anyhow::ensure!(
        schema == "nq-load-v1",
        "{path}: unexpected load report schema {schema:?} (expected \"nq-load-v1\")"
    );
    let cells = doc.path(&["cells"])?.as_array()?;
    let mut by_scenario: HashMap<&str, &json::Value> = HashMap::new();
    for cell in cells {
        by_scenario.insert(cell.path(&["scenario"])?.as_str()?, cell);
    }
    for want in ["steady", "storm", "coldstart"] {
        let cell = by_scenario.get(want).with_context(|| {
            format!("{path}: missing load cell {want:?} — the schedule must exercise every scenario")
        })?;
        let requests = cell.path(&["requests"])?.as_u64()?;
        anyhow::ensure!(
            requests > 0,
            "{path}: load cell {want:?} recorded zero requests"
        );
        let p50 = cell.path(&["p50_us"])?.as_u64()?;
        let p99 = cell.path(&["p99_us"])?.as_u64()?;
        anyhow::ensure!(
            p99 >= p50,
            "{path}: load cell {want:?} has p99 {p99}us < p50 {p50}us"
        );
    }
    let requests = doc.path(&["requests"])?.as_u64()?;
    let shed = doc.path(&["shed"])?.as_u64()?;
    anyhow::ensure!(requests > 0, "{path}: load run recorded zero requests");
    let shed_rate = shed as f64 / requests as f64;
    anyhow::ensure!(
        shed_rate <= MAX_SHED_RATE,
        "{path}: shed rate {shed_rate:.3} exceeds {MAX_SHED_RATE} ({shed}/{requests} requests)"
    );
    let sustained = doc.path(&["sustained_rps"])?.as_f64()?;
    anyhow::ensure!(sustained > 0.0, "{path}: sustained_rps is zero");
    println!(
        "bench-guard: load report ok — sustained {sustained:.1} rps, \
         shed rate {shed_rate:.3}, all scenario cells present"
    );
    Ok(())
}

fn cmd_info(root: &std::path::Path) -> Result<()> {
    let manifest = nestquant::runtime::Manifest::load(root)?;
    println!("artifacts: {}", root.display());
    println!(
        "dataset: {} val images, {}x{}x{}, batch {}",
        manifest.val_count, manifest.img, manifest.img, manifest.channels, manifest.batch
    );
    for (name, spec) in &manifest.models {
        let n_params: usize = spec.params.iter().map(|p| p.count()).sum();
        println!(
            "  {name:9} {:>9} params  hlo:{:?}  nest:{:?}",
            n_params,
            spec.hlo.keys().collect::<Vec<_>>(),
            spec.nest_containers.keys().collect::<Vec<_>>(),
        );
    }
    Ok(())
}

/// Inspect one `.nq` artifact through the store API: section index,
/// A/B byte split, and the per-tensor layout — without decoding a single
/// payload into tensors (the layout walk skips them).
fn cmd_inspect(args: &Args) -> Result<()> {
    use nestquant::store::NqArchive;

    let Some(path) = args.positional.get(1) else {
        bail!("usage: nestquant inspect <model.nq>");
    };
    let archive = NqArchive::open(path)?;
    let idx = archive.index();
    println!("{path}");
    println!(
        "  kind {:?}  name {:?}  INT({}|{})  act_bits {}",
        idx.kind, idx.name, idx.n, idx.h, idx.act_bits
    );
    let a = idx.section_a();
    let b = idx.section_b();
    println!(
        "  file {:>10} B   section A [{:>10}, {:>10}) {:>10} B ({:.1}%)",
        idx.file_len,
        a.start,
        a.end,
        idx.section_a_bytes(),
        idx.section_a_bytes() as f64 / idx.file_len.max(1) as f64 * 100.0
    );
    println!(
        "  {:>16}   section B [{:>10}, {:>10}) {:>10} B ({:.1}%)",
        "",
        b.start,
        b.end,
        idx.section_b_bytes(),
        idx.section_b_bytes() as f64 / idx.file_len.max(1) as f64 * 100.0
    );
    match idx.checksums {
        // decimal on purpose: the golden fixture normalizes digit runs
        Some(ck) => println!(
            "  checksums crc64 A={} B={} (each section verified lazily on first touch)",
            ck.a, ck.b
        ),
        None => println!("  checksums absent (pre-trailer artifact; fetches unverified)"),
    }

    let layout = archive.layout()?;
    if !layout.meta().is_empty() {
        println!("  meta {}", layout.meta());
    }
    println!("  {} tensors:", layout.len());
    println!(
        "    {:<24} {:<14} {:>9}  {:>6}  {:>12}  {:>12}",
        "name", "shape", "elems", "bits", "A bytes", "B bytes"
    );
    for t in layout.tensors() {
        let shape = t
            .shape()
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x");
        let (bits, a_bytes) = match t.packed_bits() {
            Some(bits) => (
                format!("INT{bits}"),
                nestquant::bits::packed_nbytes(t.count(), bits),
            ),
            None => ("f32".to_string(), 4 * t.count()),
        };
        println!(
            "    {:<24} {:<14} {:>9}  {:>6}  {:>12}  {:>12}",
            t.name(),
            shape,
            t.count(),
            bits,
            a_bytes,
            t.low_block_bytes()
        );
    }
    let stats = archive.stats();
    println!(
        "  (inspect cost: {} section-A fetch / {} B, section B untouched)",
        stats.a_fetches, stats.a_bytes_fetched
    );
    Ok(())
}

fn cmd_eval(root: &std::path::Path, args: &Args) -> Result<()> {
    let arch = args.req("arch")?;
    let n: u8 = args.num("n", 8)?;
    let h: u8 = args.num("h", 4)?;
    let limit = args.flag("limit").map(|v| v.parse()).transpose()?;
    let variant = args.flag("variant").unwrap_or("full");
    let mut c = Coordinator::new(root, arch, n, h)?;
    let cost = match variant {
        "part" => c.manager.load_part_bit(&mut c.ledger)?,
        "full" => c.manager.load_full_bit(&mut c.ledger)?,
        other => bail!("--variant must be part|full, got {other}"),
    };
    println!(
        "loaded {arch} INT({n}|{h}) {variant}-bit: paged in {:.2}MB in {:.1}ms",
        cost.page_in_bytes as f64 / 1e6,
        cost.micros as f64 / 1e3
    );
    let acc = c.eval_accuracy(limit)?;
    println!("top-1 accuracy = {:.3}", acc);
    println!("{}", c.metrics.summary());
    Ok(())
}

fn cmd_trace(root: &std::path::Path, args: &Args) -> Result<()> {
    let arch = args.req("arch")?;
    let n: u8 = args.num("n", 8)?;
    let h: u8 = args.num("h", 4)?;
    let steps: usize = args.num("steps", 48)?;
    let reqs: usize = args.num("reqs", 32)?;
    let trace = match args.flag("trace").unwrap_or("solar") {
        "solar" => ResourceTrace::solar_day(steps),
        "discharge" => ResourceTrace::discharge(1.0, 0.0, steps),
        other => bail!("--trace must be solar|discharge, got {other}"),
    };
    let mut c = Coordinator::new(root, arch, n, h)?;
    let report = c.run_trace(trace, SwitchPolicy::default(), reqs)?;
    println!(
        "trace: {} steps, {} switches; full-bit acc {:.3} over {} reqs, part-bit acc {:.3} over {} reqs",
        report.steps,
        report.switches.len(),
        report.full_acc(),
        report.full_served,
        report.part_acc(),
        report.part_served
    );
    for s in &report.switches {
        println!(
            "  step {:>3} level {:.2} → {:?}: page-in {:.2}MB page-out {:.2}MB in {:.1}ms",
            s.step,
            s.level,
            s.to,
            s.cost.page_in_bytes as f64 / 1e6,
            s.cost.page_out_bytes as f64 / 1e6,
            s.cost.micros as f64 / 1e3
        );
    }
    println!("{}", c.metrics.summary());
    Ok(())
}

fn cmd_serve(root: &std::path::Path, args: &Args) -> Result<()> {
    if args.flag("store").is_some() {
        return cmd_serve_store(args);
    }
    let arch = args.req("arch")?;
    let n: u8 = args.num("n", 8)?;
    let h: u8 = args.num("h", 4)?;
    let mut c = Coordinator::new(root, arch, n, h)?;
    c.manager.load_full_bit(&mut c.ledger)?;
    let coord = std::sync::Arc::new(std::sync::Mutex::new(c));
    let handle = server::serve(coord, server::ServerConfig::default())?;
    println!("serving {arch} INT({n}|{h}) full-bit on {}", handle.addr);
    println!("(send a Control frame named \"stop\" to shut down; Ctrl-C also works)");
    wait_until_stopped(handle)
}

/// Multi-tenant mode: host every nest `.nq` in a directory from one
/// shared `ModelStore`, all tenants paging Section B through one RAM
/// budget. Clients route by model id (`infer` frames are id-tagged; the
/// `models` command lists what is hosted).
fn cmd_serve_store(args: &Args) -> Result<()> {
    use nestquant::coordinator::server::{serve_tenants, ServerConfig, TenantExecutor};
    use nestquant::coordinator::tenant::nest_tenants_from_dir;
    use nestquant::store::{ModelStore, StoreBudget};

    let dir = std::path::PathBuf::from(args.req("store")?);
    let budget_mb: u64 = args.num("budget-mb", 64)?;
    let batch: usize = args.num("batch", 4)?;
    let synth: usize = args.num("synth", 0)?;
    if synth > 0 {
        // seed the dir with synthetic nest containers: the CI telemetry
        // scrape (and quick local demos) need a store without artifacts
        let zoo = nestquant::fleet::synthetic_zoo(&dir, synth, 0xF1EE7)?;
        println!("seeded {} synthetic INT(8|4) containers into {}", zoo.len(), dir.display());
    }
    let store = ModelStore::new();
    let budget = std::sync::Arc::new(StoreBudget::new(budget_mb << 20));
    let tenants = nest_tenants_from_dir(&dir, &store, &budget, batch)?;
    anyhow::ensure!(
        !tenants.is_empty(),
        "no nest .nq artifacts found in {}",
        dir.display()
    );
    for (id, t) in &tenants {
        let (b, img, classes) = t.shape();
        println!(
            "  {id:<24} batch {b}  image_len {img:>6}  classes {classes:>4}  sections {:>8}/{:<8} B",
            t.archive().section_a_bytes(),
            t.archive().section_b_bytes()
        );
    }
    let n = tenants.len();
    let boxed: Vec<(String, Box<dyn TenantExecutor>)> = tenants
        .into_iter()
        .map(|(id, t)| (id, Box::new(t) as Box<dyn TenantExecutor>))
        .collect();
    let handle = serve_tenants(boxed, ServerConfig::default())?;
    let armed = nestquant::faults::armed_sites();
    if !armed.is_empty() {
        println!("fault injection armed (NQ_FAULTS): {}", armed.join(", "));
    }
    println!(
        "serving {n} models from {} on {} (Section-B budget {budget_mb} MiB)",
        dir.display(),
        handle.addr
    );
    println!("(send a Control frame named \"stop\" to shut down; Ctrl-C also works)");
    wait_until_stopped(handle)
}

/// Block until a client's `stop` frame lands, then join every thread.
fn wait_until_stopped(handle: server::ServerHandle) -> Result<()> {
    while !handle.stopped() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    handle.stop();
    Ok(())
}

/// Fleet-distribution simulation: start a `fleet::FleetServer` over the
/// artifact zoo (or a synthetic zoo when `make artifacts` hasn't run),
/// drive a heterogeneous device fleet through phase-shifted resource
/// traces, and demonstrate a killed Section-B transfer resuming from the
/// last acked chunk. Everything printed is *measured wire traffic*.
fn cmd_fleet(root: &std::path::Path, args: &Args) -> Result<()> {
    use nestquant::device::MemoryLedger;
    use nestquant::fleet::{FleetClient, FleetConfig, FleetServer, PlaybackReport, Zoo};

    let devices: usize = args.num("devices", 6)?;
    let steps: usize = args.num("steps", 32)?;
    let budget_mb: u64 = args.num("budget-mb", 64)?;
    let chunk_kb: usize = args.num("chunk-kb", 64)?;
    let n_models: usize = args.num("models", 3)?;

    // zoo: real artifacts when built, synthetic containers otherwise
    let mut zoo = Zoo::new();
    let nq_dir = root.join("nq");
    if nq_dir.is_dir() && zoo.scan_nest_dir(&nq_dir).unwrap_or(0) > 0 {
        println!("fleet: serving {} artifact nest containers from {}", zoo.len(), nq_dir.display());
    }
    if zoo.is_empty() {
        let dir = std::env::temp_dir().join(format!("nq_fleet_zoo_{}", std::process::id()));
        zoo = nestquant::fleet::synthetic_zoo(&dir, n_models, 0xF1EE7)?;
        println!("fleet: no artifacts found; serving {} synthetic INT(8|4) containers", zoo.len());
    }
    let model_ids: Vec<String> = zoo.ids().map(str::to_string).collect();

    let config = FleetConfig {
        chunk_bytes: chunk_kb.max(1) << 10,
        cache_budget_bytes: budget_mb << 20,
        ..FleetConfig::default()
    };
    let handle = FleetServer::start(zoo, config)?;
    println!(
        "fleet: server on {} (chunk {} KiB, cache budget {} MiB)\n",
        handle.addr, chunk_kb, budget_mb
    );

    // fleet playback: each device follows its own resource trace
    let traces = ResourceTrace::fleet(devices, steps, 0x5eed);
    let mut joins = Vec::new();
    for (d, trace) in traces.into_iter().enumerate() {
        let addr = handle.addr;
        let model = model_ids[d % model_ids.len()].clone();
        joins.push(std::thread::spawn(move || -> Result<(String, PlaybackReport, u64, u64)> {
            let mut client = FleetClient::connect(
                addr,
                &format!("dev-{d:02}"),
                std::time::Duration::from_secs(30),
            )?;
            let mut ledger = MemoryLedger::new(4 << 30);
            let report = client.playback(&model, trace, &mut ledger)?;
            let (sent, received) = client.wire();
            Ok((model, report, sent, received))
        }));
    }
    let mut dev_received = 0u64;
    let mut dev_sent = 0u64;
    for (d, j) in joins.into_iter().enumerate() {
        let (model, r, sent, received) = j.join().unwrap()?;
        dev_sent += sent;
        dev_received += received;
        println!(
            "  dev-{d:02} {model:<12} steps {:>3}  up {}  down {}  pulled {:>8.2} KB  final {:?}",
            r.steps,
            r.upgrades,
            r.downgrades,
            r.payload_pulled as f64 / 1e3,
            r.final_variant
        );
    }

    // resume demo: kill a Section-B pull mid-flight, reconnect, resume
    let model = model_ids[0].clone();
    println!("\nfleet: killing a Section-B transfer mid-flight, then resuming…");
    let demo = nestquant::fleet::demo_kill_resume(
        handle.addr,
        "dev-resume",
        &model,
        2,
        std::time::Duration::from_secs(30),
    )?;
    if demo.killed.completed {
        println!("  (section B fits in ≤2 chunks here; nothing to resume)");
    }
    println!(
        "  killed after {} chunks ({} / {} bytes acked)",
        demo.killed.chunks, demo.killed.received_to, demo.killed.total_len
    );
    println!(
        "  resumed at byte {} → completed with {} more bytes ({} saved vs restart)",
        demo.resume_from, demo.resumed.payload_bytes, demo.resume_from
    );
    dev_sent += demo.wire.0;
    dev_received += demo.wire.1;

    // stop first (joins every handler thread) so accounting is exact
    let cache = std::sync::Arc::clone(&handle.cache);
    let sessions = std::sync::Arc::clone(&handle.sessions);
    let meter = std::sync::Arc::clone(&handle.meter);
    let latency = std::sync::Arc::clone(&handle.xfer_latency);
    handle.stop();
    let stats = cache.stats();
    let summaries = sessions.summaries();
    let (srv_sent, srv_received) = meter.snapshot();
    println!("\nfleet: cache  hits {} misses {} evictions {} disk {:.2} KB resident {:.2} KB",
        stats.hits, stats.misses, stats.evictions,
        stats.disk_bytes as f64 / 1e3, stats.used_bytes as f64 / 1e3);
    let resent: u64 = summaries.iter().map(|s| s.bytes_resent).sum();
    println!(
        "fleet: wire  server sent {:.2} KB / received {:.2} KB; devices sent {:.2} KB / received {:.2} KB; resent {:.2} KB",
        srv_sent as f64 / 1e3,
        srv_received as f64 / 1e3,
        dev_sent as f64 / 1e3,
        dev_received as f64 / 1e3,
        resent as f64 / 1e3
    );
    println!(
        "fleet: xfers {} completed, latency mean {:.0}us p99 {}us max {}us",
        latency.count(),
        latency.mean_us(),
        latency.quantile_us(0.99),
        latency.max_us()
    );
    Ok(())
}

/// `nestquant loadgen`: open-loop synthetic fleet load against a live
/// server (`--addr`), or against a fleet server booted in-process over a
/// store directory (`--store`, optionally seeded with `--synth N`
/// synthetic containers first). Replays a deterministic seeded schedule
/// (Poisson steady state, cold-start waves, bitwidth-switch storms,
/// Zipf-tailed model popularity) through the real `FleetClient` wire
/// protocol and writes the schema-versioned `BENCH_load.json` that
/// `bench-guard --load` gates on.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use nestquant::fleet::{FleetConfig, FleetServer, Zoo};
    use nestquant::loadgen::{self, LoadgenConfig};
    use nestquant::util::json;

    let defaults = LoadgenConfig::default();
    let cfg = LoadgenConfig {
        devices: args.num("devices", defaults.devices)?,
        duration: std::time::Duration::from_secs_f64(
            args.num("duration-s", defaults.duration.as_secs_f64())?,
        ),
        rps: args.num("rps", defaults.rps)?,
        seed: args.num("seed", defaults.seed)?,
        zipf_s: args.num("zipf", defaults.zipf_s)?,
        threads: args.num("threads", defaults.threads)?,
        ..defaults
    };
    let out = args.flag("out").unwrap_or("BENCH_load.json");

    // target: an external server, or one booted here over a store dir
    let (addr, local) = if let Some(addr) = args.flag("addr") {
        let addr = addr
            .parse()
            .with_context(|| format!("--addr {addr:?} is not HOST:PORT"))?;
        (addr, None)
    } else if let Some(dir) = args.flag("store") {
        let dir = std::path::PathBuf::from(dir);
        let synth: usize = args.num("synth", 0)?;
        let mut zoo = Zoo::new();
        if synth > 0 {
            zoo = nestquant::fleet::synthetic_zoo(&dir, synth, 0xF1EE7)?;
            println!(
                "loadgen: seeded {} synthetic containers into {}",
                zoo.len(),
                dir.display()
            );
        } else {
            zoo.scan_nest_dir(&dir)?;
        }
        anyhow::ensure!(!zoo.is_empty(), "no nest .nq artifacts in {}", dir.display());
        let handle = FleetServer::start(zoo, FleetConfig::default())?;
        println!("loadgen: booted fleet server on {}", handle.addr);
        (handle.addr, Some(handle))
    } else {
        bail!("loadgen needs --addr HOST:PORT or --store DIR");
    };

    println!(
        "loadgen: {} devices, {:.0} offered rps for {:.0}s (seed {}) against {addr}",
        cfg.devices,
        cfg.rps,
        cfg.duration.as_secs_f64(),
        cfg.seed
    );
    let report = loadgen::run(addr, &cfg)?;
    println!(
        "loadgen: {} requests, {} completed, {} shed — sustained {:.1} rps, {:.2} MB paged",
        report.requests,
        report.completed,
        report.shed,
        report.sustained_rps,
        report.bytes_paged as f64 / 1e6
    );
    println!(
        "loadgen: {} upgrades (switch p50 {}us p99 {}us), evictions {:.2}/s",
        report.switches, report.switch_p50_us, report.switch_p99_us, report.eviction_rate_per_s
    );
    for (sc, cell) in &report.cells {
        println!(
            "  {:<10} {:>6} reqs  {:>6} ok  {:>4} shed  p50 {:>7}us  p99 {:>7}us",
            sc.label(),
            cell.requests,
            cell.completed,
            cell.shed,
            cell.p50_us(),
            cell.p99_us()
        );
    }
    if let Some(s) = &report.server {
        println!(
            "loadgen: server Δ — chunk bytes {:.2} MB, cache evictions {}, rate-limited {}, \
             mapped {:.2} MB, map faults {}",
            s.chunk_bytes_sent as f64 / 1e6,
            s.cache_evictions,
            s.rate_limited,
            s.mapped_bytes as f64 / 1e6,
            s.map_faults
        );
    }
    std::fs::write(out, json::to_string(&report.to_json()))
        .with_context(|| format!("writing {out}"))?;
    println!("loadgen: wrote {out}");
    if let Some(handle) = local {
        handle.stop();
    }
    Ok(())
}

/// Scrape one telemetry snapshot (the `metrics` wire command) from a
/// live server — coordinator and fleet servers answer the same frame.
/// Returns the raw JSON payload.
fn scrape_metrics(addr: &str) -> Result<String> {
    use nestquant::transport::{recv_frame, send_frame, Frame, FrameKind, Meter};

    let addr: std::net::SocketAddr = addr
        .parse()
        .with_context(|| format!("--addr {addr:?} is not HOST:PORT"))?;
    let mut sock = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting {addr}"))?;
    sock.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    let meter = Meter::default();
    send_frame(
        &mut sock,
        &Frame {
            kind: FrameKind::Control,
            name: "metrics".into(),
            payload: Vec::new(),
        },
        &meter,
    )?;
    let (reply, _) = recv_frame(&mut sock, &meter)?;
    anyhow::ensure!(
        reply.name == "metrics",
        "unexpected reply {:?}: {}",
        reply.name,
        String::from_utf8_lossy(&reply.payload)
    );
    String::from_utf8(reply.payload).context("metrics payload")
}

/// `nestquant metrics`: scrape a live server, print the snapshot as JSON
/// (default) or Prometheus text (`--prom`). `--check` validates the
/// Prometheus grammar, `--require a,b` fails on zeroed counters (the CI
/// must-move gate), `--out FILE` writes the JSON sidecar.
fn cmd_metrics(args: &Args) -> Result<()> {
    use nestquant::telemetry::{validate_prometheus, Snapshot};

    let json = scrape_metrics(args.req("addr")?)?;
    let snap = Snapshot::from_json(&json)?;
    if let Some(required) = args.flag("require") {
        let zeroed: Vec<&str> = required
            .split(',')
            .map(str::trim)
            .filter(|n| !n.is_empty())
            .filter(|n| snap.counter(n).unwrap_or(0) == 0)
            .collect();
        anyhow::ensure!(
            zeroed.is_empty(),
            "required counters absent or zero: {}",
            zeroed.join(", ")
        );
    }
    if let Some(path) = args.flag("out") {
        std::fs::write(path, &json).with_context(|| format!("writing {path}"))?;
    }
    if args.flag("prom").is_some() {
        let text = snap.prometheus();
        if args.flag("check").is_some() {
            validate_prometheus(&text).context("prometheus grammar")?;
        }
        print!("{text}");
    } else {
        println!("{json}");
    }
    Ok(())
}

/// `nestquant top`: one-shot human table rendered from the same JSON
/// snapshot the `metrics` command scrapes — identical totals by
/// construction.
fn cmd_top(args: &Args) -> Result<()> {
    use nestquant::telemetry::Snapshot;

    let json = scrape_metrics(args.req("addr")?)?;
    print!("{}", Snapshot::from_json(&json)?.top_table());
    Ok(())
}

/// Adaptive nesting selection (the paper's future-work §5): find the
/// critical nested combination with a handful of part-bit evaluations.
/// `--live` evaluates through PJRT on the built containers; otherwise the
/// pipeline's recorded sweep accuracies are used.
fn cmd_select(root: &std::path::Path, args: &Args) -> Result<()> {
    use nestquant::nest::selector::{select_critical_h, SelectorConfig};
    use nestquant::nest::PAPER_BANDS;
    use nestquant::util::json;

    let arch = args.req("arch")?;
    let n: u8 = args.num("n", 8)?;
    let live = args.flag("live").is_some();
    let acc = json::parse_file(&root.join("report/accuracy.json"))?;
    let nest = acc.path(&[arch, "nest", &n.to_string()])?;
    let full = nest.path(&["full"])?.as_f64()?;
    let sizes = json::parse_file(&root.join("report/sizes.json"))?;
    let fp32 = sizes.path(&[arch, "fp32_bytes"])?.as_f64()? as u64;

    let sel = select_critical_h(n, fp32, PAPER_BANDS, full, SelectorConfig::default(), |h| {
        if live {
            // live part-bit accuracy through the real runtime, when the
            // container for this h was built by the pipeline
            if let Ok(mut c) = Coordinator::new(root, arch, n, h) {
                c.manager.load_part_bit(&mut c.ledger)?;
                let a = c.eval_accuracy(Some(512))?;
                println!("  live eval INT({n}|{h}): part-bit acc {a:.3}");
                return Ok(a);
            }
        }
        let a = nest.path(&["h", &h.to_string(), "part"])?.as_f64()?;
        println!("  sweep  eval INT({n}|{h}): part-bit acc {a:.3}");
        Ok(a)
    })?;
    println!(
        "\n{arch}: Eq-12 prior h={}, selected critical combination: {}  ({} evaluations; full-bit acc {full:.3})",
        sel.prior_h,
        sel.critical_h
            .map(|h| format!("INT({n}|{h})"))
            .unwrap_or_else(|| "none effective".into()),
        sel.evals.len()
    );
    Ok(())
}

fn cmd_report(root: &std::path::Path, args: &Args) -> Result<()> {
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let arch = args.flag("arch").unwrap_or("cnn_m");
    let family = args.flag("family");
    let n: u8 = args.num("n", 8)?;
    let run = |w: &str| -> Result<()> {
        match w {
            "errors" => report::cmd_errors(),
            "storage-ideal" => report::cmd_storage_ideal(),
            "storage" => report::cmd_storage(root, args.flag("n").map(|_| n)),
            "switching" => report::cmd_switching(root),
            "similarity" => report::cmd_similarity(root, arch),
            "nesting-test" => report::cmd_nesting_test(root, arch),
            "nesting" => report::cmd_nesting(root, family, n),
            "cliff" => report::cmd_cliff(root),
            "combos" => report::cmd_combos(root),
            "traffic" => report::cmd_traffic(root, family),
            "comparison" => report::cmd_comparison(root),
            "ptq-cost" => report::cmd_ptq_cost(root),
            "ablations" => report::cmd_ablations(root),
            "hardware" => report::cmd_hardware(),
            "libraries" => report::cmd_libraries(),
            other => bail!("unknown report {other:?}"),
        }
    };
    if what == "all" {
        for w in [
            "hardware", "libraries", "errors", "storage-ideal", "storage", "switching",
            "similarity", "nesting-test", "cliff", "combos", "ptq-cost", "comparison",
            "ablations",
        ] {
            run(w)?;
        }
        report::cmd_nesting(root, Some("cnn"), 8)?;
        report::cmd_nesting(root, Some("cnn"), 6)?;
        report::cmd_nesting(root, Some("mobile"), 8)?;
        report::cmd_nesting(root, Some("vit"), 8)?;
        report::cmd_traffic(root, None)?;
        Ok(())
    } else {
        run(what)
    }
}
