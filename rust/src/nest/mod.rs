//! Integer nesting core (S2): the paper's §3.2/§3.3 bit-level machinery.
//!
//! - decompose / residual / recompose (Eqs. 6–11) with the extra-1-bit
//!   compensation of §3.3.2,
//! - the Table 7 numerical-error enumeration (bit-exact vs the paper),
//! - the Eq. 12 critical-nested-combination rules and Table 8 ideal
//!   storage-reduction arithmetic.

pub mod selector;

use anyhow::{ensure, Result};

use crate::bits::int_range;

/// Rounding method used to derive `w_high` from `w_int / 2^l` (Table 6/7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rounding {
    /// Arithmetic right shift (floor division).
    BitShift,
    /// Round to nearest (ties away from zero, matching numpy's rint on
    /// halves is banker's — we use nearest-even to match `np.round`).
    Rtn,
    /// Always round up (ceil).
    Up,
    /// Always round down == BitShift (kept distinct for Table 7's rows).
    Down,
}

/// A (n|h) nesting configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NestConfig {
    pub n: u8,
    pub h: u8,
}

impl NestConfig {
    pub fn new(n: u8, h: u8) -> Result<Self> {
        ensure!(n >= 2 && n <= 16, "n out of range: {n}");
        ensure!(h >= 1 && h < n, "h must be in [1, n): n={n} h={h}");
        Ok(NestConfig { n, h })
    }

    /// Lower bits l = n - h.
    pub fn l(&self) -> u8 {
        self.n - self.h
    }

    /// Stored low bits (with the 1-bit compensation): l + 1.
    pub fn low_bits(&self) -> u8 {
        self.l() + 1
    }

    /// Scale inflation factor for the part-bit model: 2^l (Eq. 10).
    pub fn scale_inflation(&self) -> f32 {
        (1u32 << self.l()) as f32
    }
}

impl std::fmt::Display for NestConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "INT({}|{})", self.n, self.h)
    }
}

/// Round a real value per `method`, nearest-even for Rtn (numpy semantics).
#[inline]
fn round_by(t: f64, method: Rounding) -> f64 {
    match method {
        Rounding::BitShift | Rounding::Down => t.floor(),
        Rounding::Up => t.ceil(),
        Rounding::Rtn => {
            // round-half-to-even, matching np.round / jnp.round
            let r = t.round();
            if (t - t.trunc()).abs() == 0.5 {
                let f = t.floor();
                if (f as i64) % 2 == 0 {
                    f
                } else {
                    f + 1.0
                }
            } else {
                r
            }
        }
    }
}

/// Derive `w_high` from one INTn value (Eq. 7), clipped to INTh.
#[inline]
pub fn high_of(w_int: i32, cfg: NestConfig, method: Rounding) -> i32 {
    let (lo, hi) = int_range(cfg.h);
    let t = w_int as f64 / (1i64 << cfg.l()) as f64;
    (round_by(t, method) as i32).clamp(lo, hi)
}

/// Residual `w_low` (Eq. 11); clipped to INTl or compensated INT(l+1).
#[inline]
pub fn low_of(w_int: i32, w_high: i32, cfg: NestConfig, compensate: bool) -> i32 {
    let bits = if compensate { cfg.low_bits() } else { cfg.l() };
    let (lo, hi) = int_range(bits);
    (w_int - (w_high << cfg.l())).clamp(lo, hi)
}

/// Recompose (Eq. 6): `w_high * 2^l + w_low`.
#[inline]
pub fn recompose(w_high: i32, w_low: i32, l: u8) -> i32 {
    (w_high << l) + w_low
}

/// Slice-level decomposition: returns (w_high, w_low) vectors.
pub fn decompose(
    w_int: &[i32],
    cfg: NestConfig,
    method: Rounding,
    compensate: bool,
) -> (Vec<i32>, Vec<i32>) {
    let mut hs = Vec::with_capacity(w_int.len());
    let mut ls = Vec::with_capacity(w_int.len());
    for &w in w_int {
        let h = high_of(w, cfg, method);
        hs.push(h);
        ls.push(low_of(w, h, cfg, compensate));
    }
    (hs, ls)
}

/// Slice-level recomposition into a caller buffer (device hot path).
pub fn recompose_into(w_high: &[i32], w_low: &[i32], l: u8, out: &mut Vec<i32>) {
    debug_assert_eq!(w_high.len(), w_low.len());
    out.clear();
    out.reserve(w_high.len());
    for (&h, &lo) in w_high.iter().zip(w_low) {
        out.push(recompose(h, lo, l));
    }
}

// ---------------------------------------------------------------------------
// Table 7: nesting numerical errors over the full signed INTn range
// ---------------------------------------------------------------------------

/// Error statistics for one (method, h) cell of Table 7.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ErrorStats {
    pub non_zero: usize,
    pub min: i32,
    pub max: i32,
}

/// Enumerate decompose→recompose numerical errors WITHOUT compensation for
/// every representable INTn value (Table 7 does n=8: 256 values).
pub fn error_stats(n: u8, h: u8, method: Rounding) -> Result<ErrorStats> {
    let cfg = NestConfig::new(n, h)?;
    let (lo, hi) = int_range(n);
    let mut non_zero = 0usize;
    let mut emin = i32::MAX;
    let mut emax = i32::MIN;
    for w in lo..=hi {
        let wh = high_of(w, cfg, method);
        let wl = low_of(w, wh, cfg, false); // uncompensated (Table 7 setting)
        let err = w - recompose(wh, wl, cfg.l());
        if err != 0 {
            non_zero += 1;
        }
        emin = emin.min(err);
        emax = emax.max(err);
    }
    Ok(ErrorStats {
        non_zero,
        min: emin,
        max: emax,
    })
}

/// §3.3.2 containment check: with compensation, recomposition is exact for
/// every representable INTn value. Returns the number of mismatches (0).
pub fn compensated_mismatches(n: u8, h: u8, method: Rounding) -> Result<usize> {
    let cfg = NestConfig::new(n, h)?;
    let (lo, hi) = int_range(n);
    let mut bad = 0;
    for w in lo..=hi {
        let wh = high_of(w, cfg, method);
        let wl = low_of(w, wh, cfg, true);
        if recompose(wh, wl, cfg.l()) != w {
            bad += 1;
        }
    }
    Ok(bad)
}

// ---------------------------------------------------------------------------
// Eq. 12: critical nested combination from model size
// ---------------------------------------------------------------------------

/// Size-band cutoffs for the Eq. 12 rule. The paper's ImageNet-zoo values
/// are 30 MB / 300 MB; our synthetic zoo re-derives its own axis
/// (report/combos.json) — both are expressible here.
#[derive(Debug, Clone, Copy)]
pub struct SizeBands {
    pub lo_bytes: u64,
    pub hi_bytes: u64,
}

pub const PAPER_BANDS: SizeBands = SizeBands {
    lo_bytes: 30_000_000,
    hi_bytes: 300_000_000,
};

/// Eq. 12: critical nested bit h for full bitwidth `n` and FP32 size.
pub fn eq12_critical_h(fp32_bytes: u64, n: u8, bands: SizeBands) -> u8 {
    if fp32_bytes < bands.lo_bytes {
        n / 2 + 1
    } else if fp32_bytes < bands.hi_bytes {
        n / 2
    } else {
        n / 2 - 1
    }
}

/// Effective nested combinations: every h from the critical one to n-1.
pub fn effective_range(critical: u8, n: u8) -> Vec<u8> {
    (critical..n).collect()
}

// ---------------------------------------------------------------------------
// Table 8: ideal storage reduction
// ---------------------------------------------------------------------------

/// Ideal storage reduction of NestQuant INT(n|h) vs diverse INTn+INTh
/// (weights only, ignoring scales — Table 8's setting):
/// NestQuant stores h + (l+1) bits/elem, diverse stores n + h bits/elem.
pub fn ideal_storage_reduction(n: u8, h: u8) -> f64 {
    let nest = (h + (n - h) + 1) as f64; // == n + 1
    let diverse = (n + h) as f64;
    1.0 - nest / diverse
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::propcheck::check;

    #[test]
    fn display_and_accessors() {
        let cfg = NestConfig::new(8, 5).unwrap();
        assert_eq!(cfg.to_string(), "INT(8|5)");
        assert_eq!(cfg.l(), 3);
        assert_eq!(cfg.low_bits(), 4);
        assert_eq!(cfg.scale_inflation(), 8.0);
        assert!(NestConfig::new(8, 8).is_err());
        assert!(NestConfig::new(8, 0).is_err());
    }

    #[test]
    fn paper_fig9_worked_example() {
        // w_int = -67, INT(8|4), BitShift: w_high=-5, uncompensated w_low=7
        // → recomposed -73 (error 6); compensated w_low=13 → exact.
        let cfg = NestConfig::new(8, 4).unwrap();
        let wh = high_of(-67, cfg, Rounding::BitShift);
        assert_eq!(wh, -5);
        let wl_nc = low_of(-67, wh, cfg, false);
        assert_eq!(wl_nc, 7);
        assert_eq!(recompose(wh, wl_nc, 4), -73);
        let wl_c = low_of(-67, wh, cfg, true);
        assert_eq!(wl_c, 13);
        assert_eq!(recompose(wh, wl_c, 4), -67);
    }

    /// Table 7, bit-exact: #Non-zero and ranges for all methods/columns.
    #[test]
    fn table7_bitshift_row() {
        for (h, range_hi) in [(7, 1), (6, 2), (5, 4), (4, 8), (3, 16)] {
            let s = error_stats(8, h, Rounding::BitShift).unwrap();
            assert_eq!(s.non_zero, 128, "h={h}");
            assert_eq!((s.min, s.max), (0, range_hi), "h={h}");
        }
    }

    #[test]
    fn table7_rtn_row() {
        let expected = [(7, 65, 1), (6, 34, 2), (5, 20, 4), (4, 16, 8), (3, 20, 16)];
        for (h, nz, hi) in expected {
            let s = error_stats(8, h, Rounding::Rtn).unwrap();
            assert_eq!(s.non_zero, nz, "h={h}");
            assert_eq!((s.min, s.max), (0, hi), "h={h}");
        }
    }

    #[test]
    fn table7_rounding_up_row() {
        let expected = [
            (7, 1, 0, 1),
            (6, 65, -1, 2),
            (5, 97, -3, 4),
            (4, 113, -7, 8),
            (3, 121, -15, 16),
        ];
        for (h, nz, lo, hi) in expected {
            let s = error_stats(8, h, Rounding::Up).unwrap();
            assert_eq!((s.min, s.max), (lo, hi), "h={h}");
            assert_eq!(s.non_zero, nz, "h={h}");
        }
    }

    #[test]
    fn table7_rounding_down_row() {
        for (h, hi) in [(7, 1), (6, 2), (5, 4), (4, 8), (3, 16)] {
            let s = error_stats(8, h, Rounding::Down).unwrap();
            assert_eq!(s.non_zero, 128, "h={h}");
            assert_eq!((s.min, s.max), (0, hi), "h={h}");
        }
    }

    /// §3.3.2: errors always lie within [-2^{l-1}+1, 2^{l-1}] so the
    /// compensated range is sufficient — for every method and h.
    #[test]
    fn error_range_containment_all_methods() {
        for method in [Rounding::BitShift, Rounding::Rtn, Rounding::Up, Rounding::Down] {
            for h in 2..8u8 {
                let l = 8 - h;
                let s = error_stats(8, h, method).unwrap();
                let bound = 1 << (l - 1).max(0);
                assert!(s.min >= -bound + 1 && s.max <= bound, "{method:?} h={h} {s:?}");
            }
        }
    }

    #[test]
    fn compensation_is_lossless_everywhere() {
        for method in [Rounding::BitShift, Rounding::Rtn, Rounding::Up, Rounding::Down] {
            for n in [6u8, 8] {
                for h in 2..n {
                    assert_eq!(
                        compensated_mismatches(n, h, method).unwrap(),
                        0,
                        "{method:?} INT({n}|{h})"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_decompose_recompose_roundtrip() {
        check(
            "nest-roundtrip",
            200,
            |r: &mut Rng, _| {
                let n = *[6u8, 8].get(r.index(2)).unwrap();
                let h = 2 + r.index((n - 2) as usize) as u8;
                let (lo, hi) = int_range(n);
                let vals: Vec<i32> = (0..r.index(500) + 1)
                    .map(|_| r.int(lo as i64, hi as i64) as i32)
                    .collect();
                (n, h, vals)
            },
            |(n, h, vals)| {
                let cfg = NestConfig::new(*n, *h).unwrap();
                for method in [Rounding::BitShift, Rounding::Rtn, Rounding::Up] {
                    let (hs, ls) = decompose(vals, cfg, method, true);
                    let mut rec = Vec::new();
                    recompose_into(&hs, &ls, cfg.l(), &mut rec);
                    if rec != *vals {
                        return false;
                    }
                    // ranges respected
                    let (hlo, hhi) = int_range(*h);
                    let (llo, lhi) = int_range(cfg.low_bits());
                    if !hs.iter().all(|&v| v >= hlo && v <= hhi) {
                        return false;
                    }
                    if !ls.iter().all(|&v| v >= llo && v <= lhi) {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn eq12_bands() {
        assert_eq!(eq12_critical_h(10_000_000, 8, PAPER_BANDS), 5);
        assert_eq!(eq12_critical_h(100_000_000, 8, PAPER_BANDS), 4);
        assert_eq!(eq12_critical_h(400_000_000, 8, PAPER_BANDS), 3);
        assert_eq!(eq12_critical_h(10_000_000, 6, PAPER_BANDS), 4);
        assert_eq!(effective_range(4, 8), vec![4, 5, 6, 7]);
    }

    /// Table 8, exact: 25/31/36/40/30/36 percent.
    #[test]
    fn table8_ideal_storage_reduction() {
        let cases = [
            (8, 4, 0.25),
            (8, 5, 0.3076923076923077),
            (8, 6, 0.35714285714285715),
            (8, 7, 0.4),
            (6, 4, 0.3),
            (6, 5, 0.36363636363636365),
        ];
        for (n, h, want) in cases {
            let got = ideal_storage_reduction(n, h);
            assert!((got - want).abs() < 1e-12, "INT({n}|{h}): {got}");
        }
    }

    #[test]
    fn rtn_is_nearest_even_like_numpy() {
        // np.round(0.5)=0, np.round(1.5)=2, np.round(-0.5)=-0, np.round(2.5)=2
        assert_eq!(round_by(0.5, Rounding::Rtn), 0.0);
        assert_eq!(round_by(1.5, Rounding::Rtn), 2.0);
        assert_eq!(round_by(-0.5, Rounding::Rtn), 0.0);
        assert_eq!(round_by(2.5, Rounding::Rtn), 2.0);
        assert_eq!(round_by(-2.5, Rounding::Rtn), -2.0);
        assert_eq!(round_by(0.4999, Rounding::Rtn), 0.0);
    }
}
