//! Adaptive nesting selection — the paper's future-work feature (§5):
//! "explore the adaptive nesting selection scheme for finding the optimal
//! NestQuant combinations automatically."
//!
//! Implements the practical search of §4.2.2: start from the Eq. 12 prior
//! (h = n/2 ± 1 by model size), evaluate the part-bit model, then walk
//! down while the accuracy stays effective or up until it becomes
//! effective — converging on the *critical nested combination* (the
//! smallest effective h) with a handful of evaluations instead of a full
//! sweep.

use anyhow::{ensure, Result};

use super::{eq12_critical_h, SizeBands};

/// Selection policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct SelectorConfig {
    /// Part-bit accuracy must be ≥ this fraction of full-bit accuracy.
    pub effective_fraction: f64,
    /// Evaluation budget (each eval = one part-bit accuracy measurement).
    pub max_evals: usize,
}

impl Default for SelectorConfig {
    fn default() -> Self {
        SelectorConfig {
            effective_fraction: 0.6,
            max_evals: 6,
        }
    }
}

/// The search outcome.
#[derive(Debug, Clone)]
pub struct Selection {
    /// The critical nested bit (smallest effective h), if any h works.
    pub critical_h: Option<u8>,
    /// Every (h, part_acc) the search evaluated, in order.
    pub evals: Vec<(u8, f64)>,
    /// Where the search started (the Eq. 12 prior).
    pub prior_h: u8,
}

/// Find the critical nested combination for an INTn model of the given
/// FP32 size, calling `eval(h) -> part-bit accuracy` as needed.
pub fn select_critical_h<F>(
    n: u8,
    fp32_bytes: u64,
    bands: SizeBands,
    full_acc: f64,
    cfg: SelectorConfig,
    mut eval: F,
) -> Result<Selection>
where
    F: FnMut(u8) -> Result<f64>,
{
    ensure!(n >= 4, "n too small to nest usefully");
    ensure!(full_acc > 0.0, "full-bit accuracy must be positive");
    let threshold = cfg.effective_fraction * full_acc;
    let prior = eq12_critical_h(fp32_bytes, n, bands).clamp(2, n - 1);

    let mut evals: Vec<(u8, f64)> = Vec::new();
    let cached = |h: u8, evals: &mut Vec<(u8, f64)>, eval: &mut F| -> Result<f64> {
        if let Some(&(_, a)) = evals.iter().find(|&&(eh, _)| eh == h) {
            return Ok(a);
        }
        let a = eval(h)?;
        evals.push((h, a));
        Ok(a)
    };

    let mut h = prior;
    let mut best: Option<u8> = None;
    while evals.len() < cfg.max_evals {
        let acc = cached(h, &mut evals, &mut eval)?;
        if acc >= threshold {
            best = Some(h);
            if h == 2 {
                break; // cannot go lower
            }
            // §4.2.2: search downwards for a smaller effective h
            h -= 1;
        } else {
            // below the cliff: search upwards
            if best.is_some() {
                break; // we already know the boundary: best is critical
            }
            if h >= n - 1 {
                break; // nothing effective at all
            }
            h += 1;
        }
    }
    Ok(Selection {
        critical_h: best,
        evals,
        prior_h: prior,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nest::PAPER_BANDS;

    /// A synthetic accuracy curve with a cliff below `cliff_h`.
    fn curve(cliff_h: u8) -> impl Fn(u8) -> Result<f64> {
        move |h| {
            Ok(if h >= cliff_h {
                0.70 - 0.005 * (8 - h) as f64
            } else {
                0.10
            })
        }
    }

    #[test]
    fn finds_critical_from_prior_above() {
        // 100MB model → prior h=4; cliff at 4 → critical is 4
        let sel = select_critical_h(
            8,
            100_000_000,
            PAPER_BANDS,
            0.71,
            SelectorConfig::default(),
            curve(4),
        )
        .unwrap();
        assert_eq!(sel.prior_h, 4);
        assert_eq!(sel.critical_h, Some(4));
        assert!(sel.evals.len() <= 3, "{:?}", sel.evals);
    }

    #[test]
    fn walks_up_when_prior_is_below_cliff() {
        // large model → prior h=3 but the cliff is at 5
        let sel = select_critical_h(
            8,
            400_000_000,
            PAPER_BANDS,
            0.71,
            SelectorConfig::default(),
            curve(5),
        )
        .unwrap();
        assert_eq!(sel.prior_h, 3);
        assert_eq!(sel.critical_h, Some(5));
    }

    #[test]
    fn walks_down_to_smallest_effective() {
        // small model → prior h=5, cliff at 3 → must walk down to 3
        let sel = select_critical_h(
            8,
            10_000_000,
            PAPER_BANDS,
            0.71,
            SelectorConfig {
                max_evals: 8,
                ..Default::default()
            },
            curve(3),
        )
        .unwrap();
        assert_eq!(sel.prior_h, 5);
        assert_eq!(sel.critical_h, Some(3));
    }

    #[test]
    fn no_effective_combination() {
        let sel = select_critical_h(
            8,
            10_000_000,
            PAPER_BANDS,
            0.71,
            SelectorConfig::default(),
            |_| Ok(0.01),
        )
        .unwrap();
        assert_eq!(sel.critical_h, None);
    }

    #[test]
    fn respects_eval_budget() {
        let mut calls = 0;
        let _ = select_critical_h(
            8,
            10_000_000,
            PAPER_BANDS,
            0.71,
            SelectorConfig {
                max_evals: 3,
                ..Default::default()
            },
            |h| {
                calls += 1;
                curve(2)(h)
            },
        )
        .unwrap();
        assert!(calls <= 3);
    }

    #[test]
    fn never_reevaluates_same_h() {
        let mut seen = std::collections::HashSet::new();
        let _ = select_critical_h(
            8,
            100_000_000,
            PAPER_BANDS,
            0.71,
            SelectorConfig {
                max_evals: 10,
                ..Default::default()
            },
            |h| {
                assert!(seen.insert(h), "h={h} evaluated twice");
                curve(4)(h)
            },
        )
        .unwrap();
    }

    #[test]
    fn errors_propagate() {
        let r = select_critical_h(
            8,
            100_000_000,
            PAPER_BANDS,
            0.71,
            SelectorConfig::default(),
            |_| anyhow::bail!("eval backend down"),
        );
        assert!(r.is_err());
    }
}
