//! Quantizer substrate (S3): symmetric linear quantization, RTN, and the
//! SQuant-style data-free adaptive rounding — the Rust port of
//! `python/compile/quantizer.py`.
//!
//! The paper's deployment story (§2.3, Table 1) is that IoT devices
//! cannot run Hessian-based PTQ. This Rust port exists to (a) quantify
//! exactly that claim on-device (Table 1 bench re-measures it), (b) let
//! the device *re-quantize* downloads when asked (fleet_ota example), and
//! (c) cross-validate the Python pipeline bit-for-bit.

use anyhow::{ensure, Result};

use crate::bits::int_range;
use crate::nest::{self, NestConfig, Rounding};

/// Per-output-channel symmetric scales over the last axis (Eq. 2).
/// `w` is row-major with the channel as the fastest-varying dimension.
pub fn channel_scales(w: &[f32], channels: usize, bits: u8) -> Result<Vec<f32>> {
    ensure!(channels > 0 && w.len() % channels == 0, "bad channel count");
    let (_, hi) = int_range(bits);
    let mut amax = vec![0f32; channels];
    for row in w.chunks_exact(channels) {
        for (a, &v) in amax.iter_mut().zip(row) {
            *a = a.max(v.abs());
        }
    }
    Ok(amax
        .into_iter()
        .map(|a| a.max(1e-12) / hi as f32)
        .collect())
}

/// Round-to-nearest-even (numpy semantics, matching the Python pipeline).
#[inline]
fn rtn(t: f64) -> f64 {
    if (t - t.trunc()).abs() == 0.5 {
        let f = t.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    } else {
        t.round()
    }
}

/// RTN quantization with per-channel scales. Iterates channel-sized row
/// chunks — the channel index is the position in the chunk, so the hot
/// loop carries no per-element `i % c` division. The length must be a
/// whole number of rows: a trailing partial row used to be silently
/// quantized against a scale prefix (mis-scaled), now it is rejected.
pub fn quantize_rtn(w: &[f32], scales: &[f32], bits: u8) -> Vec<i32> {
    let (lo, hi) = int_range(bits);
    let mut out = Vec::with_capacity(w.len());
    if w.is_empty() {
        return out;
    }
    assert!(
        !scales.is_empty() && w.len() % scales.len() == 0,
        "quantize_rtn: {} values not a multiple of {} channels",
        w.len(),
        scales.len()
    );
    for row in w.chunks(scales.len()) {
        for (&v, &s) in row.iter().zip(scales) {
            out.push((rtn((v / s) as f64) as i32).clamp(lo, hi));
        }
    }
    out
}

/// SQuant-style flip-based adaptive rounding (diagonal-Hessian objective):
/// start from RTN, then per channel flip the elements with the largest
/// fractional residues so the accumulated channel error lands within ±0.5.
/// Mirrors `quantizer._flip_round` element-for-element.
pub fn quantize_adaptive(w: &[f32], scales: &[f32], bits: u8) -> Vec<i32> {
    let c = scales.len();
    assert!(
        c > 0 && w.len() % c == 0,
        "quantize_adaptive: {} values not a multiple of {c} channels",
        w.len()
    );
    let rows = w.len() / c;
    let (lo, hi) = int_range(bits);
    let mut out = vec![0i32; w.len()];
    // per-channel scratch: (frac, row_index)
    let mut frac = vec![0f64; rows];
    let mut base = vec![0f64; rows];
    let mut order: Vec<usize> = Vec::with_capacity(rows);
    for ch in 0..c {
        let mut err = 0f64;
        for r in 0..rows {
            let t = (w[r * c + ch] / scales[ch]) as f64;
            let b = rtn(t);
            base[r] = b;
            frac[r] = t - b;
            err += frac[r];
        }
        let k = rtn(err) as i64;
        if k != 0 {
            // O(n) selection of the k most-flippable residues instead of a
            // full argsort (§Perf L3: 5x on the PTQ path). The flip set is
            // identical to the sorted version except for exact frac ties,
            // where any choice is equally optimal for the channel sum.
            order.clear();
            order.extend(0..rows);
            let kk = (k.unsigned_abs() as usize).min(rows);
            if k > 0 {
                if kk < rows {
                    order.select_nth_unstable_by(kk - 1, |&a, &b| {
                        frac[b].partial_cmp(&frac[a]).unwrap()
                    });
                }
                for &r in order.iter().take(kk) {
                    base[r] += 1.0;
                }
            } else {
                if kk < rows {
                    order.select_nth_unstable_by(kk - 1, |&a, &b| {
                        frac[a].partial_cmp(&frac[b]).unwrap()
                    });
                }
                for &r in order.iter().take(kk) {
                    base[r] -= 1.0;
                }
            }
        }
        for r in 0..rows {
            out[r * c + ch] = (base[r] as i32).clamp(lo, hi);
        }
    }
    out
}

/// Dequantize: `ŵ = s · w_int` with per-channel scales (Eq. 3).
/// Channel-sized row chunks instead of a per-element `i % c` (the
/// remaining non-fused callers — fleet re-quantize, report tables —
/// keep this path hot; the switch path uses `crate::kernels`).
pub fn dequant(w_int: &[i32], scales: &[f32], out: &mut Vec<f32>) {
    out.clear();
    if w_int.is_empty() {
        return;
    }
    assert!(
        !scales.is_empty() && w_int.len() % scales.len() == 0,
        "dequant: {} values not a multiple of {} channels",
        w_int.len(),
        scales.len()
    );
    out.reserve(w_int.len());
    for row in w_int.chunks(scales.len()) {
        out.extend(row.iter().zip(scales).map(|(&v, &s)| v as f32 * s));
    }
}

/// Per-tensor symmetric activation quantization for the integer-domain
/// forward: `out[i] = clamp(rtn(x[i] / s_x))` with one dynamic scale
/// `s_x = amax / hi` (floored like [`channel_scales`] so an all-zero
/// input stays finite). Returns `s_x`; the caller folds it into the
/// accumulator epilogue together with the weight scales. RTN matches
/// the weight path's rounding so the error model is uniform.
pub fn quantize_activations(x: &[f32], bits: u8, out: &mut Vec<i32>) -> f32 {
    let (lo, hi) = int_range(bits);
    let amax = x.iter().fold(0f32, |a, &v| a.max(v.abs()));
    let s = amax.max(1e-12) / hi as f32;
    out.clear();
    out.reserve(x.len());
    out.extend(
        x.iter()
            .map(|&v| (rtn((v / s) as f64) as i32).clamp(lo, hi)),
    );
    s
}

/// Secondary (nesting) quantization — Step 2 of Algorithm 1: derive
/// `w_high` from `w_int / 2^l` per the chosen rounding, using the flip
/// algorithm for `Adaptive` (per-channel error cancellation on the
/// integer targets).
pub fn nest_high(
    w_int: &[i32],
    channels: usize,
    cfg: NestConfig,
    method: NestMethod,
) -> Vec<i32> {
    match method {
        NestMethod::BitShift => w_int
            .iter()
            .map(|&v| nest::high_of(v, cfg, Rounding::BitShift))
            .collect(),
        NestMethod::Rtn => w_int
            .iter()
            .map(|&v| nest::high_of(v, cfg, Rounding::Rtn))
            .collect(),
        NestMethod::Adaptive => {
            let scale = (1u32 << cfg.l()) as f32;
            let t: Vec<f32> = w_int.iter().map(|&v| v as f32).collect();
            let scales = vec![scale; channels];
            quantize_adaptive(&t, &scales, cfg.h)
        }
    }
}

/// Rounding method for the secondary quantization (Table 6's rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NestMethod {
    BitShift,
    Rtn,
    Adaptive,
}

impl std::str::FromStr for NestMethod {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "bitshift" => Ok(NestMethod::BitShift),
            "rtn" => Ok(NestMethod::Rtn),
            "adaptive" => Ok(NestMethod::Adaptive),
            _ => anyhow::bail!("unknown nesting method {s:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::propcheck::check;

    fn toy(seed: u64, rows: usize, c: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..rows * c).map(|_| (r.normal() * 0.5) as f32).collect()
    }

    #[test]
    fn scales_cover_range() {
        let w = toy(0, 64, 8);
        let s = channel_scales(&w, 8, 8).unwrap();
        assert_eq!(s.len(), 8);
        for (i, &v) in w.iter().enumerate() {
            assert!((v / s[i % 8]).abs() <= 127.0 + 1e-3);
        }
    }

    #[test]
    fn rtn_error_bound() {
        let w = toy(1, 128, 4);
        let s = channel_scales(&w, 4, 8).unwrap();
        let wi = quantize_rtn(&w, &s, 8);
        for (i, (&v, &q)) in w.iter().zip(&wi).enumerate() {
            let err = (v - q as f32 * s[i % 4]).abs();
            assert!(err <= s[i % 4] / 2.0 + 1e-6);
        }
    }

    #[test]
    fn adaptive_is_up_or_down() {
        let w = toy(2, 200, 8);
        let s = channel_scales(&w, 8, 8).unwrap();
        let wi = quantize_adaptive(&w, &s, 8);
        for (i, (&v, &q)) in w.iter().zip(&wi).enumerate() {
            let t = (v / s[i % 8]) as f64;
            assert!(
                (q as f64 - t.floor()).abs() < 1e-9 || (q as f64 - t.ceil()).abs() < 1e-9,
                "i={i} t={t} q={q}"
            );
        }
    }

    #[test]
    fn adaptive_channel_error_cancellation() {
        let w = toy(3, 512, 16);
        let s = channel_scales(&w, 16, 8).unwrap();
        let wi = quantize_adaptive(&w, &s, 8);
        for ch in 0..16 {
            let e: f64 = (0..512)
                .map(|r| (w[r * 16 + ch] / s[ch]) as f64 - wi[r * 16 + ch] as f64)
                .sum();
            assert!(e.abs() <= 1.5, "channel {ch}: {e}");
        }
    }

    #[test]
    fn adaptive_beats_rtn_on_channel_error() {
        let w = toy(4, 1024, 4);
        let s = channel_scales(&w, 4, 8).unwrap();
        let ad = quantize_adaptive(&w, &s, 8);
        let rt = quantize_rtn(&w, &s, 8);
        let err = |wi: &[i32]| -> f64 {
            (0..4)
                .map(|ch| {
                    (0..1024)
                        .map(|r| (w[r * 4 + ch] / s[ch]) as f64 - wi[r * 4 + ch] as f64)
                        .sum::<f64>()
                        .abs()
                })
                .sum()
        };
        assert!(err(&ad) <= err(&rt) + 1e-9);
    }

    #[test]
    fn prop_nest_high_in_range_and_recompose_exact() {
        check(
            "quant-nest-high",
            100,
            |r: &mut Rng, _| {
                let n = if r.bool() { 8u8 } else { 6 };
                let h = 2 + r.index((n - 2) as usize) as u8;
                let (lo, hi) = int_range(n);
                let vals: Vec<i32> = (0..r.index(300) + 8)
                    .map(|_| r.int(lo as i64, hi as i64) as i32)
                    .collect();
                (n, h, vals)
            },
            |(n, h, vals)| {
                let cfg = NestConfig::new(*n, *h).unwrap();
                for m in [NestMethod::BitShift, NestMethod::Rtn, NestMethod::Adaptive] {
                    let wh = nest_high(vals, 1, cfg, m);
                    let (hlo, hhi) = int_range(*h);
                    if !wh.iter().all(|&v| v >= hlo && v <= hhi) {
                        return false;
                    }
                    // compensated residual always recomposes exactly
                    for (&w, &hval) in vals.iter().zip(&wh) {
                        let lo_v = nest::low_of(w, hval, cfg, true);
                        if nest::recompose(hval, lo_v, cfg.l()) != w {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }

    #[test]
    fn dequant_matches_definition() {
        let wi = vec![-128, 0, 127, 5];
        let s = vec![0.01f32, 0.02];
        let mut out = Vec::new();
        dequant(&wi, &s, &mut out);
        for (got, want) in out.iter().zip([-1.28f32, 0.0, 1.27, 0.1]) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    // channel-count validation (satellite bugfix): a trailing partial
    // row used to be silently mis-scaled against a scale prefix

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn quantize_rtn_rejects_partial_row() {
        quantize_rtn(&[1.0, 2.0, 3.0], &[0.5, 0.25], 8);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn dequant_rejects_partial_row() {
        let mut out = Vec::new();
        dequant(&[1, 2, 3], &[0.5, 0.25], &mut out);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn quantize_adaptive_rejects_partial_row() {
        quantize_adaptive(&[1.0, 2.0, 3.0], &[0.5, 0.25], 8);
    }

    #[test]
    fn activation_quant_bound_and_zero_input() {
        let x: Vec<f32> = toy(7, 16, 4);
        let mut q = Vec::new();
        let s = quantize_activations(&x, 8, &mut q);
        assert_eq!(q.len(), x.len());
        for (&v, &qi) in x.iter().zip(&q) {
            assert!((v - qi as f32 * s).abs() <= s / 2.0 + 1e-6);
            assert!((-128..=127).contains(&qi));
        }
        // all-zero input: finite scale, all-zero codes
        let s0 = quantize_activations(&[0.0; 8], 8, &mut q);
        assert!(s0 > 0.0 && s0.is_finite());
        assert!(q.iter().all(|&v| v == 0));
    }
}
