//! Reactor (S14): a dependency-free readiness-driven serving core —
//! sessions are **state, not threads**.
//!
//! One loop thread owns every accepted socket: raw `epoll` on Linux
//! (a `poll(2)` sweep elsewhere on unix) reports readiness, connections
//! advance explicit state machines over the incremental
//! [`transport::FrameReader`]/[`transport::FrameWriter`] codec, and CPU
//! work runs on a shared worker pool fed through the
//! [`queue::FairScheduler`] (strict class priority, DRR tenant
//! fairness). Both TCP servers — the coordinator inference router and
//! the fleet distribution server — are [`Service`] implementations on
//! this loop, so 10k+ devices cost buffers and slab slots, not OS
//! threads.
//!
//! ## Connection state machine
//!
//! ```text
//!          accept            frame decoded        service op
//!  (slab insert, EPOLLIN) ──► on_frame(..) ──► Send / Pause / Close /
//!            ▲                    │              Deadline / Stop
//!            │                    ▼
//!   level-triggered readiness; a Paused conn drops read interest
//!   (backpressure) and keeps already-buffered bytes until Resume.
//! ```
//!
//! Replies queue into the conn's `FrameWriter` and flush as far as the
//! socket allows; write interest is registered only while bytes remain,
//! and a frame hits the byte meter exactly when its last byte leaves.
//!
//! ## Shutdown drain ordering
//!
//! 1. [`Remote::request_stop`] (or a service `Stop` op) flips the flag
//!    and wakes the loop.
//! 2. The loop closes the listener, stops parsing new frames, and gives
//!    every surviving conn a grace deadline.
//! 3. [`Service::on_stop`] closes idle connections; conns with in-flight
//!    work stay until their replies flush (the owner joins its worker
//!    pool first, so every claimed job still answers).
//! 4. The loop exits once the slab is empty; [`ReactorHandle::join`]
//!    then returns. Nothing is dropped mid-reply.

pub mod poll;
pub mod queue;
pub mod sys;

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::telemetry::registry;
use crate::transport::{self, Frame, FrameReader, FrameWriter, Meter};

use poll::{Interest, PollEvent, Poller};

pub use queue::{Admit, BatchPolicy, Entry, FairScheduler, Priority, RateLimit, TokenBucket, Work};
pub use sys::raise_nofile_limit;

const TOKEN_LISTENER: usize = 0;
const TOKEN_WAKER: usize = 1;
const TOKEN_BASE: usize = 2;

/// How long a connection may linger after a stop before it is closed
/// regardless of unflushed output.
const DRAIN_GRACE: Duration = Duration::from_secs(5);

/// Stable connection identity: slab slot plus generation, so a worker's
/// late reply can never land on a recycled slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConnId {
    slot: u32,
    gen: u32,
}

/// A connection-level callback module: the coordinator router and the
/// fleet distributor each implement this and run unchanged wire
/// protocols over the shared loop.
///
/// Callbacks run on the loop thread. They never block on I/O — slow
/// work goes to the worker pool, whose results come back through a
/// service-owned queue drained in [`Service::on_wake`].
pub trait Service: Send + 'static {
    /// A connection was accepted and registered.
    fn on_open(&mut self, conn: ConnId, ctl: &mut Ctl) {
        let _ = (conn, ctl);
    }

    /// One complete frame arrived (already metered as received).
    fn on_frame(&mut self, conn: ConnId, frame: Frame, ctl: &mut Ctl);

    /// The connection is gone (peer EOF/error, service close, or drain).
    /// Always called exactly once per accepted connection.
    fn on_close(&mut self, conn: ConnId, ctl: &mut Ctl) {
        let _ = (conn, ctl);
    }

    /// The loop woke up (cross-thread waker, readiness, or tick): drain
    /// any worker results queued for injection.
    fn on_wake(&mut self, ctl: &mut Ctl) {
        let _ = ctl;
    }

    /// A connection's deadline expired (service-set or partial-frame).
    /// Default: close it.
    fn on_deadline(&mut self, conn: ConnId, ctl: &mut Ctl) {
        ctl.close(conn);
    }

    /// Stop observed: the listener is closed and no further frames will
    /// be parsed. Close everything that is not awaiting an in-flight
    /// reply; whatever survives is force-closed after [`DRAIN_GRACE`].
    fn on_stop(&mut self, ctl: &mut Ctl) {
        let _ = ctl;
    }
}

/// Deferred connection operations a [`Service`] callback may emit.
/// Applied by the loop immediately after the callback returns (and
/// between successive frames of one read burst, so a `pause` takes
/// effect before the next frame is parsed).
#[derive(Debug, Default)]
pub struct Ctl {
    ops: Vec<Op>,
}

#[derive(Debug)]
enum Op {
    Send(ConnId, Frame),
    Close(ConnId),
    CloseAfterFlush(ConnId),
    Pause(ConnId),
    Resume(ConnId),
    Deadline(ConnId, Option<Instant>),
    Stop,
}

impl Ctl {
    /// Queue a frame to `conn` (flushes as far as the socket allows
    /// before returning to the loop).
    pub fn send(&mut self, conn: ConnId, frame: Frame) {
        self.ops.push(Op::Send(conn, frame));
    }

    /// Close `conn` now, discarding unflushed output.
    pub fn close(&mut self, conn: ConnId) {
        self.ops.push(Op::Close(conn));
    }

    /// Close `conn` once its outbox drains.
    pub fn close_after_flush(&mut self, conn: ConnId) {
        self.ops.push(Op::CloseAfterFlush(conn));
    }

    /// Stop reading/parsing `conn` (in-flight gating / backpressure).
    /// Already-buffered bytes are kept and parsed again on resume.
    pub fn pause(&mut self, conn: ConnId) {
        self.ops.push(Op::Pause(conn));
    }

    /// Undo [`Ctl::pause`]; buffered frames are parsed immediately.
    pub fn resume(&mut self, conn: ConnId) {
        self.ops.push(Op::Resume(conn));
    }

    /// Set or clear `conn`'s service deadline (e.g. the fleet ack
    /// timeout). Expiry triggers [`Service::on_deadline`].
    pub fn set_deadline(&mut self, conn: ConnId, at: Option<Instant>) {
        self.ops.push(Op::Deadline(conn, at));
    }

    /// Begin the shutdown drain (equivalent to
    /// [`Remote::request_stop`] from inside a callback).
    pub fn stop(&mut self) {
        self.ops.push(Op::Stop);
    }
}

/// Cross-thread handle into a running loop: workers and owners use it
/// to wake the loop and to request the stop drain. Wakes are delivered
/// over an internal loopback socket pair registered like any other fd.
#[derive(Debug)]
pub struct Remote {
    waker_tx: TcpStream,
    stop: AtomicBool,
    stopped: AtomicBool,
}

impl Remote {
    /// Wake the loop (idempotent; coalesces while the loop is busy).
    pub fn wake(&self) {
        // A full pipe means a wake is already pending — both outcomes
        // leave the loop guaranteed to run another iteration.
        let _ = (&self.waker_tx).write(&[1]);
    }

    /// Flip the stop flag and wake the loop into its drain sequence.
    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.wake();
    }

    pub fn stop_requested(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// True once the loop thread has fully drained and exited.
    pub fn is_stopped(&self) -> bool {
        self.stopped.load(Ordering::SeqCst)
    }
}

/// Options for [`spawn`].
pub struct ReactorOpts {
    /// Loop thread name (shows up in `/proc/self/task` and panics).
    pub name: String,
    /// Byte meter charged for every decoded (received) and fully
    /// flushed (sent) frame.
    pub meter: Arc<Meter>,
    /// Close a connection whose partially received frame makes no
    /// progress for this long (`None`: wait forever).
    pub partial_frame_timeout: Option<Duration>,
}

/// A running reactor: the loop thread plus its cross-thread remote.
pub struct ReactorHandle {
    pub addr: SocketAddr,
    remote: Arc<Remote>,
    thread: Option<JoinHandle<()>>,
}

impl ReactorHandle {
    pub fn remote(&self) -> Arc<Remote> {
        Arc::clone(&self.remote)
    }

    /// Ask the loop to drain (non-blocking).
    pub fn request_stop(&self) {
        self.remote.request_stop();
    }

    /// Wait for the loop thread to exit. Safe to call more than once.
    pub fn join(&mut self) {
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ReactorHandle {
    fn drop(&mut self) {
        self.request_stop();
        self.join();
    }
}

#[cfg(unix)]
fn raw_fd<T: std::os::fd::AsRawFd>(t: &T) -> sys::RawFd {
    t.as_raw_fd()
}

#[cfg(not(unix))]
fn raw_fd<T>(_t: &T) -> sys::RawFd {
    unreachable!("no reactor backend on this platform")
}

/// Build the loopback waker pair: `(loop-side read end, remote-side
/// write end)`. A TCP pair keeps this portable — no unix-only pipes.
fn waker_pair() -> io::Result<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let tx = TcpStream::connect(listener.local_addr()?)?;
    let (rx, _) = listener.accept()?;
    rx.set_nonblocking(true)?;
    tx.set_nonblocking(true)?;
    Ok((rx, tx))
}

/// Start a reactor on `listener`, serving `service` from one loop
/// thread. The listener is switched to nonblocking mode and owned by
/// the loop until stop.
pub fn spawn<S: Service>(
    listener: TcpListener,
    service: S,
    opts: ReactorOpts,
) -> io::Result<ReactorHandle> {
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let mut poller = Poller::new()?;
    let (waker_rx, waker_tx) = waker_pair()?;
    poller.register(raw_fd(&listener), TOKEN_LISTENER, Interest::READ)?;
    poller.register(raw_fd(&waker_rx), TOKEN_WAKER, Interest::READ)?;
    let remote = Arc::new(Remote {
        waker_tx,
        stop: AtomicBool::new(false),
        stopped: AtomicBool::new(false),
    });
    let r2 = Arc::clone(&remote);
    let meter = Arc::clone(&opts.meter);
    let partial = opts.partial_frame_timeout;
    let thread = std::thread::Builder::new()
        .name(format!("nq-reactor-{}", opts.name))
        .spawn(move || {
            let mut lp = EventLoop {
                poller,
                listener: Some(listener),
                waker_rx,
                service,
                remote: Arc::clone(&r2),
                meter,
                partial_timeout: partial,
                slots: Vec::new(),
                free: Vec::new(),
                live: 0,
                events: Vec::new(),
                resume_pending: Vec::new(),
                draining: false,
            };
            lp.run();
            r2.stopped.store(true, Ordering::SeqCst);
        })?;
    Ok(ReactorHandle {
        addr,
        remote,
        thread: Some(thread),
    })
}

struct Conn {
    stream: TcpStream,
    id: ConnId,
    reader: FrameReader,
    writer: FrameWriter,
    interest: Interest,
    paused: bool,
    close_after_flush: bool,
    /// Service-set deadline (ack timeouts etc.).
    deadline: Option<Instant>,
    /// Reactor-managed partial-frame progress deadline.
    partial_deadline: Option<Instant>,
}

struct Slot {
    gen: u32,
    conn: Option<Conn>,
}

struct EventLoop<S: Service> {
    poller: Poller,
    listener: Option<TcpListener>,
    waker_rx: TcpStream,
    service: S,
    remote: Arc<Remote>,
    meter: Arc<Meter>,
    partial_timeout: Option<Duration>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    live: usize,
    events: Vec<PollEvent>,
    resume_pending: Vec<usize>,
    draining: bool,
}

impl<S: Service> EventLoop<S> {
    fn run(&mut self) {
        let mut ctl = Ctl::default();
        loop {
            let timeout = self.next_timeout();
            let mut events = std::mem::take(&mut self.events);
            if self.poller.wait(&mut events, Some(timeout)).is_err() {
                // A broken poller is unrecoverable; drain and exit so
                // joiners do not hang.
                self.events = events;
                break;
            }
            for i in 0..events.len() {
                let ev = events[i];
                match ev.token {
                    TOKEN_LISTENER => self.accept_all(&mut ctl),
                    TOKEN_WAKER => self.drain_waker(),
                    t => self.conn_event(t - TOKEN_BASE, ev, &mut ctl),
                }
                self.pump(&mut ctl);
            }
            self.events = events;
            self.service.on_wake(&mut ctl);
            self.pump(&mut ctl);
            self.sweep_deadlines(&mut ctl);
            if self.remote.stop_requested() && !self.draining {
                self.begin_drain(&mut ctl);
            }
            if self.draining && self.live == 0 {
                break;
            }
        }
    }

    /// Wait no longer than the shared idle tick, or until the soonest
    /// connection deadline, whichever is first.
    fn next_timeout(&self) -> Duration {
        let tick = transport::read_timeout();
        let now = Instant::now();
        let mut soonest: Option<Instant> = None;
        for s in &self.slots {
            if let Some(c) = &s.conn {
                for d in [c.deadline, c.partial_deadline].into_iter().flatten() {
                    soonest = Some(match soonest {
                        Some(cur) => cur.min(d),
                        None => d,
                    });
                }
            }
        }
        match soonest {
            Some(at) => tick.min(at.saturating_duration_since(now)),
            None => tick,
        }
    }

    // -- slab ---------------------------------------------------------------

    fn conn_mut(&mut self, slot: usize) -> Option<&mut Conn> {
        self.slots.get_mut(slot).and_then(|s| s.conn.as_mut())
    }

    fn valid_slot(&self, id: ConnId) -> Option<usize> {
        let slot = id.slot as usize;
        match self.slots.get(slot) {
            Some(s) if s.gen == id.gen && s.conn.is_some() => Some(slot),
            _ => None,
        }
    }

    fn insert_conn(&mut self, stream: TcpStream) -> io::Result<ConnId> {
        let slot = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(Slot { gen: 0, conn: None });
                self.slots.len() - 1
            }
        };
        let id = ConnId {
            slot: slot as u32,
            gen: self.slots[slot].gen,
        };
        if let Err(e) = self
            .poller
            .register(raw_fd(&stream), slot + TOKEN_BASE, Interest::READ)
        {
            self.free.push(slot);
            return Err(e);
        }
        self.slots[slot].conn = Some(Conn {
            stream,
            id,
            reader: FrameReader::new(),
            writer: FrameWriter::new(),
            interest: Interest::READ,
            paused: false,
            close_after_flush: false,
            deadline: None,
            partial_deadline: None,
        });
        self.live += 1;
        registry().reactor.active_connections.inc();
        Ok(id)
    }

    /// Tear down a connection and tell the service. The generation bump
    /// invalidates any in-flight [`ConnId`]s for this slot.
    fn close_conn(&mut self, slot: usize, ctl: &mut Ctl) {
        let Some(conn) = self.slots[slot].conn.take() else {
            return;
        };
        let _ = self.poller.deregister(raw_fd(&conn.stream));
        self.slots[slot].gen = self.slots[slot].gen.wrapping_add(1);
        self.free.push(slot);
        self.live -= 1;
        registry().reactor.active_connections.dec();
        let id = conn.id;
        drop(conn);
        self.service.on_close(id, ctl);
    }

    // -- event handling -----------------------------------------------------

    fn accept_all(&mut self, ctl: &mut Ctl) {
        loop {
            let Some(listener) = &self.listener else { return };
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let Ok(id) = self.insert_conn(stream) else {
                        continue;
                    };
                    registry().reactor.accepts.inc();
                    self.service.on_open(id, ctl);
                    self.pump(ctl);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                // EMFILE and friends: give up for this tick rather than
                // spinning; level-triggered readiness will retry.
                Err(_) => return,
            }
        }
    }

    fn drain_waker(&mut self) {
        registry().reactor.wakeups.inc();
        let mut buf = [0u8; 64];
        loop {
            match self.waker_rx.read(&mut buf) {
                Ok(0) => return, // remote dropped; stop flag handles the rest
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    fn conn_event(&mut self, slot: usize, ev: PollEvent, ctl: &mut Ctl) {
        if ev.hangup {
            // ERR/HUP are reported regardless of the interest mask. A
            // paused conn will not read its way to EOF, so close it here
            // instead of letting a level-triggered HUP spin the loop;
            // any in-flight reply is dropped by the generation guard.
            let paused = self.conn_mut(slot).is_some_and(|c| c.paused);
            if paused {
                self.close_conn(slot, ctl);
                return;
            }
        }
        if ev.readable || ev.hangup {
            self.read_conn(slot, ctl);
        }
        if ev.writable {
            self.flush_conn(slot, ctl);
        }
    }

    fn read_conn(&mut self, slot: usize, ctl: &mut Ctl) {
        let mut buf = [0u8; 16 << 10];
        loop {
            let Some(conn) = self.conn_mut(slot) else { return };
            if conn.paused {
                return;
            }
            match conn.stream.read(&mut buf) {
                Ok(0) => {
                    self.close_conn(slot, ctl);
                    return;
                }
                Ok(n) => {
                    if conn.reader.feed(&buf[..n]).is_err() {
                        // poisoned stream (bad magic/kind/length)
                        self.close_conn(slot, ctl);
                        return;
                    }
                    self.parse_frames(slot, ctl);
                    if n < buf.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_conn(slot, ctl);
                    return;
                }
            }
        }
        self.note_partial_progress(slot);
    }

    /// Refresh the partial-frame deadline: armed while a frame prefix is
    /// buffered, cleared (and re-armed on the next burst) otherwise.
    fn note_partial_progress(&mut self, slot: usize) {
        let Some(timeout) = self.partial_timeout else {
            return;
        };
        let Some(conn) = self.conn_mut(slot) else { return };
        conn.partial_deadline = if conn.reader.buffered() > 0 {
            Some(Instant::now() + timeout)
        } else {
            None
        };
    }

    /// Decode and dispatch every complete frame buffered on `slot`,
    /// applying service ops between frames so pause/close take effect
    /// before the next frame is parsed.
    fn parse_frames(&mut self, slot: usize, ctl: &mut Ctl) {
        loop {
            if self.draining {
                return;
            }
            let Some(conn) = self.conn_mut(slot) else { return };
            if conn.paused {
                return;
            }
            let id = conn.id;
            match conn.reader.next_frame() {
                Ok(Some((frame, wire))) => {
                    self.meter.received.fetch_add(wire, Ordering::Relaxed);
                    self.service.on_frame(id, frame, ctl);
                    self.apply_ops(ctl);
                }
                Ok(None) => return,
                Err(_) => {
                    self.close_conn(slot, ctl);
                    return;
                }
            }
        }
    }

    fn flush_conn(&mut self, slot: usize, ctl: &mut Ctl) {
        let meter = Arc::clone(&self.meter);
        let Some(conn) = self.conn_mut(slot) else { return };
        match conn.writer.flush_to(&mut conn.stream, &meter) {
            Ok(true) => {
                if conn.close_after_flush {
                    self.close_conn(slot, ctl);
                } else {
                    self.set_interest(slot, false);
                }
            }
            Ok(false) => self.set_interest(slot, true),
            Err(_) => self.close_conn(slot, ctl),
        }
    }

    /// Keep the registered interest in sync with (paused, want_write).
    fn set_interest(&mut self, slot: usize, want_write: bool) {
        let Some(conn) = self.conn_mut(slot) else { return };
        let want = Interest {
            readable: !conn.paused,
            writable: want_write,
        };
        if want != conn.interest {
            conn.interest = want;
            let fd = raw_fd(&conn.stream);
            let token = slot + TOKEN_BASE;
            let _ = self.poller.reregister(fd, token, want);
        }
    }

    // -- op application -----------------------------------------------------

    /// Settle the op/resume fixpoint after an event or callback.
    fn pump(&mut self, ctl: &mut Ctl) {
        loop {
            self.apply_ops(ctl);
            let pending = std::mem::take(&mut self.resume_pending);
            if pending.is_empty() && ctl.ops.is_empty() {
                return;
            }
            for slot in pending {
                self.parse_frames(slot, ctl);
            }
        }
    }

    fn apply_ops(&mut self, ctl: &mut Ctl) {
        while !ctl.ops.is_empty() {
            let batch: Vec<Op> = std::mem::take(&mut ctl.ops);
            for op in batch {
                match op {
                    Op::Send(id, frame) => {
                        let Some(slot) = self.valid_slot(id) else {
                            continue; // conn died; reply dropped like a broken write
                        };
                        let Some(conn) = self.conn_mut(slot) else {
                            continue;
                        };
                        if conn.writer.queue(&frame).is_err() {
                            self.close_conn(slot, ctl);
                            continue;
                        }
                        self.flush_conn(slot, ctl);
                    }
                    Op::Close(id) => {
                        if let Some(slot) = self.valid_slot(id) {
                            self.close_conn(slot, ctl);
                        }
                    }
                    Op::CloseAfterFlush(id) => {
                        let Some(slot) = self.valid_slot(id) else {
                            continue;
                        };
                        let Some(conn) = self.conn_mut(slot) else {
                            continue;
                        };
                        if conn.writer.is_empty() {
                            self.close_conn(slot, ctl);
                        } else {
                            conn.close_after_flush = true;
                        }
                    }
                    Op::Pause(id) => {
                        if let Some(slot) = self.valid_slot(id) {
                            if let Some(conn) = self.conn_mut(slot) {
                                conn.paused = true;
                            }
                            self.set_interest(slot, self.wants_write(slot));
                        }
                    }
                    Op::Resume(id) => {
                        if let Some(slot) = self.valid_slot(id) {
                            if let Some(conn) = self.conn_mut(slot) {
                                conn.paused = false;
                            }
                            self.set_interest(slot, self.wants_write(slot));
                            self.resume_pending.push(slot);
                        }
                    }
                    Op::Deadline(id, at) => {
                        if let Some(slot) = self.valid_slot(id) {
                            if let Some(conn) = self.conn_mut(slot) {
                                conn.deadline = at;
                            }
                        }
                    }
                    Op::Stop => {
                        self.remote.stop.store(true, Ordering::SeqCst);
                    }
                }
            }
        }
    }

    fn wants_write(&self, slot: usize) -> bool {
        self.slots[slot]
            .conn
            .as_ref()
            .is_some_and(|c| !c.writer.is_empty())
    }

    // -- deadlines & drain --------------------------------------------------

    fn sweep_deadlines(&mut self, ctl: &mut Ctl) {
        let now = Instant::now();
        let mut expired: Vec<ConnId> = Vec::new();
        for s in &mut self.slots {
            if let Some(c) = &mut s.conn {
                let hit = [c.deadline, c.partial_deadline]
                    .into_iter()
                    .flatten()
                    .any(|d| now >= d);
                if hit {
                    // clear both so a service that keeps the conn open
                    // does not see the same expiry every tick
                    c.deadline = None;
                    c.partial_deadline = None;
                    expired.push(c.id);
                }
            }
        }
        for id in expired {
            if self.valid_slot(id).is_some() {
                self.service.on_deadline(id, ctl);
                self.pump(ctl);
            }
        }
    }

    fn begin_drain(&mut self, ctl: &mut Ctl) {
        self.draining = true;
        if let Some(listener) = self.listener.take() {
            let _ = self.poller.deregister(raw_fd(&listener));
        }
        let grace = Instant::now() + DRAIN_GRACE;
        for s in &mut self.slots {
            if let Some(c) = &mut s.conn {
                c.deadline = Some(match c.deadline {
                    Some(d) => d.min(grace),
                    None => grace,
                });
            }
        }
        self.service.on_stop(ctl);
        self.pump(ctl);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::{recv_frame, send_frame, FrameKind};

    /// Echoes every frame back with "echo:" prefixed to the name; a
    /// Control frame named "stop" begins the drain.
    #[derive(Default)]
    struct Echo {
        open: Vec<ConnId>,
    }

    impl Service for Echo {
        fn on_open(&mut self, conn: ConnId, _ctl: &mut Ctl) {
            self.open.push(conn);
        }

        fn on_close(&mut self, conn: ConnId, _ctl: &mut Ctl) {
            self.open.retain(|&c| c != conn);
        }

        fn on_frame(&mut self, conn: ConnId, frame: Frame, ctl: &mut Ctl) {
            if frame.kind == FrameKind::Control && frame.name == "stop" {
                ctl.stop();
                return;
            }
            ctl.send(
                conn,
                Frame {
                    kind: frame.kind,
                    name: format!("echo:{}", frame.name),
                    payload: frame.payload,
                },
            );
        }

        fn on_stop(&mut self, ctl: &mut Ctl) {
            for &conn in &self.open {
                ctl.close_after_flush(conn);
            }
        }
    }

    fn start_echo() -> ReactorHandle {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        spawn(
            listener,
            Echo::default(),
            ReactorOpts {
                name: "echo-test".into(),
                meter: Arc::new(Meter::default()),
                partial_frame_timeout: Some(Duration::from_secs(5)),
            },
        )
        .unwrap()
    }

    #[test]
    fn echo_roundtrip_over_reactor() {
        let mut handle = start_echo();
        let meter = Meter::default();
        let mut sock = TcpStream::connect(handle.addr).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        for i in 0..5usize {
            let f = Frame {
                kind: FrameKind::Control,
                name: format!("ping{i}"),
                payload: vec![i as u8; 100 * i + 1],
            };
            send_frame(&mut sock, &f, &meter).unwrap();
            let (back, _) = recv_frame(&mut sock, &meter).unwrap();
            assert_eq!(back.name, format!("echo:ping{i}"));
            assert_eq!(back.payload, f.payload);
        }
        handle.request_stop();
        handle.join();
    }

    #[test]
    fn wire_stop_frame_drains_loop() {
        let mut handle = start_echo();
        let meter = Meter::default();
        let mut sock = TcpStream::connect(handle.addr).unwrap();
        send_frame(
            &mut sock,
            &Frame {
                kind: FrameKind::Control,
                name: "stop".into(),
                payload: vec![],
            },
            &meter,
        )
        .unwrap();
        // the loop observes the stop, drains, and exits on its own
        let remote = handle.remote();
        let t0 = Instant::now();
        while !remote.is_stopped() && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(remote.is_stopped(), "loop never drained after wire stop");
        handle.join();
    }

    #[test]
    fn partial_frame_is_tolerated_then_completed() {
        let mut handle = start_echo();
        let meter = Meter::default();
        let mut sock = TcpStream::connect(handle.addr).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let f = Frame {
            kind: FrameKind::ModelPart,
            name: "slow".into(),
            payload: (0..5000).map(|i| (i % 251) as u8).collect(),
        };
        let mut bytes = Vec::new();
        send_frame(&mut bytes, &f, &meter).unwrap();
        // dribble the frame across several writes with pauses
        for chunk in bytes.chunks(bytes.len() / 4 + 1) {
            sock.write_all(chunk).unwrap();
            sock.flush().unwrap();
            std::thread::sleep(Duration::from_millis(20));
        }
        let (back, _) = recv_frame(&mut sock, &meter).unwrap();
        assert_eq!(back.name, "echo:slow");
        assert_eq!(back.payload, f.payload);
        handle.request_stop();
        handle.join();
    }
}
