//! Safe readiness poller: raw `epoll` on Linux, a `poll(2)` sweep on
//! other unixes, and an explicit "unsupported" stub elsewhere. One
//! instance is owned by one loop thread (`&mut self` everywhere); the
//! cross-thread wake path goes through a socketpair registered like any
//! other fd, so nothing here needs interior locking.

use std::io;
use std::time::Duration;

use super::sys::RawFd;

/// What a registration wants to hear about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest {
        readable: true,
        writable: false,
    };
    pub const WRITE: Interest = Interest {
        readable: false,
        writable: true,
    };
    pub const READ_WRITE: Interest = Interest {
        readable: true,
        writable: true,
    };
}

/// One readiness report. `hangup` folds in error conditions: the owner
/// should read (draining any final bytes) and then close.
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    pub token: usize,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        // Round up so a 100µs request does not busy-spin as 0 ms.
        Some(t) => t
            .as_millis()
            .max(u128::from(u32::from(!t.is_zero())))
            .min(i32::MAX as u128) as i32,
        None => -1,
    }
}

#[cfg(target_os = "linux")]
pub use self::linux::Poller;
#[cfg(all(unix, not(target_os = "linux")))]
pub use self::unix_poll::Poller;
#[cfg(not(unix))]
pub use self::stub::Poller;

#[cfg(target_os = "linux")]
mod linux {
    use super::*;
    use crate::reactor::sys::{cvt, epoll};

    /// Level-triggered epoll behind a tiny safe wrapper. Level-triggered
    /// keeps the state machine honest: unread bytes or an unflushed
    /// outbox re-report until handled, so a missed edge can never strand
    /// a connection.
    pub struct Poller {
        epfd: RawFd,
        events: Vec<epoll::epoll_event>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = cvt(unsafe { epoll::epoll_create1(epoll::EPOLL_CLOEXEC) })?;
            Ok(Poller {
                epfd,
                events: vec![epoll::epoll_event { events: 0, data: 0 }; 1024],
            })
        }

        fn ctl(&mut self, op: i32, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            let mut flags = 0u32;
            if interest.readable {
                flags |= epoll::EPOLLIN;
            }
            if interest.writable {
                flags |= epoll::EPOLLOUT;
            }
            let mut ev = epoll::epoll_event {
                events: flags,
                data: token as u64,
            };
            cvt(unsafe { epoll::epoll_ctl(self.epfd, op, fd, &mut ev) }).map(|_| ())
        }

        pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            self.ctl(epoll::EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn reregister(
            &mut self,
            fd: RawFd,
            token: usize,
            interest: Interest,
        ) -> io::Result<()> {
            self.ctl(epoll::EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            // The event argument must be non-null for portability with
            // pre-2.6.9 kernels; reuse a zeroed one.
            let mut ev = epoll::epoll_event { events: 0, data: 0 };
            cvt(unsafe { epoll::epoll_ctl(self.epfd, epoll::EPOLL_CTL_DEL, fd, &mut ev) })
                .map(|_| ())
        }

        pub fn wait(
            &mut self,
            out: &mut Vec<PollEvent>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let n = unsafe {
                epoll::epoll_wait(
                    self.epfd,
                    self.events.as_mut_ptr(),
                    self.events.len() as i32,
                    timeout_ms(timeout),
                )
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(()); // EINTR: report an empty tick
                }
                return Err(err);
            }
            for ev in &self.events[..n as usize] {
                // Copy out of the (possibly packed) struct before use.
                let flags = ev.events;
                let data = ev.data;
                out.push(PollEvent {
                    token: data as usize,
                    readable: flags & epoll::EPOLLIN != 0,
                    writable: flags & epoll::EPOLLOUT != 0,
                    hangup: flags & (epoll::EPOLLERR | epoll::EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe { crate::reactor::sys::unix::close(self.epfd) };
        }
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod unix_poll {
    use super::*;
    use crate::reactor::sys::{cvt, unix};
    use std::collections::HashMap;

    /// `poll(2)` fallback: O(n) per wait, which is fine for the
    /// non-Linux dev platforms it exists for.
    pub struct Poller {
        registry: HashMap<RawFd, (usize, Interest)>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller {
                registry: HashMap::new(),
            })
        }

        pub fn register(&mut self, fd: RawFd, token: usize, interest: Interest) -> io::Result<()> {
            self.registry.insert(fd, (token, interest));
            Ok(())
        }

        pub fn reregister(
            &mut self,
            fd: RawFd,
            token: usize,
            interest: Interest,
        ) -> io::Result<()> {
            self.registry.insert(fd, (token, interest));
            Ok(())
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.registry.remove(&fd);
            Ok(())
        }

        pub fn wait(
            &mut self,
            out: &mut Vec<PollEvent>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<unix::pollfd> = self
                .registry
                .iter()
                .map(|(&fd, &(_, interest))| unix::pollfd {
                    fd,
                    events: if interest.readable { unix::POLLIN } else { 0 }
                        | if interest.writable { unix::POLLOUT } else { 0 },
                    revents: 0,
                })
                .collect();
            let n = unsafe {
                unix::poll(
                    fds.as_mut_ptr(),
                    fds.len() as std::os::raw::c_ulong,
                    timeout_ms(timeout),
                )
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            let _ = cvt(n);
            for pfd in &fds {
                if pfd.revents == 0 {
                    continue;
                }
                let Some(&(token, _)) = self.registry.get(&pfd.fd) else {
                    continue;
                };
                out.push(PollEvent {
                    token,
                    readable: pfd.revents & unix::POLLIN != 0,
                    writable: pfd.revents & unix::POLLOUT != 0,
                    hangup: pfd.revents & (unix::POLLERR | unix::POLLHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

#[cfg(not(unix))]
mod stub {
    use super::*;

    /// Non-unix platforms have no reactor backend; construction fails
    /// with a clear error and the blocking client paths keep working.
    pub struct Poller;

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "the nestquant reactor requires epoll (Linux) or poll(2) (unix)",
            ))
        }

        pub fn register(&mut self, _: RawFd, _: usize, _: Interest) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        pub fn reregister(&mut self, _: RawFd, _: usize, _: Interest) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        pub fn deregister(&mut self, _: RawFd) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }

        pub fn wait(&mut self, _: &mut Vec<PollEvent>, _: Option<Duration>) -> io::Result<()> {
            unreachable!("stub poller cannot be constructed")
        }
    }
}
