//! Scheduling layer of the reactor: a three-class priority scheduler
//! with deficit-round-robin weighted fairness across tenants inside the
//! lowest class, plus the token bucket used for per-device rate limits.
//!
//! Priority contract (strict): **control > switch/advice > infer**. A
//! worker never takes an infer batch while a control or advice job is
//! queued. Within the infer class, tenants share the pool by DRR — each
//! waiting tenant earns `weight` credits per replenish round and pays
//! one credit per request served, so a tenant with weight 3 gets 3× the
//! throughput of a weight-1 tenant under saturation, and an idle tenant
//! costs nothing.
//!
//! Infer work is taken in per-tenant *batches* with the same deadline
//! semantics the old per-tenant executor threads had: the batch closes
//! when full, or when the oldest member has waited `max_wait`, whichever
//! comes first. Close-drain ordering for shutdown: [`FairScheduler::close`]
//! refuses new work, in-flight collectors ship their partial batches
//! immediately, and workers keep draining until every queue is empty
//! before they see [`Work::Shutdown`].

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::telemetry::{registry, TraceKind};

/// Priority classes, highest first. The discriminant doubles as the
/// queue-depth gauge index in `ReactorTelemetry`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Admin/observability: stop, models, metrics, index.
    Control = 0,
    /// Bitwidth-switch traffic: fleet advice decisions.
    Switch = 1,
    /// Inference requests (weighted-fair across tenants).
    Infer = 2,
}

impl Priority {
    pub fn label(self) -> &'static str {
        match self {
            Priority::Control => "control",
            Priority::Switch => "switch",
            Priority::Infer => "infer",
        }
    }
}

/// One queued job.
#[derive(Debug)]
pub struct Entry<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// Outcome of [`FairScheduler::push_infer`]: admission control turns
/// overload into a *typed* refusal instead of unbounded queue growth.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admit {
    /// The job is queued and will execute.
    Queued,
    /// The scheduler is closed (shutdown); callers reply `error`.
    Closed,
    /// The tenant's queue is at its depth cap; the job is shed and the
    /// caller replies `busy` so the client can back off and retry.
    Shed,
}

/// What a worker gets from [`FairScheduler::next_work`].
#[derive(Debug)]
pub enum Work<T> {
    /// A control or switch job, taken singly.
    One(Priority, Entry<T>),
    /// An infer batch for one tenant. The worker MUST call
    /// [`FairScheduler::finish_batch`] with the tenant index when done.
    Batch(usize, Vec<Entry<T>>),
    /// Closed and fully drained; the worker should exit.
    Shutdown,
}

/// Batch-formation policy for the infer class (mirrors the coordinator's
/// `ServerConfig::max_wait` + executor batch size).
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub batch_size: usize,
    pub max_wait: Duration,
}

#[derive(Debug)]
struct TenantQueue<T> {
    queue: VecDeque<Entry<T>>,
    weight: i64,
    deficit: i64,
    /// One batch per tenant at a time: a collector owns the tenant until
    /// `finish_batch`, so batches stay maximal and per-tenant execution
    /// stays serial (the old one-executor-thread-per-tenant invariant).
    busy: bool,
}

#[derive(Debug)]
struct Inner<T> {
    closed: bool,
    control: VecDeque<Entry<T>>,
    switch: VecDeque<Entry<T>>,
    tenants: Vec<TenantQueue<T>>,
    cursor: usize,
}

impl<T> Inner<T> {
    fn queued(&self) -> usize {
        self.control.len()
            + self.switch.len()
            + self.tenants.iter().map(|t| t.queue.len()).sum::<usize>()
    }

    /// DRR pick: scan from the cursor for a waiting tenant with credit;
    /// if a full scan finds backlog but no credit, replenish every
    /// waiting tenant by its weight and scan once more (weights >= 1, so
    /// the second scan always succeeds when there is backlog).
    fn pick_tenant(&mut self) -> Option<usize> {
        let n = self.tenants.len();
        for round in 0..2 {
            for k in 0..n {
                let i = (self.cursor + k) % n;
                let t = &mut self.tenants[i];
                if t.busy || t.queue.is_empty() {
                    continue;
                }
                if t.deficit >= 1 {
                    self.cursor = (i + 1) % n;
                    crate::nq_trace!(
                        TraceKind::Fairness,
                        "infer pick tenant={i} deficit={} backlog={} round={round}",
                        t.deficit,
                        t.queue.len()
                    );
                    return Some(i);
                }
            }
            let mut waiting = false;
            for t in self.tenants.iter_mut() {
                if !t.busy && !t.queue.is_empty() {
                    t.deficit += t.weight;
                    waiting = true;
                }
            }
            if !waiting {
                return None;
            }
        }
        None
    }
}

/// Three-class priority scheduler with DRR tenant fairness in the infer
/// class. Shared between the reactor loop (producers) and the worker
/// pool (consumers); all waiting happens on one condvar.
#[derive(Debug)]
pub struct FairScheduler<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    /// Per-tenant infer queue depth cap; pushes beyond it are shed.
    infer_cap: usize,
}

impl<T> FairScheduler<T> {
    /// `weights[i]` is tenant i's DRR weight (clamped to >= 1). No
    /// depth cap: every infer push is admitted until close.
    pub fn new(weights: &[u32]) -> FairScheduler<T> {
        Self::with_infer_cap(weights, usize::MAX)
    }

    /// Like [`FairScheduler::new`] but with per-tenant admission
    /// control: once a tenant has `infer_cap` infer jobs waiting,
    /// further pushes return [`Admit::Shed`] (counted in
    /// `nq_shed_total`) instead of growing the queue without bound.
    /// Control and switch traffic is never shed — it is what an
    /// operator uses to diagnose the overload.
    pub fn with_infer_cap(weights: &[u32], infer_cap: usize) -> FairScheduler<T> {
        FairScheduler {
            inner: Mutex::new(Inner {
                closed: false,
                control: VecDeque::new(),
                switch: VecDeque::new(),
                tenants: weights
                    .iter()
                    .map(|&w| TenantQueue {
                        queue: VecDeque::new(),
                        weight: i64::from(w.max(1)),
                        deficit: 0,
                        busy: false,
                    })
                    .collect(),
                cursor: 0,
            }),
            cv: Condvar::new(),
            infer_cap: infer_cap.max(1),
        }
    }

    /// Queue a control-class job. Returns false if the scheduler is
    /// closed (the job is dropped; callers reply with an error).
    pub fn push_control(&self, payload: T) -> bool {
        self.push_single(Priority::Control, payload)
    }

    /// Queue a switch/advice-class job.
    pub fn push_switch(&self, payload: T) -> bool {
        self.push_single(Priority::Switch, payload)
    }

    fn push_single(&self, prio: Priority, payload: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        let q = match prio {
            Priority::Control => &mut g.control,
            _ => &mut g.switch,
        };
        q.push_back(Entry {
            payload,
            enqueued: Instant::now(),
        });
        registry().reactor.queue_depth(prio as usize).inc();
        self.cv.notify_all();
        true
    }

    /// Queue an infer-class job for `tenant`, subject to admission
    /// control: a closed scheduler refuses it, a tenant at its depth
    /// cap sheds it (see [`Admit`]).
    pub fn push_infer(&self, tenant: usize, payload: T) -> Admit {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Admit::Closed;
        }
        if g.tenants[tenant].queue.len() >= self.infer_cap {
            registry().faults.shed_total.inc();
            crate::nq_trace!(
                TraceKind::Shed,
                "infer shed tenant={tenant} depth={} cap={}",
                g.tenants[tenant].queue.len(),
                self.infer_cap
            );
            return Admit::Shed;
        }
        g.tenants[tenant].queue.push_back(Entry {
            payload,
            enqueued: Instant::now(),
        });
        registry().reactor.queue_depth(Priority::Infer as usize).inc();
        self.cv.notify_all();
        Admit::Queued
    }

    /// Block for the next unit of work, honoring class priority and
    /// tenant fairness. Infer work for tenant `i` is collected into a
    /// batch under `policies[i]` before being returned (tenants have
    /// per-model batch shapes, so the policy is per-tenant).
    pub fn next_work(&self, policies: &[BatchPolicy]) -> Work<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(e) = g.control.pop_front() {
                registry().reactor.queue_depth(Priority::Control as usize).dec();
                return Work::One(Priority::Control, e);
            }
            if let Some(e) = g.switch.pop_front() {
                registry().reactor.queue_depth(Priority::Switch as usize).dec();
                return Work::One(Priority::Switch, e);
            }
            if let Some(t) = g.pick_tenant() {
                g.tenants[t].busy = true;
                return self.collect_batch(g, t, policies[t]);
            }
            if g.closed && g.queued() == 0 {
                return Work::Shutdown;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Gather up to `batch_size` requests from tenant `t`, waiting until
    /// the oldest member has aged `max_wait` (a full batch or a close
    /// ships immediately).
    fn collect_batch(
        &self,
        mut g: std::sync::MutexGuard<'_, Inner<T>>,
        t: usize,
        policy: BatchPolicy,
    ) -> Work<T> {
        let batch_size = policy.batch_size.max(1);
        let mut batch: Vec<Entry<T>> = Vec::with_capacity(batch_size);
        loop {
            while batch.len() < batch_size {
                match g.tenants[t].queue.pop_front() {
                    Some(e) => {
                        registry().reactor.queue_depth(Priority::Infer as usize).dec();
                        batch.push(e);
                    }
                    None => break,
                }
            }
            if batch.len() >= batch_size || g.closed {
                break;
            }
            // Deadline anchors at the oldest member's enqueue time, so a
            // request never waits more than max_wait in total.
            let deadline = batch[0].enqueued + policy.max_wait;
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
        g.tenants[t].deficit -= batch.len() as i64;
        Work::Batch(t, batch)
    }

    /// Release tenant `t` after its batch executed, so other workers can
    /// collect from it again.
    pub fn finish_batch(&self, t: usize) {
        let mut g = self.inner.lock().unwrap();
        g.tenants[t].busy = false;
        drop(g);
        self.cv.notify_all();
    }

    /// Refuse new work and wake everyone. Workers drain what is already
    /// queued (collectors ship partial batches immediately), then see
    /// [`Work::Shutdown`].
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        drop(g);
        self.cv.notify_all();
    }

    /// Jobs queued and not yet claimed, in `(control, switch, infer)`.
    pub fn depths(&self) -> (usize, usize, usize) {
        let g = self.inner.lock().unwrap();
        (
            g.control.len(),
            g.switch.len(),
            g.tenants.iter().map(|t| t.queue.len()).sum(),
        )
    }
}

// ---------------------------------------------------------------------------
// token bucket (per-device rate limits)
// ---------------------------------------------------------------------------

/// Token-bucket parameters: sustained rate and burst headroom.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RateLimit {
    /// Sustained admissions per second.
    pub per_sec: f64,
    /// Maximum banked tokens (burst size); clamped to >= 1.
    pub burst: f64,
}

/// Classic token bucket: `per_sec` tokens drip in continuously up to
/// `burst`; each admission spends one. Callers own the clock so tests
/// are deterministic.
#[derive(Debug)]
pub struct TokenBucket {
    limit: RateLimit,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(limit: RateLimit, now: Instant) -> TokenBucket {
        TokenBucket {
            limit,
            tokens: limit.burst.max(1.0),
            last: now,
        }
    }

    /// Admit one request at `now`, or refuse it (no partial spend).
    pub fn admit(&mut self, now: Instant) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.limit.per_sec).min(self.limit.burst.max(1.0));
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    const NOW_OR_LATER: BatchPolicy = BatchPolicy {
        batch_size: 1,
        max_wait: Duration::from_millis(0),
    };

    #[test]
    fn strict_class_priority() {
        let s: FairScheduler<&str> = FairScheduler::new(&[1]);
        assert_eq!(s.push_infer(0, "infer"), Admit::Queued);
        assert!(s.push_switch("advice"));
        assert!(s.push_control("stop"));
        match s.next_work(&[NOW_OR_LATER]) {
            Work::One(Priority::Control, e) => assert_eq!(e.payload, "stop"),
            w => panic!("expected control first, got {w:?}"),
        }
        match s.next_work(&[NOW_OR_LATER]) {
            Work::One(Priority::Switch, e) => assert_eq!(e.payload, "advice"),
            w => panic!("expected switch second, got {w:?}"),
        }
        match s.next_work(&[NOW_OR_LATER]) {
            Work::Batch(0, b) => assert_eq!(b[0].payload, "infer"),
            w => panic!("expected infer last, got {w:?}"),
        }
    }

    #[test]
    fn drr_respects_weights_under_saturation() {
        let s: FairScheduler<usize> = FairScheduler::new(&[1, 3]);
        for _ in 0..100 {
            s.push_infer(0, 0);
            s.push_infer(1, 1);
        }
        let mut served = [0usize; 2];
        for _ in 0..80 {
            match s.next_work(&[NOW_OR_LATER; 2]) {
                Work::Batch(t, b) => {
                    served[t] += b.len();
                    s.finish_batch(t);
                }
                w => panic!("unexpected {w:?}"),
            }
        }
        // weight 3 tenant gets ~3x the service of weight 1
        assert_eq!(served[0] + served[1], 80);
        assert!(
            served[1] >= 55 && served[0] >= 15,
            "DRR shares off: {served:?}"
        );
    }

    #[test]
    fn batch_waits_for_stragglers_until_oldest_deadline() {
        let s: Arc<FairScheduler<u32>> = Arc::new(FairScheduler::new(&[1]));
        s.push_infer(0, 1);
        s.push_infer(0, 2);
        let pusher = {
            let s = Arc::clone(&s);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(20));
                s.push_infer(0, 3);
            })
        };
        let t0 = Instant::now();
        let w = s.next_work(&[BatchPolicy {
            batch_size: 4,
            max_wait: Duration::from_millis(200),
        }]);
        pusher.join().unwrap();
        match w {
            Work::Batch(0, b) => {
                // the straggler pushed mid-wait joins the batch; the
                // deadline still bounds the total wait
                assert!(b.len() >= 2, "batch lost members: {}", b.len());
                assert!(t0.elapsed() < Duration::from_secs(5));
            }
            w => panic!("unexpected {w:?}"),
        }
    }

    #[test]
    fn full_batch_ships_immediately() {
        let s: FairScheduler<u32> = FairScheduler::new(&[1]);
        for i in 0..4 {
            s.push_infer(0, i);
        }
        let t0 = Instant::now();
        match s.next_work(&[BatchPolicy {
            batch_size: 4,
            max_wait: Duration::from_secs(30),
        }]) {
            Work::Batch(0, b) => assert_eq!(b.len(), 4),
            w => panic!("unexpected {w:?}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "waited despite full batch");
    }

    #[test]
    fn close_drains_then_shuts_down() {
        let s: FairScheduler<u32> = FairScheduler::new(&[1]);
        s.push_infer(0, 7);
        s.push_control(9);
        s.close();
        assert_eq!(
            s.push_infer(0, 8),
            Admit::Closed,
            "closed scheduler refuses work"
        );
        match s.next_work(&[NOW_OR_LATER]) {
            Work::One(Priority::Control, e) => assert_eq!(e.payload, 9),
            w => panic!("unexpected {w:?}"),
        }
        match s.next_work(&[NOW_OR_LATER]) {
            Work::Batch(0, b) => {
                assert_eq!(b[0].payload, 7);
                s.finish_batch(0);
            }
            w => panic!("unexpected {w:?}"),
        }
        assert!(matches!(s.next_work(&[NOW_OR_LATER]), Work::Shutdown));
    }

    #[test]
    fn depth_cap_sheds_infer_but_never_control() {
        let s: FairScheduler<u32> = FairScheduler::with_infer_cap(&[1, 1], 2);
        assert_eq!(s.push_infer(0, 1), Admit::Queued);
        assert_eq!(s.push_infer(0, 2), Admit::Queued);
        assert_eq!(s.push_infer(0, 3), Admit::Shed, "third push exceeds the cap");
        // per-tenant cap: tenant 1's queue is independent
        assert_eq!(s.push_infer(1, 4), Admit::Queued);
        // control/switch classes are exempt from shedding
        assert!(s.push_control(9));
        assert!(s.push_switch(8));
        // draining tenant 0 re-opens admission
        match s.next_work(&[NOW_OR_LATER; 2]) {
            Work::One(Priority::Control, _) => {}
            w => panic!("unexpected {w:?}"),
        }
        match s.next_work(&[NOW_OR_LATER; 2]) {
            Work::One(Priority::Switch, _) => {}
            w => panic!("unexpected {w:?}"),
        }
        match s.next_work(&[NOW_OR_LATER; 2]) {
            Work::Batch(t, b) => {
                assert_eq!(b.len(), 1);
                s.finish_batch(t);
            }
            w => panic!("unexpected {w:?}"),
        }
        assert_eq!(s.push_infer(0, 5), Admit::Queued, "drained queue admits again");
    }

    #[test]
    fn token_bucket_burst_then_sustained() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(
            RateLimit {
                per_sec: 10.0,
                burst: 3.0,
            },
            t0,
        );
        // burst capacity admits 3 back-to-back, then refuses
        assert!(b.admit(t0));
        assert!(b.admit(t0));
        assert!(b.admit(t0));
        assert!(!b.admit(t0));
        // 100ms later one token (10/s) has dripped in
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.admit(t1));
        assert!(!b.admit(t1));
        // a long idle period refills only to burst, never beyond
        let t2 = t1 + Duration::from_secs(60);
        assert!(b.admit(t2));
        assert!(b.admit(t2));
        assert!(b.admit(t2));
        assert!(!b.admit(t2));
    }
}
