//! Raw OS bindings for the reactor: the handful of syscalls a
//! readiness-driven loop needs, declared by hand against the platform
//! libc that `std` already links (the workspace is dependency-free, so
//! no `libc` crate). Everything here is `unsafe` plumbing; the safe
//! wrapper lives in [`super::poll`].

#![allow(non_camel_case_types)]

use std::io;

/// Raw file descriptor (matches `std::os::fd::RawFd` on unix).
pub type RawFd = i32;

/// Turn a -1 libc return into the calling thread's errno as an
/// [`io::Error`].
pub fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

#[cfg(target_os = "linux")]
pub mod epoll {
    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;

    pub const EPOLL_CLOEXEC: i32 = 0o2000000;

    /// Mirrors the kernel's `struct epoll_event`, which is packed on
    /// x86-64 (and only there) so the 64-bit data field sits at offset 4.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct epoll_event {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut epoll_event) -> i32;
        pub fn epoll_wait(
            epfd: i32,
            events: *mut epoll_event,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
    }
}

#[cfg(unix)]
pub mod unix {
    use std::os::raw::c_ulong;

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct pollfd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x1;
    pub const POLLOUT: i16 = 0x4;
    pub const POLLERR: i16 = 0x8;
    pub const POLLHUP: i16 = 0x10;

    extern "C" {
        pub fn poll(fds: *mut pollfd, nfds: c_ulong, timeout_ms: i32) -> i32;
        pub fn close(fd: i32) -> i32;
    }
}

/// Raise the soft `RLIMIT_NOFILE` toward `target` (capped by the hard
/// limit). Returns the soft limit now in effect. Linux-only helper for
/// the churn test, which holds >10k sockets in one process; elsewhere
/// it reports the request as unsupported.
#[cfg(target_os = "linux")]
pub fn raise_nofile_limit(target: u64) -> io::Result<u64> {
    #[repr(C)]
    struct rlimit {
        rlim_cur: u64,
        rlim_max: u64,
    }
    const RLIMIT_NOFILE: i32 = 7;
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const rlimit) -> i32;
    }
    let mut lim = rlimit {
        rlim_cur: 0,
        rlim_max: 0,
    };
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) })?;
    let want = target.min(lim.rlim_max);
    if want > lim.rlim_cur {
        lim.rlim_cur = want;
        cvt(unsafe { setrlimit(RLIMIT_NOFILE, &lim) })?;
    }
    Ok(lim.rlim_cur)
}

#[cfg(not(target_os = "linux"))]
pub fn raise_nofile_limit(_target: u64) -> io::Result<u64> {
    Err(io::Error::new(
        io::ErrorKind::Unsupported,
        "RLIMIT_NOFILE adjustment is only wired up on Linux",
    ))
}
