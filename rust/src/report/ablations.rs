//! Ablation studies over the design choices DESIGN.md calls out:
//!
//! A1 — the extra-1-bit compensation (§3.3.2): full-bit accuracy with vs
//!      without it, from the pipeline's `full_nc` sweep.
//! A2 — policy hysteresis width: switches + bytes moved on a noisy
//!      battery trace, NestQuant vs diverse, per band width.
//! A3 — packing word size: u64 lanes (ours) vs u32 lanes, per bitwidth.
//! A4 — adaptive selector (future-work feature): evals needed vs a full
//!      h-sweep, against the pipeline's measured accuracy curves.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::{Decision, PolicyState, SwitchPolicy, Variant};
use crate::nest::selector::{select_critical_h, SelectorConfig};
use crate::nest::{PAPER_BANDS};
use crate::util::json::Value;
use crate::util::prng::Rng;

use super::{fmt_size, load_report, pct, Table};

fn f(v: &Value, path: &[&str]) -> Result<f64> {
    v.path(path)?.as_f64()
}

/// A1 — compensation ablation.
pub fn cmd_ablation_compensation(root: &Path) -> Result<()> {
    let acc = load_report(root, "accuracy")?;
    let mut t = Table::new(
        "Ablation A1: extra-1-bit compensation (full-bit accuracy, INT8 nesting)",
        &["Model", "h", "with compensation", "w/o compensation", "compen. needed?"],
    );
    for (arch, a) in acc.as_object()? {
        let Ok(nest) = a.path(&["nest", "8"]) else { continue };
        let full = f(nest, &["full"])?;
        for h in [4u8, 5, 6] {
            let Ok(cell) = nest.path(&["h", &h.to_string()]) else { continue };
            let nc = f(cell, &["full_nc"])?;
            t.row(vec![
                arch.clone(),
                h.to_string(),
                pct(full),
                pct(nc),
                if (full - nc).abs() < 1e-9 { "no (acc unchanged)" } else { "yes" }.into(),
            ]);
        }
    }
    t.print();
    println!("(compensated recomposition is verified bit-identical to the INT8 model by the pipeline)");
    Ok(())
}

/// A2 — hysteresis band width vs switch thrash on a noisy battery.
pub fn cmd_ablation_hysteresis(root: &Path) -> Result<()> {
    let sizes = load_report(root, "sizes")?;
    // representative model for byte costs
    let arch = "cnn_m";
    let s = sizes.get(arch).unwrap();
    let sec_b = f(s, &["nest", "8|4", "section_b"])? as u64;
    let mono = (f(s, &["mono", "8"])? + f(s, &["mono", "4"])?) as u64;

    let mut t = Table::new(
        &format!("Ablation A2: hysteresis width vs switch thrash ({arch}, noisy battery, 10k steps)"),
        &["band (±)", "dwell", "switches", "NestQuant I/O", "diverse I/O"],
    );
    for (band, dwell) in [(0.0, 0u32), (0.0, 2), (0.05, 2), (0.10, 2), (0.20, 2)] {
        let policy = SwitchPolicy {
            downgrade_below: 0.5 - band,
            upgrade_above: 0.5 + band,
            min_dwell: dwell,
        };
        let mut state = PolicyState::new(policy, Variant::FullBit);
        let mut rng = Rng::new(2024);
        let mut level = 0.5f64;
        let mut switches = 0u64;
        for _ in 0..10_000 {
            // noisy random-walk battery hovering near the threshold
            level = (level + rng.normal() * 0.03).clamp(0.0, 1.0);
            if matches!(state.decide(level), Decision::SwitchTo(_)) {
                switches += 1;
            }
        }
        t.row(vec![
            format!("{band:.2}"),
            dwell.to_string(),
            switches.to_string(),
            fmt_size(switches * sec_b),
            fmt_size(switches * mono),
        ]);
    }
    t.print();
    println!("(the default ±0.05 band + dwell 2 kills threshold thrash; diverse pays ~4x bytes per switch regardless)");
    Ok(())
}

/// A3 — packing word size: u64 (ours) vs u32 lanes.
pub fn cmd_ablation_packing() -> Result<()> {
    let mut t = Table::new(
        "Ablation A3: packing word size (bits wasted per word)",
        &["k", "u64: lanes/pad bits", "u32: lanes/pad bits", "u64 overhead vs ideal", "u32 overhead"],
    );
    for k in [3u32, 4, 5, 6, 7, 8] {
        let l64 = 64 / k;
        let p64 = 64 - l64 * k;
        let l32 = 32 / k;
        let p32 = 32 - l32 * k;
        t.row(vec![
            k.to_string(),
            format!("{l64} / {p64}"),
            format!("{l32} / {p32}"),
            pct(p64 as f64 / 64.0),
            pct(p32 as f64 / 32.0),
        ]);
    }
    t.print();
    println!("(u64 words waste ≤4.7% for k∈{{3..8}}; u32 would waste up to 6.3% — and halve unpack word-parallelism)");
    Ok(())
}

/// A4 — adaptive selector vs full sweep, on the measured accuracy curves.
pub fn cmd_ablation_selector(root: &Path) -> Result<()> {
    let acc = load_report(root, "accuracy")?;
    let sizes = load_report(root, "sizes")?;
    let mut t = Table::new(
        "Ablation A4: adaptive nesting selection (future-work §5) vs full sweep",
        &["Model", "prior h (Eq12)", "selected h", "sweep critical h", "evals used", "sweep evals"],
    );
    for (arch, a) in acc.as_object()? {
        let Ok(nest) = a.path(&["nest", "8"]) else { continue };
        let full = f(nest, &["full"])?;
        let sweep_crit = nest
            .get("critical_h")
            .filter(|v| !v.is_null())
            .map(|v| v.as_f64().unwrap() as u8);
        let fp32 = f(sizes.get(arch.as_str()).unwrap(), &["fp32_bytes"])? as u64;
        let hs: Vec<u8> = nest.path(&["h"])?.as_object()?
            .iter()
            .map(|(k, _)| k.parse().unwrap())
            .collect();
        let sel = select_critical_h(
            8,
            fp32,
            PAPER_BANDS,
            full,
            SelectorConfig::default(),
            |h| {
                f(nest, &["h", &h.to_string(), "part"])
                    .map_err(|_| anyhow::anyhow!("h={h} not in sweep"))
            },
        )?;
        t.row(vec![
            arch.clone(),
            sel.prior_h.to_string(),
            sel.critical_h.map(|h| h.to_string()).unwrap_or("-".into()),
            sweep_crit.map(|h| h.to_string()).unwrap_or("-".into()),
            sel.evals.len().to_string(),
            hs.len().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

/// Run every ablation.
pub fn cmd_ablations(root: &Path) -> Result<()> {
    cmd_ablation_compensation(root)?;
    cmd_ablation_hysteresis(root)?;
    cmd_ablation_packing()?;
    cmd_ablation_selector(root)
}
