//! Report harness (S10): regenerates every table and figure of the
//! paper's evaluation from the artifacts + live measurements.
//!
//! Each `cmd_*` function prints one paper artifact (markdown-ish rows
//! matching the paper's layout) and, where the paper's own numbers are
//! bit-reproducible (Tables 7/8), asserts them. See DESIGN.md §5 for the
//! experiment index.

mod ablations;
mod tables;

pub use ablations::*;
pub use tables::*;

use crate::util::json::Value;

/// Fixed-width table printer.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{c:<w$} | "));
            }
            s.trim_end().to_string()
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        out.push_str(&format!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        ));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Percent formatting helper.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// MB formatting (paper convention: 1e6 bytes).
pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.1}", bytes as f64 / 1e6)
}

/// Adaptive size formatting: KB below 1MB (our zoo is laptop-scale).
pub fn fmt_size(bytes: u64) -> String {
    if bytes >= 1_000_000 {
        format!("{:.2}MB", bytes as f64 / 1e6)
    } else {
        format!("{:.1}KB", bytes as f64 / 1e3)
    }
}

/// Load one of the report JSONs produced by the Python pipeline.
pub fn load_report(root: &std::path::Path, name: &str) -> anyhow::Result<Value> {
    crate::util::json::parse_file(&root.join("report").join(format!("{name}.json")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["a", "long header", "c"]);
        t.row(vec!["1".into(), "2".into(), "3".into()]);
        t.row(vec!["xxxx".into(), "y".into(), "z".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| xxxx | y           | z |"));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.781), "78.1%");
        assert_eq!(fmt_mb(44_700_000), "44.7");
    }
}
