//! One function per paper table/figure (experiment index in DESIGN.md §5).

use std::path::Path;

use anyhow::{Context, Result};

use crate::container::{Kind, TensorData};
use crate::store::NqArchive;
use crate::device;
use crate::nest::{self, Rounding};
use crate::quant;
use crate::stats;
use crate::transport::{Frame, FrameKind, Meter, PushServer};
use crate::util::json::Value;

use super::{fmt_size, load_report, pct, Table};

fn f(v: &Value, path: &[&str]) -> Result<f64> {
    v.path(path)?.as_f64()
}

fn archs(acc: &Value) -> Vec<String> {
    acc.as_object()
        .map(|o| o.iter().map(|(k, _)| k.clone()).collect())
        .unwrap_or_default()
}

/// Table 7 — nesting numerical errors of signed INT8 numbers (bit-exact
/// reproduction; the assertions ARE the experiment).
pub fn cmd_errors() -> Result<()> {
    let mut t = Table::new(
        "Table 7: Nesting Numerical Errors of Signed INT8 Numbers (256 values)",
        &["Method", "Metric", "INT(8|7)", "INT(8|6)", "INT(8|5)", "INT(8|4)", "INT(8|3)"],
    );
    let methods = [
        ("BitShift", Rounding::BitShift),
        ("RTN", Rounding::Rtn),
        ("RoundingUp", Rounding::Up),
        ("RoundingDown", Rounding::Down),
    ];
    for (name, m) in methods {
        let mut nz = vec![name.to_string(), "#Non-zero".into()];
        let mut rg = vec![name.to_string(), "Range".into()];
        for h in [7u8, 6, 5, 4, 3] {
            let s = nest::error_stats(8, h, m)?;
            nz.push(s.non_zero.to_string());
            rg.push(format!("[{}, {}]", s.min, s.max));
        }
        t.row(nz);
        t.row(rg);
    }
    t.print();
    // paper-exact checks (legible cells of Table 7)
    assert_eq!(nest::error_stats(8, 4, Rounding::Rtn)?.non_zero, 16);
    assert_eq!(nest::error_stats(8, 3, Rounding::Up)?.non_zero, 121);
    println!("✓ matches the paper's Table 7 exactly (and compensation makes all rows zero-error)");
    Ok(())
}

/// Table 8 — ideal nesting storage reduction (exact arithmetic).
pub fn cmd_storage_ideal() -> Result<()> {
    let mut t = Table::new(
        "Table 8: Ideal Nesting Storage Reduction",
        &["NestQuant", "Diverse Bitwidths", "Ideal Reduction", "Paper"],
    );
    let paper = [
        (8u8, 4u8, "25%"),
        (8, 5, "31%"),
        (8, 6, "36%"),
        (8, 7, "40%"),
        (6, 4, "30%"),
        (6, 5, "36%"),
    ];
    for (n, h, want) in paper {
        let r = nest::ideal_storage_reduction(n, h);
        t.row(vec![
            format!("INT({n}|{h})"),
            format!("INT{n}+INT{h}"),
            pct(r),
            want.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

/// Tables 9/10 — measured packed model sizes: NestQuant vs diverse vs FP32.
pub fn cmd_storage(root: &Path, n_filter: Option<u8>) -> Result<()> {
    let sizes = load_report(root, "sizes")?;
    for n in [8u8, 6] {
        if let Some(nf) = n_filter {
            if n != nf {
                continue;
            }
        }
        let mut t = Table::new(
            &format!("Table {}: INT{} Nesting Model Size (measured .nq files)", if n == 8 { 9 } else { 10 }, n),
            &["Model", "n,h", "NestQuant (MB)", "Diverse (MB)", "Reduction", "FP32 (MB)", "FP32 Reduction"],
        );
        for arch in archs(&sizes) {
            let s = sizes.get(&arch).unwrap();
            let fp32 = f(s, &["fp32_container"])? as u64;
            let nest_obj = s.path(&["nest"])?;
            for (key, info) in nest_obj.as_object()? {
                let (kn, kh) = key.split_once('|').context("bad nest key")?;
                let kn: u8 = kn.parse()?;
                let kh: u8 = kh.parse()?;
                if kn != n {
                    continue;
                }
                let nest_total = f(info, &["total"])? as u64;
                let mono_n = f(s, &["mono", &kn.to_string()])? as u64;
                let mono_h = f(s, &["mono", &kh.to_string()])? as u64;
                let diverse = mono_n + mono_h;
                t.row(vec![
                    arch.clone(),
                    format!("{kn},{kh}"),
                    fmt_size(nest_total),
                    fmt_size(diverse),
                    pct(1.0 - nest_total as f64 / diverse as f64),
                    fmt_size(fp32),
                    pct(1.0 - nest_total as f64 / fp32 as f64),
                ]);
            }
        }
        t.print();
    }
    Ok(())
}

/// Table 11 — switching overheads and memory usage (numerical computation
/// from the measured section sizes, exactly the paper's method §4.3.3).
pub fn cmd_switching(root: &Path) -> Result<()> {
    let sizes = load_report(root, "sizes")?;
    let mut t = Table::new(
        "Table 11: Switching Overheads (upgrade: page-in/out; reductions vs diverse)",
        &[
            "Model", "n,h", "NQ in", "NQ out", "Div in", "Div out", "Reduced",
            "Down NQ out", "Down reduced",
        ],
    );
    for arch in archs(&sizes) {
        let s = sizes.get(&arch).unwrap();
        for (key, info) in s.path(&["nest"])?.as_object()? {
            let (kn, kh) = key.split_once('|').context("bad key")?;
            let (kn, kh): (u8, u8) = (kn.parse()?, kh.parse()?);
            let sec_b = f(info, &["section_b"])?;
            let mono_n = f(s, &["mono", &kn.to_string()])?;
            let mono_h = f(s, &["mono", &kh.to_string()])?;
            // Upgrade: NestQuant pages in w_low only, pages out nothing.
            // Diverse pages in INTn and pages out INTh.
            let nq = sec_b;
            let diverse = mono_n + mono_h;
            let reduced = 1.0 - nq / diverse;
            t.row(vec![
                arch.clone(),
                format!("{kn},{kh}"),
                fmt_size(sec_b as u64),
                "0".into(),
                fmt_size(mono_n as u64),
                fmt_size(mono_h as u64),
                pct(reduced),
                fmt_size(sec_b as u64),
                pct(reduced),
            ]);
        }
    }
    t.print();
    println!("(downgrade row mirrors upgrade: NestQuant pages out w_low only; diverse swaps whole models)");
    Ok(())
}

/// Table 4/5 + Figs 3/4 — similarity analysis of decomposed weights, run
/// live on a real quantized model's weights.
pub fn cmd_similarity(root: &Path, arch: &str) -> Result<()> {
    // Gather ŵ, ŵ_high, ŵ_low over all quantized tensors of the INT8 model.
    let sizes = load_report(root, "sizes").ok(); // only to confirm artifacts exist
    let _ = sizes;
    let path = root.join(format!("nq/{arch}_int8.nq"));
    let c = NqArchive::open(&path)?.to_container(false)?;
    anyhow::ensure!(c.kind == Kind::Mono && c.n == 8, "need the INT8 mono container");

    let mut w_int_all: Vec<i32> = Vec::new();
    let mut scales_all: Vec<f32> = Vec::new();
    for t in &c.tensors {
        if let TensorData::Mono { scales, w_int } = &t.data {
            let vals = w_int.unpack();
            let cch = scales.len();
            for (i, v) in vals.iter().enumerate() {
                w_int_all.push(*v);
                scales_all.push(scales[i % cch]);
            }
        }
    }
    println!("\nSimilarity analysis on {} ({} weight elements)", arch, w_int_all.len());

    let deq: Vec<f64> = w_int_all
        .iter()
        .zip(&scales_all)
        .map(|(&w, &s)| w as f64 * s as f64)
        .collect();

    let mut t4 = Table::new(
        &format!("Table 4: Wilcoxon Rank-Sum (nesting {arch})"),
        &["Weights Pair", "INT(8|5)", "INT(8|4)", "INT(8|3)", "INT(8|2)"],
    );
    let mut t5 = Table::new(
        &format!("Table 5: Correlations (nesting {arch})"),
        &["Metric", "Pair", "INT(8|5)", "INT(8|4)", "INT(8|3)", "INT(8|2)"],
    );
    let mut f4 = Table::new(
        "Fig 4: 95% CI upper bounds of Δ_high / Δ_low",
        &["Quantity", "INT(8|5)", "INT(8|4)", "INT(8|3)", "INT(8|2)"],
    );

    let hs = [5u8, 4, 3, 2];
    let mut p_high = Vec::new();
    let mut p_low = Vec::new();
    let mut corr = vec![Vec::new(); 6]; // pearson/spearman/kendall × high/low
    let mut ub_high = Vec::new();
    let mut ub_low = Vec::new();

    // Correlations on the full vectors are O(n log n); subsample for
    // Kendall which is the heaviest, deterministically.
    let stride = (w_int_all.len() / 30_000).max(1);

    for &h in &hs {
        let cfg = nest::NestConfig::new(8, h)?;
        let mut dq_high = Vec::with_capacity(deq.len());
        let mut dq_low = Vec::with_capacity(deq.len());
        let mut d_high = Vec::with_capacity(deq.len());
        let mut d_low = Vec::with_capacity(deq.len());
        for ((&w, &s), &d) in w_int_all.iter().zip(&scales_all).zip(&deq) {
            let hi = nest::high_of(w, cfg, Rounding::Rtn);
            let lo = nest::low_of(w, hi, cfg, true);
            let dh = hi as f64 * s as f64 * cfg.scale_inflation() as f64;
            let dl = lo as f64 * s as f64;
            dq_high.push(dh);
            dq_low.push(dl);
            d_high.push((d - dh).abs());
            d_low.push((d - dl).abs());
        }
        p_high.push(stats::ranksums(&deq, &dq_high)?.p);
        p_low.push(stats::ranksums(&deq, &dq_low)?.p);
        let sub = |v: &[f64]| -> Vec<f64> { v.iter().step_by(stride).cloned().collect() };
        let (ds, dhs, dls) = (sub(&deq), sub(&dq_high), sub(&dq_low));
        corr[0].push(stats::pearson(&ds, &dhs)?);
        corr[1].push(stats::pearson(&ds, &dls)?);
        corr[2].push(stats::spearman(&ds, &dhs)?);
        corr[3].push(stats::spearman(&ds, &dls)?);
        corr[4].push(stats::kendall_tau_b(&ds, &dhs)?);
        corr[5].push(stats::kendall_tau_b(&ds, &dls)?);
        ub_high.push(stats::ci95(&d_high)?.1);
        ub_low.push(stats::ci95(&d_low)?.1);
    }

    let fmtv = |v: &[f64]| v.iter().map(|x| format!("{x:.3}")).collect::<Vec<_>>();
    let mut row = vec!["(ŵ, ŵ_high)".to_string()];
    row.extend(p_high.iter().map(|p| format!("{p:.2}")));
    t4.row(row);
    let mut row = vec!["(ŵ, ŵ_low)".to_string()];
    row.extend(p_low.iter().map(|p| format!("{p:.2}")));
    t4.row(row);
    t4.print();

    let names = [
        ("Pearson", "(ŵ, ŵ_high)", 0),
        ("Pearson", "(ŵ, ŵ_low)", 1),
        ("Spearman", "(ŵ, ŵ_high)", 2),
        ("Spearman", "(ŵ, ŵ_low)", 3),
        ("Kendall", "(ŵ, ŵ_high)", 4),
        ("Kendall", "(ŵ, ŵ_low)", 5),
    ];
    for (metric, pair, i) in names {
        let mut row = vec![metric.to_string(), pair.to_string()];
        row.extend(fmtv(&corr[i]));
        t5.row(row);
    }
    t5.print();

    let mut row = vec!["UB Δ_high".to_string()];
    row.extend(ub_high.iter().map(|x| format!("{x:.4}")));
    f4.row(row);
    let mut row = vec!["UB Δ_low".to_string()];
    row.extend(ub_low.iter().map(|x| format!("{x:.4}")));
    f4.row(row);
    f4.print();

    // Fig 3: histogram series exported as CSV for plotting
    let (edges, counts) = stats::histogram(&deq, 64)?;
    let out = root.join("report/fig3_hist.csv");
    let mut csv = String::from("bin_left,count\n");
    for (e, c) in edges.iter().zip(&counts) {
        csv.push_str(&format!("{e},{c}\n"));
    }
    std::fs::write(&out, csv)?;
    println!("Fig 3 histogram series → {}", out.display());
    println!(
        "shape check: corr(ŵ, ŵ_high) rises toward 1 with h; corr(ŵ, ŵ_low) ≈ 0 — {}",
        if corr[0][0] > 0.95 && corr[1].iter().all(|c| c.abs() < 0.2) {
            "REPRODUCED"
        } else {
            "UNEXPECTED"
        }
    );
    Ok(())
}

/// Table 6 — INT8 nesting test: rounding methods × part/full(±compen.).
pub fn cmd_nesting_test(root: &Path, arch: &str) -> Result<()> {
    let acc = load_report(root, "accuracy")?;
    let a = acc.get(arch).context("arch not in accuracy.json")?;
    let fp32 = f(a, &["fp32"])?;
    let int8 = f(a, &["nest", "8", "full"])?;
    let mut t = Table::new(
        &format!("Table 6: INT8 Nesting Test in {arch} (A8)"),
        &["Method", "W-bit", "Part-Bit", "Full-Bit (w/o compen.)", "Full-Bit"],
    );
    t.row(vec!["-".into(), "FP32".into(), "-".into(), "-".into(), pct(fp32)]);
    t.row(vec!["-".into(), "INT8".into(), "-".into(), "-".into(), pct(int8)]);
    let table6 = a.path(&["table6"])?;
    for (method, label) in [("bitshift", "BitShift"), ("rtn", "RTN"), ("adaptive", "AdaptiveRounding")] {
        if let Some(m) = table6.get(method) {
            for h in [3u8, 4, 5, 6, 7] {
                if let Some(cell) = m.get(&h.to_string()) {
                    t.row(vec![
                        label.into(),
                        format!("INT(8|{h})"),
                        pct(f(cell, &["part"])?),
                        pct(f(cell, &["full_nc"])?),
                        pct(int8), // compensated full-bit is bit-exact
                    ]);
                }
            }
        }
    }
    t.print();
    println!("(compensated Full-Bit equals the INT8 model exactly — verified bit-level by the pipeline)");
    Ok(())
}

/// Figs 10/11/12 + Table 12 — nesting accuracy sweeps per family.
pub fn cmd_nesting(root: &Path, family: Option<&str>, n: u8) -> Result<()> {
    let acc = load_report(root, "accuracy")?;
    let fig = match (family, n) {
        (Some("cnn"), 8) => "Fig 10 (std CNNs, INT8)",
        (Some("cnn"), 6) => "Fig 11 (std CNNs, INT6)",
        (Some("mobile"), _) => "Fig 12 (lightweight, INT8)",
        (Some("vit"), _) => "Table 12 (ViTs, INT8)",
        _ => "nesting sweep",
    };
    let mut t = Table::new(
        &format!("{fig}: part-bit accuracy by nested bits h (A{n})"),
        &["Model", "FP32", &format!("INT{n} full"), "h=7", "h=6", "h=5", "h=4", "h=3", "h=2", "critical"],
    );
    for arch in archs(&acc) {
        if let Some(fam) = family {
            if !arch.starts_with(fam) {
                continue;
            }
        }
        let a = acc.get(&arch).unwrap();
        let nest = match a.path(&["nest", &n.to_string()]) {
            Ok(v) => v,
            Err(_) => continue,
        };
        let full = f(nest, &["full"])?;
        let mut row = vec![arch.clone(), pct(f(a, &["fp32"])?), pct(full)];
        for h in [7u8, 6, 5, 4, 3, 2] {
            match nest.path(&["h", &h.to_string()]) {
                Ok(cell) => row.push(pct(f(cell, &["part"])?)),
                Err(_) => row.push("-".into()),
            }
        }
        let crit = nest
            .get("critical_h")
            .filter(|v| !v.is_null())
            .map(|v| format!("INT({n}|{})", v.as_f64().unwrap() as u8))
            .unwrap_or_else(|| "-".into());
        row.push(crit);
        t.row(row);
    }
    t.print();
    Ok(())
}

/// Fig 6 — the performance cliff: accuracy vs weight bitwidth.
pub fn cmd_cliff(root: &Path) -> Result<()> {
    let acc = load_report(root, "accuracy")?;
    let mut t = Table::new(
        "Fig 6: Performance cliff (monolithic PTQ, A8, W=k)",
        &["Model", "FP32", "INT8", "INT7", "INT6", "INT5", "INT4", "INT3", "INT2"],
    );
    for arch in archs(&acc) {
        let a = acc.get(&arch).unwrap();
        let mut row = vec![arch.clone(), pct(f(a, &["fp32"])?)];
        for k in [8u8, 7, 6, 5, 4, 3, 2] {
            row.push(pct(f(a, &["mono", &k.to_string(), "a8"])?));
        }
        t.row(row);
    }
    t.print();
    Ok(())
}

/// Fig 7 / Eq 12 — critical nested combination vs model size.
pub fn cmd_combos(root: &Path) -> Result<()> {
    let combos = load_report(root, "combos")?;
    let mut t = Table::new(
        "Fig 7: Critical nested combination vs model size",
        &["Model", "Family", "FP32 MB", "n", "critical h", "Eq12 (ours)", "Eq12 (paper bands)"],
    );
    let cuts = combos.path(&["cutoffs_mb"])?;
    let lo = cuts.get("lo").and_then(|v| v.as_f64().ok());
    let hi = cuts.get("hi").and_then(|v| v.as_f64().ok());
    for row in combos.path(&["rows"])?.as_array()? {
        let mb = f(row, &["fp32_mb"])?;
        let n = f(row, &["n"])? as u8;
        let ours = match (lo, hi) {
            (Some(l), Some(h2)) => nest::eq12_critical_h(
                (mb * 1e6) as u64,
                n,
                nest::SizeBands {
                    lo_bytes: (l * 1e6) as u64,
                    hi_bytes: (h2 * 1e6) as u64,
                },
            )
            .to_string(),
            (Some(l), None) => nest::eq12_critical_h(
                (mb * 1e6) as u64,
                n,
                nest::SizeBands {
                    lo_bytes: (l * 1e6) as u64,
                    hi_bytes: u64::MAX,
                },
            )
            .to_string(),
            _ => "-".into(),
        };
        t.row(vec![
            row.path(&["arch"])?.as_str()?.to_string(),
            row.path(&["family"])?.as_str()?.to_string(),
            format!("{mb:.3}"),
            n.to_string(),
            (f(row, &["critical_h"])? as u8).to_string(),
            ours,
            nest::eq12_critical_h((mb * 1e6) as u64, n, nest::PAPER_BANDS).to_string(),
        ]);
    }
    t.print();
    println!(
        "our zoo's re-derived cutoffs (log-midpoint): lo={:?}MB hi={:?}MB (paper: 30/300MB on ImageNet models)",
        lo, hi
    );
    Ok(())
}

/// Figs 13/14 — live TCP network-traffic measurement.
pub fn cmd_traffic(root: &Path, family: Option<&str>) -> Result<()> {
    let sizes = load_report(root, "sizes")?;
    let mut t = Table::new(
        "Figs 13/14: Network traffic (measured wire bytes over localhost TCP)",
        &["Model", "FP32", "Diverse INT8+INTh", "NestQuant (n=8,crit h)", "Saved vs diverse"],
    );
    let acc = load_report(root, "accuracy")?;
    for arch in archs(&sizes) {
        if let Some(fam) = family {
            if !arch.starts_with(fam) {
                continue;
            }
        }
        let crit = acc
            .path(&[&arch, "nest", "8", "critical_h"])
            .ok()
            .and_then(|v| v.as_f64().ok())
            .map(|v| v as u8)
            .unwrap_or(4);
        let send = |paths: Vec<std::path::PathBuf>| -> Result<u64> {
            let frames: Vec<Frame> = paths
                .iter()
                .map(|p| {
                    Ok(Frame {
                        kind: FrameKind::ModelFull,
                        name: p.file_name().unwrap().to_string_lossy().into_owned(),
                        payload: std::fs::read(p)?,
                    })
                })
                .collect::<Result<_>>()?;
            let n = frames.len();
            let server = PushServer::serve_frames(frames, 1)?;
            let meter = Meter::default();
            crate::transport::pull_frames(server.addr, n, &meter)?;
            let (sent, _) = server.join();
            Ok(sent)
        };
        let fp32 = send(vec![root.join(format!("nq/{arch}_fp32.nq"))])?;
        let diverse = send(vec![
            root.join(format!("nq/{arch}_int8.nq")),
            root.join(format!("nq/{arch}_int{crit}.nq")),
        ])?;
        let nest_rel = format!("nq/{arch}_n8h{crit}.nq");
        let nq = if root.join(&nest_rel).exists() {
            send(vec![root.join(&nest_rel)])?
        } else {
            0
        };
        t.row(vec![
            arch.clone(),
            fmt_size(fp32),
            fmt_size(diverse),
            format!("{} (h={crit})", fmt_size(nq)),
            if nq > 0 { pct(1.0 - nq as f64 / diverse as f64) } else { "-".into() },
        ]);
    }
    t.print();
    Ok(())
}

/// Table 13 — comparison vs mixed/dynamic precision methods. QAT/MP rows
/// are the paper's reported numbers (cannot be reproduced without
/// ImageNet training / special hardware) and are marked as such.
pub fn cmd_comparison(root: &Path) -> Result<()> {
    let acc = load_report(root, "accuracy")?;
    let sizes = load_report(root, "sizes")?;
    let mut t = Table::new(
        "Table 13: Mixed/Dynamic precision comparison (our substrate + paper-reported rows)",
        &["Tech", "Method", "W-bit", "Top-1 (%)", "Train", "Data", "HW", "Model size", "Source"],
    );
    t.row(vec![
        "QAT".into(), "AnyPrecision [12]".into(), "INT[8,4,2,1]".into(),
        "68.0/68.0/64.2/54.6".into(), "yes".into(), "yes".into(), "no".into(),
        "FP32".into(), "paper-reported (ResNet-18)".into(),
    ]);
    t.row(vec![
        "QAT".into(), "EQ-Net [13]".into(), "INT[8..2]".into(),
        "70.7/70.7/70.8/70.6/70.3/69.3/65.9".into(), "yes".into(), "yes".into(), "no".into(),
        "FP32".into(), "paper-reported (ResNet-18)".into(),
    ]);
    t.row(vec![
        "MP".into(), "SPARK [14]".into(), "INT4 MP".into(), "69.7".into(),
        "no".into(), "no".into(), "yes".into(), "-".into(), "paper-reported (ResNet-18)".into(),
    ]);
    for arch in archs(&acc) {
        let a = acc.get(&arch).unwrap();
        let s = sizes.get(&arch).unwrap();
        let fp32 = f(a, &["fp32"])?;
        let full = f(a, &["nest", "8", "full"])?;
        let crit = a
            .path(&["nest", "8", "critical_h"])
            .ok()
            .and_then(|v| v.as_f64().ok())
            .map(|v| v as u8);
        let Some(h) = crit else { continue };
        let part = f(a, &["nest", "8", "h", &h.to_string(), "part"])?;
        let nest_sz = f(s, &["nest", &format!("8|{h}")], ).map(|_| 0.0); // placeholder
        let _ = nest_sz;
        let nest_total = f(s.path(&["nest", &format!("8|{h}")])?, &["total"])? as u64;
        let div = f(s, &["mono", "8"])? as u64 + f(s, &["mono", &h.to_string()])? as u64;
        t.row(vec![
            "-".into(), "Pretrained".into(), "FP32".into(), pct(fp32),
            "-".into(), "-".into(), "-".into(),
            fmt_size(f(s, &["fp32_container"])? as u64),
            format!("measured ({arch})"),
        ]);
        t.row(vec![
            "PTQ".into(), "Diverse Bitwidths".into(), format!("INT8+INT{h}"),
            format!("{}/{}", pct(full), pct(f(a, &["mono", &h.to_string(), "a8"])?)),
            "no".into(), "no".into(), "no".into(),
            fmt_size(div), format!("measured ({arch})"),
        ]);
        t.row(vec![
            "PTQ".into(), "NestQuant (ours)".into(), format!("INT(8|{h})"),
            format!("{}/{}", pct(full), pct(part)),
            "no".into(), "no".into(), "no".into(),
            fmt_size(nest_total), format!("measured ({arch})"),
        ]);
    }
    t.print();
    Ok(())
}

/// Table 1 — PTQ optimization cost, re-measured on this substrate
/// (python timings from the pipeline + live Rust timings).
pub fn cmd_ptq_cost(root: &Path) -> Result<()> {
    let cost = load_report(root, "ptq_cost")?;
    let mut t = Table::new(
        "Table 1 (re-measured): PTQ optimization cost on this substrate",
        &["Model", "SQuant INT8 (py)", "RTN INT8 (py)", "SQuant INT8 (rust)", "RTN INT8 (rust)", "Require data"],
    );
    for arch in archs(&cost) {
        let c = cost.get(&arch).unwrap();
        // live rust timing on the real FP32 container
        let path = root.join(format!("nq/{arch}_fp32.nq"));
        let (rust_sq, rust_rtn) = if path.exists() {
            let cont = NqArchive::open(&path)?.to_container(false)?;
            let mut sq = std::time::Duration::ZERO;
            let mut rt = std::time::Duration::ZERO;
            for tens in &cont.tensors {
                if let TensorData::Fp32(vals) = &tens.data {
                    if tens.shape.len() < 2 {
                        continue; // bias
                    }
                    let ch = *tens.shape.last().unwrap();
                    let scales = quant::channel_scales(vals, ch, 8)?;
                    let t0 = std::time::Instant::now();
                    let _ = quant::quantize_adaptive(vals, &scales, 8);
                    sq += t0.elapsed();
                    let t0 = std::time::Instant::now();
                    let _ = quant::quantize_rtn(vals, &scales, 8);
                    rt += t0.elapsed();
                }
            }
            (format!("{:.3}s", sq.as_secs_f64()), format!("{:.3}s", rt.as_secs_f64()))
        } else {
            ("-".into(), "-".into())
        };
        t.row(vec![
            arch.clone(),
            format!("{:.3}s", f(c, &["squant_int8_s"]).unwrap_or(f64::NAN)),
            format!("{:.3}s", f(c, &["rtn_int8_s"]).unwrap_or(f64::NAN)),
            rust_sq,
            rust_rtn,
            "no (data-free)".into(),
        ]);
    }
    t.print();
    println!("paper Table 1 (for reference): BRECQ 1901s / OBQ 5187s / SQuant 2-241s on RTX 2080Ti; SQuant 1445s on RPi 4B");
    Ok(())
}

/// Table 2 — hardware resource conditions (profiles used by the simulator).
pub fn cmd_hardware() -> Result<()> {
    let mut t = Table::new(
        "Table 2: Hardware resource conditions (device-simulator profiles)",
        &["Hardware", "Comput. Perf.", "Memory", "Link"],
    );
    for p in [device::EDGE_SERVER, device::JETSON_NANO, device::RPI_4B, device::RPI_3B_PLUS] {
        t.row(vec![
            p.name.to_string(),
            if p.gflops >= 1000.0 {
                format!("{:.1} TFLOPS", p.gflops / 1000.0)
            } else {
                format!("{:.4} GFLOPS", p.gflops)
            },
            format!("{}GB", p.mem_bytes >> 30),
            format!("{:.0} Mbps", p.link_bytes_per_s * 8.0 / 1e6),
        ]);
    }
    t.print();
    Ok(())
}

/// Table 3 — DL library dtype support + what our PackedTensor covers.
pub fn cmd_libraries() -> Result<()> {
    let mut t = Table::new(
        "Table 3: Quantized dtype support (survey) vs this repo",
        &["Library", "Quantized data types"],
    );
    t.row(vec!["TensorFlow/TFLite".into(), "quint32, quint16, qint16, quint8, qint8".into()]);
    t.row(vec!["PyTorch/PyTorchMobile".into(), "quint8, qint8, quint4x2".into()]);
    t.row(vec!["ONNX/ONNX Runtime".into(), "uint8, int8, uint4x2, int4x2".into()]);
    t.row(vec!["Ncnn".into(), "int8".into()]);
    t.row(vec![
        "nestquant (this repo)".into(),
        "packed signed INT2..INT16 (64//k lanes per u64 word)".into(),
    ]);
    t.print();
    Ok(())
}
