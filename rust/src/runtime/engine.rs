//! Execution engine: compile HLO-text artifacts, hold executables, run
//! batches.
//!
//! Two implementations behind one API:
//!
//! * **`pjrt` feature** — the real PJRT CPU client. Weights are uploaded
//!   to device-resident `PjRtBuffer`s once per model switch; each request
//!   uploads only its input batch and calls `execute_b`, so no weight
//!   bytes move per inference (§Perf L3).
//! * **default (offline)** — a pure-Rust host-buffer engine. Uploads and
//!   weight materialization behave identically (the switching/paging and
//!   fleet-distribution layers never execute a graph), but `run` reports
//!   a clear error directing the caller to `--features pjrt`. This keeps
//!   tier-1 `cargo build --release && cargo test -q` green offline; every
//!   artifact-dependent test skips itself before calling `run`.

use anyhow::{ensure, Context, Result};

use super::manifest::ParamSpec;

// ---------------------------------------------------------------------------
// PJRT-backed implementation
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod imp {
    use std::path::Path;
    use std::sync::Arc;

    use anyhow::{ensure, Context, Result};

    /// Shared PJRT CPU client.
    #[derive(Clone)]
    pub struct Engine {
        client: Arc<xla::PjRtClient>,
    }

    // Safety: the PJRT CPU client is a thread-safe C++ object (the PJRT API
    // contract requires clients be callable from any thread); the Rust
    // wrapper just doesn't declare it. All our mutation goes through &self.
    unsafe impl Send for Engine {}
    unsafe impl Sync for Engine {}

    impl Engine {
        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<Engine> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Engine {
                client: Arc::new(client),
            })
        }

        /// Compile an HLO-text file into an executable.
        pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Executable { exe })
        }

        /// Upload an f32 tensor to a device-resident buffer.
        pub fn upload(&self, data: &[f32], shape: &[usize]) -> Result<DeviceBuffer> {
            let count: usize = shape.iter().product();
            ensure!(
                data.len() == count,
                "shape {shape:?} needs {count} values, got {}",
                data.len()
            );
            let buf = self
                .client
                .buffer_from_host_buffer(data, shape, None)
                .context("uploading buffer")?;
            Ok(DeviceBuffer { buf })
        }
    }

    /// A device-resident tensor.
    pub struct DeviceBuffer {
        buf: xla::PjRtBuffer,
    }

    unsafe impl Send for DeviceBuffer {}
    unsafe impl Sync for DeviceBuffer {}

    /// One compiled (architecture, act-bits) graph.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
    }

    // Safety: see Engine.
    unsafe impl Send for Executable {}
    unsafe impl Sync for Executable {}

    impl Executable {
        /// Execute with `[input, weights...]` device buffers; returns the
        /// flattened f32 output. Graphs are lowered with
        /// `return_tuple=True`, so the single output is a 1-tuple.
        pub fn run(&self, input: &DeviceBuffer, weights: &[DeviceBuffer]) -> Result<Vec<f32>> {
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + weights.len());
            args.push(&input.buf);
            args.extend(weights.iter().map(|w| &w.buf));
            let result = self.exe.execute_b(&args).context("PJRT execute")?;
            let lit = result[0][0].to_literal_sync()?;
            let tuple = lit.to_tuple1()?;
            Ok(tuple.to_vec::<f32>()?)
        }
    }
}

// ---------------------------------------------------------------------------
// Pure-Rust fallback (no PJRT available)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::{Path, PathBuf};

    use anyhow::{bail, ensure, Context, Result};

    /// Host-buffer engine: validates and holds tensors like the PJRT
    /// client, but cannot execute lowered HLO graphs.
    #[derive(Clone)]
    pub struct Engine;

    impl Engine {
        /// Create the fallback engine (always succeeds).
        pub fn cpu() -> Result<Engine> {
            Ok(Engine)
        }

        /// Validate an HLO-text artifact and hold a reference to it. The
        /// file must exist and be non-empty so misconfiguration surfaces
        /// at load time, exactly like the PJRT path.
        pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading HLO text {}", path.display()))?;
            ensure!(!text.is_empty(), "empty HLO artifact {}", path.display());
            Ok(Executable {
                path: path.to_path_buf(),
            })
        }

        /// Upload an f32 tensor to a host-resident buffer.
        pub fn upload(&self, data: &[f32], shape: &[usize]) -> Result<DeviceBuffer> {
            let count: usize = shape.iter().product();
            ensure!(
                data.len() == count,
                "shape {shape:?} needs {count} values, got {}",
                data.len()
            );
            Ok(DeviceBuffer {
                data: data.to_vec(),
                shape: shape.to_vec(),
            })
        }
    }

    /// A host-resident tensor (fallback stand-in for a PJRT buffer).
    pub struct DeviceBuffer {
        data: Vec<f32>,
        shape: Vec<usize>,
    }

    impl DeviceBuffer {
        /// Host view of the buffer (fallback only; useful in tests).
        pub fn host(&self) -> &[f32] {
            &self.data
        }

        /// Logical shape of the buffer.
        pub fn shape(&self) -> &[usize] {
            &self.shape
        }
    }

    /// A validated-but-uncompiled graph reference.
    pub struct Executable {
        path: PathBuf,
    }

    impl Executable {
        /// Graph execution needs PJRT; the fallback reports why.
        pub fn run(&self, _input: &DeviceBuffer, _weights: &[DeviceBuffer]) -> Result<Vec<f32>> {
            bail!(
                "cannot execute {}: nestquant was built without the `pjrt` feature \
                 (rebuild with `--features pjrt` to run lowered HLO graphs)",
                self.path.display()
            )
        }
    }
}

pub use imp::{DeviceBuffer, Engine, Executable};

impl Engine {
    /// Upload every weight tensor in spec order. Takes borrowed slices
    /// so callers can feed dequantized scratch buffers (or
    /// `store`-view-decoded tensors) without building owned `Vec<Vec>`s.
    pub fn upload_weights(
        &self,
        values: &[&[f32]],
        specs: &[ParamSpec],
    ) -> Result<Vec<DeviceBuffer>> {
        ensure!(values.len() == specs.len(), "param count mismatch");
        specs
            .iter()
            .zip(values)
            .map(|(s, v)| self.upload(v, &s.shape).with_context(|| s.name.clone()))
            .collect()
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn fallback_upload_validates_shape() {
        let e = Engine::cpu().unwrap();
        let buf = e.upload(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(buf.host(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(buf.shape(), &[2, 2]);
        assert!(e.upload(&[1.0; 3], &[2, 2]).is_err());
    }

    #[test]
    fn upload_weights_in_spec_order() {
        let e = Engine::cpu().unwrap();
        let specs = vec![
            ParamSpec {
                name: "w".into(),
                shape: vec![2, 2],
                quantized: true,
            },
            ParamSpec {
                name: "b".into(),
                shape: vec![2],
                quantized: false,
            },
        ];
        let w = [1.0f32, 2.0, 3.0, 4.0];
        let b = [0.5f32, 0.25];
        let bufs = e.upload_weights(&[&w, &b], &specs).unwrap();
        assert_eq!(bufs.len(), 2);
        assert_eq!(bufs[0].host(), &w);
        assert_eq!(bufs[1].shape(), &[2]);
        assert!(e.upload_weights(&[&w[..]], &specs).is_err(), "count mismatch");
    }

    #[test]
    fn fallback_load_hlo_checks_file() {
        let e = Engine::cpu().unwrap();
        assert!(e.load_hlo(Path::new("/nonexistent/x.hlo.txt")).is_err());
        let dir = std::env::temp_dir().join(format!("nq_engine_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("toy.hlo.txt");
        std::fs::write(&p, "HloModule toy\n").unwrap();
        let exe = e.load_hlo(&p).unwrap();
        let x = e.upload(&[0.0], &[1]).unwrap();
        let err = exe.run(&x, &[]).unwrap_err();
        assert!(format!("{err}").contains("pjrt"));
    }
}
