//! PJRT engine: compile HLO-text artifacts, hold executables, run batches.
//!
//! Hot-path design: weights are uploaded to device-resident `PjRtBuffer`s
//! once per model switch; each request uploads only its input batch and
//! calls `execute_b`, so no weight bytes move per inference (§Perf L3).

use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::manifest::ParamSpec;

/// Shared PJRT CPU client.
#[derive(Clone)]
pub struct Engine {
    client: Arc<xla::PjRtClient>,
}

// Safety: the PJRT CPU client is a thread-safe C++ object (the PJRT API
// contract requires clients be callable from any thread); the Rust
// wrapper just doesn't declare it. All our mutation goes through &self.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client: Arc::new(client),
        })
    }

    /// Compile an HLO-text file into an executable.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }

    /// Upload an f32 tensor to a device-resident buffer.
    pub fn upload(&self, data: &[f32], shape: &[usize]) -> Result<DeviceBuffer> {
        let count: usize = shape.iter().product();
        ensure!(
            data.len() == count,
            "shape {shape:?} needs {count} values, got {}",
            data.len()
        );
        let buf = self
            .client
            .buffer_from_host_buffer(data, shape, None)
            .context("uploading buffer")?;
        Ok(DeviceBuffer { buf })
    }

    /// Upload every weight tensor in spec order.
    pub fn upload_weights(
        &self,
        values: &[Vec<f32>],
        specs: &[ParamSpec],
    ) -> Result<Vec<DeviceBuffer>> {
        ensure!(values.len() == specs.len(), "param count mismatch");
        specs
            .iter()
            .zip(values)
            .map(|(s, v)| self.upload(v, &s.shape).with_context(|| s.name.clone()))
            .collect()
    }
}

/// A device-resident tensor.
pub struct DeviceBuffer {
    buf: xla::PjRtBuffer,
}

unsafe impl Send for DeviceBuffer {}
unsafe impl Sync for DeviceBuffer {}

/// One compiled (architecture, act-bits) graph.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

// Safety: see Engine.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with `[input, weights...]` device buffers; returns the
    /// flattened f32 output. Graphs are lowered with `return_tuple=True`,
    /// so the single output is a 1-tuple.
    pub fn run(&self, input: &DeviceBuffer, weights: &[DeviceBuffer]) -> Result<Vec<f32>> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + weights.len());
        args.push(&input.buf);
        args.extend(weights.iter().map(|w| &w.buf));
        let result = self.exe.execute_b(&args).context("PJRT execute")?;
        let lit = result[0][0].to_literal_sync()?;
        let tuple = lit.to_tuple1()?;
        Ok(tuple.to_vec::<f32>()?)
    }
}
