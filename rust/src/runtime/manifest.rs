//! `artifacts/manifest.json` parsing: the contract between the Python
//! build path and the Rust request path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

/// One model parameter: name, shape, whether it is weight-quantized.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub quantized: bool,
}

impl ParamSpec {
    pub fn count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One architecture's artifact set.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub params: Vec<ParamSpec>,
    /// act_bits ("0", "6", "8") → HLO text path (relative to artifacts/).
    pub hlo: BTreeMap<u8, String>,
    /// "n|h" → nest container path.
    pub nest_containers: BTreeMap<String, String>,
    /// bits → mono container path.
    pub mono_containers: BTreeMap<u8, String>,
    pub fp32_container: String,
    /// Golden logits: key → raw f32 path.
    pub expected: BTreeMap<String, String>,
}

impl ModelSpec {
    /// Container path for an INT(n|h) nest model, if built.
    pub fn nest_container(&self, n: u8, h: u8) -> Option<&str> {
        self.nest_containers.get(&format!("{n}|{h}")).map(|s| s.as_str())
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub batch: usize,
    pub img: usize,
    pub channels: usize,
    pub num_classes: usize,
    pub val_count: usize,
    pub val_x: String,
    pub val_y: String,
    pub models: BTreeMap<String, ModelSpec>,
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: &Path) -> Result<Manifest> {
        let doc = json::parse_file(&root.join("manifest.json"))?;
        let batch = doc.path(&["batch"])?.as_usize()?;
        let img = doc.path(&["img"])?.as_usize()?;
        let channels = doc.path(&["channels"])?.as_usize()?;
        let num_classes = doc.path(&["num_classes"])?.as_usize()?;
        let val_count = doc.path(&["data", "count"])?.as_usize()?;
        let val_x = doc.path(&["data", "val_x"])?.as_str()?.to_string();
        let val_y = doc.path(&["data", "val_y"])?.as_str()?.to_string();

        let mut models = BTreeMap::new();
        for (name, m) in doc.path(&["models"])?.as_object()? {
            models.insert(name.clone(), parse_model(name, m)
                .with_context(|| format!("model {name}"))?);
        }
        Ok(Manifest {
            root: root.to_path_buf(),
            batch,
            img,
            channels,
            num_classes,
            val_count,
            val_x,
            val_y,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model {name:?} (have: {:?})",
                self.models.keys().collect::<Vec<_>>()))
    }

    /// Absolute path for an artifacts-relative path.
    pub fn abs(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    /// Load the validation images (flattened NHWC f32).
    pub fn load_val(&self) -> Result<(Vec<f32>, Vec<u32>)> {
        let x = crate::util::read_f32_file(&self.abs(&self.val_x))?;
        let y = crate::util::read_u32_file(&self.abs(&self.val_y))?;
        anyhow::ensure!(y.len() == self.val_count, "label count mismatch");
        anyhow::ensure!(
            x.len() == self.val_count * self.img * self.img * self.channels,
            "image data size mismatch"
        );
        Ok((x, y))
    }
}

fn parse_model(name: &str, m: &Value) -> Result<ModelSpec> {
    let mut params = Vec::new();
    for p in m.path(&["params"])?.as_array()? {
        params.push(ParamSpec {
            name: p.path(&["name"])?.as_str()?.to_string(),
            shape: p
                .path(&["shape"])?
                .as_array()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            quantized: p.path(&["quantized"])?.as_bool()?,
        });
    }
    let mut hlo = BTreeMap::new();
    for (k, v) in m.path(&["hlo"])?.as_object()? {
        hlo.insert(k.parse::<u8>()?, v.as_str()?.to_string());
    }
    let mut nest_containers = BTreeMap::new();
    for (k, v) in m.path(&["containers", "nest"])?.as_object()? {
        nest_containers.insert(k.clone(), v.as_str()?.to_string());
    }
    let mut mono_containers = BTreeMap::new();
    for (k, v) in m.path(&["containers", "mono"])?.as_object()? {
        mono_containers.insert(k.parse::<u8>()?, v.as_str()?.to_string());
    }
    let mut expected = BTreeMap::new();
    for (k, v) in m.path(&["expected"])?.as_object()? {
        expected.insert(k.clone(), v.as_str()?.to_string());
    }
    Ok(ModelSpec {
        name: name.to_string(),
        params,
        hlo,
        nest_containers,
        mono_containers,
        fp32_container: m.path(&["containers", "fp32"])?.as_str()?.to_string(),
        expected,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest_when_built() {
        let root = crate::artifacts_dir();
        if !root.join("manifest.json").exists() {
            eprintln!("skipping (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&root).unwrap();
        assert!(m.batch > 0 && m.num_classes == 10);
        assert!(!m.models.is_empty());
        for (name, spec) in &m.models {
            assert!(!spec.params.is_empty(), "{name}");
            assert!(spec.hlo.contains_key(&8), "{name} missing a8 HLO");
            assert!(spec.params.iter().any(|p| p.quantized));
            // every referenced file exists
            for rel in spec.hlo.values() {
                assert!(m.abs(rel).exists(), "{rel}");
            }
            assert!(m.abs(&spec.fp32_container).exists());
        }
    }
}
