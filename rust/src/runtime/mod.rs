//! PJRT runtime (S8): load the AOT-lowered HLO text artifacts and execute
//! them on the CPU PJRT client from the request path.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* → HloModuleProto
//! → XlaComputation → compile → execute. One compiled executable per
//! (architecture, act-bits) pair; weights are execution *arguments*, so
//! the NestQuant model switch never recompiles anything — it only swaps
//! the cached weight literals (see coordinator::manager).

mod engine;
mod manifest;

pub use engine::{DeviceBuffer, Engine, Executable};
pub use manifest::{Manifest, ModelSpec, ParamSpec};
