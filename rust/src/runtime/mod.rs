//! Runtime (S8): load the AOT-lowered HLO text artifacts and execute
//! them from the request path.
//!
//! With the `pjrt` feature: HLO *text* → HloModuleProto → XlaComputation
//! → compile → execute on the CPU PJRT client. One compiled executable
//! per (architecture, act-bits) pair; weights are execution *arguments*,
//! so the NestQuant model switch never recompiles anything — it only
//! swaps the cached weight literals (see coordinator::manager).
//!
//! Without the feature (the offline tier-1 build) a pure-Rust fallback
//! engine provides the same API; see `engine.rs`.

mod engine;
mod manifest;

pub use engine::{DeviceBuffer, Engine, Executable};
pub use manifest::{Manifest, ModelSpec, ParamSpec};
