//! Statistics substrate (S4) for the paper's similarity analysis (§3.2.2):
//! Wilcoxon rank-sum (Table 4), Pearson/Spearman/Kendall correlations
//! (Table 5), Gaussian KDE and percentile confidence intervals (Figs 3/4).
//!
//! Implementations follow the scipy definitions; cargo test validates
//! against scipy-generated goldens in `artifacts/golden/stats_golden.json`
//! (written by the Python test-suite, seeds fixed).

use anyhow::{ensure, Result};

// ---------------------------------------------------------------------------
// ranks
// ---------------------------------------------------------------------------

/// Midranks (average rank for ties), 1-based — scipy.stats.rankdata.
pub fn midranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut ranks = vec![0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j + 2) as f64 / 2.0; // average of 1-based ranks i+1..=j+1
        for k in i..=j {
            ranks[idx[k]] = avg;
        }
        i = j + 1;
    }
    ranks
}

// ---------------------------------------------------------------------------
// Wilcoxon rank-sum (Table 4)
// ---------------------------------------------------------------------------

/// Result of a two-sided Wilcoxon rank-sum test (scipy.stats.ranksums).
#[derive(Debug, Clone, Copy)]
pub struct RankSum {
    pub z: f64,
    pub p: f64,
}

/// Two-sided Wilcoxon rank-sum with the normal approximation
/// (scipy.stats.ranksums; no tie correction, matching scipy).
pub fn ranksums(a: &[f64], b: &[f64]) -> Result<RankSum> {
    let (n1, n2) = (a.len() as f64, b.len() as f64);
    ensure!(n1 > 0.0 && n2 > 0.0, "empty sample");
    let mut all: Vec<f64> = Vec::with_capacity(a.len() + b.len());
    all.extend_from_slice(a);
    all.extend_from_slice(b);
    let ranks = midranks(&all);
    let s: f64 = ranks[..a.len()].iter().sum();
    let expected = n1 * (n1 + n2 + 1.0) / 2.0;
    let var = n1 * n2 * (n1 + n2 + 1.0) / 12.0;
    let z = (s - expected) / var.sqrt();
    let p = 2.0 * (1.0 - std_normal_cdf(z.abs()));
    Ok(RankSum { z, p })
}

/// Standard normal CDF via erfc (Abramowitz–Stegun 7.1.26-based erf).
pub fn std_normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Complementary error function to near machine precision, via the
/// regularized incomplete gamma function P(1/2, x²) (series + Lentz
/// continued fraction — Numerical Recipes gser/gcf).
pub fn erfc(x: f64) -> f64 {
    if x == 0.0 {
        return 1.0;
    }
    let p = gammp_half(x * x); // P(1/2, x²) = erf(|x|)
    if x > 0.0 {
        1.0 - p
    } else {
        1.0 + p
    }
}

/// Regularized lower incomplete gamma P(1/2, x).
fn gammp_half(x: f64) -> f64 {
    const A: f64 = 0.5;
    let gln = 0.5723649429247001_f64; // ln Γ(1/2) = ln √π
    if x < A + 1.0 {
        // series representation
        let mut ap = A;
        let mut sum = 1.0 / A;
        let mut del = sum;
        for _ in 0..200 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-16 {
                break;
            }
        }
        sum * (-x + A * x.ln() - gln).exp()
    } else {
        // continued fraction for Q, then P = 1 - Q (modified Lentz)
        let tiny = 1e-300;
        let mut b = x + 1.0 - A;
        let mut c = 1.0 / tiny;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..200 {
            let an = -(i as f64) * (i as f64 - A);
            b += 2.0;
            d = an * d + b;
            if d.abs() < tiny {
                d = tiny;
            }
            c = b + an / c;
            if c.abs() < tiny {
                c = tiny;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-16 {
                break;
            }
        }
        let q = (-x + A * x.ln() - gln).exp() * h;
        1.0 - q
    }
}

// ---------------------------------------------------------------------------
// correlations (Table 5)
// ---------------------------------------------------------------------------

/// Pearson linear correlation.
pub fn pearson(a: &[f64], b: &[f64]) -> Result<f64> {
    ensure!(a.len() == b.len() && a.len() >= 2, "need paired samples");
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let (mut sab, mut saa, mut sbb) = (0.0, 0.0, 0.0);
    for (&x, &y) in a.iter().zip(b) {
        let (dx, dy) = (x - ma, y - mb);
        sab += dx * dy;
        saa += dx * dx;
        sbb += dy * dy;
    }
    ensure!(saa > 0.0 && sbb > 0.0, "zero variance");
    Ok(sab / (saa * sbb).sqrt())
}

/// Spearman rank correlation (Pearson on midranks).
pub fn spearman(a: &[f64], b: &[f64]) -> Result<f64> {
    pearson(&midranks(a), &midranks(b))
}

/// Kendall tau-b with tie correction — O(n log n) via merge-sort inversion
/// counting (matches scipy.stats.kendalltau for real data sizes).
pub fn kendall_tau_b(a: &[f64], b: &[f64]) -> Result<f64> {
    ensure!(a.len() == b.len() && a.len() >= 2, "need paired samples");
    let n = a.len();
    // sort by a, then b
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| {
        a[i].partial_cmp(&a[j])
            .unwrap()
            .then(b[i].partial_cmp(&b[j]).unwrap())
    });
    let bs: Vec<f64> = idx.iter().map(|&i| b[i]).collect();
    let asrt: Vec<f64> = idx.iter().map(|&i| a[i]).collect();

    // tie counts
    let tie_pairs = |xs: &[f64]| -> f64 {
        let mut sorted = xs.to_vec();
        sorted.sort_by(|p, q| p.partial_cmp(q).unwrap());
        let mut t = 0f64;
        let mut i = 0;
        while i < sorted.len() {
            let mut j = i;
            while j + 1 < sorted.len() && sorted[j + 1] == sorted[i] {
                j += 1;
            }
            let c = (j - i + 1) as f64;
            t += c * (c - 1.0) / 2.0;
            i = j + 1;
        }
        t
    };
    let n_pairs = (n * (n - 1) / 2) as f64;
    let t_a = tie_pairs(a);
    let t_b = tie_pairs(b);
    // joint ties (both a and b equal)
    let mut t_ab = 0f64;
    {
        let mut i = 0;
        while i < n {
            let mut j = i;
            while j + 1 < n && asrt[j + 1] == asrt[i] && bs[j + 1] == bs[i] {
                j += 1;
            }
            let c = (j - i + 1) as f64;
            t_ab += c * (c - 1.0) / 2.0;
            i = j + 1;
        }
    }
    // discordant pairs = inversions in bs restricted to strictly-increasing a
    // standard Knight's algorithm: count swaps in mergesort of bs
    let mut arr = bs.clone();
    let mut tmp = vec![0f64; n];
    let discordant = merge_count(&mut arr, &mut tmp);
    // concordant + discordant = n_pairs - t_a - t_b + t_ab
    let con_plus_dis = n_pairs - t_a - t_b + t_ab;
    let concordant = con_plus_dis - discordant;
    let denom = ((n_pairs - t_a) * (n_pairs - t_b)).sqrt();
    ensure!(denom > 0.0, "degenerate ties");
    Ok((concordant - discordant) / denom)
}

fn merge_count(arr: &mut [f64], tmp: &mut [f64]) -> f64 {
    let n = arr.len();
    if n <= 1 {
        return 0.0;
    }
    let mid = n / 2;
    let (left, right) = arr.split_at_mut(mid);
    let mut inv = merge_count(left, &mut tmp[..mid]) + merge_count(right, &mut tmp[mid..]);
    // merge, counting strict inversions (left > right)
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    while i < left.len() && j < right.len() {
        if left[i] <= right[j] {
            tmp[k] = left[i];
            i += 1;
        } else {
            tmp[k] = right[j];
            j += 1;
            inv += (left.len() - i) as f64;
        }
        k += 1;
    }
    while i < left.len() {
        tmp[k] = left[i];
        i += 1;
        k += 1;
    }
    while j < right.len() {
        tmp[k] = right[j];
        j += 1;
        k += 1;
    }
    arr.copy_from_slice(&tmp[..n]);
    inv
}

// ---------------------------------------------------------------------------
// KDE + CIs (Figs 3/4)
// ---------------------------------------------------------------------------

/// Gaussian KDE evaluated on a uniform grid (Scott's bandwidth).
pub fn kde(xs: &[f64], grid_points: usize) -> Result<(Vec<f64>, Vec<f64>)> {
    ensure!(xs.len() >= 2, "need ≥2 samples");
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let std = (xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0)).sqrt();
    let bw = (std * n.powf(-0.2)).max(1e-9); // Scott's rule
    let (lo, hi) = xs
        .iter()
        .fold((f64::MAX, f64::MIN), |(l, h), &x| (l.min(x), h.max(x)));
    let (lo, hi) = (lo - 3.0 * bw, hi + 3.0 * bw);
    let step = (hi - lo) / (grid_points - 1) as f64;
    let norm = 1.0 / (n * bw * (2.0 * std::f64::consts::PI).sqrt());
    let grid: Vec<f64> = (0..grid_points).map(|i| lo + i as f64 * step).collect();
    let dens: Vec<f64> = grid
        .iter()
        .map(|&g| {
            xs.iter()
                .map(|&x| (-(g - x).powi(2) / (2.0 * bw * bw)).exp())
                .sum::<f64>()
                * norm
        })
        .collect();
    Ok((grid, dens))
}

/// Linear-interpolated percentile (numpy default), q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> Result<f64> {
    ensure!(!xs.is_empty(), "empty sample");
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (s.len() - 1) as f64;
    let i = pos.floor() as usize;
    let frac = pos - i as f64;
    if i + 1 < s.len() {
        Ok(s[i] * (1.0 - frac) + s[i + 1] * frac)
    } else {
        Ok(s[i])
    }
}

/// 95% percentile confidence interval (Fig 4's [LB, UB]).
pub fn ci95(xs: &[f64]) -> Result<(f64, f64)> {
    Ok((percentile(xs, 2.5)?, percentile(xs, 97.5)?))
}

/// Sample mean and (ddof=1) standard deviation.
pub fn mean_std(xs: &[f64]) -> Result<(f64, f64)> {
    ensure!(xs.len() >= 2, "need ≥2 samples");
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1.0);
    Ok((mean, var.sqrt()))
}

/// Equal-width histogram over [min, max] (Fig 3's distribution series).
pub fn histogram(xs: &[f64], bins: usize) -> Result<(Vec<f64>, Vec<u64>)> {
    ensure!(!xs.is_empty() && bins > 0, "empty input");
    let (lo, hi) = xs
        .iter()
        .fold((f64::MAX, f64::MIN), |(l, h), &x| (l.min(x), h.max(x)));
    let span = (hi - lo).max(1e-12);
    let mut counts = vec![0u64; bins];
    for &x in xs {
        let b = (((x - lo) / span) * bins as f64) as usize;
        counts[b.min(bins - 1)] += 1;
    }
    let edges: Vec<f64> = (0..=bins)
        .map(|i| lo + span * i as f64 / bins as f64)
        .collect();
    Ok((edges, counts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;
    use std::path::Path;

    #[test]
    fn midranks_with_ties() {
        let r = midranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b).unwrap() - 1.0).abs() < 1e-12);
        let c = [-2.0, -4.0, -6.0, -8.0];
        assert!((pearson(&a, &c).unwrap() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotonic() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0, 8.0, 27.0, 64.0, 125.0]; // monotone, nonlinear
        assert!((spearman(&a, &b).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_small_exact() {
        // classic example: tau of reversed sequence is -1
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [4.0, 3.0, 2.0, 1.0];
        assert!((kendall_tau_b(&a, &b).unwrap() + 1.0).abs() < 1e-12);
        let c = [1.0, 2.0, 3.0, 4.0];
        assert!((kendall_tau_b(&a, &c).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranksum_symmetric_same_distribution() {
        // identical samples → z = 0 exactly (rank sum hits expectation)
        let a: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let r = ranksums(&a, &a).unwrap();
        assert!(r.z.abs() < 1e-9);
        assert!((r.p - 1.0).abs() < 1e-9);
    }

    #[test]
    fn normal_cdf_known_values() {
        assert!((std_normal_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!((std_normal_cdf(1.959964) - 0.975).abs() < 1e-5);
        assert!((std_normal_cdf(-1.959964) - 0.025).abs() < 1e-5);
    }

    #[test]
    fn percentile_matches_numpy_linear() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 50.0).unwrap() - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0).unwrap() - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0).unwrap() - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0).unwrap() - 1.75).abs() < 1e-12);
    }

    #[test]
    fn kde_integrates_to_one() {
        let xs: Vec<f64> = (0..200).map(|i| (i as f64 * 0.7).sin()).collect();
        let (grid, dens) = kde(&xs, 256).unwrap();
        let step = grid[1] - grid[0];
        let integral: f64 = dens.iter().sum::<f64>() * step;
        assert!((integral - 1.0).abs() < 0.02, "integral {integral}");
    }

    #[test]
    fn histogram_counts_everything() {
        let xs = [0.0, 0.1, 0.5, 0.9, 1.0];
        let (edges, counts) = histogram(&xs, 2).unwrap();
        assert_eq!(edges.len(), 3);
        assert_eq!(counts.iter().sum::<u64>(), 5);
    }

    /// scipy goldens (written by python/tests/test_stats_golden.py).
    #[test]
    fn matches_scipy_goldens() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden/stats_golden.json");
        if !path.exists() {
            eprintln!("skipping scipy goldens (run `make artifacts` first)");
            return;
        }
        let doc = json::parse_file(&path).unwrap();
        for case in doc.as_array().unwrap() {
            let a: Vec<f64> = case.get("a").unwrap().as_array().unwrap()
                .iter().map(|v| v.as_f64().unwrap()).collect();
            let b: Vec<f64> = case.get("b").unwrap().as_array().unwrap()
                .iter().map(|v| v.as_f64().unwrap()).collect();
            let n = a.len().min(b.len());
            let g = |k: &str| case.get(k).unwrap().as_f64().unwrap();

            assert!((pearson(&a[..n], &b[..n]).unwrap() - g("pearson")).abs() < 1e-9);
            assert!((spearman(&a[..n], &b[..n]).unwrap() - g("spearman")).abs() < 1e-9);
            assert!((kendall_tau_b(&a[..n], &b[..n]).unwrap() - g("kendall")).abs() < 1e-9);
            let rs = ranksums(&a, &b).unwrap();
            assert!((rs.z - g("wilcoxon_z")).abs() < 1e-7, "z {} vs {}", rs.z, g("wilcoxon_z"));
            assert!((rs.p - g("wilcoxon_p")).abs() < 1e-6);
            let (mean, std) = mean_std(&a).unwrap();
            assert!((mean - g("mean_a")).abs() < 1e-9);
            assert!((std - g("std_a")).abs() < 1e-9);
            assert!((percentile(&a, 2.5).unwrap() - g("percentile_a_2_5")).abs() < 1e-9);
            assert!((percentile(&a, 97.5).unwrap() - g("percentile_a_97_5")).abs() < 1e-9);
        }
    }
}
