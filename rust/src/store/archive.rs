//! [`NqArchive`]: one opened `.nq` artifact, and [`ModelStore`]: the
//! id → shared-archive registry.
//!
//! The archive is the single owner of an artifact's bytes: section A is
//! fetched once and shared (a [`Bytes`] handle — owned heap bytes, or
//! an OS-paged mmap window from the default [`MmapSource`]), the tensor
//! layout is parsed once, and section B attaches/detaches as one handle
//! — so the coordinator's upgrade path moves exactly the section-B
//! bytes and the downgrade path moves nothing. [`ArchiveStats`] counts
//! every fetch and parse; tests assert the zeros instead of trusting
//! comments.
//!
//! Integrity is lazy: when the artifact carries a CRC-64 trailer, each
//! section is hashed on its *first touch* and the verdict memoized —
//! opening a 1000-archive zoo costs one header probe per archive, and a
//! part↔full switch storm re-hashes nothing. A memoized failure keeps
//! failing (without re-reading); the untouched section keeps serving.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, ensure, Context, Result};

use crate::container::{self, Container, Kind, SectionIndex};
use crate::faults;
use crate::nq_trace;
use crate::telemetry::{registry, TraceKind};

use super::layout::{FullBitModel, ModelLayout, PartBitModel};
use super::{Bytes, MemorySource, MmapSource, Section, SectionSource};

/// Byte-accounting counters of one archive. Monotonic; snapshot via
/// [`NqArchive::stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArchiveStats {
    /// Section-A fetches from the source (1 after any number of
    /// part↔full switches — the "zero section-A re-reads" claim).
    pub a_fetches: u64,
    /// Section-B fetches (one per upgrade after a release).
    pub b_fetches: u64,
    /// Section-A bytes moved out of the source.
    pub a_bytes_fetched: u64,
    /// Section-B bytes moved out of the source.
    pub b_bytes_fetched: u64,
    /// Layout parses (1 for the archive's lifetime — the "zero
    /// re-parses" claim).
    pub layout_parses: u64,
    /// Section-B releases (downgrades / unloads).
    pub b_releases: u64,
    /// Of `a_bytes_fetched`, bytes that arrived as mmap windows (OS-
    /// paged — no heap copy, not counted in the resident gauges).
    pub a_bytes_mapped: u64,
    /// Of `b_bytes_fetched`, bytes that arrived as mmap windows.
    pub b_bytes_mapped: u64,
}

struct State {
    a: Option<Bytes>,
    b: Option<Bytes>,
    layout: Option<Arc<ModelLayout>>,
    stats: ArchiveStats,
    /// Memoized CRC verdicts (lazy first-touch integrity): `None` =
    /// never hashed, `Some(ok)` = hashed once, verdict stands for the
    /// archive's lifetime (sources are immutable by contract).
    crc_a: Option<bool>,
    crc_b: Option<bool>,
}

/// One opened `.nq` artifact over a [`SectionSource`].
///
/// Thread-safe; fetches hold the archive's internal lock for their
/// duration, so concurrent sessions of the same archive single-flight
/// their section reads (the fleet server's budgeted [`SectionCache`]
/// covers the many-archive case).
///
/// [`SectionCache`]: crate::fleet::SectionCache
pub struct NqArchive {
    source: Arc<dyn SectionSource>,
    index: SectionIndex,
    state: Mutex<State>,
}

impl NqArchive {
    /// Open over any source (probes the index once, eagerly — it is the
    /// one thing every consumer needs).
    pub fn with_source(source: Arc<dyn SectionSource>) -> Result<NqArchive> {
        let index = source
            .index()
            .with_context(|| format!("indexing {}", source.describe()))?;
        registry().store.archive_opens.inc();
        Ok(NqArchive {
            source,
            index,
            state: Mutex::new(State {
                a: None,
                b: None,
                layout: None,
                stats: ArchiveStats::default(),
                crc_a: None,
                crc_b: None,
            }),
        })
    }

    /// Open a `.nq` file (header probe only; no payload reads). The
    /// default source is [`MmapSource`]: sections arrive as OS-paged
    /// windows where `mmap(2)` is available and as positioned reads
    /// everywhere else.
    pub fn open(path: impl AsRef<Path>) -> Result<NqArchive> {
        NqArchive::with_source(Arc::new(MmapSource::new(path.as_ref())))
    }

    /// Wrap a whole in-memory artifact.
    pub fn from_bytes(data: &[u8]) -> Result<NqArchive> {
        NqArchive::with_source(Arc::new(MemorySource::new(data)?))
    }

    /// Serialize a [`Container`] and wrap it (synthetic zoos, tests).
    pub fn from_container(c: &Container) -> Result<NqArchive> {
        NqArchive::with_source(Arc::new(MemorySource::from_container(c)?))
    }

    pub fn index(&self) -> &SectionIndex {
        &self.index
    }

    pub fn kind(&self) -> Kind {
        self.index.kind
    }

    pub fn source(&self) -> &Arc<dyn SectionSource> {
        &self.source
    }

    /// Section-A bytes (the part-bit page-in cost).
    pub fn section_a_bytes(&self) -> u64 {
        self.index.section_a_bytes()
    }

    /// Section-B bytes (the upgrade delta).
    pub fn section_b_bytes(&self) -> u64 {
        self.index.section_b_bytes()
    }

    /// The archive's internal state, recovering from lock poisoning: a
    /// worker panic isolated by `catch_unwind` must not brick a shared
    /// archive (section caches are `Option`s, so any observed state is
    /// servable; stats are best-effort across a panic).
    fn state(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn stats(&self) -> ArchiveStats {
        self.state().stats
    }

    pub fn a_resident(&self) -> bool {
        self.state().a.is_some()
    }

    pub fn b_resident(&self) -> bool {
        self.state().b.is_some()
    }

    /// Section A, fetching it from the source on first use only.
    /// Failpoint: `store.read_a`.
    pub fn ensure_a(&self) -> Result<Bytes> {
        let mut s = self.state();
        if let Some(a) = &s.a {
            return Ok(a.clone());
        }
        faults::fail_point("store.read_a")
            .with_context(|| format!("fetching section A of {}", self.source.describe()))?;
        let a = self
            .source
            .fetch(Section::A)
            .with_context(|| format!("fetching section A of {}", self.source.describe()))?;
        ensure!(
            a.len() as u64 == self.index.section_a_bytes(),
            "section A fetch returned {} bytes, index says {}",
            a.len(),
            self.index.section_a_bytes()
        );
        if let Some(ck) = self.index.checksums {
            // integrity trailer present: the payload must match it bit-
            // for-bit (geometry checks can't catch payload flips). The
            // hash runs on first touch only and the verdict is memoized
            // — a re-fetch after release never re-hashes, and a failed
            // section keeps failing without re-reading. Failpoint
            // `store.crc` forges a mismatch down the same path.
            let ok = match s.crc_a {
                Some(v) => v,
                None => {
                    let v = !faults::fires("store.crc") && crate::util::crc64::crc64(&a) == ck.a;
                    if !v {
                        registry().store.crc_failures.inc();
                        nq_trace!(
                            TraceKind::CrcFailure,
                            "section A of {}",
                            self.source.describe()
                        );
                    }
                    s.crc_a = Some(v);
                    v
                }
            };
            if !ok {
                bail!(
                    "section A checksum mismatch for {} (corrupt fetch)",
                    self.source.describe()
                );
            }
        }
        s.stats.a_fetches += 1;
        s.stats.a_bytes_fetched += a.len() as u64;
        registry().store.a_fetches.inc();
        registry().store.a_bytes_fetched.add(a.len() as u64);
        if a.is_mapped() {
            // OS-paged window: the heap-residency gauge stays untouched
            s.stats.a_bytes_mapped += a.len() as u64;
        } else {
            registry().store.resident_a_bytes.add(a.len() as u64);
        }
        nq_trace!(
            TraceKind::PageIn,
            "section A of {} ({} bytes)",
            self.source.describe(),
            a.len()
        );
        s.a = Some(a.clone());
        Ok(a)
    }

    /// Attach section B (the upgrade page-in), fetching unless already
    /// resident. Nest archives only.
    pub fn attach_b(&self) -> Result<Bytes> {
        ensure!(
            self.index.kind == Kind::Nest,
            "section B only exists for nest containers ({})",
            self.source.describe()
        );
        // an A-only source (section-A blob wrapped as a whole artifact)
        // has no B to attach; fail before touching bytes or stats
        ensure!(
            self.index.section_b_bytes() > 0,
            "source has no section-B bytes ({} is part-bit only)",
            self.source.describe()
        );
        let mut s = self.state();
        if let Some(b) = &s.b {
            return Ok(b.clone());
        }
        faults::fail_point("store.read_b")
            .with_context(|| format!("fetching section B of {}", self.source.describe()))?;
        let b = self
            .source
            .fetch(Section::B)
            .with_context(|| format!("fetching section B of {}", self.source.describe()))?;
        ensure!(
            b.len() as u64 == self.index.section_b_bytes(),
            "section B fetch returned {} bytes, index says {}",
            b.len(),
            self.index.section_b_bytes()
        );
        if let Some(ck) = self.index.checksums {
            // lazy first-touch hash, memoized verdict (see `ensure_a`) —
            // this is what makes a switch storm re-hash nothing
            let ok = match s.crc_b {
                Some(v) => v,
                None => {
                    let v = !faults::fires("store.crc") && crate::util::crc64::crc64(&b) == ck.b;
                    if !v {
                        registry().store.crc_failures.inc();
                        nq_trace!(
                            TraceKind::CrcFailure,
                            "section B of {}",
                            self.source.describe()
                        );
                    }
                    s.crc_b = Some(v);
                    v
                }
            };
            if !ok {
                bail!(
                    "section B checksum mismatch for {} (corrupt fetch)",
                    self.source.describe()
                );
            }
        }
        s.stats.b_fetches += 1;
        s.stats.b_bytes_fetched += b.len() as u64;
        registry().store.b_fetches.inc();
        registry().store.b_bytes_fetched.add(b.len() as u64);
        if b.is_mapped() {
            s.stats.b_bytes_mapped += b.len() as u64;
        } else {
            registry().store.resident_b_bytes.add(b.len() as u64);
        }
        nq_trace!(
            TraceKind::PageIn,
            "section B of {} ({} bytes)",
            self.source.describe(),
            b.len()
        );
        s.b = Some(b.clone());
        Ok(b)
    }

    /// Drop the resident section-B bytes (the downgrade page-out).
    /// Returns whether anything was resident. Section A and the layout
    /// are untouched — that is the whole point.
    pub fn release_b(&self) -> bool {
        let mut s = self.state();
        let Some(b) = s.b.take() else { return false };
        s.stats.b_releases += 1;
        registry().store.b_releases.inc();
        if b.is_mapped() {
            // the OS owns these pages: hint them out rather than
            // pretending to free heap memory the gauge never counted
            b.advise_dontneed();
        } else {
            registry()
                .store
                .resident_b_bytes
                .sub(self.index.section_b_bytes());
        }
        nq_trace!(
            TraceKind::PageOut,
            "section B of {}",
            self.source.describe()
        );
        true
    }

    /// Drop the resident section-A bytes too (full unload; releases a
    /// resident section B first, counted). The parsed layout is kept:
    /// metadata is tiny and sources are immutable, so a re-load
    /// re-fetches bytes but never re-parses.
    pub fn release_a(&self) -> bool {
        let mut s = self.state();
        if let Some(b) = s.b.take() {
            s.stats.b_releases += 1;
            registry().store.b_releases.inc();
            if b.is_mapped() {
                b.advise_dontneed();
            } else {
                registry()
                    .store
                    .resident_b_bytes
                    .sub(self.index.section_b_bytes());
            }
        }
        let Some(a) = s.a.take() else { return false };
        if a.is_mapped() {
            a.advise_dontneed();
        } else {
            registry()
                .store
                .resident_a_bytes
                .sub(self.index.section_a_bytes());
        }
        nq_trace!(
            TraceKind::PageOut,
            "section A of {}",
            self.source.describe()
        );
        true
    }

    /// The tensor layout, parsed once per archive (fetches section A if
    /// needed).
    pub fn layout(&self) -> Result<Arc<ModelLayout>> {
        if let Some(l) = &self.state().layout {
            return Ok(Arc::clone(l));
        }
        let a = self.ensure_a()?;
        let parsed = Arc::new(
            ModelLayout::parse(&a, &self.index)
                .with_context(|| format!("parsing layout of {}", self.source.describe()))?,
        );
        let mut s = self.state();
        if let Some(l) = &s.layout {
            return Ok(Arc::clone(l)); // a racer parsed first
        }
        s.stats.layout_parses += 1;
        s.layout = Some(Arc::clone(&parsed));
        Ok(parsed)
    }

    /// Typed view over section A. For nest archives this is the
    /// part-bit launch state; for mono/fp32 archives it is the whole
    /// model.
    pub fn part_bit(&self) -> Result<PartBitModel> {
        let layout = self.layout()?;
        let a = self.ensure_a()?;
        PartBitModel::new(layout, a)
    }

    /// Typed view over both sections (attaches B if not resident).
    pub fn full_bit(&self) -> Result<FullBitModel> {
        let layout = self.layout()?;
        let a = self.ensure_a()?;
        let b = self.attach_b()?;
        FullBitModel::new(layout, a, b)
    }

    /// Owned [`Container`] decode (compat path for code that needs the
    /// typed tensors rather than views — report tables, baselines).
    pub fn to_container(&self, part_bit_only: bool) -> Result<Container> {
        let a = self.ensure_a()?;
        let mut c = container::parse_impl(&a, true)
            .with_context(|| format!("parsing {}", self.source.describe()))?;
        if self.index.kind == Kind::Nest && !part_bit_only {
            let b = self.attach_b()?;
            container::attach_section_b_impl(&mut c, &b)?;
        }
        c.file_len = self.index.payload_len();
        Ok(c)
    }
}

// ---------------------------------------------------------------------------
// ModelStore
// ---------------------------------------------------------------------------

/// Model id → shared [`NqArchive`]. Opening the same id twice returns
/// the *same* archive, so every consumer shares one set of section
/// bytes ("who owns the bytes" has one answer: the store's `Arc`).
///
/// Sharing also shares the paging lifecycle: `release_a`/`release_b`
/// on a shared archive drops the cached bytes for every sharer (each
/// refetches on demand — correctness is unaffected, residency-style
/// accounting is). Consumers that *drive* paging, like `ModelManager`,
/// therefore own private archives and opt into sharing explicitly.
#[derive(Default)]
pub struct ModelStore {
    inner: Mutex<BTreeMap<String, Arc<NqArchive>>>,
}

impl ModelStore {
    pub fn new() -> ModelStore {
        ModelStore::default()
    }

    /// The process-wide store. The coordinator resolves artifact paths
    /// through this, so N managers over one artifact share one archive.
    /// Keys are canonicalized paths; artifacts are treated as immutable
    /// for the process lifetime (same contract as the fleet zoo).
    pub fn global() -> &'static ModelStore {
        static GLOBAL: OnceLock<ModelStore> = OnceLock::new();
        GLOBAL.get_or_init(ModelStore::new)
    }

    /// Open (or share) the archive for a `.nq` path, keyed by its
    /// canonical form.
    pub fn open_path(&self, path: impl AsRef<Path>) -> Result<Arc<NqArchive>> {
        let path = path.as_ref();
        let key = std::fs::canonicalize(path)
            .unwrap_or_else(|_| path.to_path_buf())
            .display()
            .to_string();
        if let Some(a) = self.get(&key) {
            return Ok(a);
        }
        let archive = Arc::new(NqArchive::open(path)?);
        Ok(self.insert(key, archive))
    }

    /// Register an archive under `id`. If the id is already present the
    /// existing archive wins (and is returned) — sharing beats
    /// replacing for immutable artifacts.
    pub fn insert(&self, id: impl Into<String>, archive: Arc<NqArchive>) -> Arc<NqArchive> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        Arc::clone(g.entry(id.into()).or_insert(archive))
    }

    pub fn get(&self, id: &str) -> Option<Arc<NqArchive>> {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .get(id)
            .map(Arc::clone)
    }

    pub fn ids(&self) -> Vec<String> {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .cloned()
            .collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::synthetic_nest;
    use crate::store::PayloadView;

    fn toy_archive(seed: u64, n: u8, h: u8) -> NqArchive {
        let c = synthetic_nest(seed, n, h, 40, 8).unwrap();
        NqArchive::from_container(&c).unwrap()
    }

    #[test]
    fn upgrade_downgrade_cycles_never_refetch_a_or_reparse() {
        let arch = toy_archive(1, 8, 4);
        let part = arch.part_bit().unwrap();
        assert_eq!(part.layout().n(), 8);
        drop(part);
        let (a_len, b_len) = (arch.section_a_bytes(), arch.section_b_bytes());
        for _ in 0..5 {
            let full = arch.full_bit().unwrap(); // upgrade
            assert!(arch.b_resident());
            drop(full);
            assert!(arch.release_b()); // downgrade
            assert!(!arch.b_resident());
            let _part = arch.part_bit().unwrap(); // still servable
        }
        let s = arch.stats();
        assert_eq!(s.a_fetches, 1, "section A fetched exactly once");
        assert_eq!(s.layout_parses, 1, "layout parsed exactly once");
        assert_eq!(s.b_fetches, 5, "one B fetch per upgrade");
        assert_eq!(s.b_releases, 5);
        assert_eq!(s.a_bytes_fetched, a_len);
        assert_eq!(s.b_bytes_fetched, 5 * b_len);
    }

    #[test]
    fn views_share_bytes_zero_copy() {
        let arch = toy_archive(2, 8, 5);
        let p1 = arch.part_bit().unwrap();
        let p2 = arch.part_bit().unwrap();
        assert!(p1.section_a().ptr_eq(&p2.section_a()), "one A handle");
        let f = arch.full_bit().unwrap();
        assert!(f.section_a().ptr_eq(&p1.section_a()));
        // a dropped full-bit view keeps its B bytes alive through the Arc
        let b = f.section_b();
        arch.release_b();
        assert_eq!(b.len() as u64, arch.section_b_bytes());
    }

    #[test]
    fn part_view_matches_owned_decode() {
        let arch = toy_archive(3, 6, 4);
        let owned = arch.to_container(false).unwrap();
        let full = arch.full_bit().unwrap();
        assert_eq!(full.len(), owned.tensors.len());
        for (view, t) in full.tensors().zip(&owned.tensors) {
            assert_eq!(view.name(), t.name);
            assert_eq!(view.shape(), &t.shape[..]);
            match (view.payload(), &t.data) {
                (
                    PayloadView::Nest { scales, w_high, w_low },
                    crate::container::TensorData::Nest {
                        scales: s2,
                        w_high: h2,
                        w_low: Some(l2),
                    },
                ) => {
                    assert_eq!(scales.to_vec(), *s2);
                    assert_eq!(w_high.unpack(), h2.unpack());
                    assert_eq!(w_low.unwrap().unpack(), l2.unpack());
                    assert_eq!(w_high.get(3), h2.get(3));
                }
                (PayloadView::Fp32(v), crate::container::TensorData::Fp32(f)) => {
                    assert_eq!(v.to_vec(), *f);
                    assert_eq!(v.get(0), f[0]);
                }
                _ => panic!("payload mismatch for {}", t.name),
            }
        }
    }

    #[test]
    fn full_bit_needs_nest_kind() {
        let mut c = synthetic_nest(4, 8, 4, 8, 4).unwrap();
        // strip to a mono-like check: fp32 container
        c.tensors.retain(|t| matches!(t.data, crate::container::TensorData::Fp32(_)));
        c.kind = Kind::Fp32;
        c.n = 0;
        c.h = 0;
        c.act_bits = 0;
        let arch = NqArchive::from_container(&c).unwrap();
        assert!(arch.full_bit().is_err());
        let part = arch.part_bit().unwrap();
        assert_eq!(part.len(), 1);
    }

    #[test]
    fn model_store_shares_archives() {
        let dir = std::env::temp_dir().join(format!("nq_store_share_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.nq");
        let c = synthetic_nest(5, 8, 4, 16, 4).unwrap();
        crate::container::write(&path, &c).unwrap();
        let store = ModelStore::new();
        let a1 = store.open_path(&path).unwrap();
        let a2 = store.open_path(&path).unwrap();
        assert!(Arc::ptr_eq(&a1, &a2), "same archive shared");
        assert_eq!(store.len(), 1);
        // both handles see the same bytes and the same stats
        a1.ensure_a().unwrap();
        assert_eq!(a2.stats().a_fetches, 1);
        let named = store.insert("alias", Arc::clone(&a1));
        assert!(Arc::ptr_eq(&named, &a1));
        assert_eq!(store.len(), 2);
        assert!(store.get("alias").is_some());
        assert!(!store.is_empty());
    }
}
