//! [`StoreBudget`]: one RAM budget for resident Section-B bytes across
//! archives — the serving-side unification of the fleet cache's budgeted
//! residency with the store's attach/release lifecycle.
//!
//! A multi-tenant server hosts N archives from one [`super::ModelStore`];
//! each tenant upgrades (attach B) and downgrades (release B)
//! independently, but the *sum* of resident Section-B bytes must stay
//! under one cap. Attaching through the budget evicts the
//! least-recently-used other tenants' B sections first (calling
//! [`NqArchive::release_b`] on them — their section A and parsed layout
//! are untouched, so an evicted tenant keeps serving part-bit with zero
//! re-reads and re-upgrades later with exactly one B re-fetch).
//!
//! The accounting is [`ArchiveStats`]-backed: every eviction is a
//! counted `b_release` on the victim archive, every admit a counted
//! `b_fetch`, and the invariant "resident B bytes ≤ cap at every
//! interleaving" holds because evictions complete *before* the new
//! attach inside one critical section (`tests/serving.rs` samples it
//! from a racing thread).
//!
//! [`ArchiveStats`]: super::ArchiveStats

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Context, Result};

use crate::faults;
use crate::nq_trace;
use crate::telemetry::{registry, TraceKind};

use super::NqArchive;

/// One entry in the budget's eviction trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BudgetEvent {
    /// `id`'s section B became resident (`bytes` admitted).
    Attached { id: String, bytes: u64 },
    /// `victim`'s section B was evicted to make room for `for_id`.
    Evicted {
        victim: String,
        bytes: u64,
        for_id: String,
    },
    /// `id` released its section B voluntarily (downgrade/unload).
    Released { id: String, bytes: u64 },
}

impl std::fmt::Display for BudgetEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BudgetEvent::Attached { id, bytes } => write!(f, "attach  {id} (+{bytes} B)"),
            BudgetEvent::Evicted { victim, bytes, for_id } => {
                write!(f, "evict   {victim} (-{bytes} B) for {for_id}")
            }
            BudgetEvent::Released { id, bytes } => write!(f, "release {id} (-{bytes} B)"),
        }
    }
}

/// Bound on the retained eviction trace (older events are dropped).
const EVENT_CAP: usize = 4096;

struct Resident {
    archive: Arc<NqArchive>,
    bytes: u64,
    last_used: u64,
    /// Whether the attached bytes are an OS-paged mmap window. Kept per
    /// entry so the eviction ledger decrements the side it credited —
    /// evicting a mapped tenant must never claim to free heap memory
    /// the budget does not own.
    mapped: bool,
}

struct Inner {
    resident: BTreeMap<String, Resident>,
    /// Owned (heap) resident Section-B bytes.
    used: u64,
    /// Mapped (OS-paged) resident Section-B bytes — accounted against
    /// the same cap (a mapped window still occupies address space and
    /// page cache) but ledgered separately from owned bytes.
    mapped: u64,
    tick: u64,
    evictions: u64,
    events: VecDeque<BudgetEvent>,
}

/// Shared Section-B residency budget over any number of archives.
///
/// Thread-safe; attach/evict/release are atomic under one lock, so a
/// concurrent observer never sees the sum of resident bytes above the
/// cap. Archives managed through a budget must page their section B
/// exclusively through it — releasing directly on the archive leaves
/// the ledger stale (section A stays every consumer's own business).
///
/// Deliberate tradeoff: the admitting fetch happens *under* the budget
/// lock, which makes the cap invariant unconditional but serializes
/// concurrent upgrades (and briefly blocks `touch`) behind one
/// tenant's section-B read. Switches are rare and local fetches are
/// sub-millisecond; budgeting a slow `RemoteSource`-backed archive is
/// where a reserve-then-fetch protocol would earn its complexity.
pub struct StoreBudget {
    cap: u64,
    inner: Mutex<Inner>,
}

impl StoreBudget {
    /// A budget capping resident Section-B bytes at `cap_bytes`.
    pub fn new(cap_bytes: u64) -> StoreBudget {
        StoreBudget {
            cap: cap_bytes,
            inner: Mutex::new(Inner {
                resident: BTreeMap::new(),
                used: 0,
                mapped: 0,
                tick: 0,
                evictions: 0,
                events: VecDeque::new(),
            }),
        }
    }

    pub fn cap(&self) -> u64 {
        self.cap
    }

    /// The ledger, recovering from lock poisoning: evict/attach updates
    /// are ordered so any observed state satisfies the cap invariant
    /// even if a panic is isolated mid-sequence.
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Sum of currently resident Section-B bytes, owned + mapped
    /// (≤ cap, always).
    pub fn resident_bytes(&self) -> u64 {
        let g = self.lock();
        g.used + g.mapped
    }

    /// Resident Section-B bytes the budget actually owns (heap copies —
    /// the memory an eviction genuinely frees).
    pub fn owned_bytes(&self) -> u64 {
        self.lock().used
    }

    /// Resident Section-B bytes that are OS-paged mmap windows (counted
    /// against the cap, but freed by the OS, not by eviction).
    pub fn mapped_bytes(&self) -> u64 {
        self.lock().mapped
    }

    /// Ids whose section B is currently resident.
    pub fn resident_ids(&self) -> Vec<String> {
        self.lock().resident.keys().cloned().collect()
    }

    /// Whether `id`'s section B is currently resident under this budget.
    pub fn is_resident(&self, id: &str) -> bool {
        self.lock().resident.contains_key(id)
    }

    pub fn evictions(&self) -> u64 {
        self.lock().evictions
    }

    /// Drain the eviction/attach/release trace accumulated so far.
    pub fn drain_events(&self) -> Vec<BudgetEvent> {
        self.lock().events.drain(..).collect()
    }

    /// LRU-refresh `id` (called on the serve path of a full-bit tenant).
    pub fn touch(&self, id: &str) {
        let mut g = self.lock();
        g.tick += 1;
        let tick = g.tick;
        if let Some(r) = g.resident.get_mut(id) {
            r.last_used = tick;
        }
    }

    /// Attach `archive`'s section B under the budget, evicting other
    /// ids' B sections (LRU first) until it fits. Returns the evicted
    /// ids. Fails — without evicting anything — when the section alone
    /// exceeds the cap.
    pub fn attach_b(&self, id: &str, archive: &Arc<NqArchive>) -> Result<Vec<String>> {
        let need = archive.section_b_bytes();
        ensure!(need > 0, "{id}: archive has no section B to attach");
        ensure!(
            need <= self.cap,
            "{id}: section B ({need} B) exceeds the shared budget ({} B)",
            self.cap
        );
        let mut g = self.lock();
        g.tick += 1;
        let tick = g.tick;
        if let Some(r) = g.resident.get_mut(id) {
            r.last_used = tick;
            // idempotent re-attach: the archive call is a no-op when the
            // bytes are still resident, a counted re-fetch otherwise
            archive.attach_b()?;
            return Ok(Vec::new());
        }
        // evict BEFORE attaching, so resident bytes never overshoot the
        // cap at any interleaving an observer can witness.
        // Failpoint `store.evict`: an injected failure aborts the attach
        // with the evictions performed so far already ledgered exactly.
        let mut evicted = Vec::new();
        while g.used + g.mapped + need > self.cap {
            faults::fail_point("store.evict")
                .with_context(|| format!("evicting under the budget for {id}"))?;
            let victim = g
                .resident
                .iter()
                .min_by_key(|(_, r)| r.last_used)
                .map(|(k, _)| k.clone());
            let Some(v) = victim else { break };
            let r = g.resident.remove(&v).unwrap();
            r.archive.release_b();
            // decrement the ledger side this entry was credited to
            if r.mapped {
                g.mapped -= r.bytes;
            } else {
                g.used -= r.bytes;
            }
            g.evictions += 1;
            registry().store.evictions.inc();
            registry().store.evicted_bytes.add(r.bytes);
            nq_trace!(
                TraceKind::Eviction,
                "budget evicted {v} ({} B) for {id}",
                r.bytes
            );
            push_event(
                &mut g.events,
                BudgetEvent::Evicted {
                    victim: v.clone(),
                    bytes: r.bytes,
                    for_id: id.to_string(),
                },
            );
            evicted.push(v);
        }
        let bytes = archive
            .attach_b()
            .with_context(|| format!("attaching section B of {id}"))?;
        debug_assert_eq!(bytes.len() as u64, need);
        let mapped = bytes.is_mapped();
        if mapped {
            g.mapped += need;
        } else {
            g.used += need;
        }
        g.resident.insert(
            id.to_string(),
            Resident {
                archive: Arc::clone(archive),
                bytes: need,
                last_used: tick,
                mapped,
            },
        );
        push_event(
            &mut g.events,
            BudgetEvent::Attached {
                id: id.to_string(),
                bytes: need,
            },
        );
        Ok(evicted)
    }

    /// Release `id`'s section B (voluntary downgrade). Returns whether
    /// it was resident under this budget.
    pub fn release_b(&self, id: &str) -> bool {
        let mut g = self.lock();
        let Some(r) = g.resident.remove(id) else {
            return false;
        };
        r.archive.release_b();
        if r.mapped {
            g.mapped -= r.bytes;
        } else {
            g.used -= r.bytes;
        }
        push_event(
            &mut g.events,
            BudgetEvent::Released {
                id: id.to_string(),
                bytes: r.bytes,
            },
        );
        true
    }
}

fn push_event(events: &mut VecDeque<BudgetEvent>, e: BudgetEvent) {
    if events.len() >= EVENT_CAP {
        events.pop_front();
    }
    events.push_back(e);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::synthetic_nest;

    fn archive(seed: u64, rows: usize) -> Arc<NqArchive> {
        let c = synthetic_nest(seed, 8, 4, rows, 8).unwrap();
        Arc::new(NqArchive::from_container(&c).unwrap())
    }

    #[test]
    fn attach_evicts_lru_across_archives() {
        let (a, b, c) = (archive(1, 64), archive(2, 64), archive(3, 64));
        let b_len = a.section_b_bytes();
        assert_eq!(b.section_b_bytes(), b_len);
        // room for exactly two resident B sections
        let budget = StoreBudget::new(2 * b_len);
        budget.attach_b("a", &a).unwrap();
        budget.attach_b("b", &b).unwrap();
        assert_eq!(budget.resident_bytes(), 2 * b_len);
        budget.touch("a"); // b becomes LRU
        let evicted = budget.attach_b("c", &c).unwrap();
        assert_eq!(evicted, vec!["b".to_string()]);
        assert!(!b.b_resident(), "victim's bytes actually released");
        assert!(a.b_resident() && c.b_resident());
        assert_eq!(budget.resident_bytes(), 2 * b_len);
        // ledger-exact: memory-backed sections are owned, never mapped
        assert_eq!(budget.owned_bytes(), 2 * b_len);
        assert_eq!(budget.mapped_bytes(), 0);
        assert_eq!(budget.evictions(), 1);
        // the victim's release is counted on ITS archive stats
        assert_eq!(b.stats().b_releases, 1);
        // re-upgrading the victim re-fetches B once, never section A
        // (this archive never fetched A at all — B attaches alone)
        budget.attach_b("b", &b).unwrap();
        assert_eq!(b.stats().b_fetches, 2);
        assert_eq!(b.stats().a_fetches, 0, "eviction never touches section A");
    }

    #[test]
    fn oversized_section_is_rejected_without_evictions() {
        let a = archive(4, 64);
        let big = archive(5, 64);
        let budget = StoreBudget::new(a.section_b_bytes());
        budget.attach_b("a", &a).unwrap();
        // shrink the cap below any B by using a tiny-budget instance
        let tiny = StoreBudget::new(big.section_b_bytes() - 1);
        assert!(tiny.attach_b("big", &big).is_err());
        assert_eq!(tiny.evictions(), 0);
        assert!(a.b_resident(), "unrelated budget untouched");
    }

    #[test]
    fn attach_is_idempotent_and_release_balances() {
        let a = archive(6, 48);
        let budget = StoreBudget::new(u64::MAX);
        budget.attach_b("a", &a).unwrap();
        budget.attach_b("a", &a).unwrap(); // idempotent: no double-count
        assert_eq!(budget.resident_bytes(), a.section_b_bytes());
        assert_eq!(a.stats().b_fetches, 1);
        assert!(budget.release_b("a"));
        assert!(!budget.release_b("a"), "second release is a no-op");
        assert_eq!(budget.resident_bytes(), 0);
        assert_eq!(budget.owned_bytes() + budget.mapped_bytes(), 0);
        assert!(!a.b_resident());
        let events = budget.drain_events();
        assert_eq!(events.len(), 2, "{events:?}");
        assert!(matches!(events[0], BudgetEvent::Attached { .. }));
        assert!(matches!(events[1], BudgetEvent::Released { .. }));
        assert!(budget.drain_events().is_empty(), "drain drains");
    }

    /// File-backed archives attach mmap windows (with the feature on):
    /// the cap still binds, evictions still fire, but the bytes land in
    /// the *mapped* ledger — an eviction never "frees" owned memory the
    /// budget doesn't hold.
    #[cfg(all(unix, feature = "mmap"))]
    #[test]
    fn mapped_sections_are_ledgered_separately_and_still_evict() {
        let dir = std::env::temp_dir().join(format!("nq_budget_map_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let open = |seed: u64| -> Arc<NqArchive> {
            let c = synthetic_nest(seed, 8, 4, 64, 8).unwrap();
            let path = dir.join(format!("m{seed}.nq"));
            crate::container::write(&path, &c).unwrap();
            Arc::new(NqArchive::open(&path).unwrap())
        };
        let (a, b) = (open(21), open(22));
        let b_len = a.section_b_bytes();
        let budget = StoreBudget::new(b_len); // room for exactly one
        budget.attach_b("a", &a).unwrap();
        assert_eq!(budget.mapped_bytes(), b_len, "file-backed B is a mapped window");
        assert_eq!(budget.owned_bytes(), 0);
        assert_eq!(a.stats().b_bytes_mapped, b_len);
        let evicted = budget.attach_b("b", &b).unwrap();
        assert_eq!(evicted, vec!["a".to_string()], "cap binds mapped bytes too");
        assert_eq!(budget.mapped_bytes(), b_len);
        assert_eq!(budget.owned_bytes(), 0);
        assert!(budget.release_b("b"));
        assert_eq!(budget.resident_bytes(), 0);
    }

    #[test]
    fn event_display_is_greppable() {
        let e = BudgetEvent::Evicted {
            victim: "m1".into(),
            bytes: 512,
            for_id: "m2".into(),
        };
        assert_eq!(e.to_string(), "evict   m1 (-512 B) for m2");
    }
}
